"""Property + behaviour tests for SSA / HA-SSA — the paper's central claims.

The strongest claim (Sec. III-A, V-A): HA-SSA's update path is *identical* to
SSA's; only the storage policy and temperature-control arithmetic differ, so
with equivalent hyperparameters the stored states are bit-identical and the
solutions equal.  We assert this structurally, not statistically.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    SSAHyperParams,
    anneal,
    fig4_example,
    gset,
    memory,
    pack_spins,
    ssa_cycle_update,
    unpack_spins,
)
from repro.core.schedule import hassa_schedule, n_temp_steps, ssa_schedule


# ---------------------------------------------------------------------------
# Eq. (2b)/(2c): the Itanh FSM epilogue
# ---------------------------------------------------------------------------
@given(
    st.integers(-100, 100),
    st.integers(-40, 40),
    st.sampled_from([-1, 1]),
    st.sampled_from([1, 2, 4, 8, 16, 32]),
    st.integers(0, 4),
)
@settings(max_examples=200, deadline=None)
def test_itanh_fsm_matches_eq2(field, itanh, r, i0, n_rnd):
    m_new, itanh_new = ssa_cycle_update(
        jnp.asarray([field]), jnp.asarray([itanh]), jnp.asarray([r]), jnp.int32(i0), n_rnd
    )
    I = field + n_rnd * r + itanh  # noqa: E741 — Eq. (2a) current
    if I >= i0:
        expect_it = i0 - 1
    elif I < -i0:
        expect_it = -i0
    else:
        expect_it = I
    assert int(itanh_new[0]) == expect_it
    assert int(m_new[0]) == (1 if expect_it >= 0 else -1)
    # FSM has 2*I0 states: Itanh always lands in [-I0, I0-1]
    assert -i0 <= int(itanh_new[0]) <= i0 - 1


# ---------------------------------------------------------------------------
# Eq. (3) vs Eq. (4): schedule equivalence (Sec. III-A)
# ---------------------------------------------------------------------------
@given(
    st.sampled_from([1, 2, 4]),
    st.sampled_from([8, 16, 32, 64]),
    st.integers(1, 2),
    st.integers(1, 50),
)
@settings(max_examples=50, deadline=None)
def test_schedule_equivalence(i0_min, i0_max, beta_shift, tau):
    """β_ssa = 2^-β_hassa ⇒ identical I0 sequences."""
    hs = hassa_schedule(i0_min, i0_max, tau, beta_shift)
    ss = ssa_schedule(i0_min, i0_max, tau, 2.0 ** (-beta_shift))
    np.testing.assert_array_equal(hs.i0_per_cycle, ss.i0_per_cycle)
    np.testing.assert_array_equal(hs.store_mask, ss.store_mask)
    assert hs.steps == n_temp_steps(i0_min, i0_max, beta_shift)
    # the store mask is exactly the final plateau
    assert hs.store_mask.sum() == tau
    assert np.all(hs.store_mask[-tau:])


def test_schedule_shift_is_power_of_two():
    s = hassa_schedule(1, 32, 3, beta_shift=1)
    np.testing.assert_array_equal(np.unique(s.i0_per_cycle), [1, 2, 4, 8, 16, 32])
    s2 = hassa_schedule(1, 16, 2, beta_shift=2)  # 1,4,16
    np.testing.assert_array_equal(np.unique(s2.i0_per_cycle), [1, 4, 16])


# ---------------------------------------------------------------------------
# The central property: HA-SSA ≡ SSA
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("noise", ["xorshift", "threefry"])
def test_hassa_equals_ssa_storage_subset(noise):
    """HA-SSA's stored bitplanes == the I0max slice of SSA's full record."""
    g = gset.toroidal_grid(64, seed=3)
    hp = SSAHyperParams(n_trials=4, m_shot=3, tau=8, i0_min=1, i0_max=8)
    ra = anneal(g, hp, seed=7, storage="i0max", record="traj", noise=noise)
    rb = anneal(g, hp, seed=7, storage="all", record="traj", noise=noise)
    steps = hp.steps
    assert ra.traj.shape == (3, hp.tau, 4, 2)
    assert rb.traj.shape == (3, steps * hp.tau, 4, 2)
    np.testing.assert_array_equal(ra.traj, rb.traj[:, -hp.tau :])
    # Eq.(5)/(6) witness: structural storage ratio equals the plateau count
    assert rb.stored_bits_per_iter == steps * ra.stored_bits_per_iter


@given(st.integers(0, 10_000), st.integers(2, 4), st.sampled_from([4, 8, 16]))
@settings(max_examples=10, deadline=None)
def test_hassa_equals_ssa_property(seed, m_shot, i0_max):
    """Property form over random seeds/hyperparams (small instances)."""
    g = gset.king_graph(36, seed=1)
    hp = SSAHyperParams(n_trials=2, m_shot=m_shot, tau=5, i0_min=1, i0_max=i0_max)
    ra = anneal(g, hp, seed=seed, storage="i0max", record="traj", noise="xorshift")
    rb = anneal(g, hp, seed=seed, storage="all", record="traj", noise="xorshift")
    np.testing.assert_array_equal(ra.traj, rb.traj[:, -hp.tau :])


def test_hassa_equals_ssa_solution_quality():
    """Fig. 8 claim: same best/avg cut values over trials (shared stream).

    The best state almost always occurs in the cold (stored) phase, so the
    policies agree; we assert equality on this seeded configuration the way
    the paper asserts it over its 100-trial runs.
    """
    g = gset.load("G11")
    hp = SSAHyperParams(n_trials=8, m_shot=8)
    ra = anneal(g, hp, seed=0, storage="i0max", record="best", noise="xorshift")
    rb = anneal(g, hp, seed=0, storage="all", record="best", noise="xorshift")
    assert ra.overall_best_cut == rb.overall_best_cut
    assert ra.mean_best_cut == rb.mean_best_cut


def test_best_record_matches_traj_record():
    """Running-best (production mode) == scan-the-trajectory (FPGA mode)."""
    g = gset.toroidal_grid(64, seed=9)
    hp = SSAHyperParams(n_trials=3, m_shot=4, tau=6, i0_min=1, i0_max=8)
    rb = anneal(g, hp, seed=11, storage="i0max", record="best", noise="xorshift")
    rt = anneal(g, hp, seed=11, storage="i0max", record="traj", noise="xorshift")
    np.testing.assert_array_equal(rb.best_cut, rt.best_cut)


def test_schedule_kind_hassa_equals_ssa_run():
    """Eq.(4) vs Eq.(3) schedules drive identical runs (β=1 ⇔ β=0.5)."""
    g = gset.toroidal_grid(36, seed=4)
    hp = SSAHyperParams(n_trials=2, m_shot=3, tau=5, i0_min=1, i0_max=8)
    ra = anneal(g, hp, seed=3, schedule_kind="hassa", record="traj", noise="xorshift")
    rb = anneal(g, hp, seed=3, schedule_kind="ssa", record="traj", noise="xorshift")
    np.testing.assert_array_equal(ra.traj, rb.traj)


# ---------------------------------------------------------------------------
# Backends agree (sparse gather vs dense MXU matmul)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["dense"])
def test_backends_bitwise_equal(backend):
    g = gset.king_graph(36, seed=5)
    hp = SSAHyperParams(n_trials=3, m_shot=3, tau=5, i0_min=1, i0_max=8)
    rs = anneal(g, hp, seed=2, record="traj", noise="xorshift", backend="sparse")
    rd = anneal(g, hp, seed=2, record="traj", noise="xorshift", backend=backend)
    np.testing.assert_array_equal(rs.traj, rd.traj)


# ---------------------------------------------------------------------------
# Solution quality / convergence behaviour
# ---------------------------------------------------------------------------
def test_fig4_all_trials_reach_optimum():
    p = fig4_example()
    hp = SSAHyperParams(n_trials=8, m_shot=5, tau=10, i0_min=1, i0_max=8)
    r = anneal(p, hp, seed=0)
    assert np.all(r.best_cut == 3)


def test_energy_trace_monotone_convergence():
    """Fig. 7 shape: mean energy decreases substantially from start to end."""
    g = gset.load("G11")
    hp = SSAHyperParams(n_trials=8, m_shot=10)
    r = anneal(g, hp, seed=0, track_energy=True)
    e = r.energy_mean
    assert e is not None and e.shape == (hp.total_cycles,)
    head = e[:100].mean()
    tail = e[-100:].mean()
    assert tail < head - 100  # converged far below the random-state energy


def test_cycle_duration_mode():
    """Conventional-SSA cycle-count control truncates the final iteration."""
    g = gset.toroidal_grid(36, seed=4)
    hp = SSAHyperParams(n_trials=2, m_shot=3, tau=5, i0_min=1, i0_max=8)
    r = anneal(g, hp, seed=1, total_cycles=37, track_energy=True)
    assert r.energy_mean.shape == (37,)


# ---------------------------------------------------------------------------
# Bit packing
# ---------------------------------------------------------------------------
@given(st.integers(1, 100), st.integers(0, 10**6))
@settings(max_examples=50, deadline=None)
def test_pack_unpack_roundtrip(n, seed):
    rng = np.random.default_rng(seed)
    m = rng.choice([-1, 1], size=(3, n)).astype(np.int8)
    packed = pack_spins(jnp.asarray(m))
    assert packed.shape == (3, (n + 31) // 32)
    out = unpack_spins(packed, n)
    np.testing.assert_array_equal(np.asarray(out), m)


# ---------------------------------------------------------------------------
# Memory model (Eq. 5/6, Table IV)
# ---------------------------------------------------------------------------
def test_memory_model_table_iv():
    hp = SSAHyperParams()  # Table II: I0 1→32, τ=100, β=1, m_shot=150
    n = 800
    m_ssa = memory.ssa_bits_per_iteration(n, hp)
    m_ha = memory.hassa_bits_per_iteration(n, hp)
    assert m_ssa == 800 * 6 * 100 == 480_000       # 0.48 Mb  (Table IV)
    assert m_ha == 800 * 100 == 80_000             # 0.08 Mb  (Table IV)
    assert memory.memory_ratio(hp) == 6            # the paper's 6×
    assert memory.bits_per_trial(n, hp, hardware_aware=False) == 72_000_000
    assert memory.bits_per_trial(n, hp, hardware_aware=True) == 12_000_000


def test_memory_matches_structural_storage():
    """Eq.(5)/(6) agree with the actual XLA buffer shapes we allocate."""
    g = gset.toroidal_grid(64, seed=3)
    hp = SSAHyperParams(n_trials=2, m_shot=2, tau=4, i0_min=1, i0_max=8)
    ra = anneal(g, hp, seed=0, storage="i0max", record="traj")
    rb = anneal(g, hp, seed=0, storage="all", record="traj")
    assert ra.stored_bits_per_iter == memory.hassa_bits_per_iteration(64, hp)
    assert rb.stored_bits_per_iter == memory.ssa_bits_per_iteration(64, hp)
    # and the materialized buffers have exactly those bit counts (packed)
    assert ra.traj.shape[1] * 64 == ra.stored_bits_per_iter
    assert rb.traj.shape[1] * 64 == rb.stored_bits_per_iter
