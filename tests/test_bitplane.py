"""Bitplane codec (repro.kernels.bitplane / DESIGN.md §4).

The codec is THE storage format of the packed memory subsystem: the engine's
packed state, the trajectory planes, and the streamed-noise kernel's
HBM-facing refs all share this bit layout (bit k of word w = sign of spin
32·w + k, 1 ⇔ +1).  Contracts under test: exact roundtrip for any N
(including non-multiple-of-32 tails), zero tail bits on pack, agreement with
the engine's re-exported symbols, and byte accounting.
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import bitplane as bp


def _random_spins(rng, shape):
    return rng.choice(np.array([-1, 1], dtype=np.int8), size=shape)


@pytest.mark.parametrize("n", [1, 5, 31, 32, 33, 64, 100, 800, 257])
def test_roundtrip_exact(n):
    rng = np.random.default_rng(n)
    m = _random_spins(rng, (3, n))
    packed = np.asarray(bp.pack_spins(m))
    assert packed.shape == (3, bp.packed_words(n))
    assert packed.dtype == np.uint32
    out = np.asarray(bp.unpack_spins(packed, n))
    np.testing.assert_array_equal(out, m)


@given(st.integers(1, 300), st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_roundtrip_property(n, seed):
    rng = np.random.default_rng(seed)
    m = _random_spins(rng, (2, n))
    out = np.asarray(bp.unpack_spins(bp.pack_spins(m), n))
    np.testing.assert_array_equal(out, m)


def test_tail_bits_are_zero():
    """For N % 32 != 0 the last word's high bits must be zero-padded."""
    n = 35  # one full word + 3 tail bits
    m = np.ones((2, n), np.int8)  # all +1: every live bit set
    packed = np.asarray(bp.pack_spins(m))
    assert packed.shape[-1] == 2
    np.testing.assert_array_equal(packed[:, 0], np.uint32(0xFFFFFFFF))
    np.testing.assert_array_equal(packed[:, 1], np.uint32(0b111))


def test_bit_layout_is_lsb_first():
    """Bit k of word w holds spin 32·w + k (the kernel relies on this)."""
    n = 40
    m = -np.ones((1, n), np.int8)
    m[0, 0] = 1    # word 0, bit 0
    m[0, 33] = 1   # word 1, bit 1
    packed = np.asarray(bp.pack_spins(m))
    assert packed[0, 0] == 1
    assert packed[0, 1] == 2


def test_pack_accepts_any_numeric_dtype():
    n = 50
    rng = np.random.default_rng(0)
    m8 = _random_spins(rng, (4, n))
    for dtype in (np.int8, np.int32, np.float32):
        np.testing.assert_array_equal(
            np.asarray(bp.pack_spins(m8.astype(dtype))),
            np.asarray(bp.pack_spins(m8)),
        )


def test_leading_batch_dims():
    rng = np.random.default_rng(7)
    m = _random_spins(rng, (2, 3, 70))
    packed = bp.pack_spins(m)
    assert packed.shape == (2, 3, bp.packed_words(70))
    np.testing.assert_array_equal(np.asarray(bp.unpack_spins(packed, 70)), m)


def test_word_and_byte_accounting():
    assert bp.packed_words(1) == 1
    assert bp.packed_words(32) == 1
    assert bp.packed_words(33) == 2
    assert bp.packed_words(800) == 25
    assert bp.packed_nbytes(800) == 100  # the paper's 800-bit BRAM word
    assert bp.packed_nbytes(33) == 8


def test_engine_reexports_are_the_codec():
    """repro.core.engine's pack/unpack ARE the kernel-side codec (one layout)."""
    from repro.core import engine

    assert engine.pack_spins is bp.pack_spins
    assert engine.unpack_spins is bp.unpack_spins
    assert engine.packed_words is bp.packed_words


def test_pack_state_roundtrip():
    """Engine-state packing is exact for ±1 spins and leaves other fields."""
    import jax.numpy as jnp

    from repro.core.engine import EngineState, pack_state, unpack_state

    rng = np.random.default_rng(3)
    n, t = 45, 3
    m = _random_spins(rng, (t, n))
    bm = _random_spins(rng, (t, n))
    st = EngineState(
        jnp.zeros((4, t, n), jnp.uint32),
        jnp.asarray(m),
        jnp.asarray(rng.integers(-8, 8, size=(t, n)), jnp.int32),
        jnp.asarray(rng.integers(-50, 50, size=(t,)), jnp.int32),
        jnp.asarray(bm),
    )
    back = unpack_state(pack_state(st), n)
    for a, b in zip(st, back):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
