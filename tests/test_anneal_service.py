"""Shape-bucketed annealing service (DESIGN.md §7).

The serving contracts under test:

* one compiled plateau program per shape bucket — counted by trace-time
  side effects AND by the jitted functions' cache sizes (jit cache misses);
* batched, padded, chunked runs are bit-identical on the live lanes to the
  unpadded single-problem drivers (padding invariance, all three backends);
* chunked execution streams per-chunk best reports and early-stops on
  target_cut;
* SA and PT-SSA requests ride the same entry.
"""
import os

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    SAHyperParams,
    SSAHyperParams,
    anneal,
    anneal_sa,
    bucket_n,
    gset,
    memory,
    pad_model,
)
from repro.core.pt import PTSSAHyperParams, anneal_pt_ssa
from repro.serve import AnnealRequest, AnnealService

HP = SSAHyperParams(n_trials=3, m_shot=4, tau=4, i0_min=1, i0_max=8)
BACKENDS = ["sparse", "dense", "pallas"]


def _mixed_problems():
    """Heterogeneous sizes spanning two buckets (min_bucket=16 → 64, 128)."""
    return [
        gset.toroidal_grid(36, seed=1, name="t36"),
        gset.king_graph(49, seed=2, name="k49"),
        gset.toroidal_grid(64, seed=3, name="t64"),
        gset.toroidal_grid(100, seed=4, name="t100"),
    ]


# ---------------------------------------------------------------------------
# The acceptance property: mixed-size batches == per-problem unpadded runs
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", BACKENDS)
def test_mixed_batch_bit_identical_to_unpadded_runs(backend):
    problems = _mixed_problems()
    reqs = [AnnealRequest(problem=p, hp=HP, seed=10 + i)
            for i, p in enumerate(problems)]
    svc = AnnealService(backend=backend, min_bucket=16)
    responses = svc.solve(reqs)
    for i, (p, resp) in enumerate(zip(problems, responses)):
        ref = anneal(p, HP, seed=10 + i, record="best", noise="xorshift",
                     backend="sparse", track_energy=False)
        np.testing.assert_array_equal(ref.best_energy, resp.result.best_energy)
        np.testing.assert_array_equal(ref.best_cut, resp.result.best_cut)
        np.testing.assert_array_equal(ref.best_m, resp.result.best_m)
        assert resp.result.best_m.shape == (HP.n_trials, p.n)  # live lanes only
        assert resp.bucket == bucket_n(p.n, 16)


# ---------------------------------------------------------------------------
# Padding-invariance property: padded-to-next-bucket == unpadded, live lanes
# ---------------------------------------------------------------------------
@given(st.integers(0, 10_000))
@settings(max_examples=3, deadline=None)
def test_padding_invariance_property(seed):
    """A problem zero-padded to its bucket (zero J rows/cols, zero h) yields
    the identical best cut and best spins on the live lanes — all three
    backends."""
    p = gset.king_graph(36, seed=seed % 7)
    model = p.to_ising()
    nb = bucket_n(model.n, 16)
    assert nb > model.n  # the property is about actual padding
    padded = pad_model(model, nb)
    assert padded.n == nb
    assert np.all(np.asarray(padded.h[model.n:]) == 0)
    assert np.all(np.asarray(padded.nbr_w[model.n:]) == 0)

    ref = anneal(p, HP, seed=seed, record="best", noise="xorshift",
                 backend="sparse", track_energy=False)
    for backend in BACKENDS:
        svc = AnnealService(backend=backend, min_bucket=16)
        resp = svc.solve([AnnealRequest(problem=p, hp=HP, seed=seed)])[0]
        np.testing.assert_array_equal(ref.best_cut, resp.result.best_cut)
        np.testing.assert_array_equal(ref.best_m, resp.result.best_m)


# ---------------------------------------------------------------------------
# One compile per bucket (the retrace/recompile fix), counted two ways
# ---------------------------------------------------------------------------
def test_same_bucket_batch_compiles_plateau_program_once():
    svc = AnnealService(backend="sparse", min_bucket=16)
    reqs = [
        AnnealRequest(problem=gset.toroidal_grid(36, seed=s, name=f"g{s}"),
                      hp=HP, seed=s)
        for s in range(4)
    ]
    svc.solve(reqs)
    # Trace-time side-effect counters: the plateau chunk program traced once.
    assert svc.stats["traces_chunk"] == 1
    assert svc.stats["traces_init"] == 1
    assert svc.stats["program_cache_misses"] == 1
    # jax.jit's own cache agrees: one miss per jitted program.
    (_, init_fn, chunk_fn), = svc._programs.values()
    assert init_fn._cache_size() == 1
    assert chunk_fn._cache_size() == 1


def test_one_compile_per_bucket_for_mixed_sizes():
    svc = AnnealService(backend="sparse", min_bucket=16)
    reqs = [AnnealRequest(problem=p, hp=HP, seed=i)
            for i, p in enumerate(_mixed_problems())]
    svc.solve(reqs)
    # 36/49/64 → bucket 64; 100 → bucket 128: two buckets, two programs.
    assert svc.stats["traces_chunk"] == 2
    assert len(svc._programs) == 2


def test_executable_reused_across_solve_calls():
    svc = AnnealService(backend="sparse", min_bucket=16)
    mk = lambda s: [AnnealRequest(  # noqa: E731
        problem=gset.toroidal_grid(36, seed=s), hp=HP, seed=s)]
    svc.solve(mk(0))
    svc.solve(mk(1))
    svc.solve(mk(2))
    assert svc.stats["traces_chunk"] == 1  # compiled once, reused twice
    assert svc.stats["program_cache_hits"] == 2


# ---------------------------------------------------------------------------
# Chunked execution: streaming reports + early stop
# ---------------------------------------------------------------------------
def test_chunk_reports_stream_and_early_stop():
    p = gset.toroidal_grid(36, seed=1)
    hp = SSAHyperParams(n_trials=3, m_shot=10, tau=4, i0_min=1, i0_max=8)
    events = []
    svc = AnnealService(backend="sparse", min_bucket=16)
    resp = svc.solve(
        [AnnealRequest(problem=p, hp=hp, seed=0, target_cut=1)],
        progress=events.append,
    )[0]
    assert resp.chunks_run < resp.chunks_total  # early stop fired
    assert resp.result.overall_best_cut >= 1
    assert len(events) == resp.chunks_run
    assert [e.chunk for e in events] == list(range(resp.chunks_run))
    # the streamed trace is monotone (a running best) and matches the result
    trace = resp.chunk_best_cut
    assert len(trace) == resp.chunks_run
    assert all(a <= b for a, b in zip(trace, trace[1:]))
    assert trace[-1] == resp.result.overall_best_cut
    assert svc.stats["early_stops"] == 1


def test_untargeted_requests_run_to_completion():
    p = gset.toroidal_grid(36, seed=1)
    hp = SSAHyperParams(n_trials=3, m_shot=4, tau=4, i0_min=1, i0_max=8)
    svc = AnnealService(backend="sparse", min_bucket=16)
    resp = svc.solve([AnnealRequest(problem=p, hp=hp, seed=0)])[0]
    assert resp.chunks_run == resp.chunks_total == hp.m_shot


def test_chunked_equals_unchunked():
    p = gset.toroidal_grid(36, seed=5)
    hp = SSAHyperParams(n_trials=3, m_shot=6, tau=4, i0_min=1, i0_max=8)
    r1 = AnnealService(backend="sparse", min_bucket=16, chunk_shots=1).solve(
        [AnnealRequest(problem=p, hp=hp, seed=3)])[0]
    r3 = AnnealService(backend="sparse", min_bucket=16, chunk_shots=3).solve(
        [AnnealRequest(problem=p, hp=hp, seed=3)])[0]
    np.testing.assert_array_equal(r1.result.best_energy, r3.result.best_energy)
    assert r1.chunks_run == 6 and r3.chunks_run == 2


# ---------------------------------------------------------------------------
# SA and PT-SSA ride the same service entry
# ---------------------------------------------------------------------------
def test_sa_requests_via_service():
    problems = [gset.toroidal_grid(36, seed=1), gset.king_graph(49, seed=2)]
    hp = SAHyperParams(n_trials=4, n_cycles=400)
    svc = AnnealService(backend="sparse", min_bucket=16)
    responses = svc.solve(
        [AnnealRequest(problem=p, hp=hp, seed=1) for p in problems]
    )
    for p, r in zip(problems, responses):
        assert r.result.best_m.shape == (hp.n_trials, p.n)
        # padded lanes never proposed → reported spins reproduce the cut
        cuts = p.cut_value(np.asarray(r.result.best_m, np.int32))
        np.testing.assert_array_equal(np.asarray(cuts), r.result.best_cut)
        # sanity vs the single-problem driver's solution quality
        ref = anneal_sa(p, hp, seed=1, track_energy=False)
        assert r.result.overall_best_cut >= 0.7 * max(ref.overall_best_cut, 1)


def test_ptssa_requests_bit_identical_to_driver():
    problems = [gset.toroidal_grid(36, seed=1), gset.king_graph(49, seed=2)]
    hp = PTSSAHyperParams(n_replicas=6, n_rounds=8, tau=10)
    svc = AnnealService(backend="sparse", min_bucket=16, chunk_shots=2)
    responses = svc.solve(
        [AnnealRequest(problem=p, hp=hp, seed=2) for p in problems]
    )
    for p, r in zip(problems, responses):
        ref = anneal_pt_ssa(p, hp, seed=2, backend="sparse", noise="xorshift")
        np.testing.assert_array_equal(ref.best_energy, r.result.best_energy)
        np.testing.assert_array_equal(ref.best_cut, r.result.best_cut)


def test_ptssa_rejects_pallas_backend():
    with pytest.raises(ValueError, match="per-replica I0"):
        AnnealService(backend="pallas", min_bucket=16).solve(
            [AnnealRequest(problem=gset.toroidal_grid(36, seed=1),
                           hp=PTSSAHyperParams(n_replicas=4, n_rounds=2, tau=5))]
        )


# ---------------------------------------------------------------------------
# Bucketing + padding-overhead memory model
# ---------------------------------------------------------------------------
def test_bucket_n_powers_of_two():
    assert bucket_n(800) == 1024
    assert bucket_n(1024) == 1024
    assert bucket_n(1025) == 2048
    assert bucket_n(10, min_bucket=64) == 64


def test_padding_overhead_model():
    hp = SSAHyperParams()  # Table II: tau=100
    # N=800 → bucket 1024: 224 dead lanes × 100 stored cycles per iteration
    assert memory.padding_overhead_bits_per_iteration(800, hp) == 224 * 100
    # conventional SSA stores every plateau → steps× the waste
    assert memory.padding_overhead_bits_per_iteration(
        800, hp, hardware_aware=False
    ) == 224 * 100 * memory.memory_ratio(hp)
    # exactly-bucket-sized problems waste nothing
    assert memory.padding_overhead_bits_per_iteration(1024, hp) == 0
    assert memory.padding_overhead_fraction(800) == pytest.approx(224 / 1024)


# ---------------------------------------------------------------------------
# Request-boundary edge cases (DESIGN.md §10)
# ---------------------------------------------------------------------------
def test_empty_batch_returns_empty():
    svc = AnnealService(backend="sparse", min_bucket=16)
    assert svc.solve([]) == []
    assert svc.stats["requests"] == 0 and len(svc._programs) == 0


def test_duplicate_and_aliased_requests():
    """The same request object repeated in one batch: every occurrence gets
    its own (identical) response; batchmates are unaffected."""
    p = gset.toroidal_grid(36, seed=1)
    hp = SSAHyperParams(n_trials=3, m_shot=4, tau=4, i0_min=1, i0_max=8)
    req = AnnealRequest(problem=p, hp=hp, seed=7)
    solo = AnnealService(backend="sparse", min_bucket=16).solve([req])[0]
    svc = AnnealService(backend="sparse", min_bucket=16)
    rs = svc.solve([req, req, AnnealRequest(problem=p, hp=hp, seed=8), req])
    assert len(rs) == 4
    for r in (rs[0], rs[1], rs[3]):
        np.testing.assert_array_equal(r.result.best_energy,
                                      solo.result.best_energy)
        np.testing.assert_array_equal(r.result.best_m, solo.result.best_m)
    assert rs[2].result.best_energy.shape == solo.result.best_energy.shape
    assert all(r.status == "ok" for r in rs)


# ---------------------------------------------------------------------------
# Executable-cache bounds and concurrency
# ---------------------------------------------------------------------------
def test_executable_cache_lru_eviction():
    """A capacity-1 cache evicts the cold program, counts the eviction,
    and recompiles (bit-identically) when the evicted bucket returns."""
    p_small = gset.toroidal_grid(36, seed=1)   # bucket 64
    p_large = gset.toroidal_grid(100, seed=2)  # bucket 128
    base = AnnealService(backend="sparse", min_bucket=16).solve(
        [AnnealRequest(problem=p_small, hp=HP, seed=1)])[0]

    svc = AnnealService(backend="sparse", min_bucket=16,
                        max_cached_executables=1)
    svc.solve([AnnealRequest(problem=p_small, hp=HP, seed=1)])
    svc.solve([AnnealRequest(problem=p_large, hp=HP, seed=2)])
    info = svc.cache_info()
    assert info["capacity"] == 1
    assert info["programs"] == 1      # bounded, not growing
    assert info["evictions"] == 1     # small-bucket program was dropped

    # the evicted program recompiles on return — same answer, new trace
    traces_before = svc.stats["traces_chunk"]
    r = svc.solve([AnnealRequest(problem=p_small, hp=HP, seed=1)])[0]
    assert svc.stats["traces_chunk"] == traces_before + 1
    assert svc.cache_info()["evictions"] == 2
    np.testing.assert_array_equal(r.result.best_energy,
                                  base.result.best_energy)
    np.testing.assert_array_equal(r.result.best_m, base.result.best_m)

    with pytest.raises(ValueError):
        AnnealService(backend="sparse", max_cached_executables=0)


def test_concurrent_solves_share_cache_safely(tmp_path):
    """Two threads solving same-bucket requests concurrently: no cache
    corruption, both bit-identical to their sequential runs, and their
    checkpoint trees land under distinct group fingerprints."""
    import threading

    from repro.serve import ResiliencePolicy

    reqs = [AnnealRequest(problem=gset.toroidal_grid(36, seed=s), hp=HP,
                          seed=s) for s in (1, 2)]
    solo = AnnealService(backend="sparse", min_bucket=16)
    base = [solo.solve([r])[0] for r in reqs]

    pol = ResiliencePolicy(checkpoint_dir=str(tmp_path),
                           cleanup_on_success=False)
    svc = AnnealService(backend="sparse", min_bucket=16, resilience=pol)
    svc.solve([reqs[0]])  # warm the executable so both threads race reuse
    results, errors = [None, None], []
    gate = threading.Barrier(2)

    def worker(i):
        try:
            gate.wait(timeout=30)
            results[i] = svc.solve([reqs[i]])[0]
        except Exception as e:  # pragma: no cover - surfaced via assert
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
    assert not errors
    for r, b in zip(results, base):
        assert r is not None and r.status == "ok"
        np.testing.assert_array_equal(r.result.best_energy,
                                      b.result.best_energy)
        np.testing.assert_array_equal(r.result.best_m, b.result.best_m)
    # distinct problems => distinct checkpoint fingerprints, both present
    assert len(os.listdir(tmp_path)) == 2
    # the cache stayed bounded and coherent: one program, no evictions
    info = svc.cache_info()
    assert info["programs"] == 1 and info["evictions"] == 0
