"""Tests for the beyond-paper HA-SSA expert-placement optimizer."""
import numpy as np
import pytest

from repro.core.placement import (coactivation_stats, expert_placement,
                                  placement_ising, traffic_cost)


def _clique_routing(E=16, K=4, T=500, seed=0):
    """Experts in cliques of 4 that co-fire."""
    rng = np.random.default_rng(seed)
    cliques = np.arange(E).reshape(E // 4, 4)
    routing = np.zeros((T, K), dtype=np.int64)
    for t in range(T):
        routing[t] = cliques[rng.integers(0, E // 4)][:K]
    return routing


def test_coactivation_stats():
    routing = np.asarray([[0, 1], [0, 1], [2, 3]])
    coact, load = coactivation_stats(routing, 4)
    assert coact[0, 1] == 2 and coact[1, 0] == 2
    assert coact[2, 3] == 1
    assert coact[0, 2] == 0
    np.testing.assert_array_equal(load, [2, 2, 1, 1])


def test_placement_ising_symmetric_integer():
    routing = _clique_routing()
    coact, load = coactivation_stats(routing, 16)
    model = placement_ising(coact, load)
    J = model.dense_J()
    assert np.array_equal(J, J.T)
    assert np.all(np.diag(J) == 0)
    assert J.dtype == np.int32


def test_placement_beats_round_robin_on_clique_structure():
    routing = _clique_routing(E=16, K=4, T=500)
    coact, load = coactivation_stats(routing, 16)
    res = expert_placement(coact, load, n_devices=4, seed=0)
    assert res.cost <= res.baseline_cost
    assert res.improvement > 0.2  # cliques are easy: expect a big win
    # all devices used, exactly 4 experts each (balanced splits)
    counts = np.bincount(res.assignment, minlength=4)
    assert counts.max() <= 8 and counts.min() >= 1


def test_placement_respects_device_count():
    routing = _clique_routing(E=32, K=4, T=300, seed=1)
    coact, load = coactivation_stats(routing, 32)
    res = expert_placement(coact, load, n_devices=8, seed=1)
    assert res.assignment.shape == (32,)
    assert set(np.unique(res.assignment)) <= set(range(8))


def test_power_of_two_required():
    routing = _clique_routing()
    coact, load = coactivation_stats(routing, 16)
    with pytest.raises(AssertionError):
        expert_placement(coact, load, n_devices=3)


def test_traffic_cost_prefers_colocated_cliques():
    routing = _clique_routing(E=8, K=4, T=200)
    coact, load = coactivation_stats(routing, 8)
    good = np.asarray([0, 0, 0, 0, 1, 1, 1, 1])  # cliques together
    bad = np.asarray([0, 1, 0, 1, 0, 1, 0, 1])   # cliques split
    assert traffic_cost(good, coact, load) < traffic_cost(bad, coact, load)
