"""Tests for the problem frontend (repro.problems, DESIGN.md §9).

Three layers per family:

* the QUBO→Ising identity — domain objective and Ising energy tied exactly
  over *all* assignments of brute-force-small instances;
* decode/verify semantics — totality, determinism, and the feasibility
  verifier rejecting crafted infeasible solutions;
* the round trip — encode → anneal → decode lands on a verified-feasible
  solution, through the single-problem driver and through the
  :class:`~repro.serve.AnnealService` on all three backends.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import SSAHyperParams, anneal, ising_energy
from repro.problems import (
    FAMILIES,
    make_demo,
    mis_problem,
    partition_problem,
    qubo_problem,
    ring_coloring,
)

SMOKE_BASE = SSAHyperParams(n_trials=8, m_shot=3)


def _all_energies(model, n):
    """Energies of all 2^n assignments (bit k of the row index = spin k)."""
    bits = np.arange(2**n, dtype=np.uint32)
    m = 2 * ((bits[:, None] >> np.arange(n)) & 1).astype(np.int32) - 1
    h, nbr_idx, nbr_w = model.device_arrays()
    return np.asarray(ising_energy(jnp.asarray(m), h, nbr_idx, nbr_w)), m


# ---------------------------------------------------------------------------
# Energy ↔ domain-objective identities (exact, all assignments)
# ---------------------------------------------------------------------------
def test_qubo_energy_identity():
    rng = np.random.default_rng(0)
    enc = qubo_problem(rng.integers(-4, 5, size=(8, 8)))
    H, ms = _all_energies(enc.model, 8)
    for e, m in zip(H, ms):
        x = enc.decode(m)
        assert 4 * enc.objective(x) == int(e) + enc.offset


def test_mis_energy_identity_and_optimum():
    # 5-cycle: max independent set has size 2
    edges = np.array([(v, (v + 1) % 5) for v in range(5)])
    enc = mis_problem(5, edges, penalty=2)
    H, ms = _all_energies(enc.model, 5)
    for e, m in zip(H, ms):
        sel = (np.asarray(m) > 0).astype(np.int64)  # raw (un-repaired) bits
        conflicts = int((sel[edges[:, 0]] & sel[edges[:, 1]]).sum())
        qubo_obj = enc.penalty * conflicts - int(sel.sum())
        assert 4 * qubo_obj == int(e) + enc.offset
    # the Ising ground state decodes to a maximum independent set
    best = enc.decode(ms[int(H.argmin())])
    assert enc.verify(best) and enc.objective(best) == 2


def test_coloring_energy_identity_and_ground_state_is_proper():
    enc = ring_coloring(4, 2)  # even cycle is 2-colorable: 8 spins
    H, ms = _all_energies(enc.model, 8)
    edges = enc.edges
    A = 3  # max_degree + 1 = 2 + 1
    for e, m in zip(H, ms):
        x = (np.asarray(m).reshape(4, 2) > 0).astype(np.int64)
        violations = int(((x.sum(axis=1) - 1) ** 2).sum())
        colors_same = sum(
            int((x[u] * x[v]).sum()) for u, v in edges
        )  # Σ_c x_uc·x_vc per edge
        assert 4 * (A * violations + colors_same) == int(e) + enc.offset
    best = enc.decode(ms[int(H.argmin())])
    assert enc.verify(best) and enc.objective(best) == 0


def test_partition_energy_identity():
    enc = partition_problem([3, 1, 4, 1, 5, 9, 2, 6])
    H, ms = _all_energies(enc.model, 8)
    for e, m in zip(H, ms):
        s = enc.decode(m)
        assert enc.objective(s) ** 2 == int(e) + enc.offset


# ---------------------------------------------------------------------------
# Decode / verify semantics
# ---------------------------------------------------------------------------
def test_mis_decode_repairs_to_independence():
    edges = np.array([(v, (v + 1) % 6) for v in range(6)])
    enc = mis_problem(6, edges)
    all_in = np.ones(6, dtype=np.int8)  # every vertex selected: maximally bad
    sel = enc.decode(all_in)
    assert enc.verify(sel)
    assert not enc.verify(np.ones(6, dtype=bool))  # raw mask is infeasible
    # repair is deterministic
    assert np.array_equal(sel, enc.decode(all_in))


def test_coloring_decode_is_total_and_repairs():
    enc = ring_coloring(6, 3)
    monochrome = -np.ones(18, dtype=np.int8)  # nothing selected → all color 0
    colors = enc.decode(monochrome)
    assert colors.shape == (6,)
    assert enc.verify(colors)  # greedy repair 3-colors a 6-cycle
    assert np.array_equal(colors, enc.decode(monochrome))  # deterministic
    bad = np.zeros(6, dtype=np.int64)
    assert not enc.verify(bad)  # all-same coloring of a cycle is improper
    assert enc.objective(bad) == 6


def test_best_feasible_picks_best_and_flags_infeasible():
    enc = partition_problem([2, 2, 4])
    perfect = np.array([1, 1, -1], dtype=np.int8)  # residual 0
    worst = np.array([1, 1, 1], dtype=np.int8)     # residual 8
    sol, obj, feas = enc.best_feasible(np.stack([worst, perfect]))
    assert feas and obj == 0 and np.array_equal(sol, [1, 1, -1])


def test_qubo_verify_shape_guard():
    enc = qubo_problem(np.eye(3, dtype=int))
    assert enc.verify(np.array([1, 0, 1]))
    assert not enc.verify(np.array([1, 0]))


# ---------------------------------------------------------------------------
# Round trips: encode → anneal → decode → verified feasible
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kind", sorted(FAMILIES))
def test_family_round_trips_through_anneal(kind):
    enc = make_demo(kind, seed=0)
    r = anneal(enc, "auto", seed=0, track_energy=False, noise="xorshift",
               auto_base=SMOKE_BASE)
    sol, obj, feas = enc.best_feasible(r.best_m)
    assert feas, f"{kind}: no feasible decoded solution"
    assert obj is not None


@settings(max_examples=4)
@given(seed=st.integers(min_value=1, max_value=10_000),
       kind=st.sampled_from(sorted(FAMILIES)))
def test_round_trip_property(seed, kind):
    """Any seeded instance of any family round-trips to a feasible solution."""
    enc = make_demo(kind, seed=seed)
    r = anneal(enc, "auto", seed=seed, track_energy=False, noise="xorshift",
               auto_base=SSAHyperParams(n_trials=8, m_shot=2))
    _, obj, feas = enc.best_feasible(r.best_m)
    assert feas and obj is not None


@pytest.mark.parametrize("backend", ("sparse", "dense", "pallas"))
def test_families_through_service_all_backends(backend):
    """Acceptance: every family solves through AnnealService per backend,
    decoding to a verified-feasible solution (hp='auto')."""
    from repro.serve import AnnealRequest, AnnealService

    encs = [make_demo(kind, seed=0) for kind in sorted(FAMILIES)]
    svc = AnnealService(backend=backend, noise="xorshift")
    base = SSAHyperParams(n_trials=4, m_shot=2)
    reqs = [AnnealRequest(problem=e, hp="auto", seed=0, auto_base=base)
            for e in encs]
    for enc, resp in zip(encs, svc.solve(reqs)):
        assert resp.feasible, f"{enc.kind} infeasible on {backend}"
        assert resp.objective is not None
        assert resp.autotune is not None  # the resolution is observable
        assert resp.request.hp.n_rnd == resp.autotune.n_rnd
