"""Spin-sharded execution (DESIGN.md §11): partition='spin'.

The contract under test: sharding the spin axis over a mesh changes the
*layout*, never the *numbers*.  A spin-sharded run — engine driver or
service, f32-tiled or XNOR-popcount fields, dense or packed state layout,
interrupted and resumed or not — is bit-identical to the single-device run
on live lanes.

CI tier-1 pins one host device (XLA_FLAGS in ci.yml), so the in-process
tests here exercise the full sharded code path on a 1-device mesh (the
shard_map program, the make_array_from_callback seeding, the psum'd energy
— all live, just P=1).  True multi-device behaviour (P=8 forced host
devices: cross-shard collectives, per-device residency drop, sharded
checkpoint/resume) runs once in a consolidated subprocess whose XLA_FLAGS
are set before its jax initializes.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax

from repro.core import SSAHyperParams, anneal, gset
from repro.core.engine import (
    MAX_UNSHARDED_SPINS,
    bucket_n,
    make_batched_backend,
    padded_noise_init,
    padded_noise_init_slice,
    resolve_partition,
    schedule_plateaus,
)
from repro.serve import AdmissionError, AnnealRequest, AnnealService
from repro.serve.resilience import filter_backend_opts, group_fingerprint
from repro.sharding import mesh_fingerprint, spin_mesh

HP = SSAHyperParams(n_trials=3, m_shot=2, tau=3, i0_min=1, i0_max=4)


def _twin():
    return gset.toroidal_grid(64, seed=17)


# ---------------------------------------------------------------------------
# Partition resolution + mesh plumbing
# ---------------------------------------------------------------------------
def test_resolve_partition_rules():
    mesh1 = spin_mesh(1)
    assert resolve_partition("problem", 1 << 20, mesh1) == "problem"
    assert resolve_partition("spin", 64, mesh1) == "spin"  # explicit wins
    # 'auto' needs a real multi-device axis — a 1-way mesh stays 'problem'
    assert resolve_partition("auto", 1 << 20, mesh1) == "problem"
    assert resolve_partition("auto", 1 << 20, None) == "problem"
    with pytest.raises(ValueError):
        resolve_partition("bogus", 64, mesh1)


def test_spin_mesh_and_fingerprint():
    mesh = spin_mesh(1)
    assert mesh.axis_names == ("model",)
    fp = mesh_fingerprint(mesh)
    assert fp and mesh_fingerprint(None) == ()
    assert fp == mesh_fingerprint(spin_mesh(1))
    with pytest.raises(ValueError):
        spin_mesh(len(jax.devices()) + 1)


def test_spinshard_requires_xorshift():
    with pytest.raises(ValueError, match="xorshift"):
        make_batched_backend("dense", n_bucket=64, n_trials=2,
                             noise="threefry", partition="spin",
                             mesh=spin_mesh(1))


# ---------------------------------------------------------------------------
# Shard-local lane seeding: any column block == the same block of the
# global init (the property that makes sharded noise bit-identical)
# ---------------------------------------------------------------------------
def test_padded_noise_init_slice_matches_full():
    full = padded_noise_init("xorshift", seed=9, n_trials=3, n_live=50,
                             n_bucket=64)
    for lo, hi in ((0, 16), (16, 48), (48, 64), (0, 64)):
        sl = padded_noise_init_slice(9, 3, 50, 64, lo, hi)
        np.testing.assert_array_equal(np.asarray(full)[..., lo:hi], sl)


# ---------------------------------------------------------------------------
# Double-buffered J-slab streaming: same numbers, prefetch pipelining only
# ---------------------------------------------------------------------------
def test_double_buffer_tiled_fields_bit_identical():
    from repro.core.ising import local_fields_tiled

    model = _twin().to_ising()
    rng = np.random.default_rng(0)
    m = rng.choice(np.array([-1, 1], np.int8), size=(3, model.n))
    ref = local_fields_tiled(m, model.h, model.nbr_idx, model.nbr_w,
                             tile_n=16)
    db = local_fields_tiled(m, model.h, model.nbr_idx, model.nbr_w,
                            tile_n=16, double_buffer=True)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(db))


def test_double_buffer_dense_backend_bit_identical():
    p = _twin()
    ref = anneal(p, HP, seed=2, backend="dense", noise="xorshift",
                 backend_opts={"j_mode": "tiled", "tile_n": 16})
    db = anneal(p, HP, seed=2, backend="dense", noise="xorshift",
                backend_opts={"j_mode": "tiled", "tile_n": 16,
                              "double_buffer": True})
    np.testing.assert_array_equal(ref.best_energy, db.best_energy)
    np.testing.assert_array_equal(ref.best_m, db.best_m)


# ---------------------------------------------------------------------------
# Sharded == single-device on a 1-device mesh (full sharded code path):
# every field arithmetic x both storage layouts, driver and service
# ---------------------------------------------------------------------------
CASES = [("sparse", {}), ("dense", {}), ("dense", {"field_mode": "popcount"})]


@pytest.mark.parametrize("base,opts", CASES)
@pytest.mark.parametrize("layout", ["dense", "packed"])
def test_sharded_matches_plain_1dev(base, opts, layout):
    model = _twin().to_ising()
    nb = bucket_n(model.n, 64)
    plats = schedule_plateaus(HP.schedule("hassa"), "i0max")
    ref_opts = dict(opts)
    if base == "dense":
        ref_opts.setdefault("j_mode", "tiled")

    def run(bk):
        problem = bk.stack([model])
        st = bk.init_state(problem, bk.init_noise([11], [model.n]))
        st = jax.jit(lambda s: bk.run_shots(problem, s, plats, HP.m_shot))(st)
        bh, bm = bk.finalize(st)
        return np.asarray(bh), np.asarray(bm)[..., : model.n]

    ref = make_batched_backend(base, n_bucket=nb, n_trials=HP.n_trials,
                               noise="xorshift", storage_layout=layout,
                               **ref_opts)
    sh = make_batched_backend(base, n_bucket=nb, n_trials=HP.n_trials,
                              noise="xorshift", storage_layout=layout,
                              partition="spin", mesh=spin_mesh(1), **opts)
    assert sh.name == "spinshard"
    bh0, bm0 = run(ref)
    bh1, bm1 = run(sh)
    np.testing.assert_array_equal(bh0, bh1)
    np.testing.assert_array_equal(bm0, bm1)


def test_sharded_anneal_driver_matches_plain():
    p = _twin()
    ref = anneal(p, HP, seed=5, backend="sparse", noise="xorshift",
                 track_energy=True)
    sh = anneal(p, HP, seed=5, backend="sparse", noise="xorshift",
                track_energy=True,
                backend_opts={"partition": "spin", "mesh": spin_mesh(1)})
    np.testing.assert_array_equal(ref.best_energy, sh.best_energy)
    np.testing.assert_array_equal(ref.best_m, sh.best_m)
    np.testing.assert_array_equal(ref.energy_mean, sh.energy_mean)
    np.testing.assert_array_equal(ref.energy_min, sh.energy_min)


def test_sharded_service_matches_plain():
    reqs = lambda: [AnnealRequest(problem=_twin(), hp=HP, seed=4)]  # noqa: E731
    base = AnnealService(backend="dense", min_bucket=64).solve(reqs())[0]
    sh = AnnealService(backend="dense", min_bucket=64, partition="spin",
                       mesh=spin_mesh(1)).solve(reqs())[0]
    np.testing.assert_array_equal(base.result.best_energy,
                                  sh.result.best_energy)
    np.testing.assert_array_equal(base.result.best_m, sh.result.best_m)
    np.testing.assert_array_equal(base.chunk_best_cut, sh.chunk_best_cut)


# ---------------------------------------------------------------------------
# Admission: giant instances only pass when they route to the spin path
# ---------------------------------------------------------------------------
def _big_request():
    big = gset.toroidal_grid(MAX_UNSHARDED_SPINS + 1232, seed=5, name="big")
    return AnnealRequest(problem=big, hp=HP, seed=1)


def test_giant_instance_rejected_unsharded():
    with pytest.raises(AdmissionError, match="partition='spin'"):
        AnnealService(backend="sparse").solve([_big_request()])


def test_giant_instance_admitted_with_spin_partition():
    # Admission only — the full solve is the scale benchmark's job.
    from repro.core.engine import normalize_problem

    svc = AnnealService(backend="sparse", partition="spin", mesh=spin_mesh(1))
    req = _big_request()
    _maxcut, model = normalize_problem(req.problem)
    svc._admit(0, req, model)  # must not raise


def test_sa_requests_never_route_to_spin():
    svc = AnnealService(partition="spin", mesh=spin_mesh(1))
    assert svc.partition_for("sa", 1 << 16) == "problem"
    assert svc.partition_for("ptssa", 1 << 16) == "problem"
    assert svc.partition_for("ssa", 1 << 16) == "spin"


# ---------------------------------------------------------------------------
# Resilience plumbing: opt filtering + checkpoint fingerprints
# ---------------------------------------------------------------------------
def test_filter_backend_opts_spin_keyset():
    opts = {"block_r": 8, "field_mode": "auto", "bogus": 1}
    assert filter_backend_opts("sparse", opts) == {}
    spin = filter_backend_opts("sparse", opts, partition="spin")
    assert spin == {"block_r": 8, "field_mode": "auto"}


def test_group_fingerprint_keys_on_partition_and_mesh():
    model = _twin().to_ising()
    items = [(0, AnnealRequest(problem=_twin(), hp=HP, seed=1), None, model)]
    base = group_fingerprint("ssa", 64, "dense", "dense", "xorshift", 1, items)
    spin = group_fingerprint("ssa", 64, "dense", "dense", "xorshift", 1,
                             items, partition="spin",
                             mesh_fp=mesh_fingerprint(spin_mesh(1)))
    assert base != spin


# ---------------------------------------------------------------------------
# Per-device accounting primitives (host + 1-device cases)
# ---------------------------------------------------------------------------
def test_per_device_bytes_accounting():
    from repro.core import memory

    tree = {"host": np.zeros(16, np.int32), "dev": jax.numpy.zeros(8, np.int8)}
    per = memory.per_device_bytes(tree)
    assert per["host"] == 64
    assert sum(v for k, v in per.items() if k != "host") == 8
    assert memory.max_device_bytes(tree) == 64


# ---------------------------------------------------------------------------
# True multi-device behaviour: one consolidated subprocess with 8 forced
# host devices (XLA_FLAGS must precede jax init, hence the subprocess).
# Covers: cross-shard bit-identity (both field modes x both layouts, P in
# {2, 8}), sharded checkpoint kill/resume through the service, and the
# ~linear per-device residency drop.
# ---------------------------------------------------------------------------
MULTIDEV_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, tempfile
    import numpy as np, jax
    assert len(jax.devices()) == 8
    from repro.core import SSAHyperParams, gset
    from repro.core.engine import (bucket_n, make_batched_backend,
                                   schedule_plateaus)
    from repro.core import memory
    from repro.ft.faults import FaultInjector, InjectedKill
    from repro.serve import AnnealRequest, AnnealService, ResiliencePolicy
    from repro.sharding import spin_mesh

    hp = SSAHyperParams(n_trials=3, m_shot=2, tau=3, i0_min=1, i0_max=4)
    model = gset.toroidal_grid(64, seed=17).to_ising()
    nb = bucket_n(model.n, 64)
    plats = schedule_plateaus(hp.schedule("hassa"), "i0max")

    def run(bk):
        problem = bk.stack([model])
        st = bk.init_state(problem, bk.init_noise([11], [model.n]))
        st = jax.jit(lambda s: bk.run_shots(problem, s, plats, hp.m_shot))(st)
        bh, bm = bk.finalize(st)
        return np.asarray(bh), np.asarray(bm)[..., :model.n]

    # 1. cross-shard bit-identity
    for base, opts in (("sparse", {}), ("dense", {}),
                       ("dense", {"field_mode": "popcount"})):
        for layout in ("dense", "packed"):
            ref_opts = dict(opts)
            if base == "dense":
                ref_opts.setdefault("j_mode", "tiled")
            ref = make_batched_backend(base, n_bucket=nb, n_trials=3,
                                       noise="xorshift",
                                       storage_layout=layout, **ref_opts)
            bh0, bm0 = run(ref)
            for p in (2, 8):
                sh = make_batched_backend(base, n_bucket=nb, n_trials=3,
                                          noise="xorshift",
                                          storage_layout=layout,
                                          partition="spin",
                                          mesh=spin_mesh(p), **opts)
                bh1, bm1 = run(sh)
                assert (bh0 == bh1).all() and (bm0 == bm1).all(), (
                    base, opts, layout, p)
    print("bit-identity ok")

    # 2. sharded checkpoint kill/resume through the service
    mesh = spin_mesh(4)
    hp_r = SSAHyperParams(n_trials=3, m_shot=6, tau=4, i0_min=1, i0_max=8)
    reqs = lambda: [AnnealRequest(problem=gset.toroidal_grid(64, seed=17),
                                  hp=hp_r, seed=4)]
    base = AnnealService(backend="dense", min_bucket=64, partition="spin",
                         mesh=mesh).solve(reqs())[0]
    tmp = tempfile.mkdtemp()
    pol = ResiliencePolicy(checkpoint_dir=tmp)
    inj = FaultInjector(); inj.arm("kill", chunk=2)
    try:
        AnnealService(backend="dense", min_bucket=64, partition="spin",
                      mesh=mesh, resilience=pol, faults=inj).solve(reqs())
        raise SystemExit("kill did not fire")
    except InjectedKill:
        pass
    resumed = AnnealService(backend="dense", min_bucket=64, partition="spin",
                            mesh=mesh, resilience=pol).solve(reqs())[0]
    assert any(e.kind == "resume" for e in resumed.events)
    np.testing.assert_array_equal(base.result.best_energy,
                                  resumed.result.best_energy)
    np.testing.assert_array_equal(base.result.best_m, resumed.result.best_m)
    print("kill/resume ok")

    # 3. per-device residency drops ~linearly with the model-axis size
    busiest = {}
    for p in (1, 8):
        bk = make_batched_backend("dense", n_bucket=4096, n_trials=2,
                                  noise="xorshift", partition="spin",
                                  mesh=spin_mesh(p))
        prob = bk.stack([model])
        st = bk.init_state(prob, bk.init_noise([0], [model.n]))
        busiest[p] = memory.max_device_bytes((prob, st))
    ratio = busiest[1] / busiest[8]
    assert ratio >= 4.0, busiest  # ~8x minus replicated best_H/h residue
    print(json.dumps({"residency": busiest, "ratio": ratio}))
    print("MULTIDEV_OK")
    """
)


def test_multidevice_subprocess():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(root, "src"), env.get("PYTHONPATH")) if p
    )
    proc = subprocess.run([sys.executable, "-c", MULTIDEV_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0 and "MULTIDEV_OK" in proc.stdout, (
        proc.stdout[-3000:] + "\n" + proc.stderr[-3000:]
    )
