"""Serving-engine tests: batched generation, greedy determinism, KV reuse."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model_defs
from repro.models.params import init_params
from repro.serve.lm import ServeConfig, generate


def _params_and_batch(arch, B=2, S=8):
    cfg = get_config(arch, reduced=True)
    params = init_params(model_defs(cfg), jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)}
    if cfg.frontend == "vision":
        batch["patches"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.n_patches, cfg.d_model)) * 0.02
    if cfg.encoder_layers:
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.n_frames, cfg.d_model)) * 0.1
    return cfg, params, batch


@pytest.mark.parametrize("arch", ["granite-3-8b", "olmoe-1b-7b", "rwkv6-3b",
                                  "jamba-1.5-large-398b"])
def test_generate_shapes_and_determinism(arch):
    cfg, params, batch = _params_and_batch(arch)
    sc = ServeConfig(max_seq=24)
    out1 = generate(params, batch, cfg, sc, n_new_tokens=6, seed=0)
    out2 = generate(params, batch, cfg, sc, n_new_tokens=6, seed=0)
    assert out1.shape == (2, 6)
    np.testing.assert_array_equal(out1, out2)  # greedy is deterministic
    assert out1.min() >= 0 and out1.max() < cfg.vocab


def test_generate_matches_teacher_forced_forward():
    """Greedy decode must agree with argmax of the full forward pass when the
    generated tokens are fed back in (consistency of the KV-cache path)."""
    from repro.models import forward
    from repro.models.transformer import lm_head_logits

    cfg, params, batch = _params_and_batch("granite-3-8b", B=1, S=8)
    sc = ServeConfig(max_seq=16)
    out = generate(params, batch, cfg, sc, n_new_tokens=4, seed=0)
    # teacher-forced: run forward on prompt+generated, check each generated
    # token is the argmax at its position
    toks = np.concatenate([np.asarray(batch["tokens"]), out], axis=1)
    h, _ = forward(params, {"tokens": jnp.asarray(toks)}, cfg)
    logits = lm_head_logits(params, h, cfg)
    for i in range(4):
        pos = 8 + i - 1  # logits at pos predict token pos+1
        pred = int(jnp.argmax(logits[0, pos]))
        assert pred == int(toks[0, 8 + i]), f"mismatch at generated index {i}"


def test_temperature_sampling_varies():
    cfg, params, batch = _params_and_batch("granite-3-8b")
    sc = ServeConfig(max_seq=24, temperature=1.0)
    outs = {tuple(generate(params, batch, cfg, sc, n_new_tokens=6, seed=s)[0])
            for s in range(4)}
    assert len(outs) > 1  # different seeds → different samples
