"""Streaming-service tests (DESIGN.md §12).

The load-bearing property is *live-lane bit-identity*: a request served
through the continuous-batching slot table — seated mid-stream into a slot
another request just vacated — returns exactly the `best_cut`/spins the
one-shot `AnnealService.solve` path returns for the same request.  That is
what makes the scheduler a pure throughput optimisation rather than a new
numerical code path.  Asserted across all three backends and across slot
backfill boundaries, plus the scheduling semantics around it: priority
ordering, deadline shed/freeze, queue backpressure, target-cut retirement,
and per-slot checkpoint resume (interchangeable with one-shot solo-group
checkpoints).
"""
import os
import time

import numpy as np
import pytest

from repro.core import SSAHyperParams, gset
from repro.ft.faults import FaultInjector, InjectedKill
from repro.serve import (
    AnnealRequest,
    AnnealService,
    QueueFullError,
    ResiliencePolicy,
    StreamingAnnealService,
    StreamPolicy,
)

HP = SSAHyperParams(n_trials=3, m_shot=4, tau=4, i0_min=1, i0_max=8)
BACKENDS = ("sparse", "dense", "pallas")


def _requests(k=6, **kw):
    return [AnnealRequest(problem=gset.toroidal_grid(36, seed=s, name=f"t{s}"),
                          hp=HP, seed=s, **kw)
            for s in range(k)]


def _assert_lane_identical(resp, base):
    np.testing.assert_array_equal(resp.result.best_cut, base.result.best_cut)
    np.testing.assert_array_equal(resp.result.best_m, base.result.best_m)
    np.testing.assert_array_equal(resp.chunk_best_cut, base.chunk_best_cut)


@pytest.fixture(scope="module")
def baselines():
    """One-shot solo solves: the bit-identity reference, per backend."""
    out = {}
    for b in BACKENDS:
        svc = AnnealService(backend=b, min_bucket=16)
        out[b] = [svc.solve([r])[0] for r in _requests()]
    return out


# ---------------------------------------------------------------------------
# Live-lane bit-identity across slot backfill (the acceptance property)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", BACKENDS)
def test_stream_bit_identical_across_backfill(backend, baselines):
    """6 requests through a 2-slot table = 3 backfill generations; every
    lane must match its one-shot solo solve bit for bit."""
    ss = StreamingAnnealService(backend=backend, min_bucket=16,
                                policy=StreamPolicy(slots_per_table=2))
    tickets = [ss.submit(r) for r in _requests()]
    ss.run_until_idle()
    for t, base in zip(tickets, baselines[backend]):
        resp = t.result(timeout=0)
        assert resp.status == "ok"
        _assert_lane_identical(resp, base)
    st = ss.stream_stats()
    assert st["stream_backfills"] == 6          # every seat is a splice
    assert st["stream_tables_created"] == 1     # one bucket, one table
    assert st["stream_completed"] == 6
    assert 0.0 < st["occupancy"] <= 1.0


def test_target_cut_retires_early_and_backfills(baselines):
    """A target-stopped lane frees its slot at the chunk boundary and the
    next queued request takes it; both report exactly what the one-shot
    path reports (chunks_run, trace prefix, result)."""
    hp_long = SSAHyperParams(n_trials=3, m_shot=10, tau=4, i0_min=1, i0_max=8)
    reqs = [AnnealRequest(problem=gset.toroidal_grid(36, seed=0), hp=hp_long,
                          seed=0, target_cut=1)] + _requests(2)
    solo = AnnealService(backend="sparse", min_bucket=16)
    base = [solo.solve([r])[0] for r in reqs]
    assert base[0].chunks_run < base[0].chunks_total  # target actually fired

    ss = StreamingAnnealService(backend="sparse", min_bucket=16,
                                policy=StreamPolicy(slots_per_table=1))
    tickets = [ss.submit(r) for r in reqs]
    ss.run_until_idle()
    for t, b in zip(tickets, base):
        resp = t.result(timeout=0)
        assert resp.chunks_run == b.chunks_run
        _assert_lane_identical(resp, b)
    st = ss.stream_stats()
    assert st["stream_retired_target"] == 1
    assert st["stream_retired_budget"] == 2


# ---------------------------------------------------------------------------
# Scheduling semantics
# ---------------------------------------------------------------------------
def test_interactive_preempts_batch_in_queue():
    """With a 1-wide table, the interactive request submitted *last* is
    seated *first* — priority class dominates FIFO order."""
    reqs = _requests(4)
    ss = StreamingAnnealService(backend="sparse", min_bucket=16,
                                policy=StreamPolicy(slots_per_table=1,
                                                    max_tables=1))
    batch = [ss.submit(r) for r in reqs[:3]]
    inter = ss.submit(reqs[3], priority="interactive")
    ss.run_until_idle()
    assert inter.t_seated < min(t.t_seated for t in batch)
    assert all(t.result(timeout=0).status == "ok" for t in batch + [inter])
    assert inter.result(timeout=0).queued_s is not None


def test_expired_queued_request_is_shed():
    """A queued request whose deadline already passed is dropped before any
    device work: status='shed', no result, counted."""
    ss = StreamingAnnealService(backend="sparse", min_bucket=16,
                                policy=StreamPolicy(slots_per_table=1))
    ok_t = ss.submit(_requests(1)[0])
    doomed = ss.submit(AnnealRequest(problem=gset.toroidal_grid(36, seed=9),
                                     hp=HP, seed=9, deadline_s=1e-6))
    ss.run_until_idle()
    resp = doomed.result(timeout=0)
    assert resp.status == "shed" and resp.result is None
    assert resp.chunks_run == 0
    assert any(e.kind == "shed" for e in resp.events)
    assert ok_t.result(timeout=0).status == "ok"
    assert ss.stream_stats()["stream_shed"] == 1


def test_seated_deadline_freezes_at_chunk_boundary():
    """With shedding disabled, an already-expired deadline still seats and
    is frozen at its first chunk boundary: best-so-far, status='deadline'."""
    ss = StreamingAnnealService(
        backend="sparse", min_bucket=16,
        policy=StreamPolicy(slots_per_table=1, shed_expired=False))
    t = ss.submit(AnnealRequest(problem=gset.toroidal_grid(36, seed=0),
                                hp=HP, seed=0, deadline_s=1e-6))
    ss.run_until_idle()
    resp = t.result(timeout=0)
    assert resp.status == "deadline"
    assert resp.chunks_run == 1 < resp.chunks_total
    assert resp.result is not None  # best-so-far, not dropped


def test_queue_full_backpressure():
    pol = StreamPolicy(slots_per_table=1, max_queue=1)
    ss = StreamingAnnealService(backend="sparse", min_bucket=16, policy=pol)
    ss.submit(_requests(1)[0])
    with pytest.raises(QueueFullError):
        ss.submit(AnnealRequest(problem=gset.toroidal_grid(36, seed=5),
                                hp=HP, seed=5))
    assert ss.stream_stats()["stream_rejected_queue_full"] == 1


# ---------------------------------------------------------------------------
# Per-slot checkpoints
# ---------------------------------------------------------------------------
def test_stream_kill_resumes_per_slot_bit_identical(baselines, tmp_path):
    """Kill the stream after its second quantum; a fresh stream resumes
    each surviving lane from its own checkpoint, bit-identical."""
    pol = ResiliencePolicy(checkpoint_dir=str(tmp_path))
    inj = FaultInjector()
    inj.arm("kill", chunk=1)
    svc = AnnealService(backend="sparse", min_bucket=16, resilience=pol,
                        faults=inj)
    ss = StreamingAnnealService(service=svc,
                                policy=StreamPolicy(slots_per_table=2))
    reqs = _requests(2)
    for r in reqs:
        ss.submit(r)
    with pytest.raises(InjectedKill):
        ss.run_until_idle()
    assert os.listdir(tmp_path)  # per-slot checkpoints survived the "crash"

    svc2 = AnnealService(backend="sparse", min_bucket=16, resilience=pol)
    ss2 = StreamingAnnealService(service=svc2,
                                 policy=StreamPolicy(slots_per_table=2))
    tickets = [ss2.submit(r) for r in reqs]
    ss2.run_until_idle()
    for t, base in zip(tickets, baselines["sparse"][:2]):
        resp = t.result(timeout=0)
        _assert_lane_identical(resp, base)
        resumes = [e for e in resp.events if e.kind == "resume"]
        assert resumes and resumes[0].detail["chunk"] == 2  # killed after 2
    assert ss2.stream_stats()["stream_resumes"] == 2
    assert os.listdir(tmp_path) == []  # purged on success


def test_oneshot_checkpoint_resumes_into_stream(baselines, tmp_path):
    """Slot checkpoints share the solo-group fingerprint: a checkpoint
    written by an interrupted one-shot solve() resumes inside a stream
    slot, and the answer still matches the uninterrupted one-shot run."""
    pol = ResiliencePolicy(checkpoint_dir=str(tmp_path))
    inj = FaultInjector()
    inj.arm("kill", chunk=1)
    req = _requests(1)[0]
    svc = AnnealService(backend="sparse", min_bucket=16, resilience=pol,
                        faults=inj)
    with pytest.raises(InjectedKill):
        svc.solve([req])

    ss = StreamingAnnealService(
        service=AnnealService(backend="sparse", min_bucket=16, resilience=pol),
        policy=StreamPolicy(slots_per_table=2))
    t = ss.submit(req)
    ss.run_until_idle()
    resp = t.result(timeout=0)
    assert any(e.kind == "resume" for e in resp.events)
    _assert_lane_identical(resp, baselines["sparse"][0])


# ---------------------------------------------------------------------------
# Observability
# ---------------------------------------------------------------------------
def test_stream_stats_counters_consistent():
    ss = StreamingAnnealService(backend="sparse", min_bucket=16,
                                policy=StreamPolicy(slots_per_table=2))
    tickets = [ss.submit(r) for r in _requests(3)]
    ss.run_until_idle()
    st = ss.stream_stats()
    assert st["queued"] == 0 and st["live_slots"] == 0
    assert st["stream_submitted"] == 3 == st["stream_completed"]
    assert st["stream_seated"] == 3
    assert st["stream_live_lane_chunks"] <= st["stream_slot_chunks"]
    # 3 lanes x 4 chunks of real work, whatever padding ran beside them
    assert st["stream_live_lane_chunks"] == 3 * 4
    for t in tickets:
        r = t.result(timeout=0)
        assert r.lane_wall_s is not None and r.queued_s is not None


def test_background_thread_drives_stream():
    ss = StreamingAnnealService(backend="sparse", min_bucket=16,
                                policy=StreamPolicy(slots_per_table=2))
    ss.start(poll_s=0.001)
    try:
        tickets = [ss.submit(r) for r in _requests(2)]
        for t in tickets:
            resp = t.result(timeout=120.0)
            assert resp.status == "ok"
    finally:
        ss.stop()
    assert not ss._thread or not ss._thread.is_alive()


def test_non_ssa_requests_rejected():
    from repro.core import SAHyperParams
    from repro.serve import AdmissionError
    ss = StreamingAnnealService(backend="sparse", min_bucket=16)
    with pytest.raises(AdmissionError):
        ss.submit(AnnealRequest(
            problem=gset.toroidal_grid(36, seed=0),
            hp=SAHyperParams(n_trials=2, n_cycles=8), seed=0))
