"""Fault-injection tests for the annealing service's resilience layer
(DESIGN.md §10).

Every fault class in the failure model is injected at its hook point and
the recovery contract is asserted:

* kill between chunks → resume from chunk checkpoints, bit-identical
  (all three backends, noise='xorshift');
* compile failure → backend fallback chain, status/events record the
  downgrade, results bit-identical;
* dense-J OOM → tiled-J downgrade on the same backend;
* NaN burst → offender quarantined (solo retry, re-autotuned I0max),
  batchmates bit-exact; exhausted retries → status='failed', no raise;
* deadline expiry → best-so-far with status='deadline', no raise;
* admission validation → typed AdmissionError before any device work;
* seeded chaos schedules → the service survives arbitrary fault mixes.
"""
import dataclasses
import os

import numpy as np
import pytest

from repro.core import IsingModel, SSAHyperParams, gset
from repro.core.rng import xorshift_lanes_ok
from repro.ft.faults import (
    FaultInjector,
    InjectedCompileFailure,
    InjectedKill,
    chaos_schedule,
)
from repro.serve import (
    AdmissionError,
    AnnealRequest,
    AnnealService,
    ResiliencePolicy,
)

HP = SSAHyperParams(n_trials=3, m_shot=6, tau=4, i0_min=1, i0_max=8)
BACKENDS = ("sparse", "dense", "pallas")


def _problems():
    return (gset.toroidal_grid(36, seed=0, name="t36"),
            gset.king_graph(36, seed=3, name="k36"))


def _requests(**kw):
    return [AnnealRequest(problem=p, hp=HP, seed=i + 1, **kw)
            for i, p in enumerate(_problems())]


def _assert_bit_identical(a, b):
    np.testing.assert_array_equal(a.result.best_energy, b.result.best_energy)
    np.testing.assert_array_equal(a.result.best_m, b.result.best_m)
    np.testing.assert_array_equal(a.chunk_best_cut, b.chunk_best_cut)


@pytest.fixture(scope="module")
def baselines():
    return {b: AnnealService(backend=b, min_bucket=16).solve(_requests())
            for b in BACKENDS}


# ---------------------------------------------------------------------------
# Checkpoint / resume
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", BACKENDS)
def test_kill_mid_solve_resumes_bit_identical(backend, baselines, tmp_path):
    pol = ResiliencePolicy(checkpoint_dir=str(tmp_path))
    inj = FaultInjector()
    inj.arm("kill", chunk=2)
    svc = AnnealService(backend=backend, min_bucket=16, resilience=pol,
                        faults=inj)
    with pytest.raises(InjectedKill):  # the kill escapes like a real death
        svc.solve(_requests())
    assert os.listdir(tmp_path)  # checkpoints survived the "crash"

    # "new process": fresh service, same policy, no faults
    svc2 = AnnealService(backend=backend, min_bucket=16, resilience=pol)
    resumed = svc2.solve(_requests())
    for base, r in zip(baselines[backend], resumed):
        _assert_bit_identical(base, r)
    resumes = [e for e in resumed[0].events if e.kind == "resume"]
    assert resumes and resumes[0].detail["chunk"] == 3  # killed after chunk 2
    assert os.listdir(tmp_path) == []  # purged after success


def test_corrupted_checkpoint_rejected_and_rerun(baselines, tmp_path):
    """Zeroed xorshift lanes in a restored checkpoint (the absorbing state)
    are detected; the service starts the group fresh instead of resuming."""
    pol = ResiliencePolicy(checkpoint_dir=str(tmp_path))
    inj = FaultInjector()
    inj.arm("kill", chunk=2)
    with pytest.raises(InjectedKill):
        AnnealService(backend="sparse", min_bucket=16, resilience=pol,
                      faults=inj).solve(_requests())
    # corrupt every checkpoint: zero the carried noise lanes
    for root, _dirs, files in os.walk(tmp_path):
        for fn in files:
            if not fn.endswith(".npz"):
                continue
            path = os.path.join(root, fn)
            with np.load(path) as z:
                flat = {k: z[k] for k in z.files}
            for k in flat:
                if "noise_state" in k:
                    flat[k] = np.zeros_like(flat[k])
                    assert not xorshift_lanes_ok(flat[k], axis=1)
            with open(path, "wb") as f:
                np.savez(f, **flat)
    resumed = AnnealService(backend="sparse", min_bucket=16,
                            resilience=pol).solve(_requests())
    kinds = [e.kind for e in resumed[0].events]
    assert "checkpoint_rejected" in kinds and "resume" not in kinds
    for base, r in zip(baselines["sparse"], resumed):
        _assert_bit_identical(base, r)  # fresh run, still correct


# ---------------------------------------------------------------------------
# Backend fallback chain
# ---------------------------------------------------------------------------
def test_pallas_compile_failure_falls_back(baselines):
    inj = FaultInjector()
    inj.arm("compile", backend="pallas")
    svc = AnnealService(backend="pallas", min_bucket=16, faults=inj)
    resp = svc.solve(_requests())
    for base, r in zip(baselines["pallas"], resp):
        assert r.status == "fallback"
        _assert_bit_identical(base, r)
    hops = [(e.detail["from"], e.detail["to"])
            for e in resp[0].events if e.kind == "fallback"]
    assert hops == [("pallas", "dense")]
    assert svc.stats["fallback_compile"] == 1


def test_full_chain_pallas_dense_sparse(baselines):
    inj = FaultInjector()
    inj.arm("compile", backend="pallas")
    inj.arm("compile", backend="dense")
    svc = AnnealService(backend="pallas", min_bucket=16, faults=inj)
    resp = svc.solve(_requests())
    hops = [(e.detail["from"], e.detail["to"])
            for e in resp[0].events if e.kind == "fallback"]
    assert hops == [("pallas", "dense"), ("dense", "sparse")]
    for base, r in zip(baselines["pallas"], resp):
        assert r.status == "fallback"
        _assert_bit_identical(base, r)


def test_terminal_backend_failure_propagates():
    """A fault on the chain's terminal backend has nowhere to go: surface."""
    inj = FaultInjector()
    inj.arm("compile", backend="sparse")
    svc = AnnealService(backend="sparse", min_bucket=16, faults=inj)
    with pytest.raises(InjectedCompileFailure):
        svc.solve(_requests())


def test_fallback_disabled_propagates():
    inj = FaultInjector()
    inj.arm("compile", backend="pallas")
    svc = AnnealService(backend="pallas", min_bucket=16, faults=inj,
                        resilience=ResiliencePolicy(fallback=False))
    with pytest.raises(InjectedCompileFailure):
        svc.solve(_requests())


def test_dense_oom_downgrades_to_tiled(baselines):
    inj = FaultInjector()
    inj.arm("oom", backend="dense", j_mode="dense")
    svc = AnnealService(backend="dense", min_bucket=16, faults=inj)
    resp = svc.solve(_requests())
    ev = [e for e in resp[0].events if e.kind == "fallback"]
    assert ev[0].detail["fault"] == "oom"
    assert ev[0].detail["to"] == "dense"
    assert ev[0].detail["to_opts"]["j_mode"] == "tiled"
    for base, r in zip(baselines["dense"], resp):
        assert r.status == "fallback"
        _assert_bit_identical(base, r)  # tiled J is bit-identical


def test_fallback_drops_incompatible_backend_opts(baselines):
    """pallas-only opts (block_r) must not leak into the dense fallback."""
    inj = FaultInjector()
    inj.arm("compile", backend="pallas")
    svc = AnnealService(backend="pallas", min_bucket=16, faults=inj,
                        backend_opts={"block_r": 8})
    resp = svc.solve(_requests())
    assert all(r.status == "fallback" for r in resp)
    ev = [e for e in resp[0].events if e.kind == "fallback"][0]
    assert "block_r" not in ev.detail["to_opts"]


# ---------------------------------------------------------------------------
# Watchdogs: NaN quarantine, deadline, admission
# ---------------------------------------------------------------------------
def test_nan_burst_quarantines_without_poisoning_batchmates(baselines):
    inj = FaultInjector()
    inj.arm("nan", chunk=1, slots=(1,))
    svc = AnnealService(backend="sparse", min_bucket=16, faults=inj)
    resp = svc.solve(_requests())
    assert resp[0].status == "ok"
    _assert_bit_identical(baselines["sparse"][0], resp[0])  # batchmate exact
    assert resp[1].status == "quarantined"
    assert resp[1].result is not None
    kinds = [e.kind for e in resp[1].events]
    assert kinds[:2] == ["quarantine", "retry"]
    retry = [e for e in resp[1].events if e.kind == "retry"][0]
    assert "i0_max" in retry.detail  # retried with a re-autotuned clamp
    assert svc.stats["nonfinite_detected"] == 1
    assert svc.stats["quarantine_recoveries"] == 1


def test_quarantine_retries_exhausted_returns_failed():
    """A request whose NaN never clears (armed for every chunk of every
    retry) comes back status='failed' — the solve never raises."""
    inj = FaultInjector()
    inj.arm("nan", count=100)  # every slot, every chunk, every retry
    pol = ResiliencePolicy(max_retries=2, backoff_base_s=0.0)
    svc = AnnealService(backend="sparse", min_bucket=16, faults=inj,
                        resilience=pol)
    resp = svc.solve([_requests()[0]])
    assert resp[0].status == "failed" and resp[0].result is None
    assert [e.kind for e in resp[0].events].count("retry") == 2
    assert svc.stats["quarantine_failures"] == 1


def test_deadline_returns_best_so_far(baselines):
    resp = AnnealService(backend="sparse", min_bucket=16).solve(
        _requests(deadline_s=1e-9))
    for r in resp:
        assert r.status == "deadline"
        assert r.result is not None
        assert r.chunks_run < r.chunks_total  # stopped at a chunk boundary
        assert any(e.kind == "deadline" for e in r.events)
    # best-so-far is a prefix of the uninterrupted run's streamed trace
    for base, r in zip(baselines["sparse"], resp):
        n = len(r.chunk_best_cut)
        np.testing.assert_array_equal(r.chunk_best_cut,
                                      base.chunk_best_cut[:n])


def test_deadline_only_affects_expired_requests(baselines):
    """One expired request must not stop its batchmate's continuation."""
    reqs = _requests()
    reqs[1] = dataclasses.replace(reqs[1], deadline_s=1e-9)
    resp = AnnealService(backend="sparse", min_bucket=16).solve(reqs)
    assert resp[0].status == "ok"
    assert resp[0].chunks_run == resp[0].chunks_total
    _assert_bit_identical(baselines["sparse"][0], resp[0])
    assert resp[1].status == "deadline"
    assert len(resp[1].chunk_best_cut) < resp[1].chunks_total


def test_admission_rejects_bad_requests():
    svc = AnnealService(backend="sparse", min_bucket=16)
    good = _requests()[0]
    # non-finite couplings (constructed directly — from_edges rejects them)
    nan_model = IsingModel(
        n=3, h=np.zeros(3, np.int32),
        nbr_idx=np.zeros((3, 1), np.int32),
        nbr_w=np.full((3, 1), np.nan),
    )
    with pytest.raises(AdmissionError, match="finite"):
        svc.solve([good, AnnealRequest(problem=nan_model, hp=HP)])
    # absurd shape
    empty = IsingModel(n=0, h=np.zeros(0, np.int32),
                       nbr_idx=np.zeros((0, 1), np.int32),
                       nbr_w=np.zeros((0, 1), np.int32))
    with pytest.raises(AdmissionError, match="n"):
        svc.solve([AnnealRequest(problem=empty, hp=HP)])
    # bad deadline
    with pytest.raises(AdmissionError, match="deadline"):
        svc.solve([dataclasses.replace(good, deadline_s=-1.0)])
    # nothing was solved, nothing compiled
    assert len(svc._programs) == 0


# ---------------------------------------------------------------------------
# Seeded chaos schedules
# ---------------------------------------------------------------------------
def test_chaos_schedule_deterministic():
    a = chaos_schedule(17)
    b = chaos_schedule(17)
    assert [(s.point, s.match, s.slots) for s in a.specs] == \
           [(s.point, s.match, s.slots) for s in b.specs]
    assert [(s.point, s.match) for s in chaos_schedule(18).specs] != \
           [(s.point, s.match) for s in a.specs]


@pytest.mark.parametrize("seed", range(4))
def test_chaos_schedule_survival(seed, baselines, tmp_path):
    """Arbitrary seeded fault mixes: the service must serve every request
    (modulo one resume after a kill), and every non-quarantined result must
    be bit-identical to the fault-free run."""
    pol = ResiliencePolicy(checkpoint_dir=str(tmp_path))
    svc = AnnealService(backend="pallas", min_bucket=16, resilience=pol,
                        faults=chaos_schedule(seed))
    try:
        resp = svc.solve(_requests())
    except InjectedKill:
        resp = AnnealService(backend="pallas", min_bucket=16,
                             resilience=pol).solve(_requests())
    assert len(resp) == 2
    for base, r in zip(baselines["pallas"], resp):
        if r.status == "quarantined":
            assert r.result is not None  # re-autotuned: different valid run
        else:
            _assert_bit_identical(base, r)
