"""Dry-run integration test (deliverable e): lower+compile a real cell on
the production meshes inside a subprocess (the 512 virtual devices must not
leak into this test process, whose other tests assume 1 CPU device).

whisper-tiny is the fastest-compiling assigned arch; one train cell on the
single-pod mesh and one decode cell on the 2-pod mesh cover both step kinds
and both meshes in ~1 min.
"""
import json
import os
import subprocess
import sys


REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys, json
sys.path.insert(0, {src!r})
from repro.launch.dryrun import run_cell
rec = run_cell({arch!r}, {shape!r}, {mesh!r}, verbose=False, analysis={analysis})
print("RESULT:" + json.dumps(rec))
"""


def _run(arch, shape, mesh, analysis=False):
    code = SCRIPT.format(src=os.path.join(REPO, "src"), arch=arch, shape=shape,
                         mesh=mesh, analysis=analysis)
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, timeout=900,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    line = [x for x in out.stdout.splitlines() if x.startswith("RESULT:")][-1]
    return json.loads(line[len("RESULT:"):])


def test_train_cell_single_pod_with_analysis():
    rec = _run("whisper-tiny", "train_4k", "single", analysis=True)
    assert rec["status"] == "ok"
    assert rec["n_chips"] == 256
    assert rec["peak_bytes_per_device"] > 0
    # analysis terms present and positive
    assert rec["t_compute_s"] > 0 and rec["t_memory_s"] > 0
    assert rec["dominant"] in ("compute", "memory", "collective")
    # MODEL_FLOPS sanity: 6·N·D within 100× of HLO global flops
    assert 0.01 < rec["useful_flops_ratio"] < 100


def test_decode_cell_multi_pod():
    rec = _run("whisper-tiny", "decode_32k", "pod", analysis=False)
    assert rec["status"] == "ok"
    assert rec["n_chips"] == 512  # proves the pod axis shards
    assert rec["fits_hbm_16g"] is True


def test_long_500k_skip_is_recorded():
    rec = _run("granite-3-8b", "long_500k", "single")
    assert rec["status"] == "skipped"
    assert "sub-quadratic" in rec["reason"]
