"""Tests for optimizer, data pipeline, loss, and training behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.data.pipeline import DataConfig, batch_spec, host_slice, synthetic_batch
from repro.models import ModelConfig
from repro.optim.adamw import (AdamWConfig, adamw_init, adamw_update,
                               clip_by_global_norm, cosine_schedule, global_norm)
from repro.train.step import (TrainConfig, chunked_ce_loss, init_train_state,
                              make_train_step)

CFG = ModelConfig(name="tiny", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                  d_head=16, d_ff=128, vocab=97, remat="none")


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------
def test_adamw_moves_toward_minimum():
    params = {"w": jnp.asarray([5.0, -3.0])}
    cfg = AdamWConfig(lr_peak=0.5, warmup_steps=0, total_steps=100,
                      weight_decay=0.0, clip_norm=100.0, zero1=False)
    opt = adamw_init(params, cfg)
    for _ in range(100):
        grads = {"w": 2 * params["w"]}  # d/dw |w|^2
        params, opt, _ = adamw_update(params, grads, opt, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr_peak=1.0, warmup_steps=10, total_steps=100)
    lrs = [float(cosine_schedule(cfg, s)) for s in range(101)]
    assert lrs[0] == 0.0
    assert abs(lrs[10] - 1.0) < 1e-6
    assert lrs[100] < 1e-6
    assert all(a >= b - 1e-9 for a, b in zip(lrs[10:], lrs[11:]))  # decreasing


@given(st.floats(0.1, 10.0), st.integers(0, 5))
@settings(max_examples=20, deadline=None)
def test_clip_by_global_norm(max_norm, seed):
    rng = np.random.default_rng(seed)
    tree = {"a": jnp.asarray(rng.normal(size=(7,)) * 10),
            "b": jnp.asarray(rng.normal(size=(3, 3)) * 10)}
    clipped, norm = clip_by_global_norm(tree, max_norm)
    new_norm = float(global_norm(clipped))
    assert new_norm <= max_norm * 1.001
    if float(norm) <= max_norm:  # no-op when under the limit
        np.testing.assert_allclose(np.asarray(clipped["a"]),
                                   np.asarray(tree["a"]), rtol=1e-6)


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------
def test_data_deterministic_and_resumable():
    dc = DataConfig(vocab=97, seq_len=16, global_batch=4, seed=3)
    a = synthetic_batch(dc, 7)
    b = synthetic_batch(dc, 7)
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    c = synthetic_batch(dc, 8)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))


def test_data_labels_are_shifted_tokens():
    dc = DataConfig(vocab=97, seq_len=16, global_batch=2, seed=0)
    b = synthetic_batch(dc, 0)
    # labels[t] is the next token after tokens[t] (common stream)
    assert b["tokens"].shape == b["labels"].shape == (2, 16)
    np.testing.assert_array_equal(
        np.asarray(b["tokens"][:, 1:]), np.asarray(b["labels"][:, :-1])
    )


def test_host_slice_partitions():
    dc = DataConfig(vocab=97, seq_len=8, global_batch=8, seed=0)
    b = synthetic_batch(dc, 0)
    parts = [host_slice(b, i, 4) for i in range(4)]
    glued = np.concatenate([np.asarray(p["tokens"]) for p in parts])
    np.testing.assert_array_equal(glued, np.asarray(b["tokens"]))


def test_batch_spec_matches_batch():
    dc = DataConfig(vocab=97, seq_len=8, global_batch=2, seed=0,
                    n_patches=3, d_model=16)
    spec = batch_spec(dc)
    b = synthetic_batch(dc, 0)
    for k in spec:
        assert tuple(spec[k].shape) == tuple(b[k].shape), k


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------
def test_chunked_loss_equals_unchunked():
    key = jax.random.PRNGKey(0)
    from repro.models.params import init_params
    from repro.models.transformer import lm_head_logits, model_defs

    params = init_params(model_defs(CFG), key)
    hidden = jax.random.normal(key, (2, 16, 64), jnp.float32) * 0.1
    labels = jax.random.randint(key, (2, 16), 0, 97)
    tot, cnt = chunked_ce_loss(params, hidden, labels, CFG, chunk=4)
    logits = lm_head_logits(params, hidden, CFG)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ref = jnp.sum(lse - ll)
    np.testing.assert_allclose(float(tot), float(ref), rtol=1e-5)
    assert float(cnt) == 32


def test_masked_labels_excluded():
    from repro.models.params import init_params
    from repro.models.transformer import model_defs

    params = init_params(model_defs(CFG), jax.random.PRNGKey(0))
    hidden = jnp.zeros((1, 8, 64))
    labels = jnp.asarray([[-1, -1, 3, 4, 5, -1, 7, 8]])
    _, cnt = chunked_ce_loss(params, hidden, labels, CFG, chunk=8)
    assert float(cnt) == 5


# ---------------------------------------------------------------------------
# Training behaviour
# ---------------------------------------------------------------------------
def test_loss_decreases_over_training():
    tc = TrainConfig(opt=AdamWConfig(lr_peak=1e-2, warmup_steps=5,
                                     total_steps=50), loss_chunk=16)
    dc = DataConfig(vocab=97, seq_len=32, global_batch=8, seed=0)
    state = init_train_state(CFG, tc, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(CFG, tc))
    losses = []
    for s in range(30):
        state, m = step(state, synthetic_batch(dc, s))
        losses.append(float(m["ce_loss"]))
    assert losses[-1] < losses[0] - 0.4
    assert int(state.opt.step) == 30


def test_microbatch_matches_single_shot():
    tc1 = TrainConfig(opt=AdamWConfig(), microbatches=1, loss_chunk=16)
    tc4 = TrainConfig(opt=AdamWConfig(), microbatches=4, loss_chunk=16)
    dc = DataConfig(vocab=97, seq_len=16, global_batch=8, seed=0)
    b = synthetic_batch(dc, 0)
    s1 = init_train_state(CFG, tc1, jax.random.PRNGKey(0))
    s4 = init_train_state(CFG, tc4, jax.random.PRNGKey(0))
    _, m1 = jax.jit(make_train_step(CFG, tc1))(s1, b)
    _, m4 = jax.jit(make_train_step(CFG, tc4))(s4, b)
    # same loss (up to bf16 batch-slicing noise) and same token count
    assert abs(float(m1["ce_loss"]) - float(m4["ce_loss"])) < 0.02
    assert float(m1["tokens"]) == float(m4["tokens"])


def test_grad_accum_dtype_bf16_compresses():
    """bf16 accumulation is the gradient-compression knob: the accumulated
    grads (and hence the DP all-reduce payload) are half-width."""
    tc = TrainConfig(opt=AdamWConfig(), microbatches=2,
                     grad_accum_dtype=jnp.bfloat16, loss_chunk=16)
    dc = DataConfig(vocab=97, seq_len=16, global_batch=4, seed=0)
    state = init_train_state(CFG, tc, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(CFG, tc))
    state2, m = step(state, synthetic_batch(dc, 0))
    assert np.isfinite(float(m["ce_loss"]))
    assert int(state2.opt.step) == 1
