"""Tests for the Sec. VI-B problem embeddings (TSP / partitioning / GI)."""
import itertools

import jax.numpy as jnp
import numpy as np

from repro.core import anneal, ising_energy
from repro.core.problems import (decode_gi, decode_partition, decode_tsp,
                                 gi_problem, partition_problem, qubo_to_ising,
                                 suggest_hyperparams, tsp_problem,
                                 tsp_tour_length)


def _energy(model, m):
    h, nbr_idx, nbr_w = model.device_arrays()
    return int(ising_energy(jnp.asarray(m, jnp.int32), h, nbr_idx, nbr_w))


def _all_energies(model, n):
    """Energies of all 2^n spin assignments, batched (bit k of the row index
    is spin k) — replaces per-assignment Python loops in the brute forces."""
    bits = np.arange(2**n, dtype=np.uint32)
    m = 2 * ((bits[:, None] >> np.arange(n)) & 1).astype(np.int32) - 1
    h, nbr_idx, nbr_w = model.device_arrays()
    return np.asarray(ising_energy(jnp.asarray(m), h, nbr_idx, nbr_w)), m


def test_qubo_to_ising_exact_over_all_assignments():
    rng = np.random.default_rng(0)
    Q = rng.integers(-3, 4, size=(6, 6))
    model, offset = qubo_to_ising(Q)
    for bits in range(2**6):
        x = np.array([(bits >> k) & 1 for k in range(6)], dtype=np.int64)
        m = 2 * x - 1
        assert 4 * int(x @ Q @ x) == _energy(model, m) + offset


def test_partition_ground_state_is_balanced():
    values = np.array([4, 5, 6, 7, 8])  # perfect split: {4,5,6} vs {7,8}
    model, v = partition_problem(values)
    best = None
    for bits in range(2**5):
        m = 2 * np.array([(bits >> k) & 1 for k in range(5)]) - 1
        e = _energy(model, m)
        if best is None or e < best[0]:
            best = (e, m)
    assert decode_partition(values, best[1]) == 0


def test_partition_solved_by_hassa():
    """integer weights need scale-matched hyperparameters (Sec. VI-B)."""
    rng = np.random.default_rng(1)
    values = rng.integers(1, 10, size=12)
    model, _ = partition_problem(values)
    hp = suggest_hyperparams(model, n_trials=8, m_shot=10)
    r = anneal(model, hp, seed=0, track_energy=False)
    resid = min(
        decode_partition(values, r.best_m[t]) for t in range(hp.n_trials)
    )
    best = min(
        decode_partition(values, 2 * np.array(x) - 1)
        for x in itertools.product([0, 1], repeat=12)
    )
    assert resid == best  # exact with tuned hyperparameters


def test_tsp_ground_state_is_shortest_tour():
    # 4 cities on a line: optimal tour length = 2·span
    pts = np.array([0, 1, 2, 5])
    dist = np.abs(pts[:, None] - pts[None, :])
    p = tsp_problem(dist)
    H, ms = _all_energies(p.model, 16)
    best = (int(H.min()), ms[int(H.argmin())])
    tour = decode_tsp(p, best[1])
    assert tour is not None, "ground state violates constraints"
    assert tsp_tour_length(p, tour) == 10  # 0→1→2→5→0


def test_tsp_solved_by_hassa():
    pts = np.array([0, 2, 3, 7])
    dist = np.abs(pts[:, None] - pts[None, :])
    p = tsp_problem(dist, penalty=14)
    hp = suggest_hyperparams(p.model, n_trials=16, m_shot=20)
    r = anneal(p.model, hp, seed=3, track_energy=False)
    tours = [decode_tsp(p, r.best_m[t]) for t in range(hp.n_trials)]
    lengths = [tsp_tour_length(p, t) for t in tours if t is not None]
    assert lengths, "no feasible tour found"
    assert min(lengths) == 14  # optimal: 2·(7-0)


def test_gi_isomorphic_graphs_have_zero_ground_state():
    # G1: path 0-1-2-3; G2: same path relabeled by perm (2,0,3,1)
    A1 = np.zeros((4, 4), dtype=int)
    for a, b in [(0, 1), (1, 2), (2, 3)]:
        A1[a, b] = A1[b, a] = 1
    perm = np.array([2, 0, 3, 1])
    A2 = A1[np.ix_(np.argsort(perm), np.argsort(perm))]
    model, offset = gi_problem(A1, A2)
    # the true permutation encoding must be a global ground state
    x = np.zeros((4, 4), dtype=int)
    for u in range(4):
        x[u, perm[u]] = 1
    m = 2 * x.reshape(-1) - 1
    e_perm = _energy(model, m)
    # brute force over all 2^16 assignments (batched)
    H, _ = _all_energies(model, 16)
    assert e_perm == int(H.min())
    mapping = decode_gi(4, m)
    assert mapping is not None and np.array_equal(mapping, perm)


def test_gi_solved_by_hassa():
    A1 = np.zeros((4, 4), dtype=int)
    for a, b in [(0, 1), (1, 2), (2, 3), (3, 0)]:  # 4-cycle
        A1[a, b] = A1[b, a] = 1
    perm = np.array([1, 3, 0, 2])
    inv = np.argsort(perm)
    A2 = A1[np.ix_(inv, inv)]
    model, offset = gi_problem(A1, A2)
    hp = suggest_hyperparams(model, n_trials=16, m_shot=15)
    r = anneal(model, hp, seed=1, track_energy=False)
    found = False
    for t in range(hp.n_trials):
        mapping = decode_gi(4, r.best_m[t])
        if mapping is None:
            continue
        # verify it's a graph isomorphism
        P = np.zeros((4, 4), dtype=int)
        P[np.arange(4), mapping] = 1
        if np.array_equal(P.T @ A1 @ P, A2):
            found = True
            break
    assert found, "HA-SSA found no valid isomorphism"
