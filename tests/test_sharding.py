"""Sharding-rule tests (run with a small forced host-device mesh via
subprocess-free jax tricks: these only exercise spec construction, which
needs a Mesh object but not 256 real devices — we build small meshes from
the single CPU device? No: jax.make_mesh requires enough devices, so we
construct Mesh objects over a reshaped device list of size 1 where possible
and otherwise test the pure functions with a fake mesh shape via
jax.sharding.AbstractMesh).
"""
import jax
from jax.sharding import PartitionSpec as P

from repro.optim.adamw import zero1_spec
from repro.sharding import (DEFAULT_RULES, abstract_mesh, logical_to_spec,
                            mesh_axis_size)

MESH = abstract_mesh((16, 16), ("data", "model"))
POD = abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def test_basic_rules():
    spec = logical_to_spec(MESH, (256, 4096, 4096), ("batch", "seq", "d_model"))
    assert spec == P("data")  # batch→data (pod absent), seq/d_model replicated


def test_pod_batch_sharding():
    spec = logical_to_spec(POD, (256, 4096), ("batch", "seq"))
    assert spec == P(("pod", "data"))


def test_tp_dims():
    spec = logical_to_spec(MESH, (4096, 32, 128), ("d_model", "heads", "d_head"))
    assert spec == P(None, "model")


def test_indivisible_dim_replicates():
    # whisper: 6 heads on a 16-way model axis → replicated, not an error
    spec = logical_to_spec(MESH, (384, 6, 64), ("d_model", "heads", "d_head"))
    assert spec == P()
    # granite vocab 49155 % 16 != 0 → replicated
    spec = logical_to_spec(MESH, (49155, 4096), ("vocab", "d_model"))
    assert spec == P()


def test_axis_used_once():
    # kv_seq and kv_heads both map to model; first dim wins, second replicates
    spec = logical_to_spec(
        MESH, (128, 32768, 8, 128), ("batch", "kv_seq", "kv_heads", "d_head")
    )
    assert spec == P("data", "model")


def test_rules_override():
    rules = DEFAULT_RULES.replace(kv_seq=None, kv_heads="model")
    spec = logical_to_spec(
        MESH, (128, 32768, 16, 128), ("batch", "kv_seq", "kv_heads", "d_head"),
        rules,
    )
    assert spec == P("data", None, "model")


def test_mesh_axis_size():
    assert mesh_axis_size(MESH, "model") == 16
    assert mesh_axis_size(POD, ("pod", "data")) == 32
    assert mesh_axis_size(MESH, "pod") == 1
    assert mesh_axis_size(MESH, None) == 1


def test_zero1_extends_free_dim():
    # param replicated over data → opt state picks up data on first divisible dim
    spec = zero1_spec(P(None, "model"), (4096, 12800), MESH)
    assert spec == P("data", "model")
    # param already data-sharded → unchanged
    spec = zero1_spec(P(("pod", "data")), (256, 64), POD)
    assert spec == P(("pod", "data"))
    # no divisible dim → unchanged
    spec = zero1_spec(P(), (7, 9), MESH)
    assert spec == P()


def test_param_defs_spec_tree():
    from repro.configs import get_config
    from repro.models import model_defs
    from repro.models.params import param_pspecs

    cfg = get_config("granite-3-8b")
    defs = model_defs(cfg)
    specs = param_pspecs(defs, MESH)
    flat = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P)
    )
    assert len(flat) > 10
    # embed table: vocab 49155 indivisible → d_model gets nothing either (both axes checked)
    assert isinstance(specs["embed"]["tok"], P)
    # decoder attn wq: (G, M, H, D) — heads on model
    wq_spec = specs["decoder"]["l0"]["mixer"]["wq"]
    assert "model" in jax.tree_util.tree_leaves(wq_spec) or wq_spec == P(
        None, None, "model"
    )
