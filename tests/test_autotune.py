"""Tests for local-energy-distribution hyperparameter determination
(repro.core.autotune): determinism, documented bounds, the Table-II
reproduction on G11, and the matches-or-beats acceptance gates.
"""

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import SSAHyperParams, anneal, gset
from repro.core.autotune import (
    I0_MAX_CEIL,
    I0_MAX_FLOOR,
    N_RND_MAX,
    TAU_FLOOR,
    autotune_hyperparams,
    resolve_hyperparams,
    sample_local_fields,
)
from repro.core.ising import local_fields_sparse
from repro.core.schedule import n_temp_steps
from repro.problems import FAMILIES, make_demo

SMOKE_BASE = SSAHyperParams(n_trials=8, m_shot=4)


def test_sampled_fields_match_engine_contraction():
    model = make_demo("qubo", seed=3).model
    z = sample_local_fields(model, n_samples=8, seed=5)
    rng = np.random.default_rng(5)
    m = rng.integers(0, 2, size=(8, model.n)) * 2 - 1
    h, nbr_idx, nbr_w = model.device_arrays()
    ref = np.asarray(local_fields_sparse(m.astype(np.int32), h, nbr_idx, nbr_w))
    assert np.array_equal(z, ref)


def test_deterministic_for_fixed_seed():
    model = gset.load("G11").to_ising()
    a1, r1 = autotune_hyperparams(model, SMOKE_BASE, seed=7)
    a2, r2 = autotune_hyperparams(model, SMOKE_BASE, seed=7)
    assert a1 == a2 and r1 == r2
    # the report records exactly what the hyperparams carry
    assert (r1.n_rnd, r1.i0_min, r1.i0_max, r1.tau) == (
        a1.n_rnd, a1.i0_min, a1.i0_max, a1.tau
    )


def test_g11_reproduces_table_ii():
    """On ±1 4-regular MAX-CUT the determination lands exactly on the
    paper's hand settings: σ = 2 → n_rnd = 2; max|z| = 4 → I0max = 32;
    plateau count unchanged → τ = 100."""
    model = gset.load("G11").to_ising()
    hp, rep = autotune_hyperparams(model)
    assert hp.n_rnd == 2
    assert hp.i0_min == 1 and hp.i0_max == 32
    assert hp.tau == 100
    assert rep.z_max == 4


@settings(max_examples=12)
@given(kind=st.sampled_from(sorted(FAMILIES)),
       seed=st.integers(min_value=0, max_value=10_000))
def test_outputs_within_documented_bounds(kind, seed):
    model = make_demo(kind, seed=seed).model
    hp, rep = autotune_hyperparams(model, SMOKE_BASE, seed=seed)
    assert 1 <= hp.n_rnd <= N_RND_MAX
    assert I0_MAX_FLOOR <= hp.i0_max <= I0_MAX_CEIL
    assert hp.i0_max & (hp.i0_max - 1) == 0  # power of two (Eq. 4 shifts)
    assert hp.i0_min == 1
    steps_base = n_temp_steps(SMOKE_BASE.i0_min, SMOKE_BASE.i0_max)
    assert TAU_FLOOR <= hp.tau <= SMOKE_BASE.tau * steps_base
    # budget knobs pass through untouched
    assert hp.n_trials == SMOKE_BASE.n_trials
    assert hp.m_shot == SMOKE_BASE.m_shot
    assert hp.beta_shift == SMOKE_BASE.beta_shift


def test_schedule_scaling_preserves_cycle_budget():
    """More plateaus ⇒ proportionally shorter ones: one iteration stays
    within ~1 plateau of the base cycle budget."""
    model = make_demo("partition", seed=0).model
    hp, _ = autotune_hyperparams(model, SMOKE_BASE)
    assert hp.steps > SMOKE_BASE.steps  # the clamp range genuinely grew
    assert hp.cycles_per_iter <= SMOKE_BASE.cycles_per_iter + hp.tau
    assert hp.cycles_per_iter >= SMOKE_BASE.cycles_per_iter // 2


def test_resolve_passthrough_and_unknown_mode():
    model = gset.load("G11").to_ising()
    hp, rep = resolve_hyperparams(SMOKE_BASE, model)
    assert hp is SMOKE_BASE and rep is None
    auto_hp, auto_rep = resolve_hyperparams("auto", model)
    assert auto_rep is not None and auto_hp.n_rnd == auto_rep.n_rnd
    try:
        resolve_hyperparams("magic", model)
    except ValueError as e:
        assert "magic" in str(e)
    else:
        raise AssertionError("unknown mode must raise")


# ---------------------------------------------------------------------------
# Acceptance: auto matches or beats the hand-set defaults
# ---------------------------------------------------------------------------
def test_auto_matches_or_beats_hand_on_g11():
    p = gset.load("G11")
    base = SSAHyperParams(n_trials=4, m_shot=2)
    hand = anneal(p, base, seed=0, track_energy=False, noise="xorshift")
    auto = anneal(p, "auto", seed=0, track_energy=False, noise="xorshift",
                  auto_base=base)
    assert auto.overall_best_cut >= hand.overall_best_cut


def test_auto_matches_or_beats_hand_on_qubo_smoke():
    enc = make_demo("qubo", seed=0)
    base = SSAHyperParams(n_trials=4, m_shot=2)
    hand = anneal(enc, base, seed=0, track_energy=False, noise="xorshift")
    auto = anneal(enc, "auto", seed=0, track_energy=False, noise="xorshift",
                  auto_base=base)
    _, hand_obj, hand_feas = enc.best_feasible(hand.best_m)
    _, auto_obj, auto_feas = enc.best_feasible(auto.best_m)
    assert auto_feas
    hand_score = -(2**62) if not hand_feas else -hand_obj
    assert -auto_obj >= hand_score  # minimization: auto ≤ hand


def test_service_autotune_keeps_identical_problems_batched():
    """The autotune draw is independent of the anneal seed, so replicated
    'auto' requests of one problem still collapse onto one group/program."""
    from repro.serve import AnnealRequest, AnnealService

    enc = make_demo("mis", seed=0)
    base = SSAHyperParams(n_trials=4, m_shot=2)
    svc = AnnealService(backend="sparse", noise="xorshift")
    reqs = [AnnealRequest(problem=enc, hp="auto", seed=s, auto_base=base)
            for s in range(3)]
    resps = svc.solve(reqs)
    info = svc.cache_info()
    assert info["groups"] == 1 and info["programs"] == 1
    assert all(r.autotune == resps[0].autotune for r in resps)
