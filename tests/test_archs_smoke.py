"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step on CPU, shape + finiteness assertions; plus a decode
round-trip.  The FULL configs are exercised only via the dry-run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.data.pipeline import DataConfig, synthetic_batch
from repro.models import cache_defs, decode_step, forward, model_defs, prefill
from repro.models.params import init_params, param_shapes
from repro.optim.adamw import AdamWConfig
from repro.train.step import TrainConfig, init_train_state, make_train_step

B, S = 2, 32


def _batch(cfg, key=0):
    dc = DataConfig(
        vocab=cfg.vocab, seq_len=S, global_batch=B, seed=key,
        n_patches=cfg.n_patches if cfg.frontend == "vision" else 0,
        d_model=cfg.d_model,
        n_frames=cfg.n_frames if cfg.encoder_layers else 0,
    )
    return synthetic_batch(dc, 0)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_and_train_step(arch):
    cfg = get_config(arch, reduced=True)
    batch = _batch(cfg)
    tc = TrainConfig(
        opt=AdamWConfig(lr_peak=1e-3, warmup_steps=2, total_steps=10),
        loss_chunk=16,
    )
    state = init_train_state(cfg, tc, jax.random.PRNGKey(0))
    # forward: shape + finite
    h, aux = forward(state.params, batch, cfg)
    assert h.shape == (B, S, cfg.d_model)
    assert bool(jnp.isfinite(h.astype(jnp.float32)).all()), "NaN/inf in forward"
    # one train step: loss finite, params move, step increments
    step_fn = jax.jit(make_train_step(cfg, tc))
    new_state, metrics = step_fn(state, batch)
    assert np.isfinite(float(metrics["ce_loss"]))
    assert int(new_state.opt.step) == 1
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))),
        state.params, new_state.params,
    )
    assert max(jax.tree_util.tree_leaves(moved)) > 0, "params did not update"


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_prefill_decode_roundtrip(arch):
    cfg = get_config(arch, reduced=True)
    params = init_params(model_defs(cfg), jax.random.PRNGKey(0))
    batch = _batch(cfg)
    pre = {k: (v[:, : S - 1] if k in ("tokens", "labels") else v) for k, v in batch.items()}
    del pre["labels"]
    logits, caches = prefill(params, pre, cfg, max_seq=S)
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    lg, caches = decode_step(params, caches, batch["tokens"][:, S - 1], jnp.int32(S - 1), cfg)
    assert lg.shape == (B, cfg.vocab)
    assert bool(jnp.isfinite(lg).all())


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_cache_defs_match_prefill_structure(arch):
    """cache_defs (used to lower serve_step in the dry-run) must mirror the
    runtime cache structure exactly."""
    cfg = get_config(arch, reduced=True)
    params = init_params(model_defs(cfg), jax.random.PRNGKey(0))
    batch = _batch(cfg)
    pre = {k: v for k, v in batch.items() if k != "labels"}
    _, caches = prefill(params, pre, cfg, max_seq=S)
    spec = param_shapes(cache_defs(cfg, B, S))
    live = jax.tree_util.tree_structure(caches)
    want = jax.tree_util.tree_structure(spec)
    assert live == want, f"cache structure mismatch:\n{live}\nvs\n{want}"
    shapes_live = jax.tree_util.tree_map(lambda x: tuple(x.shape), caches)
    shapes_want = jax.tree_util.tree_map(lambda x: tuple(x.shape), spec)
    assert shapes_live == shapes_want


def test_full_configs_match_assignment():
    """Spot-check the full configs against the assignment table."""
    c = get_config("jamba-1.5-large-398b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) == (
        72, 8192, 64, 8, 24576, 65536)
    assert c.n_experts == 16 and c.top_k == 2
    # 1:7 attention:mamba interleave
    mixers = [m for m, _ in c.block]
    assert mixers.count("attn") == 1 and mixers.count("mamba") == 7
    assert c.sub_quadratic

    c = get_config("granite-3-8b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) == (
        40, 4096, 32, 8, 12800, 49155)

    c = get_config("mistral-large-123b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) == (
        88, 12288, 96, 8, 28672, 32768)

    c = get_config("qwen3-1.7b")
    assert (c.n_layers, c.d_model, c.n_heads, c.vocab, c.qk_norm) == (
        28, 2048, 16, 151936, True)

    c = get_config("qwen3-32b")
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff, c.vocab) == (
        64, 5120, 64, 25600, 151936)

    c = get_config("olmoe-1b-7b")
    assert (c.n_layers, c.d_model, c.n_experts, c.top_k, c.vocab) == (
        16, 2048, 64, 8, 50304)

    c = get_config("moonshot-v1-16b-a3b")
    assert (c.n_layers, c.d_model, c.n_experts, c.top_k, c.vocab) == (
        48, 2048, 64, 6, 163840)

    c = get_config("rwkv6-3b")
    assert (c.n_layers, c.d_model, c.d_ff, c.vocab) == (32, 2560, 8960, 65536)
    assert c.sub_quadratic and not c.pure_attention

    c = get_config("whisper-tiny")
    assert (c.n_layers, c.encoder_layers, c.d_model, c.n_heads, c.d_ff, c.vocab) == (
        4, 4, 384, 6, 1536, 51865)

    c = get_config("phi-3-vision-4.2b")
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff, c.vocab, c.n_patches) == (
        32, 3072, 32, 8192, 32064, 576)


def test_long_500k_applicability():
    from repro.configs import SHAPES, applicable

    runnable = {
        a: applicable(get_config(a), SHAPES["long_500k"])[0] for a in ARCH_NAMES
    }
    assert runnable["jamba-1.5-large-398b"] is True
    assert runnable["rwkv6-3b"] is True
    for a in ("granite-3-8b", "mistral-large-123b", "qwen3-1.7b", "qwen3-32b",
              "olmoe-1b-7b", "moonshot-v1-16b-a3b", "whisper-tiny",
              "phi-3-vision-4.2b"):
        assert runnable[a] is False, a
