"""Packed memory subsystem (DESIGN.md §4): bit-identity of the packed
storage layout, the streamed-noise kernel, and the tiled-J path.

The refactor's gate: every memory-saving representation — uint32 spin
bitplanes between launches, in-kernel xorshift noise instead of the
(C, R, N) pregen buffer, (tile_n, N) J slabs instead of dense (N, N) — must
be bit-identical on live lanes to the dense reference, for all three
backends and both storage policies.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import SSAHyperParams, anneal, gset
from repro.core.engine import (
    PackedEngineState,
    make_backend,
    make_batched_backend,
    resolve_j_mode,
)
from repro.core.ising import (
    local_fields_dense,
    local_fields_sparse,
    local_fields_tiled,
)

HP = SSAHyperParams(n_trials=3, m_shot=2, tau=4, i0_min=1, i0_max=8)
BACKENDS = ["sparse", "dense", "pallas"]


def _problem():
    # 50 spins: exercises the non-multiple-of-32 bitplane tail in every layer
    return gset.toroidal_grid(50, seed=17)


# ---------------------------------------------------------------------------
# The acceptance property: packed ≡ dense, all backends × storage policies
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("storage", ["i0max", "all"])
def test_packed_bitwise_equal_to_dense(backend, storage):
    p = _problem()
    kw = dict(seed=3, record="best", noise="xorshift", storage=storage,
              track_energy=False)
    ref = anneal(p, HP, backend="sparse", **kw)
    out = anneal(p, HP, backend=backend, storage_layout="packed", **kw)
    np.testing.assert_array_equal(ref.best_energy, out.best_energy)
    np.testing.assert_array_equal(ref.best_cut, out.best_cut)
    np.testing.assert_array_equal(ref.best_m, out.best_m)


@given(st.integers(0, 10_000))
@settings(max_examples=3, deadline=None)
def test_packed_equivalence_property(seed):
    p = _problem()
    hp = SSAHyperParams(n_trials=2, m_shot=2, tau=3, i0_min=1, i0_max=4)
    runs = [
        anneal(p, hp, seed=seed, record="best", noise="xorshift",
               backend=b, storage_layout=layout, track_energy=False)
        for b in BACKENDS
        for layout in ("dense", "packed")
    ]
    for other in runs[1:]:
        np.testing.assert_array_equal(runs[0].best_energy, other.best_energy)
        np.testing.assert_array_equal(runs[0].best_m, other.best_m)


def test_packed_state_is_the_engine_carry():
    """storage_layout='packed' really stores bitplanes: the state between
    plateaus is a PackedEngineState with uint32 spin words."""
    model = _problem().to_ising()
    bk = make_backend("sparse", model, n_trials=3, noise="xorshift",
                      storage_layout="packed")
    st = bk.init_state(0)
    assert isinstance(st, PackedEngineState)
    assert st.m_packed.dtype == jnp.uint32
    assert st.m_packed.shape == (3, (model.n + 31) // 32)
    st2, _, _ = bk.run_plateau(st, 4, length=3, eligible=True)
    assert isinstance(st2, PackedEngineState)
    bh, bm = bk.finalize(st2)
    assert bm.shape == (3, model.n) and bm.dtype == jnp.int8


# ---------------------------------------------------------------------------
# The streamed-noise resident kernel: no (C, R, N) noise buffer anywhere
# ---------------------------------------------------------------------------
def _collect_avals(jaxpr, out):
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            out.append(v.aval)
        for v in eqn.params.values():
            sub = getattr(v, "jaxpr", None)
            if sub is not None:
                _collect_avals(sub, out)
            elif isinstance(v, (list, tuple)):
                for vv in v:
                    sub = getattr(vv, "jaxpr", None)
                    if sub is not None:
                        _collect_avals(sub, out)
    return out


def test_xorshift_pallas_plateau_has_no_noise_buffer():
    """The legacy datapath pregenerated (C, T, N) int8 noise per plateau;
    the streamed kernel must not materialize it at any nesting level."""
    model = _problem().to_ising()
    length = 7
    bk = make_backend("pallas", model, n_trials=3, noise="xorshift")
    state = bk.init_state(0)
    jaxpr = jax.make_jaxpr(
        lambda st: bk.run_plateau(st, 8, length=length, eligible=True)[0]
    )(state)
    avals = _collect_avals(jaxpr.jaxpr, [])
    noise_shape = (length, bk.n_trials, model.n)
    assert not any(
        getattr(a, "shape", None) == noise_shape and a.dtype == jnp.int8
        for a in avals
    ), "found a (C, T, N) int8 noise buffer in the streamed plateau program"


def test_threefry_pallas_still_pregenerates():
    """The reference path is unchanged: threefry noise cannot be generated
    in-kernel, so its plateau program still carries the (C, T, N) buffer."""
    model = _problem().to_ising()
    length = 7
    bk = make_backend("pallas", model, n_trials=3, noise="threefry")
    state = bk.init_state(0)
    jaxpr = jax.make_jaxpr(
        lambda st: bk.run_plateau(st, 8, length=length, eligible=True)[0]
    )(state)
    avals = _collect_avals(jaxpr.jaxpr, [])
    noise_shape = (length, bk.n_trials, model.n)
    assert any(
        getattr(a, "shape", None) == noise_shape and a.dtype == jnp.int8
        for a in avals
    )


def test_pregen_noise_mode_is_bit_identical_and_materializes_buffer():
    """noise_mode='pregen' (the measured dense baseline of
    benchmarks/timing.py --memory) really runs the legacy datapath: its
    plateau program carries the (C, T, N) buffer, and its results equal
    the streamed kernel's bit-for-bit."""
    p = _problem()
    model = p.to_ising()
    length = 7
    bk = make_backend("pallas", model, n_trials=3, noise="xorshift",
                      noise_mode="pregen")
    assert bk.noise_mode == "pregen"
    state = bk.init_state(0)
    jaxpr = jax.make_jaxpr(
        lambda st: bk.run_plateau(st, 8, length=length, eligible=True)[0]
    )(state)
    avals = _collect_avals(jaxpr.jaxpr, [])
    noise_shape = (length, bk.n_trials, model.n)
    assert any(
        getattr(a, "shape", None) == noise_shape and a.dtype == jnp.int8
        for a in avals
    )
    kw = dict(seed=3, record="best", noise="xorshift", track_energy=False)
    ref = anneal(p, HP, backend="pallas", **kw)
    out = anneal(p, HP, backend="pallas",
                 backend_opts={"noise_mode": "pregen"}, **kw)
    np.testing.assert_array_equal(ref.best_energy, out.best_energy)
    np.testing.assert_array_equal(ref.best_m, out.best_m)
    with pytest.raises(ValueError, match="streamed"):
        make_backend("pallas", model, n_trials=3, noise="threefry",
                     noise_mode="streamed")


def test_streamed_kernel_advances_the_same_rng_stream():
    """After a plateau, the kernel's carried xorshift lanes equal the host
    stream advanced by `length` draws — chunk/plateau chaining stays exact."""
    from repro.core.rng import xorshift_init, xorshift_next_bits

    model = _problem().to_ising()
    length = 5
    bk = make_backend("pallas", model, n_trials=2, noise="xorshift")
    state = bk.init_state(0)
    st2, _, _ = bk.run_plateau(state, 4, length=length, eligible=True)
    ns = state.noise_state
    for _ in range(length):
        ns, _ = xorshift_next_bits(ns)
    np.testing.assert_array_equal(np.asarray(st2.noise_state), np.asarray(ns))


# ---------------------------------------------------------------------------
# Tiled J: (tile_n, N) slabs ≡ dense (N, N), no dense buffer above threshold
# ---------------------------------------------------------------------------
def test_local_fields_tiled_matches_dense_and_sparse():
    model = _problem().to_ising()
    h = jnp.asarray(model.h, jnp.int32)
    J = jnp.asarray(model.dense_J(), jnp.float32)
    _, idx, w = model.device_arrays()
    rng = np.random.default_rng(0)
    m = jnp.asarray(rng.choice([-1, 1], size=(4, model.n)), jnp.int32)
    ref_d = local_fields_dense(m, h, J)
    ref_s = local_fields_sparse(m, h, idx, w)
    np.testing.assert_array_equal(np.asarray(ref_d), np.asarray(ref_s))
    for tile_n in (8, 16, 50, 64):  # incl. non-dividing and full-N tiles
        out = local_fields_tiled(m, h, idx, w, tile_n=tile_n)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref_d))


def test_tiled_anneal_bitwise_equal_to_dense():
    p = _problem()
    kw = dict(seed=3, record="best", noise="xorshift", track_energy=False)
    ref = anneal(p, HP, backend="dense", **kw)
    out = anneal(p, HP, backend="dense",
                 backend_opts={"j_mode": "tiled", "tile_n": 16}, **kw)
    np.testing.assert_array_equal(ref.best_energy, out.best_energy)
    np.testing.assert_array_equal(ref.best_m, out.best_m)


def test_j_mode_auto_threshold():
    from repro.core.engine import TILED_J_THRESHOLD

    assert resolve_j_mode("auto", TILED_J_THRESHOLD) == "dense"
    assert resolve_j_mode("auto", TILED_J_THRESHOLD + 1) == "tiled"
    assert resolve_j_mode("dense", 10**6) == "dense"
    with pytest.raises(ValueError):
        resolve_j_mode("bogus", 16)


def test_tiled_backend_never_materializes_dense_J():
    """Above the threshold the dense backend holds adjacency, not (N, N)."""
    model = _problem().to_ising()
    bk = make_backend("dense", model, n_trials=2, noise="xorshift",
                      j_mode="tiled")
    assert not hasattr(bk, "J")
    assert bk.nbr_idx.shape == (model.n, model.max_degree)
    bkb = make_batched_backend("dense", n_bucket=64, n_trials=2,
                               noise="xorshift", j_mode="tiled")
    stacked = bkb.stack([model])
    assert "J" not in stacked and "nbr_idx" in stacked


# ---------------------------------------------------------------------------
# The service: packed layout + tiled J end-to-end (the G77 path, scaled down)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", BACKENDS)
def test_service_packed_bit_identical_to_unpadded_runs(backend):
    from repro.serve import AnnealRequest, AnnealService

    problems = [
        gset.toroidal_grid(36, seed=1, name="t36"),
        gset.king_graph(49, seed=2, name="k49"),
        gset.toroidal_grid(100, seed=4, name="t100"),
    ]
    svc = AnnealService(backend=backend, min_bucket=16, storage_layout="packed")
    responses = svc.solve(
        [AnnealRequest(problem=p, hp=HP, seed=10 + i)
         for i, p in enumerate(problems)]
    )
    for i, (p, resp) in enumerate(zip(problems, responses)):
        ref = anneal(p, HP, seed=10 + i, record="best", noise="xorshift",
                     backend="sparse", track_energy=False)
        np.testing.assert_array_equal(ref.best_energy, resp.result.best_energy)
        np.testing.assert_array_equal(ref.best_cut, resp.result.best_cut)
        np.testing.assert_array_equal(ref.best_m, resp.result.best_m)


def test_service_tiled_j_group(monkeypatch):
    """A bucket above TILED_J_THRESHOLD serves through slabs with no dense J
    — the G77 scenario property-checked at reduced N."""
    import repro.core.engine as engine_mod

    from repro.serve import AnnealRequest, AnnealService

    monkeypatch.setattr(engine_mod, "TILED_J_THRESHOLD", 64)
    p = gset.toroidal_grid(100, seed=4, name="t100")
    ref = anneal(p, HP, seed=0, record="best", noise="xorshift",
                 backend="sparse", track_energy=False)
    svc = AnnealService(backend="dense", min_bucket=16,
                        storage_layout="packed",
                        backend_opts={"tile_n": 32})
    resp = svc.solve([AnnealRequest(problem=p, hp=HP, seed=0)])[0]
    np.testing.assert_array_equal(ref.best_energy, resp.result.best_energy)
    np.testing.assert_array_equal(ref.best_m, resp.result.best_m)
    (ent,) = svc._programs.values()
    assert ent[0].j_mode == "tiled"


def test_service_layouts_share_no_programs_but_agree():
    from repro.serve import AnnealRequest, AnnealService

    p = gset.toroidal_grid(36, seed=1)
    outs = {}
    for layout in ("dense", "packed"):
        svc = AnnealService(backend="pallas", min_bucket=16,
                            storage_layout=layout)
        outs[layout] = svc.solve([AnnealRequest(problem=p, hp=HP, seed=0)])[0]
        assert all(layout in k for k in svc._programs)
    np.testing.assert_array_equal(
        outs["dense"].result.best_energy, outs["packed"].result.best_energy
    )


# ---------------------------------------------------------------------------
# Distributed: the batched step carries packed layout and tiled J
# ---------------------------------------------------------------------------
def _init_batched(models, hp, seeds):
    from repro.core.rng import xorshift_init, xorshift_next_bits

    T = hp.n_trials
    rngs, ms, its = [], [], []
    for seed, mo in zip(seeds, models):
        r = xorshift_init(seed, (T, mo.n))
        r, r0 = xorshift_next_bits(r)
        rngs.append(r)
        ms.append(r0.astype(jnp.int8))
        its.append(jnp.where(r0 > 0, 0, -1).astype(jnp.int32))
    bH = jnp.full((len(models), T), 2**30, jnp.int32)
    return (
        jnp.stack(rngs, axis=1),
        jnp.stack(ms),
        jnp.stack(its),
        bH,
        jnp.stack(ms),
    )


def test_batched_step_packed_and_tiled_match_dense():
    from repro.core.distributed import make_batched_iteration_step
    from repro.core.engine import pack_spins, unpack_spins

    # equal max_degree (4-regular tori) so the adjacency arrays stack
    problems = [gset.toroidal_grid(36, seed=5), gset.toroidal_grid(36, seed=7)]
    models = [p.to_ising() for p in problems]
    hp = SSAHyperParams(n_trials=4, m_shot=1, tau=5, i0_min=1, i0_max=8)
    rng, m8, it, bH, bm = _init_batched(models, hp, seeds=(20, 21))
    J = jnp.stack([jnp.asarray(mo.dense_J(), jnp.float32) for mo in models])
    h = jnp.stack([jnp.asarray(mo.h, jnp.int32) for mo in models])
    idx = jnp.stack([jnp.asarray(mo.nbr_idx, jnp.int32) for mo in models])
    w = jnp.stack([jnp.asarray(mo.nbr_w, jnp.int32) for mo in models])

    ref_step = jax.jit(make_batched_iteration_step(hp, mesh=None))
    ref = ref_step(rng, m8.astype(jnp.float32), it, bH, bm, J, h)

    pk_step = jax.jit(
        make_batched_iteration_step(hp, mesh=None, storage_layout="packed")
    )
    pk = pk_step(rng, pack_spins(m8), it, bH, pack_spins(bm), J, h)
    np.testing.assert_array_equal(
        np.asarray(unpack_spins(pk[1], 36)),
        np.asarray(ref[1]).astype(np.int8),
    )
    np.testing.assert_array_equal(np.asarray(pk[3]), np.asarray(ref[3]))
    np.testing.assert_array_equal(
        np.asarray(unpack_spins(pk[4], 36)), np.asarray(ref[4])
    )

    td_step = jax.jit(
        make_batched_iteration_step(hp, mesh=None, j_mode="tiled", tile_n=16)
    )
    td = td_step(rng, m8.astype(jnp.float32), it, bH, bm, idx, w, h)
    np.testing.assert_array_equal(np.asarray(td[3]), np.asarray(ref[3]))
    np.testing.assert_array_equal(np.asarray(td[4]), np.asarray(ref[4]))
