"""Test-session bootstrap.

Provides a minimal, dependency-free stand-in for ``hypothesis`` when the
real package is not installed (this container ships a pinned environment
with no network access).  The stub implements the tiny subset these tests
use — ``@given`` with ``integers`` / ``sampled_from`` / ``floats``
strategies and a no-op ``settings`` — by deterministic pseudo-random
example draws, so the property tests still execute many concrete examples
instead of being skipped wholesale.

If the real hypothesis is importable it is used untouched.
"""
from __future__ import annotations

import random
import sys
import types

_DEFAULT_EXAMPLES = 25


def _install_hypothesis_stub() -> None:
    try:
        import hypothesis  # noqa: F401

        return
    except ImportError:
        pass

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def sampled_from(options):
        options = list(options)
        return _Strategy(lambda rng: options[rng.randrange(len(options))])

    def floats(min_value=0.0, max_value=1.0, allow_nan=False, allow_infinity=False,
               width=64):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)

    def just(value):
        return _Strategy(lambda rng: value)

    class settings:  # noqa: N801 - mimic hypothesis' decorator class
        def __init__(self, max_examples=_DEFAULT_EXAMPLES, deadline=None, **_kw):
            self.max_examples = max_examples

        def __call__(self, fn):
            fn._stub_max_examples = self.max_examples
            return fn

    def given(*strategies, **kw_strategies):
        def decorate(fn):
            # NOTE: no functools.wraps — pytest must see a zero-arg
            # signature, not the strategy parameters (they'd be treated
            # as fixtures).
            def wrapper(*args, **kwargs):
                max_examples = getattr(fn, "_stub_max_examples", _DEFAULT_EXAMPLES)
                # Cap the stub's example count: these are smoke-level draws,
                # the real hypothesis explores far more when available.
                n = min(max_examples, _DEFAULT_EXAMPLES)
                rng = random.Random(f"{fn.__module__}.{fn.__qualname__}")
                for i in range(n):
                    ex_args = tuple(s.example(rng) for s in strategies)
                    ex_kw = {k: s.example(rng) for k, s in kw_strategies.items()}
                    try:
                        fn(*args, *ex_args, **{**kwargs, **ex_kw})
                    except Exception as e:  # pragma: no cover - failure path
                        raise AssertionError(
                            f"stub-hypothesis falsifying example "
                            f"(draw {i}): args={ex_args} kwargs={ex_kw}"
                        ) from e

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.hypothesis_stub = True
            return wrapper

        return decorate

    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.integers = integers
    st_mod.sampled_from = sampled_from
    st_mod.floats = floats
    st_mod.booleans = booleans
    st_mod.just = just

    hyp_mod = types.ModuleType("hypothesis")
    hyp_mod.given = given
    hyp_mod.settings = settings
    hyp_mod.strategies = st_mod
    hyp_mod.__stub__ = True

    sys.modules["hypothesis"] = hyp_mod
    sys.modules["hypothesis.strategies"] = st_mod


_install_hypothesis_stub()
