"""Unit + property tests for the Ising/MAX-CUT substrate."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import IsingModel, MaxCutProblem, fig4_example, ising_energy
from repro.core import gset


def brute_force_maxcut(p: MaxCutProblem):
    best = -(10**9)
    for bits in range(2**p.n):
        m = np.array([1 if (bits >> k) & 1 else -1 for k in range(p.n)])
        best = max(best, int(p.cut_value(jnp.asarray(m))))
    return best


def test_fig4_example_structure():
    p = fig4_example()
    assert p.n == 4 and len(p.edges) == 5
    # the paper's partitions: {A}|{BCD} -> 1, {A,B}|{C,D} -> 3
    m_b = jnp.asarray([1, -1, -1, -1])
    m_c = jnp.asarray([1, 1, -1, -1])
    assert int(p.cut_value(m_b)) == 1
    assert int(p.cut_value(m_c)) == 3
    assert brute_force_maxcut(p) == 3 == p.best_known


@given(st.integers(0, 2**10 - 1), st.integers(0, 10**6))
@settings(max_examples=30, deadline=None)
def test_cut_energy_consistency(bits, seed):
    """cut(m) == (w_total - H(m)) / 2 for the Ising embedding (J=-w, h=0)."""
    rng = np.random.default_rng(seed)
    n = 10
    ii, jj = np.triu_indices(n, k=1)
    keep = rng.random(len(ii)) < 0.4
    if keep.sum() == 0:
        keep[0] = True
    edges = np.stack([ii[keep], jj[keep]], axis=1)
    weights = rng.integers(-3, 4, size=len(edges))
    p = MaxCutProblem(n=n, edges=edges, weights=weights, name="rand")
    model = p.to_ising()
    m = np.array([1 if (bits >> k) & 1 else -1 for k in range(n)], dtype=np.int32)
    h, nbr_idx, nbr_w = model.device_arrays()
    H = int(ising_energy(jnp.asarray(m), h, nbr_idx, nbr_w))
    cut = int(p.cut_value(jnp.asarray(m)))
    assert cut == (p.w_total - H) // 2
    assert cut == int(p.cut_from_energy(H))


def test_dense_sparse_field_agreement():
    p = gset.king_graph(36, seed=5)
    model = p.to_ising()
    from repro.core.ising import local_fields_dense, local_fields_sparse

    h, nbr_idx, nbr_w = model.device_arrays()
    J = jnp.asarray(model.dense_J(), jnp.float32)
    rng = np.random.default_rng(0)
    m = jnp.asarray(rng.choice([-1, 1], size=(7, 36)).astype(np.int8))
    fs = local_fields_sparse(m.astype(jnp.int32), h, nbr_idx, nbr_w)
    fd = local_fields_dense(m, h, J)
    np.testing.assert_array_equal(np.asarray(fs), np.asarray(fd))


def test_dense_J_roundtrip():
    p = gset.toroidal_grid(36, seed=2)
    model = p.to_ising()
    J = model.dense_J()
    assert np.array_equal(J, J.T)
    assert np.all(np.diag(J) == 0)
    edges, w = model.edge_list()
    assert len(edges) == len(p.edges)
    model2 = IsingModel.from_edges(model.n, edges, w)
    assert np.array_equal(model2.dense_J(), J)


def test_gset_instances_match_table1():
    """Table I: G11/12/13 have 800 vertices / 1600 edges; King1 3200 edges."""
    for name in ("G11", "G12", "G13"):
        p = gset.load(name)
        assert p.n == 800 and len(p.edges) == 1600
        assert set(np.unique(p.weights)) <= {-1, 1}
    k = gset.load("King1")
    assert k.n == 800 and len(k.edges) == 3200
    # 4-regular / 8-regular degree structure
    deg = np.zeros(800, int)
    for i, j in gset.load("G11").edges:
        deg[i] += 1
        deg[j] += 1
    assert np.all(deg == 4)
    deg = np.zeros(800, int)
    for i, j in k.edges:
        deg[i] += 1
        deg[j] += 1
    assert np.all(deg == 8)


def test_gset_parser():
    text = "3 2\n1 2 1\n2 3 -1\n"
    p = gset.parse_gset_text(text, name="G11")
    assert p.n == 3 and len(p.edges) == 2
    assert p.best_known == 564  # table lookup by name
    np.testing.assert_array_equal(p.edges, [[0, 1], [1, 2]])
    np.testing.assert_array_equal(p.weights, [1, -1])


def test_self_loop_rejected():
    with pytest.raises(ValueError):
        IsingModel.from_edges(3, np.array([[0, 0]]), np.array([1]))


def test_from_edges_rejects_nonfinite_weights():
    edges = np.array([[0, 1], [1, 2]])
    with pytest.raises(ValueError, match="finite"):
        IsingModel.from_edges(3, edges, np.array([1.0, np.nan]))
    with pytest.raises(ValueError, match="finite"):
        IsingModel.from_edges(3, edges, np.array([1.0, np.inf]))
    with pytest.raises(ValueError, match="finite"):
        IsingModel.from_edges(3, edges, np.array([1.0, 2.0]),
                              h=np.array([0.0, np.nan, 0.0]))


def test_from_dense_rejects_nonfinite_J():
    J = np.zeros((3, 3))
    J[0, 1] = J[1, 0] = np.nan
    with pytest.raises(ValueError, match="finite"):
        IsingModel.from_dense(J)
