"""Tests for the xorshift128 noise generator (paper's RNG, ref [26])."""
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.rng import Xorshift128, xorshift_init, xorshift_next_bits


def _ref_xorshift128(state):
    """Pure-python Marsaglia xorshift128 reference."""
    x, y, z, w = [int(v) for v in state]
    t = (x ^ (x << 11)) & 0xFFFFFFFF
    w_new = (w ^ (w >> 19)) ^ (t ^ (t >> 8))
    return [y, z, w, w_new & 0xFFFFFFFF]


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_matches_python_reference(seed):
    st_j = xorshift_init(seed, (3,))
    st_py = np.array(st_j).T.copy()  # (3 lanes, 4 words)
    for _ in range(16):
        st_j, bits = xorshift_next_bits(st_j)
        for lane in range(3):
            st_py[lane] = _ref_xorshift128(st_py[lane])
            expect = 1 if (st_py[lane][3] >> 31) & 1 else -1
            assert int(bits[lane]) == expect


def test_lanes_decorrelated_and_balanced():
    gen = Xorshift128(seed=42, lanes=(64,))
    draws = np.stack([np.asarray(gen.next_bits()) for _ in range(512)])
    # each lane individually near-balanced
    lane_means = draws.mean(axis=0)
    assert np.abs(lane_means).max() < 0.35
    # lanes differ (no two lanes emit identical streams)
    assert len({tuple(draws[:, k]) for k in range(64)}) == 64


def test_no_allzero_state():
    st0 = xorshift_init(0, (8,))
    assert not np.any(np.all(np.asarray(st0) == 0, axis=0))


def test_deterministic():
    a = Xorshift128(7, (4, 5))
    b = Xorshift128(7, (4, 5))
    for _ in range(10):
        np.testing.assert_array_equal(np.asarray(a.next_bits()), np.asarray(b.next_bits()))
