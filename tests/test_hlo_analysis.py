"""Tests for the roofline extraction layer (HLO parsing + term math)."""
import jax
import pytest

from repro.launch.hlo_analysis import (RooflineReport, collective_bytes,
                                       count_hlo_ops, model_flops, shape_bytes)

HLO = """
HloModule jit_step

ENTRY %main (p0: f32[128,256]) -> f32[128,256] {
  %p0 = f32[128,256]{1,0} parameter(0)
  %ag = f32[128,4096]{1,0} all-gather(f32[128,256]{1,0} %p0), dimensions={1}
  %ar = f32[128,256]{1,0} all-reduce(f32[128,256]{1,0} %p0), to_apply=%add
  %rs = bf16[8,256]{1,0} reduce-scatter(bf16[64,256]{1,0} %x), dimensions={0}
  %a2a = s8[16,64]{1,0} all-to-all(s8[16,64]{1,0} %y), dimensions={0}
  %cp = f32[4,4]{1,0} collective-permute(f32[4,4]{1,0} %z), source_target_pairs={{0,1}}
  %ars = f32[128,256]{1,0} all-reduce-start(f32[128,256]{1,0} %p0), to_apply=%add
  %ard = f32[128,256]{1,0} all-reduce-done(f32[128,256]{1,0} %ars)
  %dot = f32[128,128]{1,0} dot(f32[128,256]{1,0} %p0, f32[256,128]{1,0} %w)
}
"""


def test_shape_bytes():
    assert shape_bytes("f32", "128,256") == 128 * 256 * 4
    assert shape_bytes("bf16", "8,256") == 8 * 256 * 2
    assert shape_bytes("s8", "16,64") == 16 * 64
    assert shape_bytes("f32", "") == 4  # scalar


def test_collective_bytes_by_type():
    out = collective_bytes(HLO)
    assert out["all-gather"] == 128 * 4096 * 4
    # plain all-reduce + async all-reduce-start; -done NOT double counted
    assert out["all-reduce"] == 2 * 128 * 256 * 4
    assert out["reduce-scatter"] == 8 * 256 * 2
    assert out["all-to-all"] == 16 * 64
    assert out["collective-permute"] == 4 * 4 * 4
    assert out["total"] == sum(
        out[k] for k in ("all-gather", "all-reduce", "reduce-scatter",
                         "all-to-all", "collective-permute")
    )


def test_dot_not_counted():
    out = collective_bytes("%d = f32[8,8]{1,0} dot(f32[8,8] %a, f32[8,8] %b)")
    assert out["total"] == 0


def test_roofline_terms_and_dominance():
    r = RooflineReport(
        flops=197e12,        # exactly 1 s of compute
        hbm_bytes=819e9 * 2,  # 2 s of memory
        coll_bytes=50e9 * 0.5,  # 0.5 s of collective
        coll_breakdown={}, n_chips=256, peak_memory_per_device=1e9,
    )
    assert abs(r.t_compute - 1.0) < 1e-9
    assert abs(r.t_memory - 2.0) < 1e-9
    assert abs(r.t_collective - 0.5) < 1e-9
    assert r.dominant == "memory"
    assert r.bound_time == 2.0


def test_model_flops():
    assert model_flops(1e9, 1e6, "train") == 6e15
    assert model_flops(1e9, 128, "decode") == 2 * 1e9 * 128


def test_count_hlo_ops_both_dialects():
    assert count_hlo_ops("%d = f32[8,8]{1,0} dot(f32[8,8] %a, f32[8,8] %b)", "dot") == 1
    assert count_hlo_ops("%5 = stablehlo.dot_general %a, %b", "dot_general") == 1
    # op-name prefixes don't cross-match
    assert count_hlo_ops("%5 = stablehlo.dot_general %a, %b", "dot") == 0
    assert count_hlo_ops("%g = s32[4]{0} gather(s32[8] %x)", "gather") == 1
    assert count_hlo_ops("%ag = f32[4] all-gather(f32[1] %x)", "gather") == 0


@pytest.mark.parametrize("track_energy", [False, True])
def test_plateau_cycle_has_one_contraction(track_energy):
    """One plateau (C cycles) compiles to exactly TWO field contractions:
    one inside the cycle loop — i.e. one per cycle — plus one epilogue for
    the plateau's final state.  The seed's record='best' scan evaluated the
    field twice per cycle; this pins the fix per backend."""
    from repro.core import gset, make_backend

    model = gset.toroidal_grid(64, seed=17).to_ising()
    counts = {"dense": "dot", "sparse": "gather"}
    for kind, op in counts.items():
        bk = make_backend(kind, model, n_trials=4, noise="xorshift")
        state = bk.init_state(0)
        f = jax.jit(
            lambda st, bk=bk: bk.run_plateau(
                st, 8, length=16, eligible=True, track_energy=track_energy
            )[0]
        )
        hlo = f.lower(state).compile().as_text()
        assert count_hlo_ops(hlo, op) == 2, (kind, op)
        # and the dense loop uses no gathers / the sparse loop no dots
        other = "gather" if op == "dot" else "dot"
        assert count_hlo_ops(hlo, other) == 0, (kind, other)
