"""remat_block (checkpoint every k-th layer group) must not change the math."""
import dataclasses

import jax
import numpy as np

from repro.data.pipeline import DataConfig, synthetic_batch
from repro.models import ModelConfig, forward
from repro.optim.adamw import AdamWConfig
from repro.train.step import TrainConfig, init_train_state, make_train_step

CFG = ModelConfig(name="t", n_layers=4, d_model=32, n_heads=2, n_kv_heads=2,
                  d_head=16, d_ff=64, vocab=53, remat="full")


def test_forward_identical_across_remat_block():
    from repro.models.params import init_params
    from repro.models.transformer import model_defs

    params = init_params(model_defs(CFG), jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 53)}
    h1, _ = forward(params, batch, CFG)
    h2, _ = forward(params, batch, dataclasses.replace(CFG, remat_block=2))
    h4, _ = forward(params, batch, dataclasses.replace(CFG, remat_block=4))
    # same math; XLA fuses the restructured scan differently → bf16-level noise
    np.testing.assert_allclose(np.asarray(h1, np.float32),
                               np.asarray(h2, np.float32), atol=0.35)
    np.testing.assert_allclose(np.asarray(h1, np.float32),
                               np.asarray(h4, np.float32), atol=0.35)


def test_train_step_identical_across_remat_block():
    tc = TrainConfig(opt=AdamWConfig(), loss_chunk=16)
    dc = DataConfig(vocab=53, seq_len=16, global_batch=4, seed=0)
    b = synthetic_batch(dc, 0)
    losses = []
    for k in (1, 2):
        cfg = dataclasses.replace(CFG, remat_block=k)
        state = init_train_state(cfg, tc, jax.random.PRNGKey(0))
        _, m = jax.jit(make_train_step(cfg, tc))(state, b)
        losses.append(float(m["ce_loss"]))
    # bf16 forward + restructured-scan fusion: loss agreement is at the
    # 1e-2 level (observed up to ~7e-3 depending on XLA's fusion choices,
    # which vary with what else compiled in the process).
    assert abs(losses[0] - losses[1]) < 2e-2
