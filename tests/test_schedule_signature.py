"""Schedule.signature(): the stable, hashable executable-cache key component.

Contract: two schedules that run the same per-cycle (I0, write-enable)
program have the same signature regardless of how they were constructed;
any per-cycle difference changes it.
"""
import numpy as np

from repro.core.schedule import Schedule, hassa_schedule, ssa_schedule


def _by_hand(i0_min, i0_max, tau):
    """Hand-build the Eq. (4) plateau sequence a hassa_schedule would make."""
    plateaus = []
    v = i0_min
    while True:
        plateaus.append(min(v, i0_max))
        if plateaus[-1] >= i0_max:
            break
        v <<= 1
    plateaus = np.asarray(plateaus, dtype=np.int32)
    return Schedule(
        i0_per_cycle=np.repeat(plateaus, tau),
        tau=tau,
        steps=len(plateaus),
        store_mask=np.repeat(plateaus == i0_max, tau),
    )


def test_equal_schedules_collide():
    a = hassa_schedule(1, 8, 5)
    b = _by_hand(1, 8, 5)
    np.testing.assert_array_equal(a.i0_per_cycle, b.i0_per_cycle)
    assert a.signature() == b.signature()


def test_hassa_and_ssa_equivalence_collides():
    """Sec. III-A: β_ssa = 2^-β_hassa makes the two schedules identical —
    their signatures agree, so the service caches one program for both."""
    a = hassa_schedule(1, 32, 10, beta_shift=1)
    b = ssa_schedule(1, 32, 10, beta=0.5)
    np.testing.assert_array_equal(a.i0_per_cycle, b.i0_per_cycle)
    assert a.signature() == b.signature()


def test_unequal_schedules_differ():
    base = hassa_schedule(1, 8, 5)
    assert base.signature() != hassa_schedule(1, 8, 6).signature()   # tau
    assert base.signature() != hassa_schedule(1, 16, 5).signature()  # i0_max
    assert base.signature() != hassa_schedule(2, 8, 5).signature()   # i0_min
    # same I0 sequence, different write-enable → different program
    hand = _by_hand(1, 8, 5)
    flipped = Schedule(
        i0_per_cycle=hand.i0_per_cycle,
        tau=hand.tau,
        steps=hand.steps,
        store_mask=np.ones_like(hand.store_mask),
    )
    assert hand.signature() != flipped.signature()


def test_signature_is_stable_and_hashable():
    s = hassa_schedule(1, 8, 5)
    sig = s.signature()
    assert isinstance(sig, str) and sig == s.signature()
    # usable directly as a dict key (the executable cache does exactly this)
    cache = {(64, sig): "program"}
    assert cache[(64, hassa_schedule(1, 8, 5).signature())] == "program"
