"""XNOR-popcount bit-parallel compute path (DESIGN.md §8).

The tentpole's gate: the packed bitplanes are the *arithmetic* format, not
just the storage format.  Every property here is exact, not approximate —
the popcount contraction computes the same integers the f32 matmul does,
so 'popcount' vs 'dense' field_mode must be bit-identical end to end:
field values, kernel plateau chains, service best-cuts, distributed steps.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import SSAHyperParams, anneal, gset
from repro.core.engine import (
    MIN_RESIDENT_N,
    POPCOUNT_AUTO_MAX_BITS,
    make_backend,
    make_batched_backend,
    model_weight_bits,
    resolve_backend,
    resolve_field_mode,
    run_schedule,
    schedule_plateaus,
)
from repro.core.ising import local_fields_popcount
from repro.kernels.bitplane import (
    PackedJ,
    adjacency_weight_bits,
    pack_couplings,
    pack_couplings_from_adjacency,
    pack_spins,
    packed_j_nbytes,
    popcount_u32,
)

HP = SSAHyperParams(n_trials=3, m_shot=2, tau=4, i0_min=1, i0_max=8)


def _torus():
    # 50 spins: non-multiple-of-32 bitplane tail, ±1 weights (1 plane)
    return gset.toroidal_grid(50, seed=17)


def _king():
    # 49 spins, king's-graph topology re-weighted to ±1..±3: integer
    # multi-bit couplings → 2 magnitude bitplanes, deterministically
    p = gset.king_graph(49, seed=3)
    rng = np.random.default_rng(11)
    w = rng.integers(1, 4, len(p.edges)) * np.sign(p.weights)
    return type(p)(n=p.n, edges=p.edges, weights=w.astype(np.int64),
                   name="King49w3")


# ---------------------------------------------------------------------------
# popcount_u32 and the packed-J codec
# ---------------------------------------------------------------------------
def test_popcount_u32_counts_bits():
    x = jnp.asarray([0, 1, 0xFFFFFFFF, 0x80000001, 0xDEADBEEF], jnp.uint32)
    got = popcount_u32(x)
    assert got.dtype == jnp.int32
    want = [bin(int(v)).count("1") for v in np.asarray(x)]
    np.testing.assert_array_equal(np.asarray(got), want)


def test_popcount_u32_rejects_non_uint32():
    with pytest.raises(TypeError):
        popcount_u32(jnp.asarray([1, 2], jnp.int32))


def test_pack_couplings_rejects_non_integer():
    J = np.asarray([[0.0, 0.5], [0.5, 0.0]], np.float32)
    with pytest.raises(ValueError, match="integer"):
        pack_couplings(J)


def test_pack_couplings_forced_bits_too_small():
    J = np.asarray([[0, 5], [5, 0]], np.float32)  # |w|=5 needs 3 planes
    with pytest.raises(ValueError, match="bitplanes"):
        pack_couplings(J, n_bits=1)


def test_packed_j_nbytes_matches_arrays():
    m = _king().to_ising()
    jb = adjacency_weight_bits(m.n, m.nbr_idx, m.nbr_w)
    pj = pack_couplings_from_adjacency(m.n, m.nbr_idx, m.nbr_w)
    assert pj.n_bits == jb == 2
    got = pj.sign.nbytes + pj.mags.nbytes + pj.base.nbytes
    assert got == packed_j_nbytes(m.n, jb)


# ---------------------------------------------------------------------------
# Exact-integer field equivalence (the tentpole's arithmetic claim)
# ---------------------------------------------------------------------------
def _dense_int_fields(spins, h, J):
    return h.astype(np.int64) + spins.astype(np.int64) @ J.T.astype(np.int64)


@given(
    n=st.integers(1, 70),
    w_max=st.integers(1, 7),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=25, deadline=None)
def test_popcount_fields_exact_integer(n, w_max, seed):
    """Random symmetric integer-weight graphs, every tail width (n spans
    1..70 → 1-3 uint32 words with all pad widths), ±1..±7 weights (1-3
    magnitude planes): popcount fields == int64 matmul fields, exactly."""
    rng = np.random.default_rng(seed)
    J = rng.integers(-w_max, w_max + 1, (n, n)).astype(np.float32)
    J = np.triu(J, 1)
    J = J + J.T
    h = rng.integers(-3, 4, n).astype(np.int32)
    spins = (rng.integers(0, 2, (2, n)) * 2 - 1).astype(np.int8)

    pj = pack_couplings(J)
    mw = pack_spins(jnp.asarray(spins))
    got = np.asarray(local_fields_popcount(mw, jnp.asarray(h), pj))
    want = _dense_int_fields(spins, h, J)
    np.testing.assert_array_equal(got.astype(np.int64), want)


def test_pack_from_adjacency_equals_pack_from_dense():
    m = _king().to_ising()
    pj_a = pack_couplings_from_adjacency(m.n, m.nbr_idx, m.nbr_w)
    pj_d = pack_couplings(np.asarray(m.dense_J()))
    np.testing.assert_array_equal(np.asarray(pj_a.sign), np.asarray(pj_d.sign))
    np.testing.assert_array_equal(np.asarray(pj_a.mags), np.asarray(pj_d.mags))
    np.testing.assert_array_equal(np.asarray(pj_a.base), np.asarray(pj_d.base))


def test_popcount_fields_tiled_equals_untiled():
    m = _king().to_ising()
    pj = pack_couplings_from_adjacency(m.n, m.nbr_idx, m.nbr_w)
    rng = np.random.default_rng(0)
    spins = (rng.integers(0, 2, (3, m.n)) * 2 - 1).astype(np.int8)
    mw = pack_spins(jnp.asarray(spins))
    h = jnp.asarray(m.h, jnp.int32)
    a = np.asarray(local_fields_popcount(mw, h, pj))
    b = np.asarray(local_fields_popcount(mw, h, pj, tile_n=16))
    np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# No f32 unpack in the hot loop (structural)
# ---------------------------------------------------------------------------
def _collect_avals(jaxpr, out):
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            out.append(v.aval)
        for v in eqn.params.values():
            sub = getattr(v, "jaxpr", None)
            if sub is not None:
                _collect_avals(sub, out)
            elif isinstance(v, (list, tuple)):
                for vv in v:
                    sub = getattr(vv, "jaxpr", None)
                    if sub is not None:
                        _collect_avals(sub, out)
    return out


def test_popcount_field_path_has_no_float_values():
    """The packed field contraction never unpacks to f32: every value in
    its jaxpr is integer/bool — the arithmetic really is bit-parallel."""
    m = _torus().to_ising()
    pj = pack_couplings_from_adjacency(m.n, m.nbr_idx, m.nbr_w)
    h = jnp.asarray(m.h, jnp.int32)
    mw = pack_spins(jnp.asarray(np.ones((3, m.n), np.int8)))
    jaxpr = jax.make_jaxpr(lambda w: local_fields_popcount(w, h, pj))(mw)
    avals = _collect_avals(jaxpr.jaxpr, [])
    floats = [a for a in avals
              if jnp.issubdtype(getattr(a, "dtype", jnp.int32), jnp.floating)]
    assert not floats, f"f32 values in the popcount field path: {floats[:5]}"


def test_dense_backend_popcount_materializes_no_J():
    m = _torus().to_ising()
    bk = make_backend("dense", m, n_trials=2, noise="xorshift",
                      field_mode="popcount")
    assert bk.field_mode == "popcount"
    assert not hasattr(bk, "J")
    assert isinstance(bk.packed_j, PackedJ)


# ---------------------------------------------------------------------------
# End-to-end bit-identity: anneal() on every backend × layout × weight depth
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("problem_fn", [_torus, _king])
@pytest.mark.parametrize("backend,layout", [
    ("dense", "dense"),
    ("dense", "packed"),
    ("pallas", "dense"),
    ("pallas", "packed"),
])
def test_popcount_anneal_bitwise_equal_to_sparse(problem_fn, backend, layout):
    p = problem_fn()
    kw = dict(seed=3, record="best", noise="xorshift", track_energy=False)
    ref = anneal(p, HP, backend="sparse", **kw)
    out = anneal(p, HP, backend=backend, storage_layout=layout,
                 backend_opts={"field_mode": "popcount"}, **kw)
    np.testing.assert_array_equal(ref.best_energy, out.best_energy)
    np.testing.assert_array_equal(ref.best_cut, out.best_cut)
    np.testing.assert_array_equal(ref.best_m, out.best_m)


@given(st.integers(0, 10_000))
@settings(max_examples=3, deadline=None)
def test_popcount_equivalence_property(seed):
    p = _king()
    hp = SSAHyperParams(n_trials=2, m_shot=2, tau=3, i0_min=1, i0_max=4)
    kw = dict(seed=seed, record="best", noise="xorshift", track_energy=False)
    ref = anneal(p, hp, backend="sparse", **kw)
    for backend in ("dense", "pallas"):
        for fm in ("popcount", "auto"):
            out = anneal(p, hp, backend=backend,
                         backend_opts={"field_mode": fm}, **kw)
            np.testing.assert_array_equal(ref.best_energy, out.best_energy)
            np.testing.assert_array_equal(ref.best_m, out.best_m)


# ---------------------------------------------------------------------------
# Multi-plateau residency: the whole chain is ONE pallas_call
# ---------------------------------------------------------------------------
def _count_primitive(jaxpr, name):
    count = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == name:
            count += 1
        for v in eqn.params.values():
            sub = getattr(v, "jaxpr", None)
            if sub is not None:
                count += _count_primitive(sub, name)
            elif isinstance(v, (list, tuple)):
                for vv in v:
                    sub = getattr(vv, "jaxpr", None)
                    if sub is not None:
                        count += _count_primitive(sub, name)
    return count


def test_popcount_chain_is_one_resident_launch():
    m = _torus().to_ising()
    bk = make_backend("pallas", m, n_trials=2, n_rnd=HP.n_rnd,
                      noise="xorshift", field_mode="popcount")
    plateaus = schedule_plateaus(HP.schedule("hassa"), "i0max")
    assert len(plateaus) > 1
    state = bk.init_state(0)
    jaxpr = jax.make_jaxpr(
        lambda s: run_schedule(bk, plateaus, s, record="best")[0]
    )(state)
    assert _count_primitive(jaxpr.jaxpr, "pallas_call") == 1


def test_popcount_run_plateaus_equals_chained_run_plateau():
    m = _king().to_ising()
    bk = make_backend("pallas", m, n_trials=2, n_rnd=HP.n_rnd,
                      noise="xorshift", field_mode="popcount")
    plateaus = schedule_plateaus(HP.schedule("hassa"), "i0max")
    st0 = bk.init_state(0)
    whole = bk.run_plateaus(st0, plateaus)
    chained = st0
    for p in plateaus:
        chained, _, _ = bk.run_plateau(chained, p.i0, length=p.length,
                                       eligible=p.eligible)
    for a, b in zip(jax.tree.leaves(whole), jax.tree.leaves(chained)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pallas_popcount_requires_streamed_noise():
    m = _torus().to_ising()
    with pytest.raises(ValueError, match="streamed"):
        make_backend("pallas", m, n_trials=2, noise="threefry",
                     field_mode="popcount")


# ---------------------------------------------------------------------------
# Batched engine: popcount group solves are bit-identical
# ---------------------------------------------------------------------------
def test_batched_popcount_bitwise_equal():
    models = [gset.toroidal_grid(36, seed=1).to_ising(), _king().to_ising()]
    nb = 64
    jb = max(adjacency_weight_bits(m.n, m.nbr_idx, m.nbr_w) for m in models)
    plateaus = schedule_plateaus(HP.schedule("hassa"), "i0max")
    seeds, lives = [7, 8], [m.n for m in models]

    def solve(backend, **opts):
        bk = make_batched_backend(backend, n_bucket=nb, n_trials=2, n_rnd=2,
                                  noise="xorshift", **opts)
        prob = bk.stack(models)
        st = bk.init_state(prob, bk.init_noise(seeds, lives))
        st = bk.run_shots(prob, st, plateaus, n_shots=2)
        bh, bm = bk.finalize(st)
        return np.asarray(bh), np.asarray(bm)

    rh, rm = solve("sparse")
    for backend in ("dense", "pallas"):
        bh, bm = solve(backend, field_mode="popcount", j_bits=jb)
        np.testing.assert_array_equal(bh, rh)
        np.testing.assert_array_equal(bm, rm)


def test_batched_popcount_insufficient_j_bits_raises():
    models = [_king().to_ising()]  # needs 2 magnitude planes
    bk = make_batched_backend("dense", n_bucket=64, n_trials=2, n_rnd=2,
                              noise="xorshift", field_mode="popcount",
                              j_bits=1)
    with pytest.raises(ValueError, match="bitplanes"):
        bk.stack(models)


# ---------------------------------------------------------------------------
# Resolvers
# ---------------------------------------------------------------------------
def test_resolve_field_mode_auto_by_weight_depth():
    assert resolve_field_mode("auto", 1) == "popcount"
    assert resolve_field_mode("auto", POPCOUNT_AUTO_MAX_BITS) == "popcount"
    assert resolve_field_mode("auto", POPCOUNT_AUTO_MAX_BITS + 1) == "dense"
    assert resolve_field_mode("dense", 1) == "dense"
    assert resolve_field_mode("popcount", 9) == "popcount"
    with pytest.raises(ValueError):
        resolve_field_mode("xnor", 1)


def test_resolve_backend_min_resident_n():
    assert resolve_backend("auto", 32) == "dense"
    assert resolve_backend("auto", MIN_RESIDENT_N - 1) == "dense"
    assert resolve_backend("auto", MIN_RESIDENT_N) == "pallas"
    assert resolve_backend("sparse", 10**6) == "sparse"
    assert resolve_backend("pallas", 2) == "pallas"  # explicit wins


def test_make_backend_auto_routes_small_n_to_dense():
    m = _torus().to_ising()  # 50 spins < MIN_RESIDENT_N
    bk = make_backend("auto", m, n_trials=2, noise="xorshift")
    assert bk.name == "dense"


def test_model_weight_bits():
    assert model_weight_bits(_torus().to_ising()) == 1
    assert model_weight_bits(_king().to_ising()) == 2


# ---------------------------------------------------------------------------
# Service: popcount parity through the full serving stack
# ---------------------------------------------------------------------------
def test_service_popcount_best_cut_parity():
    from repro.serve.anneal_service import AnnealRequest, AnnealService

    probs = [gset.toroidal_grid(36, seed=1), _king()]
    hp = SSAHyperParams(n_trials=3, m_shot=2, tau=3, i0_min=1, i0_max=8)
    reqs = [AnnealRequest(problem=p, hp=hp, seed=10 + i)
            for i, p in enumerate(probs)]

    def cuts(svc):
        return [tuple(np.asarray(r.result.best_cut).tolist())
                for r in svc.solve(reqs)]

    ref = cuts(AnnealService(backend="sparse", noise="xorshift"))
    for backend, layout in [("dense", "dense"), ("pallas", "packed"),
                            ("auto", "packed")]:
        svc = AnnealService(backend=backend, noise="xorshift",
                            storage_layout=layout,
                            backend_opts={"field_mode": "auto"})
        assert cuts(svc) == ref, (backend, layout)
        if backend != "auto":
            keys = svc.cache_info()["keys"]
            assert any("field_mode" in repr(k) for k in keys)


# ---------------------------------------------------------------------------
# Distributed lowering parity
# ---------------------------------------------------------------------------
def test_distributed_popcount_step_matches_dense():
    """The batched mesh step under field_mode='popcount' is bit-identical
    to the dense-einsum step — the exact-integer property survives the
    distributed lowering path."""
    from repro.core.distributed import make_batched_iteration_step
    from repro.core.rng import xorshift_init

    models = [gset.king_graph(36, seed=5).to_ising(),
              gset.toroidal_grid(36, seed=7).to_ising()]
    hp = SSAHyperParams(n_trials=3, m_shot=2, tau=3, i0_min=1, i0_max=4)
    T, N, B = hp.n_trials, 36, len(models)
    jb = max(adjacency_weight_bits(m.n, m.nbr_idx, m.nbr_w) for m in models)

    step_d = jax.jit(make_batched_iteration_step(hp, mesh=None))
    step_pc = jax.jit(make_batched_iteration_step(hp, mesh=None,
                                                  field_mode="popcount"))

    rng0 = jnp.stack([xorshift_init(20 + i, (T, N)) for i in range(B)],
                     axis=1)                        # (4, B, T, N)
    m0 = jnp.stack([jnp.asarray(
        (np.random.default_rng(i).integers(0, 2, (T, N)) * 2 - 1), jnp.float32)
        for i in range(B)])
    it0 = jnp.where(m0 > 0, 0, -1).astype(jnp.int32)
    bH0 = jnp.full((B, T), 2**30, jnp.int32)
    bm0 = m0.astype(jnp.int8)

    JB = jnp.stack([jnp.asarray(m.dense_J(), jnp.float32) for m in models])
    hB = jnp.stack([jnp.asarray(m.h, jnp.int32) for m in models])
    pjs = [pack_couplings_from_adjacency(m.n, m.nbr_idx, m.nbr_w, n_bits=jb)
           for m in models]
    sign = jnp.stack([pj.sign for pj in pjs])
    mags = jnp.stack([pj.mags for pj in pjs])
    base = jnp.stack([pj.base for pj in pjs])

    st_d = (rng0, m0, it0, bH0, bm0)
    st_pc = (rng0, m0, it0, bH0, bm0)
    for _ in range(hp.m_shot):
        st_d = step_d(*st_d, JB, hB)
        st_pc = step_pc(*st_pc, sign, mags, base, hB)
    for a, b in zip(jax.tree.leaves(st_d), jax.tree.leaves(st_pc)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_batched_lowering_popcount_operands():
    """The dry-run lowering under popcount carries bitplane operands (no
    (B, N, N) f32 J anywhere in the program)."""
    from jax.sharding import Mesh

    from repro.core.distributed import batched_anneal_step_lowering

    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    hp = SSAHyperParams(n_trials=2, m_shot=1, tau=2, i0_min=1, i0_max=2)
    B, N = 2, 64
    low = batched_anneal_step_lowering(
        mesh, n_problems=B, n_spins=N, n_trials=hp.n_trials, hp=hp,
        field_mode="popcount", j_bits=2,
    )
    txt = low.as_text()
    assert f"{B}x{N}x{N}xf32" not in txt
    assert "ui32" in txt or "u32" in txt
