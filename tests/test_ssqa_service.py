"""SSQA through the serving layer (DESIGN.md §13).

Contracts under test:

* ``AnnealRequest(algo='ssqa')`` solves through :class:`AnnealService` on
  all three backends with bit-identical results, and the streaming front
  door returns exactly the one-shot service's answer (the slot splice /
  extract machinery carries the replica axis untouched);
* the registry resolves families by hp type, rejects algo/hp mismatches
  and unknown algos at admission, and keeps the family admission rules
  (PT-SSA×pallas, SSQA×pallas noise) active even with validation off;
* per-request :class:`SolverConfig` redirects a group to another execution
  surface (bit-identity preserved) but may not disagree with the service
  on noise/storage_layout (they key checkpoint fingerprints);
* checkpoint ``group_fingerprint``s distinguish algo and config.
"""
import numpy as np
import pytest

from repro.core import SolverConfig, SSAHyperParams, gset
from repro.core.ssqa import SSQAHyperParams, anneal_ssqa
from repro.serve import (
    AdmissionError,
    AnnealRequest,
    AnnealService,
    family_for,
    registered_algos,
)
from repro.serve.resilience import group_fingerprint

HP = SSQAHyperParams(n_trials=8, n_replicas=4, m_shot=3, tau=4,
                     i0_min=1, i0_max=8)
BACKENDS = ["sparse", "dense", "pallas"]


def _problems():
    return [gset.toroidal_grid(50, seed=17, name="t50"),
            gset.king_graph(49, seed=3, name="k49")]


def _requests(**kw):
    return [AnnealRequest(problem=p, hp=HP, seed=7 + 2 * i, algo="ssqa", **kw)
            for i, p in enumerate(_problems())]


# ---------------------------------------------------------------------------
# Service: backend-invariant, matches the single-problem driver
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", BACKENDS)
def test_service_matches_driver(backend):
    svc = AnnealService(backend=backend, min_bucket=16)
    responses = svc.solve(_requests())
    for i, (p, resp) in enumerate(zip(_problems(), responses)):
        ref = anneal_ssqa(p, HP, seed=7 + 2 * i, track_energy=False,
                          config=SolverConfig())
        np.testing.assert_array_equal(ref.best_energy,
                                      resp.result.best_energy)
        np.testing.assert_array_equal(ref.best_m, resp.result.best_m)
        assert resp.result.best_m.shape == (HP.n_trials, p.n)


def test_mixed_ssa_ssqa_batch_does_not_share_groups():
    """Same bucket, same budget knobs — different families must not share a
    compiled program (their plateau programs differ by the J⊥ ramp)."""
    p = _problems()[0]
    hp_ssa = SSAHyperParams(n_trials=8, m_shot=3, tau=4, i0_min=1, i0_max=8)
    svc = AnnealService(backend="sparse", min_bucket=16)
    k_ssa = svc._group_key(AnnealRequest(problem=p, hp=hp_ssa, seed=7), 64)
    k_ssqa = svc._group_key(AnnealRequest(problem=p, hp=HP, seed=7), 64)
    assert k_ssa[0] == "ssa" and k_ssqa[0] == "ssqa"
    assert k_ssa != k_ssqa
    # and the mixed batch still solves both
    rs = svc.solve([AnnealRequest(problem=p, hp=hp_ssa, seed=7),
                    AnnealRequest(problem=p, hp=HP, seed=7, algo="ssqa")])
    assert all(r.status == "ok" and r.result is not None for r in rs)


def test_per_request_config_redirects_backend():
    """A sparse service can serve an SSQA group on the pallas popcount
    surface via the request's SolverConfig — bit-identically."""
    svc = AnnealService(backend="sparse", min_bucket=16)
    ref = svc.solve(_requests())
    cfg = SolverConfig(backend="pallas", field_mode="popcount",
                       noise_mode="streamed", backend_opts={"j_bits": 2})
    got = svc.solve(_requests(config=cfg))
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(a.result.best_energy,
                                      b.result.best_energy)
        np.testing.assert_array_equal(a.result.best_m, b.result.best_m)


def test_per_request_config_noise_and_layout_must_match_service():
    svc = AnnealService(backend="sparse", min_bucket=16)
    with pytest.raises(AdmissionError, match="noise"):
        svc.solve(_requests(config=SolverConfig(noise="threefry")))
    with pytest.raises(AdmissionError, match="storage_layout"):
        svc.solve(_requests(config=SolverConfig(storage_layout="packed")))


# ---------------------------------------------------------------------------
# Registry: resolution + admission rules
# ---------------------------------------------------------------------------
def test_registry_families():
    algos = registered_algos()
    assert set(algos) >= {"ssa", "sa", "ptssa", "ssqa"}
    # most-specific-type-first: an SSQA hp is also an SSA instance
    assert family_for(HP).name == "ssqa"
    assert family_for(SSAHyperParams(n_trials=4)).name == "ssa"
    assert family_for(HP, algo="ssqa").name == "ssqa"


def test_registry_rejects_mismatch_and_unknown():
    with pytest.raises(AdmissionError, match="does not match"):
        family_for(HP, algo="ssa")
    with pytest.raises(AdmissionError, match="does not match"):
        family_for(SSAHyperParams(n_trials=4), algo="ssqa")
    with pytest.raises(AdmissionError, match="unknown algo"):
        family_for(HP, algo="quantum")
    svc = AnnealService(backend="sparse", min_bucket=16)
    p = _problems()[0]
    with pytest.raises(AdmissionError, match="does not match"):
        svc.solve([AnnealRequest(problem=p, hp=HP, seed=7, algo="ssa")])


def test_ssqa_pallas_noise_rules_fire_even_with_validation_off():
    """Family admission rules are correctness, not hygiene: they apply with
    validate_admission=False too (like the historical PT-SSA×pallas one)."""
    from repro.serve import ResiliencePolicy

    p = _problems()[0]
    svc = AnnealService(
        backend="pallas", noise="threefry", min_bucket=16,
        resilience=ResiliencePolicy(validate_admission=False))
    with pytest.raises(AdmissionError, match="xorshift"):
        svc.solve([AnnealRequest(problem=p, hp=HP, seed=7)])
    svc2 = AnnealService(
        backend="pallas", min_bucket=16,
        backend_opts={"noise_mode": "pregen"},
        resilience=ResiliencePolicy(validate_admission=False))
    with pytest.raises(AdmissionError, match="streamed"):
        svc2.solve([AnnealRequest(problem=p, hp=HP, seed=7)])


# ---------------------------------------------------------------------------
# Streaming front door
# ---------------------------------------------------------------------------
def test_stream_ssqa_matches_one_shot():
    from repro.serve import StreamingAnnealService, StreamPolicy

    one_shot = AnnealService(backend="sparse", min_bucket=16)
    ref = one_shot.solve(_requests())

    ss = StreamingAnnealService(
        backend="sparse", min_bucket=16,
        policy=StreamPolicy(slots_per_table=2))
    ss.start()
    try:
        tickets = [ss.submit(r) for r in _requests()]
        got = [t.result(timeout=None) for t in tickets]
    finally:
        ss.stop()
    for a, b in zip(ref, got):
        assert b.status == "ok"
        np.testing.assert_array_equal(a.result.best_energy,
                                      b.result.best_energy)
        np.testing.assert_array_equal(a.result.best_m, b.result.best_m)


def test_stream_rejects_non_plateau_families():
    from repro.core.sa import SAHyperParams
    from repro.serve import StreamingAnnealService

    ss = StreamingAnnealService(backend="sparse", min_bucket=16)
    ss.start()
    try:
        with pytest.raises(AdmissionError, match="plateau-family"):
            ss.submit(AnnealRequest(
                problem=_problems()[0],
                hp=SAHyperParams(n_trials=4, n_cycles=64), seed=7))
    finally:
        ss.stop()


# ---------------------------------------------------------------------------
# Checkpoint fingerprints
# ---------------------------------------------------------------------------
def test_group_fingerprint_distinguishes_algo_and_config():
    p = _problems()[0]
    model = p.to_ising()

    def fp(req):
        return group_fingerprint("ssqa", 64, "sparse", "dense", "xorshift",
                                 1, [(0, req, p, model)])

    base = AnnealRequest(problem=p, hp=HP, seed=7, algo="ssqa")
    with_cfg = AnnealRequest(problem=p, hp=HP, seed=7, algo="ssqa",
                             config=SolverConfig(backend="dense"))
    no_algo = AnnealRequest(problem=p, hp=HP, seed=7)
    assert fp(base) != fp(with_cfg)
    assert fp(base) != fp(no_algo)
    assert fp(base) == fp(AnnealRequest(problem=p, hp=HP, seed=7,
                                        algo="ssqa"))
