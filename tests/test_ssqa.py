"""SSQA — stochastic simulated quantum annealing (DESIGN.md §13).

Contracts under test:

* the Trotter-replica ring coupling is backend-invariant: sparse, dense,
  pallas (streamed noise), pallas XNOR-popcount and packed-storage runs
  produce bit-identical best states, single-problem and batched (including
  spin-sharded);
* classical runs are untouched: a backend built with ``n_replicas`` set
  executes jperp-free schedules bit-identically to a classical backend;
* the J⊥ ramp rides the schedule and is visible to ``Schedule.signature()``
  (executable-cache soundness);
* the autotuner derives the Trotter dimension and J⊥ ceiling from the
  local-field distribution and rounds ``n_trials`` up to whole rings.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import SSAHyperParams, anneal, gset
from repro.core.autotune import resolve_hyperparams
from repro.core.engine import (
    DenseBackend,
    PallasBackend,
    SparseBackend,
    bucket_n,
    make_batched_backend,
    replica_coupling,
    run_schedule,
    schedule_plateaus,
)
from repro.core.schedule import hassa_schedule, ssqa_schedule
from repro.core.ssqa import SSQAHyperParams, anneal_ssqa

T, R = 8, 4
TORUS = gset.toroidal_grid(50, seed=17)
MODEL = TORUS.to_ising()
SCHED = ssqa_schedule(1, 8, tau=4, jperp_max=3)
PLATEAUS = schedule_plateaus(SCHED, "i0max")

SINGLE_BACKENDS = {
    "sparse": lambda: SparseBackend(
        MODEL, n_trials=T, n_rnd=2, noise="xorshift", n_replicas=R),
    "dense": lambda: DenseBackend(
        MODEL, n_trials=T, n_rnd=2, noise="xorshift", n_replicas=R),
    "pallas": lambda: PallasBackend(
        MODEL, n_trials=T, n_rnd=2, noise="xorshift",
        noise_mode="streamed", n_replicas=R),
    "pallas-popcount": lambda: PallasBackend(
        MODEL, n_trials=T, n_rnd=2, noise="xorshift",
        noise_mode="streamed", field_mode="popcount", n_replicas=R),
    "sparse-packed": lambda: SparseBackend(
        MODEL, n_trials=T, n_rnd=2, noise="xorshift",
        storage_layout="packed", n_replicas=R),
}


def _run_single(mk):
    bk = mk()
    st = bk.init_state(seed=7)
    for _ in range(3):
        st, _, _ = run_schedule(bk, PLATEAUS, st)
    bh, bm = bk.finalize(st)
    return np.asarray(bh), np.asarray(bm)


# ---------------------------------------------------------------------------
# Bit-identity across backends and field modes
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", [k for k in SINGLE_BACKENDS if k != "sparse"])
def test_single_problem_backends_bit_identical(name):
    ref_h, ref_m = _run_single(SINGLE_BACKENDS["sparse"])
    bh, bm = _run_single(SINGLE_BACKENDS[name])
    np.testing.assert_array_equal(ref_h, bh)
    np.testing.assert_array_equal(ref_m, bm)


@pytest.mark.parametrize("name,kw", [
    ("b-dense", dict(backend="dense")),
    ("b-pallas", dict(backend="pallas", noise_mode="streamed")),
    ("b-pallas-pc", dict(backend="pallas", noise_mode="streamed",
                         field_mode="popcount", j_bits=2)),
    ("b-sparse-packed", dict(backend="sparse", storage_layout="packed")),
    ("b-spin", dict(backend="dense", partition="spin")),
])
def test_batched_backends_bit_identical(name, kw):
    """Batched SSQA (the service's execution shape): the replica axis rides
    the trial axis through stacking and padding untouched."""
    models = [MODEL, gset.king_graph(49, seed=3).to_ising()]
    nb = max(bucket_n(m.n) for m in models)

    def run(backend, **opts):
        bk = make_batched_backend(
            backend, n_bucket=nb, n_trials=T, n_rnd=2,
            noise="xorshift", n_replicas=R, **opts)
        problem = bk.stack(models)
        st = bk.init_state(problem, bk.init_noise([7, 9], [m.n for m in models]))
        st = bk.run_shots(problem, st, PLATEAUS, 3)
        bh, bm = bk.finalize(st)
        return np.asarray(bh), np.asarray(bm)

    ref_h, ref_m = run("sparse")
    kw = dict(kw)
    bh, bm = run(kw.pop("backend"), **kw)
    np.testing.assert_array_equal(ref_h, bh)
    np.testing.assert_array_equal(ref_m, bm)


def test_classical_schedule_unchanged_by_replica_backend():
    """jperp=0 disables the coupling entirely: a backend carrying
    n_replicas runs classical plateau programs bit-identically."""
    cplat = schedule_plateaus(hassa_schedule(1, 8, tau=4), "i0max")
    bk0 = SparseBackend(MODEL, n_trials=T, n_rnd=2, noise="xorshift")
    bkr = SparseBackend(MODEL, n_trials=T, n_rnd=2, noise="xorshift",
                        n_replicas=R)
    s0, sr = bk0.init_state(seed=7), bkr.init_state(seed=7)
    s0, _, _ = run_schedule(bk0, cplat, s0)
    sr, _, _ = run_schedule(bkr, cplat, sr)
    np.testing.assert_array_equal(np.asarray(bk0.finalize(s0)[0]),
                                  np.asarray(bkr.finalize(sr)[0]))
    np.testing.assert_array_equal(np.asarray(bk0.finalize(s0)[1]),
                                  np.asarray(bkr.finalize(sr)[1]))


def test_coupling_changes_the_dynamics():
    """Sanity: on the coupled schedule SSQA is not SSA in disguise."""
    bh_q, _ = _run_single(SINGLE_BACKENDS["sparse"])
    bk = SparseBackend(MODEL, n_trials=T, n_rnd=2, noise="xorshift")
    st = bk.init_state(seed=7)
    for _ in range(3):
        st, _, _ = run_schedule(bk, PLATEAUS, st)
    bh_c = np.asarray(bk.finalize(st)[0])
    assert not np.array_equal(bh_q, bh_c)


def test_replica_coupling_ring_topology():
    """m[k-1] + m[k+1] over G independent rings of R consecutive trials."""
    rng = np.random.default_rng(0)
    m = rng.choice(np.asarray([-1, 1], np.int8), size=(8, 5))
    nb = np.asarray(replica_coupling(m, 4))
    for g in range(2):
        ring = m[4 * g:4 * (g + 1)].astype(np.int32)
        for k in range(4):
            np.testing.assert_array_equal(
                nb[4 * g + k], ring[(k - 1) % 4] + ring[(k + 1) % 4])


# ---------------------------------------------------------------------------
# Schedule: the J⊥ ramp and its signature
# ---------------------------------------------------------------------------
def test_ssqa_schedule_ramp_shape():
    s = ssqa_schedule(1, 8, tau=4, jperp_max=3)
    jp = np.asarray(s.jperp_per_cycle)
    assert jp.shape == s.i0_per_cycle.shape
    assert jp[0] == 0                       # hottest plateau: free replicas
    assert jp[-1] == 3                      # coldest plateau: J⊥ = jperp_max
    assert (np.diff(jp) >= 0).all()         # monotone ramp
    # per-plateau constant (held over each tau-cycle plateau)
    assert (jp.reshape(s.steps, s.tau) == jp.reshape(s.steps, s.tau)[:, :1]).all()


def test_ssqa_schedule_signature_distinct():
    base = hassa_schedule(1, 8, 4)
    q = ssqa_schedule(1, 8, 4, jperp_max=3)
    np.testing.assert_array_equal(base.i0_per_cycle, q.i0_per_cycle)
    assert q.signature() != base.signature()           # J⊥ ramp is visible
    assert (ssqa_schedule(1, 8, 4, jperp_max=4).signature()
            != q.signature())                          # and so is its height
    # a jperp-free Schedule hashes to the historical v1 payload
    stripped = dataclasses.replace(q, jperp_per_cycle=None)
    assert stripped.signature() == base.signature()


def test_plateaus_carry_jperp():
    by_i0 = {p.i0: p.jperp for p in PLATEAUS}
    assert by_i0[1] == 0 and by_i0[8] == 3
    assert all(p.jperp == 0 for p in schedule_plateaus(hassa_schedule(1, 8, 4)))


# ---------------------------------------------------------------------------
# Hyper-parameters, driver entry point, autotune
# ---------------------------------------------------------------------------
def test_hp_validation():
    with pytest.raises(ValueError, match="n_replicas"):
        SSQAHyperParams(n_trials=8, n_replicas=1)
    with pytest.raises(ValueError, match="divisible"):
        SSQAHyperParams(n_trials=10, n_replicas=4)
    with pytest.raises(ValueError, match="jperp_max"):
        SSQAHyperParams(n_trials=8, n_replicas=4, jperp_max=-1)
    with pytest.raises(ValueError, match="schedule_kind"):
        SSQAHyperParams(n_trials=8, n_replicas=4).schedule("ssa")


def test_anneal_ssqa_matches_anneal_with_ssqa_hp():
    hp = SSQAHyperParams(n_trials=T, n_replicas=R, m_shot=2, tau=4, i0_max=8)
    r1 = anneal_ssqa(TORUS, hp, seed=5, track_energy=False)
    r2 = anneal(TORUS, hp, seed=5, track_energy=False)
    np.testing.assert_array_equal(r1.best_energy, r2.best_energy)
    np.testing.assert_array_equal(r1.best_m, r2.best_m)
    assert r1.best_m.shape == (T, TORUS.n)  # every replica is a candidate


def test_autotune_derives_trotter_knobs():
    """torus σ≈2 → R = next_pow2(4σ) = 8, J⊥max = 2σ = 4 (the defaults),
    and n_trials rounds up to whole rings."""
    hp, report = resolve_hyperparams(
        "auto", TORUS, base=SSQAHyperParams(n_trials=10, n_replicas=2),
        algo="ssqa")
    assert isinstance(hp, SSQAHyperParams)
    assert hp.n_replicas == 8 and hp.jperp_max == 4
    assert hp.n_trials == 16                 # 10 → next multiple of 8
    assert report.n_replicas == 8 and report.jperp_max == 4


def test_autotune_algo_ssqa_defaults_base():
    hp, _ = resolve_hyperparams("auto", TORUS, algo="ssqa")
    assert isinstance(hp, SSQAHyperParams)
    assert hp.n_trials % hp.n_replicas == 0


def test_autotune_classical_base_untouched():
    hp, report = resolve_hyperparams(
        "auto", TORUS, base=SSAHyperParams(n_trials=10))
    assert not isinstance(hp, SSQAHyperParams)
    assert hp.n_trials == 10
    assert report.n_replicas is None and report.jperp_max is None
