"""MoE routing tests: capacity semantics, impl equivalence, balance loss."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.models import ModelConfig
from repro.models.moe import _dispatch_combine, _top_k_mask, moe_defs, moe_ffn
from repro.models.params import init_params

CFG = ModelConfig(name="m", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                  d_head=16, d_ff=64, vocab=53, block=(("attn", "moe"),),
                  n_experts=8, top_k=2, capacity_factor=1.5, remat="none",
                  moe_seq_chunk=8)


def _params(key=0):
    return init_params({"m": moe_defs(CFG)}, jax.random.PRNGKey(key))["m"]


@given(st.integers(0, 10**6), st.integers(1, 4))
@settings(max_examples=25, deadline=None)
def test_top_k_mask_selects_distinct_max(seed, k):
    rng = np.random.default_rng(seed)
    probs = jnp.asarray(jax.nn.softmax(jnp.asarray(rng.normal(size=(2, 5, 8))), -1))
    gates, onehot = _top_k_mask(probs, k)
    oh = np.asarray(onehot)
    # each choice picks exactly one expert; choices are distinct
    assert np.all(oh.sum(-1) == 1)
    picked = oh.argmax(-1)
    for b in range(2):
        for t in range(5):
            assert len(set(picked[b, t])) == k
    # gates are the picked probabilities, descending
    g = np.asarray(gates)
    assert np.all(np.diff(g, axis=-1) <= 1e-6)


def test_capacity_drops_overflow():
    # all tokens pick expert 0 → only `cap` of them keep nonzero weight
    probs = jnp.zeros((1, 6, 4)).at[:, :, 0].set(0.97).at[:, :, 1:].set(0.01)
    combine, _ = _dispatch_combine(probs, k=1, cap=2)
    kept = np.asarray((combine > 0).sum(axis=(2, 3)))[0]
    assert kept.sum() == 2  # 2 kept, 4 dropped


def test_einsum_gather_equivalence():
    cfg_g = dataclasses.replace(CFG, moe_impl="gather")
    p = _params()
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 16, 32)) * 0.5
    ye, ae = moe_ffn(p, x, CFG)
    yg, ag = moe_ffn(p, x, cfg_g)
    assert float(ae) == float(ag)  # identical routing decisions
    a, b = np.asarray(ye, np.float32), np.asarray(yg, np.float32)
    scale = max(np.abs(a).max(), 1.0)
    assert np.abs(a - b).max() / scale < 0.02  # bf16 accumulation-order noise


def test_einsum_gather_equivalence_decode():
    cfg_g = dataclasses.replace(CFG, moe_impl="gather")
    p = _params()
    x = jax.random.normal(jax.random.PRNGKey(2), (5, 1, 32)) * 0.5
    ye, _ = moe_ffn(p, x, CFG)
    yg, _ = moe_ffn(p, x, cfg_g)
    a, b = np.asarray(ye, np.float32), np.asarray(yg, np.float32)
    assert np.abs(a - b).max() / max(np.abs(a).max(), 1.0) < 0.02


def test_chunked_equals_unchunked():
    p = _params()
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 16, 32)) * 0.5
    cfg_1 = dataclasses.replace(CFG, moe_seq_chunk=16)   # single chunk
    cfg_8 = dataclasses.replace(CFG, moe_seq_chunk=8)    # two chunks
    y1, _ = moe_ffn(p, x, cfg_1)
    y8, _ = moe_ffn(p, x, cfg_8)
    # chunking changes capacity groups → results differ ONLY via dropping;
    # with generous capacity they agree
    cfg_1b = dataclasses.replace(cfg_1, capacity_factor=8.0)
    cfg_8b = dataclasses.replace(cfg_8, capacity_factor=8.0)
    y1b, _ = moe_ffn(p, x, cfg_1b)
    y8b, _ = moe_ffn(p, x, cfg_8b)
    a, b = np.asarray(y1b, np.float32), np.asarray(y8b, np.float32)
    assert np.abs(a - b).max() / max(np.abs(a).max(), 1.0) < 0.02


def test_unrolled_chunks_match_scanned():
    """The analysis lowering's unrolled chunk loop is numerically the scan."""
    p = _params()
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 16, 32)) * 0.5
    y_scan, a_scan = moe_ffn(p, x, CFG)
    cfg_u = dataclasses.replace(CFG, scan_layers=False)
    y_unr, a_unr = moe_ffn(p, x, cfg_u)
    np.testing.assert_allclose(np.asarray(y_scan, np.float32),
                               np.asarray(y_unr, np.float32), atol=1e-3)
    np.testing.assert_allclose(float(a_scan), float(a_unr), rtol=1e-5)


def test_aux_loss_balanced_router_is_one():
    """Uniform routing gives aux ≈ 1 (E · Σ (1/E)·(1/E) · E = 1)."""
    p = _params()
    # force uniform router by zeroing its weights
    p = dict(p)
    p["router"] = jnp.zeros_like(p["router"])
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 16, 32))
    _, aux = moe_ffn(p, x, CFG)
    assert abs(float(aux) - 1.0) < 0.05
