"""Plateau-engine backend equivalence (DESIGN.md §2).

The engine's contract: `sparse`, `dense` and `pallas` (interpret mode on
CPU) backends driven by the same xorshift noise stream produce
**bit-identical** spin trajectories and best-cut results.  The update math
is integer-valued throughout and the dense/Pallas float32 accumulations are
exact below 2^24, so equality is exact, not approximate.
"""
import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import SSAHyperParams, anneal, fig4_example, gset, make_backend
from repro.core.engine import (
    Plateau,
    schedule_plateaus,
    tile_plateaus,
)

BACKENDS = ["sparse", "dense", "pallas"]


def _gset_twin():
    """A small structure-faithful G-set twin (4-regular torus, ±1 weights)."""
    return gset.toroidal_grid(64, seed=17)


# ---------------------------------------------------------------------------
# Plateau grouping: the schedule's structural view
# ---------------------------------------------------------------------------
def test_schedule_plateaus_grouping():
    hp = SSAHyperParams(i0_min=1, i0_max=8, tau=5)
    ps = schedule_plateaus(hp.schedule("hassa"), "i0max")
    assert [p.i0 for p in ps] == [1, 2, 4, 8]
    assert all(p.length == 5 for p in ps)
    # HA-SSA's write-enable: only the I0max plateau is storage-eligible
    assert [p.eligible for p in ps] == [False, False, False, True]
    ps_all = schedule_plateaus(hp.schedule("hassa"), "all")
    assert all(p.eligible for p in ps_all)


def test_tile_plateaus_truncates():
    ps = (Plateau(1, 5, False), Plateau(2, 5, True))
    seq = tile_plateaus(ps, 23)
    assert sum(p.length for p in seq) == 23
    # 2 full iterations (10+10) + 3 cycles into the third
    assert [p.length for p in seq] == [5, 5, 5, 5, 3]
    assert seq[-1] == Plateau(1, 3, False)


# ---------------------------------------------------------------------------
# The acceptance property: bit-identical trajectories and best cuts
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("problem_fn", [fig4_example, _gset_twin])
@pytest.mark.parametrize("backend", ["dense", "pallas"])
def test_traj_bitwise_equal_across_backends(problem_fn, backend):
    p = problem_fn()
    hp = SSAHyperParams(n_trials=3, m_shot=2, tau=4, i0_min=1, i0_max=8)
    ref = anneal(p, hp, seed=5, record="traj", noise="xorshift", backend="sparse")
    out = anneal(p, hp, seed=5, record="traj", noise="xorshift", backend=backend)
    np.testing.assert_array_equal(ref.traj, out.traj)
    np.testing.assert_array_equal(ref.best_cut, out.best_cut)
    np.testing.assert_array_equal(ref.best_m, out.best_m)


@pytest.mark.parametrize("problem_fn", [fig4_example, _gset_twin])
@pytest.mark.parametrize("storage", ["i0max", "all"])
@pytest.mark.parametrize("backend", ["dense", "pallas"])
def test_best_bitwise_equal_across_backends(problem_fn, storage, backend):
    """record='best' (the production path; pallas runs the resident kernel)."""
    p = problem_fn()
    hp = SSAHyperParams(n_trials=3, m_shot=2, tau=4, i0_min=1, i0_max=8)
    kw = dict(seed=3, record="best", noise="xorshift", storage=storage,
              track_energy=False)
    ref = anneal(p, hp, backend="sparse", **kw)
    out = anneal(p, hp, backend=backend, **kw)
    np.testing.assert_array_equal(ref.best_energy, out.best_energy)
    np.testing.assert_array_equal(ref.best_cut, out.best_cut)
    np.testing.assert_array_equal(ref.best_m, out.best_m)


@given(st.integers(0, 10_000))
@settings(max_examples=5, deadline=None)
def test_backend_equivalence_property(seed):
    """Property form over random seeds: all three backends, same stream."""
    p = _gset_twin()
    hp = SSAHyperParams(n_trials=2, m_shot=2, tau=3, i0_min=1, i0_max=4)
    runs = [
        anneal(p, hp, seed=seed, record="traj", noise="xorshift", backend=b)
        for b in BACKENDS
    ]
    for other in runs[1:]:
        np.testing.assert_array_equal(runs[0].traj, other.traj)
        np.testing.assert_array_equal(runs[0].best_cut, other.best_cut)


def test_energy_trace_equal_across_jnp_backends():
    """Per-cycle energy traces (one field contraction per cycle) agree."""
    p = _gset_twin()
    hp = SSAHyperParams(n_trials=3, m_shot=2, tau=4, i0_min=1, i0_max=8)
    rs = anneal(p, hp, seed=1, noise="xorshift", backend="sparse")
    rd = anneal(p, hp, seed=1, noise="xorshift", backend="dense")
    assert rs.energy_mean.shape == (hp.total_cycles,)
    np.testing.assert_array_equal(rs.energy_mean, rd.energy_mean)
    np.testing.assert_array_equal(rs.energy_min, rd.energy_min)


# ---------------------------------------------------------------------------
# The pallas backend is *resident*: one pallas_call per plateau, not per cycle
# ---------------------------------------------------------------------------
def _count_primitive(jaxpr, name: str) -> int:
    count = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == name:
            count += 1
        for v in eqn.params.values():
            sub = getattr(v, "jaxpr", None)
            if sub is not None:
                count += _count_primitive(sub, name)
            elif isinstance(v, (list, tuple)):
                for vv in v:
                    sub = getattr(vv, "jaxpr", None)
                    if sub is not None:
                        count += _count_primitive(sub, name)
    return count


def test_pallas_backend_one_call_per_plateau():
    p = _gset_twin()
    model = p.to_ising()
    hp = SSAHyperParams(n_trials=2, m_shot=3, tau=4, i0_min=1, i0_max=8)
    bk = make_backend("pallas", model, n_trials=hp.n_trials, n_rnd=hp.n_rnd,
                      noise="xorshift")
    state = bk.init_state(0)

    jaxpr = jax.make_jaxpr(
        lambda st: bk.run_plateau(st, 8, length=hp.tau, eligible=True)[0]
    )(state)
    assert _count_primitive(jaxpr.jaxpr, "pallas_call") == 1

    from repro.core.engine import run_schedule, schedule_plateaus

    plateaus = schedule_plateaus(hp.schedule("hassa"), "i0max")
    jaxpr = jax.make_jaxpr(
        lambda st: run_schedule(bk, plateaus, st, record="best")[0]
    )(state)
    assert _count_primitive(jaxpr.jaxpr, "pallas_call") == len(plateaus) == hp.steps


def test_backend_factory_accepts_instances_and_classes():
    from repro.core.engine import DenseBackend

    model = fig4_example().to_ising()
    bk = make_backend("dense", model, n_trials=2)
    assert isinstance(bk, DenseBackend)
    assert make_backend(bk, model, n_trials=2) is bk
    bk2 = make_backend(DenseBackend, model, n_trials=2)
    assert isinstance(bk2, DenseBackend)
    with pytest.raises(ValueError):
        make_backend("no-such-backend", model, n_trials=2)
