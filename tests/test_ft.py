"""Fault-tolerance tests: checkpoint/restart bit-exactness, straggler
detection, checkpoint atomicity/GC, elastic re-mesh of state."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import CheckpointManager, latest_step, restore, save
from repro.data.pipeline import DataConfig, synthetic_batch
from repro.ft.resilience import SimulatedFailure, StragglerMonitor, remesh, run_training
from repro.models import ModelConfig
from repro.optim.adamw import AdamWConfig
from repro.train.step import TrainConfig, init_train_state, make_train_step

CFG = ModelConfig(name="tiny", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                  d_head=16, d_ff=64, vocab=53, remat="none")
TC = TrainConfig(opt=AdamWConfig(lr_peak=1e-2, warmup_steps=2, total_steps=40),
                 loss_chunk=8)
DC = DataConfig(vocab=53, seq_len=16, global_batch=4, seed=0)


def _setup(tmp_path, save_interval=5):
    step = jax.jit(make_train_step(CFG, TC))
    ckpt = CheckpointManager(str(tmp_path / "ckpt"), save_interval=save_interval,
                             keep=2, async_save=False)
    kw = dict(
        init_state_fn=lambda: init_train_state(CFG, TC, jax.random.PRNGKey(0)),
        train_step=step,
        batch_fn=lambda s: synthetic_batch(DC, s),
        ckpt=ckpt,
    )
    return kw, ckpt


def test_restart_resumes_bit_exact(tmp_path):
    """Kill training mid-run; resuming reproduces the uninterrupted losses."""
    kw, _ = _setup(tmp_path)
    # uninterrupted reference
    ref_kw, _ = _setup(tmp_path / "ref")
    _, ref_losses = run_training(n_steps=20, **ref_kw)

    # interrupted at step 13 (after the step-10 checkpoint)
    with pytest.raises(SimulatedFailure):
        run_training(n_steps=20, fail_at_step=13, **kw)
    assert latest_step(str(tmp_path / "ckpt")) == 10
    # restart: replays steps 10..20 from the checkpoint
    _, resumed = run_training(n_steps=20, **kw)
    np.testing.assert_allclose(resumed, ref_losses[10:20], rtol=1e-6)


def test_checkpoint_atomic_and_gc(tmp_path):
    d = str(tmp_path)
    tree = {"a": jnp.arange(4.0), "b": {"c": jnp.ones((2, 2))}}
    for s in (5, 10, 15, 20):
        save(d, s, tree, meta={"x": s})
    mgr = CheckpointManager(d, save_interval=5, keep=2, async_save=False)
    mgr._gc()
    steps = sorted(int(f.split("_")[1].split(".")[0])
                   for f in os.listdir(d) if f.endswith(".npz"))
    assert steps == [15, 20]
    got, meta = restore(d, tree)
    assert meta["step"] == 20
    np.testing.assert_array_equal(np.asarray(got["a"]), np.arange(4.0))


def test_async_save_consistent(tmp_path):
    d = str(tmp_path)
    mgr = CheckpointManager(d, save_interval=1, keep=3, async_save=True)
    tree = {"w": jnp.arange(8.0)}
    mgr.maybe_save(1, tree)
    mgr.wait()
    got, meta = restore(d, tree)
    np.testing.assert_array_equal(np.asarray(got["w"]), np.arange(8.0))


def test_straggler_monitor_flags_slow_host():
    mon = StragglerMonitor(n_hosts=8, threshold=1.5, warmup_steps=3)
    for step in range(10):
        for h in range(8):
            mon.record(h, 1.0 if h != 5 else 3.0)  # host 5 is 3× slower
    assert mon.stragglers() == [5]


def test_straggler_monitor_quiet_when_uniform():
    mon = StragglerMonitor(n_hosts=4)
    for step in range(10):
        for h in range(4):
            mon.record(h, 1.0 + 0.01 * h)
    assert mon.stragglers() == []


def test_remesh_roundtrip():
    """Elastic re-mesh: state moves to new shardings without value change."""
    state = init_train_state(CFG, TC, jax.random.PRNGKey(0))
    # 'new mesh' = single device here; shardings_fn maps every leaf to the
    # default device sharding (the mechanism under test is the tree move)
    dev = jax.devices()[0]
    moved = remesh(state.params,
                   lambda tree: jax.tree_util.tree_map(lambda _: dev, tree))
    same = jax.tree_util.tree_map(
        lambda a, b: bool(jnp.all(a == b)), state.params, moved)
    assert all(jax.tree_util.tree_leaves(same))


def test_straggler_monitor_single_host_never_flags():
    """A single-host fleet has no peers: it is its own median, never a
    straggler — even with wildly varying step times."""
    mon = StragglerMonitor(n_hosts=1, threshold=1.5, warmup_steps=3)
    for t in (0.1, 5.0, 0.1, 40.0, 0.1):
        mon.record(0, t)
    assert mon.stragglers() == []


def test_straggler_monitor_warmup_boundary():
    """Hosts below warmup_steps are excluded from both flagging and the
    fleet median; flagging starts exactly at the warmup_steps-th record."""
    mon = StragglerMonitor(n_hosts=3, threshold=1.5, warmup_steps=3)
    # Slow host 2 has only 2 records: not ready, must not be flagged, and
    # must not drag the median for the others.
    for step in range(3):
        mon.record(0, 1.0)
        mon.record(1, 1.0)
    for step in range(2):
        mon.record(2, 50.0)
    assert mon.stragglers() == []
    # The 3rd record crosses the warmup boundary: now it flags.
    mon.record(2, 50.0)
    assert mon.stragglers() == [2]


def test_straggler_monitor_no_ready_hosts():
    mon = StragglerMonitor(n_hosts=4, warmup_steps=5)
    for h in range(4):
        mon.record(h, 1.0)
    assert mon.stragglers() == []


def test_remesh_single_device_mesh_namedsharding():
    """Elastic re-mesh onto a 1-device mesh (the post-pod-loss floor):
    NamedShardings from a Mesh of one device, values unchanged."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    state = init_train_state(CFG, TC, jax.random.PRNGKey(0))
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    shard = NamedSharding(mesh, P())  # fully replicated on the 1-device mesh
    moved = remesh(state.params,
                   lambda tree: jax.tree_util.tree_map(lambda _: shard, tree))
    same = jax.tree_util.tree_map(
        lambda a, b: bool(jnp.all(a == b)), state.params, moved)
    assert all(jax.tree_util.tree_leaves(same))
    for leaf in jax.tree_util.tree_leaves(moved):
        assert leaf.sharding.mesh.devices.size == 1
