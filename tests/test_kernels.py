"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles.

All assertions are exact equality (the math is integer-valued by
construction; MXU accumulation is f32 — see DESIGN.md §2).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SSAHyperParams, anneal, gset
from repro.kernels import ops, ref, ssa_update


def _dense_problem(n, seed=0):
    g = gset.king_graph(n, seed=seed)
    model = g.to_ising()
    return g, model, model.dense_J()


# ---------------------------------------------------------------------------
# Kernel A: local_field
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("r", [1, 3, 8, 17])
@pytest.mark.parametrize("n", [16, 36, 100])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_local_field_sweep(r, n, dtype):
    rng = np.random.default_rng(r * 1000 + n)
    J = rng.integers(-3, 4, size=(n, n))
    J = np.triu(J, 1)
    J = J + J.T
    h = rng.integers(-4, 5, size=(n,))
    m = rng.choice([-1.0, 1.0], size=(r, n)).astype(np.float32)
    out_k = ssa_update.local_field(
        jnp.asarray(m), jnp.asarray(h, jnp.int32), jnp.asarray(J, dtype),
        block_r=4, block_n=32, block_k=32,
    )
    out_r = ref.local_field_ref(jnp.asarray(m), jnp.asarray(h), jnp.asarray(J, jnp.float32))
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))


@pytest.mark.parametrize("blocks", [(2, 16, 16), (4, 32, 64), (8, 128, 128)])
def test_local_field_block_shapes(blocks):
    br, bn, bk = blocks
    _, model, J = _dense_problem(64, seed=1)
    rng = np.random.default_rng(0)
    m = rng.choice([-1.0, 1.0], size=(10, 64)).astype(np.float32)
    out_k = ssa_update.local_field(
        jnp.asarray(m), jnp.asarray(model.h), jnp.asarray(J, jnp.float32),
        block_r=br, block_n=bn, block_k=bk,
    )
    out_r = ref.local_field_ref(jnp.asarray(m), jnp.asarray(model.h), jnp.asarray(J, jnp.float32))
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))


# ---------------------------------------------------------------------------
# Kernel B: resident plateau
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("r,n,c", [(2, 16, 3), (5, 36, 7), (9, 64, 4)])
@pytest.mark.parametrize("eligible", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_plateau_sweep(r, n, c, eligible, dtype):
    rng = np.random.default_rng(r + n + c)
    _, model, J = _dense_problem(n, seed=n)
    m = jnp.asarray(rng.choice([-1.0, 1.0], size=(r, n)).astype(np.float32))
    itanh = jnp.asarray(rng.integers(-4, 4, size=(r, n)), jnp.int32)
    noise = jnp.asarray(rng.choice([-1, 1], size=(c, r, n)).astype(np.int8))
    bH = jnp.full((r,), 2**30, jnp.int32)
    bm = m.astype(jnp.int8)
    h = jnp.asarray(model.h, jnp.int32)
    Jd = jnp.asarray(J, dtype)
    out_k = ssa_update.ssa_plateau(
        m, itanh, Jd, h, noise, jnp.int32(8), bH, bm,
        n_rnd=2, eligible=eligible, block_r=4,
    )
    out_r = ref.ssa_plateau_ref(
        m, itanh, jnp.asarray(J, jnp.float32), h, noise, 8, bH, bm,
        n_rnd=2, eligible=eligible,
    )
    for a, b, name in zip(out_k, out_r, ["m", "itanh", "best_H", "best_m"]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=name)


@pytest.mark.parametrize("eligible", [True, False])
def test_plateau_batched_matches_per_problem(eligible):
    """The (B, R-tile)-grid batched kernel == B independent B=1 launches."""
    rng = np.random.default_rng(7)
    B, r, n, c = 3, 4, 36, 5
    Js, hs = [], []
    for b in range(B):
        _, model, J = _dense_problem(n, seed=10 + b)
        Js.append(np.asarray(J, np.float32))
        hs.append(np.asarray(model.h, np.int32))
    J = jnp.asarray(np.stack(Js))
    h = jnp.asarray(np.stack(hs))
    m = jnp.asarray(rng.choice([-1.0, 1.0], size=(B, r, n)).astype(np.float32))
    itanh = jnp.asarray(rng.integers(-4, 4, size=(B, r, n)), jnp.int32)
    noise = jnp.asarray(rng.choice([-1, 1], size=(B, c, r, n)).astype(np.int8))
    bH = jnp.full((B, r), 2**30, jnp.int32)
    bm = m.astype(jnp.int8)
    out_b = ssa_update.ssa_plateau_batched(
        m, itanh, J, h, noise, jnp.int32(8), bH, bm,
        n_rnd=2, eligible=eligible, block_r=4,
    )
    for b in range(B):
        out_1 = ssa_update.ssa_plateau(
            m[b], itanh[b], J[b], h[b], noise[b], jnp.int32(8), bH[b], bm[b],
            n_rnd=2, eligible=eligible, block_r=4,
        )
        for a, o, name in zip(out_b, out_1, ["m", "itanh", "best_H", "best_m"]):
            np.testing.assert_array_equal(
                np.asarray(a[b]), np.asarray(o), err_msg=f"problem {b}: {name}"
            )


def test_plateau_chain_matches_ref_chain():
    """Chaining plateaus (heat→cold) through the kernel == chained oracle."""
    rng = np.random.default_rng(3)
    _, model, J = _dense_problem(36, seed=2)
    r, n = 4, 36
    m = jnp.asarray(rng.choice([-1.0, 1.0], size=(r, n)).astype(np.float32))
    it = jnp.where(m > 0, 0, -1).astype(jnp.int32)
    bH = jnp.full((r,), 2**30, jnp.int32)
    bm = m.astype(jnp.int8)
    h = jnp.asarray(model.h, jnp.int32)
    Jf = jnp.asarray(J, jnp.float32)
    state_k = (m, it, bH, bm)
    state_r = (m, it, bH, bm)
    for i0, elig in [(1, False), (2, False), (4, True)]:
        noise = jnp.asarray(rng.choice([-1, 1], size=(5, r, n)).astype(np.int8))
        state_k = ssa_update.ssa_plateau(
            state_k[0], state_k[1], Jf, h, noise, jnp.int32(i0),
            state_k[2], state_k[3], n_rnd=2, eligible=elig, block_r=4,
        )
        state_r = ref.ssa_plateau_ref(
            state_r[0], state_r[1], Jf, h, noise, i0,
            state_r[2], state_r[3], n_rnd=2, eligible=elig,
        )
    for a, b in zip(state_k, state_r):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# End-to-end: resident-kernel annealer ≡ core annealer (same noise stream)
# ---------------------------------------------------------------------------
def test_anneal_resident_matches_core():
    g = gset.king_graph(36, seed=5)
    model = g.to_ising()
    hp = SSAHyperParams(n_trials=4, m_shot=3, tau=5, i0_min=1, i0_max=8)
    r_core = anneal(
        g, hp, seed=9, storage="i0max", record="best", noise="xorshift",
        backend="dense", track_energy=False,
    )
    best_H, best_m = ops.anneal_resident(
        jnp.asarray(model.dense_J(), jnp.float32),
        jnp.asarray(model.h, jnp.int32),
        hp.schedule("hassa"),
        m_shot=hp.m_shot,
        n_trials=hp.n_trials,
        n_rnd=hp.n_rnd,
        storage="i0max",
        seed=9,
        block_r=4,
    )
    np.testing.assert_array_equal(best_H, r_core.best_energy)


def test_anneal_resident_ssa_policy_not_worse():
    """'all' policy sees a superset of states, so its best is <= HA-SSA's."""
    g = gset.king_graph(36, seed=6)
    model = g.to_ising()
    hp = SSAHyperParams(n_trials=4, m_shot=3, tau=5, i0_min=1, i0_max=8)
    args = (
        jnp.asarray(model.dense_J(), jnp.float32),
        jnp.asarray(model.h, jnp.int32),
        hp.schedule("hassa"),
    )
    kw = dict(m_shot=hp.m_shot, n_trials=hp.n_trials, n_rnd=hp.n_rnd, seed=4, block_r=4)
    bh_ha, _ = ops.anneal_resident(*args, storage="i0max", **kw)
    bh_ssa, _ = ops.anneal_resident(*args, storage="all", **kw)
    assert np.all(bh_ssa <= bh_ha)


def test_core_pallas_backend():
    """repro.core.ssa backend='pallas' bit-matches the sparse backend."""
    g = gset.king_graph(36, seed=5)
    hp = SSAHyperParams(n_trials=2, m_shot=2, tau=4, i0_min=1, i0_max=4)
    rs = anneal(g, hp, seed=2, record="traj", noise="xorshift", backend="sparse")
    rp = anneal(g, hp, seed=2, record="traj", noise="xorshift", backend="pallas")
    np.testing.assert_array_equal(rs.traj, rp.traj)
