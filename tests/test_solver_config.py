"""SolverConfig — the consolidated typed option surface (DESIGN.md §13).

Contracts under test:

* ``signature()`` is injective over the option grid (distinct configs never
  collide; equal configs always do) and stable across construction spelling;
* the legacy-kwarg shim produces bit-identical results to the typed config,
  warns ``DeprecationWarning`` exactly once per site, and rejects mixing;
* a service built from a config and one built from the historical kwargs
  populate the executable cache with IDENTICAL keys (no silent recompiles
  when callers migrate);
* ``engine_opts()`` only emits per-backend-family knobs the configured
  backend accepts.
"""
import itertools
import warnings

import numpy as np
import pytest

from repro.core import SolverConfig, SSAHyperParams, anneal, gset
from repro.core import config as config_mod
from repro.core.config import legacy_kwargs_to_config
from repro.serve import AnnealRequest, AnnealService

TORUS = gset.toroidal_grid(50, seed=17)
HP = SSAHyperParams(n_trials=4, m_shot=2, tau=4, i0_min=1, i0_max=8)


def _grid():
    """A deliberately overlapping sample of the option space."""
    cfgs = [
        SolverConfig(backend=b, storage_layout=sl, noise=n)
        for b, sl, n in itertools.product(
            ("sparse", "dense", "pallas"), ("dense", "packed"),
            ("xorshift", "threefry"))
    ]
    cfgs += [SolverConfig(backend="dense", field_mode=fm)
             for fm in ("auto", "dense", "popcount")]
    cfgs += [SolverConfig(backend="dense", j_mode=jm)
             for jm in ("auto", "dense", "tiled")]
    cfgs += [SolverConfig(backend="pallas", noise_mode=nm)
             for nm in ("auto", "pregen", "streamed")]
    cfgs += [
        SolverConfig(partition="spin"),
        SolverConfig(backend_opts={"n_replicas": 8}),
        SolverConfig(backend_opts={"n_replicas": 4}),
        SolverConfig(backend_opts={"n_replicas": 8, "j_bits": 2}),
        SolverConfig(backend="pallas", backend_opts={"block_r": 8}),
    ]
    return cfgs


# ---------------------------------------------------------------------------
# Signature: injectivity + stability
# ---------------------------------------------------------------------------
def test_signature_injective_over_grid():
    cfgs = _grid()
    for a, b in itertools.product(cfgs, cfgs):
        if a == b:
            assert a.signature() == b.signature(), (a, b)
        else:
            assert a.signature() != b.signature(), (a, b)


def test_signature_stable_across_spelling():
    # dict vs pre-sorted tuple vs reversed-order dict: one canonical form
    a = SolverConfig(backend_opts={"j_bits": 2, "n_replicas": 8})
    b = SolverConfig(backend_opts=(("j_bits", 2), ("n_replicas", 8)))
    c = SolverConfig(backend_opts={"n_replicas": 8, "j_bits": 2})
    assert a == b == c
    assert a.signature() == b.signature() == c.signature()
    assert isinstance(a.signature(), str) and len(a.signature()) == 16


def test_validation_rejects_bad_knobs():
    with pytest.raises(ValueError, match="backend"):
        SolverConfig(backend="fpga")
    with pytest.raises(ValueError, match="storage_layout"):
        SolverConfig(storage_layout="sparse")
    with pytest.raises(ValueError, match="noise_mode"):
        SolverConfig(noise_mode="inline")
    with pytest.raises(ValueError, match="xorshift"):
        SolverConfig(noise="threefry", noise_mode="streamed")


def test_engine_opts_gated_by_backend_family():
    # sparse accepts neither field_mode nor j_mode nor noise_mode
    sparse = SolverConfig(backend="sparse", field_mode="popcount",
                          j_mode="tiled", noise_mode="streamed")
    assert sparse.engine_opts() == {"storage_layout": "dense"}
    dense = SolverConfig(backend="dense", field_mode="popcount",
                         j_mode="tiled", noise_mode="streamed")
    assert dense.engine_opts() == {
        "storage_layout": "dense", "field_mode": "popcount",
        "j_mode": "tiled"}
    pallas = SolverConfig(backend="pallas", field_mode="popcount",
                          noise_mode="streamed",
                          backend_opts={"n_replicas": 4})
    assert pallas.engine_opts() == {
        "storage_layout": "dense", "field_mode": "popcount",
        "noise_mode": "streamed", "n_replicas": 4}


def test_partition_and_mesh_hoisted_out_of_backend_opts():
    # PR-8 spelling: partition/mesh rode inside backend_opts.  They must be
    # hoisted into the typed fields (so make_backend never sees them twice)
    # and never linger in backend_opts/engine_opts.
    cfg = SolverConfig(backend_opts={"partition": "spin", "tile_n": 64})
    assert cfg.partition == "spin"
    assert cfg.opts_dict() == {"tile_n": 64}
    assert "partition" not in cfg.engine_opts()
    assert cfg.signature() == SolverConfig(
        partition="spin", backend_opts={"tile_n": 64}).signature()
    # equal spellings don't conflict; contradictory ones do
    assert SolverConfig(partition="spin",
                        backend_opts={"partition": "spin"}).partition == "spin"
    with pytest.raises(ValueError, match="conflicts"):
        SolverConfig(partition="spin", backend_opts={"partition": "problem"})
    # the legacy anneal(backend_opts={'partition': ..., 'mesh': ...}) path
    # (benchmarks/scale.py, tests/test_spinshard.py) must keep working
    from repro.sharding import spin_mesh
    mesh = spin_mesh(1)
    r = anneal(TORUS, HP, seed=5, noise="xorshift",
               backend_opts={"partition": "spin", "mesh": mesh})
    ref = anneal(TORUS, HP, seed=5, config=SolverConfig())
    np.testing.assert_array_equal(r.best_energy, ref.best_energy)
    np.testing.assert_array_equal(r.best_m, ref.best_m)


# ---------------------------------------------------------------------------
# The legacy shim
# ---------------------------------------------------------------------------
def test_shim_warns_once_per_site_and_builds_equal_config():
    site = "tests.test_solver_config.shim_once"
    config_mod._WARNED_SITES.discard(site)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        c1 = legacy_kwargs_to_config(site, None, backend="dense",
                                     noise="xorshift")
        c2 = legacy_kwargs_to_config(site, None, backend="dense",
                                     noise="xorshift")
    dep = [w for w in rec if issubclass(w.category, DeprecationWarning)]
    assert len(dep) == 1 and site in str(dep[0].message)
    assert c1 == c2 == SolverConfig(backend="dense", noise="xorshift")


def test_shim_ignores_none_and_rejects_mixing():
    c = legacy_kwargs_to_config("tests.none-site", None, backend=None,
                                noise=None)
    assert c == SolverConfig()
    with pytest.raises(TypeError, match="not both"):
        legacy_kwargs_to_config("tests.mix-site", SolverConfig(),
                                backend="dense")


@pytest.mark.parametrize("legacy_kw,cfg", [
    (dict(backend="dense", noise="xorshift"),
     SolverConfig(backend="dense", noise="xorshift")),
    (dict(backend="sparse", noise="xorshift", storage_layout="packed"),
     SolverConfig(backend="sparse", noise="xorshift",
                  storage_layout="packed")),
    (dict(backend="pallas", noise="xorshift",
          backend_opts={"noise_mode": "streamed"}),
     SolverConfig(backend="pallas", noise="xorshift",
                  backend_opts={"noise_mode": "streamed"})),
])
def test_legacy_kwargs_bit_identical_to_config(legacy_kw, cfg):
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        r_legacy = anneal(TORUS, HP, seed=3, track_energy=False, **legacy_kw)
    r_cfg = anneal(TORUS, HP, seed=3, track_energy=False, config=cfg)
    np.testing.assert_array_equal(r_legacy.best_energy, r_cfg.best_energy)
    np.testing.assert_array_equal(r_legacy.best_cut, r_cfg.best_cut)
    np.testing.assert_array_equal(r_legacy.best_m, r_cfg.best_m)


def test_legacy_default_noise_stays_threefry():
    """anneal()'s historical no-kwarg default (threefry) is preserved; the
    typed default (xorshift) applies only when a config is passed."""
    r_bare = anneal(TORUS, HP, seed=3, track_energy=False)
    r_tf = anneal(TORUS, HP, seed=3, track_energy=False,
                  config=SolverConfig(noise="threefry"))
    r_xs = anneal(TORUS, HP, seed=3, track_energy=False,
                  config=SolverConfig())
    np.testing.assert_array_equal(r_bare.best_energy, r_tf.best_energy)
    np.testing.assert_array_equal(r_bare.best_m, r_tf.best_m)
    assert not np.array_equal(r_bare.best_m, r_xs.best_m)


# ---------------------------------------------------------------------------
# Cache-key identity: config-built vs kwarg-built services
# ---------------------------------------------------------------------------
def test_service_cache_keys_identical_config_vs_legacy():
    reqs = lambda: [AnnealRequest(problem=TORUS, hp=HP, seed=7)]  # noqa: E731
    svc_kw = AnnealService(backend="dense", noise="xorshift", min_bucket=16)
    svc_cfg = AnnealService(
        config=SolverConfig(backend="dense", noise="xorshift"), min_bucket=16)
    r_kw = svc_kw.solve(reqs())
    r_cfg = svc_cfg.solve(reqs())
    np.testing.assert_array_equal(r_kw[0].result.best_m,
                                  r_cfg[0].result.best_m)
    keys_kw, keys_cfg = set(svc_kw._programs), set(svc_cfg._programs)
    assert keys_kw and keys_kw == keys_cfg


def test_per_request_config_signature_splits_groups():
    """Two same-shape requests whose configs demand different execution
    surfaces must not share a group (the config signature is in the key)."""
    hp = HP
    r1 = AnnealRequest(problem=TORUS, hp=hp, seed=7,
                       config=SolverConfig(backend="dense"))
    r2 = AnnealRequest(problem=TORUS, hp=hp, seed=7,
                       config=SolverConfig(backend="dense", j_mode="tiled"))
    svc = AnnealService(backend="sparse", min_bucket=16)
    k1, k2 = svc._group_key(r1, 64), svc._group_key(r2, 64)
    assert k1 != k2
    # and config-less requests key separately from config-carrying ones
    r3 = AnnealRequest(problem=TORUS, hp=hp, seed=7)
    assert svc._group_key(r3, 64) != k1
