"""The distributed (pjit-able) iteration step must reproduce the core
annealer exactly (same noise stream, same storage policy) — and the batched
step (the serving layer's problem axis on the mesh) must reproduce the
single-problem step per problem."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SSAHyperParams, anneal, gset
from repro.core.distributed import (
    make_batched_iteration_step,
    make_iteration_step,
)
from repro.core.rng import xorshift_init, xorshift_next_bits


def test_iteration_step_matches_core_annealer():
    g = gset.king_graph(36, seed=5)
    model = g.to_ising()
    hp = SSAHyperParams(n_trials=4, m_shot=3, tau=5, i0_min=1, i0_max=8)

    r_core = anneal(
        g, hp, seed=9, storage="i0max", record="best", noise="xorshift",
        backend="dense", track_energy=False,
    )

    step = jax.jit(make_iteration_step(hp, mesh=None))
    T, N = hp.n_trials, model.n
    rng = xorshift_init(9, (T, N))
    rng, r0 = xorshift_next_bits(rng)
    m = r0.astype(jnp.float32)
    itanh = jnp.where(m > 0, 0, -1).astype(jnp.int32)
    best_H = jnp.full((T,), 2**30, jnp.int32)
    best_m = m.astype(jnp.int8)
    J = jnp.asarray(model.dense_J(), jnp.float32)
    h = jnp.asarray(model.h, jnp.int32)
    for _ in range(hp.m_shot):
        rng, m, itanh, best_H, best_m = step(rng, m, itanh, best_H, best_m, J, h)

    np.testing.assert_array_equal(np.asarray(best_H), r_core.best_energy)


def test_iteration_step_improves_over_iterations():
    g = gset.load("G11")
    model = g.to_ising()
    hp = SSAHyperParams(n_trials=4, m_shot=1)
    step = jax.jit(make_iteration_step(hp, mesh=None))
    T, N = hp.n_trials, model.n
    rng = xorshift_init(0, (T, N))
    rng, r0 = xorshift_next_bits(rng)
    m = r0.astype(jnp.float32)
    itanh = jnp.where(m > 0, 0, -1).astype(jnp.int32)
    best_H = jnp.full((T,), 2**30, jnp.int32)
    best_m = m.astype(jnp.int8)
    J = jnp.asarray(model.dense_J(), jnp.float32)
    h = jnp.asarray(model.h, jnp.int32)
    rng, m, itanh, best_H, best_m = step(rng, m, itanh, best_H, best_m, J, h)
    first = np.asarray(best_H).copy()
    for _ in range(2):
        rng, m, itanh, best_H, best_m = step(rng, m, itanh, best_H, best_m, J, h)
    assert np.all(np.asarray(best_H) <= first)
    # best_m is consistent with best_H
    cuts = g.cut_value(jnp.asarray(best_m, jnp.int32))
    np.testing.assert_array_equal(
        np.asarray(cuts), (g.w_total - np.asarray(best_H)) // 2
    )


def test_batched_iteration_step_matches_per_problem_steps():
    """B stacked problems through the batched step == B single-problem steps."""
    problems = [gset.king_graph(36, seed=5), gset.toroidal_grid(36, seed=7)]
    models = [p.to_ising() for p in problems]
    hp = SSAHyperParams(n_trials=4, m_shot=2, tau=5, i0_min=1, i0_max=8)
    T, N, B = hp.n_trials, 36, len(models)

    step1 = jax.jit(make_iteration_step(hp, mesh=None))
    stepB = jax.jit(make_batched_iteration_step(hp, mesh=None))

    # identical per-problem init for both paths
    rngs = [xorshift_init(20 + i, (T, N)) for i in range(B)]
    ms, its = [], []
    rng1 = []
    for r in rngs:
        r, r0 = xorshift_next_bits(r)
        rng1.append(r)
        m = r0.astype(jnp.float32)
        ms.append(m)
        its.append(jnp.where(m > 0, 0, -1).astype(jnp.int32))
    Js = [jnp.asarray(mo.dense_J(), jnp.float32) for mo in models]
    hs = [jnp.asarray(mo.h, jnp.int32) for mo in models]
    bH = jnp.full((T,), 2**30, jnp.int32)

    singles = []
    for i in range(B):
        st = (rng1[i], ms[i], its[i], bH, ms[i].astype(jnp.int8))
        for _ in range(hp.m_shot):
            st = step1(*st, Js[i], hs[i])
        singles.append(st)

    stB = (
        jnp.stack(rng1, axis=1),            # (4, B, T, N)
        jnp.stack(ms),
        jnp.stack(its),
        jnp.stack([bH] * B),
        jnp.stack([m.astype(jnp.int8) for m in ms]),
    )
    JB, hB = jnp.stack(Js), jnp.stack(hs)
    for _ in range(hp.m_shot):
        stB = stepB(*stB, JB, hB)

    for i in range(B):
        np.testing.assert_array_equal(
            np.asarray(stB[3][i]), np.asarray(singles[i][3]), err_msg="best_H"
        )
        np.testing.assert_array_equal(
            np.asarray(stB[4][i]), np.asarray(singles[i][4]), err_msg="best_m"
        )
        np.testing.assert_array_equal(
            np.asarray(stB[1][i]), np.asarray(singles[i][1]), err_msg="m"
        )
