"""Tests for the SA and PT baselines + cross-method convergence claims."""
import numpy as np

from repro.core import (
    PTHyperParams,
    SAHyperParams,
    SSAHyperParams,
    anneal,
    anneal_pt,
    anneal_sa,
    fig4_example,
    gset,
)


def test_sa_solves_fig4():
    p = fig4_example()
    r = anneal_sa(p, SAHyperParams(n_trials=8, n_cycles=2000), seed=0)
    assert r.overall_best_cut == 3


def test_pt_solves_fig4():
    p = fig4_example()
    r = anneal_pt(p, PTHyperParams(n_replicas=4, n_cycles=2000, swap_interval=50), seed=0)
    assert r.best_cut == 3


def test_sa_energy_decreases():
    g = gset.load("G11")
    r = anneal_sa(g, SAHyperParams(n_trials=4, n_cycles=5000), seed=1)
    e = r.energy_mean
    assert e.shape == (5000,)
    assert e[-100:].mean() < e[:100].mean()


def test_sa_best_tracks_min():
    g = gset.toroidal_grid(64, seed=2)
    r = anneal_sa(g, SAHyperParams(n_trials=4, n_cycles=3000), seed=3)
    # recorded best energy must equal the min of the energy trace floor
    assert r.best_energy.min() <= r.energy_min.min()


def test_hassa_converges_faster_than_sa():
    """Sec. V-A: at equal cycle budget, HA-SSA reaches a much better cut.

    (The paper reports 58–114× fewer cycles for SA-equivalent quality; at a
    fixed small budget this manifests as a strictly better mean cut.)
    """
    g = gset.load("G11")
    cycles = 6000
    hp = SSAHyperParams(n_trials=8, m_shot=10)  # 10 × 600 = 6000 cycles
    r_ha = anneal(g, hp, seed=0)
    r_sa = anneal_sa(g, SAHyperParams(n_trials=8, n_cycles=cycles), seed=0)
    assert r_ha.mean_best_cut > r_sa.mean_best_cut + 20
    assert r_ha.overall_best_cut > r_sa.overall_best_cut


def test_pt_beats_plain_sa_on_quality_budget():
    """PT should roughly match SA's solution quality at equal cycles.

    Slack: PT here is ONE 8-replica chain while SA gets 8 independent
    trials (an 8-way max), and 8000 single-flip cycles on N=800 is a short
    budget — per-seed spread is ~±15 around parity either way.
    """
    g = gset.load("G11")
    r_pt = anneal_pt(g, PTHyperParams(n_replicas=8, n_cycles=8000), seed=0)
    r_sa = anneal_sa(g, SAHyperParams(n_trials=8, n_cycles=8000), seed=0)
    assert r_pt.best_cut >= r_sa.overall_best_cut - 20


def test_fig12_equal_temperature_control():
    """Sec. VI-A: with the SSA-equivalent (inverted) temperature ladder, SA
    cannot reach the near-optimum in the short window while HA-SSA does."""
    g = gset.load("G11")
    hp = SSAHyperParams(n_trials=4, m_shot=5)  # 3000 cycles
    r_ha = anneal(g, hp, seed=0, total_cycles=3000)
    # SA with temperature 1 → 1/32 over 600-cycle periods, tiled
    period = np.repeat(1.0 / np.array([1, 2, 4, 8, 16, 32], np.float32), 100)
    temps = np.tile(period, 5)
    r_sa = anneal_sa(
        g, SAHyperParams(n_trials=4, n_cycles=3000), seed=0, temperatures=temps
    )
    assert r_ha.mean_best_cut > r_sa.mean_best_cut


def test_pt_swap_perm_exchanges_pairs():
    """Accepted (k, k+1) swaps must exchange BOTH members (regression: the
    old two-scatter construction half-applied every swap at pair k >= 1)."""
    import jax.numpy as jnp

    from repro.core.pt import _swap_perm

    def ref(do_swap, R):
        perm = list(range(R))
        for k, s in enumerate(do_swap):
            if s:
                perm[k], perm[k + 1] = perm[k + 1], perm[k]
        return perm

    R = 6
    for bits in range(1 << (R - 1)):
        do_swap = [(bits >> k) & 1 == 1 for k in range(R - 1)]
        # valid PT rounds only propose same-parity (disjoint) pairs
        if any(do_swap[k] and do_swap[k + 1] for k in range(R - 2)):
            continue
        got = list(np.asarray(_swap_perm(jnp.asarray(do_swap), R)))
        assert got == ref(do_swap, R), (do_swap, got)
