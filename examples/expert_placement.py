"""Beyond-paper feature: HA-SSA optimizes MoE expert placement (EP sharding).

Generates synthetic-but-structured co-activation statistics for an
olmoe-style 64-expert layer, then anneals the balanced-min-cut placement
onto 16 devices and compares modeled all-to-all cost vs round-robin.

    PYTHONPATH=src python examples/expert_placement.py
"""
import numpy as np

from repro.core.placement import coactivation_stats, expert_placement

E, K, T = 64, 8, 4000
rng = np.random.default_rng(0)

# structured routing: experts cluster into 8 cliques that co-fire
cliques = np.arange(E).reshape(8, 8)
routing = np.zeros((T, K), dtype=np.int64)
for t in range(T):
    c = rng.integers(0, 8)
    members = cliques[c]
    routing[t] = rng.choice(members, size=K, replace=False) if K <= 8 else members
    if rng.random() < 0.3:  # cross-clique noise
        routing[t, 0] = rng.integers(0, E)

coact, load = coactivation_stats(routing, E)
res = expert_placement(coact, load, n_devices=16, seed=0)
print(f"experts={E} devices=16 tokens={T}")
print(f"round-robin traffic cost : {res.baseline_cost:.0f}")
print(f"HA-SSA placement cost    : {res.cost:.0f}")
print(f"improvement              : {100*res.improvement:.1f}%")
print(f"assignment (expert -> device): {res.assignment.tolist()}")
