"""Serving example: batched prefill + greedy decode with a KV cache.

    PYTHONPATH=src python examples/serve_lm.py [--arch granite-3-8b]
"""
import argparse

import jax

from repro.configs import get_config
from repro.models import model_defs
from repro.models.params import init_params
from repro.serve.lm import ServeConfig, generate

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="granite-3-8b")
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--prompt-len", type=int, default=16)
ap.add_argument("--new-tokens", type=int, default=24)
args = ap.parse_args()

cfg = get_config(args.arch, reduced=True)
params = init_params(model_defs(cfg), jax.random.PRNGKey(0))
prompts = jax.random.randint(jax.random.PRNGKey(1),
                             (args.batch, args.prompt_len), 0, cfg.vocab)
batch = {"tokens": prompts}
if cfg.frontend == "vision":
    batch["patches"] = jax.random.normal(
        jax.random.PRNGKey(2), (args.batch, cfg.n_patches, cfg.d_model)) * 0.02
if cfg.encoder_layers:
    batch["frames"] = jax.random.normal(
        jax.random.PRNGKey(2), (args.batch, cfg.n_frames, cfg.d_model)) * 0.1

out = generate(params, batch, cfg,
               ServeConfig(max_seq=args.prompt_len + args.new_tokens),
               n_new_tokens=args.new_tokens)
print(f"arch={cfg.name} batch={args.batch}")
for b in range(args.batch):
    print(f"  request {b}: {out[b].tolist()}")
