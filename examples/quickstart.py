"""Quickstart: solve a MAX-CUT instance with HA-SSA (the paper in 25 lines).

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import SSAHyperParams, anneal, gset, memory

# G11-class instance: 800-vertex toroidal 4-regular graph, ±1 weights
problem = gset.load("G11")

# Table-II hyperparameters, scaled down for a quick demo
hp = SSAHyperParams(n_trials=16, m_shot=20, n_rnd=2, i0_min=1, i0_max=32,
                    tau=100, beta_shift=1)

# storage='i0max' is HA-SSA: spin states kept only while I0 == I0max
result = anneal(problem, hp, seed=0, storage="i0max")

print(f"problem: {problem.name} (N={problem.n}, |E|={len(problem.edges)})")
print(f"cycles per trial: {hp.total_cycles}")
print(f"best cut  : {result.overall_best_cut}")
print(f"mean cut  : {result.mean_best_cut:.1f} over {hp.n_trials} trials")
print(f"best energy: {result.best_energy.min()}")
print(f"trajectory memory: HA-SSA {memory.hassa_bits_per_iteration(problem.n, hp)} "
      f"bits/iter vs SSA {memory.ssa_bits_per_iteration(problem.n, hp)} "
      f"({memory.memory_ratio(hp)}x saving)")
