"""End-to-end annealing driver (the paper's kind of workload): solve the
benchmark set with HA-SSA / SSA / SA and reproduce the paper's comparisons.

    PYTHONPATH=src python examples/anneal_gset.py [--full] [--problems G11,King1]

--full uses the paper's scale (100 trials x 90,000 cycles; minutes on CPU).
"""
import argparse
import time

from repro.core import (SAHyperParams, SSAHyperParams, anneal, anneal_sa, gset)

ap = argparse.ArgumentParser()
ap.add_argument("--full", action="store_true")
ap.add_argument("--problems", default="G11,G12,G13,King1")
args = ap.parse_args()

trials = 100 if args.full else 8
m_shot = 150 if args.full else 15

for name in args.problems.split(","):
    p = gset.load(name)
    hp = SSAHyperParams(n_trials=trials, m_shot=m_shot)
    t0 = time.time()
    r_ha = anneal(p, hp, seed=0, storage="i0max", noise="xorshift")
    t_ha = time.time() - t0
    t0 = time.time()
    r_sa = anneal_sa(p, SAHyperParams(n_trials=trials, n_cycles=hp.total_cycles), seed=0)
    t_sa = time.time() - t0
    print(f"\n=== {p.name} (N={p.n}, |E|={len(p.edges)}) "
          f"{hp.total_cycles} cycles x {trials} trials ===")
    print(f"  HA-SSA: best {r_ha.overall_best_cut}  avg {r_ha.mean_best_cut:.1f}  "
          f"({t_ha:.1f}s)")
    print(f"  SA    : best {r_sa.overall_best_cut}  avg {r_sa.mean_best_cut:.1f}  "
          f"({t_sa:.1f}s)")
    if p.best_known:
        print(f"  best known: {p.best_known} "
              f"(HA-SSA at {100*r_ha.overall_best_cut/p.best_known:.1f}%)")
