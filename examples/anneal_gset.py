"""End-to-end annealing driver (the paper's kind of workload): solve the
benchmark set with HA-SSA / SSA / SA and reproduce the paper's comparisons.

    PYTHONPATH=src python examples/anneal_gset.py [--full] \
        [--problems G11,King1] [--backend sparse|dense|pallas]

--full uses the paper's scale (100 trials x 90,000 cycles; minutes on CPU).

The solves go through :func:`solve_batch` — a thin client of
:class:`repro.serve.AnnealService` (DESIGN.md §7): requests are grouped by
shape bucket, padded, stacked on a problem axis and solved by ONE compiled
plateau program per bucket.  All G-set-class instances (N=800) share a
bucket, so this whole batch compiles once and runs as one device launch —
the pre-service version of this file re-traced and re-compiled the entire
plateau program per request.
"""
import argparse
import time
from typing import List, Optional

from repro.core import SAHyperParams, SSAHyperParams, anneal_sa, gset
from repro.serve import AnnealRequest, AnnealResponse, AnnealService


def solve_batch(requests: List[AnnealRequest], *, backend: str = "sparse",
                noise: str = "xorshift", service: Optional[AnnealService] = None,
                progress=None) -> List[AnnealResponse]:
    """Solve a batch of annealing requests on the shared annealing service.

    Same-bucket requests are stacked and solved by one compiled plateau
    program (one compile per shape bucket, cached across calls when a
    ``service`` instance is reused).  ``backend='pallas'`` executes every
    temperature plateau of the whole batch as a single resident kernel
    launch on a (B, R-tile) grid.
    """
    service = service or AnnealService(backend=backend, noise=noise)
    return service.solve(requests, progress=progress)


def stream_demo(backend: str = "sparse", full: bool = False):
    """Continuous-batching demo (DESIGN.md §12): replay a mixed trace of
    G-set Max-Cut and QUBO requests through the streaming front door.

    The batch QUBOs are submitted first and an interactive G-set request
    last — the scheduler still seats the interactive one ahead of the
    remaining batch queue, and every lane retires independently at its own
    chunk boundary (watch the backfills in the closing stats line).
    """
    from repro.problems import make_demo
    from repro.serve import StreamingAnnealService, StreamPolicy

    trials = 16 if full else 4
    hp = SSAHyperParams(n_trials=trials, m_shot=30 if full else 6,
                        tau=8, i0_min=1, i0_max=16)
    ss = StreamingAnnealService(backend=backend, min_bucket=64,
                                policy=StreamPolicy(slots_per_table=2))
    ss.start()
    t0 = time.time()
    tickets = []
    try:
        for i in range(3):  # the standing batch workload: demo QUBOs
            req = AnnealRequest(problem=make_demo("qubo", n=96, seed=i),
                                hp=hp, seed=i)
            tickets.append(("batch", ss.submit(req, priority="batch")))
        for name in ("G11", "King1"):  # a latency-sensitive user shows up
            req = AnnealRequest(problem=gset.load(name), hp=hp, seed=7)
            tickets.append(
                ("interactive", ss.submit(req, priority="interactive")))
        print(f"submitted {len(tickets)} requests "
              "(3 batch QUBOs first, 2 interactive G-set last)")
        for prio, t in tickets:
            r = t.result(timeout=None)
            name = getattr(t.request.problem, "name", None) or \
                t.request.problem.model.name
            if r.result is None:
                # 'shed' (dropped unstarted: deadline already unmeetable)
                # and 'failed' (retries exhausted) carry no result — report
                # the status instead of crashing on best_cut=None.
                print(f"  [{prio:11s}] {name}: {r.status.upper()} — "
                      "no result")
                continue
            best = (r.objective if r.objective is not None
                    else r.result.overall_best_cut)
            note = " (best-so-far at deadline)" if r.status == "deadline" \
                else ""
            print(f"  [{prio:11s}] {name}: best {best} "
                  f"(queued {r.queued_s:.2f}s, lane {r.lane_wall_s:.2f}s, "
                  f"status={r.status}){note}")
    finally:
        ss.stop()
    st = ss.stream_stats()
    print(f"stream drained in {time.time() - t0:.1f}s: "
          f"occupancy={st['occupancy']:.2f} "
          f"backfills={st['stream_backfills']} "
          f"tables={st['stream_tables_created']} "
          f"quanta={st['stream_quanta']}")


def main(argv: Optional[List[str]] = None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--problems", default="G11,G12,G13,King1")
    ap.add_argument("--backend", choices=("sparse", "dense", "pallas"),
                    default="sparse")
    ap.add_argument("--skip-sa", action="store_true",
                    help="skip the SA baseline comparison")
    ap.add_argument("--stream-demo", action="store_true",
                    help="replay a mixed G-set + QUBO trace through the "
                         "continuous-batching StreamingAnnealService "
                         "(DESIGN.md §12) instead of one solve() batch")
    args = ap.parse_args(argv)

    if args.stream_demo:
        return stream_demo(backend=args.backend, full=args.full)

    trials = 100 if args.full else 8
    m_shot = 150 if args.full else 15
    hp = SSAHyperParams(n_trials=trials, m_shot=m_shot)

    problems = [gset.load(name) for name in args.problems.split(",")]
    batch = [AnnealRequest(problem=p, hp=hp) for p in problems]
    responses = solve_batch(batch, backend=args.backend)

    for p, resp in zip(problems, responses):
        r_ha = resp.result
        print(f"\n=== {p.name} (N={p.n}, |E|={len(p.edges)}) "
              f"{hp.total_cycles} cycles x {trials} trials "
              f"[backend={args.backend} bucket={resp.bucket} "
              f"batch={resp.batch}] ===")
        print(f"  HA-SSA: best {r_ha.overall_best_cut}  "
              f"avg {r_ha.mean_best_cut:.1f}  ({resp.wall_s:.1f}s batch)")
        if not args.skip_sa:
            t0 = time.time()
            r_sa = anneal_sa(
                p, SAHyperParams(n_trials=trials, n_cycles=hp.total_cycles),
                seed=0)
            t_sa = time.time() - t0
            print(f"  SA    : best {r_sa.overall_best_cut}  "
                  f"avg {r_sa.mean_best_cut:.1f}  ({t_sa:.1f}s)")
        if p.best_known:
            print(f"  best known: {p.best_known} "
                  f"(HA-SSA at {100*r_ha.overall_best_cut/p.best_known:.1f}%)")


if __name__ == "__main__":
    main()
