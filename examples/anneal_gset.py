"""End-to-end annealing driver (the paper's kind of workload): solve the
benchmark set with HA-SSA / SSA / SA and reproduce the paper's comparisons.

    PYTHONPATH=src python examples/anneal_gset.py [--full] \
        [--problems G11,King1] [--backend sparse|dense|pallas]

--full uses the paper's scale (100 trials x 90,000 cycles; minutes on CPU).

The solves go through :func:`solve_batch` — a serve-style batch API in the
spirit of ``repro.serve``: callers enqueue :class:`AnnealRequest`\\ s and get
:class:`AnnealResponse`\\ s back, while the service runs every request on the
shared plateau engine with one backend choice (DESIGN.md §7).  This is the
shape the ROADMAP's annealing-as-a-service work builds on: requests are
independent, so a pod-scale deployment shards them over hosts and batches
trials per device.
"""
import argparse
import dataclasses
import time
from typing import List, Optional, Union

from repro.core import (IsingModel, MaxCutProblem, SAHyperParams,
                        SSAHyperParams, AnnealResult, anneal, anneal_sa, gset)


@dataclasses.dataclass(frozen=True)
class AnnealRequest:
    """One problem + hyperparameters, as a service would accept it."""

    problem: Union[MaxCutProblem, IsingModel]
    hp: SSAHyperParams = SSAHyperParams()
    seed: int = 0
    storage: str = "i0max"


@dataclasses.dataclass
class AnnealResponse:
    request: AnnealRequest
    result: AnnealResult
    wall_s: float


def solve_batch(requests: List[AnnealRequest], *, backend: str = "sparse",
                noise: str = "xorshift", track_energy: bool = False
                ) -> List[AnnealResponse]:
    """Solve a batch of annealing requests on the shared plateau engine.

    Requests are independent; each runs its trials as one device batch.
    ``backend='pallas'`` executes every temperature plateau as a single
    resident kernel launch.
    """
    responses = []
    for req in requests:
        t0 = time.time()
        r = anneal(req.problem, req.hp, seed=req.seed, storage=req.storage,
                   backend=backend, noise=noise, track_energy=track_energy)
        responses.append(AnnealResponse(req, r, time.time() - t0))
    return responses


def main(argv: Optional[List[str]] = None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--problems", default="G11,G12,G13,King1")
    ap.add_argument("--backend", choices=("sparse", "dense", "pallas"),
                    default="sparse")
    ap.add_argument("--skip-sa", action="store_true",
                    help="skip the SA baseline comparison")
    args = ap.parse_args(argv)

    trials = 100 if args.full else 8
    m_shot = 150 if args.full else 15
    hp = SSAHyperParams(n_trials=trials, m_shot=m_shot)

    problems = [gset.load(name) for name in args.problems.split(",")]
    batch = [AnnealRequest(problem=p, hp=hp) for p in problems]
    responses = solve_batch(batch, backend=args.backend)

    for p, resp in zip(problems, responses):
        r_ha = resp.result
        print(f"\n=== {p.name} (N={p.n}, |E|={len(p.edges)}) "
              f"{hp.total_cycles} cycles x {trials} trials "
              f"[backend={args.backend}] ===")
        print(f"  HA-SSA: best {r_ha.overall_best_cut}  "
              f"avg {r_ha.mean_best_cut:.1f}  ({resp.wall_s:.1f}s)")
        if not args.skip_sa:
            t0 = time.time()
            r_sa = anneal_sa(
                p, SAHyperParams(n_trials=trials, n_cycles=hp.total_cycles),
                seed=0)
            t_sa = time.time() - t0
            print(f"  SA    : best {r_sa.overall_best_cut}  "
                  f"avg {r_sa.mean_best_cut:.1f}  ({t_sa:.1f}s)")
        if p.best_known:
            print(f"  best known: {p.best_known} "
                  f"(HA-SSA at {100*r_ha.overall_best_cut/p.best_known:.1f}%)")


if __name__ == "__main__":
    main()
