"""End-to-end training driver: train a LM on the synthetic pipeline with
checkpointing; resumes if interrupted (kill it mid-run and re-run).

    PYTHONPATH=src python examples/train_lm.py [--steps 200] [--arch qwen3-1.7b]
                                               [--scale reduced|full]

'reduced' trains the smoke-scale config (CPU-friendly); 'full' is the real
config (use on a TPU host via launch/train.py).
"""
import argparse

import jax

from repro.checkpoint.ckpt import CheckpointManager
from repro.configs import get_config
from repro.data.pipeline import DataConfig, synthetic_batch
from repro.ft.resilience import run_training
from repro.optim.adamw import AdamWConfig
from repro.train.step import TrainConfig, init_train_state, make_train_step

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=120)
ap.add_argument("--arch", default="qwen3-1.7b")
ap.add_argument("--scale", default="reduced", choices=("reduced", "full"))
ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=64)
args = ap.parse_args()

cfg = get_config(args.arch, reduced=(args.scale == "reduced"))
tc = TrainConfig(opt=AdamWConfig(lr_peak=3e-3, warmup_steps=10,
                                 total_steps=args.steps), loss_chunk=64)
dc = DataConfig(vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
                n_patches=cfg.n_patches if cfg.frontend == "vision" else 0,
                d_model=cfg.d_model,
                n_frames=cfg.n_frames if cfg.encoder_layers else 0)

step_fn = jax.jit(make_train_step(cfg, tc))
state, losses = run_training(
    init_state_fn=lambda: init_train_state(cfg, tc, jax.random.PRNGKey(0)),
    train_step=step_fn,
    batch_fn=lambda s: synthetic_batch(dc, s),
    n_steps=args.steps,
    ckpt=CheckpointManager(args.ckpt_dir, save_interval=20, keep=2),
    log_every=10,
)
print(f"\ntrained {args.arch} ({args.scale}) for {args.steps} steps: "
      f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
