from .step import *  # noqa: F401,F403
