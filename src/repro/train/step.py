"""Training step: chunked-vocab CE loss, microbatch accumulation, AdamW.

Memory-scaling choices that matter at 1000+ nodes (DESIGN.md §6):
  * the LM head never materializes (B, S, V) logits — the loss scans vocab
    projections over sequence chunks (151k-vocab × 32k-seq would be TBs);
  * optional microbatch gradient accumulation (scan over microbatches) with
    bf16 accumulation — cross-DP gradient reduction then happens on bf16
    tensors, i.e. 2× collective compression;
  * per-group remat is configured in the model (ModelConfig.remat).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.layers import COMPUTE_DTYPE
from repro.optim.adamw import AdamWConfig, OptState, adamw_init, adamw_update
from repro.sharding import DEFAULT_RULES, ShardingRules, constrain

__all__ = ["TrainState", "TrainConfig", "chunked_ce_loss", "make_loss_fn",
           "make_train_step", "init_train_state"]


class TrainState(NamedTuple):
    params: Any
    opt: OptState


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: AdamWConfig = AdamWConfig()
    microbatches: int = 1
    loss_chunk: int = 512          # sequence chunk for the vocab projection
    aux_coef: float = 0.01         # MoE load-balance loss coefficient
    grad_accum_dtype: Any = jnp.float32  # bf16 → compressed DP all-reduce
    # False → unroll the microbatch/loss-chunk loops (analysis lowering:
    # XLA's cost model counts scan bodies once, so scans undercount)
    scan_microbatches: bool = True
    scan_loss_chunks: bool = True
    # bf16 → mixed precision with fp32 master: forward/backward (and any
    # FSDP weight all-gathers) see half-width params; AdamW updates fp32.
    param_compute_dtype: Any = None


def chunked_ce_loss(
    params, hidden, labels, cfg, *, mesh=None, rules=DEFAULT_RULES, chunk=512,
    scan: bool = True,
):
    """Σ CE(logits, labels) over positions with labels >= 0, plus count.

    hidden (B,S,M); labels (B,S) int32 (-1 = masked).  Scans S in chunks so
    only (B, chunk, V) logits are ever live.
    """
    B, S, M = hidden.shape
    head = params["embed"]["tok"].T if cfg.tie_embeddings else params["embed"]["head"]
    chunk = min(chunk, S)
    if S % chunk:
        chunk = S
    n = S // chunk
    hc = jnp.moveaxis(hidden.reshape(B, n, chunk, M), 1, 0)
    lc = jnp.moveaxis(labels.reshape(B, n, chunk), 1, 0)

    def step(carry, hl):
        tot, cnt = carry
        h, lab = hl
        logits = jnp.einsum(
            "bsm,mv->bsv", h.astype(COMPUTE_DTYPE), head.astype(COMPUTE_DTYPE)
        ).astype(jnp.float32)
        logits = constrain(logits, mesh, ("batch", "seq", "vocab"),
                           rules.replace(seq=None))
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(
            logits, jnp.maximum(lab, 0)[..., None], axis=-1
        )[..., 0]
        mask = (lab >= 0).astype(jnp.float32)
        tot = tot + jnp.sum((lse - ll) * mask)
        cnt = cnt + jnp.sum(mask)
        return (tot, cnt), None

    carry = (jnp.zeros(()), jnp.zeros(()))
    if scan:
        (tot, cnt), _ = jax.lax.scan(step, carry, (hc, lc))
    else:  # unrolled (analysis lowering) — same chunking, every chunk counted
        for i in range(n):
            carry, _ = step(carry, (hc[i], lc[i]))
        tot, cnt = carry
    return tot, cnt


def make_loss_fn(model_cfg, train_cfg: TrainConfig, mesh=None, rules=DEFAULT_RULES):
    def loss_fn(params, batch):
        hidden, aux = T.forward(params, batch, model_cfg, mesh=mesh, rules=rules)
        labels = batch["labels"]
        if model_cfg.frontend == "vision" and model_cfg.n_patches:
            # patch-prefix positions carry no next-token target
            mask_prefix = jnp.arange(labels.shape[1]) < model_cfg.n_patches
            labels = jnp.where(mask_prefix[None, :], -1, labels)
        tot, cnt = chunked_ce_loss(
            params, hidden, labels, model_cfg, mesh=mesh, rules=rules,
            chunk=train_cfg.loss_chunk, scan=train_cfg.scan_loss_chunks,
        )
        loss = tot / jnp.maximum(cnt, 1.0)
        total = loss + train_cfg.aux_coef * aux
        return total, {"ce_loss": loss, "aux_loss": aux, "tokens": cnt}

    return loss_fn


def _microbatch_grads(loss_fn, params, batch, n_micro: int, accum_dtype,
                      scan: bool = True):
    """Scan over microbatches, accumulating grads in ``accum_dtype``."""
    B = batch["tokens"].shape[0]
    assert B % n_micro == 0, (B, n_micro)
    mb = B // n_micro
    stacked = {
        k: jnp.moveaxis(v.reshape((n_micro, mb) + v.shape[1:]), 0, 0)
        for k, v in batch.items()
    }
    grad_fn = jax.grad(loss_fn, has_aux=True)

    def step(carry, mbatch):
        acc, msum = carry
        g, metrics = grad_fn(params, mbatch)
        acc = jax.tree_util.tree_map(
            lambda a, b: a + b.astype(accum_dtype), acc, g
        )
        msum = jax.tree_util.tree_map(lambda a, b: a + b, msum, metrics)
        return (acc, msum), None

    acc0 = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, accum_dtype), params
    )
    m0 = {"ce_loss": jnp.zeros(()), "aux_loss": jnp.zeros(()), "tokens": jnp.zeros(())}
    carry = (acc0, m0)
    if scan:
        (acc, msum), _ = jax.lax.scan(step, carry, stacked)
    else:  # unrolled (analysis lowering)
        for i in range(n_micro):
            carry, _ = step(carry, {k: v[i] for k, v in stacked.items()})
        acc, msum = carry
    grads = jax.tree_util.tree_map(lambda g: g / n_micro, acc)
    metrics = {k: v / n_micro for k, v in msum.items()}
    metrics["tokens"] = msum["tokens"]
    return grads, metrics


def make_train_step(
    model_cfg,
    train_cfg: TrainConfig,
    mesh=None,
    rules: ShardingRules = DEFAULT_RULES,
    param_specs=None,
):
    """Returns train_step(state, batch) -> (state, metrics) — jit/pjit-ready."""
    loss_fn = make_loss_fn(model_cfg, train_cfg, mesh, rules)

    def train_step(state: TrainState, batch):
        cdt = train_cfg.param_compute_dtype
        params_c = (
            jax.tree_util.tree_map(
                lambda p: p.astype(cdt)
                if jnp.issubdtype(p.dtype, jnp.floating) else p,
                state.params,
            )
            if cdt is not None else state.params
        )
        if train_cfg.microbatches > 1:
            grads, metrics = _microbatch_grads(
                loss_fn, params_c, batch, train_cfg.microbatches,
                train_cfg.grad_accum_dtype, scan=train_cfg.scan_microbatches,
            )
        else:
            grads, metrics = jax.grad(loss_fn, has_aux=True)(params_c, batch)
        new_params, new_opt, opt_metrics = adamw_update(
            state.params, grads, state.opt, train_cfg.opt,
            mesh=mesh, param_specs=param_specs,
        )
        metrics = {**metrics, **opt_metrics, "step": new_opt.step}
        return TrainState(params=new_params, opt=new_opt), metrics

    return train_step


def init_train_state(model_cfg, train_cfg: TrainConfig, key, *, mesh=None,
                     param_specs=None) -> TrainState:
    from repro.models.params import init_params

    defs = T.model_defs(model_cfg)
    params = init_params(defs, key)
    opt = adamw_init(params, train_cfg.opt, mesh=mesh, param_specs=param_specs)
    return TrainState(params=params, opt=opt)
