"""Model zoo substrate: composable transformer/SSM/MoE definitions."""
from . import layers, mamba, moe, params, rwkv, transformer  # noqa: F401
from .transformer import ModelConfig, cache_defs, decode_step, forward, model_defs, prefill  # noqa: F401
