"""RWKV-6 (Finch) block: time-mix with data-dependent decay + channel-mix.

Per head (dim D): state S ∈ R^{D×D};  for each token t:

    S_t  = diag(w_t) · S_{t-1} + k_tᵀ ⊗ v_t
    y_t  = r_t · (S_{t-1} + diag(u) · k_tᵀ ⊗ v_t)

with r,k,v,g from token-shifted projections and the *data-dependent* decay
w_t = exp(-exp(w0 + tanh(x W_w1) W_w2)) (the Finch contribution,
arXiv:2404.05892).  Channel-mix is the RWKV squared-ReLU FFN.  Attention-
free: O(1) state per token — this is why rwkv6-3b runs the long_500k cell.

Simplifications vs the reference implementation (noted in DESIGN.md):
token-shift interpolation uses per-channel learned μ (the RWKV-5 form)
rather than the full ddlerp LoRA stack; decay LoRA is kept (it is the
paper-defining feature).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.sharding import DEFAULT_RULES, ShardingRules, constrain

from .layers import COMPUTE_DTYPE, rms_norm
from .params import ParamDef

__all__ = ["rwkv_defs", "rwkv_time_mix", "rwkv_time_mix_decode",
           "rwkv_channel_mix", "rwkv_channel_mix_decode", "rwkv_init_cache"]

_DECAY_LORA = 64


def _dims(cfg):
    H = cfg.d_model // cfg.rwkv_head_dim
    return H, cfg.rwkv_head_dim


def rwkv_defs(cfg) -> Dict[str, ParamDef]:
    M = cfg.d_model
    H, D = _dims(cfg)
    L = _DECAY_LORA
    return {
        "mu_r": ParamDef((M,), ("d_model",), init="ones", scale=0.5),
        "mu_k": ParamDef((M,), ("d_model",), init="ones"),
        "mu_v": ParamDef((M,), ("d_model",), init="ones"),
        "mu_g": ParamDef((M,), ("d_model",), init="ones"),
        "mu_w": ParamDef((M,), ("d_model",), init="ones"),
        "wr": ParamDef((M, H, D), ("d_model", "heads", "d_head")),
        "wk": ParamDef((M, H, D), ("d_model", "heads", "d_head")),
        "wv": ParamDef((M, H, D), ("d_model", "heads", "d_head")),
        "wg": ParamDef((M, H, D), ("d_model", "heads", "d_head")),
        "w0": ParamDef((H, D), ("heads", "d_head"), init="zeros"),
        "w_lora_a": ParamDef((M, L), ("d_model", None), scale=0.02),
        "w_lora_b": ParamDef((L, H, D), (None, "heads", "d_head"), scale=0.02),
        "u_bonus": ParamDef((H, D), ("heads", "d_head"), init="zeros"),
        "ln_scale": ParamDef((H, D), ("heads", "d_head"), init="ones"),
        "wo": ParamDef((H, D, M), ("heads", "d_head", "d_model")),
    }


def _shift(x, x_prev):
    """Token shift: concat previous token (carry) with x[:-1]."""
    return jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)


def _mix(x, xs, mu):
    return x * mu + xs * (1.0 - mu)


def _time_mix_projections(p, x, xs, cfg):
    """x, xs (B,S,M) f32 → r,k,v,g (B,S,H,D), w (B,S,H,D) decay in (0,1)."""
    H, D = _dims(cfg)
    cd = COMPUTE_DTYPE
    xr = _mix(x, xs, p["mu_r"].astype(jnp.float32))
    xk = _mix(x, xs, p["mu_k"].astype(jnp.float32))
    xv = _mix(x, xs, p["mu_v"].astype(jnp.float32))
    xg = _mix(x, xs, p["mu_g"].astype(jnp.float32))
    xw = _mix(x, xs, p["mu_w"].astype(jnp.float32))
    r = jnp.einsum("bsm,mhd->bshd", xr.astype(cd), p["wr"].astype(cd)).astype(jnp.float32)
    k = jnp.einsum("bsm,mhd->bshd", xk.astype(cd), p["wk"].astype(cd)).astype(jnp.float32)
    v = jnp.einsum("bsm,mhd->bshd", xv.astype(cd), p["wv"].astype(cd)).astype(jnp.float32)
    g = jnp.einsum("bsm,mhd->bshd", xg.astype(cd), p["wg"].astype(cd)).astype(jnp.float32)
    lora = jnp.tanh(
        jnp.einsum("bsm,ml->bsl", xw.astype(jnp.float32), p["w_lora_a"].astype(jnp.float32))
    )
    dd = jnp.einsum("bsl,lhd->bshd", lora, p["w_lora_b"].astype(jnp.float32))
    w = jnp.exp(-jnp.exp(p["w0"].astype(jnp.float32) + dd))  # (B,S,H,D) ∈ (0,1)
    return r, k, v, g, w


def _wkv_scan(r, k, v, w, u, s0):
    """WKV6 recurrence.  r,k,v,w (B,S,H,D); u (H,D); s0 (B,H,D,D).

    Returns (y (B,S,H,D), s_final).  State layout: S[d_k, d_v].
    """
    def step(s, inp):
        rt, kt, vt, wt = inp  # (B,H,D)
        kv = kt[..., :, None] * vt[..., None, :]          # (B,H,Dk,Dv)
        y = jnp.einsum("bhk,bhkv->bhv", rt, s + u[..., None] * kv)
        s_new = wt[..., :, None] * s + kv
        return s_new, y

    rt = jnp.moveaxis(r, 1, 0)
    kt = jnp.moveaxis(k, 1, 0)
    vt = jnp.moveaxis(v, 1, 0)
    wt = jnp.moveaxis(w, 1, 0)
    s_fin, ys = jax.lax.scan(step, s0, (rt, kt, vt, wt))
    return jnp.moveaxis(ys, 0, 1), s_fin


def rwkv_time_mix(
    p,
    x,  # (B, S, M)
    cfg,
    *,
    mesh=None,
    rules: ShardingRules = DEFAULT_RULES,
    cache: Optional[Dict[str, jnp.ndarray]] = None,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    B, S, M = x.shape
    H, D = _dims(cfg)
    xf = x.astype(jnp.float32)
    x_prev = (
        jnp.zeros((B, M), jnp.float32)
        if cache is None
        else cache["shift"].astype(jnp.float32)
    )
    s0 = (
        jnp.zeros((B, H, D, D), jnp.float32)
        if cache is None
        else cache["wkv"].astype(jnp.float32)
    )
    xs = _shift(xf, x_prev)
    r, k, v, g, w = _time_mix_projections(p, xf, xs, cfg)
    y, s_fin = _wkv_scan(r, k, v, w, p["u_bonus"].astype(jnp.float32), s0)
    # per-head groupnorm then gate
    y = rms_norm(y, p["ln_scale"])
    y = (y.astype(jnp.float32) * jax.nn.silu(g)).astype(COMPUTE_DTYPE)
    out = jnp.einsum("bshd,hdm->bsm", y, p["wo"].astype(COMPUTE_DTYPE))
    new_cache = {"shift": xf[:, -1].astype(COMPUTE_DTYPE), "wkv": s_fin}
    return constrain(out, mesh, ("batch", "seq", "d_model"), rules), new_cache


def rwkv_time_mix_decode(p, x, cache, cfg, *, mesh=None, rules=DEFAULT_RULES):
    """x (B,1,M); cache {"shift": (B,M), "wkv": (B,H,D,D)}."""
    y, new_cache = rwkv_time_mix(p, x, cfg, mesh=mesh, rules=rules, cache=cache)
    return y, new_cache


# ---------------------------------------------------------------------------
# Channel mix (RWKV FFN): r gate + squared-relu key
# ---------------------------------------------------------------------------
def rwkv_channel_defs(cfg) -> Dict[str, ParamDef]:
    M, F = cfg.d_model, cfg.d_ff
    return {
        "mu_r": ParamDef((M,), ("d_model",), init="ones"),
        "mu_k": ParamDef((M,), ("d_model",), init="ones"),
        "wr": ParamDef((M, M), ("d_model", None), scale=0.02),
        "wk": ParamDef((M, F), ("d_model", "d_ff")),
        "wv": ParamDef((F, M), ("d_ff", "d_model")),
    }


def rwkv_channel_mix(
    p, x, cfg, *, mesh=None, rules=DEFAULT_RULES, cache=None
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    B, S, M = x.shape
    cd = COMPUTE_DTYPE
    xf = x.astype(jnp.float32)
    x_prev = (
        jnp.zeros((B, M), jnp.float32)
        if cache is None
        else cache["shift"].astype(jnp.float32)
    )
    xs = _shift(xf, x_prev)
    xr = _mix(xf, xs, p["mu_r"].astype(jnp.float32))
    xk = _mix(xf, xs, p["mu_k"].astype(jnp.float32))
    r = jax.nn.sigmoid(jnp.einsum("bsm,mn->bsn", xr.astype(cd), p["wr"].astype(cd)).astype(jnp.float32))
    k = jnp.einsum("bsm,mf->bsf", xk.astype(cd), p["wk"].astype(cd))
    k = jnp.square(jax.nn.relu(k.astype(jnp.float32)))
    k = constrain(k, mesh, ("batch", "seq", "d_ff"), rules)
    v = jnp.einsum("bsf,fm->bsm", k.astype(cd), p["wv"].astype(cd))
    out = (r * v.astype(jnp.float32)).astype(cd)
    new_cache = {"shift": xf[:, -1].astype(cd)}
    return constrain(out, mesh, ("batch", "seq", "d_model"), rules), new_cache


def rwkv_channel_mix_decode(p, x, cache, cfg, *, mesh=None, rules=DEFAULT_RULES):
    return rwkv_channel_mix(p, x, cfg, mesh=mesh, rules=rules, cache=cache)


def rwkv_init_cache(cfg, batch: int, dtype=COMPUTE_DTYPE):
    H, D = _dims(cfg)
    return {
        "time": {"shift": jnp.zeros((batch, cfg.d_model), dtype),
                 "wkv": jnp.zeros((batch, H, D, D), jnp.float32)},
        "channel": {"shift": jnp.zeros((batch, cfg.d_model), dtype)},
    }
