"""Core transformer layers: norms, RoPE, GQA attention (qk-norm, chunked
flash form, flash-decode), dense MLPs, embeddings.

Conventions:
  * params fp32; compute bf16 (cast at use); softmax/norm statistics f32.
  * activations (B, S, M); attention heads layout (B, S, H, D).
  * every function takes (mesh, rules) and self-constrains its activations —
    GSPMD propagates the rest.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding import DEFAULT_RULES, ShardingRules, constrain

from .params import ParamDef

COMPUTE_DTYPE = jnp.bfloat16

__all__ = [
    "rms_norm",
    "layer_norm",
    "norm_defs",
    "apply_norm",
    "rope",
    "attn_defs",
    "attention",
    "attention_decode",
    "mlp_defs",
    "mlp",
    "embed_defs",
]


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def rms_norm(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(x.dtype)


def layer_norm(x, scale, bias, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32) + bias.astype(
        jnp.float32
    )
    return out.astype(x.dtype)


def norm_defs(d_model: int, kind: str) -> Dict[str, ParamDef]:
    if kind == "rmsnorm":
        return {"scale": ParamDef((d_model,), ("d_model",), init="ones")}
    if kind == "layernorm":
        return {
            "scale": ParamDef((d_model,), ("d_model",), init="ones"),
            "bias": ParamDef((d_model,), ("d_model",), init="zeros"),
        }
    raise ValueError(kind)


def apply_norm(p, x, kind: str):
    if kind == "rmsnorm":
        return rms_norm(x, p["scale"])
    return layer_norm(x, p["scale"], p["bias"])


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope(x, positions, theta: float = 1e4):
    """Rotary embedding; x (..., S, H, D) or (..., H, D) with matching positions.

    positions: int32 broadcastable to x.shape[:-2].
    """
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None, None] * freq  # (..., 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------
def attn_defs(cfg) -> Dict[str, ParamDef]:
    M, H, K, D = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    defs = {
        "wq": ParamDef((M, H, D), ("d_model", "heads", "d_head")),
        "wk": ParamDef((M, K, D), ("d_model", "kv_heads", "d_head")),
        "wv": ParamDef((M, K, D), ("d_model", "kv_heads", "d_head")),
        "wo": ParamDef((H, D, M), ("heads", "d_head", "d_model")),
    }
    if cfg.qk_norm:
        defs["q_norm"] = ParamDef((D,), ("d_head",), init="ones")
        defs["k_norm"] = ParamDef((D,), ("d_head",), init="ones")
    return defs


def _qkv(p, x, x_kv, cfg, positions, positions_kv):
    cd = COMPUTE_DTYPE
    q = jnp.einsum("bsm,mhd->bshd", x.astype(cd), p["wq"].astype(cd))
    k = jnp.einsum("bsm,mkd->bskd", x_kv.astype(cd), p["wk"].astype(cd))
    v = jnp.einsum("bsm,mkd->bskd", x_kv.astype(cd), p["wv"].astype(cd))
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if cfg.rope_theta > 0:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions_kv, cfg.rope_theta)
    return q, k, v


def _flash(q, k, v, *, causal: bool, q_chunk: int, kv_chunk: int,
           mesh, rules, kv_len: Optional[jnp.ndarray] = None):
    """Chunked online-softmax attention with GQA grouping.

    q (B,S,H,D), k/v (B,Skv,KVH,D).  Scans q chunks (outer) and kv chunks
    (inner); never materializes more than (B,KVH,G,Cq,Ck) scores.
    """
    B, S, H, D = q.shape
    Skv, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    q_chunk = min(q_chunk, S)
    kv_chunk = min(kv_chunk, Skv)
    # pad to chunk multiples; padded kv is masked out, padded q sliced off
    S_orig, Skv_orig = S, Skv
    if S % q_chunk:
        q = jnp.pad(q, ((0, 0), (0, -S % q_chunk), (0, 0), (0, 0)))
        S = q.shape[1]
    if Skv % kv_chunk:
        pad = -Skv % kv_chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Skv = k.shape[1]
        kv_len = jnp.minimum(
            Skv_orig if kv_len is None else kv_len, Skv_orig
        )
    nq, nk = S // q_chunk, Skv // kv_chunk
    scale = 1.0 / np.sqrt(D)

    qb = q.reshape(B, nq, q_chunk, KVH, G, D)
    kb = k.reshape(B, nk, kv_chunk, KVH, D)
    vb = v.reshape(B, nk, kv_chunk, KVH, D)
    # scan carries move the chunk axis to the front
    qb = jnp.moveaxis(qb, 1, 0)  # (nq, B, Cq, KVH, G, D)
    kb = jnp.moveaxis(kb, 1, 0)
    vb = jnp.moveaxis(vb, 1, 0)

    def q_step(_, qi_qc):
        qi, qc = qi_qc  # chunk index, (B, Cq, KVH, G, D)
        q_pos = qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, ki_kc):
            acc, mx, dn = carry
            ki, kc, vc = ki_kc
            k_pos = ki * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum(
                "bqkgd,bckd->bkgqc", qc, kc, preferred_element_type=jnp.float32
            ) * scale  # (B,KVH,G,Cq,Ck) f32
            mask = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                mask &= q_pos[:, None] >= k_pos[None, :]
            if kv_len is not None:
                mask &= k_pos[None, :] < kv_len
            s = jnp.where(mask, s, -1e30)
            m_new = jnp.maximum(mx, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(mx - m_new)
            dn = dn * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum(
                "bkgqc,bckd->bkgqd", p.astype(vc.dtype), vc,
                preferred_element_type=jnp.float32,
            )
            acc = acc * corr[..., None] + pv
            return (acc, m_new, dn), None

        acc0 = jnp.zeros((B, KVH, G, q_chunk, D), jnp.float32)
        m0 = jnp.full((B, KVH, G, q_chunk), -1e30, jnp.float32)
        d0 = jnp.zeros((B, KVH, G, q_chunk), jnp.float32)
        (acc, _, dn), _ = jax.lax.scan(
            kv_step, (acc0, m0, d0), (jnp.arange(nk), kb, vb)
        )
        out = acc / jnp.maximum(dn[..., None], 1e-30)  # (B,KVH,G,Cq,D)
        out = jnp.moveaxis(out, 3, 1).reshape(B, q_chunk, KVH * G, D)
        return None, out.astype(q.dtype)

    _, chunks = jax.lax.scan(q_step, None, (jnp.arange(nq), qb))
    out = jnp.moveaxis(chunks, 0, 1).reshape(B, S, H, D)[:, :S_orig]
    return constrain(out, mesh, ("batch", "seq", "heads", "d_head"), rules)


def attention(
    p,
    x,
    cfg,
    *,
    mesh=None,
    rules: ShardingRules = DEFAULT_RULES,
    causal: bool = True,
    x_kv: Optional[jnp.ndarray] = None,   # cross-attention source
    positions: Optional[jnp.ndarray] = None,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Full-sequence attention (train / prefill).  Returns (y, kv_cache)."""
    B, S, _ = x.shape
    x_kv = x if x_kv is None else x_kv
    Skv = x_kv.shape[1]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    pos_kv = jnp.broadcast_to(jnp.arange(Skv), (B, Skv))
    q, k, v = _qkv(p, x, x_kv, cfg, positions, pos_kv)
    # internals prefer head/TP sharding; under sequence-parallel rules the
    # seq→model assignment applies only to the residual stream, so GSPMD
    # places the SP gather/scatter at the layer boundary.
    rules_i = rules.replace(seq=None)
    q = constrain(q, mesh, ("batch", "seq", "heads", "d_head"), rules_i)
    k = constrain(k, mesh, ("batch", "seq", "kv_heads", "d_head"), rules_i)
    v = constrain(v, mesh, ("batch", "seq", "kv_heads", "d_head"), rules_i)
    out = _flash(
        q, k, v, causal=causal, q_chunk=q_chunk, kv_chunk=kv_chunk,
        mesh=mesh, rules=rules_i,
    )
    y = jnp.einsum(
        "bshd,hdm->bsm", out.astype(COMPUTE_DTYPE), p["wo"].astype(COMPUTE_DTYPE)
    )
    cache = {"k": k, "v": v}
    return constrain(y, mesh, ("batch", "seq", "d_model"), rules), cache


def attention_decode(
    p,
    x,          # (B, 1, M) current token activations
    cache,      # {"k": (B, Smax, KVH, D), "v": ...} — kv_seq sharded
    pos,        # scalar int32 — current position (same across batch)
    cfg,
    *,
    mesh=None,
    rules: ShardingRules = DEFAULT_RULES,
    cross: bool = False,   # cross-attention: cache is static, no update
    cross_len: Optional[int] = None,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Single-token decode with flash-decode semantics.

    The KV cache is sequence-sharded over the model axis (DESIGN.md §6): the
    softmax over the sharded sequence dim lowers to partial max/sum +
    all-reduce — XLA's distributed flash-decode.
    """
    B = x.shape[0]
    positions = jnp.broadcast_to(pos, (B, 1))
    q, k_new, v_new = _qkv(p, x, x, cfg, positions, positions)
    if cross:
        k, v = cache["k"], cache["v"]
        kv_len = cross_len if cross_len is not None else k.shape[1]
    else:
        k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype), (0, pos, 0, 0))
        v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype), (0, pos, 0, 0))
        k = constrain(k, mesh, ("batch", "kv_seq", "kv_heads", "d_head"), rules)
        v = constrain(v, mesh, ("batch", "kv_seq", "kv_heads", "d_head"), rules)
        kv_len = pos + 1
    Smax, KVH = k.shape[1], k.shape[2]
    H = q.shape[2]
    G = H // KVH
    qg = q.reshape(B, KVH, G, -1)  # (B,KVH,G,D) — S=1 squeezed
    s = jnp.einsum(
        "bkgd,bckd->bkgc", qg, k, preferred_element_type=jnp.float32
    ) / np.sqrt(cfg.d_head)
    live = jnp.arange(Smax) < kv_len
    s = jnp.where(live[None, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bkgc,bckd->bkgd", w.astype(v.dtype), v, preferred_element_type=jnp.float32
    )
    out = out.reshape(B, 1, H, cfg.d_head).astype(COMPUTE_DTYPE)
    y = jnp.einsum("bshd,hdm->bsm", out, p["wo"].astype(COMPUTE_DTYPE))
    new_cache = cache if cross else {"k": k, "v": v}
    return constrain(y, mesh, ("batch", "seq", "d_model"), rules), new_cache


# ---------------------------------------------------------------------------
# Dense MLP
# ---------------------------------------------------------------------------
def mlp_defs(cfg, d_ff: Optional[int] = None) -> Dict[str, ParamDef]:
    M = cfg.d_model
    F = d_ff or cfg.d_ff
    defs = {"wo": ParamDef((F, M), ("d_ff", "d_model"))}
    if cfg.act == "swiglu":
        defs["wi"] = ParamDef((M, 2, F), ("d_model", None, "d_ff"))
    else:
        defs["wi"] = ParamDef((M, F), ("d_model", "d_ff"))
    return defs


def mlp(p, x, cfg, *, mesh=None, rules: ShardingRules = DEFAULT_RULES):
    cd = COMPUTE_DTYPE
    xc = x.astype(cd)
    if cfg.act == "swiglu":
        gu = jnp.einsum("bsm,mtf->bstf", xc, p["wi"].astype(cd))
        h = jax.nn.silu(gu[:, :, 0]) * gu[:, :, 1]
    elif cfg.act == "gelu":
        h = jax.nn.gelu(jnp.einsum("bsm,mf->bsf", xc, p["wi"].astype(cd)))
    elif cfg.act == "relu_sq":
        h = jnp.square(jax.nn.relu(jnp.einsum("bsm,mf->bsf", xc, p["wi"].astype(cd))))
    else:
        raise ValueError(cfg.act)
    h = constrain(h, mesh, ("batch", "seq", "d_ff"), rules.replace(seq=None))
    y = jnp.einsum("bsf,fm->bsm", h, p["wo"].astype(cd))
    return constrain(y, mesh, ("batch", "seq", "d_model"), rules)


# ---------------------------------------------------------------------------
# Embeddings / LM head
# ---------------------------------------------------------------------------
def embed_defs(cfg) -> Dict[str, ParamDef]:
    defs = {
        "tok": ParamDef((cfg.vocab, cfg.d_model), ("vocab", "d_model"), init="embed", scale=0.02)
    }
    if not cfg.tie_embeddings:
        defs["head"] = ParamDef((cfg.d_model, cfg.vocab), ("d_model", "vocab"))
    return defs
