"""Mixture-of-Experts FFN: top-k router + GShard-style capacity dispatch.

Design (DESIGN.md §6):
  * experts sharded over the `model` mesh axis (EP); the dispatch/combine
    einsums are where GSPMD materializes the all-to-all traffic.
  * sequence-chunked: the (B, C, E, cap) dispatch tensor is bounded by
    chunking the sequence (cap scales with the chunk, keeping the buffer
    ~capacity_factor × activation size regardless of S).
  * decode (S == 1) folds the batch into the token group instead, so expert
    compute stays ≈ active-FLOPs × capacity_factor rather than E×.
  * router in f32; auxiliary load-balancing loss (Switch-style) returned to
    the caller.

The per-token group capacity is cap = ceil(tokens_per_group · top_k / E ·
capacity_factor); overflow tokens are dropped (combine weight 0) — the
standard dropping MoE, which keeps every shape static for pjit.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding import DEFAULT_RULES, ShardingRules, constrain

from .layers import COMPUTE_DTYPE
from .params import ParamDef

__all__ = ["moe_defs", "moe_ffn"]


def moe_defs(cfg) -> Dict[str, ParamDef]:
    M, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    defs = {
        "router": ParamDef((M, E), ("d_model", "experts"), scale=0.02),
        "wo": ParamDef((E, F, M), ("experts", "d_ff", "d_model")),
    }
    if cfg.act == "swiglu":
        defs["wi"] = ParamDef((E, M, 2, F), ("experts", "d_model", None, "d_ff"))
    else:
        defs["wi"] = ParamDef((E, M, F), ("experts", "d_model", "d_ff"))
    return defs


def _top_k_mask(probs: jnp.ndarray, k: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Iterative top-k: returns (gates (..., k), onehot (..., k, E))."""
    E = probs.shape[-1]
    p = probs
    gates, onehots = [], []
    for _ in range(k):
        idx = jnp.argmax(p, axis=-1)
        oh = jax.nn.one_hot(idx, E, dtype=probs.dtype)
        gates.append(jnp.sum(p * oh, axis=-1))
        onehots.append(oh)
        p = p * (1.0 - oh)
    return jnp.stack(gates, axis=-1), jnp.stack(onehots, axis=-2)


def _dispatch_combine(probs, k: int, cap: int):
    """Build the (G, T, E, cap) combine tensor for one token group axis.

    probs: (G, T, E) router probabilities (f32); G groups of T tokens.
    Returns (combine (G,T,E,cap) f32, aux_loss scalar).
    """
    G, T, E = probs.shape
    gates, onehot = _top_k_mask(probs, k)  # (G,T,k), (G,T,k,E)
    # position of each (token, choice) within its expert queue, priority =
    # (token index, then choice rank): flatten (T, k)
    flat = onehot.reshape(G, T * k, E)
    pos = jnp.cumsum(flat, axis=1) - flat  # 0-based positions
    pos = jnp.sum(pos * flat, axis=-1).reshape(G, T, k)  # (G,T,k)
    keep = (pos < cap).astype(probs.dtype)
    gates = gates * keep
    # renormalize kept gates (standard for top-k>1)
    denom = jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    gates = gates / denom
    pos_oh = jax.nn.one_hot(pos, cap, dtype=probs.dtype) * keep[..., None]
    # combine[g,t,e,c] = Σ_k gate · onehot_e · onehot_c
    combine = jnp.einsum("gtk,gtke,gtkc->gtec", gates, onehot, pos_oh)
    # Switch aux loss: E · Σ_e mean_tokens(frac routed to e) · mean(prob e)
    frac = jnp.mean(onehot[:, :, 0, :], axis=1)  # top-1 routing fraction (G,E)
    mprob = jnp.mean(probs, axis=1)
    aux = E * jnp.mean(jnp.sum(frac * mprob, axis=-1))
    return combine, aux


def _expert_compute(p, xin, cfg):
    """xin: (E, G, cap, M) → (E, G, cap, M)."""
    cd = COMPUTE_DTYPE
    xin = xin.astype(cd)
    if cfg.act == "swiglu":
        gu = jnp.einsum("egcm,emtf->egctf", xin, p["wi"].astype(cd))
        h = jax.nn.silu(gu[..., 0, :]) * gu[..., 1, :]
    else:
        h = jax.nn.gelu(jnp.einsum("egcm,emf->egcf", xin, p["wi"].astype(cd)))
    return jnp.einsum("egcf,efm->egcm", h, p["wo"].astype(cd))


def _dispatch_gather(probs, k: int, cap: int):
    """Scatter/gather routing metadata (no (G,T,E,cap) one-hot tensors).

    Returns (e_idx, pos, gates, keep): each (G, T, k).  The one-hot
    ``combine`` einsum form costs O(T·E·cap·M) FLOPs+bytes; this form costs
    O(T·k·M) — the §Perf 'gather-MoE' optimization.  Bit-equivalent routing
    (same experts, same positions, same gates) — property-tested.
    """
    gates, onehot = _top_k_mask(probs, k)  # (G,T,k), (G,T,k,E)
    flat = onehot.reshape(onehot.shape[0], -1, onehot.shape[-1])
    pos = jnp.cumsum(flat, axis=1) - flat
    pos = jnp.sum(pos * flat, axis=-1).reshape(gates.shape).astype(jnp.int32)
    e_idx = jnp.argmax(onehot, axis=-1).astype(jnp.int32)  # (G,T,k)
    keep = pos < cap
    gates = gates * keep
    denom = jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    gates = gates / denom
    # Switch aux loss (same as einsum path)
    E = probs.shape[-1]
    frac = jnp.mean(onehot[:, :, 0, :], axis=1)
    mprob = jnp.mean(probs, axis=1)
    aux = E * jnp.mean(jnp.sum(frac * mprob, axis=-1))
    return e_idx, pos, gates, keep, aux


def moe_ffn(
    p,
    x,  # (B, S, M)
    cfg,
    *,
    mesh=None,
    rules: ShardingRules = DEFAULT_RULES,
    seq_chunk: int = 512,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y (B,S,M), aux_loss scalar f32)."""
    B, S, M = x.shape
    E, K = cfg.n_experts, cfg.top_k
    cd = COMPUTE_DTYPE
    impl = getattr(cfg, "moe_impl", "einsum")

    def run_group_einsum(xg):
        """xg: (G, T, M) — G token groups of T tokens each."""
        G, T, _ = xg.shape
        cap = max(1, int(np.ceil(T * K / E * cfg.capacity_factor)))
        logits = jnp.einsum(
            "gtm,me->gte", xg.astype(jnp.float32), p["router"].astype(jnp.float32)
        )
        probs = jax.nn.softmax(logits, axis=-1)
        combine, aux = _dispatch_combine(probs, K, cap)  # (G,T,E,cap)
        dispatch = (combine > 0).astype(cd)
        xin = jnp.einsum("gtec,gtm->egcm", dispatch, xg.astype(cd))
        xin = constrain(xin, mesh, ("experts", "batch", None, "d_model"), rules)
        xout = _expert_compute(p, xin, cfg)
        xout = constrain(xout, mesh, ("experts", "batch", None, "d_model"), rules)
        y = jnp.einsum("gtec,egcm->gtm", combine.astype(cd), xout)
        return y, aux

    def run_group_gather(xg):
        """Scatter-add dispatch / gather combine (no one-hot einsums)."""
        G, T, _ = xg.shape
        cap = max(1, int(np.ceil(T * K / E * cfg.capacity_factor)))
        logits = jnp.einsum(
            "gtm,me->gte", xg.astype(jnp.float32), p["router"].astype(jnp.float32)
        )
        probs = jax.nn.softmax(logits, axis=-1)
        e_idx, pos, gates, keep, aux = _dispatch_gather(probs, K, cap)
        g_ar = jnp.arange(G)[:, None, None]
        t_ar = jnp.broadcast_to(jnp.arange(T)[None, :, None], (G, T, K))
        pos_c = jnp.where(keep, pos, cap)  # dropped → scatter into pad slot
        xin = jnp.zeros((E, G, cap + 1, M), cd)
        xin = xin.at[e_idx, g_ar, pos_c].add(
            jnp.broadcast_to(xg[:, :, None, :], (G, T, K, M)).astype(cd)
        )
        xin = constrain(xin[:, :, :cap], mesh,
                        ("experts", "batch", None, "d_model"), rules)
        xout = _expert_compute(p, xin, cfg)
        xout = constrain(xout, mesh, ("experts", "batch", None, "d_model"), rules)
        y_tok = xout[e_idx, g_ar, jnp.minimum(pos, cap - 1)]  # (G,T,K,M)
        y = jnp.sum(y_tok * gates[..., None].astype(cd), axis=2)
        return y.astype(cd), aux

    run_group = run_group_gather if impl == "gather" else run_group_einsum

    if S == 1:
        # decode: fold batch into the token group
        y, aux = run_group(x.reshape(1, B, M))
        y = y.reshape(B, 1, M)
        return constrain(y, mesh, ("batch", "seq", "d_model"), rules), aux

    chunk = min(seq_chunk, S)
    if S % chunk:
        chunk = S  # odd lengths: single group (shapes here are powers of two)
    n_chunks = S // chunk

    if n_chunks == 1:
        y, aux = run_group(x)
        return constrain(y, mesh, ("batch", "seq", "d_model"), rules), aux

    xc = jnp.moveaxis(x.reshape(B, n_chunks, chunk, M), 1, 0)

    if not getattr(cfg, "scan_layers", True):
        # analysis lowering: unroll so XLA's cost model counts every chunk
        # (identical math — same chunk size, same capacity semantics)
        outs = [run_group(xc[i]) for i in range(n_chunks)]
        y = jnp.moveaxis(jnp.stack([o[0] for o in outs]), 0, 1).reshape(B, S, M)
        aux = jnp.mean(jnp.stack([o[1] for o in outs]))
        return constrain(y, mesh, ("batch", "seq", "d_model"), rules), aux

    def step(_, xg):
        y, aux = run_group(xg)
        return None, (y, aux)

    _, (ys, auxs) = jax.lax.scan(step, None, xc)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, M)
    return constrain(y, mesh, ("batch", "seq", "d_model"), rules), jnp.mean(auxs)
