"""Composable LM: dense / MoE / hybrid(Mamba) / RWKV / enc-dec architectures.

A model is ``n_layers`` layers arranged as repeats of a *block pattern* —
a tuple of (mixer, ffn) pairs, e.g.

  granite/qwen/mistral/phi3v : (("attn",  "dense"),)
  olmoe/moonshot             : (("attn",  "moe"),)
  rwkv6                      : (("rwkv",  "rwkv"),)
  jamba (1 attn : 7 mamba,   : (("attn","moe"),("mamba","dense"),("mamba","moe"),
         MoE every 2nd layer)   ("mamba","dense"),("mamba","moe"),("mamba","dense"),
                                ("mamba","moe"),("mamba","dense"))

Parameters for one pattern-repeat ("group") are stacked on a leading axis
and the stack is driven by ``lax.scan`` (compact HLO for 88-layer models),
with per-group ``jax.checkpoint`` (remat).  KV/SSM caches mirror the same
(groups, ...) stacking and thread through the scan for prefill/decode.

Three entry points (all mesh/rules-aware, pure functions of params):
  forward(...)            -> final hidden states (training)
  prefill(...)            -> (last-position logits, caches)
  decode_step(...)        -> (logits, updated caches)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding import DEFAULT_RULES, constrain

from . import layers as L
from . import mamba as MB
from . import moe as MOE
from . import rwkv as RW
from .params import ParamDef, stack_defs

__all__ = ["ModelConfig", "model_defs", "cache_defs", "forward", "prefill",
           "decode_step", "encode", "lm_head_logits"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    block: Tuple[Tuple[str, str], ...] = (("attn", "dense"),)
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_seq_chunk: int = 512
    moe_impl: str = "einsum"  # 'einsum' (GShard dispatch) | 'gather' (§Perf)
    # attention
    qk_norm: bool = False
    rope_theta: float = 1e4  # 0 → no RoPE (whisper uses absolute positions)
    pos_embed: str = "rope"  # 'rope' | 'learned' | 'sincos'
    max_pos: int = 0         # size of learned position table (0 = unused)
    q_chunk: int = 1024
    kv_chunk: int = 1024
    # mamba
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0
    # rwkv
    rwkv_head_dim: int = 64
    # enc-dec (whisper): encoder_layers > 0 adds an encoder + cross-attn
    encoder_layers: int = 0
    n_frames: int = 1500
    # frontends (stubs per spec)
    frontend: str = "none"  # 'none' | 'vision' | 'audio'
    n_patches: int = 0
    # numerics / structure
    norm: str = "rmsnorm"
    act: str = "swiglu"
    tie_embeddings: bool = False
    remat: str = "full"  # 'full' | 'none'
    # scan_layers=False unrolls the group stack (python loop) — used by the
    # roofline analysis lowering, where XLA's count-loop-bodies-once cost
    # model would otherwise undercount FLOPs by ~n_groups×.
    scan_layers: bool = True
    # checkpoint every k-th group instead of every group: divides the
    # remat activation stash by k at the cost of re-running k layers per
    # backward segment (total recompute unchanged ≈ 1 forward) — §Perf knob.
    remat_block: int = 1

    @property
    def n_groups(self) -> int:
        assert self.n_layers % len(self.block) == 0, (self.n_layers, len(self.block))
        return self.n_layers // len(self.block)

    @property
    def sub_quadratic(self) -> bool:
        """True if decode state is O(1)-ish per token (SSM / hybrid)."""
        return any(mixer in ("mamba", "rwkv") for mixer, _ in self.block)

    @property
    def pure_attention(self) -> bool:
        return all(mixer == "attn" for mixer, _ in self.block)


# ---------------------------------------------------------------------------
# Parameter definitions
# ---------------------------------------------------------------------------
def _mixer_defs(cfg, mixer: str):
    if mixer == "attn":
        return L.attn_defs(cfg)
    if mixer == "mamba":
        return MB.mamba_defs(cfg)
    if mixer == "rwkv":
        return RW.rwkv_defs(cfg)
    raise ValueError(mixer)


def _ffn_defs(cfg, ffn: str):
    if ffn == "dense":
        return L.mlp_defs(cfg)
    if ffn == "moe":
        return MOE.moe_defs(cfg)
    if ffn == "rwkv":
        return RW.rwkv_channel_defs(cfg)
    raise ValueError(ffn)


def _group_defs(cfg, cross_attn: bool = False):
    defs = {}
    for li, (mixer, ffn) in enumerate(cfg.block):
        d = {
            "norm1": L.norm_defs(cfg.d_model, cfg.norm),
            "mixer": _mixer_defs(cfg, mixer),
            "norm2": L.norm_defs(cfg.d_model, cfg.norm),
            "ffn": _ffn_defs(cfg, ffn),
        }
        if cross_attn:
            d["norm_x"] = L.norm_defs(cfg.d_model, cfg.norm)
            d["cross"] = L.attn_defs(cfg)
        defs[f"l{li}"] = d
    return defs


def _encoder_group_defs(cfg):
    return {
        "l0": {
            "norm1": L.norm_defs(cfg.d_model, cfg.norm),
            "mixer": L.attn_defs(cfg),
            "norm2": L.norm_defs(cfg.d_model, cfg.norm),
            "ffn": L.mlp_defs(cfg),
        }
    }


def model_defs(cfg: ModelConfig):
    enc_dec = cfg.encoder_layers > 0
    defs: Dict[str, Any] = {
        "embed": L.embed_defs(cfg),
        "final_norm": L.norm_defs(cfg.d_model, cfg.norm),
        "decoder": stack_defs(_group_defs(cfg, cross_attn=enc_dec), cfg.n_groups),
    }
    if cfg.pos_embed == "learned":
        assert cfg.max_pos > 0, "learned positions need max_pos"
        defs["pos"] = ParamDef((cfg.max_pos, cfg.d_model), (None, "d_model"), scale=0.02)
    if enc_dec:
        defs["encoder"] = stack_defs(_encoder_group_defs(cfg), cfg.encoder_layers)
        defs["enc_norm"] = L.norm_defs(cfg.d_model, cfg.norm)
    return defs


# ---------------------------------------------------------------------------
# Cache definitions (ParamDef reuse: shapes/axes/shardings for free)
# ---------------------------------------------------------------------------
def _layer_cache_defs(cfg, mixer: str, ffn: str, batch: int, max_seq: int,
                      cross: bool = False):
    d: Dict[str, Any] = {}
    if mixer == "attn":
        kv = (batch, max_seq, cfg.n_kv_heads, cfg.d_head)
        axes = ("batch", "kv_seq", "kv_heads", "d_head")
        d["mixer"] = {
            "k": ParamDef(kv, axes, init="zeros", dtype=jnp.bfloat16),
            "v": ParamDef(kv, axes, init="zeros", dtype=jnp.bfloat16),
        }
    elif mixer == "mamba":
        di = cfg.expand * cfg.d_model
        d["mixer"] = {
            "conv": ParamDef((batch, cfg.d_conv - 1, di), ("batch", None, "d_ff"),
                             init="zeros", dtype=jnp.bfloat16),
            "ssm": ParamDef((batch, di, cfg.d_state), ("batch", "d_ff", "ssm_state"),
                            init="zeros", dtype=jnp.float32),
        }
    elif mixer == "rwkv":
        h = cfg.d_model // cfg.rwkv_head_dim
        dd = cfg.rwkv_head_dim
        d["mixer"] = {
            "shift": ParamDef((batch, cfg.d_model), ("batch", "d_model"),
                              init="zeros", dtype=jnp.bfloat16),
            "wkv": ParamDef((batch, h, dd, dd), ("batch", "heads", None, None),
                            init="zeros", dtype=jnp.float32),
        }
    if ffn == "rwkv":
        d["ffn"] = {
            "shift": ParamDef((batch, cfg.d_model), ("batch", "d_model"),
                              init="zeros", dtype=jnp.bfloat16)
        }
    if cross:
        kv = (batch, cfg.n_frames, cfg.n_kv_heads, cfg.d_head)
        axes = ("batch", None, "kv_heads", "d_head")
        d["cross"] = {
            "k": ParamDef(kv, axes, init="zeros", dtype=jnp.bfloat16),
            "v": ParamDef(kv, axes, init="zeros", dtype=jnp.bfloat16),
        }
    return d


def cache_defs(cfg: ModelConfig, batch: int, max_seq: int):
    enc_dec = cfg.encoder_layers > 0
    group = {
        f"l{li}": _layer_cache_defs(cfg, mixer, ffn, batch, max_seq, cross=enc_dec)
        for li, (mixer, ffn) in enumerate(cfg.block)
    }
    return {"decoder": stack_defs(group, cfg.n_groups)}


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------
def _embed_tokens(params, tokens, cfg, mesh, rules):
    tbl = params["embed"]["tok"]
    x = jnp.take(tbl, tokens, axis=0).astype(L.COMPUTE_DTYPE)
    return constrain(x, mesh, ("batch", "seq", "d_model"), rules)


def lm_head_logits(params, x, cfg, mesh=None, rules=DEFAULT_RULES):
    """x (B, S, M) → logits (B, S, V) f32 (caller chunks S for big V)."""
    head = (
        params["embed"]["tok"].T if cfg.tie_embeddings else params["embed"]["head"]
    )
    logits = jnp.einsum(
        "bsm,mv->bsv", x.astype(L.COMPUTE_DTYPE), head.astype(L.COMPUTE_DTYPE)
    ).astype(jnp.float32)
    return constrain(logits, mesh, ("batch", "seq", "vocab"), rules)


def _sincos_pos(S, M, offset=0):
    pos = np.arange(S)[:, None] + offset
    dim = np.arange(M // 2)[None, :]
    ang = pos / (10000 ** (2 * dim / M))
    emb = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(emb, jnp.float32)


def _add_positions(params, x, cfg, start):
    if cfg.pos_embed == "learned":
        S = x.shape[1]
        pos = jax.lax.dynamic_slice_in_dim(params["pos"], start, S, axis=0)
        return x + pos.astype(x.dtype)
    if cfg.pos_embed == "sincos":
        return x + _sincos_pos(x.shape[1], cfg.d_model, start).astype(x.dtype)
    return x  # rope handled inside attention


# ---------------------------------------------------------------------------
# One group (pattern-repeat) — full-sequence form
# ---------------------------------------------------------------------------
def _apply_group(
    gp, x, cfg, mesh, rules, *, make_cache: bool, enc_out=None, causal=True
):
    aux = jnp.zeros((), jnp.float32)
    caches = {}
    for li, (mixer, ffn) in enumerate(cfg.block):
        lp = gp[f"l{li}"]
        lcache: Dict[str, Any] = {}
        h = L.apply_norm(lp["norm1"], x, cfg.norm)
        if mixer == "attn":
            y, c = L.attention(
                lp["mixer"], h, cfg, mesh=mesh, rules=rules, causal=causal,
                q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
            )
            if make_cache:
                lcache["mixer"] = {
                    "k": constrain(c["k"].astype(jnp.bfloat16), mesh,
                                   ("batch", "kv_seq", "kv_heads", "d_head"), rules),
                    "v": constrain(c["v"].astype(jnp.bfloat16), mesh,
                                   ("batch", "kv_seq", "kv_heads", "d_head"), rules),
                }
        elif mixer == "mamba":
            y, c = MB.mamba(lp["mixer"], h, cfg, mesh=mesh, rules=rules)
            if make_cache:
                lcache["mixer"] = {"conv": c["conv"].astype(jnp.bfloat16),
                                   "ssm": c["ssm"]}
        elif mixer == "rwkv":
            y, c = RW.rwkv_time_mix(lp["mixer"], h, cfg, mesh=mesh, rules=rules)
            if make_cache:
                lcache["mixer"] = {"shift": c["shift"], "wkv": c["wkv"]}
        else:
            raise ValueError(mixer)
        x = x + y

        if enc_out is not None:
            h = L.apply_norm(lp["norm_x"], x, cfg.norm)
            y, cc = L.attention(
                lp["cross"], h, cfg, mesh=mesh, rules=rules, causal=False,
                x_kv=enc_out, q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
            )
            if make_cache:
                lcache["cross"] = {"k": cc["k"].astype(jnp.bfloat16),
                                   "v": cc["v"].astype(jnp.bfloat16)}
            x = x + y

        h = L.apply_norm(lp["norm2"], x, cfg.norm)
        if ffn == "dense":
            y = L.mlp(lp["ffn"], h, cfg, mesh=mesh, rules=rules)
        elif ffn == "moe":
            y, a = MOE.moe_ffn(lp["ffn"], h, cfg, mesh=mesh, rules=rules,
                               seq_chunk=cfg.moe_seq_chunk)
            aux = aux + a
        elif ffn == "rwkv":
            y, c = RW.rwkv_channel_mix(lp["ffn"], h, cfg, mesh=mesh, rules=rules)
            if make_cache:
                lcache["ffn"] = {"shift": c["shift"]}
        else:
            raise ValueError(ffn)
        x = x + y
        caches[f"l{li}"] = lcache
    return x, caches, aux


def _scan_stack(stack_params, x, cfg, mesh, rules, *, make_cache, enc_out=None,
                causal=True):
    def body(carry, gp):
        xx, aux_sum = carry
        xx, caches, aux = _apply_group(
            gp, xx, cfg, mesh, rules, make_cache=make_cache,
            enc_out=enc_out, causal=causal,
        )
        return (xx, aux_sum + aux), caches

    k = cfg.remat_block
    if k > 1 and cfg.scan_layers and not make_cache:
        # super-group scan: k layer-groups per checkpointed scan step, so the
        # stash holds G/k residual-stream snapshots instead of G.
        G = jax.tree_util.tree_leaves(stack_params)[0].shape[0]
        assert G % k == 0, (G, k)
        sp = jax.tree_util.tree_map(
            lambda p: p.reshape((G // k, k) + p.shape[1:]), stack_params
        )
        inner = body

        def kbody(carry, gpk):
            for i in range(k):
                carry, _ = inner(carry, jax.tree_util.tree_map(lambda p: p[i], gpk))
            return carry, None

        if cfg.remat == "full":
            kbody = jax.checkpoint(
                kbody, policy=jax.checkpoint_policies.nothing_saveable
            )
        (x, aux), _ = jax.lax.scan(kbody, (x, jnp.zeros((), jnp.float32)), sp)
        return x, None, aux

    if cfg.remat == "full":
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)

    if not cfg.scan_layers:  # unrolled (analysis lowering)
        n_groups = jax.tree_util.tree_leaves(stack_params)[0].shape[0]
        carry = (x, jnp.zeros((), jnp.float32))
        all_caches = []
        for g in range(n_groups):
            gp = jax.tree_util.tree_map(lambda p: p[g], stack_params)
            carry, caches = body(carry, gp)
            all_caches.append(caches)
        (x, aux) = carry
        caches = (
            jax.tree_util.tree_map(lambda *cs: jnp.stack(cs), *all_caches)
            if make_cache else all_caches[0]
        )
        return x, caches, aux

    (x, aux), caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), stack_params)
    return x, caches, aux


# ---------------------------------------------------------------------------
# Encoder (whisper)
# ---------------------------------------------------------------------------
def encode(params, frames, cfg, *, mesh=None, rules=DEFAULT_RULES):
    """frames (B, F, M) — precomputed conv-frontend embeddings (stub)."""
    x = frames.astype(L.COMPUTE_DTYPE)
    x = x + _sincos_pos(x.shape[1], cfg.d_model).astype(x.dtype)
    x = constrain(x, mesh, ("batch", "seq", "d_model"), rules)
    x, _, _ = _scan_stack(
        params["encoder"], x, cfg, mesh, rules, make_cache=False, causal=False
    )
    return L.apply_norm(params["enc_norm"], x, cfg.norm)


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------
def _embed_inputs(params, batch, cfg, mesh, rules, start=0):
    """tokens + optional frontend embeddings → (B, S, M)."""
    x = _embed_tokens(params, batch["tokens"], cfg, mesh, rules)
    if cfg.frontend == "vision" and "patches" in batch:
        # stubbed CLIP tower: precomputed patch embeddings replace the prefix
        p = batch["patches"].astype(x.dtype)
        x = jnp.concatenate([p, x[:, cfg.n_patches :]], axis=1)
    x = _add_positions(params, x, cfg, start)
    return constrain(x, mesh, ("batch", "seq", "d_model"), rules)


def forward(params, batch, cfg, *, mesh=None, rules=DEFAULT_RULES):
    """Training forward → (hidden (B,S,M), aux_loss)."""
    enc_out = None
    if cfg.encoder_layers > 0:
        enc_out = encode(params, batch["frames"], cfg, mesh=mesh, rules=rules)
    x = _embed_inputs(params, batch, cfg, mesh, rules)
    x, _, aux = _scan_stack(
        params["decoder"], x, cfg, mesh, rules, make_cache=False, enc_out=enc_out
    )
    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    return x, aux


def prefill(params, batch, cfg, *, mesh=None, rules=DEFAULT_RULES, max_seq=None):
    """Prefill → (last-position logits (B,V), caches).

    Caches are padded to ``max_seq`` (defaults to S) so decode can continue.
    """
    enc_out = None
    if cfg.encoder_layers > 0:
        enc_out = encode(params, batch["frames"], cfg, mesh=mesh, rules=rules)
    x = _embed_inputs(params, batch, cfg, mesh, rules)
    S = x.shape[1]
    x, caches, _ = _scan_stack(
        params["decoder"], x, cfg, mesh, rules, make_cache=True, enc_out=enc_out
    )
    max_seq = max_seq or S
    if max_seq != S:
        caches = jax.tree_util.tree_map(
            lambda c: _pad_cache_seq(c, max_seq) if _is_kv(c, S) else c, caches
        )
    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    logits = lm_head_logits(params, x[:, -1:], cfg, mesh, rules)[:, 0]
    return logits, {"decoder": caches}


def _is_kv(c, S):
    return c.ndim == 5 and c.shape[2] == S  # (G, B, S, KVH, D)


def _pad_cache_seq(c, max_seq):
    pad = max_seq - c.shape[2]
    return jnp.pad(c, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))


def decode_step(params, caches, token, pos, cfg, *, mesh=None, rules=DEFAULT_RULES):
    """One decode step.  token (B,), pos scalar int32 → (logits (B,V), caches)."""
    batch = {"tokens": token[:, None]}
    x = _embed_tokens(params, batch["tokens"], cfg, mesh, rules)
    if cfg.pos_embed == "learned":
        x = x + jax.lax.dynamic_slice_in_dim(params["pos"], pos, 1, axis=0).astype(x.dtype)
    elif cfg.pos_embed == "sincos":
        # decode with sincos uses rope-free absolute positions via lookup
        x = x + _sincos_table_lookup(cfg, pos).astype(x.dtype)

    def body(carry, gp_cache):
        xx = carry
        gp, gc = gp_cache
        xx, new_gc = _decode_group(gp, gc, xx, pos, cfg, mesh, rules)
        return xx, new_gc

    if not cfg.scan_layers:  # unrolled (analysis lowering)
        n_groups = jax.tree_util.tree_leaves(params["decoder"])[0].shape[0]
        outs = []
        for g in range(n_groups):
            gp = jax.tree_util.tree_map(lambda p: p[g], params["decoder"])
            gc = jax.tree_util.tree_map(lambda c: c[g], caches["decoder"])
            x, new_gc = body(x, (gp, gc))
            outs.append(new_gc)
        new_caches = jax.tree_util.tree_map(lambda *cs: jnp.stack(cs), *outs)
        x = L.apply_norm(params["final_norm"], x, cfg.norm)
        logits = lm_head_logits(params, x, cfg, mesh, rules)[:, 0]
        return logits, {"decoder": new_caches}

    x, new_caches = jax.lax.scan(body, x, (params["decoder"], caches["decoder"]))
    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    logits = lm_head_logits(params, x, cfg, mesh, rules)[:, 0]
    return logits, {"decoder": new_caches}


def _sincos_table_lookup(cfg, pos):
    # small closed-form sincos for a single position
    M = cfg.d_model
    dim = jnp.arange(M // 2, dtype=jnp.float32)
    ang = pos.astype(jnp.float32) / (10000 ** (2 * dim / M))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])[None, None, :]


def _decode_group(gp, gc, x, pos, cfg, mesh, rules):
    new_cache = {}
    for li, (mixer, ffn) in enumerate(cfg.block):
        lp = gp[f"l{li}"]
        lc = gc[f"l{li}"]
        nc: Dict[str, Any] = {}
        h = L.apply_norm(lp["norm1"], x, cfg.norm)
        if mixer == "attn":
            y, c = L.attention_decode(
                lp["mixer"], h, lc["mixer"], pos, cfg, mesh=mesh, rules=rules
            )
            nc["mixer"] = c
        elif mixer == "mamba":
            y, c = MB.mamba_decode(lp["mixer"], h, lc["mixer"], cfg, mesh=mesh, rules=rules)
            nc["mixer"] = {"conv": c["conv"].astype(jnp.bfloat16), "ssm": c["ssm"]}
        elif mixer == "rwkv":
            y, c = RW.rwkv_time_mix_decode(lp["mixer"], h, lc["mixer"], cfg, mesh=mesh, rules=rules)
            nc["mixer"] = {"shift": c["shift"], "wkv": c["wkv"]}
        x = x + y

        if "cross" in lc:
            h = L.apply_norm(lp["norm_x"], x, cfg.norm)
            y, _ = L.attention_decode(
                lp["cross"], h, lc["cross"], pos, cfg, mesh=mesh, rules=rules,
                cross=True,
            )
            nc["cross"] = lc["cross"]
            x = x + y

        h = L.apply_norm(lp["norm2"], x, cfg.norm)
        if ffn == "dense":
            y = L.mlp(lp["ffn"], h, cfg, mesh=mesh, rules=rules)
        elif ffn == "moe":
            y, _ = MOE.moe_ffn(lp["ffn"], h, cfg, mesh=mesh, rules=rules)
        elif ffn == "rwkv":
            y, c = RW.rwkv_channel_mix_decode(lp["ffn"], h, lc["ffn"], cfg, mesh=mesh, rules=rules)
            nc["ffn"] = {"shift": c["shift"]}
        x = x + y
        new_cache[f"l{li}"] = nc
    return x, new_cache
