"""Parameter definition system: one source of truth for shape/axes/init.

Each layer exposes ``*_defs(cfg) -> nested dict of ParamDef``; from that tree
we derive, guaranteed-consistent:

* ``init_params``      — materialized fp32 arrays (deterministic per path),
* ``param_shapes``     — ShapeDtypeStructs (the dry-run lowers 398B-param
                         models without allocating a byte),
* ``param_pspecs``     — PartitionSpecs via the logical-axis rules,
* ``param_shardings``  — NamedShardings for a concrete mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.sharding import DEFAULT_RULES, ShardingRules, logical_to_spec

__all__ = [
    "ParamDef",
    "init_params",
    "param_shapes",
    "param_pspecs",
    "param_shardings",
    "stack_defs",
    "tree_defs_map",
]


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"  # 'normal' | 'zeros' | 'ones' | 'embed'
    scale: Optional[float] = None  # stddev override for 'normal'
    dtype: Any = jnp.float32

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} / axes {self.axes} rank mismatch")


def _is_def(x) -> bool:
    return isinstance(x, ParamDef)


def tree_defs_map(fn, defs):
    return jax.tree_util.tree_map(fn, defs, is_leaf=_is_def)


def stack_defs(defs, n: int, axis_name: Optional[str] = "layers"):
    """Prepend a stacking dim (scan-over-layers parameter stacking)."""
    return tree_defs_map(
        lambda d: dataclasses.replace(
            d, shape=(n,) + d.shape, axes=(axis_name,) + d.axes
        ),
        defs,
    )


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def init_params(defs, key: jax.Array):
    """Deterministic init: each leaf keyed by fold_in(hash(path))."""

    def init_one(path, d: ParamDef):
        k = jax.random.fold_in(key, np.uint32(hash(_path_str(path)) & 0x7FFFFFFF))
        if d.init == "zeros":
            return jnp.zeros(d.shape, d.dtype)
        if d.init == "ones":
            return jnp.ones(d.shape, d.dtype)
        fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
        std = d.scale if d.scale is not None else 1.0 / np.sqrt(max(fan_in, 1))
        if d.init == "embed":
            std = d.scale if d.scale is not None else 1.0
        return (jax.random.normal(k, d.shape, jnp.float32) * std).astype(d.dtype)

    return jax.tree_util.tree_map_with_path(init_one, defs, is_leaf=_is_def)


def param_shapes(defs):
    return tree_defs_map(lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), defs)


def param_pspecs(defs, mesh: Mesh, rules: ShardingRules = DEFAULT_RULES):
    return tree_defs_map(lambda d: logical_to_spec(mesh, d.shape, d.axes, rules), defs)


def param_shardings(defs, mesh: Mesh, rules: ShardingRules = DEFAULT_RULES):
    return tree_defs_map(
        lambda d: NamedSharding(mesh, logical_to_spec(mesh, d.shape, d.axes, rules)),
        defs,
    )
