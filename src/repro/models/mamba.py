"""Mamba (S6 selective SSM) block — the 'mamba' layers of Jamba-1.5.

Faithful Mamba-1 structure (Gu & Dao 2023; Jamba arXiv:2403.19887):
  in_proj   : M → 2·d_inner  (x branch, z gate branch)
  conv1d    : depthwise causal, width d_conv, over the x branch
  selection : x → (dt_low (dt_rank), B (d_state), C (d_state));
              dt = softplus(dt_low @ W_dt + dt_bias)
  SSM       : h_t = exp(dt·A) ⊙ h_{t-1} + (dt·B_t) · x_t ;  y_t = C_t·h_t + D·x_t
  out       : (y ⊙ silu(z)) @ out_proj → M

Train/prefill run a `lax.scan` over the sequence (state (B, d_inner, N));
decode is a single fused state update.  The recurrence is O(L·d_inner·N) —
negligible next to the projections, so the scan form is the right TPU
baseline (see DESIGN.md; an associative-scan variant trades 2× FLOPs for
parallel depth and is a §Perf candidate for long_500k).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.sharding import DEFAULT_RULES, ShardingRules, constrain

from .layers import COMPUTE_DTYPE
from .params import ParamDef

__all__ = ["mamba_defs", "mamba", "mamba_decode", "mamba_init_cache"]


def _dims(cfg):
    d_inner = cfg.expand * cfg.d_model
    dt_rank = cfg.dt_rank or max(1, cfg.d_model // 16)
    return d_inner, dt_rank, cfg.d_state, cfg.d_conv


def mamba_defs(cfg) -> Dict[str, ParamDef]:
    M = cfg.d_model
    DI, R, N, K = _dims(cfg)
    return {
        "in_proj": ParamDef((M, 2, DI), ("d_model", None, "d_ff")),
        "conv_w": ParamDef((K, DI), (None, "d_ff"), scale=0.5),
        "conv_b": ParamDef((DI,), ("d_ff",), init="zeros"),
        "x_proj": ParamDef((DI, R + 2 * N), ("d_ff", None)),
        "dt_proj": ParamDef((R, DI), (None, "d_ff"), scale=0.1),
        "dt_bias": ParamDef((DI,), ("d_ff",), init="zeros"),
        "a_log": ParamDef((DI, N), ("d_ff", "ssm_state"), init="zeros"),
        "d_skip": ParamDef((DI,), ("d_ff",), init="ones"),
        "out_proj": ParamDef((DI, M), ("d_ff", "d_model")),
    }


def _selection(p, xc, cfg):
    """xc (..., DI) → dt (..., DI), Bm (..., N), Cm (..., N), all f32."""
    DI, R, N, _ = _dims(cfg)
    proj = jnp.einsum(
        "...d,dr->...r", xc.astype(jnp.float32), p["x_proj"].astype(jnp.float32)
    )
    dt_low, Bm, Cm = proj[..., :R], proj[..., R : R + N], proj[..., R + N :]
    dt = jax.nn.softplus(
        jnp.einsum("...r,rd->...d", dt_low, p["dt_proj"].astype(jnp.float32))
        + p["dt_bias"].astype(jnp.float32)
    )
    return dt, Bm, Cm


def _ssm_step(h, xt, dt, Bm, Cm, A, D_skip):
    """One recurrence step.  h (B, DI, N); xt/dt (B, DI); Bm/Cm (B, N)."""
    dA = jnp.exp(dt[..., None] * A)                      # (B, DI, N)
    dBx = (dt * xt)[..., None] * Bm[:, None, :]          # (B, DI, N)
    h_new = dA * h + dBx
    y = jnp.einsum("bdn,bn->bd", h_new, Cm) + D_skip * xt
    return h_new, y


def mamba(
    p,
    x,  # (B, S, M)
    cfg,
    *,
    mesh=None,
    rules: ShardingRules = DEFAULT_RULES,
    h0: Optional[jnp.ndarray] = None,
    conv0: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Full-sequence Mamba.  Returns (y (B,S,M), cache{conv,ssm})."""
    B, S, M = x.shape
    DI, R, N, K = _dims(cfg)
    cd = COMPUTE_DTYPE
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    D_skip = p["d_skip"].astype(jnp.float32)

    xz = jnp.einsum("bsm,mtd->bstd", x.astype(cd), p["in_proj"].astype(cd))
    xs, z = xz[:, :, 0], xz[:, :, 1]  # (B,S,DI)
    xs = constrain(xs, mesh, ("batch", "seq", "d_ff"), rules)

    # depthwise causal conv1d, width K
    pad = jnp.zeros((B, K - 1, DI), xs.dtype) if conv0 is None else conv0.astype(xs.dtype)
    xp = jnp.concatenate([pad, xs], axis=1)  # (B, S+K-1, DI)
    conv_w = p["conv_w"].astype(jnp.float32)
    xc = sum(
        xp[:, i : i + S].astype(jnp.float32) * conv_w[i]
        for i in range(K)
    ) + p["conv_b"].astype(jnp.float32)
    xc = jax.nn.silu(xc)  # (B,S,DI) f32

    dt, Bm, Cm = _selection(p, xc, cfg)

    def step(h, inp):
        xt, dtt, bt, ct = inp
        h_new, y = _ssm_step(h, xt, dtt, bt, ct, A, D_skip)
        return h_new, y

    h_init = (
        jnp.zeros((B, DI, N), jnp.float32) if h0 is None else h0.astype(jnp.float32)
    )
    xs_t = jnp.moveaxis(xc, 1, 0)  # (S,B,DI)
    dt_t = jnp.moveaxis(dt, 1, 0)
    B_t = jnp.moveaxis(Bm, 1, 0)
    C_t = jnp.moveaxis(Cm, 1, 0)
    h_fin, ys = jax.lax.scan(step, h_init, (xs_t, dt_t, B_t, C_t))
    y = jnp.moveaxis(ys, 0, 1)  # (B,S,DI)

    out = (y * jax.nn.silu(z.astype(jnp.float32))).astype(cd)
    out = jnp.einsum("bsd,dm->bsm", out, p["out_proj"].astype(cd))
    # cache["conv"] holds the last K-1 *pre-conv* inputs
    cache = {"conv": xp[:, -(K - 1):].astype(cd), "ssm": h_fin}
    return constrain(out, mesh, ("batch", "seq", "d_model"), rules), cache


def mamba_init_cache(cfg, batch: int, dtype=COMPUTE_DTYPE):
    DI, R, N, K = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, K - 1, DI), dtype),
        "ssm": jnp.zeros((batch, DI, N), jnp.float32),
    }


def mamba_decode(
    p,
    x,      # (B, 1, M)
    cache,  # {"conv": (B, K-1, DI), "ssm": (B, DI, N)}
    cfg,
    *,
    mesh=None,
    rules: ShardingRules = DEFAULT_RULES,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    B = x.shape[0]
    DI, R, N, K = _dims(cfg)
    cd = COMPUTE_DTYPE
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    D_skip = p["d_skip"].astype(jnp.float32)

    xz = jnp.einsum("bsm,mtd->bstd", x.astype(cd), p["in_proj"].astype(cd))
    xs, z = xz[:, 0, 0], xz[:, 0, 1]  # (B, DI)

    window = jnp.concatenate([cache["conv"].astype(jnp.float32), xs[:, None].astype(jnp.float32)], axis=1)  # (B,K,DI)
    conv_w = p["conv_w"].astype(jnp.float32)
    xc = jnp.einsum("bkd,kd->bd", window, conv_w) + p["conv_b"].astype(jnp.float32)
    xc = jax.nn.silu(xc)

    dt, Bm, Cm = _selection(p, xc, cfg)
    h_new, y = _ssm_step(cache["ssm"].astype(jnp.float32), xc, dt, Bm, Cm, A, D_skip)

    out = (y * jax.nn.silu(z.astype(jnp.float32))).astype(cd)
    out = jnp.einsum("bd,dm->bm", out, p["out_proj"].astype(cd))[:, None]
    new_cache = {"conv": window[:, 1:].astype(cd), "ssm": h_new}
    return constrain(out, mesh, ("batch", "seq", "d_model"), rules), new_cache
