from .adamw import *  # noqa: F401,F403
