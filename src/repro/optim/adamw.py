"""AdamW + schedules + ZeRO-1 optimizer-state sharding, pure JAX.

ZeRO-1: optimizer moments replicate a parameter's TP sharding *plus* get
sharded along the `data` axis on the first dimension that divides evenly and
is not already sharded — each data-parallel rank owns a slice of the
optimizer state (the collective cost shows up as reduce-scatter/all-gather
in the compiled step, visible in the dry-run HLO).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["AdamWConfig", "OptState", "adamw_init", "adamw_update",
           "cosine_schedule", "global_norm", "clip_by_global_norm",
           "zero1_spec"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    zero1: bool = True  # shard moments over the data axis


class OptState(NamedTuple):
    step: jnp.ndarray  # int32 scalar
    mu: Any            # pytree like params
    nu: Any            # pytree like params


def cosine_schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    return cfg.lr_peak * warm * 0.5 * (1.0 + jnp.cos(np.pi * prog))


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(leaf.astype(jnp.float32))) for leaf in leaves)
    )


def clip_by_global_norm(tree, max_norm):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree), norm


def zero1_spec(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Extend a param PartitionSpec with 'data' on the first free divisible dim."""
    if "data" not in mesh.shape:
        return spec
    data = mesh.shape["data"]
    entries = list(spec) + [None] * (len(shape) - len(spec))
    used = set()
    for e in entries:
        for a in (e if isinstance(e, tuple) else (e,)):
            if a:
                used.add(a)
    if "data" in used:
        return spec
    for i, (dim, e) in enumerate(zip(shape, entries)):
        if e is None and dim % data == 0 and dim >= data:
            entries[i] = "data"
            while entries and entries[-1] is None:
                entries.pop()
            return P(*entries)
    return spec


def _moment_constrain(tree, param_specs, mesh: Optional[Mesh], zero1: bool):
    if mesh is None or param_specs is None:
        return tree

    def one(x, spec):
        sp = zero1_spec(spec, x.shape, mesh) if zero1 else spec
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, sp))

    return jax.tree_util.tree_map(one, tree, param_specs)


def adamw_init(params, cfg: AdamWConfig, *, mesh=None, param_specs=None) -> OptState:
    def zeros(p):
        return jnp.zeros_like(p, dtype=jnp.float32)

    mu = jax.tree_util.tree_map(zeros, params)
    nu = jax.tree_util.tree_map(zeros, params)
    mu = _moment_constrain(mu, param_specs, mesh, cfg.zero1)
    nu = _moment_constrain(nu, param_specs, mesh, cfg.zero1)
    return OptState(step=jnp.zeros((), jnp.int32), mu=mu, nu=nu)


def adamw_update(
    params,
    grads,
    opt: OptState,
    cfg: AdamWConfig,
    *,
    mesh: Optional[Mesh] = None,
    param_specs=None,
):
    """One AdamW step.  Returns (new_params, new_opt, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = opt.step + 1
    lr = cosine_schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        p_new = p.astype(jnp.float32) - lr * (
            mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        )
        return p_new.astype(p.dtype), m, v

    flat = jax.tree_util.tree_map(upd, params, grads, opt.mu, opt.nu)
    new_params = jax.tree_util.tree_map(lambda t: t[0], flat, is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree_util.tree_map(lambda t: t[1], flat, is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree_util.tree_map(lambda t: t[2], flat, is_leaf=lambda t: isinstance(t, tuple))
    new_mu = _moment_constrain(new_mu, param_specs, mesh, cfg.zero1)
    new_nu = _moment_constrain(new_nu, param_specs, mesh, cfg.zero1)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, OptState(step=step, mu=new_mu, nu=new_nu), metrics
