"""Generic QUBO — minimize xᵀQx over x ∈ {0,1}ⁿ (DESIGN.md §9).

The workhorse reduction every other family builds on.  With x = (1+m)/2 and
the objective scaled by 4 to keep every coupling integral:

    4·xᵀQx = H(m) + offset,   J_ij = -(Q_ij + Q_ji) (i≠j),  h_i = -ΣQ row/col

(the exact expansion is in :func:`qubo_to_ising`).  QUBO is unconstrained,
so every spin vector decodes to a feasible solution — ``verify`` is always
true and the annealer's job is purely objective quality.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from repro.core.ising import IsingModel

from .base import ProblemEncoding, spins_to_bits

__all__ = ["QUBOProblem", "qubo_problem", "qubo_to_ising", "random_qubo"]


def qubo_to_ising(Q: np.ndarray, name: str = "qubo") -> Tuple[IsingModel, int]:
    """Minimize xᵀQx over x∈{0,1}ⁿ as an Ising model (integer couplings).

    With x = (1+m)/2:  xᵀQx = ¼ Σ_ij Q_ij (1+m_i)(1+m_j).  Multiplying the
    objective by 4 keeps everything integral:

        4·xᵀQx = Σ_ij Q_ij (1 + m_i + m_j + m_i m_j)
               = sum(Q) + Σ_i m_i (rowQ_i + colQ_i) + Σ_ij Q_ij m_i m_j

    and with H = -Σ h m - ½ Σ_{i≠j} J m m this pins h_i = -(rowQ_i + colQ_i),
    J_ij = -(Q_ij + Q_ji) and offset = sum(Q) + Σ_i Q_ii.  Returns
    ``(model, offset)`` with ``4·xᵀQx = H(m) + offset`` exactly — verified
    over all assignments in tests.
    """
    Q = np.asarray(Q, dtype=np.int64)
    n = Q.shape[0]
    S = Q + Q.T  # symmetric part ×2
    const = int(Q.sum())
    lin = Q.sum(axis=1) + Q.sum(axis=0)  # coefficient of m_i
    quad = S.copy()
    diag = np.diag(quad).copy()
    np.fill_diagonal(quad, 0)
    # Σ_ij Q_ij m_i m_j = ½ Σ_{i≠j} S_ij m_i m_j + Σ_i Q_ii (m_i² = 1)
    const += int(diag.sum() // 2)  # diag of S is 2·Q_ii
    h = -lin
    J = -quad
    model = IsingModel.from_dense(J.astype(np.int64), h=h.astype(np.int64), name=name)
    return model, const


@dataclasses.dataclass(frozen=True)
class QUBOProblem(ProblemEncoding):
    """Encoded QUBO instance; ``4·xᵀQx = H(m) + offset``."""

    Q: np.ndarray = dataclasses.field(default_factory=lambda: np.zeros((0, 0)))

    def decode(self, m: np.ndarray) -> np.ndarray:
        return spins_to_bits(m)

    def verify(self, solution: np.ndarray) -> bool:
        x = np.asarray(solution)
        return x.shape == (self.Q.shape[0],) and bool(np.all((x == 0) | (x == 1)))

    def objective(self, solution: np.ndarray) -> int:
        x = np.asarray(solution, dtype=np.int64)
        return int(x @ self.Q @ x)


def qubo_problem(Q: np.ndarray, name: str = "qubo") -> QUBOProblem:
    """Encode a dense integer QUBO matrix (minimization)."""
    Q = np.asarray(Q, dtype=np.int64)
    if Q.ndim != 2 or Q.shape[0] != Q.shape[1]:
        raise ValueError(f"Q must be square, got {Q.shape}")
    model, offset = qubo_to_ising(Q, name=name)
    return QUBOProblem(kind="qubo", model=model, offset=offset, Q=Q)


def random_qubo(
    n: int = 32, *, seed: int = 0, lo: int = -8, hi: int = 8
) -> QUBOProblem:
    """Dense random integer QUBO — the smoke/benchmark instance family."""
    rng = np.random.default_rng(seed)
    Q = rng.integers(lo, hi + 1, size=(n, n))
    return qubo_problem(Q, name=f"qubo{n}s{seed}")
