"""repro.problems — the scenario-diverse problem frontend (DESIGN.md §9).

Each family reduces a domain instance to an Ising model and carries the way
back (decode → domain solution, verify → feasibility, objective → domain
cost), so the annealers and the :class:`~repro.serve.AnnealService` consume
every family through one interface:

  qubo       — generic xᵀQx minimization (unconstrained)
  mis        — maximum independent set (penalty reduction + repair decode)
  coloring   — graph k-coloring (one-hot reduction)
  partition  — number partitioning (fully-connected integer Ising)

``FAMILIES`` maps the kind names to demo-instance factories sized for
smoke runs and benchmarks; :func:`make_demo` is the launcher/benchmark
entry.  Max-Cut stays on its dedicated
:class:`~repro.core.ising.MaxCutProblem` path (it *is* the Ising model).
"""

from typing import Callable, Dict

from .base import ProblemEncoding, spins_to_bits  # noqa: F401
from .coloring import ColoringProblem, coloring_problem, ring_coloring  # noqa: F401
from .mis import MISProblem, mis_problem, random_mis_graph  # noqa: F401
from .partition import (  # noqa: F401
    PartitionProblem,
    partition_problem,
    random_partition,
)
from .qubo import QUBOProblem, qubo_problem, qubo_to_ising, random_qubo  # noqa: F401

__all__ = [
    "ProblemEncoding",
    "spins_to_bits",
    "QUBOProblem",
    "qubo_problem",
    "qubo_to_ising",
    "random_qubo",
    "MISProblem",
    "mis_problem",
    "random_mis_graph",
    "ColoringProblem",
    "coloring_problem",
    "ring_coloring",
    "PartitionProblem",
    "partition_problem",
    "random_partition",
    "FAMILIES",
    "make_demo",
]

# kind → demo-instance factory (n, seed) → ProblemEncoding.  The sizes the
# factories default to are smoke-scale; benchmarks pass their own n.
FAMILIES: Dict[str, Callable[..., ProblemEncoding]] = {
    "qubo": lambda n=32, seed=0: random_qubo(n, seed=seed),
    "mis": lambda n=48, seed=0: random_mis_graph(n, seed=seed),
    "coloring": lambda n=36, seed=0: ring_coloring(
        max(n // 3, 3), 3, chords=n // 12, seed=seed
    ),
    "partition": lambda n=24, seed=0: random_partition(n, seed=seed),
}


def make_demo(kind: str, n: int = 0, seed: int = 0) -> ProblemEncoding:
    """Build a demo instance of a problem family (launcher/benchmark entry)."""
    try:
        factory = FAMILIES[kind]
    except KeyError:
        raise ValueError(
            f"unknown problem kind {kind!r}; known: {sorted(FAMILIES)}"
        ) from None
    return factory(n, seed=seed) if n else factory(seed=seed)
