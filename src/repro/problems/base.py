"""Problem-encoding protocol: domain problem ⇄ Ising model (DESIGN.md §9).

Every problem family in :mod:`repro.problems` reduces its domain instance to
an :class:`~repro.core.ising.IsingModel` and knows how to come back:

* ``encode``  — the family's ``*_problem`` constructor returns a
  :class:`ProblemEncoding` whose ``model`` the annealers (and the
  :class:`~repro.serve.AnnealService`) consume unchanged;
* ``decode``  — spin vector → domain solution (always total: constraint
  violations are repaired deterministically where a canonical repair
  exists, or surfaced via ``verify`` where one does not);
* ``verify``  — feasibility check of a *decoded* solution against the
  original instance (never against the Ising energy — the whole point is
  an independent witness);
* ``objective`` — the domain objective of a feasible solution.  The
  ``minimize`` flag states the direction; :meth:`ProblemEncoding.score`
  folds it so callers can always maximize.

The Ising energy and the domain objective are tied by
``H(m) + offset = scale · objective_qubo(x)`` for the exact-QUBO families —
asserted per family in tests/test_problem_frontend.py.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import numpy as np

from repro.core.ising import IsingModel


@dataclasses.dataclass(frozen=True)
class ProblemEncoding:
    """Base of every family's encoding: the Ising model plus the way back.

    Subclasses add the instance data they need for decode/verify and
    override :meth:`decode`, :meth:`verify` and :meth:`objective`.
    ``offset`` is the constant of the QUBO→Ising expansion (family-specific
    meaning, documented per encoder).  The ``model`` attribute is what
    :func:`repro.core.engine.normalize_problem` picks up, so an encoding can
    be passed directly to ``anneal()`` or an ``AnnealRequest``.
    """

    kind: str
    model: IsingModel
    offset: int = 0
    minimize: bool = True

    # -- the way back -----------------------------------------------------
    def decode(self, m: np.ndarray) -> Any:
        """Spin vector (N,) in {-1,+1} → domain solution."""
        raise NotImplementedError

    def verify(self, solution: Any) -> bool:
        """Feasibility of a decoded solution against the domain instance."""
        raise NotImplementedError

    def objective(self, solution: Any) -> int:
        """Domain objective of a feasible solution (direction: ``minimize``)."""
        raise NotImplementedError

    # -- conveniences shared by the service, benchmarks and tests ---------
    def score(self, solution: Any) -> int:
        """Objective folded to maximize-is-better (service-trace polarity)."""
        obj = int(self.objective(solution))
        return -obj if self.minimize else obj

    def best_feasible(
        self, best_m: np.ndarray
    ) -> Tuple[Optional[Any], Optional[int], bool]:
        """Best feasible decoded solution over a (T, N) batch of trials.

        Returns ``(solution, objective, feasible)``: the feasible solution
        with the best domain objective, or — when no trial decodes to a
        feasible solution — the first trial's decode with ``feasible=False``.
        """
        best_m = np.asarray(best_m)
        if best_m.ndim == 1:
            best_m = best_m[None]
        best: Optional[Tuple[int, Any]] = None
        for trial in best_m:
            sol = self.decode(trial)
            if not self.verify(sol):
                continue
            s = self.score(sol)
            if best is None or s > best[0]:
                best = (s, sol)
        if best is None:
            sol = self.decode(best_m[0])
            return sol, None, False
        return best[1], int(self.objective(best[1])), True


def spins_to_bits(m: np.ndarray) -> np.ndarray:
    """±1 spins → {0,1} bits under the x = (1+m)/2 convention."""
    return (np.asarray(m) > 0).astype(np.int64)
