"""Number partitioning as the classic fully-connected Ising model.

Split integers v into two subsets minimizing the sum difference:

    residual(m) = |Σ_i v_i m_i|,   minimize residual²

Direct Ising form (no QUBO detour): (Σ v m)² = Σ v² + Σ_{i≠j} v_i v_j m_i m_j,
so J_ij = -2 v_i v_j, h = 0 gives H(m) = Σ_{i≠j} v_i v_j m_i m_j =
residual² − Σ v² — i.e. ``residual² = H(m) + offset`` with offset = Σ v².

Every spin vector is a valid split, so ``verify`` only checks shape; the
objective is the residual (minimize; the parity of Σv floors it at 0 or 1).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.ising import IsingModel

from .base import ProblemEncoding

__all__ = ["PartitionProblem", "partition_problem", "random_partition"]


@dataclasses.dataclass(frozen=True)
class PartitionProblem(ProblemEncoding):
    """Encoded partitioning instance; ``residual² = H(m) + offset``."""

    values: np.ndarray = dataclasses.field(default_factory=lambda: np.zeros(0, int))

    def decode(self, m: np.ndarray) -> np.ndarray:
        """Spins → subset membership (±1 per value)."""
        return np.where(np.asarray(m) > 0, 1, -1).astype(np.int64)

    def verify(self, solution: np.ndarray) -> bool:
        s = np.asarray(solution)
        return s.shape == (len(self.values),) and bool(np.all(np.abs(s) == 1))

    def objective(self, solution: np.ndarray) -> int:
        """|sum(A) − sum(B)| over the two subsets."""
        return int(abs((self.values * np.asarray(solution, np.int64)).sum()))


def partition_problem(values: np.ndarray) -> PartitionProblem:
    """Encode a partitioning instance: J_ij = -2 v_i v_j, h = 0."""
    v = np.asarray(values, dtype=np.int64)
    J = -2 * np.outer(v, v)
    np.fill_diagonal(J, 0)
    model = IsingModel.from_dense(J, name=f"partition{len(v)}")
    return PartitionProblem(
        kind="partition",
        model=model,
        offset=int((v * v).sum()),
        values=v,
    )


def random_partition(n: int = 24, *, seed: int = 0, hi: int = 50) -> PartitionProblem:
    """Uniform random integers in [1, hi] — the smoke/benchmark family."""
    rng = np.random.default_rng(seed)
    return partition_problem(rng.integers(1, hi + 1, size=n))
