"""Graph k-coloring as a one-hot QUBO/Ising reduction (DESIGN.md §9).

Spins x[v, c] = vertex v has color c (n·k spins):

    minimize  A·Σ_v (Σ_c x_vc − 1)²  +  B·Σ_{(u,v)∈E} Σ_c x_uc x_vc

The A-term forces exactly one color per vertex, the B-term charges one unit
per monochromatic edge.  A > B·max_degree guarantees ground states are
one-hot; a proper k-coloring exists iff the minimum is the constant offset.

``decode`` is total: each vertex takes its first selected color (ties and
all-unselected rows fall back to color 0), so the solution is always a full
assignment; feasibility — properness, i.e. zero conflicting edges — is what
``verify`` checks and the annealer must earn.  The objective is the number
of conflicting edges (minimize; 0 = proper).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .base import ProblemEncoding
from .qubo import qubo_to_ising

__all__ = ["ColoringProblem", "coloring_problem", "ring_coloring"]


@dataclasses.dataclass(frozen=True)
class ColoringProblem(ProblemEncoding):
    """Encoded k-coloring instance; spins index (vertex, color) row-major."""

    n_vertices: int = 0
    n_colors: int = 0
    edges: np.ndarray = dataclasses.field(default_factory=lambda: np.zeros((0, 2), int))

    def decode(self, m: np.ndarray) -> np.ndarray:
        """Spins → color per vertex, with deterministic conflict repair.

        Each vertex takes the first selected color of its one-hot row
        (all-unselected rows → color 0, so the decode is total).  Residual
        conflicts are then repaired greedily: the lowest-index conflicted
        vertex is recolored with the smallest color absent from its
        neighborhood; vertices whose neighborhoods exhaust all k colors are
        left as-is (``verify`` reports them).
        """
        x = np.asarray(m).reshape(self.n_vertices, self.n_colors) > 0
        colors = x.argmax(axis=1)
        edges = np.asarray(self.edges)
        if len(edges) == 0:
            return colors
        nbrs = [[] for _ in range(self.n_vertices)]
        for u, v in edges:
            nbrs[u].append(v)
            nbrs[v].append(u)
        for _ in range(self.n_vertices * self.n_colors):
            bad = edges[colors[edges[:, 0]] == colors[edges[:, 1]]]
            if len(bad) == 0:
                break
            repaired = False
            for v in sorted(set(bad.reshape(-1).tolist())):
                used = {int(colors[u]) for u in nbrs[v]}
                free = [c for c in range(self.n_colors) if c not in used]
                if free:
                    colors[v] = free[0]
                    repaired = True
                    break
            if not repaired:
                break  # no locally repairable vertex — leave for verify
        return colors

    def verify(self, solution: np.ndarray) -> bool:
        """Properness: a full assignment with no monochromatic edge."""
        colors = np.asarray(solution)
        if colors.shape != (self.n_vertices,):
            return False
        if colors.min(initial=0) < 0 or colors.max(initial=0) >= self.n_colors:
            return False
        return self.objective(colors) == 0

    def objective(self, solution: np.ndarray) -> int:
        """Number of monochromatic (conflicting) edges — 0 means proper."""
        colors = np.asarray(solution)
        if len(self.edges) == 0:
            return 0
        return int((colors[self.edges[:, 0]] == colors[self.edges[:, 1]]).sum())


def coloring_problem(
    n: int, edges: np.ndarray, k: int, *, penalty: int = 0
) -> ColoringProblem:
    """Encode k-coloring of an n-vertex graph (n·k spins).

    ``penalty`` is the one-hot constraint weight A; the default 0 picks
    ``max_degree + 1`` (> B·deg bound with B = 1, keeping couplings small).
    """
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    deg = np.zeros(n, dtype=np.int64)
    for u, v in edges:
        deg[u] += 1
        deg[v] += 1
    A = int(penalty) if penalty else int(deg.max(initial=0)) + 1
    nk = n * k
    Q = np.zeros((nk, nk), dtype=np.int64)

    def idx(v, c):
        return v * k + c

    # one color per vertex: A·(Σ_c x_vc − 1)² = A·(Σ_c x_vc − 2·Σ x + cross)
    for v in range(n):
        for c1 in range(k):
            Q[idx(v, c1), idx(v, c1)] -= A
            for c2 in range(c1 + 1, k):
                Q[idx(v, c1), idx(v, c2)] += 2 * A
    # conflict term: one unit per monochromatic edge
    for u, v in edges:
        for c in range(k):
            Q[idx(u, c), idx(v, c)] += 1
    model, offset = qubo_to_ising(Q, name=f"color{n}x{k}")
    return ColoringProblem(
        kind="coloring",
        model=model,
        offset=offset + 4 * A * n,  # the +A·n constant of the squared term
        n_vertices=n,
        n_colors=k,
        edges=edges,
    )


def ring_coloring(
    n: int = 12, k: int = 3, *, chords: int = 0, seed: int = 0
) -> ColoringProblem:
    """An n-cycle (plus optional random chords) to k-color — smoke family."""
    if n < 3:
        raise ValueError(f"a ring needs at least 3 vertices, got {n}")
    edges = [(v, (v + 1) % n) for v in range(n)]
    if chords:
        rng = np.random.default_rng(seed)
        have = set(map(tuple, (sorted(e) for e in edges)))
        while len(edges) < n + chords:
            u, v = sorted(map(int, rng.integers(0, n, size=2)))
            if u != v and (u, v) not in have:
                have.add((u, v))
                edges.append((u, v))
    return coloring_problem(n, np.asarray(edges), k)
