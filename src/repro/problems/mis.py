"""Maximum independent set (MIS) as a QUBO/Ising reduction (DESIGN.md §9).

    maximize |S|  s.t.  no edge inside S
    ⇒ minimize  -Σ_i x_i + P·Σ_{(i,j)∈E} x_i x_j,   P ≥ 2

With integer penalty P ≥ 2, removing a violating endpoint never worsens the
QUBO objective, so every ground state is a (maximum) independent set.

``decode`` applies the canonical deterministic repair — while any edge has
both endpoints selected, drop the endpoint with the most in-set conflicts
(ties to the lowest vertex index) — so a decoded solution is *always*
feasible; ``verify`` independently checks independence against the edge
list.  The objective is the set size (maximize).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .base import ProblemEncoding, spins_to_bits
from .qubo import qubo_to_ising

__all__ = ["MISProblem", "mis_problem", "random_mis_graph"]


@dataclasses.dataclass(frozen=True)
class MISProblem(ProblemEncoding):
    """Encoded MIS instance over an undirected edge list."""

    n_vertices: int = 0
    edges: np.ndarray = dataclasses.field(default_factory=lambda: np.zeros((0, 2), int))
    penalty: int = 2

    def decode(self, m: np.ndarray) -> np.ndarray:
        """Spins → independent set (bool mask), via deterministic repair."""
        sel = spins_to_bits(m).astype(bool)
        edges = np.asarray(self.edges)
        if len(edges) == 0:
            return sel
        while True:
            inside = sel[edges[:, 0]] & sel[edges[:, 1]]
            if not inside.any():
                return sel
            conflicts = np.zeros(self.n_vertices, dtype=np.int64)
            np.add.at(conflicts, edges[inside, 0], 1)
            np.add.at(conflicts, edges[inside, 1], 1)
            sel[int(np.argmax(conflicts))] = False  # argmax ties → lowest index

    def verify(self, solution: np.ndarray) -> bool:
        sel = np.asarray(solution, dtype=bool)
        if sel.shape != (self.n_vertices,):
            return False
        if len(self.edges) == 0:
            return True
        return not bool((sel[self.edges[:, 0]] & sel[self.edges[:, 1]]).any())

    def objective(self, solution: np.ndarray) -> int:
        return int(np.asarray(solution, dtype=bool).sum())


def mis_problem(n: int, edges: np.ndarray, penalty: int = 2) -> MISProblem:
    """Encode an MIS instance; ``4·(P·conflicts − |S|) = H(m) + offset``."""
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    if penalty < 2:
        raise ValueError("MIS penalty must be >= 2 to dominate the size reward")
    Q = np.zeros((n, n), dtype=np.int64)
    np.fill_diagonal(Q, -1)  # reward −1 per selected vertex
    for i, j in edges:
        Q[i, j] += penalty  # conflict penalty on each undirected edge
    model, offset = qubo_to_ising(Q, name=f"mis{n}")
    return MISProblem(
        kind="mis",
        model=model,
        offset=offset,
        minimize=False,
        n_vertices=n,
        edges=edges,
        penalty=int(penalty),
    )


def random_mis_graph(n: int = 48, *, seed: int = 0, p: float = 0.12) -> MISProblem:
    """Erdős–Rényi G(n, p) MIS instance — the smoke/benchmark family."""
    rng = np.random.default_rng(seed)
    iu = np.triu_indices(n, k=1)
    mask = rng.random(len(iu[0])) < p
    edges = np.stack([iu[0][mask], iu[1][mask]], axis=1)
    return mis_problem(n, edges)
