"""Resilience policy for the annealing service (DESIGN.md §10).

Everything the service needs to degrade gracefully instead of failing the
batch lives here: the policy knobs (:class:`ResiliencePolicy`), the typed
admission errors, the fault taxonomy (:func:`classify_fault`), the backend
fallback chain (:func:`fallback_step`), the structured event records
(:class:`ServiceEvent`), and the stable group fingerprint that keys
chunk-level checkpoints (:func:`group_fingerprint`).

The design leans on the same property the paper's HA-SSA storage trick
leans on: *all* live state between plateau chunks is a tiny explicit
buffer — spin (bit)planes, the carried xorshift128 lanes, ``best_H`` and
the chunk index — so checkpoint/resume and group re-execution are
bit-identical, not best-effort.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.engine import resolve_j_mode

__all__ = [
    "STATUS_OK",
    "STATUS_FALLBACK",
    "STATUS_DEADLINE",
    "STATUS_QUARANTINED",
    "STATUS_FAILED",
    "STATUS_SHED",
    "AdmissionError",
    "QueueFullError",
    "QuarantineFault",
    "ServiceEvent",
    "ResiliencePolicy",
    "classify_fault",
    "fallback_step",
    "filter_backend_opts",
    "group_fingerprint",
]

# AnnealResponse.status values (DESIGN.md §10, §12).
STATUS_OK = "ok"                   # solved on the configured backend
STATUS_FALLBACK = "fallback"       # solved after >=1 backend/j_mode downgrade
STATUS_DEADLINE = "deadline"       # deadline expired; best-so-far returned
STATUS_QUARANTINED = "quarantined"  # non-finite detection; solved solo on retry
STATUS_FAILED = "failed"           # retries exhausted; no result
STATUS_SHED = "shed"               # streaming: dropped from the queue unstarted
#                                    (deadline already unmeetable); no result


class AdmissionError(ValueError):
    """A request rejected at admission (bad weights, absurd shape, bad knobs).

    Raised before any group starts solving, so a rejected batch does no
    device work at all.
    """


class QueueFullError(AdmissionError):
    """Streaming admission control: the request queue is at capacity.

    Raised by :meth:`repro.serve.stream.StreamingAnnealService.submit` when
    the queue's depth or aggregate cost bound is hit — backpressure belongs
    at the front door, not in an unbounded queue.  Subclasses
    :class:`AdmissionError` so clients can treat both as "not accepted".
    """


class QuarantineFault(RuntimeError):
    """Internal signal: non-finite readings detected for some batch slots.

    Carries the *group-slot* indices of the offending requests; the service
    re-runs the healthy slots as a fresh group (bit-identical — per-problem
    lanes are independent) and retries the offenders solo.
    """

    def __init__(self, slots: Tuple[int, ...]):
        super().__init__(f"non-finite energies in batch slots {sorted(slots)}")
        self.slots = tuple(slots)


@dataclasses.dataclass(frozen=True)
class ServiceEvent:
    """One structured resilience event, attached to the responses it touched.

    ``kind``: 'fallback' | 'resume' | 'deadline' | 'quarantine' | 'retry'
    | 'checkpoint_rejected', plus the streaming lifecycle kinds 'seat' |
    'retire' | 'shed' | 'retries_exhausted' (DESIGN.md §12).  ``t`` is
    seconds since the ``solve()`` call began (streaming: since submission).
    Events are group-scoped (every response in the group carries the
    group's events) except quarantine/retry, which are per-request.
    """

    kind: str
    detail: Dict[str, object]
    t: float


@dataclasses.dataclass(frozen=True)
class ResiliencePolicy:
    """Service-level failure-handling knobs.

    checkpoint_dir:        root for chunk-level group checkpoints (None =
                           checkpointing off).  Each request group writes
                           under ``<dir>/<group_fingerprint>/``.
    checkpoint_interval:   save every k-th chunk boundary.
    keep_checkpoints:      keep-last-n per group (crash window = interval).
    cleanup_on_success:    purge a group's checkpoints when it completes.
    fallback:              enable the backend fallback chain
                           (pallas→dense→sparse, dense-J→tiled-J on OOM).
    max_retries:           solo retries for a quarantined request.
    backoff_base_s:        exponential-backoff base for those retries.
    validate_admission:    reject non-finite weights / absurd shapes / bad
                           knobs with :class:`AdmissionError` before solving.
    """

    checkpoint_dir: Optional[str] = None
    checkpoint_interval: int = 1
    keep_checkpoints: int = 2
    cleanup_on_success: bool = True
    fallback: bool = True
    max_retries: int = 3
    backoff_base_s: float = 0.05
    validate_admission: bool = True


# Constructor keywords each batched backend accepts beyond the common set —
# fallback must drop e.g. pallas block_r when downgrading to dense.  Both
# field-capable backends carry field_mode/j_bits, so a pallas→dense
# downgrade keeps the XNOR-popcount arithmetic (and its bit-exactness).
# n_replicas (the SSQA Trotter depth) is accepted everywhere: the replica
# ring is a trial-axis property, so every backend in the fallback chain
# must preserve it — dropping it would silently turn SSQA into SSA.
_BACKEND_OPT_KEYS = {
    "sparse": frozenset({"n_replicas"}),
    "dense": frozenset(
        {"j_dtype", "j_mode", "tile_n", "field_mode", "j_bits",
         "double_buffer", "n_replicas"}
    ),
    "pallas": frozenset(
        {"j_dtype", "block_r", "interpret", "noise_mode", "field_mode",
         "j_bits", "n_replicas"}
    ),
    # partition='spin': the shard_map backend wraps any base field style and
    # tolerates (ignores) the single-device resident-kernel knobs, so the
    # fallback chain can walk pallas→dense→sparse under spin sharding too.
    "spinshard": frozenset(
        {"j_dtype", "j_mode", "tile_n", "field_mode", "j_bits",
         "double_buffer", "block_r", "interpret", "noise_mode", "n_replicas"}
    ),
}


def filter_backend_opts(backend: str, opts: dict, *,
                        partition: str = "problem") -> dict:
    """Project backend_opts onto what ``backend`` actually accepts.

    Under ``partition='spin'`` the group runs on the spin-sharded shard_map
    backend regardless of the base backend name, so the wider 'spinshard'
    keyset applies.
    """
    if partition == "spin":
        backend = "spinshard"
    keys = _BACKEND_OPT_KEYS.get(backend, frozenset())
    return {k: v for k, v in opts.items() if k in keys}


def classify_fault(exc: BaseException, backend: str) -> Optional[str]:
    """Map an exception from a group solve to a fault class.

    Returns 'oom', 'compile', or None (not recoverable by fallback — the
    exception propagates).  Injected kills and quarantine signals are never
    classified: a kill must escape like a real process death, and
    quarantines have their own path.  For the pallas backend any unexpected
    error during the group solve is treated as a compile/launch failure —
    that backend failing while dense/sparse can still serve the batch is
    precisely the fault the chain exists for.
    """
    from repro.ft.faults import (
        InjectedCompileFailure,
        InjectedKill,
        InjectedOOM,
    )

    if isinstance(exc, (InjectedKill, QuarantineFault, AdmissionError,
                        KeyboardInterrupt)):
        return None
    if isinstance(exc, (InjectedOOM, MemoryError)):
        return "oom"
    if isinstance(exc, InjectedCompileFailure):
        return "compile"
    msg = str(exc)
    if "RESOURCE_EXHAUSTED" in msg or "out of memory" in msg.lower():
        return "oom"
    if type(exc).__name__ == "XlaRuntimeError":
        return "compile"
    if backend == "pallas":
        return "compile"
    return None


def fallback_step(
    backend: str, opts: dict, fault: str, n_bucket: int
) -> Optional[Tuple[str, dict]]:
    """One step down the fallback chain; None = chain exhausted.

    compile/launch: pallas → dense → sparse.
    oom on dense with materialized J: dense-J → tiled-J first (same
    backend, re-keyed executable), then sparse.
    """
    if backend == "dense" and fault == "oom":
        if resolve_j_mode(opts.get("j_mode", "auto"), n_bucket) != "tiled":
            return "dense", {**filter_backend_opts("dense", opts), "j_mode": "tiled"}
        return "sparse", filter_backend_opts("sparse", opts)
    if backend == "pallas":
        return "dense", filter_backend_opts("dense", opts)
    if backend == "dense":
        return "sparse", filter_backend_opts("sparse", opts)
    return None


def group_fingerprint(kind: str, n_bucket: int, backend: str,
                      storage_layout: str, noise: str, chunk: int,
                      items, *, partition: str = "problem",
                      mesh_fp: tuple = ()) -> str:
    """Stable identity of a request group, for checkpoint keying.

    Hashes the execution configuration plus, per request, the seed, the
    request knobs and the *problem arrays themselves* — so a resumed
    ``solve()`` in a fresh process maps onto the interrupted run's
    checkpoints iff it would replay the identical computation.

    ``partition``/``mesh_fp`` fold the spin-sharding layout in: a checkpoint
    written by a spin-sharded group on one mesh shape must not be resumed
    under another (the *state values* are layout-invariant, but mixing
    layouts silently would hide device-count configuration mistakes).
    """
    hsh = hashlib.sha256()
    hsh.update(repr((kind, n_bucket, backend, storage_layout, noise,
                     chunk, partition, mesh_fp)).encode())
    for _idx, req, _maxcut, model in items:
        cfg = getattr(req, "config", None)
        hsh.update(repr((req.seed, req.storage, req.schedule_kind,
                         req.target_cut, req.hp,
                         cfg.signature() if cfg is not None else None,
                         getattr(req, "algo", None))).encode())
        for arr in (model.h, model.nbr_idx, model.nbr_w):
            a = np.ascontiguousarray(np.asarray(arr))
            hsh.update(str(a.dtype).encode())
            hsh.update(a.tobytes())
    return hsh.hexdigest()[:20]
