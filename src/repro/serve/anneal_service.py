"""Shape-bucketed annealing service: one compiled plateau program serving
batched heterogeneous Max-Cut requests (DESIGN.md §7).

The paper's operating mode is "one fixed pipeline, many instances": the FPGA
streams Max-Cut problems through a single annealing datapath.  The TPU
transcription is this service:

* **Shape buckets** — incoming problems are zero-padded to power-of-two N
  (:func:`repro.core.engine.bucket_n` / :func:`~repro.core.engine.pad_model`),
  so a heterogeneous request stream collapses onto a handful of shapes.
* **Compiled-executable cache** — one jitted plateau program per
  ``(algorithm, backend, N_bucket, B_bucket, n_trials, n_rnd, noise,
  storage, Schedule.signature(), chunk)``.  Problem arrays are *arguments*
  to the program, never closed-over constants, so every same-bucket request
  group reuses the same executable: 4 G-set instances in one bucket compile
  the plateau program exactly once (trace-count tested).
* **Problem-axis batching** — same-bucket requests are stacked on a leading
  problem axis and solved in ONE device launch via the engine's batched
  backends (vmap for sparse/dense, the (B, R-tile)-grid resident kernel for
  pallas).  Batched runs are bit-identical per problem to unbatched,
  unpadded runs on the live lanes (padding-invariance tested) when the
  noise source is ``xorshift``.
* **Chunked execution with early stop** — the m_shot iteration budget runs
  in chunks; after each chunk the per-request best energy is reported
  (streaming progress) and a group whose requests have all reached their
  ``target_cut`` stops early.
* **Packed storage + tiled J** — ``storage_layout='packed'`` carries the
  engine state between chunk launches as uint32 spin bitplanes (and, for
  the pallas backend with xorshift noise, runs the streamed-noise packed
  kernel: no noise buffer, packed HBM refs).  The dense backend's
  ``j_mode='auto'`` streams (tile_n, N) J slabs above
  ``engine.TILED_J_THRESHOLD`` spins instead of materializing (B, N, N) —
  G77/G81-class buckets (N = 10k–20k) serve through the same entry.  Both
  axes ride the executable-cache key; results stay bit-identical.

Beyond Max-Cut, any :class:`~repro.problems.ProblemEncoding` (QUBO, MIS,
coloring, partitioning — DESIGN.md §9) rides the same entry: the encoding's
Ising model is bucketed/stacked like any other problem, and the response
carries the decoded, feasibility-verified domain solution.  ``hp='auto'``
resolves per-instance hyperparameters from the local-field distribution
(:mod:`repro.core.autotune`) before grouping, so autotuning composes with
batching and the executable cache instead of fragmenting them.

SA (:class:`~repro.core.sa.SAHyperParams`) and PT-SSA
(:class:`~repro.core.pt.PTSSAHyperParams`) requests ride the same entry:
they are grouped, bucketed, stacked, chunked and early-stopped identically —
SA through the vmapped Metropolis core (`repro.core.sa.sa_run` pieces),
PT-SSA through :func:`repro.core.pt.pt_ssa_rounds` with the replica ladder
on the engine's trial axis.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.autotune import AutotuneReport, resolve_hyperparams
from repro.core.engine import (
    bucket_n,
    finalize_cut,
    make_batched_backend,
    next_pow2,
    normalize_problem,
    schedule_plateaus,
)
from repro.core.ising import IsingModel, MaxCutProblem
from repro.core.pt import PTSSAHyperParams, PTSSAResult, pt_ssa_rounds
from repro.core.sa import SAHyperParams, SAResult, sa_cycles, sa_init
from repro.core.schedule import sa_temperature_ladder
from repro.core.ssa import AnnealResult, SSAHyperParams
from repro.problems import ProblemEncoding

__all__ = ["AnnealRequest", "AnnealResponse", "AnnealProgress", "AnnealService"]

HyperParams = Union[SSAHyperParams, SAHyperParams, PTSSAHyperParams]


@dataclasses.dataclass(frozen=True)
class AnnealRequest:
    """One problem + hyperparameters, as the service accepts it.

    ``problem`` is a Max-Cut instance, a raw Ising model, or any encoded
    problem from :mod:`repro.problems` (QUBO, MIS, coloring, partitioning…)
    — encoded problems come back with a decoded, feasibility-verified domain
    solution on the response.

    ``hp`` selects the algorithm: SSAHyperParams → SSA/HA-SSA (the paper's
    annealer), SAHyperParams → Metropolis SA, PTSSAHyperParams → PT on the
    plateau engine.  The string ``'auto'`` requests local-energy-distribution
    autotuning (:mod:`repro.core.autotune`): the service measures the
    instance's local-field distribution and derives per-instance n_rnd and
    I0 clamp before bucketing, taking the budget knobs (trials, m_shot,
    cycle budget) from ``auto_base``.  ``target_cut`` arms chunk-level early
    stop: once the request's best cut reaches it (and every other live
    request in its batch group is also satisfied), remaining chunks are
    skipped.
    """

    problem: Union[MaxCutProblem, IsingModel, ProblemEncoding]
    hp: Union[HyperParams, str] = SSAHyperParams()
    seed: int = 0
    storage: str = "i0max"         # SSA only: 'i0max' (HA-SSA) | 'all' (SSA)
    schedule_kind: str = "hassa"   # SSA only
    target_cut: Optional[int] = None
    auto_base: Optional[SSAHyperParams] = None  # budget knobs for hp='auto'


@dataclasses.dataclass
class AnnealResponse:
    request: AnnealRequest
    result: object                 # AnnealResult | SAResult | PTSSAResult
    wall_s: float                  # group wall time (the batch solves together)
    bucket: int                    # padded N the request ran at
    batch: int                     # live requests stacked in its group
    chunks_run: int                # chunks executed (early stop may cut short)
    chunks_total: int
    chunk_best_cut: np.ndarray     # (chunks_run,) streaming best-objective trace
    solution: object = None        # decoded domain solution (encoded problems)
    objective: Optional[int] = None  # domain objective of `solution` if feasible
    feasible: Optional[bool] = None  # verifier verdict (None: raw Ising/maxcut)
    autotune: Optional[AutotuneReport] = None  # set when hp='auto' resolved


@dataclasses.dataclass(frozen=True)
class AnnealProgress:
    """One streaming progress report (per group, per chunk)."""

    kind: str                      # 'ssa' | 'sa' | 'ptssa'
    bucket: int
    chunk: int
    chunks_total: int
    request_indices: tuple         # indices into the solve() request list
    best_cut: tuple                # best objective so far, per request


def _largest_divisor_leq(n: int, k: int) -> int:
    k = max(1, min(int(k), int(n)))
    while n % k:
        k -= 1
    return k


class AnnealService:
    """Batched annealing-as-a-service over the plateau engine.

    One service instance owns a backend choice, a noise source and the
    compiled-executable cache.  ``solve(requests)`` groups requests by
    (algorithm, shape bucket, hyperparameters), stacks each group on the
    problem axis, and runs it through one cached compiled program.

    Bit-exactness contract (noise='xorshift'): an SSA or PT-SSA request
    solved through the service — padded, stacked, chunked — returns the
    same best energy/spins on its live lanes as the corresponding
    single-problem driver (`anneal` / `anneal_pt_ssa`) on the unpadded
    instance.  SA requests are valid runs but not bit-comparable (their
    threefry init draw is shape-dependent).
    """

    def __init__(
        self,
        backend: str = "sparse",
        *,
        noise: str = "xorshift",
        storage_layout: str = "dense",
        chunk_shots: int = 1,
        sa_chunks: int = 8,
        min_bucket: int = 64,
        backend_opts: Optional[dict] = None,
        autotune_seed: int = 0,
    ):
        """``storage_layout='packed'`` keeps the HBM-resident engine state
        between chunk launches as uint32 spin bitplanes (DESIGN.md §4) — for
        the pallas backend with xorshift noise the kernel's HBM-facing refs
        are packed too, and noise is generated in-kernel (no (C, T, N)
        buffer).  SSA results are bit-identical across layouts; SA/PT-SSA
        groups always run the dense layout (their drivers own their state).
        """
        if storage_layout not in ("dense", "packed"):
            raise ValueError(f"unknown storage_layout {storage_layout!r}")
        self.backend = backend
        self.noise = noise
        self.storage_layout = storage_layout
        self.chunk_shots = int(chunk_shots)   # SSA iterations / PT rounds per chunk
        self.sa_chunks = int(sa_chunks)       # SA: report/early-stop points per run
        self.min_bucket = int(min_bucket)
        self.autotune_seed = int(autotune_seed)
        self.backend_opts = dict(backend_opts or {})
        self._programs: dict = {}
        self.stats = collections.Counter()

    # ------------------------------------------------------------------
    # Public API
    # ------------------------------------------------------------------
    def solve(
        self,
        requests: Sequence[AnnealRequest],
        progress: Optional[Callable[[AnnealProgress], None]] = None,
    ) -> List[AnnealResponse]:
        """Solve a batch of heterogeneous requests; responses keep order.

        ``hp='auto'`` requests are resolved *before* grouping — autotuned
        hyperparameters are ordinary call-time arguments by the time the
        bucketing and the compiled-executable cache see them, so the cache
        keying machinery is untouched and identical problems (the autotune
        draw is independent of the anneal seed) still batch together.
        Encoded problems (:class:`~repro.problems.ProblemEncoding`) get
        their best spins decoded and feasibility-verified on the response.
        """
        self.stats["requests"] += len(requests)
        responses: List[Optional[AnnealResponse]] = [None] * len(requests)
        reports: dict = {}
        groups = collections.defaultdict(list)
        for idx, req in enumerate(requests):
            maxcut, model = normalize_problem(req.problem)
            if isinstance(req.hp, str):
                hp, reports[idx] = resolve_hyperparams(
                    req.hp, model, base=req.auto_base, seed=self.autotune_seed
                )
                req = dataclasses.replace(req, hp=hp)
                self.stats["autotuned"] += 1
            nb = bucket_n(model.n, self.min_bucket)
            groups[self._group_key(req, nb)].append((idx, req, maxcut, model))
        self.stats["groups"] += len(groups)
        for key, items in sorted(groups.items(), key=lambda kv: repr(kv[0])):
            kind, nb = key[0], key[1]
            solver = {"ssa": self._solve_ssa_group,
                      "sa": self._solve_sa_group,
                      "ptssa": self._solve_ptssa_group}[kind]
            solver(nb, items, responses, progress)
        for idx, resp in enumerate(responses):
            resp.autotune = reports.get(idx)
            enc = resp.request.problem
            if isinstance(enc, ProblemEncoding):
                sol, obj, feas = enc.best_feasible(resp.result.best_m)
                resp.solution, resp.objective, resp.feasible = sol, obj, feas
        return responses  # type: ignore[return-value]

    def cache_info(self) -> dict:
        """Executable-cache observability (programs + trace counters)."""
        return {
            "programs": len(self._programs),
            "keys": sorted(repr(k) for k in self._programs),
            **{k: v for k, v in self.stats.items()},
        }

    # ------------------------------------------------------------------
    # Grouping
    # ------------------------------------------------------------------
    def _group_key(self, req: AnnealRequest, nb: int):
        hp = req.hp
        if isinstance(hp, SSAHyperParams):
            sig = hp.schedule(req.schedule_kind).signature()
            return ("ssa", nb, hp.n_trials, hp.n_rnd, hp.m_shot, req.storage, sig)
        if isinstance(hp, SAHyperParams):
            return ("sa", nb, hp)
        if isinstance(hp, PTSSAHyperParams):
            return ("ptssa", nb, hp)
        raise TypeError(f"unsupported hyperparameter type {type(hp).__name__}")

    def _pad_group(self, items):
        """Pad a request group to a power-of-two batch (executable reuse).

        Dummy slots repeat the first request; their outputs are discarded.
        """
        b_live = len(items)
        b_bucket = next_pow2(b_live)
        padded = list(items) + [items[0]] * (b_bucket - b_live)
        return padded, b_live, b_bucket

    # ------------------------------------------------------------------
    # SSA / HA-SSA groups (the tentpole hot path)
    # ------------------------------------------------------------------
    def _solve_ssa_group(self, nb, items, responses, progress):
        t0 = time.perf_counter()
        _, req0, _, _ = items[0]
        hp: SSAHyperParams = req0.hp
        plateaus = schedule_plateaus(hp.schedule(req0.schedule_kind), req0.storage)
        stored_per_iter = sum(p.length for p in plateaus if p.eligible)
        chunk = _largest_divisor_leq(hp.m_shot, self.chunk_shots)
        n_chunks = hp.m_shot // chunk

        padded, b_live, b_bucket = self._pad_group(items)
        sig = self._group_key(req0, nb)[-1]
        cache_key = ("ssa", self.backend, self.storage_layout, nb, b_bucket,
                     hp.n_trials, hp.n_rnd, self.noise, req0.storage, sig,
                     chunk)
        ent = self._programs.get(cache_key)
        if ent is None:
            self.stats["program_cache_misses"] += 1
            bk = make_batched_backend(
                self.backend, n_bucket=nb, n_trials=hp.n_trials,
                n_rnd=hp.n_rnd, noise=self.noise,
                storage_layout=self.storage_layout, **self.backend_opts,
            )

            def init_fn(problem, ns0):
                self.stats["traces_init"] += 1
                return bk.init_state(problem, ns0)

            def chunk_fn(problem, state):
                self.stats["traces_chunk"] += 1
                return bk.run_shots(problem, state, plateaus, chunk)

            ent = (bk, jax.jit(init_fn), jax.jit(chunk_fn))
            self._programs[cache_key] = ent
        else:
            self.stats["program_cache_hits"] += 1
        bk, init_fn, chunk_fn = ent

        stacked = bk.stack([model for _, _, _, model in padded])
        ns0 = bk.init_noise(
            [req.seed for _, req, _, _ in padded],
            [model.n for _, _, _, model in padded],
        )
        state = init_fn(stacked, ns0)

        state, chunk_traces = self._chunk_loop(
            "ssa", nb, items, n_chunks, progress,
            lambda st: chunk_fn(stacked, st), state,
            lambda st: st.best_H,
        )
        bh_dev, bm_dev = bk.finalize(state)  # layout-agnostic (unpacks bitplanes)
        best_H = np.asarray(bh_dev)
        best_m = np.asarray(bm_dev)
        wall = time.perf_counter() - t0

        for slot, (idx, req, maxcut, model) in enumerate(items):
            bh = best_H[slot]
            result = AnnealResult(
                best_cut=np.asarray(finalize_cut(bh, maxcut)),
                best_energy=bh,
                best_m=best_m[slot][:, : model.n],
                energy_mean=None,
                energy_min=None,
                traj=None,
                stored_bits_per_iter=model.n * stored_per_iter,
                hp=req.hp,
            )
            responses[idx] = AnnealResponse(
                request=req, result=result, wall_s=wall, bucket=nb,
                batch=b_live, chunks_run=len(chunk_traces[slot]),
                chunks_total=n_chunks,
                chunk_best_cut=np.asarray(chunk_traces[slot]),
            )

    # ------------------------------------------------------------------
    # SA groups
    # ------------------------------------------------------------------
    def _solve_sa_group(self, nb, items, responses, progress):
        t0 = time.perf_counter()
        _, req0, _, _ = items[0]
        hp: SAHyperParams = req0.hp
        n_chunks = _largest_divisor_leq(hp.n_cycles, self.sa_chunks)
        chunk_cycles = hp.n_cycles // n_chunks

        padded, b_live, b_bucket = self._pad_group(items)
        cache_key = ("sa", nb, b_bucket, hp.n_trials, chunk_cycles)
        ent = self._programs.get(cache_key)
        if ent is None:
            self.stats["program_cache_misses"] += 1

            def init_fn(problem, keys):
                self.stats["traces_init"] += 1
                return jax.vmap(
                    lambda pr, k: sa_init(
                        pr["h"], pr["nbr_idx"], pr["nbr_w"], k,
                        n_trials=hp.n_trials,
                    )
                )(problem, keys)

            def chunk_fn(problem, carry, temps, n_lives):
                self.stats["traces_chunk"] += 1
                def one(pr, ca, nl):
                    ca, _ = sa_cycles(
                        pr["h"], pr["nbr_idx"], pr["nbr_w"], ca, temps,
                        n_live=nl,
                    )
                    return ca
                return jax.vmap(one)(problem, carry, n_lives)

            ent = (jax.jit(init_fn), jax.jit(chunk_fn))
            self._programs[cache_key] = ent
        else:
            self.stats["program_cache_hits"] += 1
        init_fn, chunk_fn = ent

        # SA reuses the sparse stacking (gather-based ΔH).
        stacker = make_batched_backend(
            "sparse", n_bucket=nb, n_trials=hp.n_trials, noise="xorshift"
        )
        stacked = stacker.stack([model for _, _, _, model in padded])
        keys = jnp.stack(
            [jax.random.PRNGKey(req.seed) for _, req, _, _ in padded]
        )
        n_lives = jnp.asarray([model.n for _, _, _, model in padded], jnp.int32)
        temps = np.asarray(
            sa_temperature_ladder(hp.t_start, hp.t_end, hp.n_cycles), np.float32
        )
        carry = init_fn(stacked, keys)

        chunk_arrays = [
            jnp.asarray(temps[c * chunk_cycles : (c + 1) * chunk_cycles])
            for c in range(n_chunks)
        ]
        state_idx = [0]

        def step(carry):
            c = state_idx[0]
            state_idx[0] += 1
            return chunk_fn(stacked, carry, chunk_arrays[c], n_lives)

        carry, chunk_traces = self._chunk_loop(
            "sa", nb, items, n_chunks, progress, step, carry,
            lambda ca: ca[3],
        )
        _, _, _, best_H, best_m = carry
        best_H = np.asarray(best_H)
        best_m = np.asarray(best_m)
        wall = time.perf_counter() - t0

        for slot, (idx, req, maxcut, model) in enumerate(items):
            bh = best_H[slot]
            result = SAResult(
                best_cut=np.asarray(finalize_cut(bh, maxcut)),
                best_energy=bh,
                best_m=best_m[slot][:, : model.n],
                energy_mean=None,
                energy_min=None,
                hp=req.hp,
            )
            responses[idx] = AnnealResponse(
                request=req, result=result, wall_s=wall, bucket=nb,
                batch=b_live, chunks_run=len(chunk_traces[slot]),
                chunks_total=n_chunks,
                chunk_best_cut=np.asarray(chunk_traces[slot]),
            )

    # ------------------------------------------------------------------
    # PT-SSA groups
    # ------------------------------------------------------------------
    def _solve_ptssa_group(self, nb, items, responses, progress):
        t0 = time.perf_counter()
        _, req0, _, _ = items[0]
        hp: PTSSAHyperParams = req0.hp
        if self.backend == "pallas":
            raise ValueError(
                "pt-ssa needs per-replica I0 columns; run the service with "
                "backend='sparse' or 'dense' for PTSSAHyperParams requests"
            )
        chunk = _largest_divisor_leq(hp.n_rounds, self.chunk_shots)
        n_chunks = hp.n_rounds // chunk

        padded, b_live, b_bucket = self._pad_group(items)
        cache_key = ("ptssa", self.backend, nb, b_bucket, hp, self.noise, chunk)
        ent = self._programs.get(cache_key)
        if ent is None:
            self.stats["program_cache_misses"] += 1
            bk = make_batched_backend(
                self.backend, n_bucket=nb, n_trials=hp.n_replicas,
                n_rnd=hp.n_rnd, noise=self.noise, **self.backend_opts,
            )

            def init_fn(problem, ns0):
                self.stats["traces_init"] += 1
                return bk.init_state(problem, ns0)

            def chunk_fn(problem, state, keys, parities):
                self.stats["traces_chunk"] += 1

                def one(pr, st, ks):
                    field_fn = lambda m: bk._field_one(pr, m)  # noqa: E731
                    return pt_ssa_rounds(
                        field_fn, bk._noise_step_one, pr["h"], hp, st,
                        ks, parities,
                    )

                return jax.vmap(one)(problem, state, keys)

            ent = (bk, jax.jit(init_fn), jax.jit(chunk_fn))
            self._programs[cache_key] = ent
        else:
            self.stats["program_cache_hits"] += 1
        bk, init_fn, chunk_fn = ent

        stacked = bk.stack([model for _, _, _, model in padded])
        ns0 = bk.init_noise(
            [req.seed for _, req, _, _ in padded],
            [model.n for _, _, _, model in padded],
        )
        state = init_fn(stacked, ns0)

        # Same swap-key derivation as anneal_pt_ssa, split once over all
        # rounds then sliced per chunk — chunked == unchunked, bitwise.
        all_keys = jnp.stack([
            jax.random.split(
                jax.random.PRNGKey(req.seed ^ 0x5CA1AB1E), hp.n_rounds
            )
            for _, req, _, _ in padded
        ])  # (B, n_rounds, 2)
        parities = jnp.arange(hp.n_rounds, dtype=jnp.int32) % 2
        state_idx = [0]

        def step(st):
            c = state_idx[0]
            state_idx[0] += 1
            sl = slice(c * chunk, (c + 1) * chunk)
            return chunk_fn(stacked, st, all_keys[:, sl], parities[sl])

        state, chunk_traces = self._chunk_loop(
            "ptssa", nb, items, n_chunks, progress, step, state,
            lambda st: st.best_H,
        )
        best_H = np.asarray(state.best_H)
        best_m = np.asarray(state.best_m)
        wall = time.perf_counter() - t0

        for slot, (idx, req, maxcut, model) in enumerate(items):
            bh = best_H[slot]
            result = PTSSAResult(
                best_cut=np.asarray(finalize_cut(bh, maxcut)),
                best_energy=bh,
                best_m=best_m[slot][:, : model.n],
                energy_mean=None,
                energy_min=None,
                hp=req.hp,
            )
            responses[idx] = AnnealResponse(
                request=req, result=result, wall_s=wall, bucket=nb,
                batch=b_live, chunks_run=len(chunk_traces[slot]),
                chunks_total=n_chunks,
                chunk_best_cut=np.asarray(chunk_traces[slot]),
            )

    # ------------------------------------------------------------------
    # Shared chunk loop: streaming best_H reports + early stop
    # ------------------------------------------------------------------
    def _chunk_loop(self, kind, nb, items, n_chunks, progress, step, state,
                    best_of):
        """Run up to n_chunks steps; report per-chunk bests; stop early when
        every request that declared a target_cut has reached it (and all
        requests declared one)."""
        any_untargeted = any(req.target_cut is None for _, req, _, _ in items)
        traces = [[] for _ in items]
        for c in range(n_chunks):
            state = step(state)
            best_H = np.asarray(best_of(state))  # device sync: the report
            bests = []
            for slot, (idx, req, maxcut, model) in enumerate(items):
                obj = np.asarray(finalize_cut(best_H[slot], maxcut))
                best = int(np.max(obj))
                traces[slot].append(best)
                bests.append(best)
            self.stats["chunks_run"] += 1
            if progress is not None:
                progress(AnnealProgress(
                    kind=kind, bucket=nb, chunk=c, chunks_total=n_chunks,
                    request_indices=tuple(idx for idx, *_ in items),
                    best_cut=tuple(bests),
                ))
            if not any_untargeted and all(
                b >= req.target_cut
                for b, (_, req, _, _) in zip(bests, items)
            ):
                self.stats["early_stops"] += 1
                break
        return state, traces
