"""Shape-bucketed annealing service: one compiled plateau program serving
batched heterogeneous Max-Cut requests (DESIGN.md §7), with a resilience
layer that degrades gracefully on any fault below the request boundary
(DESIGN.md §10).

The paper's operating mode is "one fixed pipeline, many instances": the FPGA
streams Max-Cut problems through a single annealing datapath.  The TPU
transcription is this service:

* **Shape buckets** — incoming problems are zero-padded to power-of-two N
  (:func:`repro.core.engine.bucket_n` / :func:`~repro.core.engine.pad_model`),
  so a heterogeneous request stream collapses onto a handful of shapes.
* **Compiled-executable cache** — one jitted plateau program per
  ``(algorithm, backend, backend_opts, N_bucket, B_bucket, n_trials, n_rnd,
  noise, storage, Schedule.signature(), chunk)``.  Problem arrays are
  *arguments* to the program, never closed-over constants, so every
  same-bucket request group reuses the same executable: 4 G-set instances
  in one bucket compile the plateau program exactly once (trace-count
  tested).
* **Problem-axis batching** — same-bucket requests are stacked on a leading
  problem axis and solved in ONE device launch via the engine's batched
  backends (vmap for sparse/dense, the (B, R-tile)-grid resident kernel for
  pallas).  Batched runs are bit-identical per problem to unbatched,
  unpadded runs on the live lanes (padding-invariance tested) when the
  noise source is ``xorshift``.
* **Chunked execution with early stop** — the m_shot iteration budget runs
  in chunks; after each chunk the per-request best energy is reported
  (streaming progress) and a group whose requests have all reached their
  ``target_cut`` stops early.
* **Packed storage + tiled J** — ``storage_layout='packed'`` carries the
  engine state between chunk launches as uint32 spin bitplanes (and, for
  the pallas backend with xorshift noise, runs the streamed-noise packed
  kernel: no noise buffer, packed HBM refs).  The dense backend's
  ``j_mode='auto'`` streams (tile_n, N) J slabs above
  ``engine.TILED_J_THRESHOLD`` spins instead of materializing (B, N, N) —
  G77/G81-class buckets (N = 10k–20k) serve through the same entry.  Both
  axes ride the executable-cache key; results stay bit-identical.

Resilience (DESIGN.md §10).  Because *all* live state between plateau
chunks is a tiny explicit buffer — spin (bit)planes, the carried
xorshift128 lanes, ``best_H`` and the chunk index — faults recover
*bit-identically*, not best-effort:

* **Chunk-level checkpoint/resume** — with
  ``ResiliencePolicy(checkpoint_dir=...)`` each group snapshots its engine
  state through :class:`repro.checkpoint.ckpt.CheckpointManager` at chunk
  boundaries, keyed by a stable group fingerprint.  A process killed
  mid-solve resumes from the last boundary and produces bit-identical
  ``best_cut``/spins to an uninterrupted run (chaos-tested for all three
  backends with ``noise='xorshift'``).
* **Backend fallback chain** — a compile/launch failure walks
  pallas→dense→sparse; a dense-J OOM downgrades to tiled-J first.  The
  fallback re-enters the executable cache under its own key, and the
  downgrade is recorded on ``AnnealResponse.status``/``events``.
* **Watchdogs** — a per-request wall-clock ``deadline_s`` returns
  best-so-far with ``status='deadline'`` at the next chunk boundary; a
  non-finite energy detector quarantines the offending request (solo retry
  with exponential backoff and a re-autotuned I0max) without touching its
  batchmates' bit-exactness; admission validation rejects non-finite
  weights and absurd shapes with typed :class:`AdmissionError`\\ s before
  any device work happens.
* **Fault injection** — every failure path above is exercised by the hook
  points an attached :class:`repro.ft.faults.FaultInjector` fires
  (compile / oom / nan / kill), driven by the chaos suite.

Beyond Max-Cut, any :class:`~repro.problems.ProblemEncoding` (QUBO, MIS,
coloring, partitioning — DESIGN.md §9) rides the same entry, and
``hp='auto'`` resolves per-instance hyperparameters before grouping
(:mod:`repro.core.autotune`), so autotuning composes with batching and the
executable cache instead of fragmenting them.

SA (:class:`~repro.core.sa.SAHyperParams`) and PT-SSA
(:class:`~repro.core.pt.PTSSAHyperParams`) requests ride the same entry:
they are grouped, bucketed, stacked, chunked, checkpointed and
early-stopped identically — SA through the vmapped Metropolis core,
PT-SSA through :func:`repro.core.pt.pt_ssa_rounds` with the replica ladder
on the engine's trial axis.  (SA groups never need the backend fallback
chain: their Metropolis core is backend-independent.)

SSQA (:class:`~repro.core.ssqa.SSQAHyperParams`, ``algo='ssqa'``) is the
fourth family (DESIGN.md §13): it rides the SSA plateau path with the
Trotter-replica ring on the trial axis — the group solver injects
``n_replicas`` into the backend opts (program-structural: ring width per
R-tile) and the J⊥ ramp rides the schedule signature, so SSQA groups get
their own cached executables while sharing every line of the batching,
chunking, checkpointing and fallback machinery.  Family dispatch and the
per-family admission rules live in :mod:`repro.serve.registry`.
"""
from __future__ import annotations

import collections
import dataclasses
import os
import threading
import time
from typing import Callable, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.ckpt import CheckpointManager, latest_step
from repro.core.autotune import (
    AutotuneReport,
    autotune_hyperparams,
    resolve_hyperparams,
)
from repro.core.engine import (
    MAX_UNSHARDED_SPINS,
    bucket_n,
    finalize_cut,
    make_batched_backend,
    model_weight_bits,
    next_pow2,
    normalize_problem,
    resolve_backend,
    resolve_field_mode,
    resolve_partition,
    schedule_plateaus,
    validate_model,
)
from repro.core.config import SolverConfig
from repro.core.ising import IsingModel, MaxCutProblem
from repro.core.pt import PTSSAHyperParams, PTSSAResult, pt_ssa_rounds
from repro.core.rng import xorshift_lanes_ok
from repro.core.sa import SAHyperParams, SAResult, sa_cycles, sa_init
from repro.core.schedule import sa_temperature_ladder
from repro.core.ssa import AnnealResult, SSAHyperParams
from repro.core.ssqa import SSQAHyperParams
from repro.ft.faults import FaultInjector
from repro.problems import ProblemEncoding
from repro.sharding import mesh_fingerprint

from .registry import family_for, registered_algos

from .resilience import (
    STATUS_DEADLINE,
    STATUS_FAILED,
    STATUS_FALLBACK,
    STATUS_OK,
    STATUS_QUARANTINED,
    AdmissionError,
    QuarantineFault,
    ResiliencePolicy,
    ServiceEvent,
    classify_fault,
    fallback_step,
    filter_backend_opts,
    group_fingerprint,
)

__all__ = [
    "AnnealRequest",
    "AnnealResponse",
    "AnnealProgress",
    "AnnealService",
]

HyperParams = Union[SSAHyperParams, SAHyperParams, PTSSAHyperParams,
                    SSQAHyperParams]


@dataclasses.dataclass(frozen=True)
class AnnealRequest:
    """One problem + hyperparameters, as the service accepts it.

    ``problem`` is a Max-Cut instance, a raw Ising model, or any encoded
    problem from :mod:`repro.problems` (QUBO, MIS, coloring, partitioning…)
    — encoded problems come back with a decoded, feasibility-verified domain
    solution on the response.

    ``hp`` selects the algorithm family through the registry
    (:mod:`repro.serve.registry`): SSAHyperParams → SSA/HA-SSA (the paper's
    annealer), SSQAHyperParams → Trotter-replica SSQA, SAHyperParams →
    Metropolis SA, PTSSAHyperParams → PT on the plateau engine.  ``algo``
    optionally names the family explicitly (``'ssa'``/``'sa'``/``'ptssa'``/
    ``'ssqa'``): it is validated against the hp type, and with ``hp='auto'``
    it selects which family the autotuner targets (``algo='ssqa'`` tunes
    the Trotter ring too).  The string ``'auto'`` requests
    local-energy-distribution autotuning (:mod:`repro.core.autotune`).
    ``config`` is a per-request :class:`~repro.core.config.SolverConfig`
    override of the service's backend/backend-option defaults (its
    ``noise``/``storage_layout`` must match the service's — those axes are
    service-wide contracts); its ``signature()`` joins the batching key so
    differently-configured requests never share a compiled program.
    ``target_cut`` arms chunk-level early stop.  ``deadline_s`` is the
    per-request wall-clock budget, measured from the ``solve()`` call: once
    it elapses, the request stops participating in its group's continuation
    and its response returns best-so-far with ``status='deadline'`` at the
    next chunk boundary — it never raises.
    """

    problem: Union[MaxCutProblem, IsingModel, ProblemEncoding]
    hp: Union[HyperParams, str] = SSAHyperParams()
    seed: int = 0
    storage: str = "i0max"         # SSA only: 'i0max' (HA-SSA) | 'all' (SSA)
    schedule_kind: str = "hassa"   # SSA only
    target_cut: Optional[int] = None
    auto_base: Optional[SSAHyperParams] = None  # budget knobs for hp='auto'
    deadline_s: Optional[float] = None  # wall-clock budget from solve() entry
    algo: Optional[str] = None     # explicit family name (registry-validated)
    config: Optional[SolverConfig] = None  # per-request solver-option override


@dataclasses.dataclass
class AnnealResponse:
    request: AnnealRequest
    result: object                 # AnnealResult | SAResult | PTSSAResult | None
    wall_s: float                  # group wall time (the batch solves together)
    bucket: int                    # padded N the request ran at
    batch: int                     # live requests stacked in its group
    chunks_run: int                # chunks executed (early stop may cut short)
    chunks_total: int
    chunk_best_cut: np.ndarray     # (chunks_run,) streaming best-objective trace
    solution: object = None        # decoded domain solution (encoded problems)
    objective: Optional[int] = None  # domain objective of `solution` if feasible
    feasible: Optional[bool] = None  # verifier verdict (None: raw Ising/maxcut)
    autotune: Optional[AutotuneReport] = None  # set when hp='auto' resolved
    status: str = STATUS_OK        # 'ok'|'fallback'|'deadline'|'quarantined'|'failed'|'shed'
    events: List[ServiceEvent] = dataclasses.field(default_factory=list)
    # Per-lane latency honesty (streaming): a lane that early-stops reports
    # the wall time to ITS chunk-boundary stop, not the whole group's.
    lane_wall_s: Optional[float] = None  # group start → this lane's stop boundary
    queued_s: Optional[float] = None     # streaming only: submit → first seated


@dataclasses.dataclass(frozen=True)
class AnnealProgress:
    """One streaming progress report (per group, per chunk)."""

    kind: str                      # 'ssa' | 'sa' | 'ptssa' | 'ssqa'
    bucket: int
    chunk: int
    chunks_total: int
    request_indices: tuple         # indices into the solve() request list
    best_cut: tuple                # best objective so far, per request


def _largest_divisor_leq(n: int, k: int) -> int:
    k = max(1, min(int(k), int(n)))
    while n % k:
        k -= 1
    return k


def _opts_key(opts: dict) -> tuple:
    """Hashable projection of backend_opts for the executable-cache key."""
    return tuple(sorted((k, repr(v)) for k, v in opts.items()))


class _LRUCache:
    """Bounded LRU map for compiled executables.

    Under diverse streaming traffic the per-group-key program population is
    unbounded (every new (bucket, batch, schedule, opts) shape compiles a
    fresh program and its XLA executable stays live), so the cache evicts
    least-recently-used entries past ``capacity``, counting evictions into
    the service's ``stats``.  Thread-safe: concurrent ``solve()`` calls and
    the streaming scheduler hit it from different threads.  Two threads
    missing on the same key may both build the program; the second ``put``
    wins and the loser's executable is garbage — wasteful but correct
    (build-outside-lock keeps compiles from serializing the service).
    """

    def __init__(self, capacity: int, stats: collections.Counter):
        if capacity < 1:
            raise ValueError(f"max_cached_executables must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self._od: collections.OrderedDict = collections.OrderedDict()
        self._lock = threading.Lock()
        self._stats = stats

    def get(self, key):
        with self._lock:
            ent = self._od.get(key)
            if ent is not None:
                self._od.move_to_end(key)
            return ent

    def __setitem__(self, key, ent):
        with self._lock:
            self._od[key] = ent
            self._od.move_to_end(key)
            while len(self._od) > self.capacity:
                self._od.popitem(last=False)
                self._stats["program_cache_evictions"] += 1

    def __len__(self):
        with self._lock:
            return len(self._od)

    def __contains__(self, key):
        with self._lock:
            return key in self._od

    def __iter__(self):
        with self._lock:
            return iter(list(self._od))

    def values(self):
        with self._lock:
            return list(self._od.values())


class _GroupCtx:
    """Per-attempt execution context for one request group.

    Carries the effective backend (which the fallback chain may have
    downgraded from the service default), the fault-injection hooks, the
    group's checkpoint namespace, and the per-request statuses/events the
    chunk loop accumulates (deadline expirations, resumes, …).
    """

    def __init__(self, service: "AnnealService", kind: str, nb: int, items,
                 backend: str, backend_opts: dict, solve_t0: float,
                 chunk: int, events: Optional[List[ServiceEvent]] = None):
        self.kind = kind
        self.backend = backend
        self.backend_opts = dict(backend_opts)
        self.solve_t0 = solve_t0
        self.faults: Optional[FaultInjector] = service.faults
        self.policy: ResiliencePolicy = service.policy
        self.noise = service.noise
        self.events: List[ServiceEvent] = list(events or [])
        self.statuses: dict = {}
        self.ckpt: Optional[CheckpointManager] = None
        self._dir: Optional[str] = None
        if self.policy.checkpoint_dir:
            part = service.partition_for(kind, nb)
            tag = group_fingerprint(kind, nb, backend, service.storage_layout,
                                    service.noise, chunk, items,
                                    partition=part,
                                    mesh_fp=(mesh_fingerprint(service.mesh)
                                             if part == "spin" else ()))
            self._dir = os.path.join(self.policy.checkpoint_dir, tag)
            self.ckpt = CheckpointManager(
                self._dir,
                save_interval=max(1, int(self.policy.checkpoint_interval)),
                keep=self.policy.keep_checkpoints,
                async_save=False,  # deterministic crash window
            )

    # -- fault hooks ------------------------------------------------------
    def fire(self, point: str, **ctx):
        if self.faults is None:
            return None
        return self.faults.fire(point, **ctx)

    def _event(self, kind: str, **detail):
        self.events.append(
            ServiceEvent(kind, detail, time.perf_counter() - self.solve_t0)
        )

    # -- checkpointing ----------------------------------------------------
    def maybe_resume(self, template, n_items: int):
        """(start_chunk, state, traces) — resuming if a valid snapshot exists."""
        if self.ckpt is None or latest_step(self._dir) is None:
            return 0, template, None
        state, meta = self.ckpt.restore_latest(template)
        traces = meta.get("traces")
        ok = isinstance(traces, list) and len(traces) == n_items
        if ok and self.noise == "xorshift":
            lanes = getattr(state, "noise_state", None)
            # Batched lane layout (B, 4, T, N): the 4-word axis is axis 1.
            ok = lanes is not None and xorshift_lanes_ok(lanes, axis=1)
        if not ok:
            self._event("checkpoint_rejected", dir=self._dir)
            return 0, template, None
        start = int(meta["step"])
        self._event("resume", chunk=start, dir=self._dir)
        return start, state, [list(map(int, t)) for t in traces]

    def save(self, step: int, state, traces):
        if self.ckpt is not None:
            self.ckpt.maybe_save(step, state, meta={"traces": traces})

    def finish_success(self):
        if self.ckpt is not None and self.policy.cleanup_on_success:
            self.ckpt.purge()


class AnnealService:
    """Batched annealing-as-a-service over the plateau engine.

    One service instance owns a backend choice, a noise source, the
    compiled-executable cache, and a :class:`ResiliencePolicy`.
    ``solve(requests)`` groups requests by (algorithm, shape bucket,
    hyperparameters), stacks each group on the problem axis, and runs it
    through one cached compiled program; any fault below the request
    boundary (compile failure, OOM, non-finite energies, deadline) degrades
    that group gracefully instead of failing the batch — see the module
    docstring and DESIGN.md §10 for the failure model.

    Bit-exactness contract (noise='xorshift'): an SSA or PT-SSA request
    solved through the service — padded, stacked, chunked, checkpointed,
    resumed — returns the same best energy/spins on its live lanes as the
    corresponding single-problem driver (`anneal` / `anneal_pt_ssa`) on the
    unpadded instance.  SA requests are valid runs but not bit-comparable
    (their threefry init draw is shape-dependent).
    """

    def __init__(
        self,
        backend: str = "sparse",
        *,
        noise: str = "xorshift",
        storage_layout: str = "dense",
        chunk_shots: int = 1,
        sa_chunks: int = 8,
        min_bucket: int = 64,
        backend_opts: Optional[dict] = None,
        autotune_seed: int = 0,
        resilience: Optional[ResiliencePolicy] = None,
        faults: Optional[FaultInjector] = None,
        partition: str = "problem",
        mesh=None,
        max_cached_executables: int = 64,
        config: Optional[SolverConfig] = None,
    ):
        """``storage_layout='packed'`` keeps the HBM-resident engine state
        between chunk launches as uint32 spin bitplanes (DESIGN.md §4).
        ``backend='auto'`` resolves per shape bucket (resident pallas at or
        above ``engine.MIN_RESIDENT_N`` spins, dense below — the small-N
        launch-overhead rule), filtering ``backend_opts`` to whatever the
        chosen backend accepts.  ``backend_opts={'field_mode': 'auto'}``
        additionally resolves the XNOR-popcount contraction per group
        (DESIGN.md §8): groups whose couplings fit
        ``engine.POPCOUNT_AUTO_MAX_BITS`` magnitude bitplanes run bit-
        parallel, with the group's plane count folded into the executable-
        cache key.  ``resilience`` configures checkpointing/fallback/retry
        (defaults: fallback + admission validation on, checkpointing off);
        ``faults`` attaches a fault injector whose hook points the service
        fires (testing/chaos only — never set in production).

        ``partition`` selects the work-partitioning axis for SSA groups
        (DESIGN.md §11): ``'problem'`` (default) stacks whole problems per
        device; ``'spin'`` shards the spin axis of every problem over
        ``mesh``'s model axis via shard_map collectives — the only way
        instances above ``engine.MAX_UNSHARDED_SPINS`` are admitted;
        ``'auto'`` resolves per shape bucket.  Spin-sharded groups require
        ``noise='xorshift'`` (shard-local lane seeding is what makes sharded
        runs bit-identical to single-device runs).  SA and PT-SSA groups
        always run problem-partitioned.

        ``config`` supplies the whole knob set from one
        :class:`~repro.core.config.SolverConfig` — its backend, noise,
        storage_layout, field/J/noise-mode options, partition and mesh
        replace the corresponding individual kwargs (which remain for
        compatibility and are ignored when ``config`` is given).
        """
        if config is not None:
            backend = config.backend
            noise = config.noise
            storage_layout = config.storage_layout
            backend_opts = config.engine_opts()
            backend_opts.pop("storage_layout", None)  # passed apart below
            partition = config.partition
            mesh = config.mesh if config.mesh is not None else mesh
        if storage_layout not in ("dense", "packed"):
            raise ValueError(f"unknown storage_layout {storage_layout!r}")
        if partition not in ("problem", "spin", "auto"):
            raise ValueError(f"unknown partition {partition!r}")
        self.backend = backend
        self.noise = noise
        self.storage_layout = storage_layout
        self.chunk_shots = int(chunk_shots)   # SSA iterations / PT rounds per chunk
        self.sa_chunks = int(sa_chunks)       # SA: report/early-stop points per run
        self.min_bucket = int(min_bucket)
        self.autotune_seed = int(autotune_seed)
        self.backend_opts = dict(backend_opts or {})
        self.policy = resilience or ResiliencePolicy()
        self.faults = faults
        self.partition = partition
        self.mesh = mesh
        self.stats = collections.Counter()
        # LRU-bounded: diverse streaming traffic would otherwise grow one
        # live XLA executable per unique group key forever.
        self._programs = _LRUCache(max_cached_executables, self.stats)

    def partition_for(self, kind: str, nb: int) -> str:
        """Effective partition for one group: 'problem' or 'spin'.

        Spin sharding applies only to the plateau path (SSA and SSQA — the
        replica ring lives on the shard-local trial axis, so sharding the
        spin axis needs no extra collectives) — SA and PT-SSA run through
        per-problem field closures the shard_map backend doesn't expose, so
        they stay problem-partitioned regardless of the knob.
        """
        if kind not in ("ssa", "ssqa"):
            return "problem"
        return resolve_partition(self.partition, nb, self.mesh)

    # ------------------------------------------------------------------
    # Public API
    # ------------------------------------------------------------------
    def solve(
        self,
        requests: Sequence[AnnealRequest],
        progress: Optional[Callable[[AnnealProgress], None]] = None,
    ) -> List[AnnealResponse]:
        """Solve a batch of heterogeneous requests; responses keep order.

        ``solve([])`` returns ``[]``.  The same request object may appear
        multiple times in one batch (aliased requests): each occurrence gets
        its own response.  ``hp='auto'`` requests are resolved *before*
        grouping — autotuned hyperparameters are ordinary call-time
        arguments by the time the bucketing and the compiled-executable
        cache see them.  Admission validation (non-finite weights, absurd
        shapes, bad knobs) rejects the batch with a typed
        :class:`AdmissionError` before any device work happens.
        """
        if not requests:
            return []
        t_solve0 = time.perf_counter()
        self.stats["requests"] += len(requests)
        responses: List[Optional[AnnealResponse]] = [None] * len(requests)
        reports: dict = {}
        groups = collections.defaultdict(list)
        for idx, req in enumerate(requests):
            try:
                maxcut, model = normalize_problem(req.problem)
            except TypeError as e:
                raise AdmissionError(f"request {idx}: {e}") from e
            if self.policy.validate_admission:
                self._admit(idx, req, model)
            if isinstance(req.hp, str):
                hp, reports[idx] = resolve_hyperparams(
                    req.hp, model, base=req.auto_base, seed=self.autotune_seed,
                    algo=req.algo,
                )
                req = dataclasses.replace(req, hp=hp)
                self.stats["autotuned"] += 1
            fam = family_for(req.hp, algo=req.algo)  # raises AdmissionError
            if fam.validate is not None:
                # Family-owned admission rules are correctness (a backend
                # the family cannot run on), not optional hygiene — they
                # fire even with policy.validate_admission off.
                fam.validate(self, idx, req, req.hp)
            nb = bucket_n(model.n, self.min_bucket)
            groups[self._group_key(req, nb)].append((idx, req, maxcut, model))
        self.stats["groups"] += len(groups)
        for key, items in sorted(groups.items(), key=lambda kv: repr(kv[0])):
            kind, nb = key[0], key[1]
            self._solve_group_resilient(kind, nb, items, responses, progress,
                                        t_solve0)
        for idx, resp in enumerate(responses):
            resp.autotune = reports.get(idx)
            if resp.result is None:
                continue
            enc = resp.request.problem
            if isinstance(enc, ProblemEncoding):
                sol, obj, feas = enc.best_feasible(resp.result.best_m)
                resp.solution, resp.objective, resp.feasible = sol, obj, feas
        return responses  # type: ignore[return-value]

    def cache_info(self) -> dict:
        """Executable-cache observability (programs + trace counters)."""
        return {
            "programs": len(self._programs),
            "capacity": self._programs.capacity,
            "evictions": self.stats["program_cache_evictions"],
            "keys": sorted(repr(k) for k in self._programs),
            **{k: v for k, v in self.stats.items()},
        }

    # ------------------------------------------------------------------
    # Admission
    # ------------------------------------------------------------------
    def _admit(self, idx: int, req: AnnealRequest, model: IsingModel):
        try:
            validate_model(model)
        except ValueError as e:
            self.stats["admission_rejects"] += 1
            raise AdmissionError(f"request {idx}: {e}") from e
        if req.deadline_s is not None and not float(req.deadline_s) > 0:
            self.stats["admission_rejects"] += 1
            raise AdmissionError(
                f"request {idx}: deadline_s must be > 0, got {req.deadline_s}"
            )
        if req.config is not None:
            # Per-request configs may retarget backend/field options, but
            # noise and storage layout are service-wide contracts (they key
            # checkpoint fingerprints and the packed-state carry format).
            if req.config.noise != self.noise:
                self.stats["admission_rejects"] += 1
                raise AdmissionError(
                    f"request {idx}: config.noise={req.config.noise!r} "
                    f"differs from the service's noise={self.noise!r}"
                )
            if req.config.storage_layout != self.storage_layout:
                self.stats["admission_rejects"] += 1
                raise AdmissionError(
                    f"request {idx}: config.storage_layout="
                    f"{req.config.storage_layout!r} differs from the "
                    f"service's storage_layout={self.storage_layout!r}"
                )
        if model.n > MAX_UNSHARDED_SPINS:
            # Giant instances are admissible only when they will actually
            # route to the spin-sharded SSA path (DESIGN.md §11) — on the
            # problem-partitioned path a single (N, N)-coupled instance of
            # this size is an OOM/compile hazard, not a request.
            ssa_family = isinstance(req.hp, (SSAHyperParams, str))
            nb = bucket_n(model.n, self.min_bucket)
            if not (ssa_family and self.partition_for("ssa", nb) == "spin"):
                self.stats["admission_rejects"] += 1
                raise AdmissionError(
                    f"request {idx}: n={model.n} exceeds the single-device "
                    f"ceiling MAX_UNSHARDED_SPINS={MAX_UNSHARDED_SPINS}; "
                    "construct the service with partition='spin' (or 'auto') "
                    "and a multi-device mesh (repro.sharding.spin_mesh) to "
                    "shard the spin axis"
                )

    # ------------------------------------------------------------------
    # Grouping
    # ------------------------------------------------------------------
    def _group_key(self, req: AnnealRequest, nb: int):
        """Family key from the registry + the per-request config signature.

        Requests batch together only when the family's own key components
        match AND they carry the same (or no) :class:`SolverConfig` — two
        requests pinned to different backends must never share a program.
        """
        fam = family_for(req.hp, algo=req.algo)
        cfg_sig = req.config.signature() if req.config is not None else None
        return fam.group_key(req, req.hp, nb) + (cfg_sig,)

    def _resolve_field_opts(self, backend: str, opts: dict, items) -> dict:
        """Resolve field_mode='auto' + group ``j_bits`` for one request group.

        The popcount contraction's magnitude-plane count is program-
        structural (the stacked ``mags`` tensor's shape), so it must be
        uniform across the group: every model packs to the group maximum.
        The resolved values land in the opts dict — and therefore in the
        executable-cache key via ``_opts_key`` — so a ±1 group and a 3-bit
        group never collide on one compiled program.
        """
        if backend not in ("dense", "pallas") or "field_mode" not in opts:
            return dict(opts)
        opts = dict(opts)
        jb = max(model_weight_bits(model) for _, _, _, model in items)
        opts["field_mode"] = resolve_field_mode(opts["field_mode"], jb)
        if opts["field_mode"] == "popcount":
            opts["j_bits"] = max(jb, int(opts.get("j_bits", 1)))
        else:
            opts.pop("j_bits", None)
        return opts

    def _pad_group(self, items):
        """Pad a request group to a power-of-two batch (executable reuse).

        Dummy slots repeat the first request; their outputs are discarded.
        """
        b_live = len(items)
        b_bucket = next_pow2(b_live)
        padded = list(items) + [items[0]] * (b_bucket - b_live)
        return padded, b_live, b_bucket

    # ------------------------------------------------------------------
    # Resilient group dispatch: fallback chain + quarantine + retry
    # ------------------------------------------------------------------
    def _solve_group_resilient(self, kind, nb, items, responses, progress,
                               solve_t0, *, requeue_quarantine: bool = True):
        """Run one group with the resilience wrapper (DESIGN.md §10).

        A classified compile/OOM fault walks the fallback chain and re-runs
        the group from scratch on the downgraded backend (bit-identity is
        preserved — the trajectory depends only on the noise stream, not the
        backend).  A quarantine signal splits the group: healthy requests
        re-run as a fresh group, offenders retry solo with backoff.  Kills
        and unclassified errors propagate.
        """
        solver = getattr(self, registered_algos()[kind].solver)
        cfg = items[0][1].config
        if cfg is not None:
            # Per-request SolverConfig override: backend + engine options
            # come from the config (noise/storage_layout were admission-
            # checked to match the service, and the group key carries the
            # config signature, so every item in the group agrees).
            backend = cfg.backend
            opts = cfg.engine_opts()
            opts.pop("storage_layout", None)  # service-wide, passed apart
        else:
            backend, opts = self.backend, dict(self.backend_opts)
        if backend == "auto":
            # Resolve per bucket (MIN_RESIDENT_N rule) and drop any opts the
            # chosen backend doesn't accept — 'auto' users pass a union.
            backend = resolve_backend(backend, nb)
            opts = filter_backend_opts(backend, opts,
                                       partition=self.partition_for(kind, nb))
        carried_events: List[ServiceEvent] = []
        while True:
            ctx = _GroupCtx(self, kind, nb, items, backend, opts, solve_t0,
                            self._chunk_of(kind, items), events=carried_events)
            try:
                solver(nb, items, responses, progress, ctx)
            except QuarantineFault as qf:
                if not requeue_quarantine:
                    raise
                self.stats["quarantines"] += 1
                self._handle_quarantine(kind, nb, items, qf, responses,
                                        progress, solve_t0, ctx)
                return
            except Exception as exc:  # noqa: BLE001 — classified below
                fault = None
                if kind != "sa":  # SA's Metropolis core is backend-independent
                    fault = classify_fault(exc, backend)
                nxt = (fallback_step(backend, opts, fault, nb)
                       if fault is not None and self.policy.fallback else None)
                if nxt is None:
                    raise
                self.stats[f"fallback_{fault}"] += 1
                carried_events = list(ctx.events)
                carried_events.append(ServiceEvent(
                    "fallback",
                    {"from": backend, "to": nxt[0], "fault": fault,
                     "from_opts": dict(opts), "to_opts": dict(nxt[1]),
                     "error": f"{type(exc).__name__}: {exc}"[:200]},
                    time.perf_counter() - solve_t0,
                ))
                backend, opts = nxt
                continue
            # Success: finalize statuses/events and clean up checkpoints.
            default = (STATUS_FALLBACK
                       if any(ev.kind == "fallback" for ev in ctx.events)
                       else STATUS_OK)
            for idx, *_rest in items:
                resp = responses[idx]
                resp.status = ctx.statuses.get(idx, default)
                resp.events = list(ctx.events)
            ctx.finish_success()
            return

    def _chunk_of(self, kind, items) -> int:
        """The group's chunk width (part of its checkpoint fingerprint)."""
        hp = items[0][1].hp
        if kind in ("ssa", "ssqa"):
            return _largest_divisor_leq(hp.m_shot, self.chunk_shots)
        if kind == "ptssa":
            return _largest_divisor_leq(hp.n_rounds, self.chunk_shots)
        return hp.n_cycles // _largest_divisor_leq(hp.n_cycles, self.sa_chunks)

    def _handle_quarantine(self, kind, nb, items, qf, responses, progress,
                           solve_t0, ctx):
        """Split a poisoned group: healthy slots re-run, offenders go solo.

        Per-problem lanes are independent (the padding-invariance property),
        so re-running the healthy requests as a fresh group is bit-identical
        to what the original batch would have produced for them.
        """
        bad = set(qf.slots)
        good = [it for s, it in enumerate(items) if s not in bad]
        bad_items = [it for s, it in enumerate(items) if s in bad]
        if good:
            self._solve_group_resilient(kind, nb, good, responses, progress,
                                        solve_t0)
        for it in bad_items:
            self._retry_solo(kind, nb, it, responses, progress, solve_t0)

    def _retry_solo(self, kind, nb, item, responses, progress, solve_t0):
        """Quarantined request: exponential backoff + re-autotuned I0max.

        Each attempt re-derives the I0 clamp from the instance's local-field
        distribution (:mod:`repro.core.autotune`) — if the non-finite energy
        came from an I0/field-scale mismatch, the retuned clamp is the
        principled fix; injected bursts simply clear on retry.  After
        ``max_retries`` the response is returned with ``status='failed'``
        (never an exception).
        """
        idx, req, maxcut, model = item
        events: List[ServiceEvent] = [ServiceEvent(
            "quarantine", {"request": idx},
            time.perf_counter() - solve_t0,
        )]
        hp = req.hp
        for attempt in range(self.policy.max_retries):
            time.sleep(self.policy.backoff_base_s * (2 ** attempt))
            if isinstance(hp, SSAHyperParams):
                tuned, rep = autotune_hyperparams(
                    model, hp, seed=self.autotune_seed + attempt + 1
                )
                hp = dataclasses.replace(hp, i0_max=tuned.i0_max)
                detail = {"request": idx, "attempt": attempt,
                          "i0_max": tuned.i0_max, "z_max": rep.z_max}
            else:
                detail = {"request": idx, "attempt": attempt}
            events.append(ServiceEvent(
                "retry", detail, time.perf_counter() - solve_t0
            ))
            req_retry = dataclasses.replace(req, hp=hp)
            try:
                self._solve_group_resilient(
                    kind, nb, [(idx, req_retry, maxcut, model)], responses,
                    progress, solve_t0, requeue_quarantine=False,
                )
            except QuarantineFault:
                self.stats["retry_requarantined"] += 1
                continue
            resp = responses[idx]
            resp.status = STATUS_QUARANTINED
            resp.events = events + resp.events
            self.stats["quarantine_recoveries"] += 1
            return
        self.stats["quarantine_failures"] += 1
        responses[idx] = AnnealResponse(
            request=req, result=None,
            wall_s=time.perf_counter() - solve_t0, bucket=nb, batch=1,
            chunks_run=0, chunks_total=0,
            chunk_best_cut=np.zeros(0, np.int64),
            status=STATUS_FAILED, events=events,
        )

    # ------------------------------------------------------------------
    # SSA / HA-SSA groups (the tentpole hot path)
    # ------------------------------------------------------------------
    def _ssa_programs(self, *, nb, b_bucket, hp, storage, schedule_kind,
                      backend, opts, chunk, fire=None, kind="ssa"):
        """Compiled SSA/SSQA plateau programs for one (bucket, batch) shape.

        Returns ``(bk, init_fn, chunk_fn, plateaus)`` from the bounded
        executable cache, compiling on miss.  Shared by the one-shot group
        solver and the streaming slot tables (:mod:`repro.serve.stream`) —
        the cache key deliberately excludes ``m_shot``: the plateau chain per
        iteration is budget-independent, so a slot table can serve mixed
        chunk budgets through one program.  SSQA groups arrive with
        ``kind='ssqa'`` and ``opts['n_replicas']`` set; the schedule
        signature (which carries the J⊥ ramp) plus the opts key keep them on
        distinct programs from classical groups.
        """
        plateaus = schedule_plateaus(hp.schedule(schedule_kind), storage)
        sig = hp.schedule(schedule_kind).signature()
        part = self.partition_for(kind, nb)
        cache_key = (kind, backend, _opts_key(opts), self.storage_layout, nb,
                     b_bucket, hp.n_trials, hp.n_rnd, self.noise, storage,
                     sig, chunk, part,
                     mesh_fingerprint(self.mesh) if part == "spin" else ())
        ent = self._programs.get(cache_key)
        if ent is None:
            if fire is not None:
                fire("compile", backend=backend, kind=kind, bucket=nb)
            self.stats["program_cache_misses"] += 1
            bk = make_batched_backend(
                backend, n_bucket=nb, n_trials=hp.n_trials,
                n_rnd=hp.n_rnd, noise=self.noise,
                storage_layout=self.storage_layout,
                partition=part, mesh=self.mesh, **opts,
            )

            def init_fn(problem, ns0):
                self.stats["traces_init"] += 1
                return bk.init_state(problem, ns0)

            def chunk_fn(problem, state):
                self.stats["traces_chunk"] += 1
                return bk.run_shots(problem, state, plateaus, chunk)

            ent = (bk, jax.jit(init_fn), jax.jit(chunk_fn))
            self._programs[cache_key] = ent
        else:
            self.stats["program_cache_hits"] += 1
        return (*ent, plateaus)

    def _solve_ssa_group(self, nb, items, responses, progress, ctx):
        t0 = time.perf_counter()
        _, req0, _, _ = items[0]
        hp: SSAHyperParams = req0.hp
        chunk = _largest_divisor_leq(hp.m_shot, self.chunk_shots)
        n_chunks = hp.m_shot // chunk

        padded, b_live, b_bucket = self._pad_group(items)
        backend, opts = ctx.backend, ctx.backend_opts
        opts = self._resolve_field_opts(backend, opts, items)
        nr = int(getattr(hp, "n_replicas", 0) or 0)
        if nr:
            # SSQA: the Trotter depth is program-structural (ring width per
            # R-tile), so it rides opts into the backend ctor AND the
            # executable-cache key; pallas replica rings exist only in the
            # streamed-noise kernel.
            opts = dict(opts)
            opts["n_replicas"] = nr
            if backend == "pallas":
                opts.setdefault("noise_mode", "streamed")
        bk, init_fn, chunk_fn, plateaus = self._ssa_programs(
            nb=nb, b_bucket=b_bucket, hp=hp, storage=req0.storage,
            schedule_kind=req0.schedule_kind, backend=backend, opts=opts,
            chunk=chunk, fire=ctx.fire, kind=ctx.kind,
        )
        stored_per_iter = sum(p.length for p in plateaus if p.eligible)

        stacked = bk.stack([model for _, _, _, model in padded])
        ctx.fire("oom", backend=backend, kind="ssa", bucket=nb, batch=b_bucket,
                 j_mode=getattr(bk, "j_mode", None))
        ns0 = bk.init_noise(
            [req.seed for _, req, _, _ in padded],
            [model.n for _, _, _, model in padded],
        )
        state = init_fn(stacked, ns0)

        state, chunk_traces, stops = self._chunk_loop(
            ctx.kind, nb, items, n_chunks, progress,
            lambda st, c: chunk_fn(stacked, st), state,
            lambda st: st.best_H, ctx, width=b_bucket,
            snap=lambda st: bk.finalize(st),
        )
        bh_dev, bm_dev = bk.finalize(state)  # layout-agnostic (unpacks bitplanes)
        best_H = np.asarray(bh_dev)
        best_m = np.asarray(bm_dev)
        wall = time.perf_counter() - t0

        for slot, (idx, req, maxcut, model) in enumerate(items):
            stop = stops[slot]
            if stop is not None and stop.get("best_H") is not None:
                bh, bm_full = stop["best_H"], stop["best_m"]
            else:
                bh, bm_full = best_H[slot], best_m[slot]
            result = AnnealResult(
                best_cut=np.asarray(finalize_cut(bh, maxcut)),
                best_energy=bh,
                best_m=bm_full[:, : model.n],
                energy_mean=None,
                energy_min=None,
                traj=None,
                stored_bits_per_iter=model.n * stored_per_iter,
                hp=req.hp,
            )
            responses[idx] = AnnealResponse(
                request=req, result=result, wall_s=wall, bucket=nb,
                batch=b_live, chunks_run=len(chunk_traces[slot]),
                chunks_total=n_chunks,
                chunk_best_cut=np.asarray(chunk_traces[slot]),
                lane_wall_s=(stop["t_abs"] - t0 if stop is not None else wall),
            )

    # ------------------------------------------------------------------
    # SA groups
    # ------------------------------------------------------------------
    def _solve_sa_group(self, nb, items, responses, progress, ctx):
        t0 = time.perf_counter()
        _, req0, _, _ = items[0]
        hp: SAHyperParams = req0.hp
        n_chunks = _largest_divisor_leq(hp.n_cycles, self.sa_chunks)
        chunk_cycles = hp.n_cycles // n_chunks

        padded, b_live, b_bucket = self._pad_group(items)
        cache_key = ("sa", nb, b_bucket, hp.n_trials, chunk_cycles)
        ent = self._programs.get(cache_key)
        if ent is None:
            ctx.fire("compile", backend="sa-core", kind="sa", bucket=nb)
            self.stats["program_cache_misses"] += 1

            def init_fn(problem, keys):
                self.stats["traces_init"] += 1
                return jax.vmap(
                    lambda pr, k: sa_init(
                        pr["h"], pr["nbr_idx"], pr["nbr_w"], k,
                        n_trials=hp.n_trials,
                    )
                )(problem, keys)

            def chunk_fn(problem, carry, temps, n_lives):
                self.stats["traces_chunk"] += 1
                def one(pr, ca, nl):
                    ca, _ = sa_cycles(
                        pr["h"], pr["nbr_idx"], pr["nbr_w"], ca, temps,
                        n_live=nl,
                    )
                    return ca
                return jax.vmap(one)(problem, carry, n_lives)

            ent = (jax.jit(init_fn), jax.jit(chunk_fn))
            self._programs[cache_key] = ent
        else:
            self.stats["program_cache_hits"] += 1
        init_fn, chunk_fn = ent

        # SA reuses the sparse stacking (gather-based ΔH).
        stacker = make_batched_backend(
            "sparse", n_bucket=nb, n_trials=hp.n_trials, noise="xorshift"
        )
        stacked = stacker.stack([model for _, _, _, model in padded])
        keys = jnp.stack(
            [jax.random.PRNGKey(req.seed) for _, req, _, _ in padded]
        )
        n_lives = jnp.asarray([model.n for _, _, _, model in padded], jnp.int32)
        temps = np.asarray(
            sa_temperature_ladder(hp.t_start, hp.t_end, hp.n_cycles), np.float32
        )
        carry = init_fn(stacked, keys)

        chunk_arrays = [
            jnp.asarray(temps[c * chunk_cycles : (c + 1) * chunk_cycles])
            for c in range(n_chunks)
        ]

        carry, chunk_traces, stops = self._chunk_loop(
            "sa", nb, items, n_chunks, progress,
            lambda ca, c: chunk_fn(stacked, ca, chunk_arrays[c], n_lives),
            carry, lambda ca: ca[3], ctx, width=b_bucket,
            snap=lambda ca: (ca[3], ca[4]),
        )
        _, _, _, best_H, best_m = carry
        best_H = np.asarray(best_H)
        best_m = np.asarray(best_m)
        wall = time.perf_counter() - t0

        for slot, (idx, req, maxcut, model) in enumerate(items):
            stop = stops[slot]
            if stop is not None and stop.get("best_H") is not None:
                bh, bm_full = stop["best_H"], stop["best_m"]
            else:
                bh, bm_full = best_H[slot], best_m[slot]
            result = SAResult(
                best_cut=np.asarray(finalize_cut(bh, maxcut)),
                best_energy=bh,
                best_m=bm_full[:, : model.n],
                energy_mean=None,
                energy_min=None,
                hp=req.hp,
            )
            responses[idx] = AnnealResponse(
                request=req, result=result, wall_s=wall, bucket=nb,
                batch=b_live, chunks_run=len(chunk_traces[slot]),
                chunks_total=n_chunks,
                chunk_best_cut=np.asarray(chunk_traces[slot]),
                lane_wall_s=(stop["t_abs"] - t0 if stop is not None else wall),
            )

    # ------------------------------------------------------------------
    # PT-SSA groups
    # ------------------------------------------------------------------
    def _solve_ptssa_group(self, nb, items, responses, progress, ctx):
        t0 = time.perf_counter()
        _, req0, _, _ = items[0]
        hp: PTSSAHyperParams = req0.hp
        backend, opts = ctx.backend, ctx.backend_opts
        if backend == "pallas":
            raise ValueError(
                "pt-ssa needs per-replica I0 columns; run the service with "
                "backend='sparse' or 'dense' for PTSSAHyperParams requests"
            )
        chunk = _largest_divisor_leq(hp.n_rounds, self.chunk_shots)
        n_chunks = hp.n_rounds // chunk

        padded, b_live, b_bucket = self._pad_group(items)
        opts = self._resolve_field_opts(backend, opts, items)
        cache_key = ("ptssa", backend, _opts_key(opts), nb, b_bucket, hp,
                     self.noise, chunk)
        ent = self._programs.get(cache_key)
        if ent is None:
            ctx.fire("compile", backend=backend, kind="ptssa", bucket=nb)
            self.stats["program_cache_misses"] += 1
            bk = make_batched_backend(
                backend, n_bucket=nb, n_trials=hp.n_replicas,
                n_rnd=hp.n_rnd, noise=self.noise, **opts,
            )

            def init_fn(problem, ns0):
                self.stats["traces_init"] += 1
                return bk.init_state(problem, ns0)

            def chunk_fn(problem, state, keys, parities):
                self.stats["traces_chunk"] += 1

                def one(pr, st, ks):
                    field_fn = lambda m: bk._field_one(pr, m)  # noqa: E731
                    return pt_ssa_rounds(
                        field_fn, bk._noise_step_one, pr["h"], hp, st,
                        ks, parities,
                    )

                return jax.vmap(one)(problem, state, keys)

            ent = (bk, jax.jit(init_fn), jax.jit(chunk_fn))
            self._programs[cache_key] = ent
        else:
            self.stats["program_cache_hits"] += 1
        bk, init_fn, chunk_fn = ent

        stacked = bk.stack([model for _, _, _, model in padded])
        ctx.fire("oom", backend=backend, kind="ptssa", bucket=nb,
                 batch=b_bucket, j_mode=getattr(bk, "j_mode", None))
        ns0 = bk.init_noise(
            [req.seed for _, req, _, _ in padded],
            [model.n for _, _, _, model in padded],
        )
        state = init_fn(stacked, ns0)

        # Same swap-key derivation as anneal_pt_ssa, split once over all
        # rounds then sliced per chunk — chunked == unchunked, bitwise.
        all_keys = jnp.stack([
            jax.random.split(
                jax.random.PRNGKey(req.seed ^ 0x5CA1AB1E), hp.n_rounds
            )
            for _, req, _, _ in padded
        ])  # (B, n_rounds, 2)
        parities = jnp.arange(hp.n_rounds, dtype=jnp.int32) % 2

        def step(st, c):
            sl = slice(c * chunk, (c + 1) * chunk)
            return chunk_fn(stacked, st, all_keys[:, sl], parities[sl])

        state, chunk_traces, stops = self._chunk_loop(
            "ptssa", nb, items, n_chunks, progress, step, state,
            lambda st: st.best_H, ctx, width=b_bucket,
            snap=lambda st: (st.best_H, st.best_m),
        )
        best_H = np.asarray(state.best_H)
        best_m = np.asarray(state.best_m)
        wall = time.perf_counter() - t0

        for slot, (idx, req, maxcut, model) in enumerate(items):
            stop = stops[slot]
            if stop is not None and stop.get("best_H") is not None:
                bh, bm_full = stop["best_H"], stop["best_m"]
            else:
                bh, bm_full = best_H[slot], best_m[slot]
            result = PTSSAResult(
                best_cut=np.asarray(finalize_cut(bh, maxcut)),
                best_energy=bh,
                best_m=bm_full[:, : model.n],
                energy_mean=None,
                energy_min=None,
                hp=req.hp,
            )
            responses[idx] = AnnealResponse(
                request=req, result=result, wall_s=wall, bucket=nb,
                batch=b_live, chunks_run=len(chunk_traces[slot]),
                chunks_total=n_chunks,
                chunk_best_cut=np.asarray(chunk_traces[slot]),
                lane_wall_s=(stop["t_abs"] - t0 if stop is not None else wall),
            )

    # ------------------------------------------------------------------
    # Shared chunk loop: streaming best_H reports, early stop, checkpoints,
    # deadline watchdog, non-finite detector, fault hooks
    # ------------------------------------------------------------------
    def _chunk_loop(self, kind, nb, items, n_chunks, progress, step, state,
                    best_of, ctx, *, width=None, snap=None):
        """Run up to n_chunks ``step(state, c)`` calls from the last
        checkpoint; report per-chunk bests; stop early when every request is
        done (target_cut reached or deadline expired).

        Chunk boundaries are where all the resilience machinery lives: the
        state snapshot (checkpoint), the kill/nan fault hooks, the
        non-finite detector (quarantine), and the deadline watchdog.  A
        request that stops early — target reached or deadline expired — has
        its streaming trace *and its result* frozen at its own chunk
        boundary (the ``snap`` callable reads best_H/best_m there), so
        per-lane latency and result reporting are honest even while the rest
        of the group keeps annealing.  The third return value carries one
        stop record per lane: ``{'chunk', 't_abs'[, 'best_H', 'best_m']}``,
        or None for a lane that ran to the group's end (its result comes
        from the final state).  ``width`` is the padded batch width, feeding
        the slot/live-lane occupancy counters the streaming benchmark reads.
        """
        traces = [[] for _ in items]
        start = 0
        if ctx is not None and ctx.ckpt is not None:
            start, state, restored = ctx.maybe_resume(state, len(items))
            if restored is not None:
                traces = restored
        done = [False] * len(items)
        frozen = [False] * len(items)
        stops: List[Optional[dict]] = [None] * len(items)
        for c in range(start, n_chunks):
            self.stats["slot_chunks"] += width if width is not None else len(items)
            self.stats["live_lane_chunks"] += sum(
                1 for s in range(len(items)) if not done[s]
            )
            state = step(state, c)
            best_H = np.asarray(best_of(state))  # device sync: the report
            # Non-finite watchdog.  The 'nan' hook corrupts the detector's
            # float view of the readings (slots it names), emulating a
            # numeric blow-up; detection itself is the production check.
            readings = best_H.astype(np.float64)
            spec = ctx.fire("nan", kind=kind, chunk=c) if ctx else None
            if spec is not None:
                slots = [s for s in (spec.slots or range(len(items)))
                         if s < len(items)]
                for s in slots:
                    readings[s] = np.nan
            bad = tuple(
                s for s in range(len(items))
                if not np.all(np.isfinite(readings[s]))
            )
            if bad:
                self.stats["nonfinite_detected"] += 1
                raise QuarantineFault(bad)
            bests = []
            for slot, (idx, req, maxcut, model) in enumerate(items):
                obj = np.asarray(finalize_cut(best_H[slot], maxcut))
                best = int(np.max(obj))
                if not frozen[slot]:
                    traces[slot].append(best)
                bests.append(best)
            self.stats["chunks_run"] += 1
            if progress is not None:
                progress(AnnealProgress(
                    kind=kind, bucket=nb, chunk=c, chunks_total=n_chunks,
                    request_indices=tuple(idx for idx, *_ in items),
                    best_cut=tuple(bests),
                ))
            now = time.perf_counter()
            newly: List[int] = []
            if ctx is not None:
                ctx.save(c + 1, state, traces)
                ctx.fire("kill", kind=kind, chunk=c)
                for slot, (idx, req, _, _) in enumerate(items):
                    if done[slot]:
                        continue
                    if req.target_cut is not None and bests[slot] >= req.target_cut:
                        done[slot] = frozen[slot] = True
                        stops[slot] = {"chunk": c + 1, "t_abs": now}
                        newly.append(slot)
                    elif (req.deadline_s is not None
                          and now - ctx.solve_t0 >= req.deadline_s):
                        done[slot] = frozen[slot] = True
                        stops[slot] = {"chunk": c + 1, "t_abs": now}
                        newly.append(slot)
                        ctx.statuses[idx] = STATUS_DEADLINE
                        ctx._event("deadline", request=idx, chunk=c,
                                   best=bests[slot])
                        self.stats["deadline_expirations"] += 1
            else:
                for slot, (idx, req, _, _) in enumerate(items):
                    if (not done[slot] and req.target_cut is not None
                            and bests[slot] >= req.target_cut):
                        done[slot] = frozen[slot] = True
                        stops[slot] = {"chunk": c + 1, "t_abs": now}
                        newly.append(slot)
            group_ends = (c + 1 == n_chunks) or (bool(done) and all(done))
            if newly and not group_ends and snap is not None:
                # The group continues past these lanes' stop boundary:
                # freeze their result here so later chunks (which they no
                # longer participate in, logically) can't change it.
                bh_s, bm_s = snap(state)
                bh_s, bm_s = np.asarray(bh_s), np.asarray(bm_s)
                for slot in newly:
                    stops[slot]["best_H"] = bh_s[slot].copy()
                    stops[slot]["best_m"] = bm_s[slot].copy()
            if done and all(done) and c + 1 < n_chunks:
                self.stats["early_stops"] += 1
                break
        return state, traces, stops
