"""Algorithm-family registry for the annealing services (DESIGN.md §13).

The service used to dispatch on a hand-maintained ``{"ssa": ..., "sa": ...,
"ptssa": ...}`` dict inside :meth:`AnnealService._solve_group_resilient`,
with family-specific admission rules (the PT-SSA×pallas rejection) inlined
in ``solve()``.  Adding SSQA as a fourth family made that sprawl the bug
surface: every new algorithm had to edit three far-apart switch sites.

This module replaces the switches with one table.  Each family registers:

* ``name`` — the wire name (``AnnealRequest(algo=...)``, group keys,
  checkpoint fingerprints, progress reports);
* ``hp_type`` — the hyperparameter dataclass that *implies* the family when
  ``algo`` is not given.  Resolution is most-specific-type-first:
  :class:`~repro.core.ssqa.SSQAHyperParams` subclasses
  :class:`~repro.core.ssa.SSAHyperParams`, so an SSQA hp lands on the
  ``ssqa`` family even though it is also an SSA instance;
* ``solver`` — the name of the ``AnnealService`` group-solver method (bound
  late so the registry has no import cycle with the service);
* ``group_key`` — the family's contribution to the batching key (what must
  match for two requests to share one compiled program);
* ``validate`` — admission-time rejection that lives *next to the family*
  instead of inside the service (e.g. PT-SSA rejects the pallas backend,
  SSQA×pallas demands the streamed-noise kernel).

Third parties can :func:`register_algo` additional families; the built-in
four (ssa, sa, ptssa, ssqa) register at import.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

from repro.core.pt import PTSSAHyperParams
from repro.core.sa import SAHyperParams
from repro.core.ssa import SSAHyperParams
from repro.core.ssqa import SSQAHyperParams

from .resilience import AdmissionError

__all__ = [
    "AlgoFamily",
    "register_algo",
    "registered_algos",
    "family_for",
]


@dataclasses.dataclass(frozen=True)
class AlgoFamily:
    """One served algorithm family (see module docstring)."""

    name: str
    hp_type: type
    solver: str                    # AnnealService method name (late-bound)
    group_key: Callable            # (req, hp, nb) -> hashable batching key
    validate: Optional[Callable] = None  # (service, idx, req, hp) -> None
    chunk_unit: str = "m_shot"     # hp attribute the chunk width divides


_REGISTRY: Dict[str, AlgoFamily] = {}


def register_algo(
    name: str,
    hp_type: type,
    *,
    solver: str,
    group_key: Callable,
    validate: Optional[Callable] = None,
    chunk_unit: str = "m_shot",
) -> AlgoFamily:
    """Register (or replace) an algorithm family under ``name``."""
    fam = AlgoFamily(str(name), hp_type, solver, group_key, validate,
                     chunk_unit)
    _REGISTRY[fam.name] = fam
    return fam


def registered_algos() -> Dict[str, AlgoFamily]:
    return dict(_REGISTRY)


def _family_for_type(hp) -> AlgoFamily:
    """Most-specific registered family whose hp_type matches ``hp``."""
    best: Optional[AlgoFamily] = None
    for fam in _REGISTRY.values():
        if isinstance(hp, fam.hp_type):
            if best is None or issubclass(fam.hp_type, best.hp_type):
                best = fam
    if best is None:
        raise TypeError(
            f"unsupported hyperparameter type {type(hp).__name__}; "
            f"registered families: {sorted(_REGISTRY)}"
        )
    return best


def family_for(hp, algo: Optional[str] = None) -> AlgoFamily:
    """Resolve the family for a request: explicit ``algo`` or hp type.

    An explicit ``algo`` must agree with what the hp type implies — an
    ``algo='ssa'`` request carrying SSQA hyperparameters (or vice versa)
    is a caller bug, rejected at admission rather than silently run as
    whichever family the solver table happens to pick.
    """
    tfam = _family_for_type(hp)
    if algo is None:
        return tfam
    fam = _REGISTRY.get(algo)
    if fam is None:
        raise AdmissionError(
            f"unknown algo {algo!r}; registered: {sorted(_REGISTRY)}"
        )
    if fam is not tfam:
        raise AdmissionError(
            f"algo={algo!r} does not match hyperparameter type "
            f"{type(hp).__name__} (which selects family {tfam.name!r})"
        )
    return fam


# ----------------------------------------------------------------------
# Built-in families
# ----------------------------------------------------------------------
def _plateau_group_key(name):
    def key(req, hp, nb):
        sig = hp.schedule(req.schedule_kind).signature()
        return (name, nb, hp.n_trials, hp.n_rnd, hp.m_shot, req.storage, sig)
    return key


def _validate_ptssa(service, idx, req, hp):
    if service.backend == "pallas":
        raise AdmissionError(
            "pt-ssa needs per-replica I0 columns; run the service with "
            "backend='sparse' or 'dense' for PTSSAHyperParams requests"
        )


def _validate_ssqa(service, idx, req, hp):
    # The batched pallas SSQA path is the streamed-noise resident kernel
    # (the pregen/threefry chains have no replica ring) — reject at
    # admission instead of letting the backend ctor fault mid-batch.
    if service.backend == "pallas":
        if service.noise != "xorshift":
            raise AdmissionError(
                f"request {idx}: ssqa on backend='pallas' requires "
                "noise='xorshift' (streamed-noise replica-ring kernel), "
                f"got noise={service.noise!r}"
            )
        if service.backend_opts.get("noise_mode") == "pregen":
            raise AdmissionError(
                f"request {idx}: ssqa on backend='pallas' requires "
                "noise_mode='streamed'; drop noise_mode='pregen' from "
                "backend_opts"
            )


register_algo(
    "ssa", SSAHyperParams,
    solver="_solve_ssa_group",
    group_key=_plateau_group_key("ssa"),
)
register_algo(
    "sa", SAHyperParams,
    solver="_solve_sa_group",
    group_key=lambda req, hp, nb: ("sa", nb, hp),
    chunk_unit="n_cycles",
)
register_algo(
    "ptssa", PTSSAHyperParams,
    solver="_solve_ptssa_group",
    group_key=lambda req, hp, nb: ("ptssa", nb, hp),
    validate=_validate_ptssa,
    chunk_unit="n_rounds",
)
register_algo(
    "ssqa", SSQAHyperParams,
    solver="_solve_ssa_group",   # SSQA rides the SSA plateau path
    group_key=_plateau_group_key("ssqa"),
    validate=_validate_ssqa,
)
