"""LM serving: prefill + decode steps and a batched greedy/temperature sampler.

serve_step == one ``decode_step`` (a new token against a KV cache of
``seq_len``) — the thing the decode_* / long_* dry-run cells lower.

This is the *language-model* side of the serve package (DESIGN.md §6); the
production serving layer for the paper's own workload — batched Max-Cut
annealing — is :mod:`repro.serve.anneal_service` (DESIGN.md §7).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.sharding import DEFAULT_RULES, ShardingRules

__all__ = ["ServeConfig", "make_prefill_step", "make_decode_step", "generate"]


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_seq: int
    temperature: float = 0.0  # 0 → greedy
    eos_id: int = -1          # -1 → never stop early


def make_prefill_step(model_cfg, mesh=None, rules: ShardingRules = DEFAULT_RULES,
                      max_seq: Optional[int] = None):
    def prefill_step(params, batch):
        return T.prefill(params, batch, model_cfg, mesh=mesh, rules=rules,
                         max_seq=max_seq)

    return prefill_step


def make_decode_step(model_cfg, mesh=None, rules: ShardingRules = DEFAULT_RULES):
    def decode_step(params, caches, token, pos):
        return T.decode_step(params, caches, token, pos, model_cfg,
                             mesh=mesh, rules=rules)

    return decode_step


def _sample(logits, key, temperature):
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature, axis=-1).astype(jnp.int32)


def generate(
    params,
    batch: Dict[str, jnp.ndarray],
    model_cfg,
    serve_cfg: ServeConfig,
    n_new_tokens: int,
    *,
    mesh=None,
    rules: ShardingRules = DEFAULT_RULES,
    seed: int = 0,
) -> np.ndarray:
    """Prefill the prompt batch then decode n_new_tokens greedily.

    Returns (B, n_new_tokens) int32.  The decode loop is jitted once and
    reused (steady-state serving shape).
    """
    prompt = batch["tokens"]
    B, S = prompt.shape
    assert S + n_new_tokens <= serve_cfg.max_seq
    prefill_step = jax.jit(
        make_prefill_step(model_cfg, mesh, rules, max_seq=serve_cfg.max_seq)
    )
    decode = jax.jit(make_decode_step(model_cfg, mesh, rules))

    logits, caches = prefill_step(params, batch)
    key = jax.random.PRNGKey(seed)
    key, k0 = jax.random.split(key)
    token = _sample(logits, k0, serve_cfg.temperature)
    out = [np.asarray(token)]
    pos = S
    for i in range(n_new_tokens - 1):
        logits, caches = decode(params, caches, token, jnp.int32(pos))
        key, ki = jax.random.split(key)
        token = _sample(logits, ki, serve_cfg.temperature)
        out.append(np.asarray(token))
        pos += 1
    return np.stack(out, axis=1)
