"""Continuous-batching streaming front door for the annealing service
(DESIGN.md §12).

``AnnealService.solve`` is a one-shot synchronous batch API: a whole shape
bucket drains at the pace of its slowest lane.  Production traffic arrives
as a *stream*, and the PR-3 substrate — plateau chunks as the unit of
execution, padding-invariant per-problem lanes, problem arrays as call-time
arguments to cached executables — is exactly what LLM-style continuous
batching needs.  :class:`StreamingAnnealService` builds it:

* **Slot tables** — one resident batched engine state per
  ``(bucket, degree, trials, schedule, chunk, opts)`` *stream key*, with a
  fixed compiled width (``slots_per_table``).  The compiled programs come
  from the owning :class:`~repro.serve.anneal_service.AnnealService`'s
  bounded executable cache (shared with the one-shot path — the cache key
  deliberately excludes ``m_shot``).
* **The plateau chunk is the scheduling quantum** — each ``pump()`` runs ONE
  chunk of one table, then walks its chunk boundary: lanes that reached
  their ``target_cut``, exhausted their chunk budget, or blew their
  deadline are *retired* and their slots *backfilled* from the queue via
  :func:`repro.core.engine.splice_slot` — no lane ever waits for the
  bucket to drain.
* **Bit-identity** — a backfilled lane is seeded by the same
  ``padded_noise_init`` stream a one-shot solo solve would use, and lanes
  never interact, so a request served through the stream returns the same
  ``best_cut``/spins as ``AnnealService.solve`` on the same request
  (property-tested across backends and across backfill boundaries).
* **Admission + scheduling** — ``submit()`` validates like ``solve()``
  (typed :class:`AdmissionError`), resolves ``hp='auto'`` so the scheduler
  has per-request cost estimates, and bounds the queue
  (:class:`QueueFullError`).  Scheduling order is priority class
  (``'interactive'`` > ``'batch'``) with aging promotion (no starvation),
  then earliest deadline first, then FIFO.  Queued requests whose deadline
  has already expired are shed (``status='shed'``) instead of wasting
  device work.
* **Per-slot resilience** — deadlines and the non-finite quarantine act on
  single slots (retire + backfill) instead of whole groups; per-slot
  checkpoints reuse the PR-6 fingerprint machinery with single-request
  groups, so a killed streaming process resumes each in-flight lane from
  its own last chunk boundary — and a slot checkpoint is interchangeable
  with the same request's one-shot solo-group checkpoint.  A classified
  compile/OOM fault rebuilds the table one step down the fallback chain
  with the engine state carried across (trajectories depend only on the
  noise stream, so the downgrade is bit-exact).
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.checkpoint.ckpt import CheckpointManager, latest_step
from repro.core.autotune import autotune_hyperparams, resolve_hyperparams
from repro.core.engine import (
    bucket_n,
    extract_slot,
    finalize_cut,
    next_pow2,
    normalize_problem,
    pad_degree,
    splice_slot,
)
from repro.core.rng import xorshift_lanes_ok
from repro.core.ssa import AnnealResult, SSAHyperParams
from repro.problems import ProblemEncoding
from repro.sharding import mesh_fingerprint

from .anneal_service import (
    AnnealProgress,
    AnnealRequest,
    AnnealResponse,
    AnnealService,
    _largest_divisor_leq,
    _opts_key,
)
from .registry import family_for
from .resilience import (
    STATUS_DEADLINE,
    STATUS_FAILED,
    STATUS_FALLBACK,
    STATUS_OK,
    STATUS_QUARANTINED,
    STATUS_SHED,
    AdmissionError,
    QueueFullError,
    ServiceEvent,
    classify_fault,
    fallback_step,
    filter_backend_opts,
    group_fingerprint,
)

__all__ = ["StreamPolicy", "StreamTicket", "StreamingAnnealService"]

PRIORITIES = ("interactive", "batch")  # rank order, best first


@dataclasses.dataclass(frozen=True)
class StreamPolicy:
    """Scheduler knobs for :class:`StreamingAnnealService`.

    slots_per_table:  compiled batch width of every slot table (power of
                      two, so stream tables share executables with one-shot
                      groups of the same width).
    max_tables:       resident slot tables (distinct stream keys) at once —
                      bounds live engine state, not correctness; extra keys
                      wait in the queue.
    max_queue:        admission bound on queued requests (QueueFullError).
    max_queue_cost:   optional admission bound on the queue's aggregate
                      estimated spin-cycles (autotuned cost estimates).
    aging_s:          a 'batch' request older than this is promoted to
                      'interactive' rank — the starvation bound.
    shed_expired:     drop queued requests whose deadline already passed
                      (status='shed') instead of running unmeetable work.
    """

    slots_per_table: int = 4
    max_tables: int = 4
    max_queue: int = 4096
    max_queue_cost: Optional[float] = None
    aging_s: float = 30.0
    shed_expired: bool = True

    def __post_init__(self):
        if self.slots_per_table != next_pow2(self.slots_per_table):
            raise ValueError(
                f"slots_per_table must be a power of two, got "
                f"{self.slots_per_table}"
            )
        if self.max_tables < 1 or self.max_queue < 1:
            raise ValueError("max_tables and max_queue must be >= 1")


class StreamTicket:
    """Handle for one submitted request: status, timing, and the response.

    ``status``: 'queued' → 'running' → 'done' (shed requests jump straight
    to 'done' with ``response.status == 'shed'``).  ``result()`` blocks
    until the response is available.
    """

    def __init__(self, seq: int, request: AnnealRequest, priority: str,
                 submit_t: float, cost: float, autotune=None):
        self.seq = seq
        self.request = request          # hp already resolved (never 'auto')
        self.priority = priority
        self.submit_t = submit_t
        self.cost = cost                # estimated spin-cycles (scheduling)
        self.autotune = autotune
        self.status = "queued"
        self.t_seated: Optional[float] = None
        self.retries = 0
        self.events: List[ServiceEvent] = []
        self.response: Optional[AnnealResponse] = None
        self._done = threading.Event()

    def done(self) -> bool:
        return self._done.is_set()

    def result(self, timeout: Optional[float] = None) -> AnnealResponse:
        if not self._done.wait(timeout):
            raise TimeoutError(f"ticket {self.seq} not done")
        return self.response

    def __repr__(self):
        return (f"StreamTicket(seq={self.seq}, priority={self.priority!r}, "
                f"status={self.status!r})")


class _Slot:
    """One seated request inside a table."""

    def __init__(self, ticket: StreamTicket, model, maxcut, budget: int):
        self.ticket = ticket
        self.model = model
        self.maxcut = maxcut
        self.budget = budget            # chunk budget (m_shot // table.chunk)
        self.chunks_done = 0
        self.trace: List[int] = []
        self.ckpt: Optional[CheckpointManager] = None
        self.ckpt_dir: Optional[str] = None


class _SlotTable:
    """One resident compiled batch: stacked problems + engine state + slots."""

    def __init__(self, key, nb, d_bucket, chunk, backend, opts, part,
                 storage, schedule_kind, hp0, kind="ssa"):
        self.key = key
        self.nb = nb
        self.d_bucket = d_bucket
        self.chunk = chunk              # plateau iterations per quantum
        self.backend = backend          # effective (may walk fallback chain)
        self.opts = dict(opts)
        self.part = part
        self.kind = kind                # family name: 'ssa' | 'ssqa'
        self.storage = storage
        self.schedule_kind = schedule_kind
        self.hp0 = hp0                  # exemplar: n_trials/n_rnd/schedule
        self.model0 = None              # dummy model for free slots
        self.bk = None
        self.chunk_fn = None
        self.bk1 = None                 # B=1 twin: lane init for backfill
        self.init1 = None
        self.plateaus = None
        self.stored_per_iter = 0
        self.stacked = None
        self.state = None
        self.slots: List[Optional[_Slot]] = []
        self.quanta = 0
        self.degraded = False           # walked the fallback chain
        self.events: List[ServiceEvent] = []  # copied to tickets at seat

    @property
    def n_live(self) -> int:
        return sum(1 for s in self.slots if s is not None)

    def free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None


class StreamingAnnealService:
    """Always-on streaming wrapper over :class:`AnnealService`.

    Either wrap an existing service (``StreamingAnnealService(service=svc)``
    — shares its executable cache, resilience policy and fault hooks) or
    pass :class:`AnnealService` constructor keywords directly.  Drive it
    synchronously (``submit()`` + ``run_until_idle()`` / ``pump()``) or as a
    background loop (``start()`` / ``stop()``).  Only plateau-family
    requests (SSA and SSQA — SSQA slot tables carry the Trotter-replica
    axis through splice/extract untouched, since it lives on the trial
    axis) are admitted: the slot tables are plateau programs (SA / PT-SSA
    requests belong on the one-shot path).
    """

    def __init__(self, service: Optional[AnnealService] = None, *,
                 policy: Optional[StreamPolicy] = None, **service_kwargs):
        if service is not None and service_kwargs:
            raise ValueError("pass either a service or its kwargs, not both")
        self.service = service or AnnealService(**service_kwargs)
        self.policy = policy or StreamPolicy()
        self.stats = self.service.stats  # one observability surface
        self._lock = threading.RLock()
        self._queue: List[StreamTicket] = []
        self._tables: Dict[tuple, _SlotTable] = {}
        self._seq = 0
        self._rr = 0                    # round-robin cursor over tables
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # ------------------------------------------------------------------
    # Admission (the front door)
    # ------------------------------------------------------------------
    def submit(self, request: AnnealRequest, *,
               priority: str = "batch") -> StreamTicket:
        """Admit one request into the stream; returns its ticket.

        Validation and ``hp='auto'`` resolution happen here (so a rejected
        request costs no device work and the scheduler knows every queued
        request's cost estimate); :class:`QueueFullError` is the
        backpressure signal.  ``request.deadline_s`` is measured from
        *submission* — queueing time counts against it.
        """
        if priority not in PRIORITIES:
            raise ValueError(f"unknown priority {priority!r}; use {PRIORITIES}")
        svc = self.service
        try:
            maxcut, model = normalize_problem(request.problem)
        except TypeError as e:
            raise AdmissionError(str(e)) from e
        with self._lock:
            seq = self._seq
            self._seq += 1
        if svc.policy.validate_admission:
            svc._admit(seq, request, model)
        report = None
        if isinstance(request.hp, str):
            hp, report = resolve_hyperparams(
                request.hp, model, base=request.auto_base,
                seed=svc.autotune_seed, algo=request.algo,
            )
            request = dataclasses.replace(request, hp=hp)
            self.stats["autotuned"] += 1
        fam = family_for(request.hp, algo=request.algo)
        if fam.solver != "_solve_ssa_group":
            raise AdmissionError(
                "the streaming service serves plateau-family requests only "
                f"(ssa/ssqa); got {type(request.hp).__name__} "
                "(use AnnealService.solve)"
            )
        if fam.validate is not None:
            fam.validate(svc, seq, request, request.hp)
        cost = float(request.hp.total_cycles) * request.hp.n_trials * model.n
        ticket = StreamTicket(seq, request, priority, time.monotonic(), cost,
                              autotune=report)
        ticket._model, ticket._maxcut = model, maxcut
        with self._lock:
            if len(self._queue) >= self.policy.max_queue:
                self.stats["stream_rejected_queue_full"] += 1
                raise QueueFullError(
                    f"queue at capacity ({self.policy.max_queue})"
                )
            if self.policy.max_queue_cost is not None:
                pending = sum(t.cost for t in self._queue)
                if pending + cost > self.policy.max_queue_cost:
                    self.stats["stream_rejected_queue_full"] += 1
                    raise QueueFullError(
                        f"queue cost bound {self.policy.max_queue_cost:g} "
                        f"would be exceeded"
                    )
            self._queue.append(ticket)
            self.stats["stream_submitted"] += 1
        return ticket

    # ------------------------------------------------------------------
    # The scheduler: one plateau chunk per pump() call
    # ------------------------------------------------------------------
    def pump(self, progress: Optional[Callable[[AnnealProgress], None]] = None
             ) -> bool:
        """One scheduling quantum: seat queued work, run ONE plateau chunk
        of one table (round-robin), retire + backfill at its boundary.

        Returns False when the stream is idle (empty queue, no live slots).
        Call from a single driver thread (or use ``start()``).
        """
        with self._lock:
            self._shed_expired()
            self._seat_queued()
            table = self._pick_table()
            if table is None:
                return False
        self._run_quantum(table, progress)
        return True

    def run_until_idle(
        self, progress: Optional[Callable[[AnnealProgress], None]] = None
    ) -> None:
        """Drive ``pump()`` until every submitted request has completed."""
        while self.pump(progress):
            pass

    def start(self, poll_s: float = 0.002) -> None:
        """Spawn the background scheduler thread (idempotent)."""
        with self._lock:
            if self._thread is not None and self._thread.is_alive():
                return
            self._stop.clear()
            self._thread = threading.Thread(
                target=self._serve_loop, args=(poll_s,),
                name="anneal-stream", daemon=True,
            )
            self._thread.start()

    def stop(self, timeout: Optional[float] = 10.0) -> None:
        self._stop.set()
        t = self._thread
        if t is not None:
            t.join(timeout)

    def _serve_loop(self, poll_s: float):
        while not self._stop.is_set():
            if not self.pump():
                self._stop.wait(poll_s)

    def stream_stats(self) -> dict:
        """Scheduler observability: queue depth, occupancy, counters."""
        with self._lock:
            live = sum(t.n_live for t in self._tables.values())
            width = sum(len(t.slots) for t in self._tables.values())
            slot_chunks = self.stats["stream_slot_chunks"]
            live_chunks = self.stats["stream_live_lane_chunks"]
        return {
            "queued": len(self._queue),
            "tables": len(self._tables),
            "live_slots": live,
            "table_width": width,
            "occupancy": (live_chunks / slot_chunks) if slot_chunks else 0.0,
            **{k: v for k, v in self.stats.items()
               if k.startswith("stream_")},
        }

    # ------------------------------------------------------------------
    # Queue ordering: priority class (with aging), then EDF, then FIFO
    # ------------------------------------------------------------------
    def _rank(self, ticket: StreamTicket, now: float):
        rank = PRIORITIES.index(ticket.priority)
        if rank and now - ticket.submit_t >= self.policy.aging_s:
            rank = 0  # aged into the top class: the starvation bound
        dl = ticket.request.deadline_s
        abs_deadline = ticket.submit_t + dl if dl is not None else np.inf
        return (rank, abs_deadline, ticket.seq)

    def _shed_expired(self):
        if not self.policy.shed_expired:
            return
        now = time.monotonic()
        keep = []
        for t in self._queue:
            dl = t.request.deadline_s
            if dl is not None and now - t.submit_t >= dl:
                self._complete_unrun(t, STATUS_SHED, "shed")
            else:
                keep.append(t)
        self._queue = keep

    def _complete_unrun(self, ticket: StreamTicket, status: str, event: str):
        ticket.events.append(ServiceEvent(
            event, {"request": ticket.seq},
            time.monotonic() - ticket.submit_t,
        ))
        ticket.response = AnnealResponse(
            request=ticket.request, result=None,
            wall_s=time.monotonic() - ticket.submit_t,
            bucket=bucket_n(ticket._model.n, self.service.min_bucket),
            batch=0, chunks_run=0, chunks_total=0,
            chunk_best_cut=np.zeros(0, np.int64),
            autotune=ticket.autotune, status=status,
            events=list(ticket.events),
        )
        ticket.status = "done"
        self.stats[f"stream_{event}"] += 1
        ticket._done.set()

    # ------------------------------------------------------------------
    # Seating: stream keys, table creation, slot backfill
    # ------------------------------------------------------------------
    def _stream_key(self, ticket: StreamTicket):
        """The slot-table identity of one request (all program-structural
        statics): requests share a table iff they can share its compiled
        chunk program *and* its stacked problem representation.  SSQA
        requests key (and run) with their family name, replica count folded
        into the opts, exactly mirroring the one-shot group solver; a
        per-request :class:`SolverConfig` re-derives backend/opts and joins
        the key via its signature."""
        svc = self.service
        req = ticket.request
        hp: SSAHyperParams = req.hp
        kind = family_for(hp, algo=req.algo).name
        model = ticket._model
        nb = bucket_n(model.n, svc.min_bucket)
        d_bucket = next_pow2(max(1, model.max_degree))
        chunk = _largest_divisor_leq(hp.m_shot, svc.chunk_shots)
        cfg = req.config
        if cfg is not None:
            backend = cfg.backend
            opts = cfg.engine_opts()
            opts.pop("storage_layout", None)
        else:
            backend = svc.backend
            opts = dict(svc.backend_opts)
        part = svc.partition_for(kind, nb)
        if backend == "auto":
            from repro.core.engine import resolve_backend
            backend = resolve_backend(backend, nb)
            opts = filter_backend_opts(backend, opts, partition=part)
        opts = svc._resolve_field_opts(backend, opts,
                                       [(ticket.seq, req, None, model)])
        nr = int(getattr(hp, "n_replicas", 0) or 0)
        if nr:
            opts["n_replicas"] = nr
            if backend == "pallas":
                opts.setdefault("noise_mode", "streamed")
        sig = hp.schedule(req.schedule_kind).signature()
        return ("stream-" + kind, nb, d_bucket, hp.n_trials, hp.n_rnd,
                req.storage, sig, chunk, backend, _opts_key(opts), part,
                mesh_fingerprint(svc.mesh) if part == "spin" else (),
                cfg.signature() if cfg is not None else None), \
            (nb, d_bucket, chunk, backend, opts, part, kind)

    def _seat_queued(self):
        """Fill free slots (and open new tables) from the queue in rank
        order.  Runs under the service lock."""
        if not self._queue:
            return
        now = time.monotonic()
        self._queue.sort(key=lambda t: self._rank(t, now))
        leftover = []
        for ticket in self._queue:
            key, params = self._stream_key(ticket)
            table = self._tables.get(key)
            if table is None:
                if len(self._tables) >= self.policy.max_tables:
                    leftover.append(ticket)
                    continue
                table = self._create_table(key, params, ticket)
            slot = table.free_slot()
            if slot is None:
                leftover.append(ticket)
                continue
            self._seat(table, slot, ticket)
        self._queue = leftover
        # Drop empty tables whose key no longer matches anything queued —
        # frees table budget (and engine state) for other stream keys.
        dead = [k for k, t in self._tables.items() if t.n_live == 0]
        for k in dead:
            if not any(self._stream_key(t)[0] == k for t in self._queue):
                del self._tables[k]

    def _programs_for(self, table: _SlotTable):
        """(Re)bind the table's compiled programs + backends from the
        service's shared executable cache (called at creation and after a
        fallback downgrade)."""
        svc = self.service
        fire = svc.faults.fire if svc.faults is not None else None
        bk, _, chunk_fn, plateaus = svc._ssa_programs(
            nb=table.nb, b_bucket=self.policy.slots_per_table, hp=table.hp0,
            storage=table.storage, schedule_kind=table.schedule_kind,
            backend=table.backend, opts=table.opts, chunk=table.chunk,
            fire=fire, kind=table.kind,
        )
        bk1, init1, _, _ = svc._ssa_programs(
            nb=table.nb, b_bucket=1, hp=table.hp0,
            storage=table.storage, schedule_kind=table.schedule_kind,
            backend=table.backend, opts=table.opts, chunk=table.chunk,
            kind=table.kind,
        )
        table.bk, table.chunk_fn, table.plateaus = bk, chunk_fn, plateaus
        table.bk1, table.init1 = bk1, init1
        table.stored_per_iter = sum(
            p.length for p in plateaus if p.eligible
        )

    def _create_table(self, key, params, ticket: StreamTicket) -> _SlotTable:
        nb, d_bucket, chunk, backend, opts, part, kind = params
        svc = self.service
        req = ticket.request
        S = self.policy.slots_per_table
        model0 = pad_degree(ticket._model, d_bucket)
        carried: List[ServiceEvent] = []
        while True:
            # A compile/OOM fault during table build walks the fallback
            # chain before any slot is seated (one-shot parity); the table
            # keeps the ORIGINAL stream key — the key routes requests, the
            # table records the effective backend.
            table = _SlotTable(key, nb, d_bucket, chunk, backend, opts, part,
                               req.storage, req.schedule_kind, req.hp,
                               kind=kind)
            table.model0 = model0
            table.events = list(carried)
            table.degraded = bool(carried)
            try:
                self._programs_for(table)
                if svc.faults is not None:
                    svc.faults.fire(
                        "oom", backend=backend, kind=kind, bucket=nb,
                        batch=S, j_mode=getattr(table.bk, "j_mode", None),
                    )
                table.stacked = table.bk.stack([model0] * S)
                ns0 = table.bk.init_noise([req.seed] * S,
                                          [ticket._model.n] * S)
                table.state = table.bk.init_state(table.stacked, ns0)
            except Exception as exc:  # noqa: BLE001 — classified below
                fault = classify_fault(exc, backend)
                nxt = (fallback_step(backend, opts, fault, nb)
                       if fault is not None and svc.policy.fallback else None)
                if nxt is None:
                    raise
                self.stats[f"fallback_{fault}"] += 1
                carried.append(ServiceEvent(
                    "fallback",
                    {"from": backend, "to": nxt[0], "fault": fault,
                     "error": f"{type(exc).__name__}: {exc}"[:200]},
                    time.monotonic(),
                ))
                backend, opts = nxt
                continue
            table.slots = [None] * S
            self._tables[key] = table
            self.stats["stream_tables_created"] += 1
            return table

    def _lane_fingerprint(self, table: _SlotTable, ticket: StreamTicket) -> str:
        """Per-slot checkpoint identity == the request's one-shot solo-group
        fingerprint (same kind/bucket/backend/chunk, a single-item group) —
        slot checkpoints and solo-group checkpoints are interchangeable."""
        svc = self.service
        return group_fingerprint(
            table.kind, table.nb, table.backend, svc.storage_layout, svc.noise,
            table.chunk, [(0, ticket.request, ticket._maxcut, ticket._model)],
            partition=table.part,
            mesh_fp=(mesh_fingerprint(svc.mesh)
                     if table.part == "spin" else ()),
        )

    def _seat(self, table: _SlotTable, slot: int, ticket: StreamTicket):
        """Splice one request into a table slot: fresh lane state (the same
        padded_noise_init stream a solo solve would use) or a resumed lane
        from its per-slot checkpoint."""
        svc = self.service
        req = ticket.request
        hp: SSAHyperParams = req.hp
        model = pad_degree(ticket._model, table.d_bucket)
        budget = hp.m_shot // table.chunk
        s = _Slot(ticket, model, ticket._maxcut, budget)

        stacked1 = table.bk1.stack([model])
        ns1 = table.bk1.init_noise([req.seed], [ticket._model.n])
        lane = table.init1(stacked1, ns1)

        if svc.policy.checkpoint_dir:
            tag = self._lane_fingerprint(table, ticket)
            s.ckpt_dir = os.path.join(svc.policy.checkpoint_dir, tag)
            s.ckpt = CheckpointManager(
                s.ckpt_dir,
                save_interval=max(1, int(svc.policy.checkpoint_interval)),
                keep=svc.policy.keep_checkpoints,
                async_save=False,
            )
            if latest_step(s.ckpt_dir) is not None:
                restored, meta = s.ckpt.restore_latest(lane)
                traces = meta.get("traces")
                ok = isinstance(traces, list) and len(traces) == 1
                if ok and svc.noise == "xorshift":
                    lanes = getattr(restored, "noise_state", None)
                    ok = lanes is not None and xorshift_lanes_ok(lanes, axis=1)
                if ok:
                    lane = restored
                    s.chunks_done = int(meta["step"])
                    s.trace = [int(v) for v in traces[0]]
                    ticket.events.append(ServiceEvent(
                        "resume", {"request": ticket.seq,
                                   "chunk": s.chunks_done, "dir": s.ckpt_dir},
                        time.monotonic() - ticket.submit_t,
                    ))
                    self.stats["stream_resumes"] += 1
                else:
                    ticket.events.append(ServiceEvent(
                        "checkpoint_rejected",
                        {"request": ticket.seq, "dir": s.ckpt_dir},
                        time.monotonic() - ticket.submit_t,
                    ))

        ticket.status = "running"
        ticket.t_seated = time.monotonic()
        ticket.events.extend(table.events)  # e.g. build-time fallbacks
        ticket.events.append(ServiceEvent(
            "seat", {"request": ticket.seq, "slot": slot,
                     "table": repr(table.key[:3])},
            ticket.t_seated - ticket.submit_t,
        ))
        self.stats["stream_seated"] += 1

        if s.chunks_done >= s.budget:
            # Resumed at (or past) completion: finish without device work.
            bh1, bm1 = table.bk1.finalize(lane)
            self._finish(table, s, np.asarray(bh1)[0], np.asarray(bm1)[0],
                         STATUS_OK, "budget")
            return

        table.stacked = splice_slot(table.stacked, slot, stacked1)
        table.state = splice_slot(table.state, slot, lane)
        table.slots[slot] = s
        self.stats["stream_backfills"] += 1

    # ------------------------------------------------------------------
    # The quantum: one chunk launch + boundary processing
    # ------------------------------------------------------------------
    def _pick_table(self) -> Optional[_SlotTable]:
        tables = [t for t in self._tables.values() if t.n_live > 0]
        if not tables:
            return None
        self._rr += 1
        return tables[self._rr % len(tables)]

    def _run_quantum(self, table: _SlotTable, progress):
        svc = self.service
        try:
            new_state = table.chunk_fn(table.stacked, table.state)
            best_H = np.asarray(new_state.best_H)
        except Exception as exc:  # noqa: BLE001 — classified below
            self._table_fault(table, exc)
            return
        table.state = new_state
        table.quanta += 1
        now = time.monotonic()
        live = table.n_live
        self.stats["stream_quanta"] += 1
        self.stats["stream_slot_chunks"] += len(table.slots)
        self.stats["stream_live_lane_chunks"] += live

        # The 'nan' hook corrupts the detector's float view (chaos parity
        # with the one-shot path); detection itself is the production check.
        readings = best_H.astype(np.float64)
        spec = (svc.faults.fire("nan", kind=table.kind, chunk=table.quanta - 1)
                if svc.faults is not None else None)
        if spec is not None:
            for sl in (spec.slots or range(len(table.slots))):
                if sl < len(table.slots):
                    readings[sl] = np.nan

        retired = []  # (slot_index, status, reason)
        bests = {}
        for i, s in enumerate(table.slots):
            if s is None:
                continue
            s.chunks_done += 1
            if not np.all(np.isfinite(readings[i])):
                self.stats["nonfinite_detected"] += 1
                retired.append((i, STATUS_QUARANTINED, "quarantine"))
                continue
            best = int(np.max(np.asarray(finalize_cut(best_H[i], s.maxcut))))
            s.trace.append(best)
            bests[i] = best
            req = s.ticket.request
            if req.target_cut is not None and best >= req.target_cut:
                retired.append((i, STATUS_OK, "target"))
            elif s.chunks_done >= s.budget:
                retired.append((i, STATUS_OK, "budget"))
            elif (req.deadline_s is not None
                  and now - s.ticket.submit_t >= req.deadline_s):
                retired.append((i, STATUS_DEADLINE, "deadline"))

        if progress is not None:
            items = [(i, s) for i, s in enumerate(table.slots)
                     if s is not None and i in bests]
            progress(AnnealProgress(
                kind=table.kind, bucket=table.nb, chunk=table.quanta - 1,
                chunks_total=0,
                request_indices=tuple(s.ticket.seq for _, s in items),
                best_cut=tuple(bests[i] for i, _ in items),
            ))

        # Checkpoint surviving lanes at the boundary, then fire the kill
        # hook (same crash window as the one-shot chunk loop).
        retiring = {i for i, _, _ in retired}
        if svc.policy.checkpoint_dir:
            for i, s in enumerate(table.slots):
                if s is None or i in retiring or s.ckpt is None:
                    continue
                s.ckpt.maybe_save(
                    s.chunks_done, extract_slot(table.state, i),
                    meta={"traces": [s.trace]},
                )
        if svc.faults is not None:
            svc.faults.fire("kill", kind=table.kind, chunk=table.quanta - 1)

        if retired:
            bh_dev, bm_dev = table.bk.finalize(table.state)
            bh_all, bm_all = np.asarray(bh_dev), np.asarray(bm_dev)
            for i, status, reason in retired:
                s = table.slots[i]
                table.slots[i] = None
                if reason == "quarantine":
                    self._requeue_quarantined(table, s)
                else:
                    self._finish(table, s, bh_all[i], bm_all[i], status,
                                 reason)

    def _table_fault(self, table: _SlotTable, exc: BaseException):
        """Walk the fallback chain in place, carrying the engine state.

        The stacked problem arrays are re-derived from the slots' models on
        the downgraded backend; the state (spins/lanes/best) is backend-
        independent, so every seated lane's trajectory continues bit-
        identically.  An unclassifiable fault propagates (as on the
        one-shot path).
        """
        svc = self.service
        fault = classify_fault(exc, table.backend)
        nxt = (fallback_step(table.backend, table.opts, fault, table.nb)
               if fault is not None and svc.policy.fallback else None)
        if nxt is None:
            raise exc
        self.stats[f"fallback_{fault}"] += 1
        new_backend, new_opts = nxt
        ev = ServiceEvent(
            "fallback",
            {"from": table.backend, "to": new_backend, "fault": fault,
             "error": f"{type(exc).__name__}: {exc}"[:200]},
            time.monotonic(),
        )
        table.backend, table.opts = new_backend, dict(new_opts)
        table.degraded = True
        table.events.append(ev)  # future seats inherit the downgrade record
        self._programs_for(table)
        models = [s.model if s is not None else table.model0
                  for s in table.slots]
        table.stacked = table.bk.stack(models)
        for s in table.slots:
            if s is not None:
                s.ticket.events.append(ev)

    def _requeue_quarantined(self, table: _SlotTable, s: _Slot):
        """Per-slot quarantine: retire the poisoned lane, re-autotune its
        I0 clamp, and send it back through the queue (bounded retries)."""
        svc = self.service
        ticket = s.ticket
        ticket.retries += 1
        ticket.events.append(ServiceEvent(
            "quarantine", {"request": ticket.seq, "chunk": s.chunks_done},
            time.monotonic() - ticket.submit_t,
        ))
        self.stats["stream_quarantines"] += 1
        if ticket.retries > svc.policy.max_retries:
            self.stats["quarantine_failures"] += 1
            self._complete_unrun(ticket, STATUS_FAILED, "retries_exhausted")
            return
        hp = ticket.request.hp
        tuned, rep = autotune_hyperparams(
            ticket._model, hp, seed=svc.autotune_seed + ticket.retries,
        )
        ticket.request = dataclasses.replace(
            ticket.request, hp=dataclasses.replace(hp, i0_max=tuned.i0_max)
        )
        ticket.events.append(ServiceEvent(
            "retry", {"request": ticket.seq, "attempt": ticket.retries - 1,
                      "i0_max": tuned.i0_max, "z_max": rep.z_max},
            time.monotonic() - ticket.submit_t,
        ))
        ticket.status = "queued"
        with self._lock:
            self._queue.append(ticket)

    def _finish(self, table: _SlotTable, s: _Slot, bh: np.ndarray,
                bm: np.ndarray, status: str, reason: str):
        ticket = s.ticket
        now = time.monotonic()
        if status == STATUS_OK and table.degraded:
            status = STATUS_FALLBACK
        ticket.events.append(ServiceEvent(
            "retire", {"request": ticket.seq, "reason": reason,
                       "chunks": s.chunks_done},
            now - ticket.submit_t,
        ))
        if status == STATUS_DEADLINE:
            self.stats["deadline_expirations"] += 1
        n = ticket._model.n
        result = AnnealResult(
            best_cut=np.asarray(finalize_cut(bh, s.maxcut)),
            best_energy=bh,
            best_m=np.asarray(bm)[:, :n],
            energy_mean=None,
            energy_min=None,
            traj=None,
            stored_bits_per_iter=n * table.stored_per_iter,
            hp=ticket.request.hp,
        )
        resp = AnnealResponse(
            request=ticket.request, result=result,
            wall_s=now - ticket.submit_t,
            bucket=table.nb, batch=table.n_live + 1,
            chunks_run=s.chunks_done, chunks_total=s.budget,
            chunk_best_cut=np.asarray(s.trace),
            autotune=ticket.autotune, status=status,
            events=list(ticket.events),
            lane_wall_s=(now - ticket.t_seated
                         if ticket.t_seated is not None else None),
            queued_s=(ticket.t_seated - ticket.submit_t
                      if ticket.t_seated is not None else None),
        )
        enc = ticket.request.problem
        if isinstance(enc, ProblemEncoding):
            sol, obj, feas = enc.best_feasible(result.best_m)
            resp.solution, resp.objective, resp.feasible = sol, obj, feas
        if s.ckpt is not None and self.service.policy.cleanup_on_success:
            s.ckpt.purge()
        ticket.response = resp
        ticket.status = "done"
        self.stats["stream_completed"] += 1
        self.stats[f"stream_retired_{reason}"] += 1
        ticket._done.set()
