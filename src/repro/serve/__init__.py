"""repro.serve — the serving layer.

The production entry is the annealing service (the paper's own workload,
DESIGN.md §7): shape-bucketed, batched, compiled-executable-cached Max-Cut
solving over the plateau engine.  :mod:`repro.serve.stream` adds the
always-on continuous-batching front door (DESIGN.md §12).  The LM
prefill/decode serving stack lives in :mod:`repro.serve.lm` (DESIGN.md §6).
"""
from .anneal_service import (  # noqa: F401
    AnnealProgress,
    AnnealRequest,
    AnnealResponse,
    AnnealService,
)
from .registry import (  # noqa: F401
    AlgoFamily,
    family_for,
    register_algo,
    registered_algos,
)
from .resilience import (  # noqa: F401
    STATUS_DEADLINE,
    STATUS_FAILED,
    STATUS_FALLBACK,
    STATUS_OK,
    STATUS_QUARANTINED,
    STATUS_SHED,
    AdmissionError,
    QueueFullError,
    QuarantineFault,
    ResiliencePolicy,
    ServiceEvent,
)
from .stream import (  # noqa: F401
    StreamingAnnealService,
    StreamPolicy,
    StreamTicket,
)
