from .engine import *  # noqa: F401,F403
