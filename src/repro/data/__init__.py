from .pipeline import *  # noqa: F401,F403
