"""Deterministic synthetic token pipeline — stateless, shardable, resumable.

Every batch is a pure function of (seed, step), so the *entire* pipeline
state checkpointable as a single integer cursor (FT requirement: resume
bit-exact after restart).  On a real cluster each host materializes only its
``process_index`` slice; here ``host_slice`` exposes the same API.

The token stream is a mixture of a Markov-ish structured component and
uniform noise so the LM loss actually decreases (used by the example
trainer and FT tests).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

__all__ = ["DataConfig", "synthetic_batch", "host_slice", "batch_spec"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # frontends (stubs)
    n_patches: int = 0
    d_model: int = 0
    n_frames: int = 0


def synthetic_batch(cfg: DataConfig, step: int) -> Dict[str, jnp.ndarray]:
    """Batch for a given step: tokens (B, S+1) → inputs/labels by shifting."""
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    B, S = cfg.global_batch, cfg.seq_len
    # structured component: tokens follow t_{i+1} = (a*t_i + b) mod V on half
    # the positions, noise elsewhere — learnable but not trivial.
    a = 31 % cfg.vocab
    t0 = jax.random.randint(k1, (B, 1), 0, cfg.vocab)
    idx = jnp.arange(S + 1)
    structured = (t0 * a + idx * 97) % cfg.vocab
    noise = jax.random.randint(k2, (B, S + 1), 0, cfg.vocab)
    use_noise = jax.random.bernoulli(k3, 0.25, (B, S + 1))
    tokens = jnp.where(use_noise, noise, structured).astype(jnp.int32)
    batch = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
    if cfg.n_patches:
        batch["patches"] = (
            jax.random.normal(k4, (B, cfg.n_patches, cfg.d_model)) * 0.02
        )
    if cfg.n_frames:
        batch["frames"] = jax.random.normal(k4, (B, cfg.n_frames, cfg.d_model)) * 0.1
    return batch


def host_slice(batch: Dict[str, jnp.ndarray], process_index: int, process_count: int):
    """Per-host shard of a global batch (multi-host data loading)."""
    def slc(x):
        per = x.shape[0] // process_count
        return x[process_index * per : (process_index + 1) * per]

    return {k: slc(v) for k, v in batch.items()}


def batch_spec(cfg: DataConfig):
    """ShapeDtypeStructs for the dry-run (no allocation)."""
    B, S = cfg.global_batch, cfg.seq_len
    spec = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }
    if cfg.n_patches:
        spec["patches"] = jax.ShapeDtypeStruct((B, cfg.n_patches, cfg.d_model), jnp.float32)
    if cfg.n_frames:
        spec["frames"] = jax.ShapeDtypeStruct((B, cfg.n_frames, cfg.d_model), jnp.float32)
    return spec
