"""Bitplane codec: ±1 spins as uint32 sign-bit words (DESIGN.md §4).

The FPGA stores one spin per BRAM bit — an 800-spin state is a single
800-bit word.  The TPU transcription is this codec: a spin vector
``m ∈ {-1,+1}^N`` becomes ``ceil(N/32)`` uint32 words, bit ``k`` of word
``w`` holding the sign of spin ``n = 32·w + k`` (1 ⇔ +1).  The same layout
is used

* for the HBM-resident engine state under ``storage_layout='packed'``
  (`repro.core.engine`): spins and best-spins live as bitplanes between
  plateau launches, 32× smaller than the seed's float32 spins;
* for the trajectory planes of ``record='traj'`` (the Eq. 5/6 witness);
* inside the streamed-noise resident kernel
  (`repro.kernels.ssa_update.ssa_plateau_packed_batched`), whose HBM-facing
  spin refs are these words — `_unpack_pm1_f32` / `_pack_pm1` are the
  kernel-side halves of the codec, operating on lane-aligned (N % 128 == 0)
  tiles in VMEM.

Everything here is pure `jnp` on uint32 (no Pallas imports), so the codec
is usable from `repro.core` without pulling in the kernel toolchain, and
identically inside kernel bodies (interpret mode and Mosaic share the ops).

Tail handling: for N not a multiple of 32 the last word's high bits are
zero-padded on pack and sliced off on unpack — roundtrip-exact for any N
(property-tested in tests/test_bitplane.py).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

__all__ = [
    "packed_words",
    "pack_spins",
    "unpack_spins",
    "packed_nbytes",
]

# Host constant (never a traced value, safe under jit) — jnp ops accept it.
_SHIFTS = np.arange(32, dtype=np.uint32)


def _shifts():
    return _SHIFTS


def packed_words(n: int) -> int:
    """Words needed for an N-spin bitplane: ceil(N/32)."""
    return (int(n) + 31) // 32


def packed_nbytes(n: int) -> int:
    """Bytes of one packed N-spin plane (uint32 words)."""
    return 4 * packed_words(n)


def pack_spins(m: jnp.ndarray) -> jnp.ndarray:
    """Pack ±1 spins [..., N] into uint32 bitplanes [..., ceil(N/32)].

    Bit k of word w is the sign bit of spin 32·w + k (1 ⇔ m > 0); tail bits
    of the last word are 0.  Accepts any numeric spin dtype.
    """
    n = m.shape[-1]
    nw = packed_words(n)
    pad = nw * 32 - n
    bits = (m > 0).astype(jnp.uint32)
    if pad:
        bits = jnp.concatenate(
            [bits, jnp.zeros(bits.shape[:-1] + (pad,), jnp.uint32)], axis=-1
        )
    bits = bits.reshape(bits.shape[:-1] + (nw, 32))
    return jnp.sum(bits << _shifts(), axis=-1, dtype=jnp.uint32)


def unpack_spins(packed: jnp.ndarray, n: int) -> jnp.ndarray:
    """Inverse of pack_spins; returns int8 spins in {-1,+1}, shape [..., n]."""
    bits = (packed[..., None] >> _shifts()) & jnp.uint32(1)
    flat = bits.reshape(bits.shape[:-2] + (-1,))[..., :n]
    return jnp.where(flat == 1, 1, -1).astype(jnp.int8)
