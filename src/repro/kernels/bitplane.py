"""Bitplane codec: ±1 spins as uint32 sign-bit words (DESIGN.md §4).

The FPGA stores one spin per BRAM bit — an 800-spin state is a single
800-bit word.  The TPU transcription is this codec: a spin vector
``m ∈ {-1,+1}^N`` becomes ``ceil(N/32)`` uint32 words, bit ``k`` of word
``w`` holding the sign of spin ``n = 32·w + k`` (1 ⇔ +1).  The same layout
is used

* for the HBM-resident engine state under ``storage_layout='packed'``
  (`repro.core.engine`): spins and best-spins live as bitplanes between
  plateau launches, 32× smaller than the seed's float32 spins;
* for the trajectory planes of ``record='traj'`` (the Eq. 5/6 witness);
* inside the streamed-noise resident kernel
  (`repro.kernels.ssa_update.ssa_plateau_packed_batched`), whose HBM-facing
  spin refs are these words — `_unpack_pm1_f32` / `_pack_pm1` are the
  kernel-side halves of the codec, operating on lane-aligned (N % 128 == 0)
  tiles in VMEM.

Everything here is pure `jnp` on uint32 (no Pallas imports), so the codec
is usable from `repro.core` without pulling in the kernel toolchain, and
identically inside kernel bodies (interpret mode and Mosaic share the ops).

Tail handling: for N not a multiple of 32 the last word's high bits are
zero-padded on pack and sliced off on unpack — roundtrip-exact for any N
(property-tested in tests/test_bitplane.py).

Since PR 7 the bitplane is also the *arithmetic* format, not just storage:
:class:`PackedJ` packs the coupling matrix itself as a sign plane plus
magnitude bitplanes (integer weights = a sum of shifted ±1 planes), and
:func:`popcount_u32` is the primitive the XNOR-popcount field contraction
(`repro.core.ising.local_fields_popcount`) is built from.  The FPGA
identity per coupling plane is

    sum_j sign_ij * m_j  =  2 * popcount(XNOR(m_words, sign_words) & mask)
                            - popcount(mask)

— 32 spins per word op, no unpack to f32 anywhere on the path.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "packed_words",
    "pack_spins",
    "unpack_spins",
    "packed_nbytes",
    "popcount_u32",
    "PackedJ",
    "pack_couplings",
    "pack_couplings_from_adjacency",
    "adjacency_weight_bits",
    "packed_j_nbytes",
]

# Host constant (never a traced value, safe under jit) — jnp ops accept it.
_SHIFTS = np.arange(32, dtype=np.uint32)


def _shifts():
    return _SHIFTS


def packed_words(n: int) -> int:
    """Words needed for an N-spin bitplane: ceil(N/32)."""
    return (int(n) + 31) // 32


def packed_nbytes(n: int) -> int:
    """Bytes of one packed N-spin plane (uint32 words)."""
    return 4 * packed_words(n)


def pack_spins(m: jnp.ndarray) -> jnp.ndarray:
    """Pack ±1 spins [..., N] into uint32 bitplanes [..., ceil(N/32)].

    Bit k of word w is the sign bit of spin 32·w + k (1 ⇔ m > 0); tail bits
    of the last word are 0.  Accepts any numeric spin dtype.
    """
    n = m.shape[-1]
    nw = packed_words(n)
    pad = nw * 32 - n
    bits = (m > 0).astype(jnp.uint32)
    if pad:
        bits = jnp.concatenate(
            [bits, jnp.zeros(bits.shape[:-1] + (pad,), jnp.uint32)], axis=-1
        )
    bits = bits.reshape(bits.shape[:-1] + (nw, 32))
    return jnp.sum(bits << _shifts(), axis=-1, dtype=jnp.uint32)


def unpack_spins(packed: jnp.ndarray, n: int) -> jnp.ndarray:
    """Inverse of pack_spins; returns int8 spins in {-1,+1}, shape [..., n]."""
    bits = (packed[..., None] >> _shifts()) & jnp.uint32(1)
    flat = bits.reshape(bits.shape[:-2] + (-1,))[..., :n]
    return jnp.where(flat == 1, 1, -1).astype(jnp.int8)


def popcount_u32(x: jnp.ndarray) -> jnp.ndarray:
    """Per-word population count of uint32 words, as int32.

    The single arithmetic primitive of the XNOR-popcount field path — one
    VPU op covering 32 spins.  Rejects non-uint32 inputs instead of
    casting: a silent widen would mean the caller left the packed domain.
    """
    if x.dtype != jnp.uint32:
        raise TypeError(f"popcount_u32 expects uint32 words, got {x.dtype}")
    return jax.lax.population_count(x).astype(jnp.int32)


class PackedJ(NamedTuple):
    """Coupling matrix as bitplanes: the XNOR-popcount operand layout.

    For a symmetric integer J (the same row convention as the sparse
    adjacency — ``field_i = h_i + sum_j J_ij m_j``):

    sign:  (N, Nw) uint32 — bit j of row i is 1 ⇔ J_ij > 0.
    mags:  (n_bits, N, Nw) uint32 — bit j of plane b row i is bit b of
           |J_ij|; plane b is the mask of couplings whose magnitude has
           that binary digit, so J = Σ_b 2^b · (±1 plane b).
    base:  (N,) int32 — −Σ_b 2^b · popcount(mags[b, i]) , the constant
           −degree terms of every plane folded into one vector, so

               field = h + base + Σ_b 2^{b+1} · popcount(XNOR & mags[b])

    All tail/padding bits (column ≥ N) are zero in every plane, which makes
    the contraction immune to garbage in the spin words' tail bits: the
    AND with the magnitude mask kills them.  ±1-weight instances (all of
    G-set) have n_bits == 1 — a single XNOR-popcount per row.
    """

    sign: jnp.ndarray
    mags: jnp.ndarray
    base: jnp.ndarray

    @property
    def n_bits(self) -> int:
        return self.mags.shape[0]

    @property
    def n_words(self) -> int:
        return self.sign.shape[-1]


def _pack_bits_np(bits: np.ndarray) -> np.ndarray:
    """Host-side pack of a 0/1 array [..., N] into uint32 words."""
    n = bits.shape[-1]
    nw = packed_words(n)
    pad = nw * 32 - n
    b = bits.astype(np.uint32)
    if pad:
        b = np.concatenate(
            [b, np.zeros(b.shape[:-1] + (pad,), np.uint32)], axis=-1
        )
    b = b.reshape(b.shape[:-1] + (nw, 32))
    return (b << _SHIFTS).sum(axis=-1, dtype=np.uint32)


def _popcount_np(words: np.ndarray) -> np.ndarray:
    """Host-side popcount summed over the word axis: [..., Nw] -> [...]."""
    u8 = np.ascontiguousarray(words).view(np.uint8)
    return np.unpackbits(u8, axis=-1).sum(axis=-1, dtype=np.int64)


def _resolve_n_bits(max_mag: int, n_bits) -> int:
    need = max(1, int(max_mag).bit_length())
    if n_bits is None:
        return need
    n_bits = int(n_bits)
    if n_bits < need:
        raise ValueError(
            f"couplings need {need} magnitude bitplanes, caller forced "
            f"{n_bits} — weights up to {max_mag} cannot be represented"
        )
    return n_bits


def pack_couplings(J: np.ndarray, n_bits=None) -> PackedJ:
    """Pack a dense symmetric integer coupling matrix into bitplanes.

    Raises on non-integral weights — the popcount path is exact-integer by
    construction and refuses inputs it cannot represent exactly.  ``n_bits``
    forces the magnitude-plane count (zero planes pad the top) so stacked
    problems share one layout; it must cover max|J|.
    """
    J = np.asarray(J)
    Ji = np.asarray(np.rint(J), dtype=np.int64)
    if not np.array_equal(Ji, np.asarray(J, dtype=np.float64)):
        raise ValueError("pack_couplings requires integer weights")
    mag = np.abs(Ji)
    n_bits = _resolve_n_bits(mag.max(initial=0), n_bits)
    sign = _pack_bits_np(Ji > 0)
    mags = np.stack(
        [_pack_bits_np((mag >> b) & 1) for b in range(n_bits)]
    )
    degs = _popcount_np(mags)  # (n_bits, N)
    shifts = (np.int64(1) << np.arange(n_bits, dtype=np.int64))[:, None]
    base = -(degs * shifts).sum(axis=0).astype(np.int32)
    return PackedJ(jnp.asarray(sign), jnp.asarray(mags), jnp.asarray(base))


def _coalesced_adjacency(n: int, nbr_idx, nbr_w):
    """(rows, cols, weights) with duplicate (i, j) slots weight-summed."""
    idx = np.asarray(nbr_idx, dtype=np.int64)
    w = np.asarray(nbr_w, dtype=np.int64)
    rows = np.broadcast_to(np.arange(n, dtype=np.int64)[:, None], idx.shape)
    live = w != 0
    keys = rows[live] * n + idx[live]
    uniq, inv = np.unique(keys, return_inverse=True)
    wsum = np.zeros(uniq.shape[0], dtype=np.int64)
    np.add.at(wsum, inv, w[live])
    nz = wsum != 0
    uniq, wsum = uniq[nz], wsum[nz]
    return uniq // n, uniq % n, wsum


def adjacency_weight_bits(n: int, nbr_idx, nbr_w) -> int:
    """Magnitude bitplanes needed for a model's couplings (≥ 1).

    Operates on the *coalesced* weights (duplicate adjacency slots summed,
    matching ``IsingModel.dense_J``), so the answer is exactly the plane
    count :func:`pack_couplings_from_adjacency` would produce.  This is the
    number `field_mode='auto'` compares against POPCOUNT_AUTO_MAX_BITS.
    """
    _, _, wsum = _coalesced_adjacency(int(n), nbr_idx, nbr_w)
    return max(1, int(np.abs(wsum).max(initial=0)).bit_length())


def pack_couplings_from_adjacency(
    n: int, nbr_idx: np.ndarray, nbr_w: np.ndarray, n_bits=None
) -> PackedJ:
    """Pack couplings from the padded adjacency without materializing J.

    ``nbr_idx``/``nbr_w`` are the `IsingModel` padded neighbor lists
    (weight 0 = padding slot).  Duplicate (i, j) entries are weight-summed
    first, matching ``IsingModel.dense_J``.  O(N·max_deg) host work — this
    is the constructor the 20k-spin instances use.
    """
    n = int(n)
    nw = packed_words(n)
    r, c, wsum = _coalesced_adjacency(n, nbr_idx, nbr_w)
    word, bit = c // 32, (c % 32).astype(np.uint32)

    mag = np.abs(wsum)
    n_bits = _resolve_n_bits(mag.max(initial=0), n_bits)
    sign = np.zeros((n, nw), np.uint32)
    pos = wsum > 0
    np.bitwise_or.at(
        sign, (r[pos], word[pos]), np.uint32(1) << bit[pos]
    )
    mags = np.zeros((n_bits, n, nw), np.uint32)
    base = np.zeros(n, np.int64)
    for b in range(n_bits):
        sel = ((mag >> b) & 1) == 1
        np.bitwise_or.at(
            mags[b], (r[sel], word[sel]), np.uint32(1) << bit[sel]
        )
        np.add.at(base, r[sel], -(np.int64(1) << b))
    return PackedJ(
        jnp.asarray(sign), jnp.asarray(mags),
        jnp.asarray(base.astype(np.int32)),
    )


def packed_j_nbytes(n: int, n_bits: int = 1) -> int:
    """Bytes of a PackedJ layout: sign + n_bits magnitude planes + base."""
    nw = packed_words(n)
    return 4 * n * nw * (1 + int(n_bits)) + 4 * int(n)
