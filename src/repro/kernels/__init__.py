"""Pallas TPU kernels for the SSA hot path; see ssa_update.py and ops.py.

Validated against ref.py oracles in interpret mode (CPU container);
TPU (Mosaic) is the compile target.
"""
from . import ops, ref, ssa_update  # noqa: F401
