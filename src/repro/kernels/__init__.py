"""Pallas TPU kernels for the SSA hot path; see ssa_update.py and ops.py.

Validated against ref.py oracles in interpret mode (CPU container);
TPU (Mosaic) is the compile target.

`bitplane` (the pure-jnp spin/noise codec) imports eagerly — `repro.core`
depends on it for the packed storage layout.  The Pallas-backed modules
(`ops`, `ref`, `ssa_update`) load lazily so importing the codec never pulls
in the kernel toolchain.
"""
from . import bitplane  # noqa: F401

_LAZY = ("ops", "ref", "ssa_update")


def __getattr__(name):
    if name in _LAZY:
        import importlib

        mod = importlib.import_module(f".{name}", __name__)
        globals()[name] = mod
        return mod
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(list(globals()) + list(_LAZY))
