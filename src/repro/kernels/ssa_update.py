"""Pallas TPU kernels for the SSA/HA-SSA spin update (DESIGN.md §2.3).

The FPGA's spin-gate array computes, for all spins in one clock,

    field_i = h_i + Σ_j J_ij m_j        (MUX tree + adder)
    Itanh   = clamp(field + n·r + Itanh, -I0, I0-1)   (saturating counter)
    m       = sign(Itanh)

On TPU we batch replicas (trials) on a leading axis so the field computation
is a (R,N)·(N,N) matmul on the MXU; the FSM is a fused VPU epilogue.  Three
kernels:

* :func:`local_field` — tiled matmul ``m @ J + h`` with a standard
  (R-tile, N-tile, K-tile) grid and a float32 VMEM accumulator.  Used as the
  drop-in dense-field backend.  Exact: ±1 spins × integer J accumulate in
  f32 (< 2^24).

* :func:`ssa_plateau` / :func:`ssa_plateau_batched` — the **resident**
  kernel: one launch executes all C cycles of a temperature plateau with J
  pinned in VMEM, streaming only pre-generated noise in and nothing but
  final state + running best out.  Per-cycle HBM traffic drops from O(N²)
  (re-reading J) to O(R·N) (noise), raising arithmetic intensity by ~C×.
  It also fuses the solution tracking (energy + arg-best restricted to
  storage-eligible plateaus), which is HA-SSA's storage policy executed
  entirely on-chip.  Since the packed kernel landed this is the *threefry
  reference path* (threefry noise cannot be generated in-kernel).

* :func:`ssa_plateau_packed` / :func:`ssa_plateau_packed_batched` — the
  **streamed-noise packed** kernel (DESIGN.md §4): the HBM-facing spin refs
  are uint32 bitplanes (`repro.kernels.bitplane` layout) and the per-cycle
  noise is generated *inside* the kernel by stepping carried xorshift128
  lanes, bit-identical to `repro.core.rng.xorshift_next_bits` — the noise
  buffer is gone entirely and per-plateau HBM traffic is O(R·N) lanes +
  O(R·N/32) packed spins.  The production path for xorshift noise.

* :func:`ssa_plateau_popcount` / :func:`ssa_plateau_popcount_batched` — the
  **bit-parallel multi-plateau** kernel (DESIGN.md §8): the field
  contraction itself runs on the bitplanes via XNOR-popcount against a
  packed-J sign/magnitude layout (`repro.kernels.bitplane.PackedJ`), 32
  spins per word op, no f32 anywhere in the body (the MXU is idle — this is
  the software twin of the FPGA's XNOR/popcount adder tree).  One launch
  additionally carries I0 and eligibility across an *entire plateau chain*
  (per-cycle schedule operands), so a full iteration costs one kernel
  dispatch instead of one per plateau — the small-N launch-overhead fix.

All are validated against :mod:`.ref` oracles / the scan engine in
interpret mode (CPU) over a shape/dtype sweep; TPU is the compile target.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "local_field",
    "ssa_plateau",
    "ssa_plateau_batched",
    "ssa_plateau_packed",
    "ssa_plateau_packed_batched",
    "ssa_plateau_popcount",
    "ssa_plateau_popcount_batched",
    "pad_to",
    "DEFAULT_INTERPRET",
]

# interpret=True executes the kernel body in Python on CPU — the validation
# mode for this container; on TPU hosts the same code lowers to Mosaic.
DEFAULT_INTERPRET = jax.default_backend() == "cpu"


def pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    """Zero-pad ``axis`` up to a multiple of ``mult`` (TPU lane alignment)."""
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads)


# ---------------------------------------------------------------------------
# Kernel A: tiled local-field matmul  field = m @ J + h
# ---------------------------------------------------------------------------
def _field_kernel(m_ref, j_ref, h_ref, out_ref, acc_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        m_ref[...], j_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        out_ref[...] = (acc_ref[...] + h_ref[...].astype(jnp.float32)).astype(
            jnp.int32
        )


@functools.partial(
    jax.jit, static_argnames=("block_r", "block_n", "block_k", "interpret")
)
def local_field(
    m: jnp.ndarray,  # (R, N) ±1, any float/int dtype
    h: jnp.ndarray,  # (N,) int32
    J: jnp.ndarray,  # (N, N) float32/bfloat16 (integer-valued)
    *,
    block_r: int = 8,
    block_n: int = 128,
    block_k: int = 128,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """field = h + m @ J, int32 exact, via the tiled Pallas kernel."""
    interpret = DEFAULT_INTERPRET if interpret is None else interpret
    R, N = m.shape
    mf = pad_to(pad_to(m.astype(J.dtype), 1, block_k), 0, block_r)
    Jp = pad_to(pad_to(J, 0, block_k), 1, block_n)
    hp = pad_to(h.astype(jnp.int32).reshape(1, -1), 1, block_n)
    Rp, Kp = mf.shape
    Np = Jp.shape[1]
    nk = Kp // block_k
    grid = (Rp // block_r, Np // block_n, nk)
    out = pl.pallas_call(
        functools.partial(_field_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_r, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, block_n), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_r, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Rp, Np), jnp.int32),
        scratch_shapes=[pltpu.VMEM((block_r, block_n), jnp.float32)],
        interpret=interpret,
    )(mf, Jp, hp)
    return out[:R, :N]


# ---------------------------------------------------------------------------
# Kernel B: resident plateau kernel — C fused cycles, J pinned in VMEM
# ---------------------------------------------------------------------------
def _plateau_kernel(
    i0_ref,      # (1, 1) int32 SMEM-ish scalar
    m_ref,       # (1, bR, N) float32  spins ±1 (leading problem-block axis)
    it_ref,      # (1, bR, N) int32    Itanh state
    j_ref,       # (1, N, N)  J dtype  resident couplings of THIS problem
    h_ref,       # (1, 1, N)  int32    biases
    noise_ref,   # (1, C, bR, N) int8  per-cycle ±1 noise
    bh_ref,      # (1, bR, 1) int32    running best energy (input)
    bm_ref,      # (1, bR, N) int8     running best spins  (input)
    m_out,       # (1, bR, N) float32
    it_out,      # (1, bR, N) int32
    bh_out,      # (1, bR, 1) int32
    bm_out,      # (1, bR, N) int8
    m_s,         # scratch (bR, N) float32
    it_s,        # scratch (bR, N) int32
    bh_s,        # scratch (bR, 1) float32 (exact ints)
    bm_s,        # scratch (bR, N) float32 (±1)
    *,
    n_cycles: int,
    n_rnd: int,
    eligible: bool,
):
    m_s[...] = m_ref[0]
    it_s[...] = it_ref[0]
    bh_s[...] = bh_ref[0].astype(jnp.float32)
    bm_s[...] = bm_ref[0].astype(jnp.float32)
    i0 = i0_ref[0, 0]
    hf = h_ref[0].astype(jnp.float32)  # (1, N)
    jm = j_ref[0]

    def energy(m, field):
        # H = -(h·m + m·field)/2 ; exact in f32 for |field| < 2^24
        hm = jnp.sum(hf * m, axis=-1, keepdims=True)
        mf_ = jnp.sum(m * field, axis=-1, keepdims=True)
        return -(hm + mf_) * 0.5

    def track_best(c, m, field):
        if not eligible:
            return
        H = energy(m, field)
        better = H < bh_s[...]
        bh_s[...] = jnp.where(better, H, bh_s[...])
        bm_s[...] = jnp.where(better, m, bm_s[...])

    def body(c, _):
        field = (
            jnp.dot(m_s[...], jm, preferred_element_type=jnp.float32) + hf
        )
        # m_s currently holds m(t0+c): produced by THIS plateau for c >= 1.
        @pl.when(c >= 1)
        def _():
            track_best(c, m_s[...], field)

        r = noise_ref[0, c].astype(jnp.int32)
        I = field.astype(jnp.int32) + n_rnd * r + it_s[...]  # noqa: E741
        it_new = jnp.clip(I, -i0, i0 - 1)
        it_s[...] = it_new
        m_s[...] = jnp.where(it_new >= 0, 1.0, -1.0).astype(jnp.float32)
        return 0

    jax.lax.fori_loop(0, n_cycles, body, 0)
    # final state m(t0+C): one more field evaluation for its energy
    field = jnp.dot(m_s[...], jm, preferred_element_type=jnp.float32) + hf
    track_best(n_cycles, m_s[...], field)

    m_out[...] = m_s[...][None]
    it_out[...] = it_s[...][None]
    bh_out[...] = bh_s[...].astype(jnp.int32)[None]
    bm_out[...] = bm_s[...].astype(jnp.int8)[None]


@functools.partial(
    jax.jit,
    static_argnames=("n_rnd", "eligible", "block_r", "interpret"),
)
def ssa_plateau_batched(
    m: jnp.ndarray,       # (B, R, N) float32 ±1
    itanh: jnp.ndarray,   # (B, R, N) int32
    J: jnp.ndarray,       # (B, N, N) float32/bfloat16 — one J per problem
    h: jnp.ndarray,       # (B, N) int32
    noise: jnp.ndarray,   # (B, C, R, N) int8 ±1
    i0: jnp.ndarray,      # scalar int32 (shared: same schedule per bucket)
    best_H: jnp.ndarray,  # (B, R) int32
    best_m: jnp.ndarray,  # (B, R, N) int8
    *,
    n_rnd: int = 2,
    eligible: bool = True,
    block_r: int = 8,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Run one constant-I0 plateau for B stacked problems fully on-chip.

    The grid is (B, R-tiles): grid step (b, i) pins problem b's J in VMEM
    and runs all C cycles for one R-tile of trials — one launch serves a
    whole shape bucket of heterogeneous instances (the serving layer's
    batched hot path).  Per-problem semantics are identical to the B=1
    kernel; :func:`ssa_plateau` is exactly this with B=1.
    """
    interpret = DEFAULT_INTERPRET if interpret is None else interpret
    B, R, N = m.shape
    C = noise.shape[1]
    LANE = 128
    mf = pad_to(pad_to(m.astype(jnp.float32), 2, LANE), 1, block_r)
    itp = pad_to(pad_to(itanh, 2, LANE), 1, block_r)
    Jp = pad_to(pad_to(J, 1, LANE), 2, LANE)
    hp = pad_to(h.astype(jnp.int32).reshape(B, 1, -1), 2, LANE)
    np_ = pad_to(pad_to(noise, 3, LANE), 2, block_r)
    bhp = pad_to(best_H.reshape(B, -1, 1), 1, block_r)
    bmp = pad_to(pad_to(best_m, 2, LANE), 1, block_r)
    _, Rp, Np = mf.shape
    grid = (B, Rp // block_r)
    i0a = jnp.asarray(i0, jnp.int32).reshape(1, 1)

    kernel = functools.partial(
        _plateau_kernel, n_cycles=C, n_rnd=n_rnd, eligible=eligible
    )
    m_o, it_o, bh_o, bm_o = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, i: (0, 0)),
            pl.BlockSpec((1, block_r, Np), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, block_r, Np), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, Np, Np), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, 1, Np), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, C, block_r, Np), lambda b, i: (b, 0, i, 0)),
            pl.BlockSpec((1, block_r, 1), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, block_r, Np), lambda b, i: (b, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_r, Np), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, block_r, Np), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, block_r, 1), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, block_r, Np), lambda b, i: (b, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Rp, Np), jnp.float32),
            jax.ShapeDtypeStruct((B, Rp, Np), jnp.int32),
            jax.ShapeDtypeStruct((B, Rp, 1), jnp.int32),
            jax.ShapeDtypeStruct((B, Rp, Np), jnp.int8),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_r, Np), jnp.float32),
            pltpu.VMEM((block_r, Np), jnp.int32),
            pltpu.VMEM((block_r, 1), jnp.float32),
            pltpu.VMEM((block_r, Np), jnp.float32),
        ],
        interpret=interpret,
    )(i0a, mf, itp, Jp.astype(J.dtype), hp, np_, bhp, bmp)
    return (
        m_o[:, :R, :N],
        it_o[:, :R, :N],
        bh_o[:, :R, 0],
        bm_o[:, :R, :N],
    )


# ---------------------------------------------------------------------------
# Kernel C: streamed-noise packed plateau kernel — the bit-packed datapath
# ---------------------------------------------------------------------------
def _unpack_pm1_f32(words: jnp.ndarray) -> jnp.ndarray:
    """Kernel-side codec: (bR, Nw) u32 words → (bR, 32·Nw) f32 spins ±1.

    Bit layout matches repro.kernels.bitplane (bit k of word w = spin
    32·w + k; 1 ⇔ +1).  Runs on lane-aligned tiles (32·Nw % 128 == 0).
    """
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)
    flat = bits.reshape(words.shape[0], -1)
    return jnp.where(flat == 1, 1.0, -1.0).astype(jnp.float32)


def _pack_pm1(m: jnp.ndarray) -> jnp.ndarray:
    """Kernel-side codec: (bR, N) ±1 f32 → (bR, N/32) u32 words (N % 32 == 0)."""
    bits = (m > 0).astype(jnp.uint32).reshape(m.shape[0], -1, 32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(bits << shifts, axis=-1, dtype=jnp.uint32)


def _plateau_streamed_kernel(
    *refs,
    # i0_ref,    # (1, 1) int32 scalar
    # [jperp_ref]  (1, 1) int32 scalar — ONLY when n_replicas > 0 (SSQA)
    # mp_ref,    # (1, bR, Nw) uint32   spins, packed sign bits
    # it_ref,    # (1, bR, N)  int32    Itanh state
    # j_ref,     # (1, N, N)   J dtype  resident couplings of THIS problem
    # h_ref,     # (1, 1, N)   int32    biases
    # rng_ref,   # (1, 4, bR, N) uint32 xorshift128 lanes (carried)
    # bh_ref,    # (1, bR, 1)  int32    running best energy (input)
    # bmp_ref,   # (1, bR, Nw) uint32   running best spins, packed (input)
    # mp_out,    # (1, bR, Nw) uint32
    # it_out,    # (1, bR, N)  int32
    # rng_out,   # (1, 4, bR, N) uint32
    # bh_out,    # (1, bR, 1)  int32
    # bmp_out,   # (1, bR, Nw) uint32
    # m_s,       # scratch (bR, N) float32
    # it_s,      # scratch (bR, N) int32
    # rng_s,     # scratch (4, bR, N) uint32
    # bh_s,      # scratch (bR, 1) float32 (exact ints)
    # bm_s,      # scratch (bR, N) float32 (±1)
    n_cycles: int,
    n_rnd: int,
    eligible: bool,
    n_replicas: int = 0,
):
    """All C cycles of a plateau with packed HBM refs and in-kernel noise.

    The HBM-facing spin state is the uint32 bitplane codec; the per-cycle
    noise is generated *inside* the kernel by stepping the carried Marsaglia
    xorshift128 lanes (bit-identical to repro.core.rng.xorshift_next_bits),
    so no (C, R, N) noise buffer exists anywhere.  Per-plateau HBM traffic
    drops from O(C·R·N) int8 noise to O(R·N) uint32 lanes + O(R·N/32)
    packed spins.

    ``n_replicas > 0`` is the SSQA mode (DESIGN.md §13): the R-tile is one
    Trotter ring (block_r == n_replicas enforced by the wrapper) and a
    ``jperp_ref`` scalar operand adds the nearest-replica coupling
    ``J⊥·(m[k-1] + m[k+1])`` — a roll over the tile's trial axis — to the
    *update* field only; best-tracking keeps the classical per-replica
    energy.  ``n_replicas == 0`` compiles the exact classical body (no
    extra operand, identical jaxpr).
    """
    if n_replicas:
        (i0_ref, jperp_ref, mp_ref, it_ref, j_ref, h_ref, rng_ref, bh_ref,
         bmp_ref, mp_out, it_out, rng_out, bh_out, bmp_out,
         m_s, it_s, rng_s, bh_s, bm_s) = refs
    else:
        (i0_ref, mp_ref, it_ref, j_ref, h_ref, rng_ref, bh_ref,
         bmp_ref, mp_out, it_out, rng_out, bh_out, bmp_out,
         m_s, it_s, rng_s, bh_s, bm_s) = refs
    m_s[...] = _unpack_pm1_f32(mp_ref[0])
    it_s[...] = it_ref[0]
    rng_s[...] = rng_ref[0]
    bh_s[...] = bh_ref[0].astype(jnp.float32)
    bm_s[...] = _unpack_pm1_f32(bmp_ref[0])
    i0 = i0_ref[0, 0]
    hf = h_ref[0].astype(jnp.float32)  # (1, N)
    jm = j_ref[0]
    one = jnp.uint32(1)

    def energy(m, field):
        hm = jnp.sum(hf * m, axis=-1, keepdims=True)
        mf_ = jnp.sum(m * field, axis=-1, keepdims=True)
        return -(hm + mf_) * 0.5

    def track_best(m, field):
        if not eligible:
            return
        H = energy(m, field)
        better = H < bh_s[...]
        bh_s[...] = jnp.where(better, H, bh_s[...])
        bm_s[...] = jnp.where(better, m, bm_s[...])

    def body(c, _):
        field = (
            jnp.dot(m_s[...], jm, preferred_element_type=jnp.float32) + hf
        )
        # m_s currently holds m(t0+c): produced by THIS plateau for c >= 1.
        @pl.when(c >= 1)
        def _():
            track_best(m_s[...], field)

        # One Marsaglia xorshift128 step per lane — the FPGA's per-spin-gate
        # bit stream, bit-identical to repro.core.rng.xorshift_next_bits.
        x, y, z, w = rng_s[0], rng_s[1], rng_s[2], rng_s[3]
        t = x ^ (x << jnp.uint32(11))
        w_new = (w ^ (w >> jnp.uint32(19))) ^ (t ^ (t >> jnp.uint32(8)))
        rng_s[0] = y
        rng_s[1] = z
        rng_s[2] = w
        rng_s[3] = w_new
        r = jnp.where((w_new >> jnp.uint32(31)) & one, 1, -1).astype(jnp.int32)

        upd = field.astype(jnp.int32)
        if n_replicas:
            # Trotter-ring coupling over the tile's trial axis (one ring per
            # R-tile): m is ±1 f32, the sum of two neighbors is exact.
            coup = (
                jnp.roll(m_s[...], 1, axis=0) + jnp.roll(m_s[...], -1, axis=0)
            ).astype(jnp.int32)
            upd = upd + jperp_ref[0, 0] * coup
        I = upd + n_rnd * r + it_s[...]  # noqa: E741
        it_new = jnp.clip(I, -i0, i0 - 1)
        it_s[...] = it_new
        m_s[...] = jnp.where(it_new >= 0, 1.0, -1.0).astype(jnp.float32)
        return 0

    jax.lax.fori_loop(0, n_cycles, body, 0)
    # final state m(t0+C): one more field evaluation for its energy
    field = jnp.dot(m_s[...], jm, preferred_element_type=jnp.float32) + hf
    track_best(m_s[...], field)

    mp_out[...] = _pack_pm1(m_s[...])[None]
    it_out[...] = it_s[...][None]
    rng_out[...] = rng_s[...][None]
    bh_out[...] = bh_s[...].astype(jnp.int32)[None]
    bmp_out[...] = _pack_pm1(bm_s[...])[None]


@functools.partial(
    jax.jit,
    static_argnames=(
        "n_cycles", "n_rnd", "eligible", "block_r", "interpret", "n_replicas"
    ),
)
def ssa_plateau_packed_batched(
    m_packed: jnp.ndarray,   # (B, R, Nw) uint32 packed ±1 spins
    itanh: jnp.ndarray,      # (B, R, N) int32
    J: jnp.ndarray,          # (B, N, N) float32/bfloat16 — one J per problem
    h: jnp.ndarray,          # (B, N) int32
    rng: jnp.ndarray,        # (B, 4, R, N) uint32 xorshift lanes (carried)
    i0: jnp.ndarray,         # scalar int32 (shared: same schedule per bucket)
    best_H: jnp.ndarray,     # (B, R) int32
    best_m_packed: jnp.ndarray,  # (B, R, Nw) uint32
    *,
    n_cycles: int,
    n_rnd: int = 2,
    eligible: bool = True,
    block_r: int = 8,
    interpret: Optional[bool] = None,
    jperp=0,                 # scalar int32 replica coupling (SSQA)
    n_replicas: int = 0,     # 0 = classical; >0 = SSQA Trotter-ring mode
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Streamed-noise resident plateau for B stacked problems, packed refs.

    Semantically `ssa_plateau_batched` with the plateau's noise equal to
    ``n_cycles`` successive `xorshift_next_bits` draws from ``rng`` — but no
    (B, C, R, N) buffer is ever materialized: noise bits are generated in
    VMEM from the carried lanes, and the HBM-facing spin state crosses the
    launch boundary as uint32 bitplanes (32× smaller than float32 spins).

    Returns (m_packed, itanh, rng, best_H, best_m_packed) after the plateau.
    """
    interpret = DEFAULT_INTERPRET if interpret is None else interpret
    B, R, N = itanh.shape
    if n_replicas:
        if block_r != n_replicas:
            raise ValueError(
                f"SSQA needs block_r == n_replicas (one Trotter ring per "
                f"R-tile), got block_r={block_r}, n_replicas={n_replicas}"
            )
        if R % n_replicas:
            raise ValueError(
                f"n_trials={R} not divisible by n_replicas={n_replicas}"
            )
    LANE = 128
    Np = N + (-N) % LANE
    Nwp = Np // 32
    # Pad packed words up to the padded lane count; zero words decode to -1
    # pad spins, which J's zero pad rows/cols make inert.
    mp = pad_to(pad_to(m_packed, 2, Nwp), 1, block_r)
    bmp = pad_to(pad_to(best_m_packed, 2, Nwp), 1, block_r)
    itp = pad_to(pad_to(itanh, 2, LANE), 1, block_r)
    Jp = pad_to(pad_to(J, 1, LANE), 2, LANE)
    hp = pad_to(h.astype(jnp.int32).reshape(B, 1, -1), 2, LANE)
    # Zero-state pad lanes are xorshift fixed points (constant -1 noise).
    rngp = pad_to(pad_to(rng, 3, LANE), 2, block_r)
    bhp = pad_to(best_H.reshape(B, -1, 1), 1, block_r)
    Rp = itp.shape[1]
    grid = (B, Rp // block_r)
    i0a = jnp.asarray(i0, jnp.int32).reshape(1, 1)

    kernel = functools.partial(
        _plateau_streamed_kernel, n_cycles=n_cycles, n_rnd=n_rnd,
        eligible=eligible, n_replicas=n_replicas,
    )
    jperp_specs, jperp_args = [], []
    if n_replicas:
        jperp_specs = [pl.BlockSpec((1, 1), lambda b, i: (0, 0))]
        jperp_args = [jnp.asarray(jperp, jnp.int32).reshape(1, 1)]
    mp_o, it_o, rng_o, bh_o, bmp_o = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, i: (0, 0)),
            *jperp_specs,
            pl.BlockSpec((1, block_r, Nwp), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, block_r, Np), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, Np, Np), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, 1, Np), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, 4, block_r, Np), lambda b, i: (b, 0, i, 0)),
            pl.BlockSpec((1, block_r, 1), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, block_r, Nwp), lambda b, i: (b, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_r, Nwp), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, block_r, Np), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, 4, block_r, Np), lambda b, i: (b, 0, i, 0)),
            pl.BlockSpec((1, block_r, 1), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, block_r, Nwp), lambda b, i: (b, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Rp, Nwp), jnp.uint32),
            jax.ShapeDtypeStruct((B, Rp, Np), jnp.int32),
            jax.ShapeDtypeStruct((B, 4, Rp, Np), jnp.uint32),
            jax.ShapeDtypeStruct((B, Rp, 1), jnp.int32),
            jax.ShapeDtypeStruct((B, Rp, Nwp), jnp.uint32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_r, Np), jnp.float32),
            pltpu.VMEM((block_r, Np), jnp.int32),
            pltpu.VMEM((4, block_r, Np), jnp.uint32),
            pltpu.VMEM((block_r, 1), jnp.float32),
            pltpu.VMEM((block_r, Np), jnp.float32),
        ],
        interpret=interpret,
    )(i0a, *jperp_args, mp, itp, Jp.astype(J.dtype), hp, rngp, bhp, bmp)
    nw = (N + 31) // 32
    return (
        mp_o[:, :R, :nw],
        it_o[:, :R, :N],
        rng_o[:, :, :R, :N],
        bh_o[:, :R, 0],
        bmp_o[:, :R, :nw],
    )


def ssa_plateau_packed(
    m_packed: jnp.ndarray,   # (R, Nw) uint32
    itanh: jnp.ndarray,      # (R, N) int32
    J: jnp.ndarray,          # (N, N)
    h: jnp.ndarray,          # (N,) int32
    rng: jnp.ndarray,        # (4, R, N) uint32
    i0: jnp.ndarray,
    best_H: jnp.ndarray,     # (R,) int32
    best_m_packed: jnp.ndarray,  # (R, Nw) uint32
    *,
    n_cycles: int,
    n_rnd: int = 2,
    eligible: bool = True,
    block_r: int = 8,
    interpret: Optional[bool] = None,
    jperp=0,
    n_replicas: int = 0,
):
    """B=1 slice of :func:`ssa_plateau_packed_batched` (one kernel body)."""
    mp, it, rs, bh, bmp = ssa_plateau_packed_batched(
        m_packed[None],
        itanh[None],
        J[None],
        h[None],
        rng[None],
        i0,
        best_H[None],
        best_m_packed[None],
        n_cycles=n_cycles,
        n_rnd=n_rnd,
        eligible=eligible,
        block_r=block_r,
        interpret=interpret,
        jperp=jperp,
        n_replicas=n_replicas,
    )
    return mp[0], it[0], rs[0], bh[0], bmp[0]


@functools.partial(
    jax.jit,
    static_argnames=("n_rnd", "eligible", "block_r", "interpret"),
)
def ssa_plateau(
    m: jnp.ndarray,       # (R, N) float32 ±1
    itanh: jnp.ndarray,   # (R, N) int32
    J: jnp.ndarray,       # (N, N) float32/bfloat16
    h: jnp.ndarray,       # (N,) int32
    noise: jnp.ndarray,   # (C, R, N) int8 ±1
    i0: jnp.ndarray,      # scalar int32
    best_H: jnp.ndarray,  # (R,) int32
    best_m: jnp.ndarray,  # (R, N) int8
    *,
    n_rnd: int = 2,
    eligible: bool = True,
    block_r: int = 8,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Run one constant-I0 plateau of C cycles fully on-chip.

    Returns (m, itanh, best_H, best_m) after the plateau.  ``eligible``
    implements HA-SSA's storage policy: only plateaus with I0 == I0max
    update the running best (Eq. 6); passing eligible=True for every plateau
    recovers conventional SSA's policy (Eq. 5).  This is the B=1 slice of
    :func:`ssa_plateau_batched` (one kernel body serves both).
    """
    m_o, it_o, bh_o, bm_o = ssa_plateau_batched(
        m[None],
        itanh[None],
        J[None],
        h[None],
        noise[None],
        i0,
        best_H[None],
        best_m[None],
        n_rnd=n_rnd,
        eligible=eligible,
        block_r=block_r,
        interpret=interpret,
    )
    return m_o[0], it_o[0], bh_o[0], bm_o[0]


# ---------------------------------------------------------------------------
# Kernel D: bit-parallel multi-plateau kernel — XNOR-popcount field, all-int
# ---------------------------------------------------------------------------
def _unpack_pm1_i32(words: jnp.ndarray) -> jnp.ndarray:
    """Kernel-side codec: (bR, Nw) u32 words → (bR, 32·Nw) int32 spins ±1."""
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)
    flat = bits.reshape(words.shape[0], -1)
    return jnp.where(flat == 1, 1, -1).astype(jnp.int32)


def _pack_bits(bits: jnp.ndarray) -> jnp.ndarray:
    """Kernel-side codec: (bR, N) bool sign bits → (bR, N/32) u32 words."""
    b = bits.astype(jnp.uint32).reshape(bits.shape[0], -1, 32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(b << shifts, axis=-1, dtype=jnp.uint32)


def _plateau_popcount_kernel(
    *refs,
    # i0_ref,    # (1, C)   int32   per-cycle I0 schedule (whole chain)
    # [jperp_ref]  (1, C)  int32   per-cycle J⊥ — ONLY when n_replicas > 0
    # fold_ref,  # (1, C+1) int32   per-state storage write-enable
    # mp_ref,    # (1, bR, Nwp) uint32  spins, packed sign bits
    # it_ref,    # (1, bR, Np)  int32   Itanh state
    # sign_ref,  # (1, Np, Nwp) uint32  packed-J sign plane of THIS problem
    # mags_ref,  # (1, nb, Np, Nwp) uint32  packed-J magnitude bitplanes
    # base_ref,  # (1, 1, Np)  int32   −Σ_b 2^b·deg_b (PackedJ.base)
    # h_ref,     # (1, 1, Np)  int32   biases
    # rng_ref,   # (1, 4, bR, Np) uint32 xorshift128 lanes (carried)
    # bh_ref,    # (1, bR, 1)  int32   running best energy (input)
    # bmp_ref,   # (1, bR, Nwp) uint32 running best spins, packed (input)
    # mp_out,    # (1, bR, Nwp) uint32
    # it_out,    # (1, bR, Np)  int32
    # rng_out,   # (1, 4, bR, Np) uint32
    # bh_out,    # (1, bR, 1)  int32
    # bmp_out,   # (1, bR, Nwp) uint32
    # mw_s,      # scratch (bR, Nwp) uint32  packed current spins
    # m_s,       # scratch (bR, Np) int32    ±1 current spins (energy dots)
    # it_s,      # scratch (bR, Np) int32
    # rng_s,     # scratch (4, bR, Np) uint32
    # bh_s,      # scratch (bR, 1) int32
    # bmw_s,     # scratch (bR, Nwp) uint32  packed best spins
    # [ring_s]   # scratch (2, bR, Np) int32 — ONLY when n_replicas > 0
    n_cycles: int,
    n_rnd: int,
    field_tile: int,
    n_replicas: int = 0,
):
    """A whole plateau *chain* with the field computed on bitplanes.

    Two departures from the streamed kernel above:

    * The contraction is XNOR-popcount against the resident packed-J planes
      — `field = h + base + Σ_b 2^{b+1}·popcount(XNOR(m, sign) & mag_b)` —
      entirely uint32/int32; there is no f32 value (and no MXU op) in this
      body.  Best spins are tracked *packed* (one uint32 select per word).
    * The launch covers C cycles spanning several plateaus: ``i0_ref`` holds
      the per-cycle I0 and ``fold_ref[c]`` the storage write-enable of the
      plateau that *produced* the state current at cycle c (fold[0] = 0 —
      the chain's incoming state belongs to the previous chunk; fold[C]
      covers the final state, folded in the epilogue).  Bit-identical to
      chaining one launch per plateau, minus the per-boundary re-dispatch
      and duplicate field evaluation.

    ``n_replicas > 0`` is the SSQA chain mode (DESIGN.md §13): the R-tile
    is one Trotter ring and a per-cycle ``jperp_ref`` schedule adds the
    nearest-replica coupling to the update field.  The replica planes are
    **double-buffered** through a two-plane ``ring_s`` scratch (the
    dual-BRAM layout of arXiv:2602.16143): cycle c reads plane c%2 and
    writes the updated spins to plane (c+1)%2, so the coupling always sees
    the coherent previous-cycle ring while the new one streams in.
    """
    if n_replicas:
        (i0_ref, jperp_ref, fold_ref, mp_ref, it_ref, sign_ref, mags_ref,
         base_ref, h_ref, rng_ref, bh_ref, bmp_ref,
         mp_out, it_out, rng_out, bh_out, bmp_out,
         mw_s, m_s, it_s, rng_s, bh_s, bmw_s, ring_s) = refs
    else:
        (i0_ref, fold_ref, mp_ref, it_ref, sign_ref, mags_ref,
         base_ref, h_ref, rng_ref, bh_ref, bmp_ref,
         mp_out, it_out, rng_out, bh_out, bmp_out,
         mw_s, m_s, it_s, rng_s, bh_s, bmw_s) = refs
    mw_s[...] = mp_ref[0]
    m_s[...] = _unpack_pm1_i32(mp_ref[0])
    it_s[...] = it_ref[0]
    rng_s[...] = rng_ref[0]
    bh_s[...] = bh_ref[0]
    bmw_s[...] = bmp_ref[0]
    if n_replicas:
        ring_s[0] = m_s[...]
        ring_s[1] = m_s[...]
    sg = sign_ref[0]          # (Np, Nwp)
    mg = mags_ref[0]          # (nb, Np, Nwp)
    hf = h_ref[0]             # (1, Np) int32
    hb = hf + base_ref[0]     # field constant: h + base
    nsg = ~sg                 # XNOR(a, b) = a ^ ~b
    nb = mg.shape[0]
    n_pad = sg.shape[0]
    br = mw_s.shape[0]
    nt = n_pad // field_tile
    one = jnp.uint32(1)

    def field_of(mw):
        """(bR, Nwp) packed spins → (bR, Np) int32 fields, row-tiled."""

        def tile_body(t, acc):
            off = t * field_tile
            st = jax.lax.dynamic_slice_in_dim(nsg, off, field_tile, axis=0)
            xs = mw[:, None, :] ^ st[None]       # (bR, tile, Nwp) XNOR words
            f = jnp.zeros((br, field_tile), jnp.int32)
            for b in range(nb):
                mt = jax.lax.dynamic_slice_in_dim(
                    mg[b], off, field_tile, axis=0
                )
                pc = jnp.sum(
                    jax.lax.population_count(xs & mt[None]).astype(jnp.int32),
                    axis=-1,
                )
                f = f + (pc << (b + 1))
            return jax.lax.dynamic_update_slice_in_dim(acc, f, off, axis=1)

        acc = jax.lax.fori_loop(
            0, nt, tile_body, jnp.zeros((br, n_pad), jnp.int32)
        )
        return acc + hb

    def track_best(fold, field):
        # H = -(h·m + m·field)/2, exact int32 (the sum is always even).
        hm = jnp.sum(hf * m_s[...], axis=-1, keepdims=True)
        mf_ = jnp.sum(m_s[...] * field, axis=-1, keepdims=True)
        H = -(hm + mf_) // 2
        better = (fold > 0) & (H < bh_s[...])
        bh_s[...] = jnp.where(better, H, bh_s[...])
        bmw_s[...] = jnp.where(better, mw_s[...], bmw_s[...])

    def body(c, _):
        field = field_of(mw_s[...])
        # m_s holds the state current at cycle c; fold_ref[c] is the
        # write-enable of the plateau that produced it (0 at c == 0).
        track_best(fold_ref[0, c], field)

        x, y, z, w = rng_s[0], rng_s[1], rng_s[2], rng_s[3]
        t = x ^ (x << jnp.uint32(11))
        w_new = (w ^ (w >> jnp.uint32(19))) ^ (t ^ (t >> jnp.uint32(8)))
        rng_s[0] = y
        rng_s[1] = z
        rng_s[2] = w
        rng_s[3] = w_new
        r = jnp.where((w_new >> jnp.uint32(31)) & one, 1, -1).astype(jnp.int32)

        i0 = i0_ref[0, c]
        upd = field
        if n_replicas:
            # Double-buffered replica planes: read the coherent ring of the
            # cycle parity, write the updated plane to the other buffer.
            even = (c % 2) == 0
            ring = jnp.where(even, ring_s[0], ring_s[1])
            coup = jnp.roll(ring, 1, axis=0) + jnp.roll(ring, -1, axis=0)
            upd = field + jperp_ref[0, c] * coup
        I = upd + n_rnd * r + it_s[...]  # noqa: E741 — Eq. (2a)
        it_new = jnp.clip(I, -i0, i0 - 1)
        it_s[...] = it_new
        bits = it_new >= 0
        m_new = jnp.where(bits, 1, -1).astype(jnp.int32)
        m_s[...] = m_new
        mw_s[...] = _pack_bits(bits)
        if n_replicas:

            @pl.when(even)
            def _wr_odd():
                ring_s[1] = m_new

            @pl.when(~even)
            def _wr_even():
                ring_s[0] = m_new

        return 0

    jax.lax.fori_loop(0, n_cycles, body, 0)
    # Final state of the chain: one epilogue field for its energy.
    field = field_of(mw_s[...])
    track_best(fold_ref[0, n_cycles], field)

    mp_out[...] = mw_s[...][None]
    it_out[...] = it_s[...][None]
    rng_out[...] = rng_s[...][None]
    bh_out[...] = bh_s[...][None]
    bmp_out[...] = bmw_s[...][None]


@functools.partial(
    jax.jit,
    static_argnames=(
        "n_rnd", "block_r", "field_tile", "interpret", "n_replicas"
    ),
)
def ssa_plateau_popcount_batched(
    m_packed: jnp.ndarray,   # (B, R, Nw) uint32 packed ±1 spins
    itanh: jnp.ndarray,      # (B, R, N) int32
    sign: jnp.ndarray,       # (B, N, Nw) uint32 packed-J sign plane
    mags: jnp.ndarray,       # (B, nb, N, Nw) uint32 packed-J magnitude planes
    base: jnp.ndarray,       # (B, N) int32 PackedJ.base (−Σ 2^b·deg_b)
    h: jnp.ndarray,          # (B, N) int32
    rng: jnp.ndarray,        # (B, 4, R, N) uint32 xorshift lanes (carried)
    i0_sched: jnp.ndarray,   # (C,) int32 per-cycle I0 over the whole chain
    fold_sched: jnp.ndarray,  # (C+1,) int32 per-state fold mask
    best_H: jnp.ndarray,     # (B, R) int32
    best_m_packed: jnp.ndarray,  # (B, R, Nw) uint32
    *,
    n_rnd: int = 2,
    block_r: int = 8,
    field_tile: int = 128,
    interpret: Optional[bool] = None,
    jperp_sched: Optional[jnp.ndarray] = None,  # (C,) int32 per-cycle J⊥
    n_replicas: int = 0,     # 0 = classical; >0 = SSQA Trotter-ring mode
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Bit-parallel resident chain for B stacked problems (multi-plateau).

    Runs ``C = len(i0_sched)`` cycles — typically a full iteration's plateau
    chain — in ONE `pallas_call`, with the coupling matrix resident as
    packed bitplanes (`PackedJ` layout: ~n_bits·N²/32 words instead of N²
    floats) and the field contraction done by XNOR-popcount.  Schedule
    operands come from :func:`repro.core.engine.plateau_cycle_schedules`.
    Bit-identical to running the same chain plateau-by-plateau through any
    other backend (property-tested in tests/test_popcount.py).

    Returns (m_packed, itanh, rng, best_H, best_m_packed) after the chain.
    """
    interpret = DEFAULT_INTERPRET if interpret is None else interpret
    B, R, N = itanh.shape
    C = i0_sched.shape[0]
    if jperp_sched is None:
        # Classical chain: no coupling operand, no ring scratch — the exact
        # pre-SSQA jaxpr (asserted in tests/test_popcount.py).
        n_replicas = 0
    elif n_replicas:
        if block_r != n_replicas:
            raise ValueError(
                f"SSQA needs block_r == n_replicas (one Trotter ring per "
                f"R-tile), got block_r={block_r}, n_replicas={n_replicas}"
            )
        if R % n_replicas:
            raise ValueError(
                f"n_trials={R} not divisible by n_replicas={n_replicas}"
            )
    else:
        raise ValueError("jperp_sched given but n_replicas == 0")
    nb = mags.shape[1]
    LANE = 128
    Np = N + (-N) % LANE
    Nwp = Np // 32
    if Np % field_tile:
        raise ValueError(
            f"field_tile {field_tile} must divide padded width {Np}"
        )
    mp = pad_to(pad_to(m_packed, 2, Nwp), 1, block_r)
    bmp = pad_to(pad_to(best_m_packed, 2, Nwp), 1, block_r)
    itp = pad_to(pad_to(itanh, 2, LANE), 1, block_r)
    # Padded J rows/words are zero in every plane: pad columns contribute 0
    # to every field regardless of the spin words' tail-bit garbage.
    signp = pad_to(pad_to(sign, 1, LANE), 2, Nwp)
    magsp = pad_to(pad_to(mags, 2, LANE), 3, Nwp)
    basep = pad_to(base.astype(jnp.int32).reshape(B, 1, -1), 2, LANE)
    hp = pad_to(h.astype(jnp.int32).reshape(B, 1, -1), 2, LANE)
    rngp = pad_to(pad_to(rng, 3, LANE), 2, block_r)
    bhp = pad_to(best_H.reshape(B, -1, 1), 1, block_r)
    Rp = itp.shape[1]
    grid = (B, Rp // block_r)
    i0a = jnp.asarray(i0_sched, jnp.int32).reshape(1, C)
    folda = jnp.asarray(fold_sched, jnp.int32).reshape(1, C + 1)

    kernel = functools.partial(
        _plateau_popcount_kernel, n_cycles=C, n_rnd=n_rnd,
        field_tile=field_tile, n_replicas=n_replicas,
    )
    jperp_specs, jperp_args, ring_scratch = [], [], []
    if n_replicas:
        jperp_specs = [pl.BlockSpec((1, C), lambda b, i: (0, 0))]
        jperp_args = [jnp.asarray(jperp_sched, jnp.int32).reshape(1, C)]
        ring_scratch = [pltpu.VMEM((2, block_r, Np), jnp.int32)]
    mp_o, it_o, rng_o, bh_o, bmp_o = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, C), lambda b, i: (0, 0)),
            *jperp_specs,
            pl.BlockSpec((1, C + 1), lambda b, i: (0, 0)),
            pl.BlockSpec((1, block_r, Nwp), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, block_r, Np), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, Np, Nwp), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, nb, Np, Nwp), lambda b, i: (b, 0, 0, 0)),
            pl.BlockSpec((1, 1, Np), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, 1, Np), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, 4, block_r, Np), lambda b, i: (b, 0, i, 0)),
            pl.BlockSpec((1, block_r, 1), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, block_r, Nwp), lambda b, i: (b, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_r, Nwp), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, block_r, Np), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, 4, block_r, Np), lambda b, i: (b, 0, i, 0)),
            pl.BlockSpec((1, block_r, 1), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, block_r, Nwp), lambda b, i: (b, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Rp, Nwp), jnp.uint32),
            jax.ShapeDtypeStruct((B, Rp, Np), jnp.int32),
            jax.ShapeDtypeStruct((B, 4, Rp, Np), jnp.uint32),
            jax.ShapeDtypeStruct((B, Rp, 1), jnp.int32),
            jax.ShapeDtypeStruct((B, Rp, Nwp), jnp.uint32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_r, Nwp), jnp.uint32),
            pltpu.VMEM((block_r, Np), jnp.int32),
            pltpu.VMEM((block_r, Np), jnp.int32),
            pltpu.VMEM((4, block_r, Np), jnp.uint32),
            pltpu.VMEM((block_r, 1), jnp.int32),
            pltpu.VMEM((block_r, Nwp), jnp.uint32),
            *ring_scratch,
        ],
        interpret=interpret,
    )(i0a, *jperp_args, folda, mp, itp, signp, magsp, basep, hp, rngp, bhp, bmp)
    nw = (N + 31) // 32
    return (
        mp_o[:, :R, :nw],
        it_o[:, :R, :N],
        rng_o[:, :, :R, :N],
        bh_o[:, :R, 0],
        bmp_o[:, :R, :nw],
    )


def ssa_plateau_popcount(
    m_packed: jnp.ndarray,   # (R, Nw) uint32
    itanh: jnp.ndarray,      # (R, N) int32
    sign: jnp.ndarray,       # (N, Nw) uint32
    mags: jnp.ndarray,       # (nb, N, Nw) uint32
    base: jnp.ndarray,       # (N,) int32
    h: jnp.ndarray,          # (N,) int32
    rng: jnp.ndarray,        # (4, R, N) uint32
    i0_sched: jnp.ndarray,   # (C,) int32
    fold_sched: jnp.ndarray,  # (C+1,) int32
    best_H: jnp.ndarray,     # (R,) int32
    best_m_packed: jnp.ndarray,  # (R, Nw) uint32
    *,
    n_rnd: int = 2,
    block_r: int = 8,
    field_tile: int = 128,
    interpret: Optional[bool] = None,
    jperp_sched: Optional[jnp.ndarray] = None,
    n_replicas: int = 0,
):
    """B=1 slice of :func:`ssa_plateau_popcount_batched` (one kernel body)."""
    mp, it, rs, bh, bmp = ssa_plateau_popcount_batched(
        m_packed[None],
        itanh[None],
        sign[None],
        mags[None],
        base[None],
        h[None],
        rng[None],
        i0_sched,
        fold_sched,
        best_H[None],
        best_m_packed[None],
        n_rnd=n_rnd,
        block_r=block_r,
        field_tile=field_tile,
        interpret=interpret,
        jperp_sched=jperp_sched,
        n_replicas=n_replicas,
    )
    return mp[0], it[0], rs[0], bh[0], bmp[0]
