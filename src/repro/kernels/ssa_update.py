"""Pallas TPU kernels for the SSA/HA-SSA spin update (DESIGN.md §2.3).

The FPGA's spin-gate array computes, for all spins in one clock,

    field_i = h_i + Σ_j J_ij m_j        (MUX tree + adder)
    Itanh   = clamp(field + n·r + Itanh, -I0, I0-1)   (saturating counter)
    m       = sign(Itanh)

On TPU we batch replicas (trials) on a leading axis so the field computation
is a (R,N)·(N,N) matmul on the MXU; the FSM is a fused VPU epilogue.  Two
kernels:

* :func:`local_field_kernel` — tiled matmul ``m @ J + h`` with a standard
  (R-tile, N-tile, K-tile) grid and a float32 VMEM accumulator.  Used as the
  drop-in dense-field backend.  Exact: ±1 spins × integer J accumulate in
  f32 (< 2^24).

* :func:`ssa_plateau_kernel` — the **resident** kernel: one launch executes
  all C cycles of a temperature plateau with J pinned in VMEM, streaming only
  noise in and nothing but final state + running best out.  This is the
  TPU answer to the FPGA's "everything on-chip" design point: per-cycle HBM
  traffic drops from O(N²) (re-reading J) to O(R·N) (noise), raising
  arithmetic intensity by ~C×.  It also fuses the solution tracking (energy
  + arg-best restricted to storage-eligible plateaus), which is HA-SSA's
  storage policy executed entirely on-chip.

Both are validated against :mod:`.ref` in interpret mode (CPU) over a
shape/dtype sweep; TPU is the compile target.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "local_field",
    "ssa_plateau",
    "ssa_plateau_batched",
    "pad_to",
    "DEFAULT_INTERPRET",
]

# interpret=True executes the kernel body in Python on CPU — the validation
# mode for this container; on TPU hosts the same code lowers to Mosaic.
DEFAULT_INTERPRET = jax.default_backend() == "cpu"


def pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    """Zero-pad ``axis`` up to a multiple of ``mult`` (TPU lane alignment)."""
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads)


# ---------------------------------------------------------------------------
# Kernel A: tiled local-field matmul  field = m @ J + h
# ---------------------------------------------------------------------------
def _field_kernel(m_ref, j_ref, h_ref, out_ref, acc_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        m_ref[...], j_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        out_ref[...] = (acc_ref[...] + h_ref[...].astype(jnp.float32)).astype(
            jnp.int32
        )


@functools.partial(
    jax.jit, static_argnames=("block_r", "block_n", "block_k", "interpret")
)
def local_field(
    m: jnp.ndarray,  # (R, N) ±1, any float/int dtype
    h: jnp.ndarray,  # (N,) int32
    J: jnp.ndarray,  # (N, N) float32/bfloat16 (integer-valued)
    *,
    block_r: int = 8,
    block_n: int = 128,
    block_k: int = 128,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """field = h + m @ J, int32 exact, via the tiled Pallas kernel."""
    interpret = DEFAULT_INTERPRET if interpret is None else interpret
    R, N = m.shape
    mf = pad_to(pad_to(m.astype(J.dtype), 1, block_k), 0, block_r)
    Jp = pad_to(pad_to(J, 0, block_k), 1, block_n)
    hp = pad_to(h.astype(jnp.int32).reshape(1, -1), 1, block_n)
    Rp, Kp = mf.shape
    Np = Jp.shape[1]
    nk = Kp // block_k
    grid = (Rp // block_r, Np // block_n, nk)
    out = pl.pallas_call(
        functools.partial(_field_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_r, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, block_n), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_r, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Rp, Np), jnp.int32),
        scratch_shapes=[pltpu.VMEM((block_r, block_n), jnp.float32)],
        interpret=interpret,
    )(mf, Jp, hp)
    return out[:R, :N]


# ---------------------------------------------------------------------------
# Kernel B: resident plateau kernel — C fused cycles, J pinned in VMEM
# ---------------------------------------------------------------------------
def _plateau_kernel(
    i0_ref,      # (1, 1) int32 SMEM-ish scalar
    m_ref,       # (1, bR, N) float32  spins ±1 (leading problem-block axis)
    it_ref,      # (1, bR, N) int32    Itanh state
    j_ref,       # (1, N, N)  J dtype  resident couplings of THIS problem
    h_ref,       # (1, 1, N)  int32    biases
    noise_ref,   # (1, C, bR, N) int8  per-cycle ±1 noise
    bh_ref,      # (1, bR, 1) int32    running best energy (input)
    bm_ref,      # (1, bR, N) int8     running best spins  (input)
    m_out,       # (1, bR, N) float32
    it_out,      # (1, bR, N) int32
    bh_out,      # (1, bR, 1) int32
    bm_out,      # (1, bR, N) int8
    m_s,         # scratch (bR, N) float32
    it_s,        # scratch (bR, N) int32
    bh_s,        # scratch (bR, 1) float32 (exact ints)
    bm_s,        # scratch (bR, N) float32 (±1)
    *,
    n_cycles: int,
    n_rnd: int,
    eligible: bool,
):
    m_s[...] = m_ref[0]
    it_s[...] = it_ref[0]
    bh_s[...] = bh_ref[0].astype(jnp.float32)
    bm_s[...] = bm_ref[0].astype(jnp.float32)
    i0 = i0_ref[0, 0]
    hf = h_ref[0].astype(jnp.float32)  # (1, N)
    jm = j_ref[0]

    def energy(m, field):
        # H = -(h·m + m·field)/2 ; exact in f32 for |field| < 2^24
        hm = jnp.sum(hf * m, axis=-1, keepdims=True)
        mf_ = jnp.sum(m * field, axis=-1, keepdims=True)
        return -(hm + mf_) * 0.5

    def track_best(c, m, field):
        if not eligible:
            return
        H = energy(m, field)
        better = H < bh_s[...]
        bh_s[...] = jnp.where(better, H, bh_s[...])
        bm_s[...] = jnp.where(better, m, bm_s[...])

    def body(c, _):
        field = (
            jnp.dot(m_s[...], jm, preferred_element_type=jnp.float32) + hf
        )
        # m_s currently holds m(t0+c): produced by THIS plateau for c >= 1.
        @pl.when(c >= 1)
        def _():
            track_best(c, m_s[...], field)

        r = noise_ref[0, c].astype(jnp.int32)
        I = field.astype(jnp.int32) + n_rnd * r + it_s[...]
        it_new = jnp.clip(I, -i0, i0 - 1)
        it_s[...] = it_new
        m_s[...] = jnp.where(it_new >= 0, 1.0, -1.0).astype(jnp.float32)
        return 0

    jax.lax.fori_loop(0, n_cycles, body, 0)
    # final state m(t0+C): one more field evaluation for its energy
    field = jnp.dot(m_s[...], jm, preferred_element_type=jnp.float32) + hf
    track_best(n_cycles, m_s[...], field)

    m_out[...] = m_s[...][None]
    it_out[...] = it_s[...][None]
    bh_out[...] = bh_s[...].astype(jnp.int32)[None]
    bm_out[...] = bm_s[...].astype(jnp.int8)[None]


@functools.partial(
    jax.jit,
    static_argnames=("n_rnd", "eligible", "block_r", "interpret"),
)
def ssa_plateau_batched(
    m: jnp.ndarray,       # (B, R, N) float32 ±1
    itanh: jnp.ndarray,   # (B, R, N) int32
    J: jnp.ndarray,       # (B, N, N) float32/bfloat16 — one J per problem
    h: jnp.ndarray,       # (B, N) int32
    noise: jnp.ndarray,   # (B, C, R, N) int8 ±1
    i0: jnp.ndarray,      # scalar int32 (shared: same schedule per bucket)
    best_H: jnp.ndarray,  # (B, R) int32
    best_m: jnp.ndarray,  # (B, R, N) int8
    *,
    n_rnd: int = 2,
    eligible: bool = True,
    block_r: int = 8,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Run one constant-I0 plateau for B stacked problems fully on-chip.

    The grid is (B, R-tiles): grid step (b, i) pins problem b's J in VMEM
    and runs all C cycles for one R-tile of trials — one launch serves a
    whole shape bucket of heterogeneous instances (the serving layer's
    batched hot path).  Per-problem semantics are identical to the B=1
    kernel; :func:`ssa_plateau` is exactly this with B=1.
    """
    interpret = DEFAULT_INTERPRET if interpret is None else interpret
    B, R, N = m.shape
    C = noise.shape[1]
    LANE = 128
    mf = pad_to(pad_to(m.astype(jnp.float32), 2, LANE), 1, block_r)
    itp = pad_to(pad_to(itanh, 2, LANE), 1, block_r)
    Jp = pad_to(pad_to(J, 1, LANE), 2, LANE)
    hp = pad_to(h.astype(jnp.int32).reshape(B, 1, -1), 2, LANE)
    np_ = pad_to(pad_to(noise, 3, LANE), 2, block_r)
    bhp = pad_to(best_H.reshape(B, -1, 1), 1, block_r)
    bmp = pad_to(pad_to(best_m, 2, LANE), 1, block_r)
    _, Rp, Np = mf.shape
    grid = (B, Rp // block_r)
    i0a = jnp.asarray(i0, jnp.int32).reshape(1, 1)

    kernel = functools.partial(
        _plateau_kernel, n_cycles=C, n_rnd=n_rnd, eligible=eligible
    )
    m_o, it_o, bh_o, bm_o = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, i: (0, 0)),
            pl.BlockSpec((1, block_r, Np), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, block_r, Np), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, Np, Np), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, 1, Np), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, C, block_r, Np), lambda b, i: (b, 0, i, 0)),
            pl.BlockSpec((1, block_r, 1), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, block_r, Np), lambda b, i: (b, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_r, Np), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, block_r, Np), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, block_r, 1), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, block_r, Np), lambda b, i: (b, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Rp, Np), jnp.float32),
            jax.ShapeDtypeStruct((B, Rp, Np), jnp.int32),
            jax.ShapeDtypeStruct((B, Rp, 1), jnp.int32),
            jax.ShapeDtypeStruct((B, Rp, Np), jnp.int8),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_r, Np), jnp.float32),
            pltpu.VMEM((block_r, Np), jnp.int32),
            pltpu.VMEM((block_r, 1), jnp.float32),
            pltpu.VMEM((block_r, Np), jnp.float32),
        ],
        interpret=interpret,
    )(i0a, mf, itp, Jp.astype(J.dtype), hp, np_, bhp, bmp)
    return (
        m_o[:, :R, :N],
        it_o[:, :R, :N],
        bh_o[:, :R, 0],
        bm_o[:, :R, :N],
    )


@functools.partial(
    jax.jit,
    static_argnames=("n_rnd", "eligible", "block_r", "interpret"),
)
def ssa_plateau(
    m: jnp.ndarray,       # (R, N) float32 ±1
    itanh: jnp.ndarray,   # (R, N) int32
    J: jnp.ndarray,       # (N, N) float32/bfloat16
    h: jnp.ndarray,       # (N,) int32
    noise: jnp.ndarray,   # (C, R, N) int8 ±1
    i0: jnp.ndarray,      # scalar int32
    best_H: jnp.ndarray,  # (R,) int32
    best_m: jnp.ndarray,  # (R, N) int8
    *,
    n_rnd: int = 2,
    eligible: bool = True,
    block_r: int = 8,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Run one constant-I0 plateau of C cycles fully on-chip.

    Returns (m, itanh, best_H, best_m) after the plateau.  ``eligible``
    implements HA-SSA's storage policy: only plateaus with I0 == I0max
    update the running best (Eq. 6); passing eligible=True for every plateau
    recovers conventional SSA's policy (Eq. 5).  This is the B=1 slice of
    :func:`ssa_plateau_batched` (one kernel body serves both).
    """
    m_o, it_o, bh_o, bm_o = ssa_plateau_batched(
        m[None],
        itanh[None],
        J[None],
        h[None],
        noise[None],
        i0,
        best_H[None],
        best_m[None],
        n_rnd=n_rnd,
        eligible=eligible,
        block_r=block_r,
        interpret=interpret,
    )
    return m_o[0], it_o[0], bh_o[0], bm_o[0]
