"""Jit'd public wrappers over the Pallas kernels.

``repro.core.ssa`` consumes :func:`local_field` for its ``backend='pallas'``
dense path; :func:`anneal_resident` is the fully-fused HA-SSA production
path (J pinned in VMEM, storage policy on-chip) used by the TPU launcher and
the perf benchmarks.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.rng import xorshift_init, xorshift_next_bits
from repro.core.schedule import Schedule

from . import ssa_update

__all__ = ["local_field", "anneal_resident"]


def local_field(m: jnp.ndarray, h: jnp.ndarray, J: jnp.ndarray) -> jnp.ndarray:
    """Drop-in dense field backend for repro.core.ssa (int32 result)."""
    return ssa_update.local_field(m, h, J)


def anneal_resident(
    J: jnp.ndarray,        # (N, N) couplings (float32/bfloat16, integer-valued)
    h: jnp.ndarray,        # (N,) int32
    schedule: Schedule,    # per-iteration plateau schedule
    m_shot: int,
    n_trials: int,
    *,
    n_rnd: int = 2,
    storage: str = "i0max",  # 'i0max' (HA-SSA) | 'all' (SSA)
    seed: int = 0,
    block_r: int = 8,
    interpret: Optional[bool] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Run a full HA-SSA schedule through the resident plateau kernel.

    Returns (best_H (T,), best_m (T, N)).  Host-side python drives the
    plateau sequence (m_shot × steps kernel launches); all cycle-level work
    is on-chip.
    """
    N = J.shape[0]
    plateaus = np.unique(schedule.i0_per_cycle)  # ascending
    i0_values = np.sort(plateaus)
    tau = schedule.tau
    i0_max = int(i0_values[-1])

    state = xorshift_init(seed, (n_trials, N))
    state, r0 = xorshift_next_bits(state)
    m = r0.astype(jnp.float32)
    itanh = jnp.where(m > 0, 0, -1).astype(jnp.int32)
    best_H = jnp.full((n_trials,), 2**30, jnp.int32)
    best_m = m.astype(jnp.int8)

    def make_noise(state, c):
        outs = []
        for _ in range(c):
            state, r = xorshift_next_bits(state)
            outs.append(r.astype(jnp.int8))
        return state, jnp.stack(outs)

    for _ in range(m_shot):
        for i0 in i0_values:
            eligible = storage == "all" or int(i0) == i0_max
            state, noise = make_noise(state, tau)
            m, itanh, best_H, best_m = ssa_update.ssa_plateau(
                m,
                itanh,
                J,
                h,
                noise,
                jnp.int32(int(i0)),
                best_H,
                best_m,
                n_rnd=n_rnd,
                eligible=eligible,
                block_r=block_r,
                interpret=interpret,
            )
    return np.asarray(best_H), np.asarray(best_m)
