"""Pure-jnp oracles for the Pallas kernels (the correctness contract)."""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

__all__ = ["local_field_ref", "ssa_plateau_ref"]


def local_field_ref(m: jnp.ndarray, h: jnp.ndarray, J: jnp.ndarray) -> jnp.ndarray:
    """field = h + m @ J, int32 exact."""
    acc = jnp.dot(m.astype(jnp.float32), J.astype(jnp.float32))
    return (acc + h.astype(jnp.float32)).astype(jnp.int32)


def ssa_plateau_ref(
    m: jnp.ndarray,       # (R, N) float32 ±1
    itanh: jnp.ndarray,   # (R, N) int32
    J: jnp.ndarray,       # (N, N)
    h: jnp.ndarray,       # (N,)
    noise: jnp.ndarray,   # (C, R, N) int8
    i0,                   # scalar int32
    best_H: jnp.ndarray,  # (R,) int32
    best_m: jnp.ndarray,  # (R, N) int8
    *,
    n_rnd: int = 2,
    eligible: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Reference semantics of the resident plateau kernel.

    Runs C cycles of Eq. (2a-2c) at constant I0 and — when ``eligible`` —
    folds every state *produced by this plateau* (m(t0+1..t0+C)) into the
    running (best_H, best_m).
    """
    C = noise.shape[0]
    i0 = jnp.asarray(i0, jnp.int32)
    hf = h.astype(jnp.int32)
    best_H = best_H.astype(jnp.int32)
    best_m = best_m.astype(jnp.int8)
    m = m.astype(jnp.float32)

    def energy(mm, field):
        m32 = mm.astype(jnp.int32)
        return -(jnp.sum(hf * m32, axis=-1) + jnp.sum(m32 * field, axis=-1)) // 2

    for c in range(C):
        field = local_field_ref(m, hf, J)
        if c >= 1 and eligible:
            H = energy(m, field)
            better = H < best_H
            best_H = jnp.where(better, H, best_H)
            best_m = jnp.where(better[:, None], m.astype(jnp.int8), best_m)
        I = field + n_rnd * noise[c].astype(jnp.int32) + itanh  # noqa: E741
        itanh = jnp.clip(I, -i0, i0 - 1)
        m = jnp.where(itanh >= 0, 1.0, -1.0)

    field = local_field_ref(m, hf, J)
    if eligible:
        H = energy(m, field)
        better = H < best_H
        best_H = jnp.where(better, H, best_H)
        best_m = jnp.where(better[:, None], m.astype(jnp.int8), best_m)
    return m, itanh, best_H, best_m
