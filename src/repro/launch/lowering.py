"""Shared step-function lowering builders for the dry-run and benchmarks.

No jax device-state side effects at import — dryrun.py sets XLA_FLAGS before
importing this.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import shapes as shp
from repro.models import cache_defs, model_defs
from repro.models.params import ParamDef, param_pspecs, param_shapes, tree_defs_map
from repro.optim.adamw import OptState, zero1_spec
from repro.serve.lm import make_decode_step, make_prefill_step
from repro.sharding import DEFAULT_RULES, ShardingRules, logical_to_spec
from repro.train.step import TrainConfig, TrainState, make_train_step

__all__ = [
    "count_params",
    "batch_shardings",
    "train_lowering",
    "prefill_lowering",
    "decode_lowering",
    "cell_lowering",
]


# ---------------------------------------------------------------------------
# Parameter counting (MODEL_FLOPS)
# ---------------------------------------------------------------------------
def count_params(cfg) -> Tuple[int, int]:
    """(total, active) parameter counts.  Active discounts expert weights by
    top_k/n_experts (MoE) — used for MODEL_FLOPS = 6·N_active·D."""
    defs = model_defs(cfg)
    total = 0
    active = 0
    for path, d in jax.tree_util.tree_flatten_with_path(
        defs, is_leaf=lambda x: isinstance(x, ParamDef)
    )[0]:
        n = int(np.prod(d.shape))
        total += n
        keys = [str(getattr(p, "key", "")) for p in path]
        is_expert = (
            cfg.n_experts > 0
            and "ffn" in keys
            and cfg.n_experts in d.shape
            and "router" not in keys
        )
        if is_expert:
            active += int(n * cfg.top_k / cfg.n_experts)
        else:
            active += n
    return total, active


# ---------------------------------------------------------------------------
# Shardings
# ---------------------------------------------------------------------------
def batch_shardings(mesh: Mesh, specs: Dict[str, jax.ShapeDtypeStruct],
                    rules: ShardingRules = DEFAULT_RULES):
    out = {}
    for k, v in specs.items():
        axes = ("batch",) + (None,) * (len(v.shape) - 1)
        out[k] = NamedSharding(mesh, logical_to_spec(mesh, v.shape, axes, rules))
    return out


def _opt_shardings(defs, mesh, rules, zero1: bool):
    pspecs = param_pspecs(defs, mesh, rules)

    def z1(d: ParamDef, spec):
        sp = zero1_spec(spec, d.shape, mesh) if zero1 else spec
        return NamedSharding(mesh, sp)

    moments = jax.tree_util.tree_map(
        z1, defs, pspecs, is_leaf=lambda x: isinstance(x, ParamDef)
    )
    return moments


def _param_shardings(defs, mesh, rules):
    return tree_defs_map(
        lambda d: NamedSharding(mesh, logical_to_spec(mesh, d.shape, d.axes, rules)),
        defs,
    )


def _replicated(mesh):
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# Lowering builders
# ---------------------------------------------------------------------------
def train_lowering(
    cfg,
    shape: shp.ShapeCell,
    mesh: Mesh,
    *,
    rules: ShardingRules = DEFAULT_RULES,
    train_cfg: Optional[TrainConfig] = None,
    donate: bool = True,
):
    """Lower train_step for (arch cfg × train shape × mesh).  No allocation."""
    train_cfg = train_cfg or TrainConfig()
    defs = model_defs(cfg)
    pshard = _param_shardings(defs, mesh, rules)
    mshard = _opt_shardings(defs, mesh, rules, train_cfg.opt.zero1)
    state_shapes = TrainState(
        params=param_shapes(defs),
        opt=OptState(
            step=jax.ShapeDtypeStruct((), jnp.int32),
            mu=tree_defs_map(
                lambda d: jax.ShapeDtypeStruct(d.shape, jnp.float32), defs
            ),
            nu=tree_defs_map(
                lambda d: jax.ShapeDtypeStruct(d.shape, jnp.float32), defs
            ),
        ),
    )
    state_shard = TrainState(
        params=pshard,
        opt=OptState(step=_replicated(mesh), mu=mshard, nu=mshard),
    )
    bspecs = shp.train_input_specs(cfg, shape)
    bshard = batch_shardings(mesh, bspecs, rules)
    pspecs = param_pspecs(defs, mesh, rules)
    step = make_train_step(cfg, train_cfg, mesh=mesh, rules=rules, param_specs=pspecs)
    jitted = jax.jit(
        step,
        in_shardings=(state_shard, bshard),
        donate_argnums=(0,) if donate else (),
    )
    with mesh:
        lowered = jitted.lower(state_shapes, bspecs)
    return lowered


def _cast_shapes(tree, dtype):
    if dtype is None:
        return tree
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype)
        if jnp.issubdtype(s.dtype, jnp.floating) else s,
        tree,
    )


def prefill_lowering(cfg, shape: shp.ShapeCell, mesh: Mesh, *,
                     rules: ShardingRules = DEFAULT_RULES, param_dtype=None):
    defs = model_defs(cfg)
    pshard = _param_shardings(defs, mesh, rules)
    bspecs = shp.prefill_input_specs(cfg, shape)
    bshard = batch_shardings(mesh, bspecs, rules)
    step = make_prefill_step(cfg, mesh=mesh, rules=rules, max_seq=shape.seq_len)
    jitted = jax.jit(step, in_shardings=(pshard, bshard))
    with mesh:
        lowered = jitted.lower(_cast_shapes(param_shapes(defs), param_dtype), bspecs)
    return lowered


def decode_lowering(cfg, shape: shp.ShapeCell, mesh: Mesh, *,
                    rules: ShardingRules = DEFAULT_RULES, donate: bool = True,
                    param_dtype=None):
    """serve_step: one new token against a KV cache of shape.seq_len.

    param_dtype=jnp.bfloat16 lowers the weight-stationary serving variant
    (half the parameter HBM traffic per token — §Perf)."""
    defs = model_defs(cfg)
    pshard = _param_shardings(defs, mesh, rules)
    cdefs = cache_defs(cfg, shape.global_batch, shape.seq_len)
    cshapes = {"decoder": param_shapes(cdefs)["decoder"]}
    cshard = {"decoder": _param_shardings(cdefs, mesh, rules)["decoder"]}
    dspecs = shp.decode_input_specs(cfg, shape)
    tok_shard = NamedSharding(
        mesh, logical_to_spec(mesh, dspecs["token"].shape, ("batch",), rules)
    )
    step = make_decode_step(cfg, mesh=mesh, rules=rules)
    jitted = jax.jit(
        step,
        in_shardings=(pshard, cshard, tok_shard, _replicated(mesh)),
        donate_argnums=(1,) if donate else (),
    )
    with mesh:
        lowered = jitted.lower(
            _cast_shapes(param_shapes(defs), param_dtype), cshapes,
            dspecs["token"], dspecs["pos"]
        )
    return lowered


def cell_lowering(cfg, shape: shp.ShapeCell, mesh: Mesh, *,
                  rules: ShardingRules = DEFAULT_RULES,
                  train_cfg: Optional[TrainConfig] = None,
                  param_dtype=None):
    if shape.kind == "train":
        return train_lowering(cfg, shape, mesh, rules=rules, train_cfg=train_cfg)
    if shape.kind == "prefill":
        return prefill_lowering(cfg, shape, mesh, rules=rules,
                                param_dtype=param_dtype)
    if shape.kind == "decode":
        return decode_lowering(cfg, shape, mesh, rules=rules,
                               param_dtype=param_dtype)
    raise ValueError(shape.kind)


# ---------------------------------------------------------------------------
# Analysis lowering: exact FLOPs/bytes/collectives despite XLA's
# count-loop-bodies-once cost model.
#
# XLA's HloCostAnalysis counts a while-loop body ONCE regardless of trip
# count, so the deployment lowering (lax.scan over layer groups + chunked
# attention/MoE/loss scans) undercounts FLOPs by ~n_groups × n_chunks.  The
# analysis lowering removes every scan: layers unrolled (scan_layers=False),
# attention/MoE/loss chunking widened to the full sequence, remat off — then
# compiles depth-1 and depth-2 variants and extrapolates linearly:
#
#     total(G) = f1 + (G - 1) · (f2 - f1)
#
# exact for homogeneous groups (per-group cost g = f2 - f1; overhead =
# embedding/loss/optimizer = f1 - g, which scales correctly because stacked
# params at depth G enter both f1 and f2 linearly).  Residual undercount:
# the sequential token scans inside Mamba/RWKV bodies (< 1–2 % of
# layer FLOPs for the assigned dims — documented in EXPERIMENTS.md).
# ---------------------------------------------------------------------------
def analysis_config(cfg, shape: shp.ShapeCell, depth_groups: int):
    S = shape.seq_len
    # moe_seq_chunk is NOT widened: capacity scales with the chunk, so a
    # wider chunk would change dropping semantics and inflate the dispatch
    # tensors ~(S/chunk)×; instead moe_ffn unrolls its chunk loop when
    # scan_layers=False.
    return dataclasses.replace(
        cfg,
        n_layers=len(cfg.block) * depth_groups,
        scan_layers=False,
        remat="none",
        q_chunk=S,
        kv_chunk=S,
    )


def _cost_numbers(cfg, shape, mesh, rules, train_cfg, param_dtype=None):
    lowered = cell_lowering(cfg, shape, mesh, rules=rules, train_cfg=train_cfg,
                            param_dtype=param_dtype)
    compiled = lowered.compile()
    from repro.launch import hlo_analysis as H

    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    coll = H.collective_bytes(compiled.as_text())
    return {
        "flops": float(ca.get("flops", 0.0)),
        "hbm_bytes": float(ca.get("bytes accessed", 0.0)),
        "coll_bytes": float(coll["total"]),
        "coll_breakdown": coll,
    }


def analysis_costs(cfg, shape: shp.ShapeCell, mesh: Mesh, *,
                   rules: ShardingRules = DEFAULT_RULES,
                   train_cfg: Optional[TrainConfig] = None,
                   param_dtype=None) -> Dict[str, Any]:
    """Extrapolated whole-model FLOPs / HBM bytes / collective bytes
    (per-device numbers, as cost_analysis reports for SPMD modules)."""
    if shape.kind == "train":
        train_cfg = dataclasses.replace(
            train_cfg or TrainConfig(),
            scan_microbatches=False, scan_loss_chunks=False,
        )
    G = cfg.n_groups
    c1 = _cost_numbers(analysis_config(cfg, shape, 1), shape, mesh, rules,
                       train_cfg, param_dtype)
    c2 = _cost_numbers(analysis_config(cfg, shape, 2), shape, mesh, rules,
                       train_cfg, param_dtype)
    out = {}
    for k in ("flops", "hbm_bytes", "coll_bytes"):
        per_group = c2[k] - c1[k]
        out[k] = c1[k] + (G - 1) * per_group
        out[f"{k}_g1"] = c1[k]
        out[f"{k}_per_group"] = per_group
    out["coll_breakdown"] = {
        k: c1["coll_breakdown"][k]
        + (G - 1) * (c2["coll_breakdown"][k] - c1["coll_breakdown"][k])
        for k in c1["coll_breakdown"]
    }
    return out
