"""Production mesh builders.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first init.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_shrunken_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_shrunken_mesh():
    """Elastic-degraded mesh (half a pod lost): 8×16 = 128 chips."""
    return jax.make_mesh((8, 16), ("data", "model"))
