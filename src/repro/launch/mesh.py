"""Mesh builders for launchers and the serving/annealing stack.

FUNCTIONS (not module-level constants) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first init.

None of the builders hard-code a device count: :func:`make_mesh` builds
any requested shape from however many devices actually exist (1 real chip,
a ``--xla_force_host_platform_device_count`` CPU fleet, a pod) and fails
with the actual-vs-requested counts when they don't match.  The historical
pod presets (:func:`make_production_mesh` / :func:`make_shrunken_mesh`)
are thin wrappers over it.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax

__all__ = [
    "parse_mesh_shape",
    "make_mesh",
    "make_spin_mesh",
    "make_production_mesh",
    "make_shrunken_mesh",
]


def parse_mesh_shape(spec: str) -> Tuple[int, ...]:
    """'8' → (8,); '2x16x16' → (2, 16, 16).  'x' or ',' separated."""
    parts = [p for p in spec.replace(",", "x").split("x") if p]
    if not parts:
        raise ValueError(f"empty mesh shape {spec!r}")
    try:
        shape = tuple(int(p) for p in parts)
    except ValueError:
        raise ValueError(f"bad mesh shape {spec!r}; want e.g. '8' or '2x16'")
    if any(d < 1 for d in shape):
        raise ValueError(f"mesh shape {spec!r} has non-positive dims")
    return shape


def make_mesh(shape: Sequence[int], axes: Sequence[str]):
    """A mesh of the requested shape over the devices that actually exist.

    Unlike a hard-coded ``jax.make_mesh((16, 16), ...)`` call, the error on
    a mismatch names both counts — the usual failure is launching a pod
    preset on a workstation (or forgetting XLA_FLAGS in a CPU run).
    """
    shape = tuple(int(d) for d in shape)
    if len(shape) != len(tuple(axes)):
        raise ValueError(f"mesh shape {shape} rank != axes {tuple(axes)}")
    need = 1
    for d in shape:
        need *= d
    have = len(jax.devices())
    if need > have:
        raise ValueError(
            f"mesh shape {shape} needs {need} devices but only {have} exist; "
            "shrink --mesh-shape or force more host devices with "
            "XLA_FLAGS=--xla_force_host_platform_device_count=N"
        )
    return jax.make_mesh(shape, tuple(axes))


def make_spin_mesh(spec: Optional[str] = None, *, axis: str = "model"):
    """1-D spin-sharding mesh from a ``--mesh-shape`` flag value.

    ``None``/'' takes every available device (the partition='spin' default);
    a spec must be 1-D — the annealer's spin axis shards over exactly one
    mesh axis (DESIGN.md §11).
    """
    from repro.sharding import spin_mesh

    if not spec:
        return spin_mesh(axis=axis)
    shape = parse_mesh_shape(spec)
    if len(shape) != 1:
        raise ValueError(
            f"--partition spin|auto wants a 1-D mesh, got shape {shape}"
        )
    return spin_mesh(shape[0], axis=axis)


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    if multi_pod:
        return make_mesh((2, 16, 16), ("pod", "data", "model"))
    return make_mesh((16, 16), ("data", "model"))


def make_shrunken_mesh():
    """Elastic-degraded mesh (half a pod lost): 8×16 = 128 chips."""
    return make_mesh((8, 16), ("data", "model"))
