"""Training launcher: ``--arch <id>`` selectable configs, mesh-aware pjit,
checkpoint/resume, optional HA-SSA expert placement for MoE archs.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --scale reduced \
        --steps 100 --batch 8 --seq 64 [--placement ssa]

On a real TPU cluster this launches under jax.distributed with the
production mesh (launch/mesh.py); on this CPU container the same code runs
the reduced configs on a trivial mesh.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.checkpoint.ckpt import CheckpointManager
from repro.configs import ARCH_NAMES, get_config
from repro.data.pipeline import DataConfig, synthetic_batch
from repro.ft.resilience import StragglerMonitor, run_training
from repro.models import model_defs
from repro.models.params import param_pspecs
from repro.optim.adamw import AdamWConfig
from repro.sharding import DEFAULT_RULES
from repro.train.step import TrainConfig, init_train_state, make_train_step


def build_mesh(kind: str):
    if kind == "none":
        return None
    from repro.launch.mesh import make_production_mesh, make_shrunken_mesh

    if kind == "single":
        return make_production_mesh(multi_pod=False)
    if kind == "pod":
        return make_production_mesh(multi_pod=True)
    if kind == "shrunken":
        return make_shrunken_mesh()
    raise ValueError(kind)


def maybe_ssa_placement(cfg, seed: int = 0):
    """Anneal an expert→EP-rank placement from (synthetic) routing stats."""
    if cfg.n_experts == 0:
        print(f"--placement ssa: {cfg.name} has no experts; skipping "
              "(technique inapplicable, see DESIGN.md §Arch-applicability)")
        return None
    from repro.core.placement import coactivation_stats, expert_placement

    rng = np.random.default_rng(seed)
    routing = rng.integers(0, cfg.n_experts, size=(2000, max(cfg.top_k, 1)))
    coact, load = coactivation_stats(routing, cfg.n_experts)
    n_dev = min(16, cfg.n_experts)
    res = expert_placement(coact, load, n_devices=n_dev, seed=seed)
    print(f"HA-SSA expert placement over {n_dev} EP ranks: "
          f"cost {res.baseline_cost:.0f} → {res.cost:.0f} "
          f"({100*res.improvement:.1f}% better than round-robin)")
    return res.assignment


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="qwen3-1.7b")
    ap.add_argument("--scale", choices=("reduced", "full"), default="reduced")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--mesh", choices=("none", "single", "pod", "shrunken"),
                    default="none")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--placement", choices=("none", "ssa"), default="none")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=(args.scale == "reduced"))
    mesh = build_mesh(args.mesh)
    if args.placement == "ssa":
        maybe_ssa_placement(cfg)

    tc = TrainConfig(
        opt=AdamWConfig(lr_peak=args.lr, warmup_steps=max(args.steps // 10, 1),
                        total_steps=args.steps),
        microbatches=args.microbatches,
        loss_chunk=min(512, args.seq),
    )
    dc = DataConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
        n_patches=cfg.n_patches if cfg.frontend == "vision" else 0,
        d_model=cfg.d_model,
        n_frames=cfg.n_frames if cfg.encoder_layers else 0,
    )
    pspecs = param_pspecs(model_defs(cfg), mesh, DEFAULT_RULES) if mesh else None
    step = make_train_step(cfg, tc, mesh=mesh, rules=DEFAULT_RULES,
                           param_specs=pspecs)
    step = jax.jit(step)
    monitor = StragglerMonitor(n_hosts=1)
    state, losses = run_training(
        init_state_fn=lambda: init_train_state(
            cfg, tc, jax.random.PRNGKey(0), mesh=mesh, param_specs=pspecs),
        train_step=step,
        batch_fn=lambda s: synthetic_batch(dc, s),
        n_steps=args.steps,
        ckpt=CheckpointManager(args.ckpt_dir, save_interval=args.ckpt_every, keep=2),
        monitor=monitor,
        log_every=10,
    )
    print(f"done: loss {losses[0]:.3f} → {losses[-1]:.3f}; "
          f"stragglers flagged: {monitor.stragglers()}")


if __name__ == "__main__":
    main()
