import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# ^ MUST precede every other import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes, and extract the roofline terms.

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh single --out experiments/dryrun

Per cell this prints compiled.memory_analysis() / cost_analysis() (the
proof-it-fits and the FLOPs/bytes source) and writes a JSON record consumed
by EXPERIMENTS.md §Dry-run/§Roofline and benchmarks/roofline.py.
"""
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.configs import ARCH_NAMES, SHAPES, applicable, get_config  # noqa: E402
from repro.launch import hlo_analysis as H  # noqa: E402
from repro.launch import lowering as LOW  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.sharding import DEFAULT_RULES  # noqa: E402

__all__ = ["run_cell", "main"]


def _mesh(kind: str):
    return make_production_mesh(multi_pod=(kind == "pod"))


def run_cell(arch: str, shape_name: str, mesh_kind: str, *, rules=DEFAULT_RULES,
             verbose: bool = True, rules_tag: str = "baseline",
             analysis: bool = True, train_cfg=None, param_dtype=None,
             cfg_transform=None):
    """Lower+compile one cell.  Returns the JSON-able record.

    Two artifacts per cell:
      deployment lowering — the real step (scan+remat+chunked): proves it
        compiles on the mesh and yields memory_analysis (capacity proof).
      analysis lowering  — unrolled depth-1/2 extrapolation (see
        lowering.analysis_costs): exact FLOPs/bytes/collective bytes for the
        roofline terms (XLA cost analysis counts loop bodies once).
    """
    cfg = get_config(arch)
    if cfg_transform is not None:
        cfg = cfg_transform(cfg)
    shape = SHAPES[shape_name]
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "rules": rules_tag,
        "kind": shape.kind,
    }
    ok, reason = applicable(cfg, shape)
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = reason
        if verbose:
            print(f"[{arch} × {shape_name} × {mesh_kind}] SKIP: {reason}")
        return rec

    mesh = _mesh(mesh_kind)
    n_chips = mesh.devices.size
    t0 = time.time()
    lowered = LOW.cell_lowering(cfg, shape, mesh, rules=rules,
                                train_cfg=train_cfg, param_dtype=param_dtype)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    peak = float(mem.temp_size_in_bytes + mem.argument_size_in_bytes
                 + mem.output_size_in_bytes - mem.alias_size_in_bytes)
    if verbose:
        print(f"[{arch} × {shape_name} × {mesh_kind}] lower {t_lower:.1f}s "
              f"compile {t_compile:.1f}s")
        print("  memory_analysis:", mem)

    raw = H.roofline(compiled, n_chips)  # scan-bodies-once (cross-check only)
    rec.update(
        status="ok",
        n_chips=n_chips,
        t_lower_s=t_lower,
        t_compile_s=t_compile,
        peak_bytes_per_device=peak,
        fits_hbm_16g=bool(peak < 16e9),
        raw_hlo_flops_per_device=raw.flops,
        raw_hlo_coll_bytes_per_device=raw.coll_bytes,
    )

    total_p, active_p = LOW.count_params(cfg)
    n_tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mf = H.model_flops(active_p, n_tokens, shape.kind)
    rec.update(params_total=total_p, params_active=active_p,
               n_tokens=n_tokens, model_flops=mf)

    if analysis:
        t0 = time.time()
        ac = LOW.analysis_costs(cfg, shape, mesh, rules=rules,
                                train_cfg=train_cfg, param_dtype=param_dtype)
        rec["t_analysis_s"] = time.time() - t0
        rep = H.RooflineReport(
            flops=ac["flops"],
            hbm_bytes=ac["hbm_bytes"],
            coll_bytes=ac["coll_bytes"],
            coll_breakdown=ac["coll_breakdown"],
            n_chips=n_chips,
            peak_memory_per_device=peak,
        )
        rec.update(**rep.asdict())
        rec["useful_flops_ratio"] = (
            mf / (rep.flops * n_chips) if rep.flops else None
        )
        if verbose:
            print(f"  roofline (extrapolated, per-device): compute "
                  f"{rep.t_compute*1e3:.2f} ms | memory {rep.t_memory*1e3:.2f} ms"
                  f" | collective {rep.t_collective*1e3:.2f} ms → "
                  f"{rep.dominant}-bound; MODEL/HLO flops "
                  f"{rec['useful_flops_ratio'] and round(rec['useful_flops_ratio'], 3)}"
                  f"; peak {peak/1e9:.2f} GB/device")
    return rec


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_NAMES)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--mesh", choices=("single", "pod", "both"), default="single")
    ap.add_argument("--all", action="store_true", help="every (arch × shape)")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args()

    cells = []
    archs = ARCH_NAMES if (args.all or not args.arch) else (args.arch,)
    shapes = tuple(SHAPES) if (args.all or not args.shape) else (args.shape,)
    meshes = ("single", "pod") if args.mesh == "both" else (args.mesh,)
    for a in archs:
        for s in shapes:
            for m in meshes:
                cells.append((a, s, m))

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for a, s, m in cells:
        try:
            # roofline analysis is a single-pod deliverable; the pod pass
            # proves the "pod" axis shards.
            rec = run_cell(a, s, m, verbose=not args.quiet,
                           analysis=(m == "single"))
        except Exception as e:  # a failure here is a bug in the system
            traceback.print_exc()
            rec = {"arch": a, "shape": s, "mesh": m, "status": "error",
                   "error": f"{type(e).__name__}: {e}"}
            failures += 1
        path = os.path.join(args.out, f"{a}__{s}__{m}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
    print(f"\n{len(cells)} cells, {failures} failures → {args.out}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
