"""Roofline-term extraction from compiled XLA artifacts.

compute/memory terms come from ``compiled.cost_analysis()``; collective
bytes are NOT in cost_analysis, so we parse the optimized HLO text and sum
the operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute op.

Hardware constants (TPU v5e, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

__all__ = [
    "HW",
    "collective_bytes",
    "count_hlo_ops",
    "roofline",
    "RooflineReport",
    "shape_bytes",
]


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12      # bf16 FLOP/s per chip
    hbm_bw: float = 819e9           # bytes/s per chip
    link_bw: float = 50e9           # bytes/s per ICI link


_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
}

# e.g. "bf16[256,4096,128]{2,1,0}" — capture dtype + dims
_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")

_COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)


def count_hlo_ops(hlo_text: str, op: str) -> int:
    """Count instruction occurrences of ``op`` in HLO or StableHLO text.

    Matches both the compiled-HLO form (``%x = f32[..] dot(...)``) and the
    StableHLO/MLIR form (``%5 = stablehlo.dot_general ...``).  Used by the
    contraction-count regression tests: a plateau's cycle loop must contain
    exactly one field contraction (dot for the dense backend, gather for the
    sparse one) — the seed's record='best' path evaluated it twice.
    """
    pat = rf"stablehlo\.{re.escape(op)}\b|(?<![\w.-]){re.escape(op)}\("
    return len(re.findall(pat, hlo_text))


def shape_bytes(dtype: str, dims_str: str) -> int:
    n = 1
    if dims_str:
        for d in dims_str.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _line_result_bytes(line: str) -> int:
    """Sum the bytes of the result shape(s) at the head of an HLO line.

    HLO line form: ``%name = <shape> <op>(<operands>)``.  For collectives,
    result bytes ≈ data moved per participating device (a good roofline
    proxy for all of AG/AR/RS/A2A/CP).
    """
    head = line.split(" = ", 1)
    if len(head) != 2:
        return 0
    result = head[1]
    # shapes before the op name — take the segment up to the op token
    m = re.search(r"\b(" + "|".join(_COLLECTIVE_OPS) + r")\b", result)
    if not m:
        return 0
    shapes_part = result[: m.start()]
    return sum(
        shape_bytes(d, s) for d, s in _SHAPE_RE.findall(shapes_part)
    )


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-type result bytes summed over the module.

    Includes '-start' variants (async collectives); '-done' lines carry the
    same tuple shape and are skipped to avoid double counting.
    """
    out = {k: 0 for k in _COLLECTIVE_OPS}
    out["total"] = 0
    for line in hlo_text.splitlines():
        ls = line.strip()
        if " = " not in ls:
            continue
        if "-done" in ls:
            continue
        for op in _COLLECTIVE_OPS:
            token = f" {op}"
            if f" {op}(" in ls or f" {op}-start(" in ls:
                b = _line_result_bytes(ls)
                out[op] += b
                out["total"] += b
                break
    return out


@dataclasses.dataclass
class RooflineReport:
    """All byte/FLOP numbers are PER-DEVICE (what cost_analysis reports for
    an SPMD-partitioned module — verified against a hand-sharded matmul).
    The prompt's form `HLO_FLOPs_global / (chips × peak)` equals
    `per_device / peak`."""

    flops: float
    hbm_bytes: float
    coll_bytes: float
    coll_breakdown: Dict[str, int]
    n_chips: int
    peak_memory_per_device: Optional[float]
    hw: HW = dataclasses.field(default_factory=HW)

    @property
    def t_compute(self) -> float:
        return self.flops / self.hw.peak_flops

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / self.hw.hbm_bw

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / self.hw.link_bw

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    def asdict(self) -> Dict:
        return {
            "flops_per_device": self.flops,
            "hbm_bytes_per_device": self.hbm_bytes,
            "coll_bytes_per_device": self.coll_bytes,
            "flops_global": self.flops * self.n_chips,
            "coll_breakdown": {k: int(v) for k, v in self.coll_breakdown.items()},
            "n_chips": self.n_chips,
            "peak_memory_per_device": self.peak_memory_per_device,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
        }


def roofline(compiled, n_chips: int, hlo_text: Optional[str] = None) -> RooflineReport:
    """Build a RooflineReport from a jax compiled artifact."""
    ca = compiled.cost_analysis()
    if isinstance(ca, list):  # older jax returns [dict]
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    hbm = float(ca.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = collective_bytes(text)
    peak = None
    try:
        ma = compiled.memory_analysis()
        peak = float(
            ma.temp_size_in_bytes + ma.argument_size_in_bytes + ma.output_size_in_bytes
        )
    except Exception:
        pass
    return RooflineReport(
        flops=flops,
        hbm_bytes=hbm,
        coll_bytes=float(coll["total"]),
        coll_breakdown=coll,
        n_chips=n_chips,
        peak_memory_per_device=peak,
    )


def model_flops(n_params_active: float, n_tokens: float, kind: str) -> float:
    """MODEL_FLOPS = 6·N·D (train) or 2·N·D (single forward / decode)."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_params_active * n_tokens
