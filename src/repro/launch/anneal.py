"""Annealing launcher (the paper's own workload, production form).

    PYTHONPATH=src python -m repro.launch.anneal --problem G11 --trials 16 \
        --m-shot 20 [--storage i0max|all] [--backend sparse|dense|pallas]

Selectable problems: G-set instances (real files if present under
data/gset/, structure-faithful generated twins otherwise), King1, K2000.

The solve runs on the plateau engine (DESIGN.md §2): `--backend pallas`
executes each temperature plateau as one resident `pallas_call` (J pinned
in VMEM); `sparse`/`dense` run the single-contraction-per-cycle scan.
`--track-energy` records per-cycle energy traces (forces the scan path on
the pallas backend, which has no per-cycle outputs).
"""
from __future__ import annotations

import argparse
import time

from repro.configs import ANNEAL_PROBLEMS
from repro.core import SSAHyperParams, anneal, gset, memory


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--problem", choices=ANNEAL_PROBLEMS, default="G11")
    ap.add_argument("--trials", type=int, default=16)
    ap.add_argument("--m-shot", type=int, default=20)
    ap.add_argument("--tau", type=int, default=100)
    ap.add_argument("--i0-min", type=int, default=1)
    ap.add_argument("--i0-max", type=int, default=32)
    ap.add_argument("--n-rnd", type=int, default=2)
    ap.add_argument("--beta-shift", type=int, default=1)
    ap.add_argument("--storage", choices=("i0max", "all"), default="i0max")
    ap.add_argument("--backend", choices=("sparse", "dense", "pallas"),
                    default="sparse")
    ap.add_argument("--record", choices=("best", "traj"), default="best")
    ap.add_argument("--track-energy", action="store_true",
                    help="record per-cycle energy traces (scan path)")
    ap.add_argument("--noise", choices=("xorshift", "threefry"), default="xorshift")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    p = gset.load(args.problem)
    hp = SSAHyperParams(
        n_trials=args.trials, m_shot=args.m_shot, n_rnd=args.n_rnd,
        i0_min=args.i0_min, i0_max=args.i0_max, tau=args.tau,
        beta_shift=args.beta_shift,
    )
    print(f"{p.name}: N={p.n} |E|={len(p.edges)}; {hp.total_cycles} cycles "
          f"× {hp.n_trials} trials; backend={args.backend}; "
          f"storage={args.storage} ({'HA-SSA' if args.storage == 'i0max' else 'SSA'})")
    t0 = time.time()
    r = anneal(p, hp, seed=args.seed, storage=args.storage, record=args.record,
               backend=args.backend, noise=args.noise,
               track_energy=args.track_energy)
    dt = time.time() - t0
    spin_cycles = hp.total_cycles * hp.n_trials
    print(f"best cut {r.overall_best_cut}  avg {r.mean_best_cut:.1f}  "
          f"best energy {r.best_energy.min()}  ({dt:.1f}s, "
          f"{spin_cycles/dt:.0f} trial-cycles/s, "
          f"{spin_cycles*p.n/dt:.2e} spin-cycles/s)")
    if p.best_known:
        print(f"best known {p.best_known} → {100*r.overall_best_cut/p.best_known:.2f}%")
    print(f"trajectory memory/iter: {memory.hassa_bits_per_iteration(p.n, hp)} bits "
          f"(SSA would use {memory.ssa_bits_per_iteration(p.n, hp)}; "
          f"{memory.memory_ratio(hp)}× saving)")


if __name__ == "__main__":
    main()
