"""Annealing launcher (the paper's own workload, production form).

    PYTHONPATH=src python -m repro.launch.anneal --problem G11 --trials 16 \
        --m-shot 20 [--storage i0max|all] [--backend sparse|dense|pallas]

Selectable problems: G-set instances (real files if present under
data/gset/, structure-faithful generated twins otherwise), King1, K2000.

The solve runs on the plateau engine (DESIGN.md §2): `--backend pallas`
executes each temperature plateau as one resident `pallas_call` (J pinned
in VMEM); `sparse`/`dense` run the single-contraction-per-cycle scan.
`--track-energy` records per-cycle energy traces (forces the scan path on
the pallas backend, which has no per-cycle outputs).

Service mode (DESIGN.md §7): pass a comma list to ``--problem`` (or
``--service``) and the launcher routes the batch through
:class:`repro.serve.AnnealService` — bucketed, stacked, one compiled
plateau program per shape bucket, with per-chunk streaming progress and
optional ``--target-cut`` early stop.

Streaming mode (DESIGN.md §12): add ``--stream`` to submit the problem
list to the always-on continuous-batching front door
(:class:`repro.serve.StreamingAnnealService`) instead of a single
``solve()`` batch — ``--arrival-rate`` paces the submissions as an
open-loop client, ``--priority`` picks the admission class.

Problem frontend (DESIGN.md §9): ``--problem-kind qubo|mis|coloring|
partition`` generates demo instances of the selected family (sized by
``--problem-n``, seeded by ``--seed``, ``--count`` of them) and solves them
through the service with decoded-solution verification.  ``--auto-tune``
replaces the Table-II hyperparameters with the local-energy-distribution
determination (:mod:`repro.core.autotune`) in every mode.
"""
from __future__ import annotations

import argparse
import time

from repro.configs import ANNEAL_PROBLEMS
from repro.core import (
    SolverConfig,
    SSAHyperParams,
    SSQAHyperParams,
    anneal,
    autotune_hyperparams,
    gset,
    memory,
)


def _resilience_policy(args):
    from repro.serve import ResiliencePolicy

    return ResiliencePolicy(checkpoint_dir=args.checkpoint_dir,
                            fallback=not args.no_fallback)


def _backend_opts(args):
    """--field-mode reaches the field-capable backends; sparse ignores it."""
    if args.field_mode != "dense" and args.backend != "sparse":
        return {"field_mode": args.field_mode}
    return {}


def _partition_mesh(args):
    """(partition, mesh) from --partition/--mesh-shape (DESIGN.md §11).

    The mesh is built lazily and only when spin sharding can apply, so
    partition='problem' launches never construct one.
    """
    if args.partition == "problem":
        return "problem", None
    from repro.launch.mesh import make_spin_mesh

    return args.partition, make_spin_mesh(args.mesh_shape)


def _run_service(problem_names, hp, args):
    from repro.serve import AnnealRequest, AnnealService

    problems = [gset.load(name) for name in problem_names]
    requests = [
        AnnealRequest(problem=p, hp="auto" if args.auto_tune else hp,
                      seed=args.seed + i, storage=args.storage,
                      target_cut=args.target_cut, auto_base=hp,
                      deadline_s=args.deadline_s, algo=args.algo)
        for i, p in enumerate(problems)
    ]
    partition, mesh = _partition_mesh(args)
    svc = AnnealService(backend=args.backend, noise=args.noise,
                        storage_layout=args.storage_layout,
                        chunk_shots=args.chunk_shots,
                        backend_opts=_backend_opts(args),
                        resilience=_resilience_policy(args),
                        partition=partition, mesh=mesh)

    def progress(ev):
        bests = ", ".join(
            f"{problems[i].name}={b}"
            for i, b in zip(ev.request_indices, ev.best_cut)
        )
        print(f"[chunk {ev.chunk + 1}/{ev.chunks_total} bucket={ev.bucket}] "
              f"best cut: {bests}")

    t0 = time.time()
    responses = svc.solve(requests, progress=progress)
    dt = time.time() - t0
    total_spin_cycles = 0
    for p, r in zip(problems, responses):
        if r.result is None:
            # No result to report: distinguish 'shed'/'deadline' (the
            # service declined or timed the work out) from 'failed'
            # (retries exhausted) instead of labeling everything a failure.
            print(f"{p.name}: {r.status.upper()} — no result "
                  f"({'; '.join(e.kind for e in r.events) or 'no events'})")
            continue
        rhp = r.request.hp  # resolved (autotuned hp differs from the base)
        shots = r.chunks_run * (rhp.m_shot // r.chunks_total)
        total_spin_cycles += (
            shots * rhp.cycles_per_iter * rhp.n_trials * p.n
        )
        tuned = (f" auto[n_rnd={rhp.n_rnd} i0_max={rhp.i0_max} "
                 f"tau={rhp.tau}]" if r.autotune else "")
        degraded = "" if r.status == "ok" else f" status={r.status}"
        print(f"{p.name}: best cut {r.result.overall_best_cut} "
              f"avg {r.result.mean_best_cut:.1f} "
              f"[bucket={r.bucket} batch={r.batch} "
              f"chunks={r.chunks_run}/{r.chunks_total}]{tuned}{degraded}")
        for ev in r.events:
            print(f"  event[{ev.t:.2f}s] {ev.kind}: {ev.detail}")
    info = svc.cache_info()
    print(f"batch of {len(problems)} in {dt:.1f}s "
          f"({total_spin_cycles/dt:.2e} aggregate spin-cycles/s; "
          f"{info['programs']} compiled program(s), "
          f"{info.get('traces_chunk', 0)} plateau-program trace(s))")


def _run_stream(problem_names, hp, args):
    """Streaming client mode (DESIGN.md §12): submit the problem list to an
    always-on StreamingAnnealService — optionally paced as an open-loop
    arrival process — and await the tickets."""
    from repro.serve import (
        AnnealRequest,
        AnnealService,
        StreamingAnnealService,
        StreamPolicy,
    )

    problems = [gset.load(name) for name in problem_names]
    partition, mesh = _partition_mesh(args)
    svc = AnnealService(backend=args.backend, noise=args.noise,
                        storage_layout=args.storage_layout,
                        chunk_shots=args.chunk_shots,
                        backend_opts=_backend_opts(args),
                        resilience=_resilience_policy(args),
                        partition=partition, mesh=mesh)
    ss = StreamingAnnealService(
        service=svc,
        policy=StreamPolicy(slots_per_table=args.stream_slots))
    ss.start()
    t0 = time.time()
    tickets = []
    try:
        for i, p in enumerate(problems):
            if args.arrival_rate > 0 and i:
                time.sleep(1.0 / args.arrival_rate)
            req = AnnealRequest(
                problem=p, hp="auto" if args.auto_tune else hp,
                seed=args.seed + i, storage=args.storage,
                target_cut=args.target_cut, auto_base=hp,
                deadline_s=args.deadline_s, algo=args.algo)
            tickets.append(ss.submit(req, priority=args.priority))
        shed = deadline = 0
        for p, t in zip(problems, tickets):
            r = t.result(timeout=None)
            if r.status == "shed":
                # Dropped unstarted (deadline already unmeetable) — not a
                # solver failure; count it separately in the summary.
                shed += 1
                print(f"{p.name}: SHED — dropped from the queue unstarted "
                      f"(deadline_s={r.request.deadline_s})")
                continue
            if r.result is None:
                print(f"{p.name}: {r.status.upper()} — no result "
                      f"({'; '.join(e.kind for e in r.events) or 'no events'})")
                continue
            if r.status == "deadline":
                deadline += 1
            print(f"{p.name}: best cut {r.result.overall_best_cut} "
                  f"[chunks={r.chunks_run}/{r.chunks_total} "
                  f"queued {r.queued_s:.2f}s lane {r.lane_wall_s:.2f}s] "
                  f"status={r.status}"
                  + (" (best-so-far at deadline)"
                     if r.status == "deadline" else ""))
    finally:
        ss.stop()
    dt = time.time() - t0
    st = ss.stream_stats()
    print(f"stream of {len(problems)} in {dt:.1f}s: "
          f"occupancy={st['occupancy']:.2f} "
          f"backfills={st['stream_backfills']} "
          f"tables={st['stream_tables_created']} "
          f"quanta={st['stream_quanta']} "
          f"shed={shed} deadline={deadline}")


def _run_problem_kind(hp, args):
    """Demo instances of a problem family through the service (DESIGN.md §9)."""
    from repro.problems import make_demo
    from repro.serve import AnnealRequest, AnnealService

    encs = [
        make_demo(args.problem_kind, n=args.problem_n, seed=args.seed + i)
        for i in range(args.count)
    ]
    requests = [
        AnnealRequest(problem=enc, hp="auto" if args.auto_tune else hp,
                      seed=args.seed + i, storage=args.storage, auto_base=hp)
        for i, enc in enumerate(encs)
    ]
    partition, mesh = _partition_mesh(args)
    svc = AnnealService(backend=args.backend, noise=args.noise,
                        storage_layout=args.storage_layout,
                        chunk_shots=args.chunk_shots,
                        backend_opts=_backend_opts(args),
                        resilience=_resilience_policy(args),
                        partition=partition, mesh=mesh)
    t0 = time.time()
    responses = svc.solve(requests)
    dt = time.time() - t0
    for enc, r in zip(encs, responses):
        if r.result is None:
            print(f"{enc.model.name}: {r.status.upper()} — no result "
                  f"({'; '.join(e.kind for e in r.events) or 'no events'})")
            continue
        rhp = r.request.hp
        tuned = (f" auto[n_rnd={rhp.n_rnd} i0_max={rhp.i0_max} "
                 f"tau={rhp.tau}]" if r.autotune else "")
        degraded = "" if r.status == "ok" else f" status={r.status}"
        print(f"{enc.model.name}: objective={r.objective} "
              f"feasible={r.feasible} energy={int(r.result.best_energy.min())} "
              f"[bucket={r.bucket} batch={r.batch}]{tuned}{degraded}")
    info = svc.cache_info()
    print(f"{len(encs)} × {args.problem_kind} in {dt:.1f}s "
          f"({info['programs']} compiled program(s))")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--problem", default="G11",
                    help="instance name, or a comma list for service mode "
                         f"(known: {sorted(ANNEAL_PROBLEMS)})")
    ap.add_argument("--problem-kind", default="gset",
                    choices=("gset", "qubo", "mis", "coloring", "partition"),
                    help="problem family: 'gset' uses --problem names; other "
                         "kinds generate demo instances through the service "
                         "frontend (DESIGN.md §9)")
    ap.add_argument("--problem-n", type=int, default=0,
                    help="demo instance size for non-gset kinds (0 = family "
                         "default)")
    ap.add_argument("--count", type=int, default=1,
                    help="number of demo instances for non-gset kinds")
    ap.add_argument("--auto-tune", action="store_true",
                    help="derive n_rnd/I0 from the local-energy distribution "
                         "(repro.core.autotune) instead of the Table-II flags")
    ap.add_argument("--service", action="store_true",
                    help="route through the AnnealService even for one problem")
    ap.add_argument("--stream", action="store_true",
                    help="streaming client mode: submit the problem list to "
                         "the continuous-batching StreamingAnnealService "
                         "(DESIGN.md §12) instead of one solve() batch")
    ap.add_argument("--stream-slots", type=int, default=4,
                    help="--stream: compiled slot-table width (power of two)")
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="--stream: pace submissions at this rate in req/s "
                         "(0 = submit everything immediately)")
    ap.add_argument("--priority", choices=("interactive", "batch"),
                    default="batch",
                    help="--stream: admission priority class")
    ap.add_argument("--target-cut", type=int, default=None,
                    help="service mode: early-stop once every request hits it")
    ap.add_argument("--chunk-shots", type=int, default=1,
                    help="service mode: iterations per progress chunk")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="service mode: chunk-level checkpoint root — a "
                         "killed solve resumes bit-identically (DESIGN.md §10)")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="service mode: per-request wall-clock budget; expiry "
                         "returns best-so-far with status='deadline'")
    ap.add_argument("--no-fallback", action="store_true",
                    help="service mode: disable the backend fallback chain "
                         "(pallas→dense→sparse) — faults propagate instead")
    ap.add_argument("--algo", choices=("ssa", "ssqa"), default="ssa",
                    help="algorithm family: 'ssqa' runs the Trotter-replica "
                         "quantum variant (DESIGN.md §13) — the replica ring "
                         "lives on the trial axis, so --trials must be a "
                         "multiple of --replicas")
    ap.add_argument("--replicas", type=int, default=8,
                    help="--algo ssqa: Trotter replicas per ring (>= 2)")
    ap.add_argument("--jperp-max", type=int, default=4,
                    help="--algo ssqa: integer replica coupling at the "
                         "coldest plateau (the Γ→0 end of the ramp)")
    ap.add_argument("--trials", type=int, default=16)
    ap.add_argument("--m-shot", type=int, default=20)
    ap.add_argument("--tau", type=int, default=100)
    ap.add_argument("--i0-min", type=int, default=1)
    ap.add_argument("--i0-max", type=int, default=32)
    ap.add_argument("--n-rnd", type=int, default=2)
    ap.add_argument("--beta-shift", type=int, default=1)
    ap.add_argument("--storage", choices=("i0max", "all"), default="i0max")
    ap.add_argument("--storage-layout", choices=("dense", "packed"),
                    default="dense",
                    help="HBM-resident engine state: int8 spins or uint32 "
                         "bitplanes (DESIGN.md §4; bit-identical results)")
    ap.add_argument("--backend", choices=("sparse", "dense", "pallas", "auto"),
                    default="sparse",
                    help="'auto' picks pallas at/above MIN_RESIDENT_N spins, "
                         "dense below (the small-N launch-overhead rule)")
    ap.add_argument("--field-mode", choices=("dense", "popcount", "auto"),
                    default="dense",
                    help="field contraction arithmetic (dense/pallas "
                         "backends): 'popcount' = XNOR-popcount on uint32 "
                         "bitplanes (DESIGN.md §8; bit-identical results), "
                         "'auto' by coupling bit depth")
    ap.add_argument("--partition", choices=("problem", "spin", "auto"),
                    default="problem",
                    help="work partitioning: 'spin' shards the spin axis of "
                         "each problem over the mesh via shard_map "
                         "collectives (DESIGN.md §11; bit-identical), 'auto' "
                         "picks per instance/bucket")
    ap.add_argument("--mesh-shape", default=None,
                    help="1-D device count for --partition spin|auto, e.g. "
                         "'4' (default: every available device); combine "
                         "with XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=N for CPU fleets")
    ap.add_argument("--record", choices=("best", "traj"), default="best")
    ap.add_argument("--track-energy", action="store_true",
                    help="record per-cycle energy traces (scan path)")
    ap.add_argument("--noise", choices=("xorshift", "threefry"), default="xorshift")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.algo == "ssqa":
        hp = SSQAHyperParams(
            n_trials=args.trials, m_shot=args.m_shot, n_rnd=args.n_rnd,
            i0_min=args.i0_min, i0_max=args.i0_max, tau=args.tau,
            beta_shift=args.beta_shift, n_replicas=args.replicas,
            jperp_max=args.jperp_max,
        )
    else:
        hp = SSAHyperParams(
            n_trials=args.trials, m_shot=args.m_shot, n_rnd=args.n_rnd,
            i0_min=args.i0_min, i0_max=args.i0_max, tau=args.tau,
            beta_shift=args.beta_shift,
        )
    if args.problem_kind != "gset":
        return _run_problem_kind(hp, args)
    names = args.problem.split(",")
    if args.stream:
        return _run_stream(names, hp, args)
    if args.service or len(names) > 1:
        return _run_service(names, hp, args)

    p = gset.load(args.problem)
    if args.auto_tune:
        hp, rep = autotune_hyperparams(p.to_ising(), hp)
        print(f"auto-tune: sigma={rep.sigma:.2f} |z|max={rep.z_max} → "
              f"n_rnd={hp.n_rnd} I0:{hp.i0_min}→{hp.i0_max} tau={hp.tau}")
    algo_name = ("SSQA" if args.algo == "ssqa"
                 else "HA-SSA" if args.storage == "i0max" else "SSA")
    extra = (f"; R={hp.n_replicas} jperp_max={hp.jperp_max}"
             if args.algo == "ssqa" else "")
    print(f"{p.name}: N={p.n} |E|={len(p.edges)}; {hp.total_cycles} cycles "
          f"× {hp.n_trials} trials; backend={args.backend}; "
          f"storage={args.storage} ({algo_name}){extra}")
    partition, mesh = _partition_mesh(args)
    cfg = SolverConfig(
        backend=args.backend, noise=args.noise,
        storage_layout=args.storage_layout,
        field_mode=(args.field_mode
                    if args.backend != "sparse" else "auto"),
        partition=partition, mesh=mesh,
    )
    t0 = time.time()
    r = anneal(p, hp, seed=args.seed, storage=args.storage, record=args.record,
               config=cfg, track_energy=args.track_energy)
    dt = time.time() - t0
    spin_cycles = hp.total_cycles * hp.n_trials
    print(f"best cut {r.overall_best_cut}  avg {r.mean_best_cut:.1f}  "
          f"best energy {r.best_energy.min()}  ({dt:.1f}s, "
          f"{spin_cycles/dt:.0f} trial-cycles/s, "
          f"{spin_cycles*p.n/dt:.2e} spin-cycles/s)")
    if p.best_known:
        print(f"best known {p.best_known} → {100*r.overall_best_cut/p.best_known:.2f}%")
    print(f"trajectory memory/iter: {memory.hassa_bits_per_iteration(p.n, hp)} bits "
          f"(SSA would use {memory.ssa_bits_per_iteration(p.n, hp)}; "
          f"{memory.memory_ratio(hp)}× saving)")


if __name__ == "__main__":
    main()
