from .resilience import *  # noqa: F401,F403
from .faults import *  # noqa: F401,F403
