from .resilience import *  # noqa: F401,F403
