"""Fault tolerance: restartable training, straggler detection, elastic re-mesh.

Three mechanisms (DESIGN.md §6):

1. **Checkpoint/restart** — `run_training` drives (train_step, data(step),
   CheckpointManager); because the data pipeline is stateless-per-step and
   the checkpoint holds (params, opt, step), a process killed at any point
   resumes bit-exact (test_ft.py kills mid-run and compares losses).

2. **Straggler mitigation** — `StragglerMonitor` keeps an EMA of per-host
   step times and flags hosts slower than `threshold ×` the fleet median;
   the driver's hook can then re-shard around them (here: logged + surfaced;
   the decision logic is what's unit-tested).

3. **Elastic re-mesh** — `remesh` moves a TrainState onto a different mesh
   (e.g. 2 pods → 1 pod after a pod loss) by re-computing NamedShardings
   from the same logical axes and `jax.device_put`-ing; the dry-run proves
   the step function re-lowers on the shrunken mesh.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.checkpoint.ckpt import CheckpointManager

__all__ = ["StragglerMonitor", "remesh", "run_training", "SimulatedFailure"]


class SimulatedFailure(RuntimeError):
    """Raised by tests to emulate a node loss mid-training."""


# ---------------------------------------------------------------------------
# Straggler detection
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class StragglerMonitor:
    n_hosts: int
    ema_decay: float = 0.9
    threshold: float = 1.5   # flag if EMA > threshold × median EMA
    warmup_steps: int = 3

    def __post_init__(self):
        self._ema = np.zeros(self.n_hosts)
        self._count = np.zeros(self.n_hosts, dtype=int)

    def record(self, host: int, step_time: float):
        if self._count[host] == 0:
            self._ema[host] = step_time
        else:
            self._ema[host] = (
                self.ema_decay * self._ema[host] + (1 - self.ema_decay) * step_time
            )
        self._count[host] += 1

    def stragglers(self) -> List[int]:
        ready = self._count >= self.warmup_steps
        if not ready.any():
            return []
        med = float(np.median(self._ema[ready]))
        if med <= 0:
            return []
        return [
            h for h in range(self.n_hosts)
            if ready[h] and self._ema[h] > self.threshold * med
        ]


# ---------------------------------------------------------------------------
# Elastic re-mesh
# ---------------------------------------------------------------------------
def remesh(tree, shardings_fn: Callable[[Any], Any]):
    """Move a pytree onto new shardings (new mesh).  shardings_fn(tree) →
    matching pytree of NamedShardings (typically params/opt spec builders
    re-run against the new mesh)."""
    shardings = shardings_fn(tree)
    return jax.tree_util.tree_map(jax.device_put, tree, shardings)


# ---------------------------------------------------------------------------
# Restartable training driver
# ---------------------------------------------------------------------------
def run_training(
    *,
    init_state_fn: Callable[[], Any],
    train_step: Callable[[Any, Any], Tuple[Any, Dict]],
    batch_fn: Callable[[int], Any],
    n_steps: int,
    ckpt: CheckpointManager,
    fail_at_step: Optional[int] = None,
    monitor: Optional[StragglerMonitor] = None,
    log_every: int = 0,
) -> Tuple[Any, List[float]]:
    """Run (or resume) training to n_steps.  Returns (state, loss history).

    Resume: if the checkpoint dir has a saved state, start from it — the
    step counter lives in state.opt.step, data is replayed from that cursor.
    `fail_at_step` raises SimulatedFailure *after* that step's optimizer
    update but before its checkpoint would complete — the worst-case window.
    """
    from repro.checkpoint.ckpt import latest_step

    state = init_state_fn()
    start = 0
    if latest_step(ckpt.directory) is not None:
        state, meta = ckpt.restore_latest(state)
        start = int(meta["step"])

    losses: List[float] = []
    for step in range(start, n_steps):
        t0 = time.perf_counter()
        batch = batch_fn(step)
        state, metrics = train_step(state, batch)
        dt = time.perf_counter() - t0
        if monitor is not None:
            monitor.record(0, dt)
        loss = float(metrics["ce_loss"])
        losses.append(loss)
        if log_every and (step + 1) % log_every == 0:
            print(f"step {step + 1}: loss {loss:.4f} ({dt*1e3:.0f} ms)")
        if fail_at_step is not None and step + 1 == fail_at_step:
            raise SimulatedFailure(f"simulated node loss at step {step + 1}")
        ckpt.maybe_save(step + 1, state, meta={"data_step": step + 1})
    ckpt.wait()
    return state, losses
