"""Fault injection for the annealing service (DESIGN.md §10).

The service's resilience layer is only trustworthy if every failure path is
exercised deliberately — this module is the chaos harness that does it.
:class:`FaultInjector` is a registry of *armed* faults that the service
fires at its hook points; each hook either raises a typed injected error
(compile failure, OOM, process kill) or returns a corruption spec that the
caller applies to its own readings (NaN burst).  Because the injector is
plain host-side Python, faults land at exactly the boundaries where real
faults land — program build, problem stacking, chunk boundaries — without
touching the traced/compiled device code, so the recovery machinery under
test is the production machinery.

Hook points (fired by :class:`repro.serve.AnnealService`):

=========  ==================================================  =============
point      fires at                                            effect
=========  ==================================================  =============
'compile'  executable-cache miss, before tracing the program   raises
           (ctx: backend, kind, bucket)                        InjectedCompileFailure
'oom'      after stacking the problem arrays (ctx: backend,    raises
           j_mode, bucket, batch)                              InjectedOOM
'nan'      each chunk boundary, on the energy readings         returns the spec;
           (ctx: kind, chunk)                                  caller plants NaN
                                                               in ``spec.slots``
'kill'     each chunk boundary, after the checkpoint write     raises
           (ctx: kind, chunk)                                  InjectedKill
=========  ==================================================  =============

:func:`chaos_schedule` builds a seeded, finite fault plan over those points
— the deterministic "chaos monkey" the chaos suite replays at many seeds.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Dict, List, Optional, Tuple

from repro.ft.resilience import SimulatedFailure

__all__ = [
    "InjectedFault",
    "InjectedCompileFailure",
    "InjectedOOM",
    "InjectedKill",
    "FaultSpec",
    "FaultInjector",
    "FAULT_POINTS",
    "chaos_schedule",
]

FAULT_POINTS = ("compile", "oom", "nan", "kill")


class InjectedFault(RuntimeError):
    """Base class for injector-raised faults (never raised by real code)."""


class InjectedCompileFailure(InjectedFault):
    """Emulates a backend compile/lowering/launch failure."""


class InjectedOOM(InjectedFault):
    """Emulates a device allocation failure (RESOURCE_EXHAUSTED)."""


class InjectedKill(InjectedFault, SimulatedFailure):
    """Emulates the process dying mid-solve (must escape all handlers)."""


@dataclasses.dataclass
class FaultSpec:
    """One armed fault: a hook point, a shot budget, and context filters.

    ``match`` keys are compared against the hook's keyword context; a spec
    only fires when every match key is present and equal.  ``slots`` names
    the batch slots a 'nan' burst corrupts (empty = every slot).
    """

    point: str
    count: int = 1
    match: Dict[str, object] = dataclasses.field(default_factory=dict)
    slots: Tuple[int, ...] = ()

    def matches(self, ctx: Dict[str, object]) -> bool:
        return self.count > 0 and all(
            ctx.get(k) == v for k, v in self.match.items()
        )


class FaultInjector:
    """Armed-fault registry + fired-fault log.

    ``arm()`` registers a fault; ``fire()`` is called by the service at each
    hook point and consumes the first matching armed spec.  Raising points
    ('compile'/'oom'/'kill') raise their typed error; passive points
    ('nan') return the spec for the caller to apply.  Every firing is
    appended to ``log`` so tests can assert exactly which faults landed.
    """

    def __init__(self, specs: Optional[List[FaultSpec]] = None):
        self.specs: List[FaultSpec] = list(specs or [])
        self.log: List[Tuple[str, Dict[str, object]]] = []

    def arm(self, point: str, *, count: int = 1, slots: Tuple[int, ...] = (),
            **match) -> FaultSpec:
        if point not in FAULT_POINTS:
            raise ValueError(f"unknown fault point {point!r}; known: {FAULT_POINTS}")
        spec = FaultSpec(point=point, count=int(count), match=dict(match),
                         slots=tuple(slots))
        self.specs.append(spec)
        return spec

    def fire(self, point: str, **ctx) -> Optional[FaultSpec]:
        for spec in self.specs:
            if spec.point != point or not spec.matches(ctx):
                continue
            spec.count -= 1
            self.log.append((point, dict(ctx)))
            detail = ", ".join(f"{k}={v}" for k, v in sorted(ctx.items()))
            if point == "compile":
                raise InjectedCompileFailure(f"injected compile failure ({detail})")
            if point == "oom":
                raise InjectedOOM(f"injected RESOURCE_EXHAUSTED ({detail})")
            if point == "kill":
                raise InjectedKill(f"injected process kill ({detail})")
            return spec  # 'nan': caller plants the corruption
        return None

    @property
    def exhausted(self) -> bool:
        return all(s.count <= 0 for s in self.specs)


def chaos_schedule(
    seed: int,
    *,
    n_faults: int = 3,
    points: Tuple[str, ...] = FAULT_POINTS,
    fallback_backends: Tuple[str, ...] = ("pallas", "dense"),
    max_chunk: int = 4,
    n_slots: int = 2,
) -> FaultInjector:
    """A seeded, finite chaos plan: ``n_faults`` armed specs drawn from
    ``points``.

    Deterministic for a fixed seed, so a chaos run is replayable.  Compile
    and OOM faults are matched to ``fallback_backends`` only (a fault armed
    on the terminal backend of the fallback chain is a *test of surfacing*,
    not of recovery — arm it explicitly when that is what you want).  Kill
    and NaN faults land at a random chunk boundary below ``max_chunk``.
    """
    rng = random.Random(seed)
    inj = FaultInjector()
    for _ in range(int(n_faults)):
        point = rng.choice(list(points))
        if point in ("compile", "oom"):
            inj.arm(point, backend=rng.choice(list(fallback_backends)))
        elif point == "kill":
            inj.arm(point, chunk=rng.randrange(max_chunk))
        else:  # nan
            inj.arm(point, chunk=rng.randrange(max_chunk),
                    slots=(rng.randrange(max(1, n_slots)),))
    return inj
