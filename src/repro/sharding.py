"""Logical-axis sharding rules (GSPMD) for the model/train/serve stack.

Every parameter and activation carries *logical* axis names; a rule table
maps them to mesh axes.  The mapping is divisibility-aware: if a dim is not
divisible by the mesh axis it would shard over, it stays replicated instead
of failing (e.g. whisper-tiny's 6 heads on a 16-way model axis) — real
frameworks need this to run heterogeneous model zoos on a fixed mesh.

Mesh axes (launch/mesh.py):
  single-pod:  ("data", "model")            = (16, 16)
  multi-pod:   ("pod", "data", "model")     = (2, 16, 16)  — pod is extra DP.

Default logical rules (overridable per call — §Perf iterates on these):
  batch    → ("pod", "data")     activations/input batch
  heads    → "model"             attention q heads (TP)
  kv_heads → "model"             KV heads (TP; replicated when indivisible)
  d_ff     → "model"             MLP hidden (TP)
  experts  → "model"             MoE experts (EP)
  vocab    → "model"             embedding/logits vocab dim
  kv_seq   → "model"             decode KV-cache sequence (SP / flash-decode)
  d_model  → None                replicated (Megatron-style row/col split
                                 covers the contracting dims already)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "ShardingRules",
    "DEFAULT_RULES",
    "logical_to_spec",
    "named_sharding",
    "constrain",
    "mesh_axis_size",
    "abstract_mesh",
    "spin_mesh",
    "mesh_fingerprint",
]

Axes = Tuple[Optional[str], ...]  # logical names per dim (None = replicated)


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """logical axis name → mesh axis (str) or tuple of mesh axes."""

    rules: Tuple[Tuple[str, Any], ...] = (
        ("batch", ("pod", "data")),
        ("heads", "model"),
        ("kv_heads", "model"),
        ("d_ff", "model"),
        ("experts", "model"),
        ("vocab", "model"),
        ("kv_seq", "model"),
        ("ssm_state", None),
        ("d_model", None),
        ("seq", None),
        ("d_head", None),
        ("layers", None),
    )

    def lookup(self, logical: Optional[str]):
        if logical is None:
            return None
        for k, v in self.rules:
            if k == logical:
                return v
        return None

    def replace(self, **kw) -> "ShardingRules":
        d = dict(self.rules)
        d.update(kw)
        return ShardingRules(rules=tuple(d.items()))


DEFAULT_RULES = ShardingRules()

# §Perf rule presets -------------------------------------------------------
# Weight-stationary serving (FSDP-style): no gradients exist, so the `data`
# axis is free — shard weights' d_model over it (params 16× smaller/device,
# 16× less HBM param traffic per token) and spread long KV over every free
# axis.  Used by the jamba long_500k hillclimb.
SERVE_WEIGHT_STATIONARY_RULES = DEFAULT_RULES.replace(
    d_model=("data",),
    kv_seq=("model", "data"),
)

# Megatron-SP + FSDP training: residual-stream activations sharded over
# `model` on the sequence dim (norms/elementwise 16× cheaper, activation
# stash 16× smaller); weights' d_model additionally sharded over `data`
# (FSDP).  Attention/MLP internals locally prefer head/d_ff sharding, so
# GSPMD places the SP all-gather/reduce-scatter at the layer boundaries.
TRAIN_FSDP_SP_RULES = DEFAULT_RULES.replace(
    d_model=("data",),
    seq=("model",),
)


def abstract_mesh(axis_sizes: Sequence[int], axis_names: Sequence[str]):
    """Version-portable `jax.sharding.AbstractMesh` constructor.

    jax <= 0.4.x takes a tuple of (name, size) pairs; newer releases take
    (axis_sizes, axis_names).  Spec-construction tests need only the shape,
    so paper over the signature change here.
    """
    from jax.sharding import AbstractMesh

    try:
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)))
    except TypeError:
        return AbstractMesh(tuple(axis_sizes), tuple(axis_names))


def spin_mesh(n_devices: Optional[int] = None, *, axis: str = "model") -> Mesh:
    """1-D mesh over the first ``n_devices`` host devices, for spin sharding.

    The annealer's model-parallel path (DESIGN.md §11) partitions the spin
    axis of a single instance over one mesh axis; this builds that mesh from
    however many devices exist — 1 real device and an 8-way
    ``--xla_force_host_platform_device_count`` CPU both work, no hard-coded
    counts.  ``n_devices=None`` takes every available device.
    """
    import numpy as np

    devs = jax.devices()
    k = len(devs) if n_devices is None else int(n_devices)
    if not 1 <= k <= len(devs):
        raise ValueError(
            f"spin_mesh: need 1 <= n_devices <= {len(devs)}, got {k}"
        )
    return Mesh(np.asarray(devs[:k]), (axis,))


def mesh_fingerprint(mesh: Optional[Mesh]) -> tuple:
    """Hashable mesh identity (axis names/sizes + device ids).

    Executable caches and checkpoint fingerprints key on this: the same
    program lowered for a different device set or axis layout is a different
    executable, and a checkpoint written under one mesh shape must not be
    silently resumed under another.
    """
    if mesh is None:
        return ()
    return (
        tuple(zip(mesh.axis_names, mesh.devices.shape)),
        tuple(int(d.id) for d in mesh.devices.flat),
    )


def mesh_axis_size(mesh: Mesh, axis) -> int:
    """Total size of a mesh axis or tuple of axes, 1 if absent from mesh."""
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        size = 1
        for a in axis:
            size *= mesh_axis_size(mesh, a)
        return size
    return int(mesh.shape[axis]) if axis in mesh.shape else 1


def _present(mesh: Mesh, axis):
    """Filter an axis spec down to the axes actually present in the mesh."""
    if axis is None:
        return None
    if isinstance(axis, (tuple, list)):
        kept = tuple(a for a in axis if a in mesh.shape)
        if not kept:
            return None
        return kept if len(kept) > 1 else kept[0]
    return axis if axis in mesh.shape else None


def logical_to_spec(
    mesh: Mesh,
    shape: Sequence[int],
    axes: Axes,
    rules: ShardingRules = DEFAULT_RULES,
) -> P:
    """Build a PartitionSpec, dropping any assignment that doesn't divide.

    A mesh axis is used at most once across all dims (GSPMD requirement);
    first-come-first-served in dim order.
    """
    if len(axes) != len(shape):
        raise ValueError(f"axes {axes} rank != shape {shape}")
    used = set()
    out = []
    for dim, logical in zip(shape, axes):
        axis = _present(mesh, rules.lookup(logical))
        if axis is None:
            out.append(None)
            continue
        parts = list(axis) if isinstance(axis, tuple) else [axis]
        # keep only axes not already used by an earlier dim, then trim from
        # the right until the product divides the dim (graceful fallback:
        # e.g. kv_seq→("model","data") with data taken by batch still
        # shards over model).
        parts = [a for a in parts if a not in used]
        while parts and (
            mesh_axis_size(mesh, tuple(parts)) <= 1
            or dim % mesh_axis_size(mesh, tuple(parts)) != 0
        ):
            parts.pop()
        if not parts:
            out.append(None)
            continue
        used.update(parts)
        out.append(tuple(parts) if len(parts) > 1 else parts[0])
    # trim trailing Nones for tidy specs
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def named_sharding(
    mesh: Mesh, shape: Sequence[int], axes: Axes, rules: ShardingRules = DEFAULT_RULES
) -> NamedSharding:
    return NamedSharding(mesh, logical_to_spec(mesh, shape, axes, rules))


def constrain(x, mesh: Optional[Mesh], axes: Axes, rules: ShardingRules = DEFAULT_RULES):
    """with_sharding_constraint by logical axes; no-op without a mesh."""
    if mesh is None or mesh.empty:
        return x
    spec = logical_to_spec(mesh, x.shape, axes, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
