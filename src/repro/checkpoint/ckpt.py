"""Checkpointing: atomic, async-capable, resumable, keep-last-k.

Format: one ``.npz`` per checkpoint holding every leaf (path-flattened) +
a JSON sidecar with step / data cursor / RNG / mesh shape.  Writes go to a
temp file then ``os.replace`` (atomic on POSIX) so a crash mid-save can
never corrupt the latest checkpoint — the FT restart test kills training
mid-run and resumes bit-exact.

(TensorStore/OCDBT is the production choice for multi-host sharded saves;
the layout here keeps the same step-atomic semantics single-process.)
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np

__all__ = [
    "save",
    "save_async",
    "restore",
    "latest_step",
    "purge",
    "CheckpointManager",
]

_SEP = "//"


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        name = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[name] = np.asarray(leaf)
    return flat


def _unflatten(template, flat: Dict[str, np.ndarray]):
    paths = jax.tree_util.tree_flatten_with_path(template)[0]
    treedef = jax.tree_util.tree_structure(template)
    leaves = []
    for path, leaf in paths:
        name = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if name not in flat:
            raise KeyError(f"checkpoint missing leaf {name}")
        arr = flat[name]
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _ckpt_path(directory: str, step: int) -> str:
    return os.path.join(directory, f"ckpt_{step:08d}.npz")


def save(directory: str, step: int, tree, meta: Optional[Dict[str, Any]] = None):
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(tree)
    path = _ckpt_path(directory, step)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **flat)
    os.replace(tmp, path)  # atomic
    meta = dict(meta or {})
    meta["step"] = step
    mpath = path.replace(".npz", ".json")
    with open(mpath + ".tmp", "w") as f:
        json.dump(meta, f)
    os.replace(mpath + ".tmp", mpath)
    return path


def save_async(directory: str, step: int, tree, meta=None) -> threading.Thread:
    """Snapshot to host memory synchronously, write to disk on a thread."""
    host_tree = jax.tree_util.tree_map(np.asarray, tree)  # device→host now
    t = threading.Thread(target=save, args=(directory, step, host_tree, meta))
    t.start()
    return t


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for fn in os.listdir(directory):
        m = re.fullmatch(r"ckpt_(\d+)\.npz", fn)
        if m:
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def restore(directory: str, template, step: Optional[int] = None):
    """Returns (tree, meta).  template = pytree with the target structure."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = _ckpt_path(directory, step)
    with np.load(path) as z:
        flat = {k: z[k] for k in z.files}
    tree = _unflatten(template, flat)
    with open(path.replace(".npz", ".json")) as f:
        meta = json.load(f)
    return tree, meta


def purge(directory: str):
    """Remove every checkpoint (and sidecar/tmp) in ``directory``.

    Used by short-lived checkpoint namespaces — e.g. the annealing service's
    per-group chunk checkpoints, which are deleted once the group completes
    so a later identical solve starts fresh instead of resuming a finished
    run.  Only checkpoint-shaped files are touched; the directory itself is
    removed if it ends up empty.
    """
    if not os.path.isdir(directory):
        return
    for fn in os.listdir(directory):
        if re.fullmatch(r"ckpt_\d+\.(npz|json)(\.tmp)?", fn):
            try:
                os.remove(os.path.join(directory, fn))
            except OSError:
                pass
    try:
        os.rmdir(directory)
    except OSError:
        pass  # non-checkpoint files present — leave the directory


@dataclasses.dataclass
class CheckpointManager:
    """save-every-k + keep-last-n + async writes + resume."""

    directory: str
    save_interval: int = 100
    keep: int = 3
    async_save: bool = True
    _pending: Optional[threading.Thread] = None

    def maybe_save(self, step: int, tree, meta=None) -> bool:
        if step % self.save_interval:
            return False
        self.wait()
        if self.async_save:
            self._pending = save_async(self.directory, step, tree, meta)
        else:
            save(self.directory, step, tree, meta)
        self._gc()
        return True

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self):
        if not os.path.isdir(self.directory):
            return
        steps = sorted(
            int(m.group(1))
            for fn in os.listdir(self.directory)
            if (m := re.fullmatch(r"ckpt_(\d+)\.npz", fn))
        )
        for s in steps[: -self.keep] if self.keep else []:
            for ext in (".npz", ".json"):
                try:
                    os.remove(_ckpt_path(self.directory, s).replace(".npz", ext))
                except OSError:
                    pass

    def restore_latest(self, template):
        self.wait()
        return restore(self.directory, template)

    def purge(self):
        self.wait()
        purge(self.directory)
