from .ckpt import *  # noqa: F401,F403
