"""Further combinatorial problems as Ising models (paper Sec. VI-B).

The paper argues HA-SSA extends beyond ±1 MAX-CUT to problems with integer
weights/biases and denser connectivity (TSP, graph isomorphism in [6]).
This module provides QUBO→Ising encoders for three such families, each with
a decoder and a feasibility/cost evaluator, so the annealers (ssa/sa/pt)
run on them unchanged:

  * TSP         — permutation one-hot encoding, integer distances
  * number partitioning — the classic fully-connected integer-weight Ising
  * graph isomorphism — permutation-matrix encoding (paper's GI workload)

QUBO x∈{0,1}ⁿ with x = (1+m)/2 maps to Ising via
  J_ij = -Q_ij/2 (i≠j),  h_i = -(Q_ii/2 + Σ_{j≠i} Q_ij/4)·2 ... we keep all
couplings integral by scaling Q by 4 up front (documented per encoder).

The production problem frontend — encodings with decode/verify carried as
one object, servable through :class:`repro.serve.AnnealService` — lives in
:mod:`repro.problems` (DESIGN.md §9); this module keeps the original
Sec. VI-B demonstrations (TSP, GI) and the legacy tuple-style entries.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from .ising import IsingModel

__all__ = [
    "qubo_to_ising",
    "TSPProblem",
    "tsp_problem",
    "decode_tsp",
    "tsp_tour_length",
    "partition_problem",
    "decode_partition",
    "gi_problem",
    "decode_gi",
]


def suggest_hyperparams(model: IsingModel, n_trials: int = 16, m_shot: int = 20):
    """Scale n_rnd / I0max to the coupling magnitude (integer-weight problems).

    The paper's Table II is tuned for ±1 MAX-CUT; for integer weights the
    fluctuation scale must track |J| (empirically n_rnd ≈ |J|max/4 and
    I0max ≈ 8·|J|max keep the accept/escape balance — validated on TSP,
    partitioning, and GI in tests/test_problems.py).

    This is the coarse *hand* heuristic; the measured, per-instance
    determination is :func:`repro.core.autotune.autotune_hyperparams`.
    """
    from .ssa import SSAHyperParams

    jmax = int(np.abs(model.dense_J()).max(initial=1))
    i0_max = 1 << max(int(np.ceil(np.log2(8 * jmax))), 3)
    return SSAHyperParams(
        n_trials=n_trials, m_shot=m_shot, tau=50,
        n_rnd=max(jmax // 4, 2), i0_min=1, i0_max=i0_max,
    )


# Canonical home of the QUBO→Ising expansion is the problem frontend
# (repro.problems.qubo); re-exported here for the Sec. VI-B callers.
from repro.problems.qubo import qubo_to_ising  # noqa: E402, F401


# ---------------------------------------------------------------------------
# TSP (paper Sec. VI-B)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class TSPProblem:
    dist: np.ndarray      # (C, C) integer distances
    model: IsingModel
    offset: int
    penalty: int

    @property
    def n_cities(self) -> int:
        return self.dist.shape[0]


def tsp_problem(dist: np.ndarray, penalty: Optional[int] = None) -> TSPProblem:
    """One-hot encoding: x[c, t] = city c visited at time t (n² spins).

    QUBO = A·(constraint violations) + tour length, A > max tour edge · 2.
    """
    dist = np.asarray(dist, dtype=np.int64)
    C = dist.shape[0]
    A = penalty if penalty is not None else int(dist.max() * 2 * C)
    n = C * C
    Q = np.zeros((n, n), dtype=np.int64)

    def idx(c, t):
        return c * C + t

    # each city exactly once: A(Σ_t x_ct − 1)²  → expand
    for c in range(C):
        for t1 in range(C):
            Q[idx(c, t1), idx(c, t1)] -= A
            for t2 in range(C):
                if t1 != t2:
                    Q[idx(c, t1), idx(c, t2)] += A
    # each time exactly one city
    for t in range(C):
        for c1 in range(C):
            Q[idx(c1, t), idx(c1, t)] -= A
            for c2 in range(C):
                if c1 != c2:
                    Q[idx(c1, t), idx(c2, t)] += A
    # tour length: d(c1,c2) x_{c1,t} x_{c2,t+1}
    for t in range(C):
        tn = (t + 1) % C
        for c1 in range(C):
            for c2 in range(C):
                if c1 != c2:
                    Q[idx(c1, t), idx(c2, tn)] += dist[c1, c2]
    model, offset = qubo_to_ising(Q, name=f"tsp{C}")
    return TSPProblem(dist=dist, model=model, offset=offset + 8 * A * C // 4, penalty=A)


def decode_tsp(p: TSPProblem, m: np.ndarray) -> Optional[np.ndarray]:
    """Spin vector → tour (city per time) or None if constraints violated."""
    C = p.n_cities
    x = (np.asarray(m).reshape(C, C) > 0)
    if not (x.sum(axis=0) == 1).all() or not (x.sum(axis=1) == 1).all():
        return None
    return x.argmax(axis=0)  # city at each time


def tsp_tour_length(p: TSPProblem, tour: np.ndarray) -> int:
    return int(sum(p.dist[tour[t], tour[(t + 1) % len(tour)]] for t in range(len(tour))))


# ---------------------------------------------------------------------------
# Number partitioning (integer weights, fully connected)
# ---------------------------------------------------------------------------
def partition_problem(values: np.ndarray) -> Tuple[IsingModel, np.ndarray]:
    """Minimize (Σ v_i m_i)²: J_ij = -2 v_i v_j, h = 0 (up to constant).

    Legacy tuple-returning entry; the encoded form lives in
    :func:`repro.problems.partition.partition_problem`.
    """
    from repro.problems.partition import partition_problem as _encode

    p = _encode(values)
    return p.model, p.values


def decode_partition(values: np.ndarray, m: np.ndarray) -> int:
    """|sum(A) − sum(B)| for the two subsets."""
    v = np.asarray(values, dtype=np.int64)
    return int(abs((v * np.asarray(m)).sum()))


# ---------------------------------------------------------------------------
# Graph isomorphism (paper's GI workload from [6])
# ---------------------------------------------------------------------------
def gi_problem(A1: np.ndarray, A2: np.ndarray, penalty: int = 4):
    """x[u, v] = vertex u of G1 maps to v of G2 (n² spins).

    QUBO: permutation constraints + edge-mismatch penalties; ground state 0
    iff the graphs are isomorphic.
    """
    A1 = np.asarray(A1, dtype=np.int64)
    A2 = np.asarray(A2, dtype=np.int64)
    n = A1.shape[0]
    assert A2.shape[0] == n
    N = n * n
    Q = np.zeros((N, N), dtype=np.int64)

    def idx(u, v):
        return u * n + v

    P = penalty
    for u in range(n):  # each u maps to exactly one v
        for v1 in range(n):
            Q[idx(u, v1), idx(u, v1)] -= P
            for v2 in range(n):
                if v1 != v2:
                    Q[idx(u, v1), idx(u, v2)] += P
    for v in range(n):  # each v is image of exactly one u
        for u1 in range(n):
            Q[idx(u1, v), idx(u1, v)] -= P
            for u2 in range(n):
                if u1 != u2:
                    Q[idx(u1, v), idx(u2, v)] += P
    # edge mismatch: (u1,u2)∈E1 but (v1,v2)∉E2 (and vice versa)
    for u1 in range(n):
        for u2 in range(n):
            if u1 == u2:
                continue
            for v1 in range(n):
                for v2 in range(n):
                    if v1 == v2:
                        continue
                    if A1[u1, u2] != A2[v1, v2]:
                        Q[idx(u1, v1), idx(u2, v2)] += 1
    model, offset = qubo_to_ising(Q, name=f"gi{n}")
    return model, offset


def decode_gi(n: int, m: np.ndarray) -> Optional[np.ndarray]:
    x = (np.asarray(m).reshape(n, n) > 0)
    if not (x.sum(axis=0) == 1).all() or not (x.sum(axis=1) == 1).all():
        return None
    return x.argmax(axis=1)  # mapping u → v
