"""Parallel-tempering baseline (paper Sec. V-C, Table VII; Gyoten et al. [11]).

R replicas run Metropolis sweeps at a fixed ladder of temperatures; every
``swap_interval`` cycles adjacent replicas attempt a configuration exchange
with probability min(1, exp((1/T_a - 1/T_b)(H_a - H_b))).  This is standard
PT [27]; IPAPT [11] is a hardware approximation of it — the algorithmic
baseline is what the paper compares solution-quality/time against.

The driver shares the engine's problem/result plumbing
(:func:`repro.core.engine.normalize_problem`,
:class:`repro.core.engine.BaseResult`) so PT results are interchangeable
with HA-SSA's and SA's in the benchmarks and the batch API.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from .engine import BaseResult, finalize_cut, normalize_problem
from .ising import IsingModel, MaxCutProblem

__all__ = ["PTHyperParams", "PTResult", "anneal_pt"]


@dataclasses.dataclass(frozen=True)
class PTHyperParams:
    n_replicas: int = 8
    n_cycles: int = 90_000
    swap_interval: int = 100
    t_min: float = 0.2
    t_max: float = 10.0


@dataclasses.dataclass
class PTResult(BaseResult):
    """PT reports one chain-best; scalars, but the BaseResult contract holds."""

    hp: PTHyperParams


def anneal_pt(
    problem: Union[MaxCutProblem, IsingModel],
    hp: PTHyperParams = PTHyperParams(),
    seed: int = 0,
    *,
    track_energy: bool = True,
) -> PTResult:
    maxcut, model = normalize_problem(problem)

    h, nbr_idx, nbr_w = model.device_arrays()
    n, R = model.n, hp.n_replicas
    # Geometric temperature ladder (hot→cold across replicas).
    temps = jnp.asarray(
        hp.t_max * (hp.t_min / hp.t_max) ** (np.arange(R) / max(R - 1, 1)),
        jnp.float32,
    )
    inv_t = 1.0 / temps

    def energy(m):
        neigh = jnp.take(m, nbr_idx, axis=-1)
        fields = jnp.sum(nbr_w * neigh, axis=-1)
        return -(jnp.sum(h * m, axis=-1) + jnp.sum(m * fields, axis=-1) // 2)

    def metro_cycle(carry, key):
        m, H = carry
        k_site, k_acc = jax.random.split(key)
        i = jax.random.randint(k_site, (R,), 0, n)
        mi = jnp.take_along_axis(m, i[:, None], axis=1)[:, 0]
        neigh = jnp.take_along_axis(jnp.broadcast_to(m, (R, n)), nbr_idx[i], axis=1)
        local = h[i] + jnp.sum(nbr_w[i] * neigh, axis=-1)
        dH = 2 * mi * local
        u = jax.random.uniform(k_acc, (R,), minval=1e-12)
        accept = (dH <= 0) | (jnp.log(u) < -dH.astype(jnp.float32) * inv_t)
        m = m.at[jnp.arange(R), i].set(jnp.where(accept, -mi, mi))
        H = H + jnp.where(accept, dH, 0)
        return (m, H), None

    def swap_phase(m, H, key, parity):
        # attempt swaps between (k, k+1) pairs of one parity
        a = jnp.arange(0, R - 1)
        pair_mask = (a % 2) == parity
        dB = inv_t[a] - inv_t[a + 1]
        dE = (H[a] - H[a + 1]).astype(jnp.float32)
        u = jax.random.uniform(key, (R - 1,), minval=1e-12)
        do_swap = pair_mask & (jnp.log(u) < dB * dE)
        perm = jnp.arange(R)
        perm = perm.at[a].set(jnp.where(do_swap, perm[a + 1], perm[a]))
        perm = perm.at[a + 1].set(jnp.where(do_swap, a, a + 1))
        # note: adjacent disjoint pairs (same parity) never overlap, so the
        # two scatter updates above are consistent.
        return m[perm], H[perm]

    rounds = hp.n_cycles // hp.swap_interval

    def one_round(carry, xs):
        m, H, best_H, best_m = carry
        key, parity = xs
        keys = jax.random.split(key, hp.swap_interval + 1)
        (m, H), _ = jax.lax.scan(metro_cycle, (m, H), keys[:-1])
        m, H = swap_phase(m, H, keys[-1], parity)
        rb = jnp.argmin(H)
        better = H[rb] < best_H
        best_H = jnp.where(better, H[rb], best_H)
        best_m = jnp.where(better, m[rb], best_m)
        trace = best_H if track_energy else 0
        return (m, H, best_H, best_m), trace

    @jax.jit
    def run():
        key = jax.random.PRNGKey(seed)
        key, k0 = jax.random.split(key)
        m0 = jnp.where(jax.random.bernoulli(k0, 0.5, (R, n)), 1, -1).astype(jnp.int32)
        H0 = energy(m0)
        keys = jax.random.split(key, rounds)
        parities = jnp.arange(rounds, dtype=jnp.int32) % 2
        b0 = jnp.argmin(H0)
        carry0 = (m0, H0, H0[b0], m0[b0])
        (_, _, best_H, best_m), mins = jax.lax.scan(one_round, carry0, (keys, parities))
        return best_m, best_H, mins

    best_m, best_H, mins = run()
    best_H = int(best_H)
    return PTResult(
        best_cut=int(finalize_cut(best_H, maxcut)),
        best_energy=best_H,
        best_m=np.asarray(best_m),
        energy_mean=None,
        energy_min=None if not track_energy else np.asarray(mins),
        hp=hp,
    )
