"""Parallel-tempering baseline (paper Sec. V-C, Table VII; Gyoten et al. [11])
and PT-SSA — parallel tempering expressed on the plateau engine.

R replicas run Metropolis sweeps at a fixed ladder of temperatures; every
``swap_interval`` cycles adjacent replicas attempt a configuration exchange
with probability min(1, exp((1/T_a - 1/T_b)(H_a - H_b))).  This is standard
PT [27]; IPAPT [11] is a hardware approximation of it — the algorithmic
baseline is what the paper compares solution-quality/time against.

**PT-SSA** (:func:`anneal_pt_ssa`) maps the replica ladder onto the plateau
engine's *trial axis*: R replicas run the Eq. (2a–2c) p-bit update
simultaneously at a fixed per-replica pseudo-inverse temperature I0 (the
ladder replaces the annealing schedule), and a swap phase between plateaus
exchanges configurations between adjacent rungs using an effective inverse
temperature β_k = beta_scale · I0_k.  Because it runs on
:func:`repro.core.engine.run_plateau_scan`, PT-SSA shares the batched
serving path: the service vmaps :func:`pt_ssa_rounds` over a stacked
problem axis exactly as it does the SSA plateau program.

The drivers share the engine's problem/result plumbing
(:func:`repro.core.engine.normalize_problem`,
:class:`repro.core.engine.BaseResult`) so PT results are interchangeable
with HA-SSA's and SA's in the benchmarks and the serving layer.
"""
from __future__ import annotations

import dataclasses
from typing import Union

import jax
import jax.numpy as jnp
import numpy as np

from .engine import (
    BaseResult,
    EngineState,
    energy_from_field,
    finalize_cut,
    make_backend,
    normalize_problem,
    run_plateau_scan,
)
from .ising import IsingModel, MaxCutProblem

__all__ = [
    "PTHyperParams",
    "PTResult",
    "anneal_pt",
    "PTSSAHyperParams",
    "PTSSAResult",
    "anneal_pt_ssa",
    "pt_ssa_rounds",
]


def _swap_perm(do_swap: jnp.ndarray, R: int) -> jnp.ndarray:
    """Permutation exchanging rungs (k, k+1) where do_swap[k] (k = 0..R-2).

    Accepted pairs all share one parity, so an index belongs to at most one
    accepted swap — as the lower member (takes from above) or the upper
    member (takes from below); the nested where resolves exactly one.
    """
    idx = jnp.arange(R)
    take_above = jnp.zeros(R, bool).at[:-1].set(do_swap)   # idx k   ← k+1
    take_below = jnp.zeros(R, bool).at[1:].set(do_swap)    # idx k+1 ← k
    return jnp.where(take_above, idx + 1, jnp.where(take_below, idx - 1, idx))


@dataclasses.dataclass(frozen=True)
class PTHyperParams:
    n_replicas: int = 8
    n_cycles: int = 90_000
    swap_interval: int = 100
    t_min: float = 0.2
    t_max: float = 10.0


@dataclasses.dataclass
class PTResult(BaseResult):
    """PT reports one chain-best; scalars, but the BaseResult contract holds."""

    hp: PTHyperParams


def anneal_pt(
    problem: Union[MaxCutProblem, IsingModel],
    hp: PTHyperParams = PTHyperParams(),
    seed: int = 0,
    *,
    track_energy: bool = True,
) -> PTResult:
    maxcut, model = normalize_problem(problem)

    h, nbr_idx, nbr_w = model.device_arrays()
    n, R = model.n, hp.n_replicas
    # Geometric temperature ladder (hot→cold across replicas).
    temps = jnp.asarray(
        hp.t_max * (hp.t_min / hp.t_max) ** (np.arange(R) / max(R - 1, 1)),
        jnp.float32,
    )
    inv_t = 1.0 / temps

    def energy(m):
        neigh = jnp.take(m, nbr_idx, axis=-1)
        fields = jnp.sum(nbr_w * neigh, axis=-1)
        return -(jnp.sum(h * m, axis=-1) + jnp.sum(m * fields, axis=-1) // 2)

    def metro_cycle(carry, key):
        m, H = carry
        k_site, k_acc = jax.random.split(key)
        i = jax.random.randint(k_site, (R,), 0, n)
        mi = jnp.take_along_axis(m, i[:, None], axis=1)[:, 0]
        neigh = jnp.take_along_axis(jnp.broadcast_to(m, (R, n)), nbr_idx[i], axis=1)
        local = h[i] + jnp.sum(nbr_w[i] * neigh, axis=-1)
        dH = 2 * mi * local
        u = jax.random.uniform(k_acc, (R,), minval=1e-12)
        accept = (dH <= 0) | (jnp.log(u) < -dH.astype(jnp.float32) * inv_t)
        m = m.at[jnp.arange(R), i].set(jnp.where(accept, -mi, mi))
        H = H + jnp.where(accept, dH, 0)
        return (m, H), None

    def swap_phase(m, H, key, parity):
        # attempt swaps between (k, k+1) pairs of one parity
        a = jnp.arange(0, R - 1)
        pair_mask = (a % 2) == parity
        dB = inv_t[a] - inv_t[a + 1]
        dE = (H[a] - H[a + 1]).astype(jnp.float32)
        u = jax.random.uniform(key, (R - 1,), minval=1e-12)
        do_swap = pair_mask & (jnp.log(u) < dB * dE)
        perm = _swap_perm(do_swap, R)
        return m[perm], H[perm]

    rounds = hp.n_cycles // hp.swap_interval

    def one_round(carry, xs):
        m, H, best_H, best_m = carry
        key, parity = xs
        keys = jax.random.split(key, hp.swap_interval + 1)
        (m, H), _ = jax.lax.scan(metro_cycle, (m, H), keys[:-1])
        m, H = swap_phase(m, H, keys[-1], parity)
        rb = jnp.argmin(H)
        better = H[rb] < best_H
        best_H = jnp.where(better, H[rb], best_H)
        best_m = jnp.where(better, m[rb], best_m)
        trace = best_H if track_energy else 0
        return (m, H, best_H, best_m), trace

    @jax.jit
    def run():
        key = jax.random.PRNGKey(seed)
        key, k0 = jax.random.split(key)
        m0 = jnp.where(jax.random.bernoulli(k0, 0.5, (R, n)), 1, -1).astype(jnp.int32)
        H0 = energy(m0)
        keys = jax.random.split(key, rounds)
        parities = jnp.arange(rounds, dtype=jnp.int32) % 2
        b0 = jnp.argmin(H0)
        carry0 = (m0, H0, H0[b0], m0[b0])
        (_, _, best_H, best_m), mins = jax.lax.scan(one_round, carry0, (keys, parities))
        return best_m, best_H, mins

    best_m, best_H, mins = run()
    best_H = int(best_H)
    return PTResult(
        best_cut=int(finalize_cut(best_H, maxcut)),
        best_energy=best_H,
        best_m=np.asarray(best_m),
        energy_mean=None,
        energy_min=None if not track_energy else np.asarray(mins),
        hp=hp,
    )


# ---------------------------------------------------------------------------
# PT-SSA: the replica ladder on the plateau engine's trial axis
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class PTSSAHyperParams:
    """PT expressed in the engine's terms: replicas = trials, rungs = I0.

    ``n_rounds`` plateau+swap rounds of ``tau`` cycles each; the I0 ladder is
    geometric from i0_min (hot) to i0_max (cold) across ``n_replicas``.
    ``beta_scale`` maps a rung's I0 to the effective inverse temperature used
    in the swap acceptance test (the p-bit dynamics' sharpness is monotone in
    I0, so any positive scale gives a valid PT exchange rule).
    """

    n_replicas: int = 8
    n_rounds: int = 60
    tau: int = 100
    i0_min: int = 1
    i0_max: int = 32
    n_rnd: int = 2
    beta_scale: float = 0.25

    def ladder(self) -> np.ndarray:
        """(R,) int32 I0 per replica, geometric hot→cold."""
        R = self.n_replicas
        ratio = (self.i0_max / self.i0_min) ** (1.0 / max(R - 1, 1))
        lad = np.round(self.i0_min * ratio ** np.arange(R))
        return np.clip(lad, self.i0_min, self.i0_max).astype(np.int32)

    @property
    def total_cycles(self) -> int:
        return self.n_rounds * self.tau


@dataclasses.dataclass
class PTSSAResult(BaseResult):
    """Per-replica best (arrays over the replica axis), BaseResult contract."""

    hp: PTSSAHyperParams


def pt_ssa_rounds(
    field_fn,
    noise_step,
    h: jnp.ndarray,
    hp: PTSSAHyperParams,
    state: EngineState,
    keys: jnp.ndarray,      # (k, 2) swap keys — one round per key
    parities: jnp.ndarray,  # (k,) int32 alternating swap parity
) -> EngineState:
    """Advance k plateau+swap rounds (traceable, single problem).

    Each round: one constant-ladder plateau of ``tau`` cycles via
    :func:`run_plateau_scan` with a **per-replica I0 column** (the engine's
    Eq. 2b clamp broadcasts over the trial axis), always storage-eligible
    (PT tracks its best continuously); then one adjacent-pair configuration
    swap at alternating parity.  Swaps permute (m, itanh); the running best
    stays attached to the rung that observed it — the final result reduces
    over rungs anyway.
    """
    ladder = jnp.asarray(hp.ladder(), jnp.int32)
    i0_col = ladder[:, None]
    betas = hp.beta_scale * ladder.astype(jnp.float32)
    R = hp.n_replicas
    a = jnp.arange(0, R - 1)

    def one_round(st, xs):
        key, parity = xs
        st, _, _ = run_plateau_scan(
            field_fn, noise_step, h, hp.n_rnd, st, i0_col,
            length=hp.tau, eligible=True,
        )
        field = field_fn(st.m)
        H = energy_from_field(st.m, field, h)
        pair_mask = (a % 2) == parity
        dB = betas[a] - betas[a + 1]
        dE = (H[a] - H[a + 1]).astype(jnp.float32)
        u = jax.random.uniform(key, (R - 1,), minval=1e-12)
        do_swap = pair_mask & (jnp.log(u) < dB * dE)
        perm = _swap_perm(do_swap, R)
        st = EngineState(
            st.noise_state, st.m[perm], st.itanh[perm], st.best_H, st.best_m
        )
        return st, None

    st, _ = jax.lax.scan(one_round, state, (keys, parities))
    return st


def anneal_pt_ssa(
    problem: Union[MaxCutProblem, IsingModel],
    hp: PTSSAHyperParams = PTSSAHyperParams(),
    seed: int = 0,
    *,
    backend: str = "sparse",
    noise: str = "xorshift",
) -> PTSSAResult:
    """PT on the plateau engine (replicas = trials, per-replica I0 clamp).

    ``backend`` must be 'sparse' or 'dense': the resident Pallas kernel takes
    a scalar plateau I0 (per-replica I0 columns are a kernel extension left
    to a later PR), so PT-SSA runs the scan path.
    """
    if backend == "pallas":
        raise ValueError(
            "pt-ssa needs a per-replica I0 column; the resident pallas "
            "kernel is scalar-I0 — use backend='sparse' or 'dense'"
        )
    maxcut, model = normalize_problem(problem)
    bk = make_backend(
        backend, model, n_trials=hp.n_replicas, n_rnd=hp.n_rnd, noise=noise
    )
    h = jnp.asarray(model.h, jnp.int32)

    @jax.jit
    def run():
        state = bk.init_state(seed)
        keys = jax.random.split(jax.random.PRNGKey(seed ^ 0x5CA1AB1E), hp.n_rounds)
        parities = jnp.arange(hp.n_rounds, dtype=jnp.int32) % 2
        state = pt_ssa_rounds(
            bk._field, bk._noise_step, h, hp, state, keys, parities
        )
        return bk.finalize(state)

    best_H, best_m = run()
    best_H = np.asarray(best_H)
    return PTSSAResult(
        best_cut=np.asarray(finalize_cut(best_H, maxcut)),
        best_energy=best_H,
        best_m=np.asarray(best_m),
        energy_mean=None,
        energy_min=None,
        hp=hp,
    )
