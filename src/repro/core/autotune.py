"""Local-energy-distribution-based hyperparameter determination.

The paper's Table II hyperparameters (n_rnd = 2, I0: 1→32) are tuned for
±1-weight MAX-CUT; on integer-weight reductions (QUBO, partitioning, …) the
same settings collapse — the noise is too weak to escape local minima and
the Itanh clamp saturates far below the local-field scale.  The companion
work *Local Energy Distribution Based Hyperparameter Determination for
Stochastic Simulated Annealing* (arXiv:2304.11839) shows both knobs are
functions of one measurable quantity: the distribution of local energies
z_i = h_i + Σ_j J_ij m_j over random spin states.

This module implements that determination, deterministically:

* sample S random ±1 states from a seeded generator and measure the local
  fields through the model's padded adjacency (pure NumPy — no compilation,
  O(S·N·deg), negligible next to any anneal);
* **noise magnitude** — n_rnd = round(σ), the sampled standard deviation:
  the stochastic term then perturbs I on the same scale the couplings do
  (the accept/escape balance of Eq. 2a);
* **I0 clamp** — I0max = next_pow2(8·max|z|): the Itanh saturation range
  covers the coldest useful temperature ≈ 8× the extreme local energy, kept
  a power of two so the HA-SSA barrel-shift schedule (Eq. 4) reaches it
  exactly; I0min stays 1 (the hottest plateau);
* **per-plateau schedule scaling** — the plateau length τ is rescaled so
  one iteration keeps the caller's cycle budget: more plateaus (larger
  I0max ⇒ steps = log2(I0max)+1) each run proportionally fewer cycles;
* **SSQA quantum knobs** — when the base carries a Trotter dimension
  (``n_replicas``/``jperp_max`` attributes, i.e.
  :class:`repro.core.ssqa.SSQAHyperParams`), the same σ fixes both: the
  replica count R = next_pow2(4σ) (clipped to [2, 16]) so the ring is deep
  enough that the path-integral coupling can carry information across it at
  the instance's energy scale, and J⊥max = round(2σ) (clipped to [1, 16])
  so the coldest-plateau coupling competes with — without dominating — the
  classical local field.  On G11-class ±1 MAX-CUT (σ = 2) this reproduces
  the SSQA defaults exactly (R = 8, J⊥max = 4), mirroring how the classical
  determination reproduces Table II.

On G11-class ±1 MAX-CUT (4-regular): σ = 2, max|z| = 4, so the
determination reproduces Table II exactly (n_rnd = 2, I0max = 32,
τ unchanged) — autotune is a strict generalization of the paper's hand
settings, property-tested in tests/test_autotune.py.

Documented bounds (asserted in tests): n_rnd ∈ [1, 2^16],
I0max a power of two in [8, 2^20], I0min = 1, τ ∈ [8, τ_base·steps_base],
and identical outputs for identical (model, base, n_samples, seed).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from .ising import IsingModel
from .schedule import n_temp_steps
from .ssa import SSAHyperParams

__all__ = [
    "AutotuneReport",
    "sample_local_fields",
    "autotune_hyperparams",
    "resolve_hyperparams",
]

# Documented output bounds (see module docstring).
N_RND_MAX = 1 << 16
I0_MAX_FLOOR = 8
I0_MAX_CEIL = 1 << 20
TAU_FLOOR = 8
N_REPLICAS_MIN = 2
N_REPLICAS_MAX = 16
JPERP_MAX_CEIL = 16


@dataclasses.dataclass(frozen=True)
class AutotuneReport:
    """What the determination measured and decided (observability)."""

    sigma: float          # std of sampled local fields
    z_max: int            # max |local field| over samples
    n_samples: int
    seed: int
    n_rnd: int
    i0_min: int
    i0_max: int
    tau: int
    # SSQA-only (None for classical bases): Trotter depth and Γ0 proxy.
    n_replicas: Optional[int] = None
    jperp_max: Optional[int] = None


def sample_local_fields(
    model: IsingModel, n_samples: int = 64, seed: int = 0
) -> np.ndarray:
    """Local fields z_i = h_i + Σ_j J_ij m_j over S seeded random states.

    Returns an (S, N) int64 array.  Pure NumPy over the padded adjacency —
    deterministic for a fixed seed, independent of backend and device.
    The gather is chunked over samples so the transient (chunk, N, deg)
    buffer stays bounded (~0.5 GB) even for dense large-N models (K2000:
    N·deg ≈ 4M entries per sample).
    """
    n_samples = int(n_samples)
    rng = np.random.default_rng(seed)
    m = rng.integers(0, 2, size=(n_samples, model.n)) * 2 - 1  # ±1
    nbr_idx = np.asarray(model.nbr_idx)
    nbr_w = np.asarray(model.nbr_w, dtype=np.int64)
    h = np.asarray(model.h, np.int64)
    chunk = max(1, int(2**26 // max(model.n * model.max_degree, 1)))
    out = np.empty((n_samples, model.n), dtype=np.int64)
    for s0 in range(0, n_samples, chunk):
        ms = m[s0 : s0 + chunk]
        neigh = ms[:, nbr_idx]  # (chunk, N, D)
        out[s0 : s0 + chunk] = h + (nbr_w * neigh).sum(axis=-1)
    return out


def _next_pow2(v: int) -> int:
    v = int(v)
    return 1 if v <= 1 else 1 << (v - 1).bit_length()


def autotune_hyperparams(
    model: IsingModel,
    base: Optional[SSAHyperParams] = None,
    *,
    n_samples: int = 64,
    seed: int = 0,
) -> Tuple[SSAHyperParams, AutotuneReport]:
    """Derive per-instance SSA hyperparameters from the local-field sample.

    ``base`` supplies the *budget* knobs (n_trials, m_shot, the per-iteration
    cycle budget via tau·steps, beta_shift); the *energy-scale* knobs
    (n_rnd, i0_min, i0_max) and the per-plateau τ are determined here.
    ``base``'s concrete type is preserved (``dataclasses.replace``): an
    :class:`~repro.core.ssqa.SSQAHyperParams` base additionally gets its
    Trotter depth and J⊥ ramp ceiling determined from the same σ, with
    n_trials rounded up to whole replica rings.
    Deterministic for fixed (model, base, n_samples, seed).
    """
    base = base if base is not None else SSAHyperParams()
    z = sample_local_fields(model, n_samples=n_samples, seed=seed)
    sigma = float(z.std())
    z_max = int(np.abs(z).max(initial=1))

    n_rnd = int(np.clip(round(sigma), 1, N_RND_MAX))
    i0_max = int(np.clip(_next_pow2(8 * z_max), I0_MAX_FLOOR, I0_MAX_CEIL))
    i0_min = 1

    # Per-plateau schedule scaling: keep the caller's per-iteration cycle
    # budget (steps·τ) as the plateau count changes with the clamp range.
    steps_base = n_temp_steps(base.i0_min, base.i0_max, base.beta_shift)
    steps = n_temp_steps(i0_min, i0_max, base.beta_shift)
    tau = int(np.clip(round(steps_base * base.tau / steps), TAU_FLOOR, None))

    updates = dict(n_rnd=n_rnd, i0_min=i0_min, i0_max=i0_max, tau=tau)
    n_replicas = jperp_max = None
    if hasattr(base, "n_replicas"):
        # SSQA: the Trotter ring depth and the coldest-plateau coupling are
        # both functions of the same local-field scale (module docstring).
        n_replicas = int(np.clip(
            _next_pow2(max(2, round(4 * sigma))), N_REPLICAS_MIN, N_REPLICAS_MAX
        ))
        jperp_max = int(np.clip(round(2 * sigma), 1, JPERP_MAX_CEIL))
        updates.update(
            n_replicas=n_replicas,
            jperp_max=jperp_max,
            # Round the trial budget up to whole rings.
            n_trials=-(-base.n_trials // n_replicas) * n_replicas,
        )
    hp = dataclasses.replace(base, **updates)
    report = AutotuneReport(
        sigma=sigma,
        z_max=z_max,
        n_samples=int(n_samples),
        seed=int(seed),
        n_rnd=n_rnd,
        i0_min=i0_min,
        i0_max=i0_max,
        tau=tau,
        n_replicas=n_replicas,
        jperp_max=jperp_max,
    )
    return hp, report


def resolve_hyperparams(
    hp,
    model: IsingModel,
    *,
    base: Optional[SSAHyperParams] = None,
    seed: int = 0,
    algo: Optional[str] = None,
) -> Tuple[SSAHyperParams, Optional[AutotuneReport]]:
    """Resolve a request's hyperparameter spec: pass through or autotune.

    ``hp='auto'`` (the :class:`~repro.serve.AnnealRequest` mode) maps to
    :func:`autotune_hyperparams` on the unpadded model; concrete
    hyperparameter objects pass through untouched.  The autotune draw is
    seeded independently of the anneal seed so identical problems resolve
    to identical hyperparameters and keep batching together in the service.

    ``algo`` selects the default *base* family when none is supplied:
    ``'ssqa'`` autotunes from :class:`~repro.core.ssqa.SSQAHyperParams`
    (adding the Trotter-ring determination); anything else — or ``None`` —
    keeps the classical :class:`~repro.core.ssa.SSAHyperParams` base.
    """
    if isinstance(hp, str):
        if hp != "auto":
            raise ValueError(f"unknown hyperparameter mode {hp!r}; use 'auto'")
        if base is None and algo == "ssqa":
            from .ssqa import SSQAHyperParams  # lazy: ssqa imports autotune

            base = SSQAHyperParams()
        if hasattr(model, "to_ising"):
            model = model.to_ising()
        return autotune_hyperparams(model, base, seed=seed)
    return hp, None
