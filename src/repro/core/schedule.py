"""Pseudo-inverse temperature schedules (paper Sec. II-B Eq. 3, Sec. III-A Eq. 4).

SSA (Eq. 3):     I0(t+τ) = I0(t) / β          with real β < 1  (needs an FP divider)
HA-SSA (Eq. 4):  I0(t+τ) = 2^β · I0(t)        with integer β   (a barrel shift)

Both raise I0 from I0min to I0max in geometric steps held for τ cycles.  When
β_ssa = 2^{-β_hassa} the two schedules are *identical* (paper Sec. III-A:
"When β in Eq. (3) is 0.5 and β in Eq. (4) is 1, the temperature control of
HA-SSA is the same as that of SSA") — property-tested in
tests/test_core_schedule.py.

HA-SSA also switches duration control from cycle count to **iteration count**
(m_shot full I0min→I0max sweeps), so the final sweep always completes
(Sec. III-A's 600-cycle/10,000-cycle example).  Both annealers here are
iteration-controlled; the conventional-SSA cycle-count mode is exposed for the
Fig. 12 comparison.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Optional

import numpy as np

__all__ = [
    "Schedule",
    "hassa_schedule",
    "ssa_schedule",
    "ssqa_schedule",
    "n_temp_steps",
]


def n_temp_steps(i0_min: int, i0_max: int, beta_shift: int = 1) -> int:
    """Number of distinct temperature plateaus in one iteration.

    For i0_min=1, i0_max=32, β=1: steps = 6 (1,2,4,8,16,32) — the '6' in the
    paper's 6× memory-efficiency claim (Eq. 5 vs Eq. 6).
    """
    if i0_min <= 0 or i0_max < i0_min:
        raise ValueError("need 0 < i0_min <= i0_max")
    steps = 1
    v = i0_min
    while v < i0_max:
        v <<= beta_shift
        steps += 1
    return steps


@dataclasses.dataclass(frozen=True)
class Schedule:
    """A per-cycle I0 schedule for one iteration.

    Attributes:
      i0_per_cycle: int32[cycles_per_iter] pseudo-inverse temperature per cycle.
      tau: plateau length in cycles.
      steps: number of plateaus.
      store_mask: bool[cycles_per_iter] — True where the HA-SSA hardware
        asserts the BRAM write-enable (I0 == I0max).  Conventional SSA stores
        every cycle (mask of all-True is used instead by the caller).
      jperp_per_cycle: optional int32[cycles_per_iter] transverse-field
        coupling J⊥(t) between adjacent Trotter replicas (SSQA,
        arXiv:2302.12454; DESIGN.md §13).  ``None`` for classical SSA/HA-SSA
        — and the signature payload of a jperp-free schedule is *exactly*
        the historical v1 payload, so pre-SSQA executable caches and
        checkpoint fingerprints stay valid.
    """

    i0_per_cycle: np.ndarray
    tau: int
    steps: int
    store_mask: np.ndarray
    jperp_per_cycle: Optional[np.ndarray] = None

    @property
    def cycles_per_iter(self) -> int:
        return int(self.i0_per_cycle.shape[0])

    def signature(self) -> str:
        """Stable, hashable identity of the per-cycle program.

        Two schedules that run the same I0 value and assert the same
        write-enable on every cycle are the *same program* regardless of how
        they were built (``hassa_schedule``, ``ssa_schedule``, by hand), so
        the signature hashes only the canonical per-cycle content —
        (i0_per_cycle, store_mask, tau).  ``steps`` is derivable and
        excluded.  Used as the schedule component of the serving layer's
        compiled-executable cache key (serve/anneal_service.py).
        """
        payload = (
            "Schedule/v1",
            tuple(int(x) for x in np.asarray(self.i0_per_cycle)),
            tuple(bool(x) for x in np.asarray(self.store_mask)),
            int(self.tau),
        )
        if self.jperp_per_cycle is not None:
            # SSQA schedules carry the replica coupling; a distinct version
            # tag guarantees no collision with any classical v1 signature.
            payload = (
                "Schedule/v2-ssqa",
                payload,
                tuple(int(x) for x in np.asarray(self.jperp_per_cycle)),
            )
        return hashlib.sha256(repr(payload).encode()).hexdigest()[:16]


def hassa_schedule(i0_min: int, i0_max: int, tau: int, beta_shift: int = 1) -> Schedule:
    """Eq. (4): integer-only, shift-based plateau sequence."""
    if beta_shift < 1:
        raise ValueError("beta_shift must be >= 1")
    plateaus = []
    v = int(i0_min)
    while True:
        plateaus.append(min(v, int(i0_max)))
        if plateaus[-1] >= i0_max:
            break
        v <<= beta_shift
    plateaus = np.asarray(plateaus, dtype=np.int32)
    i0 = np.repeat(plateaus, tau)
    mask = np.repeat(plateaus == i0_max, tau)
    return Schedule(i0_per_cycle=i0, tau=tau, steps=len(plateaus), store_mask=mask)


def ssa_schedule(i0_min: int, i0_max: int, tau: int, beta: float = 0.5) -> Schedule:
    """Eq. (3): real-β division-based plateau sequence (conventional SSA).

    The reference implementation keeps integer I0 plateaus (the paper found
    integer representations sufficient, Sec. III-A); division by β<1 raises I0.
    """
    if not (0.0 < beta < 1.0):
        raise ValueError("ssa beta must be in (0,1)")
    plateaus = []
    v = float(i0_min)
    while True:
        plateaus.append(min(int(round(v)), int(i0_max)))
        if plateaus[-1] >= i0_max:
            break
        v = v / beta
    plateaus = np.asarray(plateaus, dtype=np.int32)
    i0 = np.repeat(plateaus, tau)
    mask = np.repeat(plateaus == i0_max, tau)
    return Schedule(i0_per_cycle=i0, tau=tau, steps=len(plateaus), store_mask=mask)


def ssqa_schedule(
    i0_min: int,
    i0_max: int,
    tau: int,
    beta_shift: int = 1,
    *,
    jperp_max: int = 4,
) -> Schedule:
    """SSQA plateau sequence (arXiv:2302.12454): HA-SSA's I0 ramp plus a
    transverse-field coupling ramp J⊥(t).

    The physical schedule anneals the transverse field Γ(t) from Γ0 down to
    ~0; the effective replica coupling J⊥ ∝ -½·T·ln tanh(Γ/(R·T)) *rises* as
    Γ falls, so on the integer datapath we carry J⊥ directly: 0 at the
    hottest plateau (free replicas ≙ large Γ) ramping linearly to
    ``jperp_max`` at the coldest (I0 == I0max, replicas locked ≙ Γ→0).
    Integer J⊥ keeps the update field exact int32 like every other term.
    """
    base = hassa_schedule(i0_min, i0_max, tau, beta_shift)
    steps = base.steps
    if steps == 1:
        per_plateau = np.asarray([int(jperp_max)], dtype=np.int32)
    else:
        per_plateau = np.asarray(
            [round(int(jperp_max) * s / (steps - 1)) for s in range(steps)],
            dtype=np.int32,
        )
    return Schedule(
        i0_per_cycle=base.i0_per_cycle,
        tau=base.tau,
        steps=base.steps,
        store_mask=base.store_mask,
        jperp_per_cycle=np.repeat(per_plateau, tau),
    )


def sa_temperature_ladder(t_start: float, t_end: float, n_cycles: int) -> np.ndarray:
    """Geometric SA cooling from t_start to t_end over n_cycles (Sec. IV-A:
    'temperature of SA gradually decreases from 10 to 1e-7 during 90,000
    cycles')."""
    if n_cycles == 1:
        return np.asarray([t_start], dtype=np.float32)
    ratio = (t_end / t_start) ** (1.0 / (n_cycles - 1))
    return (t_start * ratio ** np.arange(n_cycles)).astype(np.float32)
