"""Plateau-structured annealing engine (DESIGN.md §2).

The paper's HA-SSA treats the temperature *plateau* — τ cycles at constant
pseudo-inverse temperature I0 — as the natural unit of execution and of
storage (Eq. 4–6): the schedule advances plateau-by-plateau, and the BRAM
write-enable is a *per-plateau* predicate (I0 == I0max), not a per-cycle
mask.  This module makes the plateau the unit of the software architecture
too:

* :class:`PlateauBackend` — the pluggable execution protocol
  (``init_state / run_plateau / finalize``).  A backend advances one
  constant-I0 plateau of C cycles at a time; everything above it (drivers,
  the distributed iteration step, benchmarks, the serving batch API) is
  backend-agnostic.
* :class:`SparseBackend` / :class:`DenseBackend` — `lax.scan` implementations
  over one plateau sharing :func:`run_plateau_scan`.  The local-field
  contraction runs **once per cycle**: the field computed for the Eq. (2a)
  update of state m(t) is reused to evaluate H(m(t)) for solution tracking
  and energy traces (the seed implementation evaluated it twice in
  ``record='best'`` mode).
* :class:`PallasBackend` — the resident plateau kernel: one ``pallas_call``
  per plateau with J pinned in VMEM.  With xorshift noise this is the
  streamed-noise packed kernel
  (:func:`repro.kernels.ssa_update.ssa_plateau_packed`): uint32-bitplane
  HBM refs, per-cycle noise generated in-kernel from carried xorshift
  lanes — no (C, R, N) noise buffer exists anywhere.  Per-cycle HBM traffic
  drops from O(N²) to O(R·N) — the TPU transcription of the FPGA's
  "everything on-chip" design point.

Storage layouts (DESIGN.md §4): every backend carries a
``storage_layout`` axis — 'dense' keeps :class:`EngineState` (int8 spins),
'packed' keeps :class:`PackedEngineState` (uint32 bitplanes between
launches).  Results are bit-identical; only the resident bytes differ.
Dense-field backends additionally carry ``j_mode`` — 'tiled' streams
(tile_n, N) J slabs instead of materializing (N, N), admitting
G77/G81-class instances.

HA-SSA's storage policy is expressed as per-plateau *eligibility*: a plateau
with ``eligible=True`` folds the states it produces into the running
arg-best (record='best') or emits their bit-packed planes (record='traj').
Under ``storage='i0max'`` only the final plateau of each iteration is
eligible; ``storage='all'`` recovers conventional SSA.

Tracking semantics (shared by all backends, matching the resident kernel and
:mod:`repro.kernels.ref`): within a plateau starting at state m(t0), the
states *produced by this plateau* — m(t0+1) … m(t0+C) — are folded into the
running best under this plateau's eligibility.  The incoming state m(t0)
belongs to the previous plateau and is skipped; the final state m(t0+C) is
folded by one extra field evaluation after the cycle loop.  Chained over a
schedule this tracks every state exactly once, under the eligibility of the
plateau that produced it — bit-identical across backends and to the seed's
flat per-cycle scan.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from .ising import (
    IsingModel,
    MaxCutProblem,
    local_fields_dense,
    local_fields_popcount,
    local_fields_sparse,
    local_fields_tiled,
)
from .rng import (
    threefry_noise,
    xorshift_init,
    xorshift_init_slice,
    xorshift_next_bits,
)
from .schedule import Schedule

__all__ = [
    "BIG_ENERGY",
    "TILED_J_THRESHOLD",
    "MIN_RESIDENT_N",
    "POPCOUNT_AUTO_MAX_BITS",
    "BaseResult",
    "EngineState",
    "PackedEngineState",
    "pack_state",
    "unpack_state",
    "Plateau",
    "PlateauBackend",
    "SparseBackend",
    "DenseBackend",
    "PallasBackend",
    "BACKENDS",
    "make_backend",
    "resolve_backend",
    "resolve_field_mode",
    "resolve_j_mode",
    "resolve_noise_mode",
    "resolve_partition",
    "spin_axis_size",
    "SPIN_SHARD_MIN_N",
    "MAX_UNSHARDED_SPINS",
    "model_weight_bits",
    "plateau_cycle_schedules",
    "normalize_problem",
    "validate_model",
    "MAX_MODEL_SPINS",
    "finalize_cut",
    "schedule_plateaus",
    "tile_plateaus",
    "run_plateau_scan",
    "run_schedule",
    "pack_spins",
    "unpack_spins",
    "packed_words",
    "ssa_cycle_update",
    "energy_from_field",
    "next_pow2",
    "bucket_n",
    "pad_model",
    "pad_degree",
    "extract_slot",
    "splice_slot",
    "padded_noise_init",
    "padded_noise_init_slice",
    "BatchedBackend",
    "BatchedSparseBackend",
    "BatchedDenseBackend",
    "BatchedPallasBackend",
    "BATCHED_BACKENDS",
    "make_batched_backend",
]

# Sentinel "no solution yet" energy (any real H is far below this).
BIG_ENERGY = 2**30

# Dense (N, N) J above this spin count is not materialized: j_mode='auto'
# resolves to the tiled path that streams (tile_n, N) slabs instead.
TILED_J_THRESHOLD = 4096

# Below this spin count the resident Pallas kernel's launch overhead beats
# its residency win (measured: ~2.4 s pallas vs ~1.5 s dense on the 32-spin
# frontend smokes) — backend='auto' dispatches the scan backends instead.
# Asserted structurally in benchmarks/other_problems.py --smoke.
MIN_RESIDENT_N = 256

# field_mode='auto' uses the XNOR-popcount contraction up to this many
# magnitude bitplanes (the paper's hardware is 4-bit); wider integer weights
# fall back to the f32 matmul, whose cost is bit-depth independent.
POPCOUNT_AUTO_MAX_BITS = 4


# ---------------------------------------------------------------------------
# Bit packing (the 800-bit BRAM word, as uint32 lanes) — the codec lives in
# repro.kernels.bitplane so the Pallas kernels and the engine share one bit
# layout; re-exported here for the core-level callers.
# ---------------------------------------------------------------------------
from repro.kernels.bitplane import (  # noqa: E402
    PackedJ,
    adjacency_weight_bits,
    pack_couplings_from_adjacency,
    pack_spins,
    packed_words,
    unpack_spins,
)


def model_weight_bits(model: IsingModel) -> int:
    """Magnitude bitplanes a model's couplings need (coalesced max |J_ij|)."""
    return adjacency_weight_bits(model.n, model.nbr_idx, model.nbr_w)


# ---------------------------------------------------------------------------
# The p-bit update (Eq. 2a–2c), shared by every backend and the kernel oracle
# ---------------------------------------------------------------------------
def ssa_cycle_update(field, itanh, r, i0, n_rnd):
    """Elementwise epilogue of one SSA cycle.

    Args:
      field: int32[..., N]  h_i + Σ_j J_ij m_j(t)      (the matvec part)
      itanh: int32[..., N]  Itanh_i(t)
      r:     int32[..., N]  noise in {-1,+1}
      i0:    int32 scalar   pseudo-inverse temperature I0(t)
      n_rnd: int            noise magnitude
    Returns:
      (m_new int8[...,N], itanh_new int32[...,N])
    """
    I = field + n_rnd * r + itanh  # noqa: E741 — Eq. (2a) current
    itanh_new = jnp.clip(I, -i0, i0 - 1)                # (2b)
    m_new = jnp.where(itanh_new >= 0, 1, -1).astype(jnp.int8)  # (2c)
    return m_new, itanh_new


def energy_from_field(m, field, h):
    """H = -(h·m + m·field)/2, exact int32 (field = h + Jm)."""
    m32 = m.astype(jnp.int32)
    hm = jnp.sum(h * m32, axis=-1)
    mf = jnp.sum(m32 * field, axis=-1)
    return -(hm + mf) // 2


# ---------------------------------------------------------------------------
# Problem / result plumbing shared by the SSA, SA and PT drivers
# ---------------------------------------------------------------------------
def normalize_problem(
    problem: Union[MaxCutProblem, IsingModel, Any],
) -> Tuple[Optional[MaxCutProblem], IsingModel]:
    """Split a problem into (maxcut-or-None, IsingModel).

    Accepts a :class:`MaxCutProblem`, a raw :class:`IsingModel`, or any
    encoded problem exposing an IsingModel ``model`` attribute (the
    :class:`repro.problems.ProblemEncoding` frontend) — duck-typed so the
    engine never imports the problems package.
    """
    if isinstance(problem, MaxCutProblem):
        return problem, problem.to_ising()
    if isinstance(problem, IsingModel):
        return None, problem
    model = getattr(problem, "model", None)
    if isinstance(model, IsingModel):
        return None, model
    raise TypeError(
        f"cannot interpret {type(problem).__name__} as an annealing problem; "
        "pass a MaxCutProblem, an IsingModel, or a ProblemEncoding"
    )


# Admission ceiling on the spin count: far above anything the backends can
# actually serve today (G81 is 20k), but low enough that a corrupted or
# adversarial shape is rejected before any padding/stacking is attempted.
MAX_MODEL_SPINS = 1 << 22


def validate_model(model: IsingModel, *, max_spins: int = MAX_MODEL_SPINS):
    """Admission-time structural validation of an Ising model.

    :meth:`IsingModel.from_edges` / :meth:`~IsingModel.from_dense` validate
    at construction, but the dataclass can also be built directly — the
    serving layer re-checks here so a malformed model is rejected with a
    clear error instead of poisoning a compiled batch.  Raises ValueError
    (callers wrap it into their own typed admission error).
    """
    n = int(model.n)
    if n <= 0:
        raise ValueError(f"model {model.name!r}: need n > 0, got {n}")
    if n > max_spins:
        raise ValueError(
            f"model {model.name!r}: n={n} exceeds the service ceiling "
            f"{max_spins} — absurd shape rejected at admission"
        )
    h = np.asarray(model.h)
    idx = np.asarray(model.nbr_idx)
    w = np.asarray(model.nbr_w)
    if h.shape != (n,):
        raise ValueError(f"model {model.name!r}: h shape {h.shape} != ({n},)")
    if idx.ndim != 2 or idx.shape[0] != n or idx.shape != w.shape:
        raise ValueError(
            f"model {model.name!r}: adjacency shapes nbr_idx {idx.shape} / "
            f"nbr_w {w.shape} inconsistent with n={n}"
        )
    for name, arr in (("h", h), ("nbr_w", w)):
        if not np.all(np.isfinite(arr.astype(np.float64, copy=False))):
            raise ValueError(
                f"model {model.name!r}: non-finite values in {name}"
            )
    if idx.size and (int(idx.min()) < 0 or int(idx.max()) >= n):
        raise ValueError(
            f"model {model.name!r}: neighbor indices outside [0, {n})"
        )


def finalize_cut(best_H, maxcut: Optional[MaxCutProblem]):
    """Map best Ising energies to the reported objective (cut or -H)."""
    if maxcut is not None:
        return (maxcut.w_total - best_H) // 2
    return -best_H


@dataclasses.dataclass
class BaseResult:
    """Outcome fields shared by the SSA/HA-SSA, SA and PT drivers."""

    best_cut: np.ndarray          # best objective per trial (cut for maxcut)
    best_energy: np.ndarray       # Ising energy of the best tracked state
    best_m: np.ndarray            # spins of the best tracked state
    energy_mean: Optional[np.ndarray]  # per-cycle mean H over trials
    energy_min: Optional[np.ndarray]   # per-cycle min H over trials

    @property
    def overall_best_cut(self) -> int:
        return int(np.max(self.best_cut))

    @property
    def mean_best_cut(self) -> float:
        return float(np.mean(self.best_cut))


# ---------------------------------------------------------------------------
# Plateaus: the schedule, grouped into its natural execution unit
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Plateau:
    """One constant-I0 run of cycles — HA-SSA's unit of execution/storage.

    ``eligible`` is the storage write-enable for the states this plateau
    *produces*: under HA-SSA (Eq. 6) only the I0 == I0max plateau asserts it;
    conventional SSA (Eq. 5) asserts it everywhere.

    ``jperp`` is the SSQA Trotter-replica ring coupling J⊥ held over this
    plateau (DESIGN.md §13); 0 — the default, and the only value classical
    SSA/HA-SSA schedules produce — disables the coupling entirely.
    """

    i0: int
    length: int
    eligible: bool
    jperp: int = 0


def _group_runs(
    i0_seq: np.ndarray, elig_seq: np.ndarray, jperp_seq=None
) -> Tuple[Plateau, ...]:
    jp = (
        np.zeros(len(i0_seq), np.int64)
        if jperp_seq is None
        else np.asarray(jperp_seq)
    )
    out = []
    start = 0
    n = len(i0_seq)
    for k in range(1, n + 1):
        if (
            k == n
            or i0_seq[k] != i0_seq[start]
            or elig_seq[k] != elig_seq[start]
            or jp[k] != jp[start]
        ):
            out.append(
                Plateau(
                    int(i0_seq[start]),
                    k - start,
                    bool(elig_seq[start]),
                    int(jp[start]),
                )
            )
            start = k
    return tuple(out)


def schedule_plateaus(sched: Schedule, storage: str = "i0max") -> Tuple[Plateau, ...]:
    """Group one iteration's per-cycle schedule into plateaus.

    storage='i0max' → HA-SSA eligibility (the BRAM write-enable);
    storage='all'   → every plateau eligible (conventional SSA).
    SSQA schedules additionally carry ``jperp_per_cycle``, split at the
    same plateau boundaries.
    """
    i0 = np.asarray(sched.i0_per_cycle)
    if storage == "i0max":
        elig = np.asarray(sched.store_mask)
    elif storage == "all":
        elig = np.ones(len(i0), dtype=bool)
    else:
        raise ValueError(f"unknown storage {storage!r}")
    return _group_runs(i0, elig, getattr(sched, "jperp_per_cycle", None))


def tile_plateaus(plateaus: Sequence[Plateau], total_cycles: int) -> Tuple[Plateau, ...]:
    """Tile an iteration's plateau list to exactly ``total_cycles`` cycles,
    truncating the final plateau (conventional-SSA cycle-count duration,
    paper Fig. 12 mode)."""
    if not plateaus and total_cycles > 0:
        raise ValueError("cannot tile an empty plateau sequence")
    out = []
    remaining = int(total_cycles)
    while remaining > 0:
        for p in plateaus:
            if remaining <= 0:
                break
            take = min(p.length, remaining)
            out.append(Plateau(p.i0, take, p.eligible, p.jperp))
            remaining -= take
    return tuple(out)


def plateau_cycle_schedules(plateaus: Sequence[Plateau]):
    """Per-cycle schedule operands for the multi-plateau resident kernel.

    Flattens a plateau chain into ``(i0_sched (C,), fold_sched (C+1,),
    jperp_sched (C,))`` int32 host arrays: ``i0_sched[c]`` is the I0 of
    cycle c, ``fold_sched[c]`` the storage write-enable of the plateau that
    *produced* the state current at cycle c — 0 at c = 0 (the chain's
    incoming state belongs to the previous chunk), eligibility of cycle
    c−1's plateau for c ≥ 1, and ``fold_sched[C]`` covers the final state —
    and ``jperp_sched[c]`` the replica coupling applied by cycle c's update
    (all-zero for classical chains).  Feeding these to
    `ssa_plateau_popcount[_batched]` reproduces chained per-plateau
    execution bit-identically in one launch.
    """
    i0s, elig, jps = [], [], []
    for p in plateaus:
        i0s.extend([int(p.i0)] * int(p.length))
        elig.extend([int(bool(p.eligible))] * int(p.length))
        jps.extend([int(p.jperp)] * int(p.length))
    if not i0s:
        raise ValueError("empty plateau chain")
    return (
        np.asarray(i0s, np.int32),
        np.asarray([0] + elig, np.int32),
        np.asarray(jps, np.int32),
    )


# ---------------------------------------------------------------------------
# Engine state and the shared one-plateau scan
# ---------------------------------------------------------------------------
class EngineState(NamedTuple):
    """Carry threaded through plateaus; canonical spin dtype is int8 ±1."""

    noise_state: Any         # xorshift (4,T,N) u32 lanes or a threefry key
    m: jnp.ndarray           # (T, N) int8 spins
    itanh: jnp.ndarray       # (T, N) int32 Itanh FSM state
    best_H: jnp.ndarray      # (T,) int32 running best energy
    best_m: jnp.ndarray      # (T, N) int8 spins of the running best


class PackedEngineState(NamedTuple):
    """EngineState with spins stored as uint32 bitplanes (DESIGN.md §4).

    Under ``storage_layout='packed'`` this is the state that lives in HBM
    between plateau/chunk launches: spins and best-spins occupy 1 bit per
    (trial, spin) — 8× below int8, 32× below the float32 crossing the old
    kernel boundary — matching the FPGA's one-spin-per-BRAM-bit layout.
    The Itanh FSM counter stays int32 (it is genuinely multi-bit state).
    """

    noise_state: Any              # xorshift (4,T,N) u32 lanes or threefry key
    m_packed: jnp.ndarray         # (T, ceil(N/32)) uint32 bitplanes
    itanh: jnp.ndarray            # (T, N) int32
    best_H: jnp.ndarray           # (T,) int32
    best_m_packed: jnp.ndarray    # (T, ceil(N/32)) uint32


def pack_state(state: EngineState) -> PackedEngineState:
    """Pack an engine state's spin planes (exact: spins are ±1)."""
    return PackedEngineState(
        state.noise_state,
        pack_spins(state.m),
        state.itanh,
        state.best_H,
        pack_spins(state.best_m),
    )


def unpack_state(state: PackedEngineState, n: int) -> EngineState:
    """Inverse of :func:`pack_state` for an N-spin model."""
    return EngineState(
        state.noise_state,
        unpack_spins(state.m_packed, n),
        state.itanh,
        state.best_H,
        unpack_spins(state.best_m_packed, n),
    )


def replica_coupling(m: jnp.ndarray, n_replicas: int) -> jnp.ndarray:
    """Sum of ring-adjacent Trotter-replica spins, per (trial, spin) lane.

    The trial axis (axis -2 of ``(..., T, N)`` spins) is G = T/R independent
    rings of R consecutive replicas — the same grouping the resident kernels
    use (one R-tile per ring), so scan and kernel paths couple identical
    neighbor pairs.  Returns int32 ``m[k-1] + m[k+1]`` with ring wraparound
    (for R = 2 the single neighbor is counted from both sides, the standard
    doubled edge of a 2-cycle).
    """
    R = int(n_replicas)
    shape = m.shape
    T = shape[-2]
    if T % R:
        raise ValueError(f"n_trials {T} not divisible by n_replicas {R}")
    mr = m.reshape(shape[:-2] + (T // R, R, shape[-1])).astype(jnp.int32)
    nb = jnp.roll(mr, 1, axis=-2) + jnp.roll(mr, -1, axis=-2)
    return nb.reshape(shape[:-2] + (T, shape[-1]))


def run_plateau_scan(
    field_fn: Callable[[jnp.ndarray], jnp.ndarray],
    noise_step: Callable,
    h: jnp.ndarray,
    n_rnd: int,
    state: EngineState,
    i0,
    *,
    length: int,
    eligible: bool,
    track_energy: bool = False,
    emit: bool = False,
    energy_fn: Callable = None,
    jperp: int = 0,
    n_replicas: int = 0,
):
    """One constant-I0 plateau as a `lax.scan` — ONE contraction per cycle.

    The field computed for the Eq. (2a) update of m(t) doubles as the field
    needed for H(m(t)); the scan's first step skips best-tracking because
    m(t0) belongs to the previous plateau, and one epilogue field evaluation
    folds the final state m(t0+C) — exactly the resident kernel's semantics
    (kernels/ssa_update.py, kernels/ref.py).

    ``energy_fn(m, field, h)`` overrides :func:`energy_from_field` for the
    best-fold/trace evaluations — the spin-sharded step passes a variant
    that psums per-shard partial sums over the model axis (int32 addition is
    exact and order-free, so the fold stays bit-identical; DESIGN.md §11).

    ``jperp``/``n_replicas`` enable SSQA's Trotter-replica ring coupling
    (DESIGN.md §13): the Eq. (2a) *update* field gains
    ``jperp · (m[k-1] + m[k+1])`` over :func:`replica_coupling` rings on the
    trial axis, while the best-fold/trace energies keep the BASE field — the
    coupling steers the dynamics, the reported energy stays the classical
    per-replica Ising energy.

    Returns (state', trace, planes) where trace is (mean_H (C,), min_H (C,))
    aligned to the produced states m(t0+1..t0+C) when ``track_energy``, and
    planes is the (C, T, ceil(N/32)) bit-packed trajectory when ``emit``.
    """
    if energy_fn is None:
        energy_fn = energy_from_field
    i0 = jnp.asarray(i0, jnp.int32)
    eligible = bool(eligible)
    track_energy = bool(track_energy)
    emit = bool(emit)
    need_H = eligible or track_energy
    jperp = int(jperp)
    couple = bool(jperp) and int(n_replicas) > 0

    def cyc(carry, not_first):
        ns, m, itanh, best_H, best_m = carry
        field = field_fn(m)
        ys = {}
        if need_H:
            H = energy_fn(m, field, h)
            if eligible:
                better = not_first & (H < best_H)
                best_H = jnp.where(better, H, best_H)
                best_m = jnp.where(better[..., None], m, best_m)
            if track_energy:
                ys["mean"] = jnp.mean(H.astype(jnp.float32))
                ys["min"] = jnp.min(H)
        ns, r = noise_step(ns)
        upd = field
        if couple:
            upd = field + (
                jperp * replica_coupling(m, n_replicas)
            ).astype(field.dtype)
        m_new, it_new = ssa_cycle_update(upd, itanh, r, i0, n_rnd)
        if emit:
            ys["plane"] = pack_spins(m_new)
        return (ns, m_new, it_new, best_H, best_m), ys

    not_first = jnp.arange(length) > 0
    carry, ys = jax.lax.scan(cyc, tuple(state), not_first)
    ns, m, itanh, best_H, best_m = carry

    trace = None
    if need_H:
        # Epilogue: the plateau's final state needs one extra field.
        field = field_fn(m)
        H = energy_fn(m, field, h)
        if eligible:
            better = H < best_H
            best_H = jnp.where(better, H, best_H)
            best_m = jnp.where(better[..., None], m, best_m)
        if track_energy:
            trace = (
                jnp.concatenate(
                    [ys["mean"][1:], jnp.mean(H.astype(jnp.float32))[None]]
                ),
                jnp.concatenate([ys["min"][1:], jnp.min(H)[None]]),
            )
    planes = ys["plane"] if emit else None
    return EngineState(ns, m, itanh, best_H, best_m), trace, planes


# ---------------------------------------------------------------------------
# Backends
# ---------------------------------------------------------------------------
class PlateauBackend:
    """The pluggable execution protocol: init_state / run_plateau / finalize.

    Subclasses provide the local-field contraction (and may override the
    whole plateau execution, as the Pallas backend does).  Everything above
    this protocol — the `anneal` driver, the distributed iteration step, the
    benchmarks and the batch API — is backend-agnostic.
    """

    name = "abstract"

    def __init__(
        self,
        model: IsingModel,
        *,
        n_trials: int,
        n_rnd: int = 2,
        noise: str = "threefry",
        storage_layout: str = "dense",
        n_replicas: int = 0,
    ):
        if storage_layout not in ("dense", "packed"):
            raise ValueError(f"unknown storage_layout {storage_layout!r}")
        self.model = model
        self.n_trials = int(n_trials)
        self.n_rnd = int(n_rnd)
        self.noise = noise
        self.storage_layout = storage_layout
        self.n_replicas = int(n_replicas)
        if self.n_replicas:
            if self.n_replicas < 2:
                raise ValueError("n_replicas must be >= 2 (or 0 to disable)")
            if self.n_trials % self.n_replicas:
                raise ValueError(
                    f"n_trials {self.n_trials} not divisible by "
                    f"n_replicas {self.n_replicas}"
                )
        self.h = jnp.asarray(model.h, jnp.int32)
        lanes = (self.n_trials, model.n)
        if noise == "xorshift":
            self._noise_init = lambda seed: xorshift_init(seed, lanes)  # noqa: E731
            self._noise_step = xorshift_next_bits
        elif noise == "threefry":
            self._noise_init = lambda seed: jax.random.PRNGKey(seed)  # noqa: E731

            def step(key):
                key, sub = jax.random.split(key)
                return key, threefry_noise(sub, lanes)

            self._noise_step = step
        else:
            raise ValueError(f"unknown noise {noise!r}")

    # -- protocol ---------------------------------------------------------
    def init_state(self, seed: int):
        """Random ±1 start from the first noise draw (shared stream layout).

        Returns :class:`EngineState` (storage_layout='dense') or
        :class:`PackedEngineState` (storage_layout='packed'); drivers stay
        layout-agnostic by only touching state through backend methods.
        """
        ns = self._noise_init(seed)
        ns, r0 = self._noise_step(ns)
        m0 = r0.astype(jnp.int8)
        itanh0 = jnp.where(m0 > 0, 0, -1).astype(jnp.int32)
        best_H = jnp.full((self.n_trials,), BIG_ENERGY, jnp.int32)
        st = EngineState(ns, m0, itanh0, best_H, m0)
        return pack_state(st) if self.storage_layout == "packed" else st

    def run_plateau(
        self,
        state,
        i0,
        *,
        length: int,
        eligible: bool,
        track_energy: bool = False,
        emit: bool = False,
        jperp: int = 0,
    ):
        """Advance one plateau in this backend's storage layout.

        The packed layout wraps the dense implementation in the exact
        pack/unpack codec (spins are ±1, so the round trip is bit-exact);
        the Pallas backend overrides this to keep the HBM-facing kernel
        refs packed end-to-end.  ``jperp`` is the SSQA replica coupling
        (requires a backend built with ``n_replicas > 0``).
        """
        if self.storage_layout == "packed":
            st = unpack_state(state, self.model.n)
            st, trace, planes = self._run_plateau_dense(
                st, i0, length=length, eligible=eligible,
                track_energy=track_energy, emit=emit, jperp=jperp,
            )
            return pack_state(st), trace, planes
        return self._run_plateau_dense(
            state, i0, length=length, eligible=eligible,
            track_energy=track_energy, emit=emit, jperp=jperp,
        )

    def run_plateaus(self, state, plateaus: Sequence[Plateau]):
        """Advance a whole plateau chain (record='best', no traces).

        The default chains :meth:`run_plateau`; resident backends override
        it to execute the chain in one launch (multi-plateau residency).
        Bit-identical either way — the chain semantics are defined by the
        per-plateau fold rules.
        """
        for p in plateaus:
            state, _, _ = self.run_plateau(
                state, p.i0, length=p.length, eligible=p.eligible,
                jperp=p.jperp,
            )
        return state

    def _run_plateau_dense(self, state, i0, *, length, eligible,
                           track_energy=False, emit=False, jperp=0):
        raise NotImplementedError

    def finalize(self, state) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Extract (best_H, best_m int8) after the last plateau."""
        if self.storage_layout == "packed":
            return state.best_H, unpack_spins(state.best_m_packed, self.model.n)
        return state.best_H, state.best_m

    # -- shared scan implementation --------------------------------------
    def _field(self, m: jnp.ndarray) -> jnp.ndarray:
        raise NotImplementedError

    def _run_plateau_scan(self, state, i0, *, length, eligible, track_energy,
                          emit, jperp=0):
        return run_plateau_scan(
            self._field,
            self._noise_step,
            self.h,
            self.n_rnd,
            state,
            i0,
            length=length,
            eligible=eligible,
            track_energy=track_energy,
            emit=emit,
            jperp=jperp,
            n_replicas=self.n_replicas,
        )


class SparseBackend(PlateauBackend):
    """Padded-adjacency gather field (4/8-regular G-set-class instances)."""

    name = "sparse"

    def __init__(self, model: IsingModel, **kw):
        super().__init__(model, **kw)
        _, self.nbr_idx, self.nbr_w = model.device_arrays()

    def _field(self, m):
        return local_fields_sparse(m.astype(jnp.int32), self.h, self.nbr_idx, self.nbr_w)

    def _run_plateau_dense(self, state, i0, *, length, eligible,
                           track_energy=False, emit=False, jperp=0):
        return self._run_plateau_scan(
            state, i0, length=length, eligible=eligible,
            track_energy=track_energy, emit=emit, jperp=jperp,
        )


def resolve_j_mode(j_mode: str, n: int) -> str:
    """'auto' picks tiled above TILED_J_THRESHOLD spins, dense below."""
    if j_mode == "auto":
        return "tiled" if n > TILED_J_THRESHOLD else "dense"
    if j_mode not in ("dense", "tiled"):
        raise ValueError(f"unknown j_mode {j_mode!r}")
    return j_mode


def resolve_field_mode(field_mode: str, j_bits: int) -> str:
    """Field-contraction arithmetic: 'popcount' (XNOR-popcount on uint32
    bitplanes, exact-integer) vs 'dense' (f32 matmul / tiled slabs).
    'auto' uses popcount while the couplings fit POPCOUNT_AUTO_MAX_BITS
    magnitude planes — the contraction costs one XNOR-popcount pass per
    plane, so deep integer weights favor the bit-depth-independent matmul.
    """
    if field_mode == "auto":
        return (
            "popcount" if int(j_bits) <= POPCOUNT_AUTO_MAX_BITS else "dense"
        )
    if field_mode not in ("dense", "popcount"):
        raise ValueError(f"unknown field_mode {field_mode!r}")
    return field_mode


def resolve_backend(backend: str, n: int) -> str:
    """'auto' dispatches the resident Pallas kernel only at or above
    MIN_RESIDENT_N spins; below it the launch overhead loses to the scan
    backends (the measured 32-spin smoke regression), so 'auto' never does.
    Non-'auto' names pass through untouched."""
    if backend == "auto":
        return "pallas" if int(n) >= MIN_RESIDENT_N else "dense"
    return backend


# Spin-sharded execution (DESIGN.md §11). partition='auto' splits the spin
# axis over the mesh's model axis only at/above this N: below it the
# per-cycle all-gather dominates the O(N·Ns) shard contraction it buys.
SPIN_SHARD_MIN_N = 2048

# A single-device (partition='problem') plateau program above this many spins
# is rejected at service admission: the per-cycle state alone (itanh i32 +
# lanes 4×u32 per (trial, spin)) makes the unsharded path the wrong tool —
# giant requests must route to partition='spin' on a multi-device mesh.
MAX_UNSHARDED_SPINS = 1 << 15


def spin_axis_size(mesh, axis: str = "model") -> int:
    """Devices on a mesh's spin-sharding axis (1 for no mesh / absent axis)."""
    if mesh is None:
        return 1
    try:
        return int(mesh.shape[axis]) if axis in mesh.shape else 1
    except TypeError:
        return 1


def resolve_partition(partition: str, n: int, mesh=None, *,
                      axis: str = "model") -> str:
    """Resolve the work-partitioning axis for an N-spin plateau program.

    'problem' stacks whole problems per device (the PR 3 serving batch);
    'spin' shards the spin axis of each problem over the mesh's ``axis``
    devices via `shard_map` collectives (DESIGN.md §11).  'auto' picks
    'spin' only when a real multi-device axis exists, N is at/above
    SPIN_SHARD_MIN_N, and the shard width divides evenly — otherwise the
    problem-partitioned path is both simpler and faster.
    """
    if partition not in ("problem", "spin", "auto"):
        raise ValueError(f"unknown partition {partition!r}")
    if partition != "auto":
        return partition
    p = spin_axis_size(mesh, axis)
    if p > 1 and int(n) >= SPIN_SHARD_MIN_N and int(n) % p == 0:
        return "spin"
    return "problem"


def resolve_noise_mode(noise_mode: str, noise: str) -> str:
    """Resident-kernel noise datapath: 'streamed' (in-kernel xorshift, no
    noise buffer) vs 'pregen' (the legacy per-plateau (C, R, N) buffer).
    'auto' streams whenever the source is xorshift; threefry cannot be
    reproduced in-kernel, so it always pregenerates."""
    if noise_mode == "auto":
        return "streamed" if noise == "xorshift" else "pregen"
    if noise_mode not in ("streamed", "pregen"):
        raise ValueError(f"unknown noise_mode {noise_mode!r}")
    if noise_mode == "streamed" and noise != "xorshift":
        raise ValueError("noise_mode='streamed' requires noise='xorshift'")
    return noise_mode


class DenseBackend(PlateauBackend):
    """(T,N)·(N,N) MXU matmul field (K2000-class dense instances).

    ``j_mode`` controls the coupling-matrix residency: 'dense' materializes
    (N, N) J once; 'tiled' streams (tile_n, N) slabs scattered on the fly
    from the padded adjacency (:func:`repro.core.ising.local_fields_tiled`) —
    bit-identical, and the only way G77/G81-class N fits in memory.  'auto'
    (the default) switches at TILED_J_THRESHOLD spins.

    ``field_mode`` selects the contraction *arithmetic*: 'popcount' packs J
    as sign/magnitude bitplanes (`kernels.bitplane.PackedJ`, ~32× smaller
    than f32 J) and computes fields by XNOR-popcount on the uint32 words
    (:func:`repro.core.ising.local_fields_popcount`) — exact-integer equal
    to the matmul, so results stay bit-identical.  'auto' uses popcount for
    couplings within POPCOUNT_AUTO_MAX_BITS magnitude planes.  Under
    popcount no J matrix (dense or tiled) is materialized at all.
    """

    name = "dense"

    def __init__(self, model: IsingModel, *, j_dtype=jnp.float32,
                 j_mode: str = "auto", tile_n: int = 512,
                 field_mode: str = "dense", double_buffer: bool = False,
                 **kw):
        super().__init__(model, **kw)
        self.j_mode = resolve_j_mode(j_mode, model.n)
        self.tile_n = int(tile_n)
        self.double_buffer = bool(double_buffer)
        self.field_mode = resolve_field_mode(
            field_mode,
            model_weight_bits(model) if field_mode == "auto" else 1,
        )
        if self.field_mode == "popcount":
            self.packed_j = pack_couplings_from_adjacency(
                model.n, model.nbr_idx, model.nbr_w
            )
            # Row-tile the contraction in the same regime the matmul would
            # tile J: the broadcast XNOR buffer stays O(T·tile_n·N/32).
            self._pc_tile = (
                None if model.n <= TILED_J_THRESHOLD else self.tile_n
            )
        elif self.j_mode == "dense":
            self.J = jnp.asarray(model.dense_J(), j_dtype)
        else:
            _, self.nbr_idx, self.nbr_w = model.device_arrays()

    def _field(self, m):
        if self.field_mode == "popcount":
            return local_fields_popcount(
                pack_spins(m), self.h, self.packed_j, tile_n=self._pc_tile
            )
        if self.j_mode == "tiled":
            return local_fields_tiled(
                m, self.h, self.nbr_idx, self.nbr_w, tile_n=self.tile_n,
                double_buffer=self.double_buffer,
            )
        return local_fields_dense(m, self.h, self.J)

    def _run_plateau_dense(self, state, i0, *, length, eligible,
                           track_energy=False, emit=False, jperp=0):
        return self._run_plateau_scan(
            state, i0, length=length, eligible=eligible,
            track_energy=track_energy, emit=emit, jperp=jperp,
        )


class PallasBackend(PlateauBackend):
    """The resident plateau kernel: one `pallas_call` per plateau.

    J is pinned in VMEM for all C cycles of the plateau.  With ``xorshift``
    noise the plateau runs the **streamed-noise packed kernel**
    (:func:`repro.kernels.ssa_update.ssa_plateau_packed`): the per-cycle
    noise is generated *inside* the kernel by stepping the carried
    xorshift128 lanes — bit-identical to pre-generated draws, but no
    (C, T, N) noise buffer exists anywhere — and the HBM-facing spin refs
    are uint32 bitplanes.  ``threefry`` noise cannot be reproduced in-kernel
    and keeps the per-plateau (C, T, N) int8 pregen path (the
    statistical-reference configuration, not the production one).

    Per-cycle *outputs* (energy traces, trajectory planes) are the one thing
    the resident kernel deliberately does not produce; plateaus that need
    them (record='traj' store phases, track_energy runs) fall back to the
    bit-identical scan path over the Pallas `local_field` kernel.  The
    production solve path — record='best', track_energy=False — is entirely
    resident.

    ``field_mode='popcount'`` switches the resident kernel to the
    bit-parallel chain kernel (:func:`~repro.kernels.ssa_update.
    ssa_plateau_popcount`): J lives in VMEM as `PackedJ` bitplanes, the
    contraction is XNOR-popcount on uint32 words, and — via
    :meth:`run_plateaus` — a whole plateau chain runs in ONE `pallas_call`
    (multi-plateau residency), amortizing launch overhead the way the dual-
    BRAM FPGA overlaps streaming with compute.  Requires the streamed
    (xorshift) noise path; no f32 J is ever materialized.
    """

    name = "pallas"

    def __init__(
        self,
        model: IsingModel,
        *,
        j_dtype=jnp.float32,
        block_r: int = 8,
        interpret: Optional[bool] = None,
        noise_mode: str = "auto",
        field_mode: str = "dense",
        **kw,
    ):
        super().__init__(model, **kw)
        # Lazy import: keeps repro.core importable without the kernels pkg.
        from repro.kernels import ops as kops
        from repro.kernels import ssa_update as kssa

        self._kops = kops
        self._kssa = kssa
        # SSQA (n_replicas > 0) pins the R-tile to the replica ring so each
        # kernel tile holds exactly one ring (the roll stays tile-local).
        self.block_r = self.n_replicas if self.n_replicas else int(block_r)
        self.interpret = interpret
        self.noise_mode = resolve_noise_mode(noise_mode, self.noise)
        self.field_mode = resolve_field_mode(
            field_mode,
            model_weight_bits(model) if field_mode == "auto" else 1,
        )
        if self.field_mode == "popcount":
            if self.noise_mode != "streamed":
                raise ValueError(
                    "field_mode='popcount' on the pallas backend requires "
                    "noise_mode='streamed' (noise='xorshift'): the bit-"
                    "parallel chain kernel generates its noise in-kernel"
                )
            self.packed_j = pack_couplings_from_adjacency(
                model.n, model.nbr_idx, model.nbr_w
            )
        else:
            self.J = jnp.asarray(model.dense_J(), j_dtype)

    def _field(self, m):
        if self.field_mode == "popcount":
            # Scan fallback (traces/trajectories) stays on the packed
            # arithmetic — no f32 J exists in this mode at all.
            return local_fields_popcount(pack_spins(m), self.h, self.packed_j)
        return self._kops.local_field(m.astype(jnp.float32), self.h, self.J)

    def _popcount_call(self, mp, itanh, rng, i0_sched, fold_sched, bh, bmp,
                       jperp_sched=None):
        pj = self.packed_j
        return self._kssa.ssa_plateau_popcount(
            mp, itanh, pj.sign, pj.mags, pj.base, self.h, rng,
            jnp.asarray(i0_sched, jnp.int32),
            jnp.asarray(fold_sched, jnp.int32),
            bh, bmp,
            n_rnd=self.n_rnd,
            block_r=self.block_r,
            interpret=self.interpret,
            jperp_sched=(
                None if jperp_sched is None
                else jnp.asarray(jperp_sched, jnp.int32)
            ),
            n_replicas=self.n_replicas,
        )

    def run_plateaus(self, state, plateaus: Sequence[Plateau]):
        """Whole-chain execution: one `pallas_call` for the full schedule.

        Only the popcount kernel carries per-cycle I0/fold operands, so only
        ``field_mode='popcount'`` gets true multi-plateau residency; other
        configurations chain per-plateau launches via the default.
        """
        if self.field_mode != "popcount" or not plateaus:
            return super().run_plateaus(state, plateaus)
        packed = self.storage_layout == "packed"
        mp = state.m_packed if packed else pack_spins(state.m)
        bmp = state.best_m_packed if packed else pack_spins(state.best_m)
        i0_sched, fold_sched, jperp_sched = plateau_cycle_schedules(plateaus)
        mp_o, it_o, rng_o, bh_o, bmp_o = self._popcount_call(
            mp, state.itanh, state.noise_state, i0_sched, fold_sched,
            state.best_H, bmp,
            jperp_sched=jperp_sched if jperp_sched.any() else None,
        )
        if packed:
            return PackedEngineState(rng_o, mp_o, it_o, bh_o, bmp_o)
        n = self.model.n
        return EngineState(
            rng_o, unpack_spins(mp_o, n), it_o, bh_o, unpack_spins(bmp_o, n)
        )

    def _pregen_noise(self, ns, length: int):
        def draw(ns, _):
            ns, r = self._noise_step(ns)
            return ns, r.astype(jnp.int8)

        return jax.lax.scan(draw, ns, None, length=length)

    def run_plateau(self, state, i0, *, length, eligible, track_energy=False,
                    emit=False, jperp=0):
        packed = self.storage_layout == "packed"
        jperp = int(jperp)
        # The pregen kernel is not jperp-extended: SSQA plateaus on the
        # pregen path (threefry, or opt-in xorshift pregen) run the
        # bit-identical scan fallback over the Pallas field kernel.
        scan_fallback = emit or track_energy or (
            jperp and self.noise_mode != "streamed"
        )
        if scan_fallback:
            st = unpack_state(state, self.model.n) if packed else state
            st, trace, planes = self._run_plateau_scan(
                st, i0, length=length, eligible=eligible,
                track_energy=track_energy, emit=emit, jperp=jperp,
            )
            return (pack_state(st) if packed else st), trace, planes
        if self.field_mode == "popcount":
            # One plateau is a length-C chain with constant I0; i0 may be
            # traced (broadcast), eligibility is static host data.
            mp = state.m_packed if packed else pack_spins(state.m)
            bmp = state.best_m_packed if packed else pack_spins(state.best_m)
            i0_sched = jnp.broadcast_to(
                jnp.asarray(i0, jnp.int32), (int(length),)
            )
            fold_sched = np.asarray(
                [0] + [int(bool(eligible))] * int(length), np.int32
            )
            jperp_sched = (
                np.full(int(length), jperp, np.int32) if jperp else None
            )
            mp_o, it_o, rng_o, bh_o, bmp_o = self._popcount_call(
                mp, state.itanh, state.noise_state, i0_sched, fold_sched,
                state.best_H, bmp, jperp_sched=jperp_sched,
            )
            if packed:
                return PackedEngineState(rng_o, mp_o, it_o, bh_o, bmp_o), None, None
            n = self.model.n
            return (
                EngineState(
                    rng_o, unpack_spins(mp_o, n), it_o, bh_o, unpack_spins(bmp_o, n)
                ),
                None,
                None,
            )
        if self.noise_mode == "streamed":
            # Streamed path: packed HBM refs, noise generated in-kernel.
            mp = state.m_packed if packed else pack_spins(state.m)
            bmp = state.best_m_packed if packed else pack_spins(state.best_m)
            mp_o, it_o, rng_o, bh_o, bmp_o = self._kssa.ssa_plateau_packed(
                mp,
                state.itanh,
                self.J,
                self.h,
                state.noise_state,
                jnp.asarray(i0, jnp.int32),
                state.best_H,
                bmp,
                n_cycles=int(length),
                n_rnd=self.n_rnd,
                eligible=bool(eligible),
                block_r=self.block_r,
                interpret=self.interpret,
                jperp=jperp,
                n_replicas=self.n_replicas if jperp else 0,
            )
            if packed:
                return PackedEngineState(rng_o, mp_o, it_o, bh_o, bmp_o), None, None
            n = self.model.n
            return (
                EngineState(
                    rng_o, unpack_spins(mp_o, n), it_o, bh_o, unpack_spins(bmp_o, n)
                ),
                None,
                None,
            )
        # Pregen path: the legacy per-plateau (C, T, N) buffer — mandatory
        # for threefry (not reproducible in-kernel), opt-in for xorshift
        # (noise_mode='pregen'; bit-identical to streamed, used as the
        # measured baseline in benchmarks/timing.py --memory).
        st = unpack_state(state, self.model.n) if packed else state
        ns, noise = self._pregen_noise(st.noise_state, length)
        m_o, it_o, bh_o, bm_o = self._kssa.ssa_plateau(
            st.m.astype(jnp.float32),
            st.itanh,
            self.J,
            self.h,
            noise,
            jnp.asarray(i0, jnp.int32),
            st.best_H,
            st.best_m,
            n_rnd=self.n_rnd,
            eligible=bool(eligible),
            block_r=self.block_r,
            interpret=self.interpret,
        )
        out = EngineState(ns, m_o.astype(jnp.int8), it_o, bh_o, bm_o)
        return (pack_state(out) if packed else out), None, None


BACKENDS = {
    "sparse": SparseBackend,
    "dense": DenseBackend,
    "pallas": PallasBackend,
}


def make_backend(
    backend: Union[str, PlateauBackend, type, None] = None,
    model: IsingModel = None,
    *,
    n_trials: int,
    n_rnd: int = 2,
    noise: str = None,
    partition: str = None,
    mesh=None,
    partition_axis: str = "model",
    config=None,
    **opts,
) -> PlateauBackend:
    """Resolve a backend spec: name, PlateauBackend subclass, or instance.

    ``partition='spin'`` (or 'auto' on a multi-device mesh) reroutes to the
    spin-sharded shard_map backend (DESIGN.md §11); ``backend`` then names
    the *field contraction* the shards run (sparse gather / tiled f32 /
    popcount via field_mode), not a single-device execution engine.

    ``config=SolverConfig(...)`` supplies backend/noise/partition/mesh and
    the engine opts in one typed object (DESIGN.md §13); the loose kwargs
    remain as a deprecated shim (warning once per process).
    """
    if config is not None:
        from .config import legacy_kwargs_to_config

        cfg = legacy_kwargs_to_config(
            "make_backend", config,
            backend=backend if isinstance(backend, str) else None,
            noise=noise, partition=partition,
        )
        backend = cfg.backend if backend is None else backend
        noise, partition = cfg.noise, cfg.partition
        mesh = cfg.mesh if mesh is None else mesh
        merged = cfg.engine_opts()
        merged.update(opts)
        opts = merged
    if backend is None:
        backend = "sparse"
    if noise is None:
        noise = "threefry"
    if partition is None:
        partition = "problem"
    part = resolve_partition(partition, model.n, mesh, axis=partition_axis)
    if part == "spin":
        from .distributed import SpinShardedBackend  # lazy: circular import

        base = backend if isinstance(backend, str) else "dense"
        return SpinShardedBackend(
            model, n_trials=n_trials, n_rnd=n_rnd, noise=noise, mesh=mesh,
            axis=partition_axis, base_backend=base, **opts,
        )
    if isinstance(backend, PlateauBackend):
        if backend.n_trials != int(n_trials) or backend.n_rnd != int(n_rnd):
            raise ValueError(
                f"backend instance was built for n_trials={backend.n_trials}, "
                f"n_rnd={backend.n_rnd}; caller wants n_trials={n_trials}, "
                f"n_rnd={n_rnd}"
            )
        return backend
    if isinstance(backend, type) and issubclass(backend, PlateauBackend):
        cls = backend
    else:
        if isinstance(backend, str):
            backend = resolve_backend(backend, model.n)
        try:
            cls = BACKENDS[backend]
        except (KeyError, TypeError):
            raise ValueError(
                f"unknown backend {backend!r}; known: {sorted(BACKENDS)}"
            ) from None
    return cls(model, n_trials=n_trials, n_rnd=n_rnd, noise=noise, **opts)


# ---------------------------------------------------------------------------
# The backend-agnostic schedule driver
# ---------------------------------------------------------------------------
def run_schedule(
    backend: PlateauBackend,
    plateaus: Sequence[Plateau],
    state: EngineState,
    *,
    record: str = "best",
    track_energy: bool = False,
):
    """Chain ``run_plateau`` over a plateau sequence (traceable).

    record='best': eligible plateaus fold their states into the running
    arg-best on the fly (the production path — what the FPGA cannot afford
    and the TPU gets almost for free next to the field contraction).

    record='traj': eligible plateaus emit bit-packed spin planes instead
    (the FPGA's UART-shipped trajectory); best-tracking is left to the
    caller's post-scan over the planes.

    Returns (state, trace, planes): trace = (mean_H, min_H) concatenated
    over all cycles when track_energy, planes concatenated over eligible
    plateaus when record='traj'.
    """
    if record == "best" and not track_energy:
        # Production path: no per-plateau outputs, so the whole chain can be
        # handed to the backend at once — resident backends execute it in a
        # single launch (multi-plateau residency), bit-identically.
        return backend.run_plateaus(state, tuple(plateaus)), None, None
    tr_mean, tr_min, planes = [], [], []
    for p in plateaus:
        if record == "traj":
            state, _, pl = backend.run_plateau(
                state, p.i0, length=p.length, eligible=False,
                track_energy=False, emit=p.eligible, jperp=p.jperp,
            )
            if pl is not None:
                planes.append(pl)
        elif record == "best":
            state, tr, _ = backend.run_plateau(
                state, p.i0, length=p.length, eligible=p.eligible,
                track_energy=track_energy, emit=False, jperp=p.jperp,
            )
            if tr is not None:
                tr_mean.append(tr[0])
                tr_min.append(tr[1])
        else:
            raise ValueError(f"unknown record {record!r}")
    trace = (
        (jnp.concatenate(tr_mean), jnp.concatenate(tr_min)) if tr_mean else None
    )
    planes_out = jnp.concatenate(planes, axis=0) if planes else None
    return state, trace, planes_out


# ---------------------------------------------------------------------------
# Shape buckets and padded problems (the serving substrate, DESIGN.md §7)
# ---------------------------------------------------------------------------
def next_pow2(n: int) -> int:
    """Smallest power of two >= n (>= 1)."""
    n = int(n)
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


def bucket_n(n: int, min_bucket: int = 64) -> int:
    """The serving shape bucket for an N-spin problem: power-of-two width.

    Every instance is zero-padded up to its bucket so heterogeneous request
    streams share compiled executables (one program per bucket, not per N).
    """
    if n <= 0:
        raise ValueError(f"need n > 0, got {n}")
    return max(next_pow2(int(min_bucket)), next_pow2(n))


def pad_model(model: IsingModel, n_bucket: int) -> IsingModel:
    """Zero-pad an Ising model to ``n_bucket`` spins.

    Padded rows carry h=0 and self-index/zero-weight adjacency, so their
    local field is identically 0 and they contribute nothing to H: the live
    lanes of a padded run evolve exactly as in the unpadded run (given a
    padding-invariant noise stream — see :func:`padded_noise_init`).
    """
    if model.n == n_bucket:
        return model
    if model.n > n_bucket:
        raise ValueError(f"model has {model.n} spins > bucket {n_bucket}")
    pad = n_bucket - model.n
    d = model.max_degree
    h = np.concatenate([np.asarray(model.h, np.int32), np.zeros(pad, np.int32)])
    idx = np.concatenate(
        [
            np.asarray(model.nbr_idx, np.int32),
            np.tile(np.arange(model.n, n_bucket, dtype=np.int32)[:, None], (1, d)),
        ],
        axis=0,
    )
    w = np.concatenate(
        [np.asarray(model.nbr_w, np.int32), np.zeros((pad, d), np.int32)], axis=0
    )
    return IsingModel(
        n=n_bucket, h=h, nbr_idx=idx, nbr_w=w, name=f"{model.name}@pad{n_bucket}"
    )


def padded_noise_init(noise: str, seed: int, n_trials: int, n_live: int, n_bucket: int):
    """Init a noise state over (n_trials, n_bucket) lanes, padding-invariant.

    The live lanes [0, n_live) are seeded exactly as an unpadded
    ``xorshift_init(seed, (n_trials, n_live))`` run would seed them; pad
    lanes get an independent (inert) stream.  Because xorshift lanes are
    elementwise-independent, a bucket-padded run is then bit-identical to
    the unpadded run on the live lanes — the padding-invariance property the
    serving layer relies on.

    ``threefry`` draws are shape-dependent, so threefry has no
    padding-invariant form; it is supported for service use but padded runs
    are *not* bit-comparable to unpadded ones.
    """
    if noise == "xorshift":
        live = xorshift_init(seed, (n_trials, n_live))
        if n_bucket == n_live:
            return live
        pad = xorshift_init(seed ^ 0x9E3779B9, (n_trials, n_bucket - n_live))
        return jnp.concatenate([live, pad], axis=-1)
    if noise == "threefry":
        return jax.random.PRNGKey(seed)
    raise ValueError(f"unknown noise {noise!r}")


def padded_noise_init_slice(seed: int, n_trials: int, n_live: int,
                            n_bucket: int, lo: int, hi: int) -> np.ndarray:
    """Columns [lo, hi) of :func:`padded_noise_init` ('xorshift'), shard-local.

    Bit-identical to ``padded_noise_init('xorshift', ...)[..., lo:hi]``
    without materializing the full (4, T, n_bucket) lane array: live columns
    are seeded from the *unpadded* (T, n_live) lane grid, pad columns from
    the independent pad stream, each via :func:`repro.core.rng
    .xorshift_init_slice`.  This is the PR 4 padding-invariance extended to
    shard-local lane offsets — each device of a spin-sharded run seeds only
    its own shard, and the result equals the single-device stream
    (DESIGN.md §11; property-tested).
    """
    lo, hi = int(lo), int(hi)
    n_live, n_bucket = int(n_live), int(n_bucket)
    if not 0 <= lo <= hi <= n_bucket:
        raise ValueError(f"slice [{lo}, {hi}) outside [0, {n_bucket})")
    parts = []
    if lo < n_live:
        parts.append(xorshift_init_slice(
            seed, (n_trials, n_live), lo, min(hi, n_live)
        ))
    if hi > n_live:
        parts.append(xorshift_init_slice(
            seed ^ 0x9E3779B9, (n_trials, n_bucket - n_live),
            max(lo, n_live) - n_live, hi - n_live,
        ))
    if not parts:
        return np.zeros((4, int(n_trials), 0), np.uint32)
    return parts[0] if len(parts) == 1 else np.concatenate(parts, axis=-1)


# ---------------------------------------------------------------------------
# Batched backends: B stacked problems through one compiled plateau program
# ---------------------------------------------------------------------------
class BatchedBackend:
    """Batched execution of B stacked, bucket-padded problems.

    The serving counterpart of :class:`PlateauBackend` (DESIGN.md §7): problem
    arrays are **call-time arguments** (a dict of stacked jnp arrays from
    :meth:`stack`), not constructor state, so one jitted program per
    (backend, N_bucket, B, n_trials, schedule signature) serves every request
    group that shape-matches — the serving layer's compiled-executable cache
    keys on exactly those statics.

    State layout is :class:`EngineState` with a leading problem axis:
    spins (B, T, N), best_H (B, T), xorshift lanes (B, 4, T, N).  ``sparse``
    and ``dense`` vmap the single-problem plateau scan over the problem axis;
    ``pallas`` launches the resident kernel on a (B, R-tile) grid.  All three
    are bit-identical per problem to the corresponding unbatched backend —
    property-tested.
    """

    name = "abstract"

    def __init__(
        self,
        *,
        n_bucket: int,
        n_trials: int,
        n_rnd: int = 2,
        noise: str = "xorshift",
        storage_layout: str = "dense",
        n_replicas: int = 0,
    ):
        if storage_layout not in ("dense", "packed"):
            raise ValueError(f"unknown storage_layout {storage_layout!r}")
        self.n_bucket = int(n_bucket)
        self.n_trials = int(n_trials)
        self.n_rnd = int(n_rnd)
        self.noise = noise
        self.storage_layout = storage_layout
        self.n_replicas = int(n_replicas)
        if self.n_replicas:
            if self.n_replicas < 2:
                raise ValueError(
                    f"n_replicas must be >= 2, got {self.n_replicas}"
                )
            if self.n_trials % self.n_replicas:
                raise ValueError(
                    f"n_trials={self.n_trials} not divisible by "
                    f"n_replicas={self.n_replicas}"
                )
        lanes = (self.n_trials, self.n_bucket)
        if noise == "xorshift":
            self._noise_step_one = xorshift_next_bits
        elif noise == "threefry":

            def step(key):
                key, sub = jax.random.split(key)
                return key, threefry_noise(sub, lanes)

            self._noise_step_one = step
        else:
            raise ValueError(f"unknown noise {noise!r}")
        self._noise_step = jax.vmap(self._noise_step_one)

    # -- host side --------------------------------------------------------
    def stack(self, models: Sequence[IsingModel]) -> dict:
        """Pad each model to the bucket and stack its arrays over axis 0."""
        raise NotImplementedError

    def init_noise(self, seeds: Sequence[int], n_lives: Sequence[int]):
        """Stacked per-problem noise states (padding-invariant live lanes)."""
        return jnp.stack(
            [
                padded_noise_init(self.noise, int(s), self.n_trials, int(nl), self.n_bucket)
                for s, nl in zip(seeds, n_lives)
            ]
        )

    # -- traced -----------------------------------------------------------
    def init_state(self, problem: dict, noise0):
        """Random ±1 start from the first noise draw (matches PlateauBackend)."""
        ns, r0 = self._noise_step(noise0)
        m0 = r0.astype(jnp.int8)
        itanh0 = jnp.where(m0 > 0, 0, -1).astype(jnp.int32)
        best_H = jnp.full(m0.shape[:-1], BIG_ENERGY, jnp.int32)
        st = EngineState(ns, m0, itanh0, best_H, m0)
        return pack_state(st) if self.storage_layout == "packed" else st

    def run_plateau(self, problem: dict, state, i0, *, length, eligible,
                    jperp=0):
        if self.storage_layout == "packed":
            st = unpack_state(state, self.n_bucket)
            st = self._run_plateau_dense(
                problem, st, i0, length=length, eligible=eligible, jperp=jperp
            )
            return pack_state(st)
        return self._run_plateau_dense(
            problem, state, i0, length=length, eligible=eligible, jperp=jperp
        )

    def run_shots(self, problem: dict, state, plateaus, n_shots: int):
        """Advance ``n_shots`` full iterations (plateau chains) — one chunk.

        The chunk launch boundary is where the storage layout is *real*:
        under 'packed' the state entering/leaving this method — the HBM-
        resident buffers between service chunks — carries spins as uint32
        bitplanes.
        """
        if self.storage_layout == "packed":
            st = unpack_state(state, self.n_bucket)
            st = self._run_shots_dense(problem, st, plateaus, n_shots)
            return pack_state(st)
        return self._run_shots_dense(problem, state, plateaus, n_shots)

    def _run_plateau_dense(self, problem: dict, state: EngineState, i0, *,
                           length, eligible, jperp=0):
        raise NotImplementedError

    def _run_shots_dense(self, problem: dict, state: EngineState, plateaus,
                         n_shots: int):
        raise NotImplementedError

    def finalize(self, state) -> Tuple[jnp.ndarray, jnp.ndarray]:
        if self.storage_layout == "packed":
            return state.best_H, unpack_spins(state.best_m_packed, self.n_bucket)
        return state.best_H, state.best_m


class _VmapBatchedBackend(BatchedBackend):
    """Shared vmap-over-problems implementation (sparse/dense fields)."""

    def _field_one(self, prob: dict, m: jnp.ndarray) -> jnp.ndarray:
        raise NotImplementedError

    def _run_one_plateaus(self, prob, st, plateaus):
        field_fn = lambda m: self._field_one(prob, m)  # noqa: E731
        for p in plateaus:
            st, _, _ = run_plateau_scan(
                field_fn, self._noise_step_one, prob["h"], self.n_rnd, st,
                p.i0, length=p.length, eligible=p.eligible,
                jperp=p.jperp, n_replicas=self.n_replicas,
            )
        return st

    def _run_plateau_dense(self, problem, state, i0, *, length, eligible,
                           jperp=0):
        p = (Plateau(int(i0), int(length), bool(eligible), int(jperp)),)
        return jax.vmap(lambda pr, st: self._run_one_plateaus(pr, st, p))(
            problem, state
        )

    def _run_shots_dense(self, problem, state, plateaus, n_shots):
        plateaus = tuple(plateaus)

        def one(prob, st):
            def iteration(st, _):
                return self._run_one_plateaus(prob, st, plateaus), None

            st, _ = jax.lax.scan(iteration, st, None, length=n_shots)
            return st

        return jax.vmap(one)(problem, state)


def pad_degree(model: IsingModel, d: int) -> IsingModel:
    """Pad a model's adjacency to ``d`` neighbor columns.

    The extra columns are self-index/zero-weight entries, so the gathered
    local field is unchanged — degree padding is results-invariant the same
    way bucket padding is (:func:`pad_model`).  The sparse/tiled stacked
    representation's neighbor width is program-structural, so anything that
    splices problems into an existing stacked batch (the streaming slot
    tables) must pre-pad every model to the batch's degree.
    """
    d = int(d)
    if model.max_degree == d:
        return model
    if model.max_degree > d:
        raise ValueError(
            f"model degree {model.max_degree} exceeds target degree {d}"
        )
    extra = d - model.max_degree
    idx, w = np.asarray(model.nbr_idx), np.asarray(model.nbr_w)
    self_idx = np.tile(np.arange(model.n, dtype=np.int32)[:, None], (1, extra))
    return IsingModel(
        n=model.n,
        h=np.asarray(model.h, np.int32),
        nbr_idx=np.concatenate([idx, self_idx], axis=1),
        nbr_w=np.concatenate([w, np.zeros((model.n, extra), np.int32)], axis=1),
        name=model.name,
    )


def _stack_sparse_models(models, n_bucket: int) -> dict:
    """Stacked, bucket-padded adjacency views {h, nbr_idx, nbr_w}."""
    padded = [pad_model(m, n_bucket) for m in models]
    d = max(m.max_degree for m in padded)
    padded = [pad_degree(m, d) for m in padded]
    return {
        "h": jnp.asarray(
            np.stack([np.asarray(m.h, np.int32) for m in padded]), jnp.int32
        ),
        "nbr_idx": jnp.asarray(
            np.stack([np.asarray(m.nbr_idx) for m in padded]), jnp.int32
        ),
        "nbr_w": jnp.asarray(
            np.stack([np.asarray(m.nbr_w) for m in padded]), jnp.int32
        ),
    }


def extract_slot(tree, slot: int):
    """Slice one problem lane out of a batched pytree, keeping a size-1 axis.

    Works on anything whose leaves carry the problem axis leading —
    :class:`EngineState` / :class:`PackedEngineState`, stacked problem dicts,
    noise-state stacks.  The size-1 leading axis makes the result directly
    comparable (and splicable) to a B=1 batched run of the same request,
    which is what makes per-slot checkpoints interchangeable with solo-group
    checkpoints.
    """
    return jax.tree_util.tree_map(lambda a: jnp.asarray(a)[slot : slot + 1], tree)


def splice_slot(tree, slot: int, sub):
    """Write a size-1-problem-axis pytree into lane ``slot`` of a batched one.

    The slot-backfill primitive of the streaming service: because per-problem
    lanes never interact (the padding-invariance property), replacing one
    lane's problem arrays + engine state leaves every other lane's
    trajectory bit-identical.  ``sub`` must be structure- and shape-
    compatible with ``extract_slot(tree, slot)``.
    """
    return jax.tree_util.tree_map(
        lambda a, s: jnp.asarray(a).at[slot].set(jnp.asarray(s)[0]), tree, sub
    )


class BatchedSparseBackend(_VmapBatchedBackend):
    """Padded-adjacency gather field, vmapped over the problem axis."""

    name = "sparse"

    def stack(self, models):
        return _stack_sparse_models(models, self.n_bucket)

    def _field_one(self, prob, m):
        return local_fields_sparse(
            m.astype(jnp.int32), prob["h"], prob["nbr_idx"], prob["nbr_w"]
        )


def _stack_dense_models(models, n_bucket: int, j_dtype) -> dict:
    """Stacked, bucket-padded dense views {h (B,N), J (B,N,N)}."""
    from repro.kernels.ssa_update import pad_to  # lazy: keeps core light

    Js, hs = [], []
    for m in models:
        Js.append(
            pad_to(pad_to(jnp.asarray(m.dense_J(), j_dtype), 0, n_bucket), 1, n_bucket)
        )
        hs.append(pad_to(jnp.asarray(m.h, jnp.int32), 0, n_bucket))
    return {"h": jnp.stack(hs), "J": jnp.stack(Js)}


def _stack_packed_models(models, n_bucket: int, j_bits: int) -> dict:
    """Stacked, bucket-padded PackedJ views {h, sign, mags, base}.

    ``j_bits`` forces the magnitude-plane count for *every* model so the
    stacked ``mags`` tensor has one uniform shape (a program-structural
    parameter — the executable cache keys on it); callers pass the group
    maximum from :func:`repro.kernels.bitplane.adjacency_weight_bits`.
    """
    hs, signs, magss, bases = [], [], [], []
    for m in models:
        p = pad_model(m, n_bucket)
        pj = pack_couplings_from_adjacency(
            p.n, p.nbr_idx, p.nbr_w, n_bits=j_bits
        )
        hs.append(jnp.asarray(p.h, jnp.int32))
        signs.append(pj.sign)
        magss.append(pj.mags)
        bases.append(pj.base)
    return {
        "h": jnp.stack(hs),
        "sign": jnp.stack(signs),
        "mags": jnp.stack(magss),
        "base": jnp.stack(bases),
    }


class BatchedDenseBackend(_VmapBatchedBackend):
    """(T,N)·(N,N) matmul field per problem, vmapped over the problem axis.

    ``j_mode='tiled'`` (auto above TILED_J_THRESHOLD spins) stacks the
    adjacency instead of dense J and streams (tile_n, N) slabs per problem —
    no (B, N, N) buffer ever exists, which is what admits G77/G81-class
    buckets through the service.

    ``field_mode='popcount'`` stacks `PackedJ` bitplanes instead (``j_bits``
    magnitude planes each, the group maximum) and contracts by XNOR-popcount
    — exact-integer equal to the matmul, ~32× less J traffic per problem.
    """

    name = "dense"

    def __init__(self, *, j_dtype=jnp.float32, j_mode: str = "auto",
                 tile_n: int = 512, field_mode: str = "dense",
                 j_bits: int = 1, double_buffer: bool = False, **kw):
        super().__init__(**kw)
        self.j_dtype = j_dtype
        self.j_mode = resolve_j_mode(j_mode, self.n_bucket)
        self.tile_n = int(tile_n)
        self.double_buffer = bool(double_buffer)
        self.j_bits = int(j_bits)
        self.field_mode = resolve_field_mode(field_mode, self.j_bits)
        self._pc_tile = (
            None if self.n_bucket <= TILED_J_THRESHOLD else self.tile_n
        )

    def stack(self, models):
        if self.field_mode == "popcount":
            return _stack_packed_models(models, self.n_bucket, self.j_bits)
        if self.j_mode == "tiled":
            return _stack_sparse_models(models, self.n_bucket)
        return _stack_dense_models(models, self.n_bucket, self.j_dtype)

    def _field_one(self, prob, m):
        if self.field_mode == "popcount":
            pj = PackedJ(prob["sign"], prob["mags"], prob["base"])
            return local_fields_popcount(
                pack_spins(m), prob["h"], pj, tile_n=self._pc_tile
            )
        if self.j_mode == "tiled":
            return local_fields_tiled(
                m, prob["h"], prob["nbr_idx"], prob["nbr_w"],
                tile_n=self.tile_n, double_buffer=self.double_buffer,
            )
        return local_fields_dense(m, prob["h"], prob["J"])


class BatchedPallasBackend(BatchedBackend):
    """The resident plateau kernel on a (B, R-tile) grid.

    One `pallas_call` per plateau advances **all problems and all trials**:
    each grid step (b, i) pins problem b's J in VMEM and runs every cycle of
    the plateau for one R-tile of trials — the serving transcription of the
    FPGA's "one pipeline, many instances" operating mode.

    With ``xorshift`` noise the plateau is the streamed-noise packed kernel
    (:func:`repro.kernels.ssa_update.ssa_plateau_packed_batched`): noise is
    generated in-kernel from the carried lanes and the HBM-facing spin refs
    are uint32 bitplanes — no (B, C, T, N) noise buffer exists anywhere.
    ``threefry`` keeps per-plateau pregen (reference path only).

    ``field_mode='popcount'`` upgrades to the bit-parallel chain kernel
    (:func:`repro.kernels.ssa_update.ssa_plateau_popcount_batched`): J is
    VMEM-resident as stacked `PackedJ` bitplanes (``j_bits`` planes, the
    group maximum) and :meth:`run_shots` launches each full iteration's
    plateau chain as ONE `pallas_call` — multi-plateau residency.
    """

    name = "pallas"

    def __init__(self, *, j_dtype=jnp.float32, block_r: int = 8,
                 interpret: Optional[bool] = None, noise_mode: str = "auto",
                 field_mode: str = "dense", j_bits: int = 1, **kw):
        super().__init__(**kw)
        from repro.kernels import ssa_update as kssa  # lazy

        self._kssa = kssa
        self.j_dtype = j_dtype
        # SSQA: replica rings demand whole rings per R-tile (the ring roll
        # happens over the tile's trial axis), so n_replicas pins block_r.
        self.block_r = self.n_replicas if self.n_replicas else int(block_r)
        self.interpret = interpret
        self.noise_mode = resolve_noise_mode(noise_mode, self.noise)
        self.j_bits = int(j_bits)
        self.field_mode = resolve_field_mode(field_mode, self.j_bits)
        if self.field_mode == "popcount" and self.noise_mode != "streamed":
            raise ValueError(
                "field_mode='popcount' on the batched pallas backend "
                "requires noise_mode='streamed' (noise='xorshift')"
            )
        if self.n_replicas and self.noise_mode != "streamed":
            raise ValueError(
                "SSQA (n_replicas > 0) on the batched pallas backend "
                "requires noise_mode='streamed' (noise='xorshift'); the "
                "pregen kernel has no replica-coupling path"
            )

    def stack(self, models):
        if self.field_mode == "popcount":
            return _stack_packed_models(models, self.n_bucket, self.j_bits)
        return _stack_dense_models(models, self.n_bucket, self.j_dtype)

    def _pregen(self, ns, length: int):
        def draw(ns, _):
            ns, r = self._noise_step(ns)
            return ns, r.astype(jnp.int8)

        return jax.lax.scan(draw, ns, None, length=length)

    def _plateau_packed(self, problem, st: PackedEngineState, i0, length,
                        eligible, jperp=0) -> PackedEngineState:
        jperp = int(jperp)
        mp_o, it_o, rng_o, bh_o, bmp_o = self._kssa.ssa_plateau_packed_batched(
            st.m_packed,
            st.itanh,
            problem["J"],
            problem["h"],
            st.noise_state,
            jnp.asarray(i0, jnp.int32),
            st.best_H,
            st.best_m_packed,
            n_cycles=int(length),
            n_rnd=self.n_rnd,
            eligible=bool(eligible),
            block_r=self.block_r,
            interpret=self.interpret,
            jperp=jperp,
            n_replicas=self.n_replicas if jperp else 0,
        )
        return PackedEngineState(rng_o, mp_o, it_o, bh_o, bmp_o)

    def _chain_popcount(self, problem, st: PackedEngineState, i0_sched,
                        fold_sched, jperp_sched=None) -> PackedEngineState:
        mp_o, it_o, rng_o, bh_o, bmp_o = self._kssa.ssa_plateau_popcount_batched(
            st.m_packed,
            st.itanh,
            problem["sign"],
            problem["mags"],
            problem["base"],
            problem["h"],
            st.noise_state,
            jnp.asarray(i0_sched, jnp.int32),
            jnp.asarray(fold_sched, jnp.int32),
            st.best_H,
            st.best_m_packed,
            n_rnd=self.n_rnd,
            block_r=self.block_r,
            interpret=self.interpret,
            jperp_sched=(
                None if jperp_sched is None
                else jnp.asarray(jperp_sched, jnp.int32)
            ),
            n_replicas=self.n_replicas,
        )
        return PackedEngineState(rng_o, mp_o, it_o, bh_o, bmp_o)

    def run_plateau(self, problem, state, i0, *, length, eligible, jperp=0):
        if self.noise_mode != "streamed":
            return super().run_plateau(
                problem, state, i0, length=length, eligible=eligible,
                jperp=jperp,
            )
        packed_in = self.storage_layout == "packed"
        st = state if packed_in else pack_state(state)
        if self.field_mode == "popcount":
            i0_sched = jnp.broadcast_to(
                jnp.asarray(i0, jnp.int32), (int(length),)
            )
            fold_sched = np.asarray(
                [0] + [int(bool(eligible))] * int(length), np.int32
            )
            jperp_sched = (
                np.full(int(length), int(jperp), np.int32) if jperp else None
            )
            st = self._chain_popcount(
                problem, st, i0_sched, fold_sched, jperp_sched
            )
        else:
            st = self._plateau_packed(problem, st, i0, length, eligible, jperp)
        return st if packed_in else unpack_state(st, self.n_bucket)

    def run_shots(self, problem, state, plateaus, n_shots):
        plateaus = tuple(plateaus)
        if self.noise_mode != "streamed":
            return super().run_shots(problem, state, plateaus, n_shots)
        packed_in = self.storage_layout == "packed"
        st = state if packed_in else pack_state(state)

        if self.field_mode == "popcount":
            # Multi-plateau residency: one launch per iteration, the whole
            # plateau chain carried inside the kernel.
            i0_sched, fold_sched, jperp_sched = plateau_cycle_schedules(plateaus)
            if not jperp_sched.any():
                jperp_sched = None  # classical chain: keep the v1 jaxpr

            def iteration(st, _):
                return self._chain_popcount(
                    problem, st, i0_sched, fold_sched, jperp_sched
                ), None
        else:

            def iteration(st, _):
                for p in plateaus:
                    st = self._plateau_packed(
                        problem, st, p.i0, p.length, p.eligible, p.jperp
                    )
                return st, None

        st, _ = jax.lax.scan(iteration, st, None, length=n_shots)
        return st if packed_in else unpack_state(st, self.n_bucket)

    def _run_plateau_dense(self, problem, state, i0, *, length, eligible,
                           jperp=0):
        if jperp:
            raise ValueError(
                "SSQA requires noise_mode='streamed' on the batched pallas "
                "backend (pregen kernel has no replica-coupling path)"
            )
        ns, noise = self._pregen(state.noise_state, length)  # (C, B, T, N)
        noise = jnp.swapaxes(noise, 0, 1)                    # (B, C, T, N)
        m_o, it_o, bh_o, bm_o = self._kssa.ssa_plateau_batched(
            state.m.astype(jnp.float32),
            state.itanh,
            problem["J"],
            problem["h"],
            noise,
            jnp.asarray(i0, jnp.int32),
            state.best_H,
            state.best_m,
            n_rnd=self.n_rnd,
            eligible=bool(eligible),
            block_r=self.block_r,
            interpret=self.interpret,
        )
        return EngineState(ns, m_o.astype(jnp.int8), it_o, bh_o, bm_o)

    def _run_shots_dense(self, problem, state, plateaus, n_shots):
        def iteration(st, _):
            for p in plateaus:
                st = self._run_plateau_dense(
                    problem, st, p.i0, length=p.length, eligible=p.eligible,
                    jperp=p.jperp,
                )
            return st, None

        st, _ = jax.lax.scan(iteration, state, None, length=n_shots)
        return st


BATCHED_BACKENDS = {
    "sparse": BatchedSparseBackend,
    "dense": BatchedDenseBackend,
    "pallas": BatchedPallasBackend,
}


def make_batched_backend(
    backend: str = None,
    *,
    n_bucket: int,
    n_trials: int,
    n_rnd: int = 2,
    noise: str = None,
    partition: str = None,
    mesh=None,
    partition_axis: str = "model",
    config=None,
    **opts,
) -> BatchedBackend:
    if config is not None:
        from .config import legacy_kwargs_to_config

        cfg = legacy_kwargs_to_config(
            "make_batched_backend", config,
            backend=backend, noise=noise, partition=partition,
        )
        backend, noise, partition = cfg.backend, cfg.noise, cfg.partition
        mesh = cfg.mesh if mesh is None else mesh
        merged = cfg.engine_opts()
        merged.update(opts)
        opts = merged
    if backend is None:
        backend = "sparse"
    if noise is None:
        noise = "xorshift"
    if partition is None:
        partition = "problem"
    part = resolve_partition(partition, n_bucket, mesh, axis=partition_axis)
    if part == "spin":
        from .distributed import BatchedSpinShardedBackend  # lazy: circular

        base = backend if isinstance(backend, str) else "dense"
        return BatchedSpinShardedBackend(
            base_backend=base, mesh=mesh, axis=partition_axis,
            n_bucket=n_bucket, n_trials=n_trials, n_rnd=n_rnd, noise=noise,
            **opts,
        )
    if isinstance(backend, str):
        backend = resolve_backend(backend, n_bucket)
    try:
        cls = BATCHED_BACKENDS[backend]
    except (KeyError, TypeError):
        raise ValueError(
            f"unknown batched backend {backend!r}; known: {sorted(BATCHED_BACKENDS)}"
        ) from None
    return cls(n_bucket=n_bucket, n_trials=n_trials, n_rnd=n_rnd, noise=noise, **opts)
