"""Ising-model substrate for the HA-SSA/SSA/SA annealers.

The paper (Sec. II-A) represents a combinatorial optimization problem as an
Ising network: spins m_i ∈ {-1,+1}, biases h_i, couplings J_ij, Hamiltonian

    H = - Σ_i h_i m_i - 1/2 Σ_{i,j} J_ij m_i m_j                       (Eq. 1)

MAX-CUT maps onto it with J_ij = -w_ij, h_i = 0, so that
cut(m) = (Σ_{i<j} w_ij - Σ_{i<j} w_ij m_i m_j) / 2 = (W_sum + H) / 2 ... see
:func:`MaxCutProblem.cut_value` for the exact sign bookkeeping.

Representations
---------------
Problems in the paper's benchmark set are *sparse* (4- or 8-regular), while
the SSA literature also targets *dense* instances (K2000).  We keep both:

* **Padded adjacency** ``(nbr_idx, nbr_w)`` of shape ``(N, max_deg)`` — the
  TPU/CPU-friendly sparse form (pure gathers, no segment ops).  Padding
  entries point at the row's own vertex with weight 0, so they contribute
  nothing to local fields.
* **Dense matrix** ``J`` of shape ``(N, N)`` — fed to the MXU/Pallas path
  for dense problems and for batched-replica matmuls.

All coupling/bias arithmetic is integer-valued (the paper's hardware uses
4-bit integers; we use int32 carriers).  The dense matmul path runs in
float32 for MXU/CPU speed, which is exact for |field| < 2^24 — asserted at
model construction.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "IsingModel",
    "MaxCutProblem",
    "ising_energy",
    "local_fields_dense",
    "local_fields_popcount",
    "local_fields_sparse",
    "local_fields_tiled",
]

# Exactness bound for the float32 matmul path: fields must stay below 2^24.
_F32_EXACT_BOUND = 1 << 24


@dataclasses.dataclass(frozen=True)
class IsingModel:
    """An Ising model with both sparse (padded adjacency) and dense views.

    Attributes:
      n: number of spins.
      h: int32[n] biases.
      nbr_idx: int32[n, max_deg] neighbor indices (padded with self-index).
      nbr_w: int32[n, max_deg] coupling weights J_ij (padded with 0).
      name: human-readable instance name.
    """

    n: int
    h: np.ndarray
    nbr_idx: np.ndarray
    nbr_w: np.ndarray
    name: str = "ising"

    @property
    def max_degree(self) -> int:
        return int(self.nbr_idx.shape[1])

    # -- constructors -----------------------------------------------------
    @staticmethod
    def from_edges(
        n: int,
        edges: np.ndarray,
        weights: np.ndarray,
        h: Optional[np.ndarray] = None,
        name: str = "ising",
    ) -> "IsingModel":
        """Build from an undirected edge list (i, j, J_ij)."""
        w_in = np.asarray(weights)
        if np.issubdtype(w_in.dtype, np.floating) and not np.all(np.isfinite(w_in)):
            raise ValueError("weights must be finite (got NaN/inf)")
        h_in = None if h is None else np.asarray(h)
        if (
            h_in is not None
            and np.issubdtype(h_in.dtype, np.floating)
            and not np.all(np.isfinite(h_in))
        ):
            raise ValueError("h must be finite (got NaN/inf)")
        edges = np.asarray(edges, dtype=np.int64)
        weights = w_in.astype(np.int64)
        if edges.ndim != 2 or edges.shape[1] != 2:
            raise ValueError(f"edges must be (E,2), got {edges.shape}")
        if len(weights) != len(edges):
            raise ValueError("weights/edges length mismatch")
        if len(edges) and np.any(edges[:, 0] == edges[:, 1]):
            raise ValueError("self-loops are not Ising couplings")
        # Vectorized bucketing: each undirected edge contributes two directed
        # half-edges.  Flattening (E,2) row-major interleaves them exactly in
        # the order a per-edge fill would visit (i before j within an edge),
        # so a stable sort by source vertex reproduces the sequential slot
        # assignment — K2000-class instances (~2M edges) build in well under
        # a second instead of minutes.
        e32 = edges.astype(np.int32)                  # int32: radix-sortable
        src = e32.reshape(-1)                         # i0, j0, i1, j1, …
        dst = e32[:, ::-1].reshape(-1)                # j0, i0, j1, i1, …
        w2 = np.repeat(weights.astype(np.int32), 2)
        deg = np.bincount(src, minlength=n)
        max_deg = int(deg.max()) if len(edges) else 1
        nbr_idx = np.tile(np.arange(n, dtype=np.int32)[:, None], (1, max_deg))
        nbr_w = np.zeros((n, max_deg), dtype=np.int32)
        if len(edges):
            order = np.argsort(src, kind="stable")
            ss, dd, ww = src[order], dst[order], w2[order]
            starts = np.concatenate([[0], np.cumsum(deg)[:-1]])
            slot = (np.arange(len(ss)) - np.repeat(starts, deg)).astype(np.int64)
            nbr_idx[ss, slot] = dd
            nbr_w[ss, slot] = ww
        hh = np.zeros(n, dtype=np.int64) if h_in is None else h_in.astype(np.int64)
        model = IsingModel(
            n=n,
            h=hh.astype(np.int32),
            nbr_idx=nbr_idx.astype(np.int32),
            nbr_w=nbr_w.astype(np.int32),
            name=name,
        )
        bound = int(np.abs(hh).max(initial=0) + np.abs(nbr_w).sum(axis=1).max(initial=0))
        if bound >= _F32_EXACT_BOUND:
            raise ValueError(
                f"field bound {bound} exceeds float32-exact range; "
                "use a smaller weight scale"
            )
        return model

    @staticmethod
    def from_dense(J: np.ndarray, h: Optional[np.ndarray] = None, name: str = "ising") -> "IsingModel":
        J = np.asarray(J)
        if np.issubdtype(J.dtype, np.floating) and not np.all(np.isfinite(J)):
            raise ValueError("J must be finite (got NaN/inf)")
        if not np.allclose(J, J.T):
            raise ValueError("J must be symmetric")
        if np.any(np.diag(J) != 0):
            raise ValueError("J must have zero diagonal")
        n = J.shape[0]
        ii, jj = np.nonzero(np.triu(J, k=1))
        edges = np.stack([ii, jj], axis=1)
        return IsingModel.from_edges(n, edges, J[ii, jj], h=h, name=name)

    # -- views -------------------------------------------------------------
    def dense_J(self) -> np.ndarray:
        """Materialize the symmetric dense coupling matrix (int32)."""
        J = np.zeros((self.n, self.n), dtype=np.int64)
        rows = np.repeat(np.arange(self.n), self.max_degree)
        cols = self.nbr_idx.reshape(-1)
        vals = self.nbr_w.reshape(-1)
        np.add.at(J, (rows, cols), vals)
        # padded entries are (i, i, 0): harmless.
        return J.astype(np.int32)

    def edge_list(self) -> Tuple[np.ndarray, np.ndarray]:
        """Recover the unique undirected edge list (E,2), weights (E,)."""
        J = self.dense_J()
        ii, jj = np.nonzero(np.triu(J, k=1))
        return np.stack([ii, jj], axis=1), J[ii, jj]

    def device_arrays(self):
        """jnp copies of (h, nbr_idx, nbr_w) for use inside jitted code."""
        return (
            jnp.asarray(self.h, jnp.int32),
            jnp.asarray(self.nbr_idx, jnp.int32),
            jnp.asarray(self.nbr_w, jnp.int32),
        )


# ---------------------------------------------------------------------------
# Local-field + energy math (pure functions usable under jit/vmap/scan).
# ---------------------------------------------------------------------------
def local_fields_sparse(m, h, nbr_idx, nbr_w):
    """h_i + Σ_j J_ij m_j with padded adjacency.  m: int32[..., N] in {-1,+1}."""
    neigh = jnp.take(m, nbr_idx, axis=-1)  # [..., N, D]
    return h + jnp.sum(nbr_w * neigh, axis=-1)


def local_fields_dense(m, h, J_f32):
    """Float32 MXU path: exact for |field| < 2^24 (asserted at construction)."""
    mf = m.astype(jnp.float32)
    return h + jnp.matmul(mf, J_f32).astype(jnp.int32)


def local_fields_tiled(m, h, nbr_idx, nbr_w, *, tile_n: int = 512,
                       double_buffer: bool = False):
    """Dense-matmul field without ever materializing the (N, N) coupling matrix.

    Streams J one ``(tile_n, N)`` row slab at a time: each scan step scatters
    the slab from the padded adjacency (integer-valued float32, exact) and
    contracts it against the full spin state on the MXU, so the only J-shaped
    buffer alive at any point is one slab — O(tile_n·N) instead of O(N²).
    This is what admits G77/G81-class instances (N = 10k–20k) on the dense
    datapath: at N=16384, one 512-row slab is 32 MB vs 1 GB for dense J.

    The contraction is rectangular: the row count comes from the adjacency
    (``nbr_idx (R, D)``, with ``h (R,)``) and the column count from the spin
    state ``m [..., N]`` — a spin-sharded device passes its own J row shard
    against the all-gathered full spins and gets back its shard's fields
    (DESIGN.md §11).  Unsharded callers have R == N and nothing changes.

    ``double_buffer=True`` software-pipelines the stream the way the
    dual-BRAM p-bit annealer pipelines its coupling reads (arXiv:2602.16143):
    the scan carry holds slab k while the body *first* scatters slab k+1 and
    only then contracts slab k — the slab build (gather/DMA-shaped work) for
    the next step carries no data dependence on the matmul, so the scheduler
    can overlap them.  Same slabs, same per-slab contraction: bit-identical.

    Bit-identical to :func:`local_fields_dense` on the same model (both are
    integer-valued f32 contractions below the 2^24 exactness bound, summation
    order immaterial) — property-tested.  ``m``: [..., N] spins in {-1,+1}.
    """
    n_rows = nbr_idx.shape[0]
    n_cols = m.shape[-1]
    tile_n = int(tile_n)
    nt = -(-n_rows // tile_n)
    pad = nt * tile_n - n_rows
    idx = jnp.pad(jnp.asarray(nbr_idx, jnp.int32), ((0, pad), (0, 0)))
    w = jnp.pad(jnp.asarray(nbr_w, jnp.int32), ((0, pad), (0, 0)))
    mf = m.astype(jnp.float32)
    rows = jnp.arange(tile_n)

    def make_slab(t):
        it = jax.lax.dynamic_slice_in_dim(idx, t * tile_n, tile_n)
        wt = jax.lax.dynamic_slice_in_dim(w, t * tile_n, tile_n)
        # slab = J[t·tile_n : (t+1)·tile_n, :], scattered on the fly.
        return jnp.zeros((tile_n, n_cols), jnp.float32).at[
            rows[:, None], it
        ].add(wt.astype(jnp.float32))

    if double_buffer:
        def one_slab(slab, t):
            # Prefetch t+1 *before* consuming slab t (dynamic_slice clamps,
            # so the dangling prefetch past the last slab is safe/unused).
            nxt = make_slab(t + 1)
            return nxt, jnp.matmul(mf, slab.T)

        _, cols = jax.lax.scan(one_slab, make_slab(0), jnp.arange(nt))
    else:
        def one_slab(_, t):
            return 0, jnp.matmul(mf, make_slab(t).T)

        _, cols = jax.lax.scan(one_slab, 0, jnp.arange(nt))  # (nt, ..., tile_n)
    field = jnp.moveaxis(cols, 0, -2).reshape(m.shape[:-1] + (nt * tile_n,))
    return h + field[..., :n_rows].astype(jnp.int32)


def _popcount_fields_block(m_words, sign, mags):
    """XNOR-popcount contraction of one row block, minus h/base terms.

    m_words: uint32[..., Nw] packed spins; sign: uint32[R, Nw];
    mags: uint32[n_bits, R, Nw].  Returns int32[..., R] equal to
    Σ_b 2^{b+1} · popcount(XNOR(m, sign_r) & mags[b, r]) per row r.
    """
    from repro.kernels.bitplane import popcount_u32

    # XNOR(a, b) = ~(a ^ b) = a ^ ~b; the AND with the magnitude mask
    # confines the contraction to real couplings (tail bits are 0 there).
    x = m_words[..., None, :] ^ ~sign  # [..., R, Nw]
    acc = jnp.sum(popcount_u32(x & mags[0]), axis=-1) << 1
    for b in range(1, mags.shape[0]):
        acc = acc + (jnp.sum(popcount_u32(x & mags[b]), axis=-1) << (b + 1))
    return acc


def local_fields_popcount(m_words, h, packed_j, *, tile_n: Optional[int] = None):
    """Bit-parallel field contraction on uint32 bitplanes (DESIGN.md §8).

    The paper's FPGA datapath computed in software: with J packed as a sign
    plane plus magnitude bitplanes (`kernels.bitplane.PackedJ`), the field

        field_i = h_i + Σ_j J_ij m_j
                = h_i + base_i + Σ_b 2^{b+1}·popcount(XNOR(m, sign_i) & mag_bi)

    is evaluated 32 spins per word op, entirely in uint32/int32 — no unpack
    to ±1 floats anywhere (jaxpr-asserted in tests/test_popcount.py), and
    exact-integer equal to :func:`local_fields_dense` for any integer J.

    ``m_words``: uint32[..., Nw] packed spins (`bitplane.pack_spins`); tail
    bits of the last word may hold anything — the magnitude masks kill them.
    ``tile_n``: row-tile size; None contracts all N rows in one block,
    an int streams (tile_n, Nw) row slabs through a scan so the broadcast
    XNOR buffer stays O(tile_n·Nw) — the G77/G81-class regime.
    """
    sign, mags, base = packed_j.sign, packed_j.mags, packed_j.base
    n = sign.shape[0]
    if tile_n is None or int(tile_n) >= n:
        return h + base + _popcount_fields_block(m_words, sign, mags)

    tile_n = int(tile_n)
    nt = -(-n // tile_n)
    pad = nt * tile_n - n
    sign_p = jnp.pad(sign, ((0, pad), (0, 0)))
    mags_p = jnp.pad(mags, ((0, 0), (0, pad), (0, 0)))

    def one_slab(_, t):
        st = jax.lax.dynamic_slice_in_dim(sign_p, t * tile_n, tile_n)
        mt = jax.lax.dynamic_slice_in_dim(mags_p, t * tile_n, tile_n, axis=1)
        return 0, _popcount_fields_block(m_words, st, mt)

    _, cols = jax.lax.scan(one_slab, 0, jnp.arange(nt))  # (nt, ..., tile_n)
    acc = jnp.moveaxis(cols, 0, -2).reshape(
        m_words.shape[:-1] + (nt * tile_n,)
    )
    return h + base + acc[..., :n]


def ising_energy(m, h, nbr_idx, nbr_w):
    """H = -Σ h_i m_i - 1/2 Σ_ij J_ij m_i m_j  (Eq. 1), int32 exact.

    Works on batched m ([..., N]).
    """
    fields = local_fields_sparse(m, jnp.zeros_like(h), nbr_idx, nbr_w)
    pair = jnp.sum(m * fields, axis=-1) // 2  # Σ_ij double-counts; halve (always even)
    return -(jnp.sum(h * m, axis=-1) + pair)


# ---------------------------------------------------------------------------
# MAX-CUT
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class MaxCutProblem:
    """A MAX-CUT instance G=(V,E,w) and its Ising embedding (Sec. II-C).

    cut(m) = Σ_{(i,j)∈E} w_ij · (1 - m_i m_j) / 2.

    Ising embedding: J = -w, h = 0, so H = Σ_{i<j} w_ij m_i m_j and
    cut = (W_sum - Σ_{i<j} w_ij m_i m_j) / 2 = (W_sum + H·sign) ... concretely
    ``cut = (w_total - pair_sum) / 2`` with ``pair_sum = -H`` when h = 0.
    """

    n: int
    edges: np.ndarray  # (E, 2) int
    weights: np.ndarray  # (E,) int
    name: str = "maxcut"
    best_known: Optional[int] = None

    @property
    def w_total(self) -> int:
        return int(np.sum(self.weights))

    def to_ising(self) -> IsingModel:
        return IsingModel.from_edges(
            self.n, self.edges, -np.asarray(self.weights), name=f"{self.name}-ising"
        )

    def cut_value(self, m) -> jnp.ndarray:
        """Cut value of spin assignment m (int, [..., N], vals in {-1,+1})."""
        wi = jnp.asarray(self.weights, jnp.int32)
        ei = jnp.asarray(self.edges[:, 0], jnp.int32)
        ej = jnp.asarray(self.edges[:, 1], jnp.int32)
        mi = jnp.take(m, ei, axis=-1)
        mj = jnp.take(m, ej, axis=-1)
        return jnp.sum(wi * (1 - mi * mj), axis=-1) // 2

    def cut_from_energy(self, H) -> jnp.ndarray:
        """With J = -w, h = 0:  H = +Σ_{i<j} w_ij m_i m_j, so
        cut = (w_total - H) / 2.  Verified against cut_value in tests."""
        return (self.w_total - H) // 2


def fig4_example() -> MaxCutProblem:
    """The 4-vertex example of paper Fig. 4 (optimal cut = 3).

    Edges: A-B (w=-1), A-C (+1), A-D (+1), B-C (+1), C-D (-1) reproduce the
    figure's structure: partition {A,B} | {C,D} cuts A-C, A-D, B-C = 3, while
    {A} | {B,C,D} cuts A-B, A-C, A-D = 1.
    """
    edges = np.array([[0, 1], [0, 2], [0, 3], [1, 2], [2, 3]])
    weights = np.array([-1, 1, 1, 1, -1])
    return MaxCutProblem(n=4, edges=edges, weights=weights, name="fig4", best_known=3)
