"""Stochastic simulated *quantum* annealing — SSQA (arXiv:2302.12454).

SSQA is the source-paper authors' Trotter-replica variant of SSA: the
path-integral decomposition of a transverse-field Ising model maps the
quantum system onto R coupled classical replicas, and the p-bit update
(Eq. 2a-2c) acquires one extra term — the nearest-neighbor replica coupling

    I_i^k(t+1) = h_i + Σ_j J_ij m_j^k + J⊥(t)·(m_i^{k-1} + m_i^{k+1})
                 + n_rnd·r + Itanh_i^k(t)

with the replica ring closed (k ± 1 mod R) and the coupling J⊥(t) *rising*
as the transverse field Γ(t) anneals to zero (J⊥ ∝ -½·T·ln tanh(Γ/(R·T))).
Everything else — the saturating Itanh counter, the sign update, the
plateau-structured I0 ramp, HA-SSA's storage policy — is unchanged, which
is exactly why the whole existing engine serves SSQA (DESIGN.md §13):

* the replica axis **is the trial axis**: ``n_trials`` holds
  ``n_trials/n_replicas`` independent rings of ``n_replicas`` consecutive
  replicas, so batching, bit-packing, bucket padding, spin sharding, and
  the service's slot splice/extract all carry it untouched;
* the J⊥ ramp rides the schedule: :func:`repro.core.schedule.ssqa_schedule`
  attaches ``jperp_per_cycle`` to the plateau program and
  ``Schedule.signature()`` distinguishes it (executable-cache soundness);
* the coupling folds into the *update* field only — best-tracking and
  energy traces keep the classical per-replica energy, so the reported
  solution is a genuine classical state (the standard SQA convention).

Reported cuts/energies are per-trial exactly like SSA: every replica is a
candidate solution (R× the candidate pool per ring), and ``m_shot`` /
schedules mean the same thing — SSQA vs SSA comparisons at equal
``n_trials`` × ``total_cycles`` are compute-fair (benchmarks/pt_compare.py).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

from .ising import IsingModel, MaxCutProblem
from .schedule import Schedule, ssqa_schedule
from .ssa import AnnealResult, SSAHyperParams, anneal

__all__ = ["SSQAHyperParams", "anneal_ssqa"]


@dataclasses.dataclass(frozen=True)
class SSQAHyperParams(SSAHyperParams):
    """SSQA hyper-parameters: SSA's Table II knobs + the Trotter dimension.

    ``n_trials`` must be a multiple of ``n_replicas``; the trial axis holds
    ``n_trials / n_replicas`` independent Trotter rings.  ``jperp_max`` is
    the integer J⊥ at the coldest plateau (Γ → 0); the ramp is linear in
    plateau index from 0 (free replicas at I0min, large Γ) — see
    :func:`repro.core.schedule.ssqa_schedule`.
    """

    n_trials: int = 96
    n_replicas: int = 8
    jperp_max: int = 4

    def __post_init__(self):
        if self.n_replicas < 2:
            raise ValueError(
                f"n_replicas must be >= 2, got {self.n_replicas}"
            )
        if self.n_trials % self.n_replicas:
            raise ValueError(
                f"n_trials={self.n_trials} must be divisible by "
                f"n_replicas={self.n_replicas} (whole Trotter rings)"
            )
        if self.jperp_max < 0:
            raise ValueError(f"jperp_max must be >= 0, got {self.jperp_max}")

    def schedule(self, kind: str = "hassa") -> Schedule:
        # SSQA's plateau ramp is the shift-based HA-SSA sequence with the
        # J⊥ ramp attached; 'ssqa' and 'hassa' both name it so the driver's
        # default schedule_kind works unchanged.
        if kind in ("hassa", "ssqa"):
            return ssqa_schedule(
                self.i0_min, self.i0_max, self.tau, self.beta_shift,
                jperp_max=self.jperp_max,
            )
        raise ValueError(
            f"SSQA supports schedule_kind 'hassa'/'ssqa', got {kind!r}"
        )


def anneal_ssqa(
    problem: Union[MaxCutProblem, IsingModel],
    hp: Union[SSQAHyperParams, str] = SSQAHyperParams(),
    seed: int = 0,
    *,
    auto_base: Optional[SSQAHyperParams] = None,
    **kw,
) -> AnnealResult:
    """Run SSQA — :func:`repro.core.ssa.anneal` with Trotter-ring coupling.

    This is literally ``anneal`` with an :class:`SSQAHyperParams` (the
    driver keys the replica machinery off the hp type); it exists so the
    launch/CLI/benchmark surfaces have an explicit SSQA entry point.
    ``hp='auto'`` autotunes Γ0 (via jperp_max) and the replica count from
    the instance's local-field distribution (:mod:`repro.core.autotune`).
    """
    if isinstance(hp, str):
        from .autotune import resolve_hyperparams  # lazy: circular import

        hp, _ = resolve_hyperparams(
            hp, problem, base=auto_base or SSQAHyperParams(), algo="ssqa"
        )
    if not isinstance(hp, SSQAHyperParams):
        raise TypeError(f"anneal_ssqa needs SSQAHyperParams, got {type(hp)}")
    return anneal(problem, hp, seed, **kw)
