"""Conventional simulated annealing baseline (paper Sec. II-A, Sec. IV-A).

Single-spin-flip Metropolis: each cycle a random spin is proposed; the flip
is accepted if it lowers the Ising energy, else with probability
exp(-ΔH / T).  Temperature decays geometrically from 10 to 1e-7 over the run
(the paper's CPU baseline configuration).

ΔH for flipping spin i:  ΔH = 2·m_i·(h_i + Σ_j J_ij m_j) — a single padded-
adjacency gather, so one cycle is O(max_deg) per trial.  Trials are batched
on a leading axis exactly as in :mod:`.ssa`, and the driver shares the
engine's problem/result plumbing (:func:`repro.core.engine.normalize_problem`,
:class:`repro.core.engine.BaseResult`) so SA results are interchangeable with
HA-SSA's in the benchmarks and the batch API.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from .engine import BaseResult, finalize_cut, normalize_problem
from .ising import IsingModel, MaxCutProblem
from .schedule import sa_temperature_ladder

__all__ = ["SAHyperParams", "SAResult", "anneal_sa", "sa_init", "sa_cycles", "sa_run"]


@dataclasses.dataclass(frozen=True)
class SAHyperParams:
    n_trials: int = 100
    n_cycles: int = 90_000
    t_start: float = 10.0
    t_end: float = 1e-7


@dataclasses.dataclass
class SAResult(BaseResult):
    hp: SAHyperParams


def _sa_energy(h, nbr_idx, nbr_w, m):
    neigh = jnp.take(m, nbr_idx, axis=-1)
    fields = jnp.sum(nbr_w * neigh, axis=-1)
    return -(jnp.sum(h * m, axis=-1) + jnp.sum(m * fields, axis=-1) // 2)


def sa_init(
    h: jnp.ndarray,        # (N,) int32
    nbr_idx: jnp.ndarray,  # (N, D) int32
    nbr_w: jnp.ndarray,    # (N, D) int32
    key: jax.Array,
    *,
    n_trials: int,
):
    """Random ±1 start; returns the (key, m, H, best_H, best_m) carry."""
    n = h.shape[0]
    key, k0 = jax.random.split(key)
    m0 = jnp.where(
        jax.random.bernoulli(k0, 0.5, (int(n_trials), n)), 1, -1
    ).astype(jnp.int32)
    H0 = _sa_energy(h, nbr_idx, nbr_w, m0)
    return (key, m0, H0, H0, m0)


def sa_cycles(
    h: jnp.ndarray,
    nbr_idx: jnp.ndarray,
    nbr_w: jnp.ndarray,
    carry,                 # (key, m, H, best_H, best_m) from sa_init
    temps: jnp.ndarray,    # (chunk_cycles,) float32
    *,
    n_live=None,           # restrict proposals to lanes [0, n_live) (bucket padding)
    track_energy: bool = False,
):
    """Advance len(temps) Metropolis cycles — the traceable/vmap-able core.

    ``n_live`` (static int or traced scalar) restricts flip proposals to the
    live lanes of a bucket-padded problem; padded lanes (zero h/weights) are
    then never proposed, so they stay inert.  The serving layer vmaps this
    over a stacked problem axis with per-problem ``n_live`` and calls it
    chunk-by-chunk (the key rides in the carry, so chunked == unchunked).
    """
    n = h.shape[0]
    T = carry[1].shape[0]
    n_prop = n if n_live is None else n_live

    def cycle(carry, xs):
        key, m, H, best_H, best_m = carry
        temp = xs
        key, k_site, k_acc = jax.random.split(key, 3)
        i = jax.random.randint(k_site, (T,), 0, n_prop)  # one proposal per trial
        mi = jnp.take_along_axis(m, i[:, None], axis=1)[:, 0]
        nb_i = nbr_idx[i]          # (T, D)
        nb_w = nbr_w[i]            # (T, D)
        neigh = jnp.take_along_axis(
            jnp.broadcast_to(m, (T, n)), nb_i, axis=1
        )
        local = h[i] + jnp.sum(nb_w * neigh, axis=-1)
        dH = 2 * mi * local
        u = jax.random.uniform(k_acc, (T,), minval=1e-12)
        accept = (dH <= 0) | (jnp.log(u) * temp < -dH.astype(jnp.float32))
        m_new = m.at[jnp.arange(T), i].set(jnp.where(accept, -mi, mi))
        H_new = H + jnp.where(accept, dH, 0)
        better = H_new < best_H
        best_H = jnp.where(better, H_new, best_H)
        best_m = jnp.where(better[:, None], m_new, best_m)
        trace = (
            (jnp.mean(H_new.astype(jnp.float32)), jnp.min(H_new))
            if track_energy
            else 0
        )
        return (key, m_new, H_new, best_H, best_m), trace

    return jax.lax.scan(cycle, carry, temps)


def sa_run(
    h: jnp.ndarray,
    nbr_idx: jnp.ndarray,
    nbr_w: jnp.ndarray,
    temps: jnp.ndarray,
    key: jax.Array,
    *,
    n_trials: int,
    n_live=None,
    track_energy: bool = False,
):
    """Full single-problem SA run: :func:`sa_init` + :func:`sa_cycles`.

    Returns (best_H (T,), best_m (T, N), trace) with trace =
    (mean_H (C,), min_H (C,)) when ``track_energy`` else None.
    """
    carry = sa_init(h, nbr_idx, nbr_w, key, n_trials=n_trials)
    carry, trace = sa_cycles(
        h, nbr_idx, nbr_w, carry, temps, n_live=n_live,
        track_energy=track_energy,
    )
    _, _, _, best_H, best_m = carry
    return best_H, best_m, (trace if track_energy else None)


def anneal_sa(
    problem: Union[MaxCutProblem, IsingModel],
    hp: SAHyperParams = SAHyperParams(),
    seed: int = 0,
    *,
    track_energy: bool = True,
    temperatures: Optional[np.ndarray] = None,  # override ladder (Fig. 12 mode)
) -> SAResult:
    maxcut, model = normalize_problem(problem)

    h, nbr_idx, nbr_w = model.device_arrays()
    temps = jnp.asarray(
        sa_temperature_ladder(hp.t_start, hp.t_end, hp.n_cycles)
        if temperatures is None
        else np.asarray(temperatures, np.float32)
    )

    @jax.jit
    def run():
        return sa_run(
            h, nbr_idx, nbr_w, temps, jax.random.PRNGKey(seed),
            n_trials=hp.n_trials, track_energy=track_energy,
        )

    best_H, best_m, trace = run()
    best_H = np.asarray(best_H)
    e_mean, e_min = (trace if track_energy else (None, None))
    return SAResult(
        best_cut=np.asarray(finalize_cut(best_H, maxcut)),
        best_energy=best_H,
        best_m=np.asarray(best_m),
        energy_mean=None if e_mean is None else np.asarray(e_mean),
        energy_min=None if e_min is None else np.asarray(e_min),
        hp=hp,
    )
