"""Conventional simulated annealing baseline (paper Sec. II-A, Sec. IV-A).

Single-spin-flip Metropolis: each cycle a random spin is proposed; the flip
is accepted if it lowers the Ising energy, else with probability
exp(-ΔH / T).  Temperature decays geometrically from 10 to 1e-7 over the run
(the paper's CPU baseline configuration).

ΔH for flipping spin i:  ΔH = 2·m_i·(h_i + Σ_j J_ij m_j) — a single padded-
adjacency gather, so one cycle is O(max_deg) per trial.  Trials are batched
on a leading axis exactly as in :mod:`.ssa`, and the driver shares the
engine's problem/result plumbing (:func:`repro.core.engine.normalize_problem`,
:class:`repro.core.engine.BaseResult`) so SA results are interchangeable with
HA-SSA's in the benchmarks and the batch API.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from .engine import BaseResult, finalize_cut, normalize_problem
from .ising import IsingModel, MaxCutProblem
from .schedule import sa_temperature_ladder

__all__ = ["SAHyperParams", "SAResult", "anneal_sa"]


@dataclasses.dataclass(frozen=True)
class SAHyperParams:
    n_trials: int = 100
    n_cycles: int = 90_000
    t_start: float = 10.0
    t_end: float = 1e-7


@dataclasses.dataclass
class SAResult(BaseResult):
    hp: SAHyperParams


def anneal_sa(
    problem: Union[MaxCutProblem, IsingModel],
    hp: SAHyperParams = SAHyperParams(),
    seed: int = 0,
    *,
    track_energy: bool = True,
    temperatures: Optional[np.ndarray] = None,  # override ladder (Fig. 12 mode)
) -> SAResult:
    maxcut, model = normalize_problem(problem)

    h, nbr_idx, nbr_w = model.device_arrays()
    n, T = model.n, hp.n_trials
    temps = jnp.asarray(
        sa_temperature_ladder(hp.t_start, hp.t_end, hp.n_cycles)
        if temperatures is None
        else np.asarray(temperatures, np.float32)
    )

    def energy(m):
        neigh = jnp.take(m, nbr_idx, axis=-1)
        fields = jnp.sum(nbr_w * neigh, axis=-1)
        return -(jnp.sum(h * m, axis=-1) + jnp.sum(m * fields, axis=-1) // 2)

    def cycle(carry, xs):
        key, m, H, best_H, best_m = carry
        temp = xs
        key, k_site, k_acc = jax.random.split(key, 3)
        i = jax.random.randint(k_site, (T,), 0, n)  # one proposal per trial
        mi = jnp.take_along_axis(m, i[:, None], axis=1)[:, 0]
        nb_i = nbr_idx[i]          # (T, D)
        nb_w = nbr_w[i]            # (T, D)
        neigh = jnp.take_along_axis(
            jnp.broadcast_to(m, (T, n)), nb_i, axis=1
        )
        local = h[i] + jnp.sum(nb_w * neigh, axis=-1)
        dH = 2 * mi * local
        u = jax.random.uniform(k_acc, (T,), minval=1e-12)
        accept = (dH <= 0) | (jnp.log(u) * temp < -dH.astype(jnp.float32))
        m_new = m.at[jnp.arange(T), i].set(jnp.where(accept, -mi, mi))
        H_new = H + jnp.where(accept, dH, 0)
        better = H_new < best_H
        best_H = jnp.where(better, H_new, best_H)
        best_m = jnp.where(better[:, None], m_new, best_m)
        trace = (
            (jnp.mean(H_new.astype(jnp.float32)), jnp.min(H_new))
            if track_energy
            else 0
        )
        return (key, m_new, H_new, best_H, best_m), trace

    @jax.jit
    def run():
        key = jax.random.PRNGKey(seed)
        key, k0 = jax.random.split(key)
        m0 = jnp.where(jax.random.bernoulli(k0, 0.5, (T, n)), 1, -1).astype(jnp.int32)
        H0 = energy(m0)
        carry0 = (key, m0, H0, H0, m0)
        carry, trace = jax.lax.scan(cycle, carry0, temps)
        _, _, _, best_H, best_m = carry
        return best_H, best_m, trace

    best_H, best_m, trace = run()
    best_H = np.asarray(best_H)
    e_mean, e_min = (trace if track_energy else (None, None))
    return SAResult(
        best_cut=np.asarray(finalize_cut(best_H, maxcut)),
        best_energy=best_H,
        best_m=np.asarray(best_m),
        energy_mean=None if e_mean is None else np.asarray(e_mean),
        energy_min=None if e_min is None else np.asarray(e_min),
        hp=hp,
    )
