"""Distributed SSA/HA-SSA: the paper's annealer on the production mesh.

Parallel axes (DESIGN.md §2):
  * replicas (independent trials) → `data`  (the paper runs trials
    sequentially on one FPGA; a pod runs thousands at once),
  * spins → `model` for dense instances (K2000-class): the per-cycle local
    field is a (T, N)·(N, N) matmul with J's rows sharded over `model`;
    GSPMD turns the contraction into partial-sum all-reduces — the only
    collective in the loop, exactly the FPGA's "all spins talk to all
    spin-gates" wiring mapped onto ICI.

``anneal_step_lowering`` builds the pjit'd one-iteration step (full
I0min→I0max sweep with the HA-SSA storage policy fused as a running
arg-best) for the dry-run; the same step runs for real on any mesh.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .rng import xorshift_next_bits
from .ssa import SSAHyperParams, ssa_cycle_update

__all__ = ["make_iteration_step", "anneal_step_lowering"]


def make_iteration_step(hp: SSAHyperParams, mesh: Optional[Mesh] = None):
    """One full I0min→I0max iteration (HA-SSA storage policy fused).

    step(rng (4,T,N) u32, m (T,N) f32, itanh (T,N) i32, best_H (T,) i32,
         best_m (T,N) i8, J (N,N) f32, h (N,) i32) → updated state tuple.
    """
    sched = hp.schedule("hassa")
    i0_seq = jnp.asarray(sched.i0_per_cycle, jnp.int32)
    elig = jnp.asarray(sched.store_mask)

    def constrain(x, spec):
        if mesh is None:
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    def step(rng, m, itanh, best_H, best_m, J, h):
        def cycle(carry, xs):
            rng, m, itanh, best_H, best_m = carry
            i0, el = xs
            field = (h + jnp.matmul(m, J)).astype(jnp.int32)
            rng, r = xorshift_next_bits(rng)
            m_new, it_new = ssa_cycle_update(field, itanh, r, i0, hp.n_rnd)
            m_new = constrain(m_new.astype(jnp.float32), P("data", "model"))
            field_new = (h + jnp.matmul(m_new, J)).astype(jnp.int32)
            m_i = m_new.astype(jnp.int32)
            H = -(jnp.sum(h * m_i, axis=-1) + jnp.sum(m_i * field_new, axis=-1)) // 2
            better = el & (H < best_H)
            best_H = jnp.where(better, H, best_H)
            best_m = jnp.where(better[:, None], m_new.astype(jnp.int8), best_m)
            return (rng, m_new, it_new, best_H, best_m), None

        m = constrain(m, P("data", "model"))
        carry = (rng, m, itanh, best_H, best_m)
        carry, _ = jax.lax.scan(cycle, carry, (i0_seq, elig))
        return carry

    return step


def anneal_step_lowering(
    mesh: Mesh,
    n_spins: int = 2000,
    n_trials: int = 4096,
    hp: Optional[SSAHyperParams] = None,
):
    """Lower+compile the distributed iteration step (dry-run, no allocation)."""
    hp = hp or SSAHyperParams(n_trials=n_trials)
    step = make_iteration_step(hp, mesh)
    T, N = n_trials, n_spins
    dm = NamedSharding(mesh, P("data", "model"))
    dd = NamedSharding(mesh, P("data"))
    rep = NamedSharding(mesh, P())
    jm = NamedSharding(mesh, P("model"))
    shapes = (
        jax.ShapeDtypeStruct((4, T, N), jnp.uint32),   # rng lanes
        jax.ShapeDtypeStruct((T, N), jnp.float32),     # m
        jax.ShapeDtypeStruct((T, N), jnp.int32),       # itanh
        jax.ShapeDtypeStruct((T,), jnp.int32),         # best_H
        jax.ShapeDtypeStruct((T, N), jnp.int8),        # best_m
        jax.ShapeDtypeStruct((N, N), jnp.float32),     # J
        jax.ShapeDtypeStruct((N,), jnp.int32),         # h
    )
    rng_sh = NamedSharding(mesh, P(None, "data", "model"))
    shardings = (rng_sh, dm, dm, dd, dm, jm, rep)
    jitted = jax.jit(step, in_shardings=shardings, donate_argnums=(0, 1, 2, 3, 4))
    with mesh:
        return jitted.lower(*shapes)
