"""Distributed SSA/HA-SSA: the paper's annealer on the production mesh.

Parallel axes (DESIGN.md §2.4):
  * stacked problems (the serving layer's bucketed batch axis) → `data`:
    independent instances of one shape bucket shard across hosts,
  * replicas (independent trials) → `data` in the single-problem step (the
    paper runs trials sequentially on one FPGA; a pod runs thousands at
    once),
  * spins → `model` for dense instances (K2000-class): the per-cycle local
    field is a (T, N)·(N, N) matmul with J's rows sharded over `model`;
    GSPMD turns the contraction into partial-sum all-reduces — the only
    collective in the loop, exactly the FPGA's "all spins talk to all
    spin-gates" wiring mapped onto ICI.

``make_iteration_step`` is built from the plateau engine's
:func:`repro.core.engine.run_plateau_scan`: one full I0min→I0max iteration
is the chain of its constant-I0 plateaus, with HA-SSA's storage policy as
per-plateau eligibility and ONE field contraction per cycle (the same
single-matvec semantics as every local backend — bit-identical, tested).
``make_batched_iteration_step`` is the same chain over a leading problem
axis — `run_plateau_scan` is batch-transparent, so the bucketed service
batch threads straight through to the mesh (problems on `data`, spins on
`model`).  It also carries the packed-memory subsystem's axes
(DESIGN.md §4): ``storage_layout='packed'`` makes the state crossing the
pjit launch boundary uint32 spin bitplanes, and ``j_mode='tiled'`` replaces
the (B, N, N) J argument with the stacked adjacency and streams
(tile_n, N) slabs — both bit-identical per problem to the default step.
``anneal_step_lowering`` / ``batched_anneal_step_lowering`` lower the
pjit'd steps for the dry-run; the same steps run for real on any mesh.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.kernels.bitplane import PackedJ
from repro.sharding import mesh_axis_size, spin_mesh

from .engine import (
    BatchedBackend,
    EngineState,
    PackedEngineState,
    Plateau,
    PlateauBackend,
    TILED_J_THRESHOLD,
    _stack_packed_models,
    _stack_sparse_models,
    pack_spins,
    resolve_backend,
    resolve_field_mode,
    run_plateau_scan,
    padded_noise_init_slice,
    schedule_plateaus,
    unpack_spins,
)
from .ising import local_fields_popcount, local_fields_sparse, local_fields_tiled
from .rng import xorshift_next_bits
from .ssa import SSAHyperParams

__all__ = [
    "make_iteration_step",
    "anneal_step_lowering",
    "make_batched_iteration_step",
    "batched_anneal_step_lowering",
    "SPIN_AXIS",
    "SpinShardedBackend",
    "BatchedSpinShardedBackend",
]

# Default mesh-axis name the spin axis shards over (DESIGN.md §11).
SPIN_AXIS = "model"


def make_iteration_step(hp: SSAHyperParams, mesh: Optional[Mesh] = None):
    """One full I0min→I0max iteration (HA-SSA storage policy fused).

    step(rng (4,T,N) u32, m (T,N) f32, itanh (T,N) i32, best_H (T,) i32,
         best_m (T,N) i8, J (N,N) f32, h (N,) i32) → updated state tuple.
    """
    plateaus = schedule_plateaus(hp.schedule("hassa"), "i0max")

    def constrain(x, spec):
        if mesh is None:
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    def step(rng, m, itanh, best_H, best_m, J, h):
        def field_fn(m8):
            mf = constrain(m8.astype(jnp.float32), P("data", "model"))
            return (h + jnp.matmul(mf, J)).astype(jnp.int32)

        state = EngineState(rng, m.astype(jnp.int8), itanh, best_H, best_m)
        for p in plateaus:
            state, _, _ = run_plateau_scan(
                field_fn, xorshift_next_bits, h, hp.n_rnd, state, p.i0,
                length=p.length, eligible=p.eligible,
            )
        return (
            state.noise_state,
            constrain(state.m.astype(jnp.float32), P("data", "model")),
            state.itanh,
            state.best_H,
            state.best_m,
        )

    return step


def anneal_step_lowering(
    mesh: Mesh,
    n_spins: int = 2000,
    n_trials: int = 4096,
    hp: Optional[SSAHyperParams] = None,
):
    """Lower+compile the distributed iteration step (dry-run, no allocation)."""
    hp = hp or SSAHyperParams(n_trials=n_trials)
    step = make_iteration_step(hp, mesh)
    T, N = n_trials, n_spins
    dm = NamedSharding(mesh, P("data", "model"))
    dd = NamedSharding(mesh, P("data"))
    rep = NamedSharding(mesh, P())
    jm = NamedSharding(mesh, P("model"))
    shapes = (
        jax.ShapeDtypeStruct((4, T, N), jnp.uint32),   # rng lanes
        jax.ShapeDtypeStruct((T, N), jnp.float32),     # m
        jax.ShapeDtypeStruct((T, N), jnp.int32),       # itanh
        jax.ShapeDtypeStruct((T,), jnp.int32),         # best_H
        jax.ShapeDtypeStruct((T, N), jnp.int8),        # best_m
        jax.ShapeDtypeStruct((N, N), jnp.float32),     # J
        jax.ShapeDtypeStruct((N,), jnp.int32),         # h
    )
    rng_sh = NamedSharding(mesh, P(None, "data", "model"))
    shardings = (rng_sh, dm, dm, dd, dm, jm, rep)
    jitted = jax.jit(step, in_shardings=shardings, donate_argnums=(0, 1, 2, 3, 4))
    with mesh:
        return jitted.lower(*shapes)


def make_batched_iteration_step(
    hp: SSAHyperParams,
    mesh: Optional[Mesh] = None,
    *,
    storage_layout: str = "dense",
    j_mode: str = "dense",
    tile_n: int = 512,
    field_mode: str = "dense",
):
    """One full iteration over B stacked (bucket-padded) problems.

    The serving layer's batch axis on the mesh: problems shard over `data`,
    spins over `model`; trials stay local.  `run_plateau_scan` is
    batch-transparent, so this is the *same* plateau chain as
    :func:`make_iteration_step` with a leading problem axis — per problem
    bit-identical to the single-problem step (tested).

    Default (dense layout, dense J):
      step(rng (4,B,T,N) u32, m (B,T,N) f32, itanh (B,T,N) i32,
           best_H (B,T) i32, best_m (B,T,N) i8, J (B,N,N) f32, h (B,N) i32)
      → updated state tuple.

    ``storage_layout='packed'`` replaces m/best_m at the step boundary with
    (B, T, ceil(N/32)) uint32 bitplanes — the HBM-resident state between
    pjit launches is the packed layout, 32×/8× smaller than f32/i8 spins.
    ``j_mode='tiled'`` replaces J with the stacked padded adjacency
    ``nbr_idx (B,N,D) i32, nbr_w (B,N,D) i32`` and streams (tile_n, N) J
    slabs per problem — no (B, N, N) buffer, admitting G77/G81-class N.
    ``field_mode='popcount'`` (takes precedence over j_mode) replaces J
    with the stacked `PackedJ` bitplanes ``sign (B,N,Nw) u32,
    mags (B,nb,N,Nw) u32, base (B,N) i32`` and contracts by XNOR-popcount
    (DESIGN.md §8) — exact-integer, ~32×/n_bits less J traffic.
    All are bit-identical per problem to the default step (tested).

    Sharding caveat: the "spins over `model`" layout above applies to the
    dense-J step (the matmul contraction is what GSPMD partitions).  The
    tiled step constrains spins to P("data", None, None) — replicated over
    the model axis, each device scattering/contracting its problems' slabs
    locally — trading redundant field compute for zero collectives; its
    scale-out axis is the problem batch on `data`.
    """
    if storage_layout not in ("dense", "packed"):
        raise ValueError(f"unknown storage_layout {storage_layout!r}")
    if j_mode not in ("dense", "tiled"):
        raise ValueError(f"unknown j_mode {j_mode!r}")
    if field_mode not in ("dense", "popcount"):
        raise ValueError(f"unknown field_mode {field_mode!r}")
    plateaus = schedule_plateaus(hp.schedule("hassa"), "i0max")

    def constrain(x, spec):
        if mesh is None:
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    def step(rng, m, itanh, best_H, best_m, *problem):
        n = itanh.shape[-1]
        if field_mode == "popcount":
            from repro.kernels.bitplane import PackedJ  # lazy, like engine

            sign, mags, base, h = problem

            def field_fn(m8):
                # Like the tiled step: spins replicated over `model`, each
                # device contracting its problems' bitplanes locally — the
                # scale-out axis is the problem batch on `data`.
                mw = pack_spins(constrain(m8, P("data", None, None)))
                return jax.vmap(
                    lambda w, hh, s, g, b: local_fields_popcount(
                        w, hh, PackedJ(s, g, b)
                    )
                )(mw, h, sign, mags, base)
        elif j_mode == "tiled":
            nbr_idx, nbr_w, h = problem

            def field_fn(m8):
                mc = constrain(m8, P("data", None, None))
                return jax.vmap(
                    lambda mm, hh, ii, ww: local_fields_tiled(
                        mm, hh, ii, ww, tile_n=tile_n
                    )
                )(mc, h, nbr_idx, nbr_w)
        else:
            J, h = problem

            def field_fn(m8):
                mf = constrain(m8.astype(jnp.float32), P("data", None, "model"))
                return (
                    h[:, None, :] + jnp.einsum("btn,bnk->btk", mf, J)
                ).astype(jnp.int32)

        h3 = h[:, None, :]  # (B, 1, N): broadcasts against (B, T, N) spins
        if storage_layout == "packed":
            m8 = unpack_spins(m, n)
            bm8 = unpack_spins(best_m, n)
        else:
            m8, bm8 = m.astype(jnp.int8), best_m
        state = EngineState(rng, m8, itanh, best_H, bm8)
        for p in plateaus:
            state, _, _ = run_plateau_scan(
                field_fn, xorshift_next_bits, h3, hp.n_rnd, state, p.i0,
                length=p.length, eligible=p.eligible,
            )
        if storage_layout == "packed":
            m_out, bm_out = pack_spins(state.m), pack_spins(state.best_m)
        else:
            m_out = constrain(
                state.m.astype(jnp.float32), P("data", None, "model")
            )
            bm_out = state.best_m
        return (state.noise_state, m_out, state.itanh, state.best_H, bm_out)

    return step


def batched_anneal_step_lowering(
    mesh: Mesh,
    n_problems: int = 8,
    n_spins: int = 2048,
    n_trials: int = 512,
    hp: Optional[SSAHyperParams] = None,
    *,
    storage_layout: str = "dense",
    j_mode: str = "dense",
    max_degree: int = 4,
    tile_n: int = 512,
    field_mode: str = "dense",
    j_bits: int = 1,
):
    """Lower+compile the batched iteration step (dry-run, no allocation)."""
    hp = hp or SSAHyperParams(n_trials=n_trials)
    step = make_batched_iteration_step(
        hp, mesh, storage_layout=storage_layout, j_mode=j_mode, tile_n=tile_n,
        field_mode=field_mode,
    )
    B, T, N = n_problems, n_trials, n_spins
    dm = NamedSharding(mesh, P("data", None, "model"))
    dd = NamedSharding(mesh, P("data"))
    hb = NamedSharding(mesh, P("data", None))
    if storage_layout == "packed":
        nw = (N + 31) // 32
        spin_sh = NamedSharding(mesh, P("data", None, None))
        m_shape = jax.ShapeDtypeStruct((B, T, nw), jnp.uint32)
        bm_shape = jax.ShapeDtypeStruct((B, T, nw), jnp.uint32)
    else:
        spin_sh = dm
        m_shape = jax.ShapeDtypeStruct((B, T, N), jnp.float32)
        bm_shape = jax.ShapeDtypeStruct((B, T, N), jnp.int8)
    shapes = [
        jax.ShapeDtypeStruct((4, B, T, N), jnp.uint32),  # rng lanes
        m_shape,                                         # m (layout-dependent)
        jax.ShapeDtypeStruct((B, T, N), jnp.int32),      # itanh
        jax.ShapeDtypeStruct((B, T), jnp.int32),         # best_H
        bm_shape,                                        # best_m
    ]
    if field_mode == "popcount":
        jw = (N + 31) // 32
        prob_shapes = [
            jax.ShapeDtypeStruct((B, N, jw), jnp.uint32),          # sign
            jax.ShapeDtypeStruct((B, j_bits, N, jw), jnp.uint32),  # mags
            jax.ShapeDtypeStruct((B, N), jnp.int32),               # base
        ]
        prob_sh = [
            NamedSharding(mesh, P("data", None, None)),
            NamedSharding(mesh, P("data", None, None, None)),
            NamedSharding(mesh, P("data", None)),
        ]
    elif j_mode == "tiled":
        prob_shapes = [
            jax.ShapeDtypeStruct((B, N, max_degree), jnp.int32),  # nbr_idx
            jax.ShapeDtypeStruct((B, N, max_degree), jnp.int32),  # nbr_w
        ]
        prob_sh = [NamedSharding(mesh, P("data", None, None))] * 2
    else:
        prob_shapes = [jax.ShapeDtypeStruct((B, N, N), jnp.float32)]  # J
        prob_sh = [NamedSharding(mesh, P("data", "model", None))]
    shapes += prob_shapes + [jax.ShapeDtypeStruct((B, N), jnp.int32)]  # h
    rng_sh = NamedSharding(mesh, P(None, "data", None, "model"))
    shardings = tuple([rng_sh, spin_sh, dm, dd, spin_sh] + prob_sh + [hb])
    jitted = jax.jit(step, in_shardings=shardings, donate_argnums=(0, 1, 2, 3, 4))
    with mesh:
        return jitted.lower(*tuple(shapes))


# ---------------------------------------------------------------------------
# Spin-sharded execution (DESIGN.md §11): partition='spin'
#
# The problem-partitioned paths above replicate the spin axis and scale out
# over the *problem* batch; a single giant instance (100k+ spins) needs the
# spin axis itself split.  These backends run the exact plateau engine
# (`run_plateau_scan`, unchanged) inside a `shard_map` over one mesh axis:
#
#   * state shards: each device owns spins [i·Ns, (i+1)·Ns) of every trial —
#     its itanh, its xorshift lanes (seeded shard-locally via
#     `padded_noise_init_slice`, bit-identical to the global stream), its
#     best-m columns.  best_H stays replicated (it is psum'd every fold).
#   * J shards by rows: the f32-tiled slabs and the PackedJ popcount
#     bitplanes are both row-rectangular contractions, so each device holds
#     only its Ns rows — per-device J residency drops ~linearly in devices.
#   * one collective per cycle: the update m(t) → m(t+1) needs the *full*
#     spin state on every device.  Spins are ±1, so the all-gather moves
#     packed uint32 bitplanes — N/32 words per (trial, plane), 8×/32× below
#     int8/f32 — the bitplane format is what makes the collective cheap.
#   * energy: H folds/traces psum the per-shard partial sums *before* the
#     floor division (local h·m + m·field may be odd; int32 addition is
#     exact and order-free, so sharded H is bit-identical to unsharded).
#
# `check_rep=False`: jax 0.4.x cannot statically infer that an all-gathered
# value is replicated; replication of best_H is instead guaranteed by the
# psum and asserted (bit-identity vs the unsharded backends) in tests.
# ---------------------------------------------------------------------------


class BatchedSpinShardedBackend(BatchedBackend):
    """B stacked problems with the *spin axis* sharded over a mesh axis.

    The serving path for instances too big for one device: the same
    bucket/stack/chunk protocol as every :class:`BatchedBackend` (so
    `AnnealService` drives it unchanged), but problem arrays are laid out
    row-sharded over ``mesh`` at :meth:`stack` time and every plateau runs
    as a `shard_map` collective program.  Bit-identical per problem to the
    problem-partitioned backends on live lanes (property-tested).

    ``base_backend`` picks the field contraction the shards run locally:
    'sparse' gathers from the all-gathered spins through the padded
    adjacency; 'dense'/'pallas' use the rectangular f32 tiled-slab stream
    (``field_mode='dense'``, with ``double_buffer`` prefetch pipelining) or
    the XNOR-popcount bitplane contraction (``field_mode='popcount'``).
    The resident Pallas kernels are single-device programs, so under spin
    sharding 'pallas' runs its arithmetic through these scan paths.
    """

    name = "spinshard"

    def __init__(self, *, mesh: Optional[Mesh] = None, axis: str = SPIN_AXIS,
                 base_backend: str = "dense", j_mode: str = "auto",
                 tile_n: int = 512, field_mode: str = "auto", j_bits: int = 1,
                 double_buffer: bool = True, j_dtype=None, block_r=None,
                 interpret=None, noise_mode=None, **kw):
        super().__init__(**kw)
        if self.noise != "xorshift":
            raise ValueError(
                "partition='spin' requires noise='xorshift': shard-local "
                "lane seeding is what makes sharded runs bit-identical"
            )
        del j_mode, j_dtype, block_r, interpret, noise_mode  # single-device knobs
        self.mesh = spin_mesh(1, axis=axis) if mesh is None else mesh
        self.axis = axis
        self.n_dev = mesh_axis_size(self.mesh, axis)
        if self.n_bucket % self.n_dev:
            raise ValueError(
                f"partition='spin': bucket {self.n_bucket} not divisible by "
                f"the {self.n_dev}-way {axis!r} mesh axis"
            )
        self.n_shard = self.n_bucket // self.n_dev
        self.tile_n = int(tile_n)
        self.j_bits = int(j_bits)
        self.double_buffer = bool(double_buffer)
        base = resolve_backend(base_backend, self.n_bucket)
        if base == "sparse":
            self.field_mode = "dense"
            self.field_style = "sparse"
        else:
            self.field_mode = resolve_field_mode(field_mode, self.j_bits)
            self.field_style = (
                "popcount" if self.field_mode == "popcount" else "tiled"
            )
        self.base_backend = base
        # Row-tile the popcount contraction in the regime the matmul would
        # tile J — but against the *shard's* row count, not the bucket's.
        self._pc_tile = (
            None if self.n_shard <= TILED_J_THRESHOLD else self.tile_n
        )
        # Packed-layout spin words shard over devices only when each shard
        # is word-aligned; otherwise the (tiny) planes stay replicated and
        # each device slices its columns after the local unpack.
        self._words_shardable = self.n_shard % 32 == 0

    # -- sharding layout --------------------------------------------------
    def _problem_specs(self) -> dict:
        ax = self.axis
        if self.field_style == "popcount":
            return {
                "h": P(None, ax),
                "sign": P(None, ax, None),
                "mags": P(None, None, ax, None),
                "base": P(None, ax),
            }
        return {
            "h": P(None, ax),
            "nbr_idx": P(None, ax, None),
            "nbr_w": P(None, ax, None),
        }

    def _state_specs(self):
        ax = self.axis
        lanes = P(None, None, None, ax)
        spins = P(None, None, ax)
        rep = P(None, None)
        if self.storage_layout == "packed":
            words = spins if self._words_shardable else rep
            return PackedEngineState(lanes, words, spins, rep, words)
        return EngineState(lanes, spins, spins, rep, spins)

    def _put_state(self, st):
        def put(x, spec):
            sh = NamedSharding(self.mesh, spec)
            if isinstance(x, jax.core.Tracer):
                return jax.lax.with_sharding_constraint(x, sh)
            return jax.device_put(x, sh)

        return type(st)(*(put(x, s) for x, s in zip(st, self._state_specs())))

    # -- host side --------------------------------------------------------
    def stack(self, models) -> dict:
        if self.field_style == "popcount":
            problem = _stack_packed_models(models, self.n_bucket, self.j_bits)
        else:
            problem = _stack_sparse_models(models, self.n_bucket)
        specs = self._problem_specs()
        return {
            k: jax.device_put(v, NamedSharding(self.mesh, specs[k]))
            for k, v in problem.items()
        }

    def init_noise(self, seeds, n_lives):
        """Shard-local lane seeding: each device seeds only its columns.

        `make_array_from_callback` hands every device its slice of the
        global (B, 4, T, N_bucket) lane array; `padded_noise_init_slice`
        seeds exactly those columns bit-identically to the full
        `padded_noise_init` — no device ever materializes the global lanes.
        """
        seeds = [int(s) for s in seeds]
        n_lives = [int(x) for x in n_lives]
        T, nb = self.n_trials, self.n_bucket
        shape = (len(seeds), 4, T, nb)
        sh = NamedSharding(self.mesh, P(None, None, None, self.axis))

        def cb(index):
            lo, hi, _ = index[3].indices(nb)
            return np.stack([
                padded_noise_init_slice(s, T, nl, nb, lo, hi)
                for s, nl in zip(seeds, n_lives)
            ])

        return jax.make_array_from_callback(shape, sh, cb)

    # -- traced -----------------------------------------------------------
    def init_state(self, problem, noise0):
        return self._put_state(super().init_state(problem, noise0))

    def _energy_local(self, m, field, h):
        # energy_from_field with the trial sums psum'd over shards BEFORE
        # the floor division: local (h·m + m·field) may be odd, the global
        # sum is what's even; int32 addition is order-free, so this is
        # bit-identical to the unsharded fold.
        m32 = m.astype(jnp.int32)
        s = jnp.sum(h * m32, axis=-1) + jnp.sum(m32 * field, axis=-1)
        return -jax.lax.psum(s, self.axis) // 2

    def _gather_words(self, m_local):
        """Local spin shard → full packed bitplanes (the cheap collective)."""
        if self.n_shard % 32 == 0:
            w = pack_spins(m_local)
            return jax.lax.all_gather(w, self.axis, axis=-1, tiled=True)
        m_full = jax.lax.all_gather(m_local, self.axis, axis=-1, tiled=True)
        return pack_spins(m_full)

    def _gather_spins(self, m_local):
        """Local spin shard → full int8 spins, moved packed when aligned."""
        if self.n_shard % 32 == 0:
            return unpack_spins(self._gather_words(m_local), self.n_bucket)
        return jax.lax.all_gather(m_local, self.axis, axis=-1, tiled=True)

    def _field_local(self, prob, m_local):
        """This shard's fields from its J rows + the all-gathered spins."""
        if self.field_style == "popcount":
            mw = self._gather_words(m_local)
            return jax.vmap(
                lambda w, hh, s, g, b: local_fields_popcount(
                    w, hh, PackedJ(s, g, b), tile_n=self._pc_tile
                )
            )(mw, prob["h"], prob["sign"], prob["mags"], prob["base"])
        m_full = self._gather_spins(m_local)
        if self.field_style == "sparse":
            return jax.vmap(
                lambda mm, hh, ii, ww: local_fields_sparse(
                    mm.astype(jnp.int32), hh, ii, ww
                )
            )(m_full, prob["h"], prob["nbr_idx"], prob["nbr_w"])
        return jax.vmap(
            lambda mm, hh, ii, ww: local_fields_tiled(
                mm, hh, ii, ww, tile_n=self.tile_n,
                double_buffer=self.double_buffer,
            )
        )(m_full, prob["h"], prob["nbr_idx"], prob["nbr_w"])

    def _unpack_local(self, st: PackedEngineState) -> EngineState:
        if self._words_shardable:
            return EngineState(
                st.noise_state, unpack_spins(st.m_packed, self.n_shard),
                st.itanh, st.best_H,
                unpack_spins(st.best_m_packed, self.n_shard),
            )
        i = jax.lax.axis_index(self.axis)

        def cols(words):
            full = unpack_spins(words, self.n_bucket)
            return jax.lax.dynamic_slice_in_dim(
                full, i * self.n_shard, self.n_shard, axis=full.ndim - 1
            )

        return EngineState(
            st.noise_state, cols(st.m_packed), st.itanh, st.best_H,
            cols(st.best_m_packed),
        )

    def _pack_local(self, st: EngineState) -> PackedEngineState:
        if self._words_shardable:
            return PackedEngineState(
                st.noise_state, pack_spins(st.m), st.itanh, st.best_H,
                pack_spins(st.best_m),
            )
        mf = jax.lax.all_gather(st.m, self.axis, axis=-1, tiled=True)
        bf = jax.lax.all_gather(st.best_m, self.axis, axis=-1, tiled=True)
        return PackedEngineState(
            st.noise_state, pack_spins(mf), st.itanh, st.best_H,
            pack_spins(bf),
        )

    def _local_chain(self, prob, st, plateaus, n_shots):
        h3 = prob["h"][:, None, :]
        field_fn = lambda m: self._field_local(prob, m)  # noqa: E731

        def iteration(st, _):
            for p in plateaus:
                st, _, _ = run_plateau_scan(
                    field_fn, self._noise_step, h3, self.n_rnd, st, p.i0,
                    length=p.length, eligible=p.eligible,
                    energy_fn=self._energy_local,
                    jperp=p.jperp, n_replicas=self.n_replicas,
                )
            return st, None

        st, _ = jax.lax.scan(iteration, st, None, length=n_shots)
        return st

    def _sharded_chain(self, plateaus, n_shots: int):
        plateaus = tuple(plateaus)
        packed = self.storage_layout == "packed"
        sspec = self._state_specs()

        def local_fn(prob, st):
            if packed:
                st = self._unpack_local(st)
            st = self._local_chain(prob, st, plateaus, n_shots)
            if packed:
                st = self._pack_local(st)
            return st

        return shard_map(
            local_fn, mesh=self.mesh,
            in_specs=(self._problem_specs(), sspec), out_specs=sspec,
            check_rep=False,
        )

    def run_plateau(self, problem, state, i0, *, length, eligible, jperp=0):
        p = Plateau(int(i0), int(length), bool(eligible), int(jperp))
        return self._sharded_chain((p,), 1)(problem, state)

    def run_plateau_traced(self, problem, state, plateau: Plateau,
                           track_energy: bool):
        """One plateau with energy traces (the track_energy driver path)."""
        packed = self.storage_layout == "packed"
        sspec = self._state_specs()

        def local_fn(prob, st):
            if packed:
                st = self._unpack_local(st)
            h3 = prob["h"][:, None, :]
            st, trace, _ = run_plateau_scan(
                lambda m: self._field_local(prob, m), self._noise_step, h3,
                self.n_rnd, st, plateau.i0, length=plateau.length,
                eligible=plateau.eligible, track_energy=track_energy,
                energy_fn=self._energy_local,
                jperp=plateau.jperp, n_replicas=self.n_replicas,
            )
            if packed:
                st = self._pack_local(st)
            if track_energy:
                return st, trace
            return st, (jnp.zeros((0,)), jnp.zeros((0,)))

        return shard_map(
            local_fn, mesh=self.mesh,
            in_specs=(self._problem_specs(), sspec),
            out_specs=(sspec, (P(None), P(None))),
            check_rep=False,
        )(problem, state)

    def run_shots(self, problem, state, plateaus, n_shots):
        return self._sharded_chain(tuple(plateaus), int(n_shots))(
            problem, state
        )


class SpinShardedBackend(PlateauBackend):
    """Single-problem spin-sharded backend (the `anneal` driver path).

    Wraps a B=1 :class:`BatchedSpinShardedBackend`: the model is padded up
    to a multiple of the mesh axis (padding-invariant — live lanes evolve
    bit-identically, the pad columns are inert), its row shards are laid
    out at construction, and every plateau runs as the shard_map collective
    program.  `record='traj'` (trajectory planes) is not supported on this
    path — emit semantics are per-device partial planes; use
    partition='problem' for trajectory studies.
    """

    name = "spinshard"

    def __init__(self, model, *, n_trials: int, n_rnd: int = 2,
                 noise: str = "xorshift", storage_layout: str = "dense",
                 mesh: Optional[Mesh] = None, axis: str = SPIN_AXIS, **opts):
        if noise != "xorshift":
            raise ValueError(
                "partition='spin' requires noise='xorshift': shard-local "
                "lane seeding is what makes sharded runs bit-identical"
            )
        super().__init__(model, n_trials=n_trials, n_rnd=n_rnd, noise=noise,
                         storage_layout=storage_layout)
        mesh = spin_mesh(axis=axis) if mesh is None else mesh
        n_dev = mesh_axis_size(mesh, axis)
        n_pad = -(-model.n // n_dev) * n_dev
        self._bk = BatchedSpinShardedBackend(
            mesh=mesh, axis=axis, n_bucket=n_pad, n_trials=n_trials,
            n_rnd=n_rnd, noise=noise, storage_layout=storage_layout, **opts,
        )
        self.mesh = mesh
        self.n_replicas = self._bk.n_replicas
        self._problem = self._bk.stack([model])

    def init_state(self, seed: int):
        noise0 = self._bk.init_noise([seed], [self.model.n])
        return self._bk.init_state(self._problem, noise0)

    def run_plateau(self, state, i0, *, length, eligible, track_energy=False,
                    emit=False, jperp=0):
        if emit:
            raise NotImplementedError(
                "record='traj' is not supported under partition='spin'; "
                "use partition='problem' for trajectory capture"
            )
        p = Plateau(int(i0), int(length), bool(eligible), int(jperp))
        if track_energy:
            st, trace = self._bk.run_plateau_traced(self._problem, state, p, True)
            return st, trace, None
        st = self._bk.run_plateau(
            self._problem, state, p.i0, length=p.length, eligible=p.eligible,
            jperp=p.jperp,
        )
        return st, None, None

    def run_plateaus(self, state, plateaus):
        return self._bk.run_shots(self._problem, state, tuple(plateaus), 1)

    def finalize(self, state):
        best_H, best_m = self._bk.finalize(state)
        return best_H[0], best_m[0, :, : self.model.n]
