"""Distributed SSA/HA-SSA: the paper's annealer on the production mesh.

Parallel axes (DESIGN.md §2.4):
  * stacked problems (the serving layer's bucketed batch axis) → `data`:
    independent instances of one shape bucket shard across hosts,
  * replicas (independent trials) → `data` in the single-problem step (the
    paper runs trials sequentially on one FPGA; a pod runs thousands at
    once),
  * spins → `model` for dense instances (K2000-class): the per-cycle local
    field is a (T, N)·(N, N) matmul with J's rows sharded over `model`;
    GSPMD turns the contraction into partial-sum all-reduces — the only
    collective in the loop, exactly the FPGA's "all spins talk to all
    spin-gates" wiring mapped onto ICI.

``make_iteration_step`` is built from the plateau engine's
:func:`repro.core.engine.run_plateau_scan`: one full I0min→I0max iteration
is the chain of its constant-I0 plateaus, with HA-SSA's storage policy as
per-plateau eligibility and ONE field contraction per cycle (the same
single-matvec semantics as every local backend — bit-identical, tested).
``make_batched_iteration_step`` is the same chain over a leading problem
axis — `run_plateau_scan` is batch-transparent, so the bucketed service
batch threads straight through to the mesh (problems on `data`, spins on
`model`).  It also carries the packed-memory subsystem's axes
(DESIGN.md §4): ``storage_layout='packed'`` makes the state crossing the
pjit launch boundary uint32 spin bitplanes, and ``j_mode='tiled'`` replaces
the (B, N, N) J argument with the stacked adjacency and streams
(tile_n, N) slabs — both bit-identical per problem to the default step.
``anneal_step_lowering`` / ``batched_anneal_step_lowering`` lower the
pjit'd steps for the dry-run; the same steps run for real on any mesh.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .engine import (
    EngineState,
    pack_spins,
    run_plateau_scan,
    schedule_plateaus,
    unpack_spins,
)
from .ising import local_fields_popcount, local_fields_tiled
from .rng import xorshift_next_bits
from .ssa import SSAHyperParams

__all__ = [
    "make_iteration_step",
    "anneal_step_lowering",
    "make_batched_iteration_step",
    "batched_anneal_step_lowering",
]


def make_iteration_step(hp: SSAHyperParams, mesh: Optional[Mesh] = None):
    """One full I0min→I0max iteration (HA-SSA storage policy fused).

    step(rng (4,T,N) u32, m (T,N) f32, itanh (T,N) i32, best_H (T,) i32,
         best_m (T,N) i8, J (N,N) f32, h (N,) i32) → updated state tuple.
    """
    plateaus = schedule_plateaus(hp.schedule("hassa"), "i0max")

    def constrain(x, spec):
        if mesh is None:
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    def step(rng, m, itanh, best_H, best_m, J, h):
        def field_fn(m8):
            mf = constrain(m8.astype(jnp.float32), P("data", "model"))
            return (h + jnp.matmul(mf, J)).astype(jnp.int32)

        state = EngineState(rng, m.astype(jnp.int8), itanh, best_H, best_m)
        for p in plateaus:
            state, _, _ = run_plateau_scan(
                field_fn, xorshift_next_bits, h, hp.n_rnd, state, p.i0,
                length=p.length, eligible=p.eligible,
            )
        return (
            state.noise_state,
            constrain(state.m.astype(jnp.float32), P("data", "model")),
            state.itanh,
            state.best_H,
            state.best_m,
        )

    return step


def anneal_step_lowering(
    mesh: Mesh,
    n_spins: int = 2000,
    n_trials: int = 4096,
    hp: Optional[SSAHyperParams] = None,
):
    """Lower+compile the distributed iteration step (dry-run, no allocation)."""
    hp = hp or SSAHyperParams(n_trials=n_trials)
    step = make_iteration_step(hp, mesh)
    T, N = n_trials, n_spins
    dm = NamedSharding(mesh, P("data", "model"))
    dd = NamedSharding(mesh, P("data"))
    rep = NamedSharding(mesh, P())
    jm = NamedSharding(mesh, P("model"))
    shapes = (
        jax.ShapeDtypeStruct((4, T, N), jnp.uint32),   # rng lanes
        jax.ShapeDtypeStruct((T, N), jnp.float32),     # m
        jax.ShapeDtypeStruct((T, N), jnp.int32),       # itanh
        jax.ShapeDtypeStruct((T,), jnp.int32),         # best_H
        jax.ShapeDtypeStruct((T, N), jnp.int8),        # best_m
        jax.ShapeDtypeStruct((N, N), jnp.float32),     # J
        jax.ShapeDtypeStruct((N,), jnp.int32),         # h
    )
    rng_sh = NamedSharding(mesh, P(None, "data", "model"))
    shardings = (rng_sh, dm, dm, dd, dm, jm, rep)
    jitted = jax.jit(step, in_shardings=shardings, donate_argnums=(0, 1, 2, 3, 4))
    with mesh:
        return jitted.lower(*shapes)


def make_batched_iteration_step(
    hp: SSAHyperParams,
    mesh: Optional[Mesh] = None,
    *,
    storage_layout: str = "dense",
    j_mode: str = "dense",
    tile_n: int = 512,
    field_mode: str = "dense",
):
    """One full iteration over B stacked (bucket-padded) problems.

    The serving layer's batch axis on the mesh: problems shard over `data`,
    spins over `model`; trials stay local.  `run_plateau_scan` is
    batch-transparent, so this is the *same* plateau chain as
    :func:`make_iteration_step` with a leading problem axis — per problem
    bit-identical to the single-problem step (tested).

    Default (dense layout, dense J):
      step(rng (4,B,T,N) u32, m (B,T,N) f32, itanh (B,T,N) i32,
           best_H (B,T) i32, best_m (B,T,N) i8, J (B,N,N) f32, h (B,N) i32)
      → updated state tuple.

    ``storage_layout='packed'`` replaces m/best_m at the step boundary with
    (B, T, ceil(N/32)) uint32 bitplanes — the HBM-resident state between
    pjit launches is the packed layout, 32×/8× smaller than f32/i8 spins.
    ``j_mode='tiled'`` replaces J with the stacked padded adjacency
    ``nbr_idx (B,N,D) i32, nbr_w (B,N,D) i32`` and streams (tile_n, N) J
    slabs per problem — no (B, N, N) buffer, admitting G77/G81-class N.
    ``field_mode='popcount'`` (takes precedence over j_mode) replaces J
    with the stacked `PackedJ` bitplanes ``sign (B,N,Nw) u32,
    mags (B,nb,N,Nw) u32, base (B,N) i32`` and contracts by XNOR-popcount
    (DESIGN.md §8) — exact-integer, ~32×/n_bits less J traffic.
    All are bit-identical per problem to the default step (tested).

    Sharding caveat: the "spins over `model`" layout above applies to the
    dense-J step (the matmul contraction is what GSPMD partitions).  The
    tiled step constrains spins to P("data", None, None) — replicated over
    the model axis, each device scattering/contracting its problems' slabs
    locally — trading redundant field compute for zero collectives; its
    scale-out axis is the problem batch on `data`.
    """
    if storage_layout not in ("dense", "packed"):
        raise ValueError(f"unknown storage_layout {storage_layout!r}")
    if j_mode not in ("dense", "tiled"):
        raise ValueError(f"unknown j_mode {j_mode!r}")
    if field_mode not in ("dense", "popcount"):
        raise ValueError(f"unknown field_mode {field_mode!r}")
    plateaus = schedule_plateaus(hp.schedule("hassa"), "i0max")

    def constrain(x, spec):
        if mesh is None:
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    def step(rng, m, itanh, best_H, best_m, *problem):
        n = itanh.shape[-1]
        if field_mode == "popcount":
            from repro.kernels.bitplane import PackedJ  # lazy, like engine

            sign, mags, base, h = problem

            def field_fn(m8):
                # Like the tiled step: spins replicated over `model`, each
                # device contracting its problems' bitplanes locally — the
                # scale-out axis is the problem batch on `data`.
                mw = pack_spins(constrain(m8, P("data", None, None)))
                return jax.vmap(
                    lambda w, hh, s, g, b: local_fields_popcount(
                        w, hh, PackedJ(s, g, b)
                    )
                )(mw, h, sign, mags, base)
        elif j_mode == "tiled":
            nbr_idx, nbr_w, h = problem

            def field_fn(m8):
                mc = constrain(m8, P("data", None, None))
                return jax.vmap(
                    lambda mm, hh, ii, ww: local_fields_tiled(
                        mm, hh, ii, ww, tile_n=tile_n
                    )
                )(mc, h, nbr_idx, nbr_w)
        else:
            J, h = problem

            def field_fn(m8):
                mf = constrain(m8.astype(jnp.float32), P("data", None, "model"))
                return (
                    h[:, None, :] + jnp.einsum("btn,bnk->btk", mf, J)
                ).astype(jnp.int32)

        h3 = h[:, None, :]  # (B, 1, N): broadcasts against (B, T, N) spins
        if storage_layout == "packed":
            m8 = unpack_spins(m, n)
            bm8 = unpack_spins(best_m, n)
        else:
            m8, bm8 = m.astype(jnp.int8), best_m
        state = EngineState(rng, m8, itanh, best_H, bm8)
        for p in plateaus:
            state, _, _ = run_plateau_scan(
                field_fn, xorshift_next_bits, h3, hp.n_rnd, state, p.i0,
                length=p.length, eligible=p.eligible,
            )
        if storage_layout == "packed":
            m_out, bm_out = pack_spins(state.m), pack_spins(state.best_m)
        else:
            m_out = constrain(
                state.m.astype(jnp.float32), P("data", None, "model")
            )
            bm_out = state.best_m
        return (state.noise_state, m_out, state.itanh, state.best_H, bm_out)

    return step


def batched_anneal_step_lowering(
    mesh: Mesh,
    n_problems: int = 8,
    n_spins: int = 2048,
    n_trials: int = 512,
    hp: Optional[SSAHyperParams] = None,
    *,
    storage_layout: str = "dense",
    j_mode: str = "dense",
    max_degree: int = 4,
    tile_n: int = 512,
    field_mode: str = "dense",
    j_bits: int = 1,
):
    """Lower+compile the batched iteration step (dry-run, no allocation)."""
    hp = hp or SSAHyperParams(n_trials=n_trials)
    step = make_batched_iteration_step(
        hp, mesh, storage_layout=storage_layout, j_mode=j_mode, tile_n=tile_n,
        field_mode=field_mode,
    )
    B, T, N = n_problems, n_trials, n_spins
    dm = NamedSharding(mesh, P("data", None, "model"))
    dd = NamedSharding(mesh, P("data"))
    hb = NamedSharding(mesh, P("data", None))
    if storage_layout == "packed":
        nw = (N + 31) // 32
        spin_sh = NamedSharding(mesh, P("data", None, None))
        m_shape = jax.ShapeDtypeStruct((B, T, nw), jnp.uint32)
        bm_shape = jax.ShapeDtypeStruct((B, T, nw), jnp.uint32)
    else:
        spin_sh = dm
        m_shape = jax.ShapeDtypeStruct((B, T, N), jnp.float32)
        bm_shape = jax.ShapeDtypeStruct((B, T, N), jnp.int8)
    shapes = [
        jax.ShapeDtypeStruct((4, B, T, N), jnp.uint32),  # rng lanes
        m_shape,                                         # m (layout-dependent)
        jax.ShapeDtypeStruct((B, T, N), jnp.int32),      # itanh
        jax.ShapeDtypeStruct((B, T), jnp.int32),         # best_H
        bm_shape,                                        # best_m
    ]
    if field_mode == "popcount":
        jw = (N + 31) // 32
        prob_shapes = [
            jax.ShapeDtypeStruct((B, N, jw), jnp.uint32),          # sign
            jax.ShapeDtypeStruct((B, j_bits, N, jw), jnp.uint32),  # mags
            jax.ShapeDtypeStruct((B, N), jnp.int32),               # base
        ]
        prob_sh = [
            NamedSharding(mesh, P("data", None, None)),
            NamedSharding(mesh, P("data", None, None, None)),
            NamedSharding(mesh, P("data", None)),
        ]
    elif j_mode == "tiled":
        prob_shapes = [
            jax.ShapeDtypeStruct((B, N, max_degree), jnp.int32),  # nbr_idx
            jax.ShapeDtypeStruct((B, N, max_degree), jnp.int32),  # nbr_w
        ]
        prob_sh = [NamedSharding(mesh, P("data", None, None))] * 2
    else:
        prob_shapes = [jax.ShapeDtypeStruct((B, N, N), jnp.float32)]  # J
        prob_sh = [NamedSharding(mesh, P("data", "model", None))]
    shapes += prob_shapes + [jax.ShapeDtypeStruct((B, N), jnp.int32)]  # h
    rng_sh = NamedSharding(mesh, P(None, "data", None, "model"))
    shardings = tuple([rng_sh, spin_sh, dm, dd, spin_sh] + prob_sh + [hb])
    jitted = jax.jit(step, in_shardings=shardings, donate_argnums=(0, 1, 2, 3, 4))
    with mesh:
        return jitted.lower(*tuple(shapes))
