"""Typed solver configuration (DESIGN.md §13): the consolidated option surface.

Nine PRs of organic growth threaded ~8 loose kwargs (backend, storage_layout,
field_mode, j_mode, noise, partition, mesh, backend_opts) hand-to-hand through
driver → service → stream → CLI.  :class:`SolverConfig` replaces that sprawl
with ONE frozen, validated object whose stable :meth:`SolverConfig.signature`
is what executable-cache keys, checkpoint ``group_fingerprint``s, and
``filter_backend_opts`` consume.

``anneal()``, :class:`~repro.serve.AnnealRequest`, ``AnnealService``, and
``make_[batched_]backend`` all accept ``config=SolverConfig(...)``; the old
kwargs keep working through :func:`legacy_kwargs_to_config`, which warns
``DeprecationWarning`` once per call site.

Signature stability contract: the payload is versioned ("SolverConfig/v1").
Any change to field semantics must bump the version string so cached
executables / checkpoints keyed on the old payload are never silently reused.
"""
from __future__ import annotations

import dataclasses
import hashlib
import warnings
from typing import Any, Dict, Optional, Tuple

__all__ = ["SolverConfig", "legacy_kwargs_to_config"]

_BACKENDS = ("auto", "sparse", "dense", "pallas")
_LAYOUTS = ("dense", "packed")
_FIELD_MODES = ("auto", "dense", "popcount")
_J_MODES = ("auto", "dense", "tiled")
_NOISES = ("xorshift", "threefry")
_NOISE_MODES = ("auto", "pregen", "streamed")
_PARTITIONS = ("problem", "spin", "auto")


def _canon_opts(opts: Optional[Dict[str, Any]]) -> Tuple[Tuple[str, Any], ...]:
    """Canonical tuple view of the backend-opts dict (live values, key-sorted)."""
    return tuple(sorted((opts or {}).items(), key=lambda kv: kv[0]))


def _mesh_fp(mesh) -> Tuple:
    if mesh is None:
        return ()
    from repro.sharding import mesh_fingerprint

    return mesh_fingerprint(mesh)


@dataclasses.dataclass(frozen=True)
class SolverConfig:
    """Every execution-surface option of the plateau engine, in one object.

    Fields mirror the historical kwargs one-for-one:

    * ``backend`` — 'sparse' | 'dense' | 'pallas' (field contraction).
    * ``storage_layout`` — 'dense' | 'packed' inter-plateau spin state.
    * ``field_mode`` — 'auto' | 'dense' | 'popcount' (dense/pallas only).
    * ``j_mode`` — 'auto' | 'dense' | 'tiled' (dense backend only).
    * ``noise`` — 'xorshift' | 'threefry' noise *family* (the RNG).
    * ``noise_mode`` — 'auto' | 'pregen' | 'streamed' (pallas: where noise
      is generated; 'streamed' requires the xorshift family).
    * ``partition`` — 'problem' | 'spin' | 'auto' device partitioning.
    * ``mesh`` — optional ``jax.sharding.Mesh`` (excluded from equality;
      its :func:`repro.sharding.mesh_fingerprint` enters the signature).
    * ``backend_opts`` — residual per-backend tuning knobs (block_r, tile_n,
      j_dtype, j_bits, interpret, double_buffer, n_replicas, …) as a
      key-sorted tuple of live (key, value) pairs.

    The object is frozen and validated at construction; ``signature()`` is a
    16-hex-digit digest that is stable across processes and injective over
    the option grid (property-tested in tests/test_solver_config.py).
    """

    backend: str = "sparse"
    storage_layout: str = "dense"
    field_mode: str = "auto"
    j_mode: str = "auto"
    noise: str = "xorshift"
    noise_mode: str = "auto"
    partition: str = "problem"
    mesh: Optional[Any] = dataclasses.field(default=None, compare=False)
    backend_opts: Tuple[Tuple[str, Any], ...] = ()

    def __post_init__(self):
        # PR-8 spelling rode partition/mesh inside backend_opts.  Hoist them
        # into the typed fields so make_backend never receives them twice and
        # the signature never falls back to repr() of a live Mesh object.
        opts = dict(self.backend_opts) if self.backend_opts else {}
        for key, default in (("partition", "problem"), ("mesh", None)):
            if key in opts:
                val = opts.pop(key)
                cur = getattr(self, key)
                if cur != default and cur != val:
                    raise ValueError(
                        f"backend_opts[{key!r}] conflicts with {key}={cur!r}"
                    )
                object.__setattr__(self, key, val)
        object.__setattr__(self, "backend_opts", _canon_opts(opts))
        if isinstance(self.backend, str) and self.backend not in _BACKENDS:
            raise ValueError(
                f"backend {self.backend!r} not in {_BACKENDS}"
            )
        if self.storage_layout not in _LAYOUTS:
            raise ValueError(
                f"storage_layout {self.storage_layout!r} not in {_LAYOUTS}"
            )
        if self.field_mode not in _FIELD_MODES:
            raise ValueError(
                f"field_mode {self.field_mode!r} not in {_FIELD_MODES}"
            )
        if self.j_mode not in _J_MODES:
            raise ValueError(f"j_mode {self.j_mode!r} not in {_J_MODES}")
        if self.noise not in _NOISES:
            raise ValueError(f"noise {self.noise!r} not in {_NOISES}")
        if self.noise_mode not in _NOISE_MODES:
            raise ValueError(
                f"noise_mode {self.noise_mode!r} not in {_NOISE_MODES}"
            )
        if self.partition not in _PARTITIONS:
            raise ValueError(
                f"partition {self.partition!r} not in {_PARTITIONS}"
            )
        if self.noise_mode == "streamed" and self.noise != "xorshift":
            raise ValueError(
                "noise_mode='streamed' requires the xorshift noise family "
                "(threefry cannot be generated in-kernel)"
            )

    # -- views ------------------------------------------------------------
    def opts_dict(self) -> Dict[str, Any]:
        """backend_opts as a live dict (values as passed at construction)."""
        return dict(self.backend_opts)

    def engine_opts(self) -> Dict[str, Any]:
        """kwargs for ``make_[batched_]backend(**...)`` minus backend/noise.

        Typed fields that are per-backend-family knobs are only emitted when
        the configured backend's constructor accepts them (sparse rejects
        ``field_mode``/``j_mode``/``noise_mode``); live ``backend_opts``
        entries are merged in — callers that need cross-backend safety
        should still run the result through
        :func:`repro.serve.resilience.filter_backend_opts`.
        """
        out: Dict[str, Any] = {"storage_layout": self.storage_layout}
        bk = self.backend
        if self.field_mode != "auto" and bk != "sparse":
            out["field_mode"] = self.field_mode
        if self.j_mode != "auto" and bk in ("dense", "auto"):
            out["j_mode"] = self.j_mode
        if self.noise_mode != "auto" and bk in ("pallas", "auto"):
            out["noise_mode"] = self.noise_mode
        out.update(self.opts_dict())
        return out

    def signature(self) -> str:
        """Stable 16-hex digest over every behavior-affecting field."""
        payload = (
            "SolverConfig/v1",
            self.backend if isinstance(self.backend, str)
            else type(self.backend).__name__,
            self.storage_layout,
            self.field_mode,
            self.j_mode,
            self.noise,
            self.noise_mode,
            self.partition,
            tuple(_mesh_fp(self.mesh)),
            tuple((k, repr(v)) for k, v in self.backend_opts),
        )
        return hashlib.sha256(repr(payload).encode()).hexdigest()[:16]

    def replace(self, **kw) -> "SolverConfig":
        return dataclasses.replace(self, **kw)


_WARNED_SITES: set = set()


def legacy_kwargs_to_config(
    site: str,
    config: Optional[SolverConfig],
    *,
    warn: bool = True,
    **legacy,
) -> SolverConfig:
    """Fold legacy loose kwargs into a :class:`SolverConfig` (the shim).

    ``legacy`` maps SolverConfig field names to explicitly-passed legacy
    values (pass only the ones the caller actually received — ``None``
    entries are ignored).  If ``config`` is given, any non-None legacy kwarg
    is a conflict.  Otherwise the legacy values build a config and a
    ``DeprecationWarning`` fires once per ``site`` (per process).
    """
    supplied = {k: v for k, v in legacy.items() if v is not None}
    if config is not None:
        if supplied:
            raise TypeError(
                f"{site}: pass either config= or legacy kwargs "
                f"({sorted(supplied)}), not both"
            )
        return config
    if supplied and warn and site not in _WARNED_SITES:
        _WARNED_SITES.add(site)
        warnings.warn(
            f"{site}: loose solver kwargs ({sorted(supplied)}) are "
            "deprecated; pass config=SolverConfig(...) instead",
            DeprecationWarning,
            stacklevel=3,
        )
    return SolverConfig(**supplied)
