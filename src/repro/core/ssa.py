"""SSA and HA-SSA annealers (paper Sec. II-B and Sec. III) in JAX.

Every spin is a p-bit updated *simultaneously* each cycle (the FPGA spin-gate
array) by integral stochastic computing:

    I_i(t+1)     = h_i + Σ_j J_ij m_j(t) + n_rnd · r_i(t) + Itanh_i(t)   (2a)
    Itanh_i(t+1) = clamp(I_i(t+1), -I0(t), I0(t)-1)                       (2b)
    m_i(t+1)     = +1 if Itanh_i(t+1) >= 0 else -1                        (2c)

The *only* difference between SSA and HA-SSA is outside this update path:

* temperature control — Eq. (3) float-β division (SSA) vs Eq. (4) integer
  barrel shift (HA-SSA); identical sequences when β_ssa = 2^{-β_hassa};
* storage policy — SSA stores the spin bitplane every cycle; HA-SSA stores
  only while I0 == I0max (the FPGA's BRAM write-enable), shrinking trajectory
  memory by (steps = log2(I0max/I0min)+1)× — Eq. (5) vs Eq. (6);
* duration control — HA-SSA counts iterations (complete I0min→I0max sweeps),
  never truncating the final sweep.

TPU adaptation (see DESIGN.md §2): :func:`anneal` is a thin driver over the
plateau-structured engine in :mod:`repro.core.engine`.  The schedule is
grouped into constant-I0 plateaus — HA-SSA's unit of execution and storage —
and each plateau is advanced by a pluggable :class:`~repro.core.engine.PlateauBackend`:

* ``backend='sparse'`` — padded-adjacency gather field, `lax.scan` per plateau;
* ``backend='dense'``  — (T,N)·(N,N) MXU matmul field, `lax.scan` per plateau
  (``j_mode='tiled'`` streams (tile_n, N) J slabs for G77/G81-class N);
* ``backend='pallas'`` — the resident plateau kernel: one ``pallas_call``
  per plateau with J pinned in VMEM (DESIGN.md §2.3).  With ``xorshift``
  noise this is the **streamed-noise packed kernel**: per-cycle noise is
  generated inside the kernel from the carried xorshift lanes and the
  HBM-facing spin refs are uint32 bitplanes — no (C, R, N) noise buffer is
  ever allocated, in the driver or anywhere else.  (``threefry`` keeps the
  per-plateau pregen reference path; it cannot be reproduced in-kernel.)

``storage_layout='packed'`` additionally keeps the engine state *between*
plateaus as uint32 bitplanes (DESIGN.md §4) — bit-identical results, 8–32×
smaller resident spin storage.

All three advance the field contraction **once per cycle** (the field used
for the Eq. 2a update of m(t) is reused for H(m(t))) and produce bit-identical
spin trajectories from the same noise stream — property-tested.

The HA-SSA storage policy is *structural*: it is per-plateau eligibility (the
FPGA's I0 == I0max write-enable), so in ``record='traj'`` mode the XLA output
buffer itself is `steps×` smaller — the BRAM-depth saving, as HBM-buffer
shape (DESIGN.md §4).

Two recording modes:

* ``record='traj'`` — materialize the stored bitplanes (tests, small runs;
  this is what the FPGA ships over UART).
* ``record='best'`` — running arg-best *restricted to storage-eligible
  plateaus*, so HA-SSA's reported solution is computed only from states it
  would have stored.  On TPU, evaluating the cut on the fly is nearly free
  next to the field matmul (compute >> memory), which is exactly the
  opposite trade the FPGA makes — noted in DESIGN.md §8.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from .engine import (
    BaseResult,
    finalize_cut,
    make_backend,
    normalize_problem,
    pack_spins,
    packed_words,
    run_schedule,
    schedule_plateaus,
    ssa_cycle_update,
    tile_plateaus,
    unpack_spins,
)
from .ising import IsingModel, MaxCutProblem, ising_energy
from .schedule import Schedule, hassa_schedule, n_temp_steps, ssa_schedule

__all__ = [
    "SSAHyperParams",
    "AnnealResult",
    "ssa_cycle_update",
    "anneal",
    "solve_maxcut",
    "pack_spins",
    "unpack_spins",
]


@dataclasses.dataclass(frozen=True)
class SSAHyperParams:
    """Table II defaults: trial=100, m_shot=150, n_rnd=2, I0: 1→32, τ=100, β=1."""

    n_trials: int = 100
    m_shot: int = 150
    n_rnd: int = 2
    i0_min: int = 1
    i0_max: int = 32
    tau: int = 100
    beta_shift: int = 1  # HA-SSA Eq.(4) β; equivalent SSA Eq.(3) β = 2^-beta_shift

    @property
    def steps(self) -> int:
        return n_temp_steps(self.i0_min, self.i0_max, self.beta_shift)

    @property
    def cycles_per_iter(self) -> int:
        return self.steps * self.tau

    @property
    def total_cycles(self) -> int:
        return self.m_shot * self.cycles_per_iter

    def schedule(self, kind: str = "hassa") -> Schedule:
        if kind == "hassa":
            return hassa_schedule(self.i0_min, self.i0_max, self.tau, self.beta_shift)
        if kind == "ssa":
            return ssa_schedule(self.i0_min, self.i0_max, self.tau, 2.0 ** (-self.beta_shift))
        raise ValueError(kind)


@dataclasses.dataclass
class AnnealResult(BaseResult):
    """Outcome of one annealing run over a batch of trials.

    Field conventions are shared with SAResult/PTResult via
    :class:`repro.core.engine.BaseResult`.
    """

    traj: Optional[np.ndarray]    # (m_shot, stored_cycles, T, Nw) uint32 bitplanes
    stored_bits_per_iter: int     # N × stored_cycles — the Eq.(5)/(6) witness
    hp: SSAHyperParams


# ---------------------------------------------------------------------------
# Main annealer: a thin driver over the plateau engine
# ---------------------------------------------------------------------------
def anneal(
    problem: Union[MaxCutProblem, IsingModel],
    hp: Union[SSAHyperParams, str] = SSAHyperParams(),
    seed: int = 0,
    *,
    storage: str = "i0max",        # 'i0max' (HA-SSA) | 'all' (conventional SSA)
    record: str = "best",          # 'best' | 'traj'
    backend=None,                  # legacy: 'sparse' | 'dense' | 'pallas' | inst
    noise: Optional[str] = None,   # legacy: 'threefry' | 'xorshift'
    track_energy: bool = True,
    schedule_kind: str = "hassa",  # 'hassa' Eq.(4) | 'ssa' Eq.(3)
    total_cycles: Optional[int] = None,  # cycle-count duration (Fig. 12 mode)
    storage_layout: Optional[str] = None,  # legacy: 'dense' | 'packed'
    backend_opts: Optional[dict] = None,   # legacy extra backend kwargs
    auto_base: Optional[SSAHyperParams] = None,  # budget knobs for hp='auto'
    config=None,                   # SolverConfig — the typed option surface
) -> AnnealResult:
    """Run SSA/HA-SSA on a MAX-CUT, raw Ising, or encoded problem instance.

    ``storage='i0max'`` + ``schedule_kind='hassa'`` is the paper's HA-SSA;
    ``storage='all'`` + ``schedule_kind='ssa'`` is conventional SSA.  The
    update path is shared, so with equal hyperparameters and the same noise
    stream the two produce bit-identical spin sequences (Sec. III-A, V-A) —
    property-tested.

    ``hp='auto'`` derives the energy-scale hyperparameters (n_rnd, I0
    clamp, per-plateau τ) from the instance's local-field distribution
    (:mod:`repro.core.autotune`), taking the budget knobs from
    ``auto_base`` (default: Table II).

    Execution-surface options come in one typed object:
    ``config=SolverConfig(backend=..., storage_layout=..., ...)``
    (DESIGN.md §13).  The loose ``backend``/``noise``/``storage_layout``/
    ``backend_opts`` kwargs keep working as a deprecated shim (one
    ``DeprecationWarning`` per process) with their historical defaults
    (sparse backend, threefry noise, dense layout).

    An :class:`~repro.core.ssqa.SSQAHyperParams` ``hp`` switches the run to
    SSQA (DESIGN.md §13): the schedule carries the J⊥ ramp and the backend
    is built with the hp's Trotter-replica count.

    The hot loop iterates ``m_shot × steps`` plateaus over the selected
    backend; ``backend='pallas'`` executes each plateau as a single resident
    ``pallas_call``.  Per-cycle energy traces (``track_energy``) and
    trajectory planes (``record='traj'``) need per-cycle outputs, which the
    resident kernel does not produce — those plateaus run the bit-identical
    scan path instead.
    """
    from .config import legacy_kwargs_to_config

    maxcut, model = normalize_problem(problem)
    if isinstance(hp, str):
        # Lazy import: autotune imports SSAHyperParams from this module.
        from .autotune import resolve_hyperparams

        hp, _ = resolve_hyperparams(hp, model, base=auto_base)
    cfg = legacy_kwargs_to_config(
        "repro.core.ssa.anneal", config,
        backend=backend if isinstance(backend, str) else None,
        noise=noise, storage_layout=storage_layout,
        backend_opts=dict(backend_opts) if backend_opts else None,
    )
    if config is None and noise is None:
        # anneal()'s historical noise default is threefry, not the
        # SolverConfig default (xorshift) — preserved for the legacy path.
        cfg = cfg.replace(noise="threefry")
    sched = hp.schedule(schedule_kind)
    opts = cfg.engine_opts()
    # SSQA hyper-params carry the Trotter-replica count; duck-typed so this
    # module needs no import of core.ssqa (which imports us).
    nr = int(getattr(hp, "n_replicas", 0) or 0)
    if nr:
        opts.setdefault("n_replicas", nr)
    bk = make_backend(
        backend if backend is not None and not isinstance(backend, str)
        else cfg.backend,
        model, n_trials=hp.n_trials, n_rnd=hp.n_rnd, noise=cfg.noise,
        partition=cfg.partition, mesh=cfg.mesh,
        **opts,
    )
    plateaus = schedule_plateaus(sched, storage)
    stored_per_iter = sum(p.length for p in plateaus if p.eligible)

    if record == "traj":
        # Iteration-structured: heat plateaus emit nothing; eligible plateaus
        # emit bit-packed planes → the output buffer is structurally
        # (stored/cpi)× smaller, mirroring the BRAM depth saving.
        hh, nbr_idx, nbr_w = model.device_arrays()

        def run():
            state = bk.init_state(seed)

            def iteration(st, _):
                st, _, planes = run_schedule(bk, plateaus, st, record="traj")
                return st, planes

            state, traj = jax.lax.scan(iteration, state, None, length=hp.m_shot)
            # Solution = best stored state, scanned outside the hot loop.
            flat = traj.reshape(-1, hp.n_trials, packed_words(model.n))
            spins = unpack_spins(flat, model.n)  # (S, T, N)
            H = ising_energy(spins.astype(jnp.int32), hh, nbr_idx, nbr_w)  # (S, T)
            if maxcut is not None:
                idx = jnp.argmax((maxcut.w_total - H) // 2, axis=0)
            else:
                idx = jnp.argmin(H, axis=0)
            tt = jnp.arange(hp.n_trials)
            best_m = spins[idx, tt]
            best_H = H[idx, tt]
            return best_H, best_m, traj

        best_H, best_m, traj = jax.jit(run)()
        e_mean = e_min = None
    else:
        if record != "best":
            raise ValueError(f"unknown record {record!r}")
        if total_cycles is None:
            # Iteration-aligned: scan the per-iteration plateau chain m_shot×.
            def run():
                state = bk.init_state(seed)

                def iteration(st, _):
                    st, trace, _ = run_schedule(
                        bk, plateaus, st, record="best", track_energy=track_energy
                    )
                    return st, trace

                state, trace = jax.lax.scan(
                    iteration, state, None, length=hp.m_shot
                )
                best_H, best_m = bk.finalize(state)
                return best_H, best_m, trace
        else:
            # Cycle-count duration control: scan the full iterations, then
            # chain the truncated tail's plateaus (keeps the compiled program
            # one iteration body + tail, not total_cycles/τ unrolled scans).
            cpi = sched.cycles_per_iter
            full_iters, rem = divmod(int(total_cycles), cpi)
            tail = tile_plateaus(plateaus, rem) if rem else ()

            def run():
                state = bk.init_state(seed)
                traces = []
                if full_iters:
                    def iteration(st, _):
                        st, trace, _ = run_schedule(
                            bk, plateaus, st, record="best",
                            track_energy=track_energy,
                        )
                        return st, trace

                    state, tr = jax.lax.scan(
                        iteration, state, None, length=full_iters
                    )
                    if track_energy:
                        traces.append((tr[0].reshape(-1), tr[1].reshape(-1)))
                if tail:
                    state, tr, _ = run_schedule(
                        bk, tail, state, record="best", track_energy=track_energy
                    )
                    if track_energy:
                        traces.append(tr)
                best_H, best_m = bk.finalize(state)
                trace = (
                    tuple(
                        jnp.concatenate([t[i] for t in traces]) for i in (0, 1)
                    )
                    if track_energy
                    else None
                )
                return best_H, best_m, trace

        best_H, best_m, trace = jax.jit(run)()
        traj = None
        if track_energy:
            e_mean = np.asarray(trace[0]).reshape(-1)
            e_min = np.asarray(trace[1]).reshape(-1)
        else:
            e_mean = e_min = None

    best_H = np.asarray(best_H)
    best_cut = np.asarray(finalize_cut(best_H, maxcut))
    return AnnealResult(
        best_cut=best_cut,
        best_energy=best_H,
        best_m=np.asarray(best_m),
        energy_mean=e_mean,
        energy_min=e_min,
        traj=None if traj is None else np.asarray(traj),
        stored_bits_per_iter=model.n * stored_per_iter,
        hp=hp,
    )


def solve_maxcut(problem: MaxCutProblem, hp: SSAHyperParams = SSAHyperParams(), **kw) -> AnnealResult:
    """Convenience wrapper with HA-SSA defaults (the paper's configuration)."""
    return anneal(problem, hp, **kw)
