"""SSA and HA-SSA annealers (paper Sec. II-B and Sec. III) in JAX.

Every spin is a p-bit updated *simultaneously* each cycle (the FPGA spin-gate
array) by integral stochastic computing:

    I_i(t+1)     = h_i + Σ_j J_ij m_j(t) + n_rnd · r_i(t) + Itanh_i(t)   (2a)
    Itanh_i(t+1) = clamp(I_i(t+1), -I0(t), I0(t)-1)                       (2b)
    m_i(t+1)     = +1 if Itanh_i(t+1) >= 0 else -1                        (2c)

The *only* difference between SSA and HA-SSA is outside this update path:

* temperature control — Eq. (3) float-β division (SSA) vs Eq. (4) integer
  barrel shift (HA-SSA); identical sequences when β_ssa = 2^{-β_hassa};
* storage policy — SSA stores the spin bitplane every cycle; HA-SSA stores
  only while I0 == I0max (the FPGA's BRAM write-enable), shrinking trajectory
  memory by (steps = log2(I0max/I0min)+1)× — Eq. (5) vs Eq. (6);
* duration control — HA-SSA counts iterations (complete I0min→I0max sweeps),
  never truncating the final sweep.

TPU adaptation (see DESIGN.md §2): trials are batched on a replica axis so
the per-cycle local-field computation is a (T,N)·(N,N) MXU matmul for dense
problems or a padded-adjacency gather for sparse ones; the Itanh FSM is a
fused elementwise epilogue.  The HA-SSA storage policy becomes *structural*:
the `lax.scan` over an iteration is split into a heat phase (no outputs) and
a store phase (bit-packed outputs), so the XLA output buffer itself is
`steps×` smaller — the BRAM-depth saving, as HBM-buffer shape.

Two recording modes:

* ``record='traj'`` — materialize the stored bitplanes (tests, small runs;
  this is what the FPGA ships over UART).
* ``record='best'`` — running arg-best *restricted to storage-eligible
  cycles*, so HA-SSA's reported solution is computed only from states it
  would have stored.  On TPU, evaluating the cut on the fly is nearly free
  next to the field matmul (compute >> memory), which is exactly the
  opposite trade the FPGA makes — noted in DESIGN.md §8.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from .ising import IsingModel, MaxCutProblem, local_fields_dense, local_fields_sparse
from .rng import threefry_noise, xorshift_init, xorshift_next_bits
from .schedule import Schedule, hassa_schedule, n_temp_steps, ssa_schedule

__all__ = [
    "SSAHyperParams",
    "AnnealResult",
    "ssa_cycle_update",
    "anneal",
    "solve_maxcut",
    "pack_spins",
    "unpack_spins",
]


@dataclasses.dataclass(frozen=True)
class SSAHyperParams:
    """Table II defaults: trial=100, m_shot=150, n_rnd=2, I0: 1→32, τ=100, β=1."""

    n_trials: int = 100
    m_shot: int = 150
    n_rnd: int = 2
    i0_min: int = 1
    i0_max: int = 32
    tau: int = 100
    beta_shift: int = 1  # HA-SSA Eq.(4) β; equivalent SSA Eq.(3) β = 2^-beta_shift

    @property
    def steps(self) -> int:
        return n_temp_steps(self.i0_min, self.i0_max, self.beta_shift)

    @property
    def cycles_per_iter(self) -> int:
        return self.steps * self.tau

    @property
    def total_cycles(self) -> int:
        return self.m_shot * self.cycles_per_iter

    def schedule(self, kind: str = "hassa") -> Schedule:
        if kind == "hassa":
            return hassa_schedule(self.i0_min, self.i0_max, self.tau, self.beta_shift)
        if kind == "ssa":
            return ssa_schedule(self.i0_min, self.i0_max, self.tau, 2.0 ** (-self.beta_shift))
        raise ValueError(kind)


@dataclasses.dataclass
class AnnealResult:
    """Outcome of one annealing run over a batch of trials."""

    best_cut: np.ndarray          # (T,) best cut per trial (maxcut) — under storage policy
    best_energy: np.ndarray       # (T,) Ising energy of the best stored state
    best_m: np.ndarray            # (T, N) int8 spins of the best stored state
    energy_mean: Optional[np.ndarray]  # (total_cycles,) mean H over trials per cycle
    energy_min: Optional[np.ndarray]   # (total_cycles,) min H over trials per cycle
    traj: Optional[np.ndarray]    # (m_shot, stored_cycles, T, Nw) uint32 bitplanes
    stored_bits_per_iter: int     # N × stored_cycles — the Eq.(5)/(6) witness
    hp: SSAHyperParams

    @property
    def overall_best_cut(self) -> int:
        return int(np.max(self.best_cut))

    @property
    def mean_best_cut(self) -> float:
        return float(np.mean(self.best_cut))


# ---------------------------------------------------------------------------
# Bit packing (the 800-bit BRAM word, as uint32 lanes)
# ---------------------------------------------------------------------------
def packed_words(n: int) -> int:
    return (n + 31) // 32


def pack_spins(m: jnp.ndarray) -> jnp.ndarray:
    """Pack ±1 spins [..., N] into uint32 bitplanes [..., ceil(N/32)]."""
    n = m.shape[-1]
    nw = packed_words(n)
    pad = nw * 32 - n
    bits = (m > 0).astype(jnp.uint32)
    if pad:
        bits = jnp.concatenate(
            [bits, jnp.zeros(bits.shape[:-1] + (pad,), jnp.uint32)], axis=-1
        )
    bits = bits.reshape(bits.shape[:-1] + (nw, 32))
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(bits << shifts, axis=-1, dtype=jnp.uint32)


def unpack_spins(packed: jnp.ndarray, n: int) -> jnp.ndarray:
    """Inverse of pack_spins; returns int8 spins in {-1,+1}, shape [..., n]."""
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (packed[..., None] >> shifts) & jnp.uint32(1)
    flat = bits.reshape(bits.shape[:-2] + (-1,))[..., :n]
    return jnp.where(flat == 1, 1, -1).astype(jnp.int8)


# ---------------------------------------------------------------------------
# The p-bit update (Eq. 2a–2c), factored so kernels/ref can share it
# ---------------------------------------------------------------------------
def ssa_cycle_update(field, itanh, r, i0, n_rnd):
    """Elementwise epilogue of one SSA cycle.

    Args:
      field: int32[..., N]  h_i + Σ_j J_ij m_j(t)      (the matvec part)
      itanh: int32[..., N]  Itanh_i(t)
      r:     int32[..., N]  noise in {-1,+1}
      i0:    int32 scalar   pseudo-inverse temperature I0(t)
      n_rnd: int            noise magnitude
    Returns:
      (m_new int8[...,N], itanh_new int32[...,N])
    """
    I = field + n_rnd * r + itanh                       # (2a)
    itanh_new = jnp.clip(I, -i0, i0 - 1)                # (2b)
    m_new = jnp.where(itanh_new >= 0, 1, -1).astype(jnp.int8)  # (2c)
    return m_new, itanh_new


def _energy_from_field(m, field, h):
    """H = -(h·m + m·field)/2, exact int32 (field = h + Jm)."""
    m32 = m.astype(jnp.int32)
    hm = jnp.sum(h * m32, axis=-1)
    mf = jnp.sum(m32 * field, axis=-1)
    return -(hm + mf) // 2


# ---------------------------------------------------------------------------
# Main annealer
# ---------------------------------------------------------------------------
def _make_field_fn(model: IsingModel, backend: str):
    h, nbr_idx, nbr_w = model.device_arrays()
    if backend == "sparse":
        return lambda m: local_fields_sparse(m.astype(jnp.int32), h, nbr_idx, nbr_w), h
    if backend == "dense":
        J = jnp.asarray(model.dense_J(), jnp.float32)
        return lambda m: local_fields_dense(m, h, J), h
    if backend == "pallas":
        from repro.kernels import ops as kops  # lazy: optional dependency path

        J = jnp.asarray(model.dense_J(), jnp.float32)
        return lambda m: kops.local_field(m, h, J), h
    raise ValueError(f"unknown backend {backend!r}")


def _make_noise_fn(noise: str, seed: int, lanes: Tuple[int, int]):
    if noise == "xorshift":
        state0 = xorshift_init(seed, lanes)
        return state0, xorshift_next_bits
    if noise == "threefry":
        key0 = jax.random.PRNGKey(seed)

        def step(key):
            key, sub = jax.random.split(key)
            return key, threefry_noise(sub, lanes)

        return key0, step
    raise ValueError(f"unknown noise {noise!r}")


def _init_state(noise_state, noise_fn, n_trials, n):
    noise_state, r0 = noise_fn(noise_state)
    m0 = r0.astype(jnp.int8)  # random ±1
    itanh0 = jnp.where(m0 > 0, 0, -1).astype(jnp.int32)
    return noise_state, m0, itanh0


def anneal(
    problem: Union[MaxCutProblem, IsingModel],
    hp: SSAHyperParams = SSAHyperParams(),
    seed: int = 0,
    *,
    storage: str = "i0max",        # 'i0max' (HA-SSA) | 'all' (conventional SSA)
    record: str = "best",          # 'best' | 'traj'
    backend: str = "sparse",       # 'sparse' | 'dense' | 'pallas'
    noise: str = "threefry",       # 'threefry' | 'xorshift'
    track_energy: bool = True,
    schedule_kind: str = "hassa",  # 'hassa' Eq.(4) | 'ssa' Eq.(3)
    total_cycles: Optional[int] = None,  # cycle-count duration (Fig. 12 mode)
) -> AnnealResult:
    """Run SSA/HA-SSA on a MAX-CUT or raw Ising instance.

    ``storage='i0max'`` + ``schedule_kind='hassa'`` is the paper's HA-SSA;
    ``storage='all'`` + ``schedule_kind='ssa'`` is conventional SSA.  The
    update path is shared, so with equal hyperparameters and the same noise
    stream the two produce bit-identical spin sequences (Sec. III-A, V-A) —
    property-tested.
    """
    if isinstance(problem, MaxCutProblem):
        maxcut: Optional[MaxCutProblem] = problem
        model = problem.to_ising()
    else:
        maxcut = None
        model = problem

    sched = hp.schedule(schedule_kind)
    field_fn, h = _make_field_fn(model, backend)
    lanes = (hp.n_trials, model.n)
    noise_state0, noise_fn = _make_noise_fn(noise, seed, lanes)
    w_total = maxcut.w_total if maxcut is not None else 0

    i0_all = jnp.asarray(sched.i0_per_cycle, jnp.int32)
    mask_all = (
        jnp.asarray(sched.store_mask) if storage == "i0max"
        else jnp.ones_like(jnp.asarray(sched.store_mask))
    )
    stored_per_iter = int(np.sum(np.asarray(mask_all)))

    def cycle(carry, xs):
        noise_state, m, itanh = carry
        i0, eligible = xs
        field = field_fn(m)
        noise_state, r = noise_fn(noise_state)
        m_new, itanh_new = ssa_cycle_update(field, itanh, r, i0, hp.n_rnd)
        # energy of the *new* state needs the new field; reuse next cycle's
        # matvec instead: report H(m_new) lazily by computing field(m_new)
        # only when tracking.  (Cheap relative to clarity at CPU scale; the
        # Pallas path fuses it.)
        return (noise_state, m_new, itanh_new), (m_new, eligible)

    def run():
        noise_state, m0, itanh0 = _init_state(noise_state0, noise_fn, hp.n_trials, model.n)

        if record == "traj":
            # Iteration-structured: heat phase emits nothing; store phase
            # emits bit-packed planes → output buffer is structurally
            # (stored/cpi)× smaller, mirroring the BRAM depth saving.
            heat_len = int(np.sum(~np.asarray(mask_all)))
            i0_heat, i0_store = i0_all[:heat_len], i0_all[heat_len:]

            def cyc_nostore(carry, i0):
                new_carry, _ = cycle(carry, (i0, False))
                return new_carry, None

            def cyc_store(carry, i0):
                new_carry, (m_new, _) = cycle(carry, (i0, True))
                return new_carry, pack_spins(m_new)

            def iteration(carry, _):
                carry, _ = jax.lax.scan(cyc_nostore, carry, i0_heat)
                carry, planes = jax.lax.scan(cyc_store, carry, i0_store)
                return carry, planes

            carry = (noise_state, m0, itanh0)
            carry, traj = jax.lax.scan(iteration, carry, None, length=hp.m_shot)
            # Solution = best stored state, scanned outside the hot loop.
            flat = traj.reshape(-1, hp.n_trials, packed_words(model.n))
            spins = unpack_spins(flat, model.n)  # (S, T, N)
            from .ising import ising_energy

            hh, nbr_idx, nbr_w = model.device_arrays()
            H = ising_energy(spins.astype(jnp.int32), hh, nbr_idx, nbr_w)  # (S, T)
            if maxcut is not None:
                cuts = (w_total - H) // 2
                idx = jnp.argmax(cuts, axis=0)
            else:
                idx = jnp.argmin(H, axis=0)
            tt = jnp.arange(hp.n_trials)
            best_m = spins[idx, tt]
            best_H = H[idx, tt]
            best_cut = ((w_total - best_H) // 2) if maxcut is not None else -best_H
            return best_cut, best_H, best_m, None, None, traj

        # record == 'best': flat scan over all cycles with running arg-best
        # restricted to storage-eligible cycles.  Supports cycle-count
        # duration control (Fig. 12 conventional-SSA mode).
        if total_cycles is None:
            i0_seq = jnp.tile(i0_all, hp.m_shot)
            el_seq = jnp.tile(mask_all, hp.m_shot)
        else:
            reps = -(-total_cycles // sched.cycles_per_iter)
            i0_seq = jnp.tile(i0_all, reps)[:total_cycles]
            el_seq = jnp.tile(mask_all, reps)[:total_cycles]

        hh, nbr_idx, nbr_w = model.device_arrays()

        def cyc(carry, xs):
            noise_state, m, itanh, best_H, best_m = carry
            i0, eligible = xs
            field = field_fn(m)
            noise_state, r = noise_fn(noise_state)
            m_new, itanh_new = ssa_cycle_update(field, itanh, r, i0, hp.n_rnd)
            field_new = field_fn(m_new)
            H = _energy_from_field(m_new, field_new, hh)  # (T,)
            better = eligible & (H < best_H)
            best_H = jnp.where(better, H, best_H)
            best_m = jnp.where(better[:, None], m_new, best_m)
            trace = (jnp.mean(H.astype(jnp.float32)), jnp.min(H)) if track_energy else 0
            return (noise_state, m_new, itanh_new, best_H, best_m), trace

        big = jnp.int32(2**30)
        carry0 = (noise_state, m0, itanh0, jnp.full((hp.n_trials,), big, jnp.int32), m0)
        carry, trace = jax.lax.scan(cyc, carry0, (i0_seq, el_seq))
        _, _, _, best_H, best_m = carry
        best_cut = ((w_total - best_H) // 2) if maxcut is not None else -best_H
        e_mean, e_min = (trace if track_energy else (None, None))
        return best_cut, best_H, best_m, e_mean, e_min, None

    best_cut, best_H, best_m, e_mean, e_min, traj = jax.jit(run)()
    return AnnealResult(
        best_cut=np.asarray(best_cut),
        best_energy=np.asarray(best_H),
        best_m=np.asarray(best_m),
        energy_mean=None if e_mean is None else np.asarray(e_mean),
        energy_min=None if e_min is None else np.asarray(e_min),
        traj=None if traj is None else np.asarray(traj),
        stored_bits_per_iter=model.n * stored_per_iter,
        hp=hp,
    )


def solve_maxcut(problem: MaxCutProblem, hp: SSAHyperParams = SSAHyperParams(), **kw) -> AnnealResult:
    """Convenience wrapper with HA-SSA defaults (the paper's configuration)."""
    return anneal(problem, hp, **kw)
