"""repro.core — the paper's contribution: HA-SSA / SSA / SA / PT annealers.

Public API:
  IsingModel, MaxCutProblem           — problem substrate (ising.py)
  gset.load                           — benchmark instances (gset.py)
  SSAHyperParams, anneal, solve_maxcut— SSA + HA-SSA (ssa.py)
  SSQAHyperParams, anneal_ssqa        — Trotter-replica SSQA (ssqa.py)
  SolverConfig                        — typed solver options (config.py)
  PlateauBackend, make_backend        — plateau engine protocol (engine.py)
  SAHyperParams, anneal_sa            — conventional SA baseline (sa.py)
  PTHyperParams, anneal_pt            — parallel-tempering baseline (pt.py)
  memory                              — Eq.(5)/(6) memory models
"""
from . import gset, memory  # noqa: F401
from .autotune import (  # noqa: F401
    AutotuneReport,
    autotune_hyperparams,
    resolve_hyperparams,
    sample_local_fields,
)
from .config import SolverConfig, legacy_kwargs_to_config  # noqa: F401
from .engine import (  # noqa: F401
    TILED_J_THRESHOLD,
    BaseResult,
    BatchedBackend,
    DenseBackend,
    EngineState,
    PackedEngineState,
    PallasBackend,
    Plateau,
    PlateauBackend,
    SparseBackend,
    bucket_n,
    make_backend,
    make_batched_backend,
    pack_state,
    pad_model,
    padded_noise_init,
    run_schedule,
    schedule_plateaus,
    unpack_state,
)
from .ising import IsingModel, MaxCutProblem, fig4_example, ising_energy  # noqa: F401
from .pt import (  # noqa: F401
    PTHyperParams,
    PTResult,
    PTSSAHyperParams,
    PTSSAResult,
    anneal_pt,
    anneal_pt_ssa,
)
from .sa import SAHyperParams, SAResult, anneal_sa  # noqa: F401
from .schedule import (  # noqa: F401
    Schedule,
    hassa_schedule,
    n_temp_steps,
    ssa_schedule,
    ssqa_schedule,
)
from .ssa import (  # noqa: F401
    AnnealResult,
    SSAHyperParams,
    anneal,
    pack_spins,
    solve_maxcut,
    ssa_cycle_update,
    unpack_spins,
)
from .ssqa import SSQAHyperParams, anneal_ssqa  # noqa: F401
