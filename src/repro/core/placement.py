"""Beyond-paper integration: HA-SSA as the MoE expert-placement optimizer.

Expert parallelism shards experts across the `model` mesh axis; each token's
top-k dispatch then crosses devices (all-to-all).  Two effects determine the
collective cost:

  * **co-activation** — experts that fire together for the same token should
    be co-located (one dispatch hop instead of two);
  * **load balance** — popular experts should spread across devices (the
    all-to-all is bottlenecked by the hottest device).

Balanced-min-cut of the co-activation graph is NP-hard (it IS weighted
MAX-CUT's complement) — exactly the workload HA-SSA solves.  We embed it as
an Ising model:

    J_ij = round(σ·coact_ij) − λ·round(σ·load_i·load_j)

(same-spin ⇒ same device; the load term is the expansion of the balance
penalty (Σ_i load_i·m_i)²) and anneal with the paper's algorithm.  D > 2
devices are handled by recursive bisection, each level one HA-SSA run.

This is the paper's technique as a first-class feature of the training
framework (DESIGN.md §3): ``launch.train --placement ssa`` applies it to the
MoE archs (olmoe, moonshot, jamba).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from .ising import IsingModel
from .ssa import SSAHyperParams, anneal

__all__ = [
    "coactivation_stats",
    "placement_ising",
    "expert_placement",
    "traffic_cost",
    "PlacementResult",
]


def coactivation_stats(routing: np.ndarray, n_experts: int) -> Tuple[np.ndarray, np.ndarray]:
    """(coact (E,E), load (E,)) from observed top-k routing decisions.

    routing: (n_tokens, top_k) int expert ids.
    """
    E = n_experts
    coact = np.zeros((E, E), dtype=np.int64)
    load = np.zeros(E, dtype=np.int64)
    for row in routing:
        u = np.unique(row)
        load[u] += 1
        for a in range(len(u)):
            for b in range(a + 1, len(u)):
                coact[u[a], u[b]] += 1
                coact[u[b], u[a]] += 1
    return coact, load


def placement_ising(
    coact: np.ndarray,
    load: np.ndarray,
    lam: float = 1.0,
    scale: float = 1.0,
) -> IsingModel:
    """Ising embedding of balanced min-cut placement (integer couplings)."""
    E = coact.shape[0]
    loadf = load.astype(np.float64)
    loadf = loadf / max(loadf.mean(), 1e-9)
    bal = np.outer(loadf, loadf)
    J = scale * coact.astype(np.float64) / max(coact.max(initial=1), 1) * 16.0
    J = J - lam * bal * 16.0
    J = np.round(J).astype(np.int64)
    np.fill_diagonal(J, 0)
    J = np.triu(J, 1) + np.triu(J, 1).T
    return IsingModel.from_dense(J, name="expert-placement")


@dataclasses.dataclass
class PlacementResult:
    assignment: np.ndarray  # (E,) device ids
    cost: float
    baseline_cost: float

    @property
    def improvement(self) -> float:
        return (self.baseline_cost - self.cost) / max(self.baseline_cost, 1e-9)


def traffic_cost(assignment: np.ndarray, coact: np.ndarray, load: np.ndarray) -> float:
    """Modeled all-to-all cost: cross-device co-activation + hottest-device load.

    cost = Σ_{i<j, dev_i≠dev_j} coact_ij  +  λ_imb · max_dev(Σ load) · D
    """
    E = len(assignment)
    cross = 0.0
    for i in range(E):
        for j in range(i + 1, E):
            if assignment[i] != assignment[j]:
                cross += coact[i, j]
    n_dev = int(assignment.max()) + 1
    per_dev = np.zeros(n_dev)
    for i in range(E):
        per_dev[assignment[i]] += load[i]
    imbalance = per_dev.max() * n_dev - load.sum()
    return float(cross + imbalance * coact.max(initial=1) / max(load.mean(), 1e-9))


def _bisect(coact, load, idx, hp, seed, lam):
    model = placement_ising(coact[np.ix_(idx, idx)], load[idx], lam=lam)
    res = anneal(model, hp, seed=seed, noise="xorshift", track_energy=False)
    best = res.best_m[int(np.argmin(res.best_energy))]
    left = idx[best > 0]
    right = idx[best <= 0]
    if len(left) == 0 or len(right) == 0:  # degenerate split: force halves
        half = len(idx) // 2
        left, right = idx[:half], idx[half:]
    return left, right


def expert_placement(
    coact: np.ndarray,
    load: np.ndarray,
    n_devices: int,
    hp: Optional[SSAHyperParams] = None,
    seed: int = 0,
    lam: float = 1.0,
) -> PlacementResult:
    """Recursive-bisection placement of E experts onto n_devices (power of 2)."""
    E = coact.shape[0]
    assert n_devices & (n_devices - 1) == 0, "n_devices must be a power of 2"
    hp = hp or SSAHyperParams(n_trials=8, m_shot=10, tau=50, i0_min=1, i0_max=16)
    groups = [np.arange(E)]
    level = 0
    while len(groups) < n_devices:
        new_groups = []
        for gi, g in enumerate(groups):
            l, r = _bisect(coact, load, g, hp, seed + 31 * level + gi, lam)
            new_groups += [l, r]
        groups = new_groups
        level += 1
    assignment = np.zeros(E, dtype=np.int64)
    for d, g in enumerate(groups):
        assignment[g] = d
    baseline = np.arange(E) % n_devices  # naive round-robin
    return PlacementResult(
        assignment=assignment,
        cost=traffic_cost(assignment, coact, load),
        baseline_cost=traffic_cost(baseline, coact, load),
    )
