"""G-set benchmark instances (Sec. II-C, Table I) and structure-faithful stand-ins.

The paper evaluates on G11, G12, G13 (800 vertices, 1600 edges, ±1 weights,
toroidal 4-regular topology) plus a custom 'King1' (800 vertices, 3200 edges,
king's-graph 8-neighbor topology, ±1 uniform weights).

This container has no network access, so the exact Stanford G-set files may be
absent.  :func:`load` first looks for real instance files under
``data/gset/<name>`` (standard G-set text format: ``n m`` header then
``i j w`` rows, 1-indexed); if absent it deterministically *generates* an
instance with the published topology and weight distribution.  Generated
instances carry ``best_known=None`` — relative claims (HA-SSA ≡ SSA, memory
ratio, speedup vs SA) are instance-independent and are what the tests assert.
"""
from __future__ import annotations

import os
from typing import Optional, Tuple

import numpy as np

from .ising import MaxCutProblem

__all__ = [
    "load",
    "parse_gset_text",
    "toroidal_grid",
    "king_graph",
    "complete_graph",
    "GSET_DIR",
]

GSET_DIR = os.environ.get(
    "REPRO_GSET_DIR", os.path.join(os.path.dirname(__file__), "..", "..", "..", "data", "gset")
)

_BEST_KNOWN = {"G11": 564, "G12": 556, "G13": 582}


def parse_gset_text(text: str, name: str = "gset") -> MaxCutProblem:
    """Parse the standard G-set format: 'n m' header, then 'i j w' (1-indexed)."""
    lines = [ln for ln in text.strip().splitlines() if ln.strip()]
    n, m = map(int, lines[0].split()[:2])
    edges = np.zeros((m, 2), dtype=np.int64)
    weights = np.zeros(m, dtype=np.int64)
    for k, ln in enumerate(lines[1 : m + 1]):
        i, j, w = map(int, ln.split()[:3])
        edges[k] = (i - 1, j - 1)
        weights[k] = w
    return MaxCutProblem(
        n=n, edges=edges, weights=weights, name=name, best_known=_BEST_KNOWN.get(name)
    )


def _torus_coords(n: int) -> Tuple[int, int]:
    """Pick a near-square (rows, cols) factorization for an n-vertex torus."""
    r = int(np.sqrt(n))
    while n % r:
        r -= 1
    return r, n // r


def toroidal_grid(n: int = 800, seed: int = 11, name: str = "toroidal") -> MaxCutProblem:
    """4-regular 2-D torus with ±1 uniform weights (G11/G12/G13 family).

    800 vertices ⇒ 1600 edges, matching Table I.
    """
    rows, cols = _torus_coords(n)
    rng = np.random.default_rng(seed)
    edges = []
    for r in range(rows):
        for c in range(cols):
            v = r * cols + c
            edges.append((v, r * cols + (c + 1) % cols))          # right
            edges.append((v, ((r + 1) % rows) * cols + c))        # down
    edges = np.asarray(edges, dtype=np.int64)
    weights = rng.choice(np.array([-1, 1], dtype=np.int64), size=len(edges))
    return MaxCutProblem(n=n, edges=edges, weights=weights, name=name)


def king_graph(n: int = 800, seed: int = 1, name: str = "King1") -> MaxCutProblem:
    """8-neighbor king's graph on a torus, ±1 uniform weights (King1 family).

    800 vertices ⇒ 3200 edges (4 undirected edge classes per vertex:
    E, S, SE, SW), matching Table I.
    """
    rows, cols = _torus_coords(n)
    rng = np.random.default_rng(seed)
    edges = []
    for r in range(rows):
        for c in range(cols):
            v = r * cols + c
            rn, cn = (r + 1) % rows, (c + 1) % cols
            cp = (c - 1) % cols
            edges.append((v, r * cols + cn))    # E
            edges.append((v, rn * cols + c))    # S
            edges.append((v, rn * cols + cn))   # SE
            edges.append((v, rn * cols + cp))   # SW
    edges = np.asarray(edges, dtype=np.int64)
    weights = rng.choice(np.array([-1, 1], dtype=np.int64), size=len(edges))
    return MaxCutProblem(n=n, edges=edges, weights=weights, name=name)


def complete_graph(n: int = 2000, seed: int = 2000, name: str = "K-like") -> MaxCutProblem:
    """Fully-connected ±1 instance (K2000 family, Sec. VI-B / [28])."""
    rng = np.random.default_rng(seed)
    ii, jj = np.triu_indices(n, k=1)
    edges = np.stack([ii, jj], axis=1)
    weights = rng.choice(np.array([-1, 1], dtype=np.int64), size=len(edges))
    return MaxCutProblem(n=n, edges=edges, weights=weights, name=name)


_GENERATORS = {
    "G11": lambda: toroidal_grid(800, seed=11, name="G11-like"),
    "G12": lambda: toroidal_grid(800, seed=12, name="G12-like"),
    "G13": lambda: toroidal_grid(800, seed=13, name="G13-like"),
    "King1": lambda: king_graph(800, seed=1, name="King1"),
    "K2000": lambda: complete_graph(2000, seed=2000, name="K2000-like"),
    # Large-N G-set scenario (tiled-J / packed-storage territory: a dense
    # (N, N) J would be 0.8–1.6 GB f32; the engine streams slabs instead).
    "G77": lambda: toroidal_grid(14383, seed=77, name="G77-like"),
    "G81": lambda: toroidal_grid(20000, seed=81, name="G81-like"),
}


def load(name: str, gset_dir: Optional[str] = None) -> MaxCutProblem:
    """Load a benchmark instance: real file if available, else generated twin."""
    d = gset_dir or GSET_DIR
    path = os.path.join(d, name)
    if os.path.exists(path):
        with open(path) as f:
            return parse_gset_text(f.read(), name=name)
    if name in _GENERATORS:
        return _GENERATORS[name]()
    raise KeyError(f"unknown instance {name!r}; known: {sorted(_GENERATORS)}")
