"""Trajectory-memory models — paper Eq. (5) and Eq. (6) — plus the serving
layer's bucket-padding overhead.

SSA must store every spin bitplane of an iteration:

    M  = N · (log2(I0max/I0min)/β + 1) · τ   bits            (Eq. 5, shift form)

HA-SSA stores only the I0 == I0max plateau:

    M' = N · τ                                bits            (Eq. 6)

ratio = steps = log2(I0max/I0min)/β + 1 → 6 for the Table-II hyperparameters
(I0: 1→32, β=1), i.e. 0.48 Mb vs 0.08 Mb per iteration for N=800 (Table IV)
and 72 Mb vs 12 Mb per 150-iteration trial.

The annealing service (serve/anneal_service.py) pads instances to
power-of-two shape buckets, so every stored bitplane carries
``bucket(N) - N`` dead bits per cycle.  The ``padding_overhead_*`` models
quantify that waste so the paper's memory comparison stays honest under
bucketing (benchmarks/memory_table.py reports the column).
Measured accounting (this module's second half) turns the closed-form
models into asserted runtime facts: :func:`live_device_bytes` sums every
live jax device buffer (`jax.live_arrays`), :func:`tree_device_bytes`
sizes a concrete state pytree, and :func:`measure_live_bytes` wraps a
builder and reports the live-byte delta it left behind.
`benchmarks/memory_table.py` prints measured next to analytic and exits
nonzero when the measured HA-SSA/SSA ratio regresses; `benchmarks/timing.py
--memory` writes both to BENCH_memory.json.
"""
from __future__ import annotations

import gc
from typing import Any, Callable, Tuple

import jax
import numpy as np

from .engine import bucket_n
from .schedule import n_temp_steps
from .ssa import SSAHyperParams

__all__ = [
    "ssa_bits_per_iteration",
    "hassa_bits_per_iteration",
    "memory_ratio",
    "bits_per_trial",
    "padding_overhead_bits_per_iteration",
    "padding_overhead_fraction",
    "live_device_bytes",
    "tree_device_bytes",
    "per_device_bytes",
    "max_device_bytes",
    "measure_live_bytes",
]


def ssa_bits_per_iteration(n_spins: int, hp: SSAHyperParams) -> int:
    """Eq. (5): all plateaus stored."""
    steps = n_temp_steps(hp.i0_min, hp.i0_max, hp.beta_shift)
    return n_spins * steps * hp.tau


def hassa_bits_per_iteration(n_spins: int, hp: SSAHyperParams) -> int:
    """Eq. (6): only the I0max plateau stored."""
    return n_spins * hp.tau


def memory_ratio(hp: SSAHyperParams) -> int:
    """M / M' = number of temperature plateaus (6 for Table II)."""
    return n_temp_steps(hp.i0_min, hp.i0_max, hp.beta_shift)


def bits_per_trial(n_spins: int, hp: SSAHyperParams, hardware_aware: bool = True) -> int:
    per_iter = (
        hassa_bits_per_iteration(n_spins, hp)
        if hardware_aware
        else ssa_bits_per_iteration(n_spins, hp)
    )
    return per_iter * hp.m_shot


def padding_overhead_bits_per_iteration(
    n_spins: int,
    hp: SSAHyperParams,
    min_bucket: int = 64,
    hardware_aware: bool = True,
) -> int:
    """Dead bits stored per iteration when N is padded to its shape bucket.

    ``(bucket(N) - N) × stored_cycles``: the service's padded lanes occupy
    bitplane width but carry no solution information.
    """
    pad = bucket_n(n_spins, min_bucket) - n_spins
    stored = hp.tau if hardware_aware else n_temp_steps(
        hp.i0_min, hp.i0_max, hp.beta_shift
    ) * hp.tau
    return pad * stored


def padding_overhead_fraction(n_spins: int, min_bucket: int = 64) -> float:
    """Fraction of each stored bitplane wasted on pad lanes: 1 - N/bucket(N)."""
    nb = bucket_n(n_spins, min_bucket)
    return 1.0 - n_spins / nb


# ---------------------------------------------------------------------------
# Measured accounting: the analytic models, asserted against live buffers
# ---------------------------------------------------------------------------
def _array_nbytes(a) -> int:
    nbytes = getattr(a, "nbytes", None)
    if nbytes is not None:
        return int(nbytes)
    return int(np.prod(a.shape)) * np.dtype(a.dtype).itemsize


def live_device_bytes() -> int:
    """Total bytes of every live jax device array (`jax.live_arrays`)."""
    return sum(_array_nbytes(a) for a in jax.live_arrays())


def tree_device_bytes(tree: Any) -> int:
    """Bytes of the concrete arrays in a pytree (an engine state, a stack)."""
    return sum(
        _array_nbytes(leaf)
        for leaf in jax.tree_util.tree_leaves(tree)
        if hasattr(leaf, "dtype")
    )


def per_device_bytes(tree: Any = None) -> dict:
    """Resident bytes keyed by device — for ``tree``, or every live array.

    The single number :func:`live_device_bytes`/:func:`tree_device_bytes`
    report is the *global* footprint; under spin sharding (DESIGN.md §11)
    the quantity that decides whether an instance fits is what each device
    actually holds.  Sums ``addressable_shards`` per jax array — a
    row-sharded J slab or spin shard counts only on its owner, a replicated
    ``best_H`` counts on every device — and attributes host (numpy) leaves
    to ``'host'``.
    """
    arrays = (
        [leaf for leaf in jax.tree_util.tree_leaves(tree)
         if hasattr(leaf, "dtype")]
        if tree is not None else list(jax.live_arrays())
    )
    out: dict = {}
    for a in arrays:
        shards = getattr(a, "addressable_shards", None)
        if shards:
            for s in shards:
                key = str(s.device)
                out[key] = out.get(key, 0) + int(s.data.nbytes)
        else:
            out["host"] = out.get("host", 0) + _array_nbytes(a)
    return out


def max_device_bytes(tree: Any = None) -> int:
    """The busiest device's resident bytes (0 when nothing is live).

    The per-device residency headline: for a spin-sharded state this is
    what must drop ~linearly with the model-axis size (tested).
    """
    per = per_device_bytes(tree)
    return max(per.values()) if per else 0


def measure_live_bytes(build: Callable[[], Any]) -> Tuple[Any, int]:
    """Run ``build()`` and measure the live-device-byte delta it leaves.

    The delta is taken over `jax.live_arrays` after a gc pass on both sides,
    so it reports the buffers the builder actually left resident (its return
    value plus anything it cached) — the measured counterpart of the Eq.
    (5)/(6) closed forms.  Returns ``(result, delta_bytes)``.
    """
    gc.collect()
    before = live_device_bytes()
    out = build()
    try:
        jax.block_until_ready(out)
    except (TypeError, ValueError):
        pass  # non-array results (dataclasses of np arrays) are already done
    gc.collect()
    return out, live_device_bytes() - before
