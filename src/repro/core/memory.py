"""Trajectory-memory models — paper Eq. (5) and Eq. (6).

SSA must store every spin bitplane of an iteration:

    M  = N · (log2(I0max/I0min)/β + 1) · τ   bits            (Eq. 5, shift form)

HA-SSA stores only the I0 == I0max plateau:

    M' = N · τ                                bits            (Eq. 6)

ratio = steps = log2(I0max/I0min)/β + 1 → 6 for the Table-II hyperparameters
(I0: 1→32, β=1), i.e. 0.48 Mb vs 0.08 Mb per iteration for N=800 (Table IV)
and 72 Mb vs 12 Mb per 150-iteration trial.
"""
from __future__ import annotations

from .schedule import n_temp_steps
from .ssa import SSAHyperParams

__all__ = [
    "ssa_bits_per_iteration",
    "hassa_bits_per_iteration",
    "memory_ratio",
    "bits_per_trial",
]


def ssa_bits_per_iteration(n_spins: int, hp: SSAHyperParams) -> int:
    """Eq. (5): all plateaus stored."""
    steps = n_temp_steps(hp.i0_min, hp.i0_max, hp.beta_shift)
    return n_spins * steps * hp.tau


def hassa_bits_per_iteration(n_spins: int, hp: SSAHyperParams) -> int:
    """Eq. (6): only the I0max plateau stored."""
    return n_spins * hp.tau


def memory_ratio(hp: SSAHyperParams) -> int:
    """M / M' = number of temperature plateaus (6 for Table II)."""
    return n_temp_steps(hp.i0_min, hp.i0_max, hp.beta_shift)


def bits_per_trial(n_spins: int, hp: SSAHyperParams, hardware_aware: bool = True) -> int:
    per_iter = (
        hassa_bits_per_iteration(n_spins, hp)
        if hardware_aware
        else ssa_bits_per_iteration(n_spins, hp)
    )
    return per_iter * hp.m_shot
