"""Random noise sources for the annealers.

The FPGA uses a XOR-shift generator [26] to produce one noise bit per
spin-gate per cycle (r_i(t) ∈ {-1,+1}, Eq. 2a).  We provide:

* :class:`Xorshift128` — Marsaglia xorshift128 (32-bit, 4-word state) with one
  independent lane per (trial, spin), matching the hardware's per-spin bit
  streams.  Pure uint32 jnp ops, scan/jit-friendly, deterministic.
* :func:`threefry_noise` — `jax.random`-based noise (statistically stronger;
  the framework default).

Both return spins' noise as int32 in {-1,+1}.  The HA-SSA ≡ SSA equivalence
property holds for *any* shared noise stream, so tests exercise both.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "Xorshift128",
    "xorshift_init",
    "xorshift_init_slice",
    "xorshift_next_bits",
    "xorshift_lanes_ok",
    "threefry_noise",
]

_U32 = jnp.uint32


def _seed_lane_states(seed: int, idx: np.ndarray, n_total: int) -> np.ndarray:
    """SplitMix avalanche: flat lane indices → (4,) + idx.shape uint32 states.

    ``idx`` holds *global* flat lane indices and ``n_total`` the global lane
    count, so any sub-block of lanes can be seeded independently yet
    bit-identically to a full :func:`xorshift_init` — the property the
    spin-sharded path needs to seed only its shard's lanes.
    """
    idx = idx.astype(np.uint64)
    states = []
    for word in range(4):
        z = (np.uint64(seed) + np.uint64(0x9E3779B97F4A7C15)
             * (idx + np.uint64(1 + word * n_total)))
        z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
        z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
        z = z ^ (z >> np.uint64(31))
        states.append((z & np.uint64(0xFFFFFFFF)).astype(np.uint32))
    st = np.stack(states, axis=0)
    # xorshift forbids the all-zero state; nudge any such lane.
    st[0] = np.where((st == 0).all(axis=0), np.uint32(0x1234567), st[0])
    return st


def xorshift_init(seed: int, lanes: Tuple[int, ...]) -> jnp.ndarray:
    """Seed per-lane xorshift128 states, shape (4,) + lanes, dtype uint32.

    SplitMix-style avalanche over (seed, lane index) so lanes decorrelate.
    """
    n = int(np.prod(lanes)) if lanes else 1
    st = _seed_lane_states(seed, np.arange(n, dtype=np.uint64), n)
    return jnp.asarray(st.reshape((4,) + tuple(lanes)))


def xorshift_init_slice(seed: int, lanes: Tuple[int, ...], lo: int, hi: int) -> np.ndarray:
    """Seed only columns [lo, hi) of the last lane axis — shard-local init.

    Returns a numpy ``(4,) + lanes[:-1] + (hi - lo,)`` block bit-identical to
    ``xorshift_init(seed, lanes)[..., lo:hi]`` without materializing the full
    lane array: the flat lane index of lane ``(..., s)`` and the *global*
    lane count both enter the seeding formula unchanged, so each device of a
    spin-sharded run can seed exactly its own columns (DESIGN.md §11).
    """
    lanes = tuple(int(x) for x in lanes)
    lo, hi = int(lo), int(hi)
    n_col = lanes[-1]
    if not 0 <= lo <= hi <= n_col:
        raise ValueError(f"slice [{lo}, {hi}) outside [0, {n_col})")
    n_total = int(np.prod(lanes)) if lanes else 1
    lead = lanes[:-1]
    n_lead = int(np.prod(lead)) if lead else 1
    base = np.arange(n_lead, dtype=np.uint64).reshape(lead + (1,)) * np.uint64(n_col)
    idx = base + np.arange(lo, hi, dtype=np.uint64)
    return _seed_lane_states(seed, idx, n_total)


def xorshift_next_bits(state: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One Marsaglia xorshift128 step per lane.

    Returns (new_state, noise) with noise int32 in {-1,+1} taken from the
    output word's MSB (an unbiased bit).
    """
    x, y, z, w = state[0], state[1], state[2], state[3]
    t = x ^ (x << _U32(11))
    t = t & _U32(0xFFFFFFFF)
    w_new = (w ^ (w >> _U32(19))) ^ (t ^ (t >> _U32(8)))
    new_state = jnp.stack([y, z, w, w_new], axis=0)
    noise = jnp.where((w_new >> _U32(31)) & _U32(1), 1, -1).astype(jnp.int32)
    return new_state, noise


def xorshift_lanes_ok(state, axis: int = 0) -> bool:
    """Integrity check on carried xorshift lanes: no all-zero lane.

    The all-zero state is xorshift128's absorbing fixed point — a lane in it
    emits constant noise forever.  :func:`xorshift_init` never produces one,
    so finding one in a state that came back from disk (a resumed service
    checkpoint) or over a wire means corruption; resume paths call this
    before trusting restored lanes.  ``axis`` is the 4-word state axis
    (0 for an unbatched ``(4, T, N)`` state, 1 for a batched
    ``(B, 4, T, N)`` state).
    """
    arr = np.asarray(state)
    if arr.ndim <= axis or arr.shape[axis] != 4:
        return False
    return not bool(np.all(arr == 0, axis=axis).any())


class Xorshift128:
    """Convenience OO wrapper (functional core above stays scan-friendly)."""

    def __init__(self, seed: int, lanes: Tuple[int, ...]):
        self.state = xorshift_init(seed, lanes)

    def next_bits(self) -> jnp.ndarray:
        self.state, bits = xorshift_next_bits(self.state)
        return bits


def threefry_noise(key: jax.Array, shape: Tuple[int, ...]) -> jnp.ndarray:
    """±1 noise from jax.random (framework default)."""
    return jnp.where(jax.random.bernoulli(key, 0.5, shape), 1, -1).astype(jnp.int32)
