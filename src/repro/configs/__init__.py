"""Architecture registry: ``--arch <id>`` → ModelConfig (full or reduced).

The 10 assigned architectures (each with its own input-shape set, see
shapes.py) plus the paper-native annealing problem configs.
"""
from __future__ import annotations


from repro.models import ModelConfig

from . import (
    granite_3_8b,
    jamba_1_5_large_398b,
    mistral_large_123b,
    moonshot_v1_16b_a3b,
    olmoe_1b_7b,
    phi_3_vision_4_2b,
    qwen3_1_7b,
    qwen3_32b,
    rwkv6_3b,
    whisper_tiny,
)
from .shapes import (  # noqa: F401
    SHAPES,
    ShapeCell,
    applicable,
    decode_input_specs,
    prefill_input_specs,
    train_input_specs,
)

_MODULES = {
    "jamba-1.5-large-398b": jamba_1_5_large_398b,
    "granite-3-8b": granite_3_8b,
    "mistral-large-123b": mistral_large_123b,
    "qwen3-1.7b": qwen3_1_7b,
    "qwen3-32b": qwen3_32b,
    "olmoe-1b-7b": olmoe_1b_7b,
    "moonshot-v1-16b-a3b": moonshot_v1_16b_a3b,
    "rwkv6-3b": rwkv6_3b,
    "whisper-tiny": whisper_tiny,
    "phi-3-vision-4.2b": phi_3_vision_4_2b,
}

ARCH_NAMES = tuple(_MODULES)


def get_config(name: str, reduced: bool = False) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_NAMES}")
    mod = _MODULES[name]
    return mod.reduced() if reduced else mod.config()


# Paper-native annealing problem configs (``--problem <id>``)
ANNEAL_PROBLEMS = ("G11", "G12", "G13", "King1", "K2000", "G77", "G81")
