"""granite-3-8b [dense] — GQA.  40L d_model=4096 32H (kv=8) d_ff=12800
vocab=49155 [hf:ibm-granite/granite-3.0-2b-base; hf].

Note: vocab 49155 is not divisible by the 16-way model axis — the sharding
layer replicates the vocab dim for this arch (divisibility-aware rules).
"""
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-3-8b",
        n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, d_head=128,
        d_ff=12800, vocab=49155, rope_theta=1e4,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="granite-reduced",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab=131, remat="none", q_chunk=16, kv_chunk=16,
    )
