"""mistral-large-123b [dense] — 88L d_model=12288 96H (GQA kv=8) d_ff=28672
vocab=32768 [hf:mistralai/Mistral-Large-Instruct-2407; unverified]."""
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mistral-large-123b",
        n_layers=88, d_model=12288, n_heads=96, n_kv_heads=8, d_head=128,
        d_ff=28672, vocab=32768, rope_theta=1e6,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="mistral-reduced",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab=128, remat="none", q_chunk=16, kv_chunk=16,
    )
