"""olmoe-1b-7b [moe] — 64 experts top-8, every layer MoE.  16L d_model=2048
16H (kv=16 = MHA) d_ff=1024/expert vocab=50304 [arXiv:2409.02060; hf]."""
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b",
        n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, d_head=128,
        d_ff=1024, vocab=50304, block=(("attn", "moe"),),
        n_experts=64, top_k=8, qk_norm=True, rope_theta=1e4,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="olmoe-reduced",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
        d_ff=32, vocab=128, block=(("attn", "moe"),),
        n_experts=8, top_k=2, capacity_factor=2.0, qk_norm=True,
        remat="none", moe_seq_chunk=16, q_chunk=16, kv_chunk=16,
    )
