"""phi-3-vision-4.2b [vlm] — phi3-mini backbone + CLIP tower STUB
(input_specs provides precomputed patch embeddings that replace the first
n_patches token positions).  32L d_model=3072 32H (kv=32) d_ff=8192
vocab=32064 [hf:microsoft/Phi-3-vision-128k-instruct; hf]."""
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi-3-vision-4.2b",
        n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32, d_head=96,
        d_ff=8192, vocab=32064, frontend="vision", n_patches=576,
        rope_theta=1e4,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="phi3v-reduced",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
        d_ff=128, vocab=128, frontend="vision", n_patches=4,
        remat="none", q_chunk=16, kv_chunk=16,
    )
