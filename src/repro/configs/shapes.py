"""The assigned input-shape cells and their applicability rules.

  train_4k     seq_len=4,096    global_batch=256   (training)
  prefill_32k  seq_len=32,768   global_batch=32    (inference-prefill)
  decode_32k   seq_len=32,768   global_batch=128   (inference-decode)
  long_500k    seq_len=524,288  global_batch=1     (long-context decode)

decode_*/long_* lower ``serve_step`` (one new token against a KV cache of
seq_len), NOT train_step.  long_500k requires sub-quadratic decode state —
run for SSM/hybrid archs, skipped (with reason) for pure full-attention
archs, per the assignment.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

__all__ = ["ShapeCell", "SHAPES", "applicable", "train_input_specs",
           "prefill_input_specs", "decode_input_specs"]


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}


def applicable(cfg, shape: ShapeCell) -> Tuple[bool, str]:
    """(runnable, reason-if-not) for an (arch, shape) cell."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, (
            "pure full-attention arch: 500k-token decode needs sub-quadratic "
            "state (run for SSM/hybrid only) — see DESIGN.md §Arch-applicability"
        )
    return True, ""


def _frontend_specs(cfg, batch: int):
    extra = {}
    if cfg.frontend == "vision" and cfg.n_patches:
        extra["patches"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_patches, cfg.d_model), jnp.float32
        )
    if cfg.encoder_layers > 0:
        extra["frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_frames, cfg.d_model), jnp.float32
        )
    return extra


def train_input_specs(cfg, shape: ShapeCell):
    B, S = shape.global_batch, shape.seq_len
    return {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
        **_frontend_specs(cfg, B),
    }


def prefill_input_specs(cfg, shape: ShapeCell):
    B, S = shape.global_batch, shape.seq_len
    return {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        **_frontend_specs(cfg, B),
    }


def decode_input_specs(cfg, shape: ShapeCell):
    """(token, pos) — caches come from models.cache_defs."""
    B = shape.global_batch
    return {
        "token": jax.ShapeDtypeStruct((B,), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }
