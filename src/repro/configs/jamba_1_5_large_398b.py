"""jamba-1.5-large-398b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2.

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536 [arXiv:2403.19887; hf].
Block structure: repeats of 8 layers with 1 attention (index 0) : 7 Mamba,
MoE FFN on every second layer (odd indices) — the Jamba block layout.
"""
from repro.models import ModelConfig

_BLOCK = tuple(
    ("attn" if i == 0 else "mamba", "moe" if i % 2 == 1 else "dense")
    for i in range(8)
)


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b",
        n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, d_head=128,
        d_ff=24576, vocab=65536, block=_BLOCK,
        n_experts=16, top_k=2,
        d_state=16, d_conv=4, expand=2, dt_rank=512,
        rope_theta=1e6,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="jamba-reduced",
        n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab=128, block=_BLOCK,
        n_experts=4, top_k=2, capacity_factor=2.0,
        d_state=8, d_conv=4, expand=2, dt_rank=8,
        remat="none", moe_seq_chunk=16, q_chunk=16, kv_chunk=16,
    )
