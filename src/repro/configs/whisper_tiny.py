"""whisper-tiny [audio] — enc-dec, conv frontend STUB (input_specs provides
precomputed frame embeddings).  4L enc + 4L dec, d_model=384 6H (kv=6)
d_ff=1536 vocab=51865 [arXiv:2212.04356; unverified].

Notes: 6 heads / d_ff 1536 don't always divide the 16-way model axis — the
divisibility-aware sharding replicates what doesn't fit (d_ff 1536 = 16×96
does shard).  max_pos is stretched to 32768 so the synthetic decode_32k cell
is lowerable (real whisper caps at 448 decoder positions).
"""
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny",
        n_layers=4, d_model=384, n_heads=6, n_kv_heads=6, d_head=64,
        d_ff=1536, vocab=51865, encoder_layers=4, n_frames=1500,
        rope_theta=0, pos_embed="learned", max_pos=32768,
        norm="layernorm", act="gelu",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="whisper-reduced",
        n_layers=2, d_model=32, n_heads=2, n_kv_heads=2, d_head=16,
        d_ff=64, vocab=101, encoder_layers=2, n_frames=8,
        rope_theta=0, pos_embed="learned", max_pos=64,
        norm="layernorm", act="gelu", remat="none", q_chunk=16, kv_chunk=16,
    )
