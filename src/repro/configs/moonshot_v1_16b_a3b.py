"""moonshot-v1-16b-a3b [moe] — kimi/moonlight, 64 experts top-6.  48L
d_model=2048 16H (kv=16) d_ff=1408/expert vocab=163840
[hf:moonshotai/Moonlight-16B-A3B; hf]."""
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b",
        n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16, d_head=128,
        d_ff=1408, vocab=163840, block=(("attn", "moe"),),
        n_experts=64, top_k=6, rope_theta=5e4,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="moonshot-reduced",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
        d_ff=32, vocab=128, block=(("attn", "moe"),),
        n_experts=8, top_k=3, capacity_factor=2.0,
        remat="none", moe_seq_chunk=16, q_chunk=16, kv_chunk=16,
    )
