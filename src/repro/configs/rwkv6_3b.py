"""rwkv6-3b [ssm] — Finch, data-dependent decay, attention-free.  32L
d_model=2560 (40 heads × 64) d_ff=8960 vocab=65536 [arXiv:2404.05892; hf]."""
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b",
        n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40, d_head=64,
        d_ff=8960, vocab=65536, block=(("rwkv", "rwkv"),),
        rwkv_head_dim=64, norm="layernorm",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-reduced",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
        d_ff=128, vocab=128, block=(("rwkv", "rwkv"),),
        rwkv_head_dim=16, norm="layernorm", remat="none",
    )
