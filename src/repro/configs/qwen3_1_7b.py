"""qwen3-1.7b [dense] — qk_norm, GQA.  28L d_model=2048 16H (kv=8) d_ff=6144
vocab=151936 [hf:Qwen/Qwen3-8B; hf]."""
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-1.7b",
        n_layers=28, d_model=2048, n_heads=16, n_kv_heads=8, d_head=128,
        d_ff=6144, vocab=151936, qk_norm=True, rope_theta=1e6,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen3-1.7b-reduced",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab=128, qk_norm=True, remat="none", q_chunk=16, kv_chunk=16,
    )
