"""qwen3-32b [dense] — qk_norm, GQA.  64L d_model=5120 64H (kv=8) d_ff=25600
vocab=151936 [hf:Qwen/Qwen3-8B; hf].  d_head=128 (q/k/v project to 8192)."""
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-32b",
        n_layers=64, d_model=5120, n_heads=64, n_kv_heads=8, d_head=128,
        d_ff=25600, vocab=151936, qk_norm=True, rope_theta=1e6,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen3-32b-reduced",
        n_layers=2, d_model=80, n_heads=4, n_kv_heads=2, d_head=32,
        d_ff=160, vocab=128, qk_norm=True, remat="none", q_chunk=16, kv_chunk=16,
    )
