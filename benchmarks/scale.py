"""Weak-scaling benchmark for the spin-sharded annealer (DESIGN.md §11).

Measures steady-state spin-cycles/s of ONE instance sharded over P devices
at fixed N/device (weak scaling: N = P × n_per_dev), the per-device
residency drop, and the largest-N-solved row — an instance above
``engine.MAX_UNSHARDED_SPINS`` that the single-device service path REJECTS
at admission and the spin-sharded path solves end to end.  Every
multi-device row also asserts sharded ≡ single-device **bit-identity** for
both field arithmetic paths (f32 tiled-slab matmul and XNOR-popcount) —
the numbers only count because the answers are exactly equal.

The device count must be fixed before jax initializes, so the benchmark
runs parent/worker: the parent (never imports jax) spawns one subprocess
per device count with ``XLA_FLAGS=--xla_force_host_platform_device_count``
set, and aggregates into ``BENCH_scale.json``.

Speedup gate (``--gate``): weak scaling doubles the work at constant wall
time, so 2 devices must reach ``GATE_SPEEDUP_2DEV`` (1.6×) the 1-device
spin-cycles/s.  **CPU-emulation floor**: forced host devices on a machine
with fewer than ``2 × devices`` cores share the same silicon — no speedup
is physically available, and the gate degrades (documented, recorded in
the JSON as ``emulation: true``) to ``GATE_EMULATION_FLOOR`` (0.45×):
sharding overhead (the per-cycle all-gather + psum) must not destroy
throughput even when it cannot add any.  On real multi-device hardware the
full 1.6× gate applies.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

GATE_SPEEDUP_2DEV = 1.6     # weak-scaling speedup @ 2 devices, real hardware
GATE_EMULATION_FLOOR = 0.45  # same-silicon forced-device floor (see docstring)

SMOKE = {"n_per_dev": 512, "n_trials": 2, "tau": 4, "i0_max": 4,
         "devices": (1, 2, 4), "big_n": 40000,
         "big_hp": dict(n_trials=1, m_shot=1, tau=2, i0_min=1, i0_max=2)}
FULL = {"n_per_dev": 4096, "n_trials": 4, "tau": 16, "i0_max": 8,
        "devices": (1, 2, 4, 8), "big_n": 40000,
        "big_hp": dict(n_trials=2, m_shot=1, tau=4, i0_min=1, i0_max=4)}


# ---------------------------------------------------------------------------
# Worker: runs inside one forced-device-count process
# ---------------------------------------------------------------------------
def _worker(args) -> None:
    import jax
    import numpy as np

    from repro.core import SSAHyperParams, anneal, gset, memory
    from repro.core.engine import (
        MAX_UNSHARDED_SPINS,
        make_backend,
        run_schedule,
        schedule_plateaus,
    )
    from repro.sharding import spin_mesh

    from .common import time_call

    P = len(jax.devices())
    assert P == args.devices, f"forced {args.devices} devices, got {P}"
    mesh = spin_mesh(P)
    out = {"devices": P, "platform": jax.devices()[0].platform,
           "cpu_count": os.cpu_count() or 1}

    # -- bit-identity: sharded == single-device, both field arithmetics ----
    # Small instance, every trial compared on best_energy AND best_m.  This
    # is the contract that makes the throughput rows comparable at all.
    small = gset.toroidal_grid(1024, seed=7, name="bitid")
    hp_id = SSAHyperParams(n_trials=2, m_shot=2, tau=3, i0_min=1, i0_max=4)
    bit_identity = {}
    for label, plain_opts, shard_opts in (
        ("tiled", {"j_mode": "tiled"}, {}),
        ("popcount", {"field_mode": "popcount"}, {"field_mode": "popcount"}),
    ):
        ref = anneal(small, hp_id, seed=3, backend="dense", noise="xorshift",
                     backend_opts=plain_opts)
        sh = anneal(small, hp_id, seed=3, backend="dense", noise="xorshift",
                    backend_opts={"partition": "spin", "mesh": mesh,
                                  **shard_opts})
        same = (np.array_equal(ref.best_energy, sh.best_energy)
                and np.array_equal(ref.best_m, sh.best_m))
        bit_identity[label] = bool(same)
        if not same:
            print(f"BIT-IDENTITY FAILURE ({label}, P={P})", file=sys.stderr)
    out["bit_identity"] = bit_identity

    # -- weak-scaling throughput: N = P * n_per_dev ------------------------
    n = P * args.n_per_dev
    model = gset.toroidal_grid(n, seed=11, name=f"scale{n}").to_ising()
    hp = SSAHyperParams(n_trials=args.n_trials, m_shot=1, tau=args.tau,
                        i0_min=1, i0_max=args.i0_max)
    plateaus = schedule_plateaus(hp.schedule("hassa"))
    cycles = sum(p.length for p in plateaus)
    bk = make_backend("dense", model, n_trials=hp.n_trials, n_rnd=hp.n_rnd,
                      noise="xorshift", partition="spin", mesh=mesh)
    state = bk.init_state(0)
    out["max_device_bytes"] = memory.max_device_bytes(
        (bk._problem, state)
    )
    chain = jax.jit(
        lambda s: run_schedule(bk, plateaus, s, record="best",
                               track_energy=False)[0]
    )
    us = time_call(chain, state, warmup=1, iters=3)
    out["n"] = n
    out["wall_us"] = us
    out["spin_cycles_per_s"] = cycles * hp.n_trials * n / (us * 1e-6)

    # -- largest-N row: service rejection + sharded end-to-end solve -------
    if args.big_n:
        from repro.serve import AdmissionError, AnnealRequest, AnnealService

        big = gset.toroidal_grid(args.big_n, seed=5, name="bigN")
        assert big.n > MAX_UNSHARDED_SPINS
        hp_big = SSAHyperParams(**json.loads(args.big_hp))
        rejected = False
        try:
            AnnealService(backend="sparse").solve(
                [AnnealRequest(problem=big, hp=hp_big, seed=1)]
            )
        except AdmissionError:
            rejected = True
        resp = AnnealService(
            backend="sparse", partition="spin", mesh=mesh
        ).solve([AnnealRequest(problem=big, hp=hp_big, seed=1)])[0]
        out["largest_n"] = {
            "n": int(big.n),
            "bucket": int(resp.bucket),
            "single_device_rejected": rejected,
            "status": resp.status,
            "best_cut": int(np.max(np.asarray(resp.result.best_cut))),
        }
    print("RESULT_JSON:" + json.dumps(out))


# ---------------------------------------------------------------------------
# Parent: one subprocess per device count, then aggregate + gate
# ---------------------------------------------------------------------------
def _spawn(devices: int, cfg: dict, big_n: int) -> dict:
    env = dict(os.environ)
    # Workers import repro regardless of how the parent found it.
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(root, "src"), root, env.get("PYTHONPATH"))
        if p
    )
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices} "
        + env.get("XLA_FLAGS", "").replace(
            "--xla_force_host_platform_device_count=1", ""
        )
    ).strip()
    cmd = [sys.executable, "-m", "benchmarks.scale", "--worker",
           "--devices", str(devices),
           "--n-per-dev", str(cfg["n_per_dev"]),
           "--n-trials", str(cfg["n_trials"]),
           "--tau", str(cfg["tau"]), "--i0-max", str(cfg["i0_max"]),
           "--big-n", str(big_n),
           "--big-hp", json.dumps(cfg["big_hp"])]
    proc = subprocess.run(cmd, env=env, capture_output=True, text=True,
                          timeout=3600)
    for line in proc.stdout.splitlines():
        if line.startswith("RESULT_JSON:"):
            return json.loads(line[len("RESULT_JSON:"):])
    raise RuntimeError(
        f"worker P={devices} produced no result\n--- stdout ---\n"
        f"{proc.stdout[-2000:]}\n--- stderr ---\n{proc.stderr[-2000:]}"
    )


def run(smoke: bool = False, json_path: str = "BENCH_scale.json",
        gate: bool = False):
    from .common import emit

    cfg = SMOKE if smoke else FULL
    rows, failures = [], []
    for i, p in enumerate(cfg["devices"]):
        # The largest-N service row runs once, on the widest mesh.
        big_n = cfg["big_n"] if p == max(cfg["devices"]) else 0
        row = _spawn(p, cfg, big_n)
        rows.append(row)
        for label, ok in row["bit_identity"].items():
            if not ok:
                failures.append(f"P={p}: sharded != single-device ({label})")
        emit(
            f"scale/P{p}/n{row['n']}", row["wall_us"],
            f"scs={row['spin_cycles_per_s']:.3e};"
            f"max_dev_bytes={row['max_device_bytes']};"
            f"bit_identity={all(row['bit_identity'].values())}",
        )
    base = rows[0]["spin_cycles_per_s"]
    for row in rows:
        row["weak_scaling_speedup"] = row["spin_cycles_per_s"] / base

    platform = rows[0]["platform"]
    cpu_count = rows[0]["cpu_count"]
    emulation = platform == "cpu" and cpu_count < 2 * 2
    two = next((r for r in rows if r["devices"] == 2), None)
    speedup2 = two["weak_scaling_speedup"] if two else None
    required = GATE_EMULATION_FLOOR if emulation else GATE_SPEEDUP_2DEV
    if gate and speedup2 is not None and speedup2 < required:
        failures.append(
            f"2-device weak-scaling speedup {speedup2:.2f}x < required "
            f"{required}x ({'CPU-emulation floor' if emulation else 'hardware gate'})"
        )
    big = next((r["largest_n"] for r in rows if "largest_n" in r), None)
    if gate and big is not None:
        if not big["single_device_rejected"]:
            failures.append("largest-N instance was NOT rejected unsharded")
        if big["status"] != "ok":
            failures.append(f"largest-N sharded solve status={big['status']}")

    report = {
        "smoke": smoke,
        "platform": platform,
        "cpu_count": cpu_count,
        "emulation": emulation,
        "gate": {"speedup_2dev_hardware": GATE_SPEEDUP_2DEV,
                 "speedup_2dev_emulation_floor": GATE_EMULATION_FLOOR,
                 "required": required, "measured_2dev": speedup2,
                 "enforced": gate, "failures": failures},
        "weak_scaling": rows,
        "largest_n": big,
    }
    with open(json_path, "w") as f:
        json.dump(report, f, indent=2)
    emit("scale/speedup_2dev", 0.0,
         f"{speedup2:.2f}x (required {required}x, "
         f"{'emulation' if emulation else 'hardware'})" if speedup2 else "n/a")
    emit("scale/gate", 0.0, "PASS" if not failures else ";".join(failures))
    return report, failures


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sizes + device counts (CI smoke cell)")
    ap.add_argument("--gate", action="store_true",
                    help="exit 1 on bit-identity/speedup/largest-N failure")
    ap.add_argument("--json", default="BENCH_scale.json")
    # worker-mode flags (internal)
    ap.add_argument("--worker", action="store_true", help=argparse.SUPPRESS)
    ap.add_argument("--devices", type=int, default=0, help=argparse.SUPPRESS)
    ap.add_argument("--n-per-dev", type=int, dest="n_per_dev",
                    default=512, help=argparse.SUPPRESS)
    ap.add_argument("--n-trials", type=int, dest="n_trials", default=2,
                    help=argparse.SUPPRESS)
    ap.add_argument("--tau", type=int, default=4, help=argparse.SUPPRESS)
    ap.add_argument("--i0-max", type=int, dest="i0_max", default=4,
                    help=argparse.SUPPRESS)
    ap.add_argument("--big-n", type=int, dest="big_n", default=0,
                    help=argparse.SUPPRESS)
    ap.add_argument("--big-hp", dest="big_hp", default="{}",
                    help=argparse.SUPPRESS)
    args = ap.parse_args()
    if args.worker:
        return _worker(args)
    _, failures = run(smoke=args.smoke, json_path=args.json, gate=args.gate)
    if failures:
        print("GATE FAILURES:")
        for f in failures:
            print("  -", f)
        sys.exit(1)


if __name__ == "__main__":
    main()
