"""Pallas kernel micro-benchmarks (interpret mode on CPU; TPU is the target).

Reports wall time of the interpret-mode kernels (correctness path), the
dense-matmul JAX fallback, and the plateau-engine dispatch path (one
`pallas_call` per plateau), plus the TPU roofline projection for the
resident kernel (the number that matters for deployment).

    PYTHONPATH=src python -m benchmarks.kernel_bench [--smoke]

``--smoke`` runs a seconds-scale configuration (small instance, one
plateau) — the CI correctness/latency canary.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SolverConfig, SSAHyperParams, anneal, gset
from repro.kernels import ref, ssa_update

from .common import emit, time_call


def run(csv_prefix: str = "kernels", smoke: bool = False):
    if smoke:
        p = gset.toroidal_grid(64, seed=17)
        R, C = 4, 4
    else:
        p = gset.load("G11")
        R, C = 8, 4
    model = p.to_ising()
    N = model.n
    J = jnp.asarray(model.dense_J(), jnp.float32)
    h = jnp.asarray(model.h, jnp.int32)
    rng = np.random.default_rng(0)
    m = jnp.asarray(rng.choice([-1.0, 1.0], size=(R, N)).astype(np.float32))
    it = jnp.zeros((R, N), jnp.int32)
    noise = jnp.asarray(rng.choice([-1, 1], size=(C, R, N)).astype(np.int8))
    bH = jnp.full((R,), 2**30, jnp.int32)
    bm = m.astype(jnp.int8)

    us = time_call(lambda: ref.local_field_ref(m, h, J))
    emit(f"{csv_prefix}/local_field_jnp", us, f"R={R};N={N}")
    us = time_call(
        lambda: ssa_update.local_field(m, h, J, block_r=8, block_n=128, block_k=128)
    )
    emit(f"{csv_prefix}/local_field_pallas_interp", us, "interpret=True")

    us = time_call(
        lambda: ssa_update.ssa_plateau(m, it, J, h, noise, jnp.int32(8), bH, bm,
                                       n_rnd=2, eligible=True, block_r=8)
    )
    emit(f"{csv_prefix}/ssa_plateau_pallas_interp", us, f"C={C}_cycles_fused")

    # Engine dispatch path: anneal(backend='pallas') — one pallas_call per
    # plateau, driven through the plateau engine (smoke-scale correctness +
    # launch-overhead canary; the G-set twins make it hermetic).
    hp = SSAHyperParams(n_trials=R, m_shot=1, tau=C, i0_min=1, i0_max=4)
    t0 = time.perf_counter()
    r = anneal(p, hp, seed=0, config=SolverConfig(backend="pallas"),
               track_energy=False)
    dt = time.perf_counter() - t0
    emit(f"{csv_prefix}/engine_pallas_backend", dt * 1e6,
         f"plateaus={hp.steps};best={r.overall_best_cut}")

    # TPU v5e projection for the resident kernel (per cycle, per chip):
    flops = 2 * R * N * N
    t_mxu = flops / 197e12
    hbm = R * N * (1 + 4 + 4)  # noise + state rw (J resident in VMEM)
    t_mem = hbm / 819e9
    emit(f"{csv_prefix}/resident_tpu_model_per_cycle", 0.0,
         f"t_compute={t_mxu*1e9:.1f}ns;t_memory={t_mem*1e9:.1f}ns;"
         f"bound={'compute' if t_mxu > t_mem else 'memory'}")
    # non-resident comparison: J re-read from HBM each cycle
    t_mem_nores = (hbm + 2 * N * N) / 819e9
    emit(f"{csv_prefix}/nonresident_tpu_model_per_cycle", 0.0,
         f"t_memory={t_mem_nores*1e9:.1f}ns;residency_gain="
         f"{t_mem_nores/max(t_mem, t_mxu):.1f}x")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale CI configuration")
    args = ap.parse_args()
    run(smoke=args.smoke)
