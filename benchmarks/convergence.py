"""Paper Fig. 7 / Fig. 9: average Ising energy vs cycles, HA-SSA vs SSA vs SA.

Derived quantities reproduce the paper's headline claims:
  * cycles for HA-SSA to reach 96% of the best energy found, vs cycles for
    SA to reach the same energy → the "58–114× faster" convergence claim;
  * HA-SSA ≡ SSA traces (identical update path).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import SAHyperParams, SSAHyperParams, anneal, anneal_sa, gset

from .common import emit


def run(problems=("G11", "G12", "G13"), trials: int = 8, m_shot: int = 20,
        backend: str = "sparse", csv_prefix: str = "fig7_convergence"):
    """Reduced-scale by default (full: trials=100, m_shot=150)."""
    rows = {}
    for name in problems:
        p = gset.load(name)
        hp = SSAHyperParams(n_trials=trials, m_shot=m_shot)
        cycles = hp.total_cycles

        t0 = time.perf_counter()
        r_ha = anneal(p, hp, seed=0, storage="i0max", noise="xorshift",
                      backend=backend)
        t_ha = (time.perf_counter() - t0) * 1e6

        t0 = time.perf_counter()
        r_ssa = anneal(p, hp, seed=0, storage="all", schedule_kind="ssa",
                       noise="xorshift", backend=backend)
        t_ssa = (time.perf_counter() - t0) * 1e6

        t0 = time.perf_counter()
        r_sa = anneal_sa(p, SAHyperParams(n_trials=trials, n_cycles=cycles), seed=0)
        t_sa = (time.perf_counter() - t0) * 1e6

        # target: 96% of HA-SSA's best mean energy (the paper's yardstick)
        e_ha = r_ha.energy_mean
        e_sa = r_sa.energy_mean
        target = 0.96 * e_ha.min()
        c_ha = int(np.argmax(e_ha <= target) + 1) if (e_ha <= target).any() else cycles
        c_sa = int(np.argmax(e_sa <= target) + 1) if (e_sa <= target).any() else cycles
        speedup = c_sa / max(c_ha, 1)

        emit(f"{csv_prefix}/{name}/hassa", t_ha,
             f"best_cut={r_ha.overall_best_cut};mean_cut={r_ha.mean_best_cut:.1f};"
             f"cycles_to_96pct={c_ha}")
        emit(f"{csv_prefix}/{name}/ssa", t_ssa,
             f"best_cut={r_ssa.overall_best_cut};mean_cut={r_ssa.mean_best_cut:.1f}")
        emit(f"{csv_prefix}/{name}/sa", t_sa,
             f"best_cut={r_sa.overall_best_cut};mean_cut={r_sa.mean_best_cut:.1f};"
             f"cycles_to_96pct={c_sa}")
        emit(f"{csv_prefix}/{name}/speedup_vs_sa", 0.0,
             f"convergence_speedup={speedup:.1f}x")
        rows[name] = dict(speedup=speedup, ha=r_ha, sa=r_sa, ssa=r_ssa)
    return rows


if __name__ == "__main__":
    run()
