"""Paper Fig. 12 (Sec. VI-A): SA vs SSA/HA-SSA under *equivalent* temperature
control over a short 15,000-cycle window.

SSA's pseudo-inverse temperature rises 1→32 per 600-cycle iteration; the
equivalent SA ladder *decreases* 1 → 1/32 on the same cadence.  The paper's
point: SA cannot reach the near-optimum in the window, SSA/HA-SSA converge
within ~3,000 cycles.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import SAHyperParams, SSAHyperParams, anneal, anneal_sa, gset

from .common import emit


def run(problem: str = "G11", trials: int = 8, window: int = 15_000,
        csv_prefix: str = "fig12_equal_temp"):
    p = gset.load(problem)
    hp = SSAHyperParams(n_trials=trials, m_shot=-(-window // 600))
    t0 = time.perf_counter()
    r_ha = anneal(p, hp, seed=0, total_cycles=window, noise="xorshift")
    t_ha = (time.perf_counter() - t0) * 1e6

    period = np.repeat(1.0 / np.array([1, 2, 4, 8, 16, 32], np.float32), hp.tau)
    temps = np.tile(period, -(-window // len(period)))[:window]
    r_sa = anneal_sa(
        p, SAHyperParams(n_trials=trials, n_cycles=window), seed=0,
        temperatures=temps,
    )
    # cycles to reach within 2% of HA-SSA's best mean energy
    tgt = 0.98 * r_ha.energy_mean.min()
    hit = (r_ha.energy_mean <= tgt).argmax() + 1
    emit(f"{csv_prefix}/{problem}/hassa", t_ha,
         f"mean_cut={r_ha.mean_best_cut:.1f};cycles_to_98pct={int(hit)}")
    emit(f"{csv_prefix}/{problem}/sa_equal_temp", 0.0,
         f"mean_cut={r_sa.mean_best_cut:.1f}")
    emit(f"{csv_prefix}/{problem}/hassa_advantage", 0.0,
         f"{r_ha.mean_best_cut - r_sa.mean_best_cut:+.1f}_cut")
    return dict(ha=r_ha, sa=r_sa)


if __name__ == "__main__":
    run()
