"""Algorithm-family comparisons on dense instances.

Two entry points share this module:

* :func:`run` — paper Table VII: HA-SSA vs parallel tempering (IPAPT-class
  baseline) at matched cycle budgets.  The paper: IPAPT reaches best-known
  G11 with avg 561 in 2.64 ms; HA-SSA reaches best-known with avg 558 in
  1.00 ms (2.64x faster).

* :func:`run_ssqa` — the PR-10 gate: SSQA vs SSA *time-to-target* on the
  K2000-class dense instance (DESIGN.md §13).  Both families get their
  hyper-parameters from the same autotuner (:mod:`repro.core.autotune` —
  SSQA additionally gets its Trotter depth and J⊥ ramp from the local-field
  σ), run at equal ``n_trials`` × ``total_cycles`` on the same dense
  backend with the same noise generator, so the comparison is compute-fair:
  the replica ring is the only difference.  Per seed, the target cut is
  ``TARGET_FRAC`` × the weaker family's final best (a self-normalizing
  time-to-quality bar); cycles-to-target comes from the deterministic
  per-cycle energy trace and is converted to wall time with each family's
  measured steady-state seconds/cycle.  Results land in ``BENCH_ssqa.json``;
  ``--gate`` at full size enforces

      time-to-target(SSA) / time-to-target(SSQA) >= GATE_TT_MIN (1x)

  i.e. SSQA must reach the shared quality bar at least as fast as SSA.
  ``--smoke`` shrinks the instance below the quality-saturation point where
  time-to-target stops discriminating, so the smoke cell only checks that
  both families reach the target at all.
"""
from __future__ import annotations

import json
import time

import jax
import numpy as np

from repro.core import (
    PTHyperParams,
    SolverConfig,
    SSAHyperParams,
    anneal,
    anneal_pt,
    gset,
)
from repro.core.autotune import resolve_hyperparams
from repro.core.engine import make_backend, run_schedule, schedule_plateaus
from repro.core.ssqa import SSQAHyperParams

from .common import emit, time_call

# SSQA-vs-SSA time-to-target gate (--gate, full size only).
GATE_TT_MIN = 1.0    # required tt(SSA)/tt(SSQA) speedup on K2000-class
TARGET_FRAC = 0.99   # per-seed quality bar: frac of the weaker final best
SSQA_SEEDS = (0, 1, 2)

# Budget knobs (the autotuner derives the energy-scale knobs and the SSQA
# Trotter dimension from the instance's local-field distribution).  K2000
# is the paper's dense benchmark; smoke shrinks it so a CI cell finishes
# in seconds.
FULL_SPEC = {"name": "K2000", "n": 2000, "n_trials": 16, "m_shot": 2}
SMOKE_SPEC = {"name": "K256", "n": 256, "n_trials": 16, "m_shot": 2}


def run(problem: str = "G11", trials: int = 8, m_shot: int = 15,
        csv_prefix: str = "table7_pt"):
    p = gset.load(problem)
    hp = SSAHyperParams(n_trials=trials, m_shot=m_shot)
    cycles = hp.total_cycles

    t0 = time.perf_counter()
    r_ha = anneal(p, hp, seed=0, track_energy=False, config=SolverConfig())
    t_ha = time.perf_counter() - t0

    t0 = time.perf_counter()
    r_pt = anneal_pt(p, PTHyperParams(n_replicas=8, n_cycles=cycles), seed=0,
                     track_energy=False)
    t_pt = time.perf_counter() - t0

    emit(f"{csv_prefix}/{problem}/hassa", t_ha * 1e6,
         f"best={r_ha.overall_best_cut};avg={r_ha.mean_best_cut:.1f}")
    emit(f"{csv_prefix}/{problem}/pt", t_pt * 1e6, f"best={r_pt.best_cut}")
    emit(f"{csv_prefix}/{problem}/hassa_vs_pt_cut", 0.0,
         f"{r_ha.overall_best_cut - r_pt.best_cut:+d}")
    return dict(ha=r_ha, pt=r_pt, t_ha=t_ha, t_pt=t_pt)


# ---------------------------------------------------------------------------
# SSQA vs SSA time-to-target (DESIGN.md §13)
# ---------------------------------------------------------------------------
def _cut_trace(p, hp, seed: int, cfg: SolverConfig) -> np.ndarray:
    """Best-so-far cut per cycle (deterministic; dense-backend scan path)."""
    r = anneal(p, hp, seed=seed, config=cfg, track_energy=True)
    best_h = np.minimum.accumulate(np.asarray(r.energy_min))
    return (p.w_total - best_h) // 2


def _s_per_cycle(model, hp) -> float:
    """Steady-state seconds per annealing cycle (compile excluded)."""
    plateaus = schedule_plateaus(hp.schedule("hassa"))
    cycles = sum(pl.length for pl in plateaus)
    opts = {}
    nr = int(getattr(hp, "n_replicas", 0) or 0)
    if nr:
        opts["n_replicas"] = nr
    bk = make_backend("dense", model, n_trials=hp.n_trials, n_rnd=hp.n_rnd,
                      noise="xorshift", **opts)
    state = bk.init_state(0)
    chain = jax.jit(
        lambda s: run_schedule(bk, plateaus, s, record="best",
                               track_energy=False)[0]
    )
    # The tt gate divides two of these, so noise matters: median of 7 warm
    # calls (the deterministic cycles-to-target term carries the signal).
    us = time_call(chain, state, warmup=2, iters=7)
    return us * 1e-6 / cycles


def run_ssqa(
    smoke: bool = False,
    json_path: str = "BENCH_ssqa.json",
    gate: bool = False,
    csv_prefix: str = "ssqa",
):
    """SSQA-vs-SSA time-to-target bench; returns (report, failures)."""
    spec = SMOKE_SPEC if smoke else FULL_SPEC
    p = gset.complete_graph(spec["n"], seed=2000, name=spec["name"])
    cfg = SolverConfig(backend="dense")

    budget = dict(n_trials=spec["n_trials"], m_shot=spec["m_shot"])
    hp_ssa, _ = resolve_hyperparams("auto", p, base=SSAHyperParams(**budget))
    hp_ssqa, _ = resolve_hyperparams(
        "auto", p, base=SSQAHyperParams(**budget), algo="ssqa")
    hps = {"ssa": hp_ssa, "ssqa": hp_ssqa}

    failures = []
    seeds = []
    ctt = {"ssa": [], "ssqa": []}
    finals = {"ssa": [], "ssqa": []}
    for seed in SSQA_SEEDS:
        tr = {a: _cut_trace(p, hps[a], seed, cfg) for a in ("ssa", "ssqa")}
        target = int(
            TARGET_FRAC * min(int(tr["ssa"][-1]), int(tr["ssqa"][-1]))
        )
        row = {"seed": seed, "target_cut": target}
        for algo in ("ssa", "ssqa"):
            reached = tr[algo] >= target
            if not reached.any():
                failures.append(
                    f"{algo} seed {seed}: never reached target {target}")
                continue
            c = int(np.argmax(reached)) + 1
            ctt[algo].append(c)
            finals[algo].append(int(tr[algo][-1]))
            row[algo] = {
                "final_cut": int(tr[algo][-1]), "cycles_to_target": c,
            }
        seeds.append(row)

    model = p.to_ising()
    summary = {}
    for algo in ("ssa", "ssqa"):
        spc = _s_per_cycle(model, hps[algo])
        mean_ctt = float(np.mean(ctt[algo])) if ctt[algo] else float("nan")
        summary[algo] = {
            "hp": repr(hps[algo]),
            "mean_cycles_to_target": mean_ctt,
            "s_per_cycle": spc,
            "time_to_target_s": mean_ctt * spc,
            "best_final_cut": max(finals[algo]) if finals[algo] else None,
        }
    tt_speedup = (summary["ssa"]["time_to_target_s"]
                  / summary["ssqa"]["time_to_target_s"])
    # The 1x bar applies at full size only: at smoke size both families
    # saturate the instance early and cycles-to-target is decided by noise.
    if gate and not smoke and not (tt_speedup >= GATE_TT_MIN):
        failures.append(
            f"{spec['name']}: SSQA time-to-target speedup {tt_speedup:.2f}x "
            f"< required {GATE_TT_MIN}x"
        )

    for algo in ("ssa", "ssqa"):
        s = summary[algo]
        emit(
            f"{csv_prefix}/{spec['name']}/{algo}",
            s["time_to_target_s"] * 1e6,
            f"mean_ctt={s['mean_cycles_to_target']:.0f}cyc;"
            f"s_per_cycle={s['s_per_cycle']:.2e};"
            f"best_final={s['best_final_cut']}",
        )
    emit(f"{csv_prefix}/{spec['name']}/tt_speedup", 0.0, f"{tt_speedup:.2f}")

    report = {
        "smoke": smoke,
        "instance": {"name": spec["name"], "n": spec["n"]},
        "target_frac": TARGET_FRAC,
        "seeds": seeds,
        "ssa": summary["ssa"],
        "ssqa": summary["ssqa"],
        "tt_speedup": tt_speedup,
        "gate": {"min_tt_speedup": GATE_TT_MIN,
                 "enforced": bool(gate and not smoke),
                 "failures": failures},
    }
    with open(json_path, "w") as f:
        json.dump(report, f, indent=2)
    emit(f"{csv_prefix}/gate", 0.0,
         "PASS" if not failures else ";".join(failures))
    return report, failures


if __name__ == "__main__":
    import argparse
    import sys

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced instance size (CI smoke cell)")
    ap.add_argument("--gate", action="store_true",
                    help="exit 1 if the SSQA time-to-target gate fails")
    ap.add_argument("--json", default="BENCH_ssqa.json")
    ap.add_argument("--table7", action="store_true",
                    help="emit the paper Table VII HA-SSA-vs-PT rows instead")
    args = ap.parse_args()
    if args.table7:
        run()
        sys.exit(0)
    _, failures = run_ssqa(smoke=args.smoke, json_path=args.json,
                           gate=args.gate)
    if failures:
        print("GATE FAILURES:")
        for f in failures:
            print("  -", f)
        sys.exit(1)
