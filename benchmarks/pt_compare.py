"""Paper Table VII: HA-SSA vs parallel tempering (IPAPT-class baseline).

The paper: IPAPT reaches best-known G11 with avg 561 in 2.64 ms; HA-SSA
reaches best-known with avg 558 in 1.00 ms (2.64× faster).  We compare the
algorithms at matched cycle budgets on the same instance.
"""
from __future__ import annotations

import time

from repro.core import PTHyperParams, SSAHyperParams, anneal, anneal_pt, gset

from .common import emit


def run(problem: str = "G11", trials: int = 8, m_shot: int = 15,
        csv_prefix: str = "table7_pt"):
    p = gset.load(problem)
    hp = SSAHyperParams(n_trials=trials, m_shot=m_shot)
    cycles = hp.total_cycles

    t0 = time.perf_counter()
    r_ha = anneal(p, hp, seed=0, track_energy=False, noise="xorshift")
    t_ha = time.perf_counter() - t0

    t0 = time.perf_counter()
    r_pt = anneal_pt(p, PTHyperParams(n_replicas=8, n_cycles=cycles), seed=0,
                     track_energy=False)
    t_pt = time.perf_counter() - t0

    emit(f"{csv_prefix}/{problem}/hassa", t_ha * 1e6,
         f"best={r_ha.overall_best_cut};avg={r_ha.mean_best_cut:.1f}")
    emit(f"{csv_prefix}/{problem}/pt", t_pt * 1e6, f"best={r_pt.best_cut}")
    emit(f"{csv_prefix}/{problem}/hassa_vs_pt_cut", 0.0,
         f"{r_ha.overall_best_cut - r_pt.best_cut:+d}")
    return dict(ha=r_ha, pt=r_pt, t_ha=t_ha, t_pt=t_pt)


if __name__ == "__main__":
    run()
