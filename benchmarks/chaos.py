"""Chaos suite: the annealing service under injected faults (DESIGN.md §10).

The resilience layer's claims are only worth stating if they are *measured*:
this benchmark drives :class:`~repro.serve.AnnealService` through every
fault class the failure model names — via the
:mod:`repro.ft.faults` injector — and gates on the recovery contracts:

* **kill/resume** — a process killed between chunks, resumed from its
  chunk-level checkpoints, must produce bit-identical best energy/spins to
  an uninterrupted run (all three backends, noise='xorshift');
* **compile fallback** — an injected pallas compile failure must complete
  via the pallas→dense→sparse chain, bit-identical, with the downgrade on
  ``AnnealResponse.status``/``events``;
* **oom→tiled** — an injected dense-J OOM must re-enter as tiled-J on the
  same backend, bit-identical;
* **nan quarantine** — a NaN burst on one batch slot must quarantine only
  that request (solo retry) while its batchmate stays bit-exact;
* **deadline** — an expired per-request deadline must return best-so-far
  with ``status='deadline'`` instead of raising;
* **chaos schedules** — seeded random fault plans
  (:func:`repro.ft.faults.chaos_schedule`) must all end in served
  responses, every produced result bit-identical to the fault-free run.

Writes ``BENCH_chaos.json`` and exits 1 if any gate fails.

    python -m benchmarks.chaos            # full sweep (nightly)
    python -m benchmarks.chaos --smoke    # CI: fewer seeds, smaller budgets
"""
from __future__ import annotations

import argparse
import json
import sys
import tempfile
import time

import numpy as np

from repro.core import SSAHyperParams, gset
from repro.ft.faults import FaultInjector, InjectedKill, chaos_schedule
from repro.serve import AnnealRequest, AnnealService, ResiliencePolicy

from .common import emit

BACKENDS = ("sparse", "dense", "pallas")


def _problems(smoke):
    n = 36 if smoke else 100
    return (gset.toroidal_grid(n, seed=0, name=f"t{n}"),
            gset.king_graph(n, seed=3, name=f"k{n}"))


def _hp(smoke):
    return (SSAHyperParams(n_trials=3, m_shot=6, tau=4, i0_min=1, i0_max=8)
            if smoke else SSAHyperParams(n_trials=8, m_shot=10))


def _requests(problems, hp, **kw):
    return [AnnealRequest(problem=p, hp=hp, seed=i + 1, **kw)
            for i, p in enumerate(problems)]


def _bit_identical(a, b):
    return (np.array_equal(a.result.best_energy, b.result.best_energy)
            and np.array_equal(a.result.best_m, b.result.best_m))


def run(smoke: bool = False, json_path: str = "BENCH_chaos.json",
        csv_prefix: str = "chaos"):
    problems, hp = _problems(smoke), _hp(smoke)
    failures = []
    report = {"smoke": smoke, "scenarios": {}}
    baseline = {
        b: AnnealService(backend=b, min_bucket=16).solve(_requests(problems, hp))
        for b in BACKENDS
    }

    # -- kill at a chunk boundary, resume from checkpoints ---------------
    for backend in BACKENDS:
        t0 = time.perf_counter()
        with tempfile.TemporaryDirectory() as d:
            pol = ResiliencePolicy(checkpoint_dir=d)
            inj = FaultInjector()
            inj.arm("kill", chunk=2)
            svc = AnnealService(backend=backend, min_bucket=16,
                                resilience=pol, faults=inj)
            killed = False
            try:
                svc.solve(_requests(problems, hp))
            except InjectedKill:
                killed = True
            resumed = AnnealService(backend=backend, min_bucket=16,
                                    resilience=pol).solve(_requests(problems, hp))
        identical = all(_bit_identical(a, b)
                        for a, b in zip(baseline[backend], resumed))
        resumed_from = [e.detail.get("chunk") for r in resumed
                        for e in r.events if e.kind == "resume"]
        ok = killed and identical and bool(resumed_from)
        report["scenarios"][f"kill_resume_{backend}"] = {
            "killed": killed, "bit_identical": identical,
            "resumed_from_chunk": resumed_from[:1], "ok": ok,
        }
        emit(f"{csv_prefix}/kill_resume/{backend}",
             (time.perf_counter() - t0) * 1e6, f"bit_identical={identical}")
        if not ok:
            failures.append(f"kill_resume[{backend}]: killed={killed} "
                            f"bit_identical={identical} resume={resumed_from}")

    # -- injected pallas compile failure → fallback chain ----------------
    inj = FaultInjector()
    inj.arm("compile", backend="pallas")
    svc = AnnealService(backend="pallas", min_bucket=16, faults=inj)
    t0 = time.perf_counter()
    resp = svc.solve(_requests(problems, hp))
    hops = [(e.detail["from"], e.detail["to"])
            for e in resp[0].events if e.kind == "fallback"]
    identical = all(_bit_identical(a, b)
                    for a, b in zip(baseline["pallas"], resp))
    ok = (all(r.status == "fallback" for r in resp)
          and hops == [("pallas", "dense")] and identical)
    report["scenarios"]["compile_fallback"] = {
        "statuses": [r.status for r in resp], "hops": hops,
        "bit_identical": identical, "ok": ok,
    }
    emit(f"{csv_prefix}/compile_fallback", (time.perf_counter() - t0) * 1e6,
         f"hops={hops}")
    if not ok:
        failures.append(f"compile_fallback: statuses="
                        f"{[r.status for r in resp]} hops={hops} "
                        f"bit_identical={identical}")

    # -- injected dense-J OOM → tiled-J downgrade ------------------------
    inj = FaultInjector()
    inj.arm("oom", backend="dense", j_mode="dense")
    svc = AnnealService(backend="dense", min_bucket=16, faults=inj)
    t0 = time.perf_counter()
    resp = svc.solve(_requests(problems, hp))
    to_opts = [e.detail["to_opts"] for e in resp[0].events
               if e.kind == "fallback"]
    identical = all(_bit_identical(a, b)
                    for a, b in zip(baseline["dense"], resp))
    ok = (all(r.status == "fallback" for r in resp) and identical
          and to_opts and to_opts[0].get("j_mode") == "tiled")
    report["scenarios"]["oom_tiled"] = {
        "statuses": [r.status for r in resp], "to_opts": to_opts,
        "bit_identical": identical, "ok": ok,
    }
    emit(f"{csv_prefix}/oom_tiled", (time.perf_counter() - t0) * 1e6,
         f"to_opts={to_opts}")
    if not ok:
        failures.append(f"oom_tiled: to_opts={to_opts} "
                        f"bit_identical={identical}")

    # -- NaN burst → quarantine, batchmate bit-exact ---------------------
    inj = FaultInjector()
    inj.arm("nan", chunk=1, slots=(1,))
    svc = AnnealService(backend="sparse", min_bucket=16, faults=inj)
    t0 = time.perf_counter()
    resp = svc.solve(_requests(problems, hp))
    mate_exact = _bit_identical(baseline["sparse"][0], resp[0])
    ok = (resp[0].status == "ok" and mate_exact
          and resp[1].status == "quarantined" and resp[1].result is not None)
    report["scenarios"]["nan_quarantine"] = {
        "statuses": [r.status for r in resp],
        "batchmate_bit_exact": mate_exact, "ok": ok,
    }
    emit(f"{csv_prefix}/nan_quarantine", (time.perf_counter() - t0) * 1e6,
         f"statuses={[r.status for r in resp]}")
    if not ok:
        failures.append(f"nan_quarantine: statuses={[r.status for r in resp]} "
                        f"batchmate_exact={mate_exact}")

    # -- deadline expiry → best-so-far, never raises ---------------------
    svc = AnnealService(backend="sparse", min_bucket=16)
    t0 = time.perf_counter()
    resp = svc.solve(_requests(problems, hp, deadline_s=1e-9))
    ok = (all(r.status == "deadline" for r in resp)
          and all(r.result is not None for r in resp)
          and all(r.chunks_run < r.chunks_total for r in resp))
    report["scenarios"]["deadline"] = {
        "statuses": [r.status for r in resp],
        "chunks": [(r.chunks_run, r.chunks_total) for r in resp], "ok": ok,
    }
    emit(f"{csv_prefix}/deadline", (time.perf_counter() - t0) * 1e6,
         f"chunks={[r.chunks_run for r in resp]}")
    if not ok:
        failures.append(f"deadline: statuses={[r.status for r in resp]}")

    # -- seeded chaos schedules ------------------------------------------
    n_seeds = 6 if smoke else 24
    survived = 0
    t0 = time.perf_counter()
    for seed in range(n_seeds):
        with tempfile.TemporaryDirectory() as d:
            pol = ResiliencePolicy(checkpoint_dir=d)
            svc = AnnealService(backend="pallas", min_bucket=16,
                                resilience=pol, faults=chaos_schedule(seed))
            try:
                resp = svc.solve(_requests(problems, hp))
            except InjectedKill:
                resp = AnnealService(backend="pallas", min_bucket=16,
                                     resilience=pol).solve(
                    _requests(problems, hp))
            # Quarantined responses retried with a re-autotuned I0max —
            # a *different valid run*, so they are exempt from bit-identity.
            good = all(
                (r.result is not None if r.status == "quarantined"
                 else _bit_identical(b, r))
                for b, r in zip(baseline["pallas"], resp)
            )
            survived += bool(good and len(resp) == len(problems))
    ok = survived == n_seeds
    report["scenarios"]["chaos_schedules"] = {
        "seeds": n_seeds, "survived": survived, "ok": ok,
    }
    emit(f"{csv_prefix}/chaos_schedules", (time.perf_counter() - t0) * 1e6,
         f"survived={survived}/{n_seeds}")
    if not ok:
        failures.append(f"chaos_schedules: survived {survived}/{n_seeds}")

    report["failures"] = failures
    report["ok"] = not failures
    if json_path:
        with open(json_path, "w") as f:
            json.dump(report, f, indent=2)
        print(f"wrote {json_path}")
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI: fewer chaos seeds and smaller budgets")
    ap.add_argument("--json", default="BENCH_chaos.json")
    args = ap.parse_args()
    rep = run(smoke=args.smoke, json_path=args.json)
    if not rep["ok"]:
        for f in rep["failures"]:
            print(f"FAIL: {f}", file=sys.stderr)
        sys.exit(1)
