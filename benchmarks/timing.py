"""Paper Table V: annealing time, HA-SSA hardware vs SA (CPU) — plus the
serving-layer throughput benchmark.

The paper's FPGA does 90,000 cycles at 100 MHz = 0.9 ms.  We report:
  * measured JAX wall-time of the plateau engine per backend
    (spin-cycles/s = cycles × trials × N / s — the acceptance metric for
    the single-contraction-per-cycle engine),
  * the SA baseline at equal cycle budget,
  * the modeled 100 MHz-equivalent (cycles × 10 ns) for comparability,
  * the TPU-projected time from the resident-kernel roofline
    (dense J resident in VMEM: per cycle ≈ max(matmul flops / 197 TF,
    noise+state HBM traffic / 819 GB/s) per chip).

:func:`run_service` benchmarks the shape-bucketed AnnealService against the
pre-service per-request Python loop (one retrace + recompile per request):
aggregate spin-cycles/s and requests/s over a batch of same-bucket
instances, written to ``BENCH_service.json``.  The acceptance bar for the
serving PR is ≥3× aggregate spin-cycles/s on a batch of 8 G11-class
instances.

:func:`run_memory` is the packed-memory-subsystem benchmark: for each
instance it solves through the AnnealService under both storage layouts and
reports **measured** live-buffer bytes/spin next to warm-run spin-cycles/s,
written to ``BENCH_memory.json``.  The dense pallas baseline runs the
legacy pregen datapath (``noise_mode='pregen'``), so the per-plateau
(C, T, N) int8 noise buffer it is charged for — sized from a real
allocation at the run's τ — is one its timed plateaus genuinely
materialize; the packed configuration runs the streamed kernel and holds no
such buffer.  The acceptance bar for the packed-memory PR is a ≥4×
dense/packed live-byte ratio at K2000 and an end-to-end G77 solve with
tiled J (no (N, N) buffer).

    python -m benchmarks.timing                   # Table V rows
    python -m benchmarks.timing --service         # 8×G11-class acceptance run
    python -m benchmarks.timing --service-smoke   # CI: 3 toy instances,
                                                  #     sparse + pallas
    python -m benchmarks.timing --memory          # dense vs packed, G11/K2000/G77
    python -m benchmarks.timing --memory-smoke    # CI: same axes, reduced cycles
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax

from repro.core import SAHyperParams, SSAHyperParams, anneal, anneal_sa, gset, memory

from .common import emit

# The dense/packed live-byte ratio the packed subsystem must hold at K2000.
MEMORY_ACCEPT_RATIO = 4.0


def run(problems=("G11", "King1"), trials: int = 8, m_shot: int = 10,
        backends=("sparse", "dense"), csv_prefix: str = "table5_timing"):
    out = {}
    for name in problems:
        p = gset.load(name)
        hp = SSAHyperParams(n_trials=trials, m_shot=m_shot)
        cycles = hp.total_cycles
        spin_cycles = cycles * trials * p.n

        t_ha = None
        for backend in backends:
            t0 = time.perf_counter()
            r = anneal(p, hp, seed=0, track_energy=False, noise="xorshift",
                       backend=backend)
            t_bk = time.perf_counter() - t0
            emit(f"{csv_prefix}/{name}/hassa_{backend}", t_bk * 1e6,
                 f"best={r.overall_best_cut};avg={r.mean_best_cut:.1f};"
                 f"cycles={cycles};spin_cycles_per_s={spin_cycles/t_bk:.3e}")
            if t_ha is None:
                t_ha = t_bk

        t0 = time.perf_counter()
        r_sa = anneal_sa(
            p, SAHyperParams(n_trials=trials, n_cycles=cycles), seed=0,
            track_energy=False,
        )
        t_sa = time.perf_counter() - t0

        hw_ms = cycles * 10e-9 * 1e3  # 100 MHz FPGA model
        # TPU v5e resident-kernel model (batched trials, one chip):
        n = p.n
        flops_per_cycle = 2 * trials * n * n
        bytes_per_cycle = trials * n * (1 + 4 + 4)  # noise int8 + state rw
        t_tpu = cycles * max(flops_per_cycle / 197e12, bytes_per_cycle / 819e9)

        emit(f"{csv_prefix}/{name}/sa_cpu", t_sa * 1e6,
             f"best={r_sa.overall_best_cut};avg={r_sa.mean_best_cut:.1f}")
        emit(f"{csv_prefix}/{name}/fpga_100mhz_model_ms", 0.0, f"{hw_ms:.2f}")
        emit(f"{csv_prefix}/{name}/tpu_v5e_model_ms", 0.0, f"{t_tpu*1e3:.3f}")
        emit(f"{csv_prefix}/{name}/jax_speedup_vs_sa", 0.0, f"{t_sa/t_ha:.1f}x")
        out[name] = dict(t_ha=t_ha, t_sa=t_sa, hw_ms=hw_ms)
    return out


def run_service(
    n_instances: int = 8,
    trials: int = 8,
    m_shot: int = 2,
    problem_n: int = 800,
    backends=("sparse",),
    csv_prefix: str = "service_timing",
    json_path: str = "BENCH_service.json",
):
    """Batched service vs per-request Python loop, same requests.

    The loop path is the pre-service serving story: each request builds a
    fresh backend and re-traces/re-compiles the whole plateau program.  The
    service path pads every instance to one shape bucket, stacks the batch
    on the problem axis and runs ONE compiled plateau program.
    """
    from repro.serve import AnnealRequest, AnnealService

    problems = [
        gset.toroidal_grid(problem_n, seed=100 + i, name=f"G11c{i}")
        for i in range(n_instances)
    ]
    hp = SSAHyperParams(n_trials=trials, m_shot=m_shot)
    agg_spin_cycles = sum(hp.total_cycles * trials * p.n for p in problems)
    report = {
        "n_instances": n_instances,
        "trials": trials,
        "m_shot": m_shot,
        "problem_n": problem_n,
        "aggregate_spin_cycles": agg_spin_cycles,
        "backends": {},
    }

    for backend in backends:
        # Per-request Python loop (re-trace + re-compile per request).
        t0 = time.perf_counter()
        loop_best = [
            anneal(p, hp, seed=100 + i, noise="xorshift", backend=backend,
                   track_energy=False).overall_best_cut
            for i, p in enumerate(problems)
        ]
        t_loop = time.perf_counter() - t0

        # Shape-bucketed service: one compile per bucket, one device launch.
        svc = AnnealService(backend=backend, noise="xorshift")
        reqs = [
            AnnealRequest(problem=p, hp=hp, seed=100 + i)
            for i, p in enumerate(problems)
        ]
        t0 = time.perf_counter()
        responses = svc.solve(reqs)
        t_svc = time.perf_counter() - t0
        svc_best = [r.result.overall_best_cut for r in responses]

        # The loop and the service run identical padded-invariant math.
        assert loop_best == svc_best, (loop_best, svc_best)

        scps_loop = agg_spin_cycles / t_loop
        scps_svc = agg_spin_cycles / t_svc
        speedup = t_loop / t_svc
        emit(f"{csv_prefix}/{backend}/loop", t_loop * 1e6,
             f"spin_cycles_per_s={scps_loop:.3e}")
        emit(f"{csv_prefix}/{backend}/service", t_svc * 1e6,
             f"spin_cycles_per_s={scps_svc:.3e};requests_per_s="
             f"{n_instances/t_svc:.2f};speedup={speedup:.1f}x;"
             f"programs={svc.cache_info()['programs']}")
        report["backends"][backend] = {
            "loop_wall_s": t_loop,
            "service_wall_s": t_svc,
            "spin_cycles_per_s_loop": scps_loop,
            "spin_cycles_per_s_service": scps_svc,
            "requests_per_s": n_instances / t_svc,
            "speedup": speedup,
            "compiled_programs": svc.cache_info()["programs"],
            "best_cuts": svc_best,
        }

    if json_path:
        with open(json_path, "w") as f:
            json.dump(report, f, indent=2)
        print(f"wrote {json_path}")
    return report


def run_service_smoke(json_path: str = "BENCH_service.json"):
    """CI canary: 3 toy instances through sparse + pallas-interpret."""
    return run_service(
        n_instances=3, trials=4, m_shot=2, problem_n=64,
        backends=("sparse", "pallas"), csv_prefix="service_smoke",
        json_path=json_path,
    )


# ---------------------------------------------------------------------------
# Packed-memory benchmark: dense vs packed storage, measured live bytes
# ---------------------------------------------------------------------------
def _measure_config(model, backend_name, layout, hp, backend_opts):
    """Measured live buffers of one (backend, layout) configuration.

    Builds the batched backend exactly as the timed solve does, materializes
    its engine state eagerly, and sizes the actual device arrays.  When the
    configuration's datapath is the pregen one (``noise_mode='pregen'`` —
    the dense baseline), it also sizes the (C, B, T, N) int8 noise buffer
    that datapath materializes on every plateau of the timed run, from a
    real allocation at the run's τ via the backend's own ``_pregen``.  A
    streamed configuration is never charged for it (its kernel generates
    noise in-kernel; tests assert no such buffer exists in its program).
    """
    from repro.core.engine import bucket_n, make_batched_backend

    nb = bucket_n(model.n)
    trials = hp.n_trials
    bk = make_batched_backend(
        backend_name, n_bucket=nb, n_trials=trials, n_rnd=hp.n_rnd,
        noise="xorshift", storage_layout=layout, **backend_opts,
    )
    stacked = bk.stack([model])
    ns0 = bk.init_noise([0], [model.n])
    state = jax.block_until_ready(bk.init_state(stacked, ns0))
    state_bytes = memory.tree_device_bytes(state)
    noise_bytes = 0
    if getattr(bk, "noise_mode", None) == "pregen":
        _, noise = bk._pregen(ns0, hp.tau)
        noise_bytes = memory.tree_device_bytes(jax.block_until_ready(noise))
        del noise
    j_mode = getattr(bk, "j_mode", "dense")
    if j_mode != "dense" and "J" in stacked:  # survives python -O
        raise RuntimeError("tiled mode leaked dense J into the stacked problem")
    return {
        "bucket": nb,
        "j_mode": j_mode,
        "noise_mode": getattr(bk, "noise_mode", "scan"),
        "state_bytes": int(state_bytes),
        "noise_bytes": int(noise_bytes),
        "live_bytes": int(state_bytes + noise_bytes),
        "bytes_per_spin": (state_bytes + noise_bytes) / (trials * nb),
    }


def run_memory(
    instances=("G11", "K2000", "G77"),
    json_path: str = "BENCH_memory.json",
    smoke: bool = False,
    csv_prefix: str = "memory_bench",
):
    """Dense vs packed storage: measured bytes/spin and spin-cycles/s.

    G11 and K2000 run the resident pallas kernel (interpret mode on CPU);
    G77 (N=14383) runs the tiled-J dense backend — the configuration whose
    dense (N, N) J would be ~1 GB and is never materialized.  Solves go
    through the AnnealService end-to-end; dense and packed layouts must
    return identical best cuts (bit-identity, asserted).

    The dense pallas baseline runs the *pregen* datapath
    (``noise_mode='pregen'``: the pre-refactor configuration, bit-identical
    results), so the (C, T, N) noise buffer it is charged for is one its
    timed plateaus genuinely materialize.  The packed configuration runs
    the streamed kernel.  Each layout's solve is timed on a warm second
    call — the first call compiles; the reported spin-cycles/s is
    steady-state, not trace time.
    """
    from repro.serve import AnnealRequest, AnnealService

    # (backend, smoke hp, full hp) per instance.  K2000 keeps the Table-II
    # plateau length τ=100 even in smoke (the cycle budget is cut via m_shot
    # and i0_max instead) so the pregen baseline's noise buffer is measured
    # at the canonical per-plateau depth.
    specs = {
        "G11": ("pallas",
                SSAHyperParams(n_trials=4, m_shot=1, tau=4, i0_max=8),
                SSAHyperParams(n_trials=8, m_shot=2, tau=20, i0_max=32)),
        "K2000": ("pallas",
                  SSAHyperParams(n_trials=2, m_shot=1, tau=100, i0_max=2),
                  SSAHyperParams(n_trials=8, m_shot=1, tau=100, i0_max=8)),
        "G77": ("dense",
                SSAHyperParams(n_trials=2, m_shot=1, tau=2, i0_max=2),
                SSAHyperParams(n_trials=4, m_shot=1, tau=4, i0_max=4)),
    }
    report = {
        "smoke": smoke,
        "acceptance_min_ratio": MEMORY_ACCEPT_RATIO,
        "instances": {},
    }
    for name in instances:
        backend_name, hp_smoke, hp_full = specs[name]
        hp = hp_smoke if smoke else hp_full
        p = gset.load(name)
        model = p.to_ising()
        row = {"n": p.n, "backend": backend_name, "trials": hp.n_trials,
               "cycles": hp.total_cycles}
        cuts = {}
        for layout in ("dense", "packed"):
            opts = (
                {"noise_mode": "pregen"}
                if backend_name == "pallas" and layout == "dense"
                else {}
            )
            meas = _measure_config(model, backend_name, layout, hp, opts)
            svc = AnnealService(backend=backend_name, noise="xorshift",
                                storage_layout=layout, backend_opts=opts)
            reqs = [AnnealRequest(problem=p, hp=hp, seed=0)]
            svc.solve(reqs)  # warm-up: compile the plateau program
            t0 = time.perf_counter()
            resp = svc.solve(reqs)[0]
            wall = time.perf_counter() - t0
            spin_cycles = hp.total_cycles * hp.n_trials * p.n
            meas.update({
                "wall_s": wall,
                "spin_cycles_per_s": spin_cycles / wall,
                "best_cut": int(resp.result.overall_best_cut),
            })
            cuts[layout] = int(resp.result.overall_best_cut)
            row[layout] = meas
            emit(f"{csv_prefix}/{name}/{layout}", wall * 1e6,
                 f"bytes_per_spin={meas['bytes_per_spin']:.2f};"
                 f"spin_cycles_per_s={meas['spin_cycles_per_s']:.3e};"
                 f"best={meas['best_cut']};j_mode={meas['j_mode']};"
                 f"noise_mode={meas['noise_mode']}")
        if cuts["dense"] != cuts["packed"]:  # gate survives python -O
            raise RuntimeError(
                f"{name}: packed/dense bit-identity broke: {cuts}"
            )
        row["ratio_dense_over_packed"] = (
            row["dense"]["live_bytes"] / row["packed"]["live_bytes"]
        )
        emit(f"{csv_prefix}/{name}/ratio", 0.0,
             f"{row['ratio_dense_over_packed']:.2f}x")
        report["instances"][name] = row

    if "K2000" in report["instances"]:
        k_ratio = report["instances"]["K2000"]["ratio_dense_over_packed"]
        report["k2000_ratio"] = k_ratio
        report["acceptance_ok"] = bool(k_ratio >= MEMORY_ACCEPT_RATIO)
        emit(f"{csv_prefix}/k2000_acceptance", 0.0,
             f"ratio={k_ratio:.2f};ok={report['acceptance_ok']}")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(report, f, indent=2)
        print(f"wrote {json_path}")
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--service", action="store_true",
                    help="8×G11-class service-vs-loop acceptance benchmark")
    ap.add_argument("--service-smoke", action="store_true",
                    help="CI smoke: 3 toy instances, sparse + pallas")
    ap.add_argument("--memory", action="store_true",
                    help="dense vs packed measured bytes/spin (G11/K2000/G77)")
    ap.add_argument("--memory-smoke", action="store_true",
                    help="CI smoke: --memory on a reduced cycle budget")
    ap.add_argument("--json", default=None)
    ap.add_argument("--min-speedup", type=float, default=None,
                    help="--service gate: fail if the batched service is not "
                         "at least this many × faster than the per-request "
                         "loop (nightly regression gate; PR-2 acceptance "
                         "was 3x)")
    args = ap.parse_args()
    if args.memory or args.memory_smoke:
        report = run_memory(json_path=args.json or "BENCH_memory.json",
                            smoke=args.memory_smoke)
        if report.get("acceptance_ok") is False:
            print(
                f"FAIL: K2000 dense/packed live-byte ratio "
                f"{report['k2000_ratio']:.2f} is below the "
                f"{MEMORY_ACCEPT_RATIO}x acceptance bar",
                file=sys.stderr,
            )
            sys.exit(1)
    elif args.service_smoke:
        run_service_smoke(json_path=args.json or "BENCH_service.json")
    elif args.service:
        report = run_service(json_path=args.json or "BENCH_service.json")
        if args.min_speedup is not None:
            slow = {
                bk: r["speedup"]
                for bk, r in report["backends"].items()
                if r["speedup"] < args.min_speedup
            }
            if slow:
                print(
                    f"FAIL: service speedup below the {args.min_speedup}x "
                    f"gate: {slow}",
                    file=sys.stderr,
                )
                sys.exit(1)
    else:
        run()
