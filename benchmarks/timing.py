"""Paper Table V: annealing time, HA-SSA hardware vs SA (CPU).

The paper's FPGA does 90,000 cycles at 100 MHz = 0.9 ms.  We report:
  * measured JAX wall-time of the plateau engine per backend
    (spin-cycles/s = cycles × trials × N / s — the acceptance metric for
    the single-contraction-per-cycle engine),
  * the SA baseline at equal cycle budget,
  * the modeled 100 MHz-equivalent (cycles × 10 ns) for comparability,
  * the TPU-projected time from the resident-kernel roofline
    (dense J resident in VMEM: per cycle ≈ max(matmul flops / 197 TF,
    noise+state HBM traffic / 819 GB/s) per chip).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import SAHyperParams, SSAHyperParams, anneal, anneal_sa, gset

from .common import emit


def run(problems=("G11", "King1"), trials: int = 8, m_shot: int = 10,
        backends=("sparse", "dense"), csv_prefix: str = "table5_timing"):
    out = {}
    for name in problems:
        p = gset.load(name)
        hp = SSAHyperParams(n_trials=trials, m_shot=m_shot)
        cycles = hp.total_cycles
        spin_cycles = cycles * trials * p.n

        t_ha = None
        for backend in backends:
            t0 = time.perf_counter()
            r = anneal(p, hp, seed=0, track_energy=False, noise="xorshift",
                       backend=backend)
            t_bk = time.perf_counter() - t0
            emit(f"{csv_prefix}/{name}/hassa_{backend}", t_bk * 1e6,
                 f"best={r.overall_best_cut};avg={r.mean_best_cut:.1f};"
                 f"cycles={cycles};spin_cycles_per_s={spin_cycles/t_bk:.3e}")
            if t_ha is None:
                t_ha = t_bk

        t0 = time.perf_counter()
        r_sa = anneal_sa(
            p, SAHyperParams(n_trials=trials, n_cycles=cycles), seed=0,
            track_energy=False,
        )
        t_sa = time.perf_counter() - t0

        hw_ms = cycles * 10e-9 * 1e3  # 100 MHz FPGA model
        # TPU v5e resident-kernel model (batched trials, one chip):
        n = p.n
        flops_per_cycle = 2 * trials * n * n
        bytes_per_cycle = trials * n * (1 + 4 + 4)  # noise int8 + state rw
        t_tpu = cycles * max(flops_per_cycle / 197e12, bytes_per_cycle / 819e9)

        emit(f"{csv_prefix}/{name}/sa_cpu", t_sa * 1e6,
             f"best={r_sa.overall_best_cut};avg={r_sa.mean_best_cut:.1f}")
        emit(f"{csv_prefix}/{name}/fpga_100mhz_model_ms", 0.0, f"{hw_ms:.2f}")
        emit(f"{csv_prefix}/{name}/tpu_v5e_model_ms", 0.0, f"{t_tpu*1e3:.3f}")
        emit(f"{csv_prefix}/{name}/jax_speedup_vs_sa", 0.0, f"{t_sa/t_ha:.1f}x")
        out[name] = dict(t_ha=t_ha, t_sa=t_sa, hw_ms=hw_ms)
    return out


if __name__ == "__main__":
    run()
