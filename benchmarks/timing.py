"""Paper Table V: annealing time, HA-SSA hardware vs SA (CPU) — plus the
serving-layer throughput benchmark.

The paper's FPGA does 90,000 cycles at 100 MHz = 0.9 ms.  We report:
  * measured JAX wall-time of the plateau engine per backend
    (spin-cycles/s = cycles × trials × N / s — the acceptance metric for
    the single-contraction-per-cycle engine),
  * the SA baseline at equal cycle budget,
  * the modeled 100 MHz-equivalent (cycles × 10 ns) for comparability,
  * the TPU-projected time from the resident-kernel roofline
    (dense J resident in VMEM: per cycle ≈ max(matmul flops / 197 TF,
    noise+state HBM traffic / 819 GB/s) per chip).

:func:`run_service` benchmarks the shape-bucketed AnnealService against the
pre-service per-request Python loop (one retrace + recompile per request):
aggregate spin-cycles/s and requests/s over a batch of same-bucket
instances, written to ``BENCH_service.json``.  The acceptance bar for the
serving PR is ≥3× aggregate spin-cycles/s on a batch of 8 G11-class
instances.

    python -m benchmarks.timing                   # Table V rows
    python -m benchmarks.timing --service         # 8×G11-class acceptance run
    python -m benchmarks.timing --service-smoke   # CI: 3 toy instances,
                                                  #     sparse + pallas
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import SAHyperParams, SSAHyperParams, anneal, anneal_sa, gset

from .common import emit


def run(problems=("G11", "King1"), trials: int = 8, m_shot: int = 10,
        backends=("sparse", "dense"), csv_prefix: str = "table5_timing"):
    out = {}
    for name in problems:
        p = gset.load(name)
        hp = SSAHyperParams(n_trials=trials, m_shot=m_shot)
        cycles = hp.total_cycles
        spin_cycles = cycles * trials * p.n

        t_ha = None
        for backend in backends:
            t0 = time.perf_counter()
            r = anneal(p, hp, seed=0, track_energy=False, noise="xorshift",
                       backend=backend)
            t_bk = time.perf_counter() - t0
            emit(f"{csv_prefix}/{name}/hassa_{backend}", t_bk * 1e6,
                 f"best={r.overall_best_cut};avg={r.mean_best_cut:.1f};"
                 f"cycles={cycles};spin_cycles_per_s={spin_cycles/t_bk:.3e}")
            if t_ha is None:
                t_ha = t_bk

        t0 = time.perf_counter()
        r_sa = anneal_sa(
            p, SAHyperParams(n_trials=trials, n_cycles=cycles), seed=0,
            track_energy=False,
        )
        t_sa = time.perf_counter() - t0

        hw_ms = cycles * 10e-9 * 1e3  # 100 MHz FPGA model
        # TPU v5e resident-kernel model (batched trials, one chip):
        n = p.n
        flops_per_cycle = 2 * trials * n * n
        bytes_per_cycle = trials * n * (1 + 4 + 4)  # noise int8 + state rw
        t_tpu = cycles * max(flops_per_cycle / 197e12, bytes_per_cycle / 819e9)

        emit(f"{csv_prefix}/{name}/sa_cpu", t_sa * 1e6,
             f"best={r_sa.overall_best_cut};avg={r_sa.mean_best_cut:.1f}")
        emit(f"{csv_prefix}/{name}/fpga_100mhz_model_ms", 0.0, f"{hw_ms:.2f}")
        emit(f"{csv_prefix}/{name}/tpu_v5e_model_ms", 0.0, f"{t_tpu*1e3:.3f}")
        emit(f"{csv_prefix}/{name}/jax_speedup_vs_sa", 0.0, f"{t_sa/t_ha:.1f}x")
        out[name] = dict(t_ha=t_ha, t_sa=t_sa, hw_ms=hw_ms)
    return out


def run_service(
    n_instances: int = 8,
    trials: int = 8,
    m_shot: int = 2,
    problem_n: int = 800,
    backends=("sparse",),
    csv_prefix: str = "service_timing",
    json_path: str = "BENCH_service.json",
):
    """Batched service vs per-request Python loop, same requests.

    The loop path is the pre-service serving story: each request builds a
    fresh backend and re-traces/re-compiles the whole plateau program.  The
    service path pads every instance to one shape bucket, stacks the batch
    on the problem axis and runs ONE compiled plateau program.
    """
    from repro.serve import AnnealRequest, AnnealService

    problems = [
        gset.toroidal_grid(problem_n, seed=100 + i, name=f"G11c{i}")
        for i in range(n_instances)
    ]
    hp = SSAHyperParams(n_trials=trials, m_shot=m_shot)
    agg_spin_cycles = sum(hp.total_cycles * trials * p.n for p in problems)
    report = {
        "n_instances": n_instances,
        "trials": trials,
        "m_shot": m_shot,
        "problem_n": problem_n,
        "aggregate_spin_cycles": agg_spin_cycles,
        "backends": {},
    }

    for backend in backends:
        # Per-request Python loop (re-trace + re-compile per request).
        t0 = time.perf_counter()
        loop_best = [
            anneal(p, hp, seed=100 + i, noise="xorshift", backend=backend,
                   track_energy=False).overall_best_cut
            for i, p in enumerate(problems)
        ]
        t_loop = time.perf_counter() - t0

        # Shape-bucketed service: one compile per bucket, one device launch.
        svc = AnnealService(backend=backend, noise="xorshift")
        reqs = [
            AnnealRequest(problem=p, hp=hp, seed=100 + i)
            for i, p in enumerate(problems)
        ]
        t0 = time.perf_counter()
        responses = svc.solve(reqs)
        t_svc = time.perf_counter() - t0
        svc_best = [r.result.overall_best_cut for r in responses]

        # The loop and the service run identical padded-invariant math.
        assert loop_best == svc_best, (loop_best, svc_best)

        scps_loop = agg_spin_cycles / t_loop
        scps_svc = agg_spin_cycles / t_svc
        speedup = t_loop / t_svc
        emit(f"{csv_prefix}/{backend}/loop", t_loop * 1e6,
             f"spin_cycles_per_s={scps_loop:.3e}")
        emit(f"{csv_prefix}/{backend}/service", t_svc * 1e6,
             f"spin_cycles_per_s={scps_svc:.3e};requests_per_s="
             f"{n_instances/t_svc:.2f};speedup={speedup:.1f}x;"
             f"programs={svc.cache_info()['programs']}")
        report["backends"][backend] = {
            "loop_wall_s": t_loop,
            "service_wall_s": t_svc,
            "spin_cycles_per_s_loop": scps_loop,
            "spin_cycles_per_s_service": scps_svc,
            "requests_per_s": n_instances / t_svc,
            "speedup": speedup,
            "compiled_programs": svc.cache_info()["programs"],
            "best_cuts": svc_best,
        }

    if json_path:
        with open(json_path, "w") as f:
            json.dump(report, f, indent=2)
        print(f"wrote {json_path}")
    return report


def run_service_smoke(json_path: str = "BENCH_service.json"):
    """CI canary: 3 toy instances through sparse + pallas-interpret."""
    return run_service(
        n_instances=3, trials=4, m_shot=2, problem_n=64,
        backends=("sparse", "pallas"), csv_prefix="service_smoke",
        json_path=json_path,
    )


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--service", action="store_true",
                    help="8×G11-class service-vs-loop acceptance benchmark")
    ap.add_argument("--service-smoke", action="store_true",
                    help="CI smoke: 3 toy instances, sparse + pallas")
    ap.add_argument("--json", default="BENCH_service.json")
    args = ap.parse_args()
    if args.service_smoke:
        run_service_smoke(json_path=args.json)
    elif args.service:
        run_service(json_path=args.json)
    else:
        run()
